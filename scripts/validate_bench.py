#!/usr/bin/env python3
"""Validate a BENCH_*.json trajectory against its checked-in schema.

Dependency-free (no jsonschema wheel in CI): implements the subset of
JSON Schema the schemas in scripts/ use — type, required, properties,
items, minItems, enum, minimum, exclusiveMinimum, maximum,
exclusiveMaximum — plus the custom
``x-contains-engines`` key: every listed name must appear as the
``engine`` field of some element of the array under validation.

Usage: validate_bench.py <data.json> <schema.json>
"""

import json
import sys

TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "boolean": bool,
    # bool is an int subclass in Python; excluded explicitly below
    "integer": int,
    "number": (int, float),
}


class ValidationError(Exception):
    pass


def check(data, schema, path="$"):
    t = schema.get("type")
    if t is not None:
        expected = TYPES[t]
        ok = isinstance(data, expected) and not (
            t in ("integer", "number") and isinstance(data, bool)
        )
        if t == "integer" and isinstance(data, float):
            ok = data.is_integer()
        if not ok:
            raise ValidationError(f"{path}: expected {t}, got {type(data).__name__}")

    if "enum" in schema and data not in schema["enum"]:
        raise ValidationError(f"{path}: {data!r} not in {schema['enum']}")

    if isinstance(data, (int, float)) and not isinstance(data, bool):
        if "minimum" in schema and data < schema["minimum"]:
            raise ValidationError(f"{path}: {data} < minimum {schema['minimum']}")
        if "exclusiveMinimum" in schema and data <= schema["exclusiveMinimum"]:
            raise ValidationError(
                f"{path}: {data} <= exclusiveMinimum {schema['exclusiveMinimum']}"
            )
        if "maximum" in schema and data > schema["maximum"]:
            raise ValidationError(f"{path}: {data} > maximum {schema['maximum']}")
        if "exclusiveMaximum" in schema and data >= schema["exclusiveMaximum"]:
            raise ValidationError(
                f"{path}: {data} >= exclusiveMaximum {schema['exclusiveMaximum']}"
            )

    if isinstance(data, dict):
        for key in schema.get("required", []):
            if key not in data:
                raise ValidationError(f"{path}: missing required key {key!r}")
        for key, sub in schema.get("properties", {}).items():
            if key in data:
                check(data[key], sub, f"{path}.{key}")

    if isinstance(data, list):
        if "minItems" in schema and len(data) < schema["minItems"]:
            raise ValidationError(
                f"{path}: {len(data)} items < minItems {schema['minItems']}"
            )
        if "items" in schema:
            for i, item in enumerate(data):
                check(item, schema["items"], f"{path}[{i}]")
        for name in schema.get("x-contains-engines", []):
            if not any(
                isinstance(item, dict) and item.get("engine") == name for item in data
            ):
                raise ValidationError(f"{path}: no element with engine == {name!r}")


def main():
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    data_path, schema_path = sys.argv[1], sys.argv[2]
    with open(data_path) as f:
        data = json.load(f)
    with open(schema_path) as f:
        schema = json.load(f)
    try:
        check(data, schema)
    except ValidationError as e:
        print(f"FAIL {data_path}: {e}", file=sys.stderr)
        sys.exit(1)
    print(f"OK {data_path} conforms to {schema_path}")


if __name__ == "__main__":
    main()
