#!/usr/bin/env python3
"""Verify that relative links and path references in the repo's
top-level docs resolve to real files.

Checks two things in README.md / DESIGN.md / ARCHITECTURE.md (and any
extra files passed on the command line):

  1. markdown links `[text](target)` whose target is a relative path
     (external URLs and intra-page anchors are skipped);
  2. backtick path references like `rust/src/em/` or
     `rust/tests/property_em.rs` (a repo-relative path containing a
     `/`), so the prose's pointers stay honest too.

Dependency-free by design: CI and pre-commit hooks can run it with a
bare python3. Exits non-zero listing every dangling reference.
"""
import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(#[^)\s]*)?\)")
PATHREF = re.compile(r"`([A-Za-z0-9_.\-]+(?:/[A-Za-z0-9_.\-]+)+/?)`")

DEFAULT_DOCS = ["README.md", "DESIGN.md", "ARCHITECTURE.md"]


def is_external(target: str) -> bool:
    return target.startswith(("http://", "https://", "mailto:", "//"))


def looks_like_path(ref: str, root: Path) -> bool:
    """Backtick references that are plausibly repo paths: start with a
    known top-level entry (resolved against the repo root, never the
    process cwd) and contain no spaces or glob characters."""
    top = ref.split("/", 1)[0]
    if any(ch in ref for ch in "*{}<>$"):
        return False
    return ((root / top).exists() or top in DEFAULT_DOCS) and "/" in ref


def check(doc: Path, root: Path) -> list:
    problems = []
    text = doc.read_text(encoding="utf-8")
    for lineno, line in enumerate(text.splitlines(), 1):
        for m in LINK.finditer(line):
            target = m.group(1)
            if is_external(target):
                continue
            resolved = (doc.parent / target).resolve()
            if not resolved.exists():
                problems.append((doc, lineno, f"link target missing: {target}"))
        for m in PATHREF.finditer(line):
            ref = m.group(1)
            if not looks_like_path(ref, root):
                continue
            if not (root / ref).exists():
                problems.append((doc, lineno, f"path reference missing: {ref}"))
    return problems


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    docs = [Path(a) for a in sys.argv[1:]] or [root / d for d in DEFAULT_DOCS]
    problems = []
    checked = 0
    for doc in docs:
        if not doc.exists():
            problems.append((doc, 0, "document itself is missing"))
            continue
        checked += 1
        problems.extend(check(doc, root))
    for doc, lineno, msg in problems:
        print(f"{doc}:{lineno}: {msg}")
    if problems:
        print(f"check_doc_links: {len(problems)} dangling reference(s)")
        return 1
    print(f"check_doc_links OK ({checked} documents)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
