#!/usr/bin/env python3
"""Validate a Prometheus text-exposition (0.0.4) export.

Dependency-free checker for the files ``rust/src/obs/export.rs``'s
``prometheus_text`` emits (``fgp health --prom``, the E18 bench's
``BENCH_health_prom.txt``). Verifies:

* every non-comment line is ``name[{labels}] value`` with a legal
  metric name (``[a-zA-Z_:][a-zA-Z0-9_:]*``) and a finite number;
* every sample is preceded by a ``# TYPE`` declaration of its family,
  each family is declared exactly once, and the declared type is one of
  ``counter``/``gauge``/``summary``;
* ``summary`` families carry ``quantile`` labels plus ``_sum`` and
  ``_count`` rows, and their quantile values are non-decreasing in the
  quantile (p50 <= p95 <= p99 for the nanosecond histograms);
* no family mixes types and no sample line appears under no family.

Usage: check_prom_text.py <export.txt> [required,family,names]

The optional second argument is a comma-separated list of family names
that must each be declared — CI uses it to pin the serve/farm families
of a health-enabled server. With ``--self-test`` as the only argument,
runs against built-in good and bad fixtures and exits non-zero on any
checker defect.
"""

import math
import re
import sys

NAME = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
SAMPLE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r" (?P<value>\S+)$"
)
LABEL = re.compile(r'^([a-zA-Z_][a-zA-Z0-9_]*)="([^"\\]*)"$')
TYPES = {"counter", "gauge", "summary"}


class CheckError(Exception):
    pass


def base_family(name, families):
    """The declared family a sample row belongs to: exact match, or the
    summary family behind its ``_sum``/``_count`` rows."""
    if name in families:
        return name
    for suffix in ("_sum", "_count"):
        if name.endswith(suffix) and name[: -len(suffix)] in families:
            return name[: -len(suffix)]
    return None


def parse_labels(raw):
    if raw is None or raw == "":
        return {}
    out = {}
    for part in raw.split(","):
        m = LABEL.match(part)
        if not m:
            raise CheckError(f"malformed label pair {part!r}")
        out[m.group(1)] = m.group(2)
    return out


def check_text(text, required=()):
    families = {}
    samples = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 2 and parts[1] == "TYPE":
                if len(parts) != 4:
                    raise CheckError(f"line {lineno}: malformed TYPE comment: {line!r}")
                _, _, name, typ = parts
                if not NAME.match(name):
                    raise CheckError(f"line {lineno}: illegal family name {name!r}")
                if typ not in TYPES:
                    raise CheckError(f"line {lineno}: unknown type {typ!r}")
                if name in families:
                    raise CheckError(f"line {lineno}: family {name!r} declared twice")
                families[name] = typ
            continue
        m = SAMPLE.match(line)
        if not m:
            raise CheckError(f"line {lineno}: not a sample line: {line!r}")
        name, labels = m.group("name"), parse_labels(m.group("labels"))
        try:
            value = float(m.group("value"))
        except ValueError:
            raise CheckError(f"line {lineno}: non-numeric value {m.group('value')!r}")
        if not math.isfinite(value):
            raise CheckError(f"line {lineno}: non-finite value in {name!r}")
        family = base_family(name, families)
        if family is None:
            raise CheckError(f"line {lineno}: sample {name!r} has no TYPE declaration")
        samples.setdefault(family, []).append((name, labels, value))

    for family, typ in families.items():
        rows = samples.get(family, [])
        if not rows:
            raise CheckError(f"family {family!r} declared but never sampled")
        if typ in ("counter", "gauge"):
            for name, labels, value in rows:
                if labels:
                    raise CheckError(f"{typ} {family!r} carries labels {labels}")
                if value < 0:
                    raise CheckError(f"{typ} {family!r} is negative ({value})")
        else:  # summary
            quantiles = sorted(
                (float(labels["quantile"]), value)
                for name, labels, value in rows
                if name == family and "quantile" in labels
            )
            if not quantiles:
                raise CheckError(f"summary {family!r} has no quantile rows")
            suffixes = {name for name, _, _ in rows}
            for need in (family + "_sum", family + "_count"):
                if need not in suffixes:
                    raise CheckError(f"summary {family!r} is missing {need}")
            values = [v for _, v in quantiles]
            if values != sorted(values):
                raise CheckError(
                    f"summary {family!r} quantiles are not monotone: {quantiles}"
                )

    missing = [n for n in required if n not in families]
    if missing:
        raise CheckError(f"required family(ies) missing: {missing}")
    return len(families), sum(len(v) for v in samples.values())


GOOD = """\
# TYPE fgp_serve_admitted counter
fgp_serve_admitted 42
# TYPE fgp_serve_inflight gauge
fgp_serve_inflight 3
# TYPE fgp_serve_latency_ns summary
fgp_serve_latency_ns{quantile="0.5"} 767
fgp_serve_latency_ns{quantile="0.95"} 1535
fgp_serve_latency_ns{quantile="0.99"} 1535
fgp_serve_latency_ns_sum 51000
fgp_serve_latency_ns_count 42
"""

BAD = [
    "fgp_orphan 1\n",  # sample without a TYPE declaration
    "# TYPE fgp_x counter\nfgp_x nan\n",  # non-finite value
    "# TYPE fgp_x counter\n# TYPE fgp_x gauge\nfgp_x 1\n",  # redeclared
    "# TYPE fgp_x histogram\nfgp_x 1\n",  # unknown type
    "# TYPE fgp_x summary\nfgp_x_sum 1\nfgp_x_count 1\n",  # no quantiles
    # non-monotone quantiles
    '# TYPE fgp_x summary\nfgp_x{quantile="0.5"} 9\nfgp_x{quantile="0.99"} 1\n'
    "fgp_x_sum 1\nfgp_x_count 1\n",
    "# TYPE fgp_x counter\nfgp_x\n",  # sample with no value
]


def self_test():
    check_text(GOOD, required=["fgp_serve_admitted", "fgp_serve_latency_ns"])
    try:
        check_text(GOOD, required=["fgp_missing"])
        raise SystemExit("self-test: missing required family not caught")
    except CheckError:
        pass
    for i, bad in enumerate(BAD):
        try:
            check_text(bad)
            raise SystemExit(f"self-test: bad fixture {i} passed validation")
        except CheckError:
            pass
    print("OK self-test: good fixture accepted, all bad fixtures rejected")


def main():
    if len(sys.argv) == 2 and sys.argv[1] == "--self-test":
        self_test()
        return
    if len(sys.argv) not in (2, 3):
        sys.exit(__doc__)
    required = [n for n in sys.argv[2].split(",") if n] if len(sys.argv) == 3 else []
    with open(sys.argv[1]) as f:
        text = f.read()
    try:
        nfam, nsamp = check_text(text, required)
    except CheckError as e:
        print(f"FAIL {sys.argv[1]}: {e}", file=sys.stderr)
        sys.exit(1)
    print(f"OK {sys.argv[1]}: {nfam} family(ies), {nsamp} sample(s)")


if __name__ == "__main__":
    main()
