#!/usr/bin/env python3
"""Structurally validate a Chrome trace-event JSON export.

Dependency-free checker for the files ``rust/src/obs/export.rs`` emits
(and ``chrome://tracing`` / Perfetto load). Verifies the envelope is
``{"traceEvents": [...]}`` with at least one complete event, and that
every event is well-formed:

* ``ph`` is ``"X"`` (complete event) or ``"M"`` (metadata);
* ``X`` events carry a non-empty ``name``, a ``cat`` (the recording
  layer), integer ``pid``/``tid``, non-negative numeric ``ts``/``dur``
  (microseconds), and ``args`` with ``trace_id``/``span_id``/
  ``parent_id`` as ``0x``-prefixed ids plus a numeric ``a0``;
* ``M`` events are ``thread_name`` rows naming a layer.

Usage: check_trace_json.py <trace.json> [required,span,names]

The optional second argument is a comma-separated list of span names
that must each appear as some ``X`` event — CI uses it to pin the full
client-to-device chain of one traced request.
"""

import json
import sys


def fail(msg):
    print(f"FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_id(event, key, i):
    v = event.get("args", {}).get(key)
    if not (isinstance(v, str) and v.startswith("0x") and len(v) == 18):
        fail(f"event[{i}]: args.{key} is not an 0x-prefixed 64-bit id: {v!r}")


def main():
    if len(sys.argv) not in (2, 3):
        sys.exit(__doc__)
    path = sys.argv[1]
    required = [n for n in sys.argv[2].split(",") if n] if len(sys.argv) == 3 else []

    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        fail(f"{path}: root is not an object with a traceEvents array")
    events = doc["traceEvents"]

    names = set()
    complete = 0
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            fail(f"event[{i}]: not an object")
        ph = e.get("ph")
        if ph == "M":
            if e.get("name") != "thread_name":
                fail(f"event[{i}]: metadata event is not a thread_name row")
            if not e.get("args", {}).get("name"):
                fail(f"event[{i}]: thread_name row names no layer")
        elif ph == "X":
            complete += 1
            name = e.get("name")
            if not (isinstance(name, str) and name):
                fail(f"event[{i}]: complete event has no name")
            names.add(name)
            if not (isinstance(e.get("cat"), str) and e["cat"]):
                fail(f"event[{i}]: complete event has no cat (layer)")
            for key in ("pid", "tid"):
                if not (isinstance(e.get(key), int) and not isinstance(e[key], bool)):
                    fail(f"event[{i}]: {key} is not an integer")
            for key in ("ts", "dur"):
                v = e.get(key)
                if not (isinstance(v, (int, float)) and not isinstance(v, bool)):
                    fail(f"event[{i}]: {key} is not numeric")
                if v < 0:
                    fail(f"event[{i}]: {key} is negative")
            for key in ("trace_id", "span_id", "parent_id"):
                check_id(e, key, i)
            a0 = e.get("args", {}).get("a0")
            if not (isinstance(a0, int) and not isinstance(a0, bool)):
                fail(f"event[{i}]: args.a0 is not an integer")
        else:
            fail(f"event[{i}]: unexpected ph {ph!r}")

    if complete == 0:
        fail(f"{path}: no complete (ph=X) events")
    missing = [n for n in required if n not in names]
    if missing:
        fail(f"{path}: required span(s) missing from the trace: {missing}")

    print(f"OK {path}: {complete} complete event(s), {len(events) - complete} metadata row(s)")


if __name__ == "__main__":
    main()
