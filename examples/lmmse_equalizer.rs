//! LMMSE block equalization (paper §I: "linear MMSE equalization").
//!
//! Sweeps SNR and reports symbol error rate for the golden f64 engine
//! and the cycle-accurate FGP simulator — the second program a baseband
//! receiver would keep in the FGP's program memory next to the RLS
//! estimator (§III's multi-program scenario). Every block is one
//! single-section workload, so the device session compiles exactly one
//! program for the whole sweep.
//!
//! Run: `cargo run --release --example lmmse_equalizer`

use fgp_repro::apps::lmmse::{ser_sweep, LmmseProblem};
use fgp_repro::engine::Session;
use fgp_repro::fgp::FgpConfig;

fn main() -> anyhow::Result<()> {
    let n = fgp_repro::paper::N;
    println!("=== LMMSE equalization: SER vs SNR ===\n");

    let snrs = [0.0, 5.0, 10.0, 15.0, 20.0];
    let trials = 40;

    let mut golden = Session::golden();
    let golden_sweep = ser_sweep(&mut golden, n, &snrs, trials)?;

    let mut sim = Session::fgp_sim(FgpConfig::default());
    let fgp_sweep = ser_sweep(&mut sim, n, &snrs, trials)?;

    println!("{:>8} {:>12} {:>12}", "SNR dB", "golden SER", "FGP SER");
    for ((snr, g), (_, f)) in golden_sweep.iter().zip(&fgp_sweep) {
        println!("{snr:>8.1} {g:>12.4} {f:>12.4}");
    }

    // single-block detail at moderate SNR
    let p = LmmseProblem::synthetic(n, 0.01, 7);
    let o = golden.run(&p)?;
    println!(
        "\nexample block @14dB: {} symbol errors, rel MSE {:.4}",
        o.outcome.symbol_errors, o.outcome.rel_mse
    );
    let cache = sim.cache_stats();
    println!(
        "device program cache over {} blocks: {} miss, {} hits",
        snrs.len() * trials as usize,
        cache.misses,
        cache.hits
    );

    // SER must be monotone-ish in SNR for both engines
    assert!(golden_sweep.first().unwrap().1 >= golden_sweep.last().unwrap().1);
    assert!(fgp_sweep.first().unwrap().1 >= fgp_sweep.last().unwrap().1);
    println!("\nlmmse_equalizer OK");
    Ok(())
}
