//! Loopy GBP grid denoising: a cyclic workload the scheduled compiler
//! cannot express, served by `gbp` with every inner update running as a
//! compound-node workload — on the golden engine, on the cycle-accurate
//! device, and sharded across a device farm.
//!
//! Run: `cargo run --release --example gbp_grid_denoise`

use fgp_repro::apps::grid::GridDenoise;
use fgp_repro::coordinator::{FgpFarm, RoutePolicy};
use fgp_repro::engine::Session;
use fgp_repro::fgp::FgpConfig;
use fgp_repro::gbp::{
    ConvergenceCriteria, FarmExecutor, GbpOptions, IterationPolicy,
};

fn render(label: &str, field: &[f64], rows: usize, cols: usize) {
    println!("{label}:");
    for r in 0..rows {
        let row: Vec<String> = (0..cols)
            .map(|c| format!("{:>6.2}", field[r * cols + c]))
            .collect();
        println!("  {}", row.join(" "));
    }
}

fn main() -> anyhow::Result<()> {
    let p = GridDenoise::synthetic(4, 4, 0.04, 42);
    println!("=== 2-D grid denoising via loopy GBP ===");
    println!(
        "{}x{} grid, obs noise var {}, smoothness var {}\n",
        p.rows, p.cols, p.obs_var, p.smooth_var
    );
    render("truth", &p.truth, p.rows, p.cols);
    render("noisy observations", &p.noisy, p.rows, p.cols);

    // golden engine, synchronous damped rounds
    let opts = GbpOptions {
        policy: IterationPolicy::Synchronous { eta_damping: 0.2 },
        ..Default::default()
    };
    let out = p.run(&mut Session::golden(), opts)?;
    render("\nGBP estimate (golden engine)", &out.estimate, p.rows, p.cols);
    println!(
        "\ngolden: {} iters ({:?}), final belief delta {:.2e}, {} messages",
        out.report.iterations, out.report.stop, out.report.final_delta,
        out.report.messages_sent
    );
    println!(
        "RMSE: noisy {:.4} -> smoothed {:.4}",
        out.noisy_rmse, out.rmse
    );

    // the exact dense reference (what GBP iterates towards)
    let dense = p.model()?.dense_marginals()?;
    let dense_field: Vec<f64> = dense.iter().map(|m| m.mean[0].re).collect();
    let max_mean_err = out
        .estimate
        .iter()
        .zip(&dense_field)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    println!("max |GBP mean - dense solve| = {max_mean_err:.2e}");

    // same model on the cycle-accurate device (fixed-point inner loop;
    // undamped so every committed number came off the Q5.10 datapath)
    let device_opts = GbpOptions {
        policy: IterationPolicy::Synchronous { eta_damping: 0.0 },
        criteria: ConvergenceCriteria { tol: 2e-2, max_iters: 40, divergence: 1e3 },
        init_var: 4.0,
        ..Default::default()
    };
    let dev = p.run(&mut Session::fgp_sim(FgpConfig::default()), device_opts)?;
    println!(
        "\nfgp-sim: {} iters ({:?}), RMSE {:.4} (Q5.10 fixed point)",
        dev.report.iterations, dev.report.stop, dev.rmse
    );

    // one round sharded across a 3-device farm
    let farm = FgpFarm::start(3, FgpConfig::default(), RoutePolicy::RoundRobin)?;
    let farmed = p.run(&mut FarmExecutor { farm: &farm }, device_opts)?;
    println!(
        "farm(3): {} iters ({:?}), RMSE {:.4}, device load {:?}",
        farmed.report.iterations, farmed.report.stop, farmed.rmse,
        farm.load_profile()
    );

    println!("\ngbp_grid_denoise OK");
    Ok(())
}
