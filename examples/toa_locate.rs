//! Time-of-arrival localization via GMP (paper §I, ref [6]).
//!
//! Anchors on the unit square range a hidden target; iteratively
//! linearized range measurements become compound-observation sweeps on
//! the FGP. Each relinearization round is one workload run; rounds after
//! the first hit the session's program cache. Reports position error vs
//! anchor count and vs relinearization rounds, golden vs fixed-point
//! device.
//!
//! Run: `cargo run --release --example toa_locate`

use fgp_repro::apps::toa::ToaProblem;
use fgp_repro::engine::Session;
use fgp_repro::fgp::FgpConfig;

fn main() -> anyhow::Result<()> {
    println!("=== ToA localization on the FGP ===\n");

    let mut golden = Session::golden();
    println!("{:>9} {:>14} {:>14}", "anchors", "golden err", "FGP err");
    for anchors in [4usize, 6, 8, 12] {
        let p = ToaProblem::synthetic(anchors, 1e-3, 17);
        let g = p.run(&mut golden, 2)?;
        let mut sim = Session::fgp_sim(FgpConfig::default());
        let f = p.run(&mut sim, 2)?;
        println!("{anchors:>9} {:>14.4} {:>14.4}", g.error, f.error);
    }

    println!("\nconvergence trace (6 anchors, golden):");
    let p = ToaProblem::synthetic(6, 1e-3, 21);
    let o = p.run(&mut golden, 4)?;
    for (round, (x, y)) in o.trace.iter().enumerate() {
        let err = ((x - p.target.0).powi(2) + (y - p.target.1).powi(2)).sqrt();
        println!("  round {}: estimate ({:.3}, {:.3}), error {:.4}", round + 1, x, y, err);
    }
    println!("  target: ({:.3}, {:.3})", p.target.0, p.target.1);

    // cache behaviour: 4 rounds on one device session = 1 compile + 3 hits
    let mut sim = Session::fgp_sim(FgpConfig::default());
    let _ = p.run(&mut sim, 4)?;
    let stats = sim.cache_stats();
    println!(
        "\ndevice program cache over 4 rounds: {} miss, {} hits",
        stats.misses, stats.hits
    );

    assert!(o.error < 0.05, "golden locator must converge");
    println!("\ntoa_locate OK");
    Ok(())
}
