//! The FGP as a served accelerator: coordinator + batched XLA offload.
//!
//! §III: "the FGP can be easily attached to an existing system as an
//! accelerator or a co-processor." This driver plays that system: a
//! multi-threaded client population fires compound-node update requests
//! at the coordinator, which batches them onto the PJRT `cn_update_batched`
//! artifact (falling back to the golden engine when `artifacts/` is not
//! built), and reports latency/throughput. A full RLS-chain workload
//! request rides the same queue ([`WorkloadRequest`]), showing the
//! coordinator serving compiled-program executions, not just raw CN
//! updates.
//!
//! It also demos the raw Fig. 5 command protocol against the
//! cycle-accurate device ([`FgpDevice`]).
//!
//! Run: `cargo run --release --example fgp_server`

use std::time::Instant;

use fgp_repro::apps::rls::RlsProblem;
use fgp_repro::coordinator::backend::{
    CnRequestData, GoldenBackend, WorkloadRequest, XlaBatchBackend,
};
use fgp_repro::coordinator::{BatchPolicy, CnServer, FgpDevice, ServerConfig};
use fgp_repro::engine::Workload;
use fgp_repro::fgp::FgpConfig;
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::runtime::RuntimeClient;
use fgp_repro::testutil::Rng;

fn request(rng: &mut Rng, n: usize) -> CnRequestData {
    CnRequestData {
        x: GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, n, 1.0).scale(0.15),
        ),
        y: GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, n, 1.0).scale(0.15),
        ),
        a: CMatrix::random(rng, n, n).scale(0.3),
    }
}

fn main() -> anyhow::Result<()> {
    let n = fgp_repro::paper::N;
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let use_xla = artifacts.join("manifest.txt").exists();

    println!("=== FGP coordinator serving CN updates ===");
    println!("backend: {}\n", if use_xla { "XLA batched (PJRT)" } else { "golden (artifacts missing)" });

    let config = ServerConfig {
        batch: BatchPolicy { max_batch: 32, max_wait: std::time::Duration::from_millis(2) },
    };
    let artifacts2 = artifacts.clone();
    let server = CnServer::start(
        move || {
            if use_xla {
                let rt = RuntimeClient::load(&artifacts2)?;
                Ok(Box::new(XlaBatchBackend::new(rt)?) as _)
            } else {
                Ok(Box::new(GoldenBackend) as _)
            }
        },
        config,
    )?;

    // --- load phase: 4 client threads x 200 requests
    let clients = 4;
    let per_client = 200;
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let client = server.client();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(7 + c as u64);
            let pending: Vec<_> =
                (0..per_client).map(|_| client.submit(request(&mut rng, n))).collect();
            for rx in pending {
                rx.recv().unwrap().unwrap();
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let elapsed = t0.elapsed();
    let total = clients * per_client;

    let client = server.client();
    println!("served {total} requests in {elapsed:?}");
    println!(
        "throughput: {:.0} CN updates/s",
        total as f64 / elapsed.as_secs_f64()
    );
    println!("metrics: {}", client.metrics().report());

    // --- a whole RLS-chain workload through the same queue
    let p = RlsProblem::synthetic(n, 16, 0.02, 77);
    let exec = client.run_workload(WorkloadRequest::from_workload(&p)?)?;
    let outcome = p.outcome(&exec)?;
    println!(
        "\nworkload request (16-section RLS chain): rel MSE {:.5}",
        outcome.rel_mse
    );
    server.shutdown();

    // --- raw command protocol against the cycle-accurate device
    // (typed helpers: protocol mismatches are errors, not match arms)
    println!("\n=== Fig. 5 command protocol (cycle-accurate device) ===");
    let dev = FgpDevice::start(FgpConfig::default());
    let (state, cycles) = dev.status()?;
    println!("status: {state:?}, {cycles} cycles");
    dev.write_message(0, GaussMessage::isotropic(n, 0.5))?;
    let m = dev.read_message(0)?;
    println!("slot 0 round-trip trace: {:.3}", m.trace_cov());
    drop(dev);

    println!("\nfgp_server OK");
    Ok(())
}
