//! Nonlinear range factors inside loopy GBP on the FGP.
//!
//! The pose loop of `gbp_pose_loop` with a nonlinear twist: each leg
//! additionally measures the scalar range it covered — a pairwise
//! factor `z = |p_to − p_from| + v` the solver relinearizes at the
//! endpoints' current beliefs every round, while every inner update
//! still lowers onto the device through the engine surface.
//!
//! Run: `cargo run --release --example nonlinear_range_gbp`

use std::sync::Arc;

use fgp_repro::apps::rangechain::RangeChain;
use fgp_repro::engine::Session;
use fgp_repro::fgp::FgpConfig;
use fgp_repro::gbp::{ConvergenceCriteria, GbpOptions, IterationPolicy};
use fgp_repro::nonlinear::{FirstOrder, SigmaPoint};

fn opts() -> GbpOptions {
    GbpOptions {
        policy: IterationPolicy::Synchronous { eta_damping: 0.3 },
        criteria: ConvergenceCriteria { tol: 1e-7, max_iters: 400, divergence: 1e3 },
        ..Default::default()
    }
}

fn main() -> anyhow::Result<()> {
    println!("=== nonlinear range factors in loopy GBP ===\n");

    let p = RangeChain::synthetic(8, 0.004, 1e-3, 21);
    let model = p.model()?;
    println!(
        "{} poses, {} factors (odometry + range per leg), cyclic: {}, nonlinear: {}\n",
        p.poses,
        model.num_factors(),
        model.has_cycle(),
        model.has_nonlinear()
    );

    println!("{:>12} {:>10} {:>10} {:>12} {:>14}", "linearizer", "engine", "iters", "rmse", "dead-reckon");
    let ekf = p.run(&mut Session::golden(), opts(), Arc::new(FirstOrder))?;
    println!(
        "{:>12} {:>10} {:>10} {:>12.5} {:>14.5}",
        "ekf", "golden", ekf.report.iterations, ekf.rmse, ekf.dead_reckoning_rmse
    );
    let ukf = p.run(&mut Session::golden(), opts(), Arc::new(SigmaPoint::default()))?;
    println!(
        "{:>12} {:>10} {:>10} {:>12.5} {:>14.5}",
        "ukf", "golden", ukf.report.iterations, ukf.rmse, ukf.dead_reckoning_rmse
    );
    let mut sim = Session::fgp_sim(FgpConfig::default());
    let dev = p.run(&mut sim, opts(), Arc::new(FirstOrder))?;
    println!(
        "{:>12} {:>10} {:>10} {:>12.5} {:>14.5}",
        "ekf", "fgp-sim", dev.report.iterations, dev.rmse, dev.dead_reckoning_rmse
    );
    let stats = sim.cache_stats();
    println!(
        "\ndevice program cache: {} misses, {} hits \
         (per-shape compiles amortized across every round)",
        stats.misses, stats.hits
    );

    assert!(ekf.report.converged() && ukf.report.converged(), "golden GBP must converge");
    println!("\nnonlinear_range_gbp OK");
    Ok(())
}
