//! EM parameter estimation, end to end: the paper's §IV channel
//! estimator with the observation-noise variance **unknown**.
//!
//! Three serving shapes over the same fixture:
//!   1. the known-parameter baseline (what the paper assumes);
//!   2. batch EM ([`fgp_repro::em::EmDriver`]): re-run the cached chain,
//!      read the posterior marginal back, commit the closed-form
//!      variance update — on the cycle-accurate simulator every round
//!      after the first is a program-cache hit;
//!   3. online EM ([`fgp_repro::em::OnlineEm`]): the same estimator as
//!      a streaming wrapper, riding `Session::run_stream` and a sticky
//!      farm stream unchanged.
//!
//! Run: `cargo run --release --example em_adaptive_rls`

use fgp_repro::apps::rls::{NoiseEmRls, RlsProblem};
use fgp_repro::coordinator::{FgpFarm, RoutePolicy};
use fgp_repro::em::{EmDriver, OnlineEm};
use fgp_repro::engine::{Session, StreamingWorkload};
use fgp_repro::fgp::FgpConfig;

fn main() -> anyhow::Result<()> {
    let true_sigma2 = 0.01;
    let problem = RlsProblem::synthetic(4, 256, true_sigma2, 17);

    // 1. known parameter: the paper's assumption
    let known = Session::golden().run(&problem)?;
    println!("known sigma2       : rel MSE {:.6}", known.outcome.rel_mse);

    // 2. batch EM from a 10x-wrong start, golden engine
    let mut em = NoiseEmRls::new(problem.clone(), true_sigma2 * 10.0);
    let report = EmDriver::new().run(&mut Session::golden(), &mut em)?;
    println!(
        "batch EM (golden)  : sigma2 {:.6} -> rel err {:.1}% in {} rounds, rel MSE {:.6}",
        report.values[0],
        100.0 * (report.values[0] - true_sigma2).abs() / true_sigma2,
        report.rounds,
        em.outcome()?.rel_mse
    );
    println!(
        "                     log-likelihood {:.2} -> {:.2} (monotone ascent)",
        report.log_likelihood.first().unwrap(),
        report.log_likelihood.last().unwrap()
    );

    // same loop on the cycle-accurate device: one compile, then hits
    let mut sim = Session::fgp_sim(FgpConfig::default());
    let mut em_dev = NoiseEmRls::new(problem.clone(), true_sigma2 * 10.0);
    let dev_report = EmDriver::new().run(&mut sim, &mut em_dev)?;
    let stats = sim.cache_stats();
    println!(
        "batch EM (fgp-sim) : sigma2 {:.6} in {} rounds | cache {} miss / {} hits",
        dev_report.values[0], dev_report.rounds, stats.misses, stats.hits
    );

    // 3. online EM riding the steady-state stream
    let stream_p = RlsProblem::synthetic(4, 512, true_sigma2, 1);
    let online = OnlineEm::new(stream_p.clone(), true_sigma2 * 10.0);
    let sr = Session::fgp_sim(FgpConfig::default()).run_stream(&online)?;
    println!(
        "online EM (stream) : sigma2 {:.6} after {} samples ({} chunk/dispatch), rel MSE {:.6}",
        sr.outcome.sigma2, sr.samples, sr.chunk, sr.outcome.inner.rel_mse
    );

    // …and over a sticky farm stream, unchanged
    let farm = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin)?;
    let farmed = OnlineEm::new(stream_p, true_sigma2 * 10.0);
    let run = farm.open_stream(&farmed)?.run_to_end()?;
    let outcome = farmed.stream_outcome(&run)?;
    println!(
        "online EM (farm)   : sigma2 {:.6} after {} samples (bitwise-identical serving path)",
        outcome.sigma2, run.samples
    );
    assert_eq!(sr.outcome.sigma2, outcome.sigma2);

    println!("\nem_adaptive_rls OK");
    Ok(())
}
