//! Streaming steady state: the paper's §VI serving shape, end to end.
//!
//! `Session::run` re-binds and re-dispatches one workload per call; the
//! silicon's whole point (Table II) is that the program loads once and
//! samples stream through. This example serves the same RLS
//! channel-estimation sample stream both ways on the cycle-accurate
//! simulator and prints the steady-state win, then shards two
//! concurrent streams over an `FgpFarm` with sticky device routing.
//!
//! Run: `cargo run --release --example streaming_rls`

use std::time::Instant;

use fgp_repro::apps::rls::RlsProblem;
use fgp_repro::coordinator::{FgpFarm, RoutePolicy};
use fgp_repro::engine::{Session, StreamingWorkload};
use fgp_repro::fgp::FgpConfig;

fn main() -> anyhow::Result<()> {
    let samples = 1024;
    let problem = RlsProblem::synthetic(4, samples, 0.01, 42);

    // --- per-call surface: one Session::run per received symbol would
    // rebuild + rebind every time; the batch run is one big dispatch
    let mut batch_session = Session::fgp_sim(FgpConfig::default());
    let batch = batch_session.run(&problem)?;

    // --- streaming surface: compile once, pipeline the sample iterator
    let mut stream_session = Session::fgp_sim(FgpConfig::default());
    let t0 = Instant::now();
    let report = stream_session.run_stream(&problem)?;
    let dt = t0.elapsed();

    println!("samples            : {}", report.samples);
    println!("chunk size         : {} samples/dispatch", report.chunk);
    println!("programs compiled  : {} (one steady-state chunk model)", report.compiles);
    println!("cycles per update  : {} (paper Table II: 260)", report.cycles_per_sample());
    println!(
        "host throughput    : {:.0} msgs/sec",
        report.samples as f64 / dt.as_secs_f64()
    );
    println!("rel MSE (stream)   : {:.6}", report.outcome.rel_mse);
    println!("rel MSE (batch)    : {:.6}", batch.outcome.rel_mse);
    assert!(
        (report.outcome.rel_mse - batch.outcome.rel_mse).abs() < 1e-12,
        "streaming is an execution strategy, not a different algorithm"
    );

    // --- run the stream again: everything is a program-cache hit now
    let again = stream_session.run_stream(&problem)?;
    assert_eq!(again.compiles, 0);
    println!(
        "second stream      : {} compiles, {} cache hits",
        again.compiles, again.cache_hits
    );

    // --- two concurrent clients, sharded over a farm with sticky routing
    let p2 = RlsProblem::synthetic(4, 768, 0.02, 7);
    let farm = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin)?;
    let s1 = farm.open_stream(&problem)?;
    let s2 = farm.open_stream(&p2)?;
    println!(
        "\nfarm streams pinned: client 1 -> device {}, client 2 -> device {}",
        s1.device(),
        s2.device()
    );
    let (r1, r2) = std::thread::scope(|scope| {
        let h1 = scope.spawn(move || s1.run_to_end());
        let h2 = scope.spawn(move || s2.run_to_end());
        (h1.join().unwrap(), h2.join().unwrap())
    });
    let (r1, r2) = (r1?, r2?);
    println!("client 1: {} samples -> rel MSE {:.6}", r1.samples, problem.stream_outcome(&r1)?.rel_mse);
    println!("client 2: {} samples -> rel MSE {:.6}", r2.samples, p2.stream_outcome(&r2)?.rel_mse);
    println!("device load profile: {:?} simulated cycles", farm.load_profile());

    println!("\nstreaming OK");
    Ok(())
}
