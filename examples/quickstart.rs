//! Quickstart: one compound-node message update, end to end.
//!
//! Builds the smallest useful factor graph (a single compound
//! observation node), compiles it to FGP assembler (the Listing 1 →
//! Listing 2 flow), runs it on the cycle-accurate simulator, and checks
//! the result against the f64 golden update rule.
//!
//! Run: `cargo run --release --example quickstart`

use fgp_repro::compiler::{compile, CompileOptions};
use fgp_repro::fgp::processor::NoFeed;
use fgp_repro::fgp::{Fgp, FgpConfig};
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::gmp::{nodes, FactorGraph, Schedule};
use fgp_repro::testutil::Rng;

fn main() -> anyhow::Result<()> {
    let n = fgp_repro::paper::N;
    let mut rng = Rng::new(42);

    // --- the factor graph: one compound observation node (Fig. 1/2)
    let a = CMatrix::random(&mut rng, n, n).scale(0.3);
    let mut graph = FactorGraph::new();
    graph.rls_chain(n, &[a.clone()]);
    let schedule = Schedule::forward_sweep(&graph);

    // --- compile: Listing 1 -> Listing 2
    let compiled = compile(&graph, &schedule, &CompileOptions::default())?;
    println!("compiled FGP assembler:\n{}", compiled.listing());
    println!(
        "memory: {} slots optimized (vs {} unoptimized)\n",
        compiled.stats.slots_optimized, compiled.stats.slots_unoptimized
    );

    // --- load onto the device and stream the operands
    let mut fgp = Fgp::new(FgpConfig::default());
    fgp.pm.load(&compiled.program.to_image())?;

    let x = GaussMessage::new(
        (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
        CMatrix::random_psd(&mut rng, n, 1.0).scale(0.15),
    );
    let y = GaussMessage::new(
        (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
        CMatrix::random_psd(&mut rng, n, 1.0).scale(0.15),
    );
    fgp.msgmem.write_message(compiled.memmap.preloads[0].1, &x);
    fgp.msgmem.write_message(compiled.memmap.streams[0].1, &y);
    fgp.statemem.write_matrix(compiled.memmap.state_streams[0].1, &a);

    let stats = fgp.run_program(1, &mut NoFeed)?;
    let got = fgp.msgmem.read_message(compiled.memmap.outputs[0].1);

    // --- golden reference
    let want = nodes::compound_observation(&x, &y, &a, true)?;
    println!("cycles: {} (paper Table II: 260)", stats.cycles);
    println!("fixed-point vs f64 distance: {:.4}", got.dist(&want));
    println!("posterior trace: {:.4} (prior was {:.4})", got.trace_cov(), x.trace_cov());
    assert!(got.dist(&want) < 0.05, "device result must match the golden rule");
    println!("\nquickstart OK");
    Ok(())
}
