//! Quickstart: one compound-node message update, end to end.
//!
//! Builds the smallest useful workload (a single compound-observation
//! node), shows the compiled FGP assembler (the Listing 1 → Listing 2
//! flow), then runs the SAME workload on the cycle-accurate simulator
//! and on the f64 golden engine through the same `Session::run` call.
//!
//! Run: `cargo run --release --example quickstart`

use std::collections::HashMap;

use anyhow::Result;
use fgp_repro::compiler::{compile, CompileOptions};
use fgp_repro::engine::{bind_streamed, preload_id, Execution, Session, Workload};
use fgp_repro::fgp::FgpConfig;
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::gmp::{FactorGraph, MsgId, Schedule};
use fgp_repro::testutil::Rng;

/// The smallest workload: prior X observed through A as Y.
struct CnUpdate {
    x: GaussMessage,
    y: GaussMessage,
    a: CMatrix,
}

impl Workload for CnUpdate {
    type Outcome = GaussMessage;

    fn name(&self) -> &str {
        "quickstart_cn"
    }

    fn n(&self) -> usize {
        self.x.dim()
    }

    fn model(&self) -> Result<(FactorGraph, Schedule)> {
        let mut graph = FactorGraph::new();
        graph.rls_chain(self.n(), std::slice::from_ref(&self.a));
        let schedule = Schedule::forward_sweep(&graph);
        Ok((graph, schedule))
    }

    fn inputs(
        &self,
        graph: &FactorGraph,
        schedule: &Schedule,
    ) -> Result<HashMap<MsgId, GaussMessage>> {
        let mut map = HashMap::new();
        map.insert(preload_id(graph, schedule, "msg_prior")?, self.x.clone());
        bind_streamed(graph, schedule, std::slice::from_ref(&self.y), &mut map)?;
        Ok(map)
    }

    fn outcome(&self, exec: &Execution) -> Result<GaussMessage> {
        exec.output().cloned()
    }

    fn quality(&self, outcome: &GaussMessage) -> f64 {
        outcome.trace_cov()
    }

    fn tolerance(&self) -> f64 {
        0.05
    }
}

fn main() -> anyhow::Result<()> {
    let n = fgp_repro::paper::N;
    let mut rng = Rng::new(42);

    let workload = CnUpdate {
        x: GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(&mut rng, n, 1.0).scale(0.15),
        ),
        y: GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(&mut rng, n, 1.0).scale(0.15),
        ),
        a: CMatrix::random(&mut rng, n, n).scale(0.3),
    };

    // --- peek at the compiled program (Listing 1 -> Listing 2)
    let (graph, schedule) = workload.model()?;
    let compiled = compile(&graph, &schedule, &CompileOptions::default())?;
    println!("compiled FGP assembler:\n{}", compiled.listing());
    println!(
        "memory: {} slots optimized (vs {} unoptimized)\n",
        compiled.stats.slots_optimized, compiled.stats.slots_unoptimized
    );

    // --- the same workload through both engines
    let mut device = Session::fgp_sim(FgpConfig::default());
    let mut golden = Session::golden();
    let measured = device.run(&workload)?;
    let reference = golden.run(&workload)?;

    println!("cycles: {} (paper Table II: 260)", measured.cycles);
    println!(
        "fixed-point vs f64 distance: {:.4}",
        measured.outcome.dist(&reference.outcome)
    );
    println!(
        "posterior trace: {:.4} (prior was {:.4})",
        measured.outcome.trace_cov(),
        workload.x.trace_cov()
    );
    assert!(
        measured.outcome.dist(&reference.outcome) < 0.05,
        "device result must match the golden rule"
    );

    // --- run it again: the session's program cache kicks in
    let again = device.run(&workload)?;
    assert!(again.cached);
    let stats = device.cache_stats();
    println!(
        "program cache: {} miss, {} hits (second run skipped the compiler)",
        stats.misses, stats.hits
    );
    println!("\nquickstart OK");
    Ok(())
}
