//! Loop-closure pose estimation via loopy GBP: dead reckoning drifts,
//! closing the loop redistributes the drift over every pose. The
//! residual-priority ("wildfire") policy shows the loop-closure
//! correction propagating outward from the closure factor.
//!
//! Run: `cargo run --release --example gbp_pose_loop`

use fgp_repro::apps::posechain::PoseChain;
use fgp_repro::engine::Session;
use fgp_repro::gbp::{ConvergenceCriteria, GbpOptions, IterationPolicy};

fn main() -> anyhow::Result<()> {
    let p = PoseChain::synthetic(10, 0.004, 7);
    println!("=== pose loop with closure via loopy GBP ===");
    println!("{} poses on a circle, odometry noise var {}\n", p.poses, p.odo_var);

    let dr = p.dead_reckoning();
    println!("{:>5} {:>18} {:>18}", "pose", "dead reckoning", "truth");
    for (k, (d, t)) in dr.iter().zip(&p.truth).enumerate() {
        println!("{k:>5} {:>8.3},{:>8.3} {:>8.3},{:>8.3}", d.re, d.im, t.re, t.im);
    }

    // synchronous, damped (weakly-anchored rings contract slowly, so
    // give the monitor headroom)
    let sync = p.run(
        &mut Session::golden(),
        GbpOptions {
            policy: IterationPolicy::Synchronous { eta_damping: 0.2 },
            criteria: ConvergenceCriteria { tol: 1e-6, max_iters: 400, divergence: 1e3 },
            ..Default::default()
        },
    )?;
    println!(
        "\nsync GBP:     {} iters ({:?}), {} messages, RMSE {:.4}",
        sync.report.iterations, sync.report.stop, sync.report.messages_sent, sync.rmse
    );

    // residual-priority: the closure correction wildfires around the ring
    let wild = p.run(
        &mut Session::golden(),
        GbpOptions {
            policy: IterationPolicy::Residual { batch: 4, eta_damping: 0.0 },
            criteria: ConvergenceCriteria { max_iters: 1500, ..Default::default() },
            ..Default::default()
        },
    )?;
    println!(
        "wildfire GBP: {} batches ({:?}), {} messages, RMSE {:.4}",
        wild.report.iterations, wild.report.stop, wild.report.messages_sent, wild.rmse
    );

    println!(
        "\ndead reckoning RMSE {:.4}  ->  GBP with loop closure RMSE {:.4}",
        sync.dead_reckoning_rmse, sync.rmse
    );
    println!("\ngbp_pose_loop OK");
    Ok(())
}
