//! **End-to-end driver** (DESIGN.md E5): the paper's §IV channel
//! estimation example on the full stack.
//!
//! 1. Synthesizes a 4-tap multipath channel and QPSK training sequence.
//! 2. Builds the Fig. 6 factor graph, compiles it (Listing 1 → 2; Fig. 7
//!    memory optimization + loop compression reported).
//! 3. Runs the workload through one `Session` per engine: the
//!    cycle-accurate FGP simulator (host streaming observations and
//!    regressors), the f64 golden chain, and (when `artifacts/` is
//!    built) the PJRT/XLA path, i.e. the Pallas kernel.
//! 4. Reports the Table II-style throughput for this workload.
//!
//! Run: `cargo run --release --example rls_channel_estimation`

use fgp_repro::apps::rls::RlsProblem;
use fgp_repro::engine::Session;
use fgp_repro::fgp::FgpConfig;
use fgp_repro::model::scaling::{normalized_throughput, ProcessorPoint};
use fgp_repro::paper;
use fgp_repro::runtime::RuntimeClient;

fn main() -> anyhow::Result<()> {
    let n = paper::N;
    let sigma2 = 0.02;

    println!("=== RLS channel estimation on the FGP (paper §IV / Fig. 6) ===\n");

    // --- learning curve: MSE vs number of sections
    let mut golden_session = Session::golden();
    let mut device_session = Session::fgp_sim(FgpConfig::default());
    println!("{:>10} {:>14} {:>14} {:>12}", "sections", "golden MSE", "FGP MSE", "cycles");
    let mut final_outcome = None;
    for sections in [4usize, 8, 16, 32, 64] {
        let p = RlsProblem::synthetic(n, sections, sigma2, 2024);
        let golden = golden_session.run(&p)?;
        let fgp = device_session.run(&p)?;
        println!(
            "{sections:>10} {:>14.5} {:>14.5} {:>12}",
            golden.quality, fgp.quality, fgp.cycles
        );
        final_outcome = Some((p, fgp));
    }
    let (problem, fgp_report) = final_outcome.unwrap();

    // --- compiler report (Fig. 7 + Listing 2)
    let compiled = problem.compile_program()?;
    println!("\ncompiled program ({} instructions):", compiled.program.instrs.len());
    println!("{}", compiled.listing());
    println!(
        "memory identifiers: {} unoptimized -> {} optimized (Fig. 7)",
        compiled.stats.slots_unoptimized, compiled.stats.slots_optimized
    );
    println!(
        "loop compression: {} -> {} instructions {:?}",
        compiled.stats.instrs_uncompressed, compiled.stats.instrs_compressed,
        compiled.stats.looped
    );
    let cache = device_session.cache_stats();
    println!(
        "session program cache: {} misses, {} hits (one compile per chain length)",
        cache.misses, cache.hits
    );

    // --- device throughput in the paper's units
    let cn_cycles = fgp_report.cycles_per_section;
    let fgp_point = ProcessorPoint::fgp(cn_cycles);
    println!(
        "\ncycles per compound-node update: {cn_cycles} (paper: {})",
        paper::FGP_CN_CYCLES
    );
    println!(
        "normalized throughput @40nm: {:.2e} CN/s (paper: 2.25e6)",
        normalized_throughput(&fgp_point, 40.0)
    );

    // --- XLA path (L1 Pallas kernel through PJRT), if artifacts exist
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.txt").exists() {
        let rt = RuntimeClient::load(&artifacts)?;
        let sections = rt.manifest.sections;
        let platform = rt.platform();
        let mut xla_session = Session::xla(rt);
        let p = RlsProblem::synthetic(n, sections, sigma2, 2024);
        let xla = xla_session.run(&p)?;
        let golden = golden_session.run(&p)?;
        println!(
            "\nXLA path ({} sections, platform {}): rel MSE {:.5} (golden {:.5})",
            sections, platform, xla.quality, golden.quality
        );
        assert!((xla.quality - golden.quality).abs() < 5e-2);
    } else {
        println!("\n(artifacts/ not built; run `make artifacts` for the XLA path)");
    }

    assert!(fgp_report.quality < 0.25, "FGP estimate must converge");
    println!("\nrls_channel_estimation OK");
    Ok(())
}
