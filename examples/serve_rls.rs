//! Serving-tier quickstart: an FGP server plus a streamed RLS client.
//!
//! Boots [`FgpServe`] on an ephemeral port (two simulated FGP devices
//! behind the coordinator farm), then drives the paper's Fig. 6
//! recursive-least-squares workload over real TCP as a sticky stream:
//! open with the RLS prior, push (observation, regressor) sections,
//! checkpoint mid-stream, kill the pinned device, and watch the stream
//! fail over and finish with the exact posterior a local fold produces.
//!
//! Run: `cargo run --release --example serve_rls`

use anyhow::Result;
use fgp_repro::apps::rls::RlsProblem;
use fgp_repro::serve::{FgpServe, ServeClient, ServeConfig, StreamMode};

fn main() -> Result<()> {
    // --- server side: one call, background threads do the rest
    let srv = FgpServe::start(ServeConfig { devices: 2, ..ServeConfig::default() })?;
    println!("serving on {}", srv.addr());

    // --- client side: stream the RLS sections through the front door
    let problem = RlsProblem::synthetic(4, 32, 0.01, 42);
    let mut client = ServeClient::connect(srv.addr(), "rls-demo")?;
    let (stream, device) = client.open_stream("fig6-rls", StreamMode::Sticky, problem.prior.clone())?;
    println!("stream {stream} pinned to device {device}");

    let sections: Vec<_> = problem
        .observations
        .iter()
        .cloned()
        .zip(problem.regressors.iter().cloned())
        .collect();

    // first half, then a checkpoint of the committed recursive state
    client.push(stream, sections[..16].to_vec())?;
    loop {
        let st = client.poll(stream)?;
        if st.samples_done == 16 && st.pending == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    let checkpoint = client.checkpoint(stream)?;
    println!("checkpointed at 16 samples ({} bytes)", checkpoint.len());

    // kill the pinned device mid-stream: the engine room re-pins the
    // stream to the surviving member and no sample is lost
    srv.farm().kill_device(device as usize)?;
    client.push(stream, sections[16..].to_vec())?;
    let closed = client.close_stream(stream)?;
    println!(
        "closed: {} samples, {} failover(s)",
        closed.samples_done, closed.failovers
    );

    // the streamed posterior is the RLS channel estimate
    let rel_mse = problem.rel_mse(&closed.state.mean);
    println!("rel MSE of streamed estimate = {rel_mse:.3e}");

    // the checkpoint restores on a brand-new server, bit for bit
    let srv2 = FgpServe::start(ServeConfig::default())?;
    let mut client2 = ServeClient::connect(srv2.addr(), "rls-demo")?;
    let (resumed, _) = client2.resume("fig6-rls", StreamMode::Sticky, checkpoint)?;
    client2.push(resumed, sections[16..].to_vec())?;
    let replay = client2.close_stream(resumed)?;
    assert_eq!(replay.state.dist(&closed.state), 0.0, "failover must be bitwise");
    println!("resume on a fresh server reproduced the posterior bitwise");

    // per-tenant SLO metrics come back over the same wire
    let stats = srv.stats();
    println!(
        "server: {} updates, p99 {} ns, {} failover(s)",
        stats.latency.completed, stats.latency.p99_ns, stats.failovers
    );

    srv2.shutdown();
    srv.shutdown();
    Ok(())
}
