//! Bearing-only target tracking: EKF vs. sigma-point (UKF) on the FGP.
//!
//! Fixed sensors measure only angles to a moving target; every time
//! step is one fixed-shape nonlinear workload (motion prelude + one
//! relinearized compound section per sensor), so the whole track runs
//! hot out of the session's program cache after one compile. The same
//! problem runs with both linearizers on the golden engine and the
//! cycle-accurate device — the EKF/UKF accuracy comparison of
//! approximate nonlinear GMP (Petersen et al. 2019).
//!
//! Run: `cargo run --release --example bearing_tracking`

use fgp_repro::apps::bearing::BearingProblem;
use fgp_repro::engine::Session;
use fgp_repro::fgp::FgpConfig;
use fgp_repro::nonlinear::{FirstOrder, SigmaPoint};

fn main() -> anyhow::Result<()> {
    println!("=== bearing-only tracking on the FGP ===\n");

    let p = BearingProblem::synthetic(10, 4, 1e-4, 17);
    println!(
        "{} steps, {} sensors, bearing noise var {:.0e} \
         (estimators weight at the device-safe floor {:.0e})\n",
        p.steps,
        p.sensors.len(),
        p.noise_var,
        p.noise_var.max(p.obs_var_floor)
    );

    println!("{:>10} {:>10} {:>12} {:>12}", "linearizer", "engine", "rmse", "rounds");
    let ekf = p.track(&mut Session::golden(), &FirstOrder, 3)?;
    println!("{:>10} {:>10} {:>12.5} {:>12}", "ekf", "golden", ekf.rmse, ekf.rounds_total);
    let ukf = p.track(&mut Session::golden(), &SigmaPoint::default(), 3)?;
    println!("{:>10} {:>10} {:>12.5} {:>12}", "ukf", "golden", ukf.rmse, ukf.rounds_total);

    let mut sim = Session::fgp_sim(FgpConfig::default());
    let dev = p.track(&mut sim, &FirstOrder, 2)?;
    println!("{:>10} {:>10} {:>12.5} {:>12}", "ekf", "fgp-sim", dev.rmse, dev.rounds_total);
    let stats = sim.cache_stats();
    println!(
        "\ndevice program cache over the whole track: {} miss, {} hits \
         (one shape for every round of every step)",
        stats.misses, stats.hits
    );

    println!("\nreference (dense per-step Gauss–Newton):");
    let reference = p.reference_track()?;
    let worst = BearingProblem::max_deviation(&ekf.estimates, &reference);
    println!("  max EKF deviation from reference: {worst:.2e}");

    assert!(!ekf.diverged && !ukf.diverged && !dev.diverged, "tracker diverged");
    assert!(ekf.rmse < 0.05 && ukf.rmse < 0.05, "golden trackers must localize");
    println!("\nbearing_tracking OK");
    Ok(())
}
