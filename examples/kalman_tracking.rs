//! Kalman tracking as GMP on the FGP (paper §I: Kalman filtering is one
//! of the algorithm classes the FGP targets).
//!
//! A constant-velocity target is tracked from noisy position fixes; the
//! filter is expressed as a factor-graph chain of multiplier, additive
//! and compound-observation nodes and run through the same `Session`
//! surface on the golden engine and the cycle-accurate simulator.
//!
//! Run: `cargo run --release --example kalman_tracking`

use fgp_repro::apps::kalman::KalmanProblem;
use fgp_repro::engine::{Session, Workload};
use fgp_repro::fgp::FgpConfig;

fn main() -> anyhow::Result<()> {
    println!("=== Constant-velocity tracking on the FGP ===\n");
    let mut golden_session = Session::golden();
    let mut device_session = Session::fgp_sim(FgpConfig::default());
    println!(
        "{:>8} {:>16} {:>16} {:>12}",
        "steps", "golden pos err", "FGP pos err", "cycles"
    );
    for steps in [10usize, 20, 40] {
        let p = KalmanProblem::synthetic(steps, 99);
        let golden = golden_session.run(&p)?;
        let fgp = device_session.run(&p)?;
        println!(
            "{steps:>8} {:>16.4} {:>16.4} {:>12}",
            golden.quality, fgp.quality, fgp.cycles
        );
    }

    // program structure report
    let p = KalmanProblem::synthetic(20, 99);
    let compiled = p.compile_program()?;
    println!(
        "\nprogram: {} instructions ({} after loop compression), {} message slots",
        compiled.stats.instrs_uncompressed,
        compiled.stats.instrs_compressed,
        compiled.memmap.num_slots
    );
    println!("\nassembler:\n{}", compiled.listing());

    let golden = golden_session.run(&p)?;
    let fgp = device_session.run(&p)?;
    assert!(fgp.quality < golden.quality + p.tolerance());
    // this 20-step run reused the compiled program from the sweep above
    assert!(fgp.cached);
    println!("kalman_tracking OK");
    Ok(())
}
