//! Kalman tracking as GMP on the FGP (paper §I: Kalman filtering is one
//! of the algorithm classes the FGP targets).
//!
//! A constant-velocity target is tracked from noisy position fixes; the
//! filter is expressed as a factor-graph chain of multiplier, additive
//! and compound-observation nodes, compiled to FGP assembler, and run on
//! the cycle-accurate simulator.
//!
//! Run: `cargo run --release --example kalman_tracking`

use fgp_repro::apps::kalman::KalmanProblem;

fn main() -> anyhow::Result<()> {
    println!("=== Constant-velocity tracking on the FGP ===\n");
    println!(
        "{:>8} {:>16} {:>16} {:>12}",
        "steps", "golden pos err", "FGP pos err", "cycles"
    );
    for steps in [10usize, 20, 40] {
        let p = KalmanProblem::synthetic(steps, 99);
        let golden = p.golden()?;
        let fgp = p.run_on_fgp()?;
        println!(
            "{steps:>8} {:>16.4} {:>16.4} {:>12}",
            golden.pos_error, fgp.pos_error, fgp.cycles
        );
    }

    // program structure report
    let p = KalmanProblem::synthetic(20, 99);
    let compiled = p.compile_program()?;
    println!(
        "\nprogram: {} instructions ({} after loop compression), {} message slots",
        compiled.stats.instrs_uncompressed,
        compiled.stats.instrs_compressed,
        compiled.memmap.num_slots
    );
    println!("\nassembler:\n{}", compiled.listing());

    let golden = p.golden()?;
    let fgp = p.run_on_fgp()?;
    assert!(fgp.pos_error < golden.pos_error + 0.3);
    println!("kalman_tracking OK");
    Ok(())
}
