//! Telemetry quickstart: trace one streamed RLS request end to end.
//!
//! Boots [`FgpServe`] with telemetry enabled, connects a *traced*
//! client sharing the server's [`Telemetry`] handle, and drives the
//! paper's Fig. 6 recursive-least-squares workload as a sticky stream.
//! Every client call mints a `TraceContext` that rides the wire's trace
//! envelope through admission, the engine room, and the pinned device,
//! so one request reads as one span tree — printed here as a flame
//! summary and exported as Chrome trace-event JSON
//! (`trace_rls.trace.json`, loadable in `chrome://tracing` or
//! Perfetto). Device spans are real FGP cycle counts rescaled onto the
//! wall clock at the paper's 130 MHz.
//!
//! Run: `cargo run --release --example trace_rls`

use anyhow::Result;
use fgp_repro::apps::rls::RlsProblem;
use fgp_repro::obs::{chrome_trace, flame_summary, TelemetryConfig};
use fgp_repro::serve::{FgpServe, ServeClient, ServeConfig, StreamMode};

fn main() -> Result<()> {
    // --- server side: same front door, telemetry switched on
    let srv = FgpServe::start(ServeConfig {
        devices: 2,
        telemetry: TelemetryConfig::on(),
        ..ServeConfig::default()
    })?;
    println!("serving on {} (wire v2, telemetry on)", srv.addr());

    // --- client side: share the server's telemetry handle so client
    // and server spans land in one ring, on one timeline
    let problem = RlsProblem::synthetic(4, 32, 0.01, 42);
    let mut client = ServeClient::connect_traced(srv.addr(), "rls-demo", srv.telemetry())?;
    let (stream, device) =
        client.open_stream("fig6-rls", StreamMode::Sticky, problem.prior.clone())?;
    println!("stream {stream} pinned to device {device}");

    let sections: Vec<_> = problem
        .observations
        .iter()
        .cloned()
        .zip(problem.regressors.iter().cloned())
        .collect();

    // one push; its trace id is the key into the span ring
    client.push(stream, sections)?;
    let push_trace = client.last_trace_id();
    loop {
        let st = client.poll(stream)?;
        if st.samples_done == 32 && st.pending == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    let closed = client.close_stream(stream)?;
    let rel_mse = problem.rel_mse(&closed.state.mean);
    println!("closed: {} samples, rel MSE {rel_mse:.3e}", closed.samples_done);

    // --- the push as a flame: client -> serve -> queue -> device -> cycles
    let spans = srv.telemetry().spans().snapshot();
    print!("\n{}", flame_summary(&spans, push_trace));

    // --- the whole ring as a Chrome trace (every request on one timeline)
    let json = chrome_trace(&spans);
    std::fs::write("trace_rls.trace.json", &json)?;
    println!("\nwrote trace_rls.trace.json ({} spans) — load it in chrome://tracing", spans.len());

    // --- the unified registry travels the wire in the same session
    let stats = client.stats()?;
    for name in ["engine.cache_hit", "engine.cache_miss", "serve.admitted"] {
        if let Some(v) = stats.telemetry.counter(name) {
            println!("{name} = {v}");
        }
    }

    srv.shutdown();
    Ok(())
}
