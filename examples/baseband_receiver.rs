//! The §III multi-program baseband receiver, end to end.
//!
//! The paper's §III scenario — one program for RLS channel estimation,
//! one for symbol detection/equalization — served from one `Session`:
//! the training workload (RLS chain with additive-leakage forgetting)
//! and the equalizer workload (single compound node with the *estimated*
//! channel streamed into state memory) alternate per frame, each program
//! shape compiled once and cached. The literal merged `prg 1`/`prg 2` PM
//! image of §III is still built and reported. SER is scored against a
//! genie receiver that knows the channel exactly.
//!
//! Run: `cargo run --release --example baseband_receiver`

use fgp_repro::apps::receiver::ReceiverProblem;
use fgp_repro::engine::Session;
use fgp_repro::fgp::Profiler;

fn main() -> anyhow::Result<()> {
    println!("=== Baseband receiver: RLS estimation + LMMSE equalization ===\n");

    // the merged PM image (the §III scenario)
    let demo = ReceiverProblem::synthetic(4, 1, 16, 16, 0.01, 5);
    let (merged, rls, lmmse) = demo.compile_receiver()?;
    println!("merged PM image: {} instructions, {} bits", merged.instrs.len(), merged.to_image().bits());
    println!("  prg 1 (RLS)   at PM[{}]", merged.start_of(1).unwrap());
    println!("  prg 2 (LMMSE) at PM[{}]", merged.start_of(2).unwrap());
    println!("  RLS slots: {}, LMMSE slots: {}\n", rls.memmap.num_slots, lmmse.memmap.num_slots);

    let mut session = Session::fgp_sim(fgp_repro::fgp::FgpConfig::default());
    println!(
        "{:>10} {:>14} {:>10} {:>12} {:>12}",
        "noise", "channel MSE", "SER", "genie SER", "cycles"
    );
    for noise in [0.002f64, 0.01, 0.05, 0.2] {
        let p = ReceiverProblem::synthetic(4, 2, 24, 32, noise, 42);
        let out = p.run(&mut session)?;
        println!(
            "{noise:>10.3} {:>14.4} {:>10.3} {:>12.3} {:>12}",
            out.channel_mse, out.ser, out.genie_ser, out.cycles
        );
    }
    let cache = session.cache_stats();
    println!(
        "\nsession program cache across all frames/blocks: {} misses, {} hits",
        cache.misses, cache.hits
    );

    // instruction-level profile of the RLS program (where cycles go)
    println!("\ninstruction-level profile (one RLS run):");
    use fgp_repro::fgp::processor::NoFeed;
    use fgp_repro::fgp::{Fgp, FgpConfig};
    use fgp_repro::gmp::matrix::CMatrix;
    use fgp_repro::gmp::message::GaussMessage;
    let mut fgp = Fgp::new(FgpConfig::default());
    fgp.pm.load(&rls.program.to_image())?;
    fgp.msgmem.write_message(rls.memmap.preloads[0].1, &GaussMessage::isotropic(4, 0.5));
    fgp.msgmem.write_message(rls.memmap.streams[0].1, &GaussMessage::isotropic(4, 0.1));
    fgp.statemem.write_matrix(rls.memmap.state_streams[0].1, &CMatrix::identity(4));
    let mut prof = Profiler::new(64);
    fgp.run_program_profiled(1, &mut NoFeed, Some(&mut prof))?;
    print!("{prof}");
    println!("Faddeev share of datapath cycles: {:.0}%", prof.faddeev_share() * 100.0);

    let p = ReceiverProblem::synthetic(4, 2, 24, 32, 0.01, 42);
    let out = p.run(&mut session)?;
    assert!(out.ser <= out.genie_ser + 0.1, "estimated-channel SER near genie bound");
    println!("\nbaseband_receiver OK");
    Ok(())
}
