//! Kernel-path selection and the multi-PE systolic sweep (E19).
//!
//! PR 9 made the simulator's layout and parallelism explicit performance
//! knobs: message storage is struct-of-arrays, compound-node updates run
//! through shape-monomorphized kernels (`kernels::kernel_path` names the
//! selection), and `FgpConfig::with_pes` scales the cycle model to N
//! processing elements. None of that may change a single bit of any
//! output — this example demonstrates both halves:
//!
//! 1. the batched SoA kernel path against per-request device dispatch,
//!    bitwise;
//! 2. the N-PE sweep: same stream, same bits, fewer simulated cycles —
//!    with N = 1 reproducing the paper's 260-cycle Table II update.
//!
//! Run: `cargo run --release --example multi_pe_sweep`

use fgp_repro::apps::rls::RlsProblem;
use fgp_repro::coordinator::{Backend, CnRequestData, FgpSimBackend};
use fgp_repro::engine::Session;
use fgp_repro::fgp::FgpConfig;
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::kernels;
use fgp_repro::paper;
use fgp_repro::testutil::Rng;

fn request(rng: &mut Rng, n: usize) -> CnRequestData {
    CnRequestData {
        x: GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, n, 1.0).scale(0.15),
        ),
        y: GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, n, 1.0).scale(0.15),
        ),
        a: CMatrix::random(rng, n, n).scale(0.3),
    }
}

fn main() -> anyhow::Result<()> {
    let n = paper::N;

    // --- which kernel serves which shape
    println!("kernel-path selection:");
    for dim in [2usize, 3, 4, 8] {
        println!("  n = {dim} -> {}", kernels::kernel_path(dim));
    }

    // --- batched SoA kernels vs per-request program dispatch, bitwise
    let mut rng = Rng::new(42);
    let reqs: Vec<CnRequestData> = (0..6).map(|_| request(&mut rng, n)).collect();
    let mut seq = FgpSimBackend::new(FgpConfig::default())?;
    let mut bat = FgpSimBackend::new(FgpConfig::default())?;
    let batched = bat.cn_update_batch(&reqs);
    for (req, got) in reqs.iter().zip(&batched) {
        let want = seq.cn_update(req)?;
        let got = got.as_ref().expect("in-shape request");
        assert_eq!(got.mean, want.mean, "batched kernel path must be bitwise");
        assert_eq!(got.cov.dist(&want.cov), 0.0);
    }
    println!(
        "\nbatched {} via {}: bitwise == per-request dispatch, {} device cycles both",
        reqs.len(),
        bat.kernel_path(),
        bat.device_cycles
    );
    assert_eq!(bat.device_cycles, seq.device_cycles);

    // --- the N-PE sweep: cycles drop, bits do not move
    let samples = 1024;
    let problem = RlsProblem::synthetic(n, samples, 0.01, 7);
    println!("\nn_pes  cycles/update  device msgs/s @130MHz  rel MSE");
    let mut h_ref: Option<Vec<c64>> = None;
    for n_pes in [1usize, 2, 4] {
        let cfg = FgpConfig::with_pes(n_pes);
        let report = Session::fgp_sim(cfg).run_stream(&problem)?;
        match &h_ref {
            None => h_ref = Some(report.outcome.h_hat.clone()),
            Some(h) => assert_eq!(
                h, &report.outcome.h_hat,
                "PE count is a cycle knob, never semantics"
            ),
        }
        let device_cycles = cfg.multi_pe.batch_cycles(&cfg.timing, n, samples);
        let per_update = device_cycles as f64 / samples as f64;
        if n_pes == 1 {
            assert_eq!(per_update, paper::FGP_CN_CYCLES as f64);
        }
        let rate = paper::FGP_FREQ_MHZ * 1e6 / per_update;
        println!(
            "{n_pes:<6} {per_update:>13.1} {rate:>21.0}  {:.6}",
            report.outcome.rel_mse
        );
    }

    println!("\nmulti-PE sweep OK (bitwise-identical at every N)");
    Ok(())
}
