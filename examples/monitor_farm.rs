//! Operational-intelligence quickstart: watch a farm degrade, alert,
//! and heal itself.
//!
//! Boots [`FgpServe`] with the health layer enabled (a background
//! watcher samples the unified registry every 10 ms, one SLO for the
//! demo tenant), attaches a stderr alert sink, and streams RLS-style
//! sections over two sticky streams. Mid-run it injects a scripted
//! 4 ms delay into device 1 — the same knob the E18 bench uses — and
//! then narrates what the health layer does about it:
//!
//! * the `DeviceOutlier` detector fires once device 1's EWMA latency
//!   crosses 8× the live-peer median (printed by the stderr sink);
//! * health-aware routing *drains* the stream pinned to device 1 onto
//!   a healthy member before dispatching its next chunk — zero samples
//!   lost, final states bitwise identical to an undegraded run;
//! * the wire `Health` request (v2) returns SLO burn rates, the firing
//!   alert, and per-device routing scores — printed as the operator
//!   report, alongside the registry in Prometheus text exposition.
//!
//! Run: `cargo run --release --example monitor_farm`

use std::time::{Duration, Instant};

use anyhow::{ensure, Result};
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::obs::health::{HealthConfig, SloDef, StderrSink};
use fgp_repro::obs::prometheus_text;
use fgp_repro::serve::{FgpServe, ServeClient, ServeConfig, StreamMode};
use fgp_repro::testutil::Rng;

fn msg(rng: &mut Rng, n: usize) -> GaussMessage {
    GaussMessage::new(
        (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
        CMatrix::random_psd(rng, n, 1.0).scale(0.15),
    )
}

fn sample(rng: &mut Rng, n: usize) -> (GaussMessage, CMatrix) {
    (msg(rng, n), CMatrix::random(rng, n, n).scale(0.3))
}

fn main() -> Result<()> {
    // --- server side: health on, 10 ms watcher cadence, one SLO
    let mut health = HealthConfig::on();
    health.watch.interval_ms = 10;
    health.watch.fire_after = 2;
    health.slos.push(SloDef::new("demo", 0, 0.05));
    let srv = FgpServe::start(ServeConfig { devices: 2, health, ..ServeConfig::default() })?;
    srv.add_alert_sink(Box::new(StderrSink));
    println!("serving on {} (wire v2, health watcher running)", srv.addr());

    // --- two sticky streams; round-robin pins them to different devices
    let mut client = ServeClient::connect(srv.addr(), "demo")?;
    let mut rng = Rng::new(2026);
    let (id_a, dev_a) = client.open_stream("a", StreamMode::Sticky, msg(&mut rng, 4))?;
    let (id_b, dev_b) = client.open_stream("b", StreamMode::Sticky, msg(&mut rng, 4))?;
    println!("stream {id_a} pinned to device {dev_a}, stream {id_b} to device {dev_b}");
    let slow_id = if dev_a == 1 { id_a } else { id_b };
    let mut pushed = [0u64; 2];
    let mut feed = |client: &mut ServeClient, rng: &mut Rng, pushed: &mut [u64; 2], rounds| {
        for _ in 0..rounds {
            for (slot, id) in [id_a, id_b].iter().enumerate() {
                let batch: Vec<_> = (0..3).map(|_| sample(rng, 4)).collect();
                pushed[slot] += batch.len() as u64;
                client.push(*id, batch).unwrap();
            }
            std::thread::sleep(Duration::from_millis(4));
        }
    };

    // --- healthy traffic: both devices warm their latency EWMAs
    feed(&mut client, &mut rng, &mut pushed, 8);
    println!("\nhealthy farm:\n{}", srv.health().report());

    // --- degrade device 1 and keep the traffic flowing
    println!("injecting a 4 ms delay into device 1 ...");
    srv.farm().set_device_delay(1, 4)?;
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        feed(&mut client, &mut rng, &mut pushed, 1);
        let pin = client.poll(slow_id)?.device;
        if pin != 1 {
            println!("stream {slow_id} drained off device 1 onto device {pin}");
            break;
        }
        ensure!(Instant::now() < deadline, "stream never drained off the slow device");
    }

    // --- the operator's view: the wire Health reply, then Prometheus
    let health = client.health()?;
    println!("\ndegraded farm:\n{}", health.report());
    let stats = srv.stats();
    println!("serve.drains = {}", stats.telemetry.counter("serve.drains").unwrap_or(0));

    // --- every pushed sample still lands, drain or no drain
    let a = client.close_stream(id_a)?;
    let b = client.close_stream(id_b)?;
    ensure!(a.samples_done + b.samples_done == pushed[0] + pushed[1], "lost samples");
    println!("closed: {} + {} samples, none lost", a.samples_done, b.samples_done);

    println!("\n--- registry, Prometheus text exposition (excerpt) ---");
    for line in prometheus_text(&stats.telemetry).lines().filter(|l| l.contains("farm_device")) {
        println!("{line}");
    }

    srv.shutdown();
    println!("\nmonitor_farm OK");
    Ok(())
}
