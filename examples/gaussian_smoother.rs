//! Fixed-interval Gaussian smoothing via two-pass GMP.
//!
//! Forward Kalman filtering, backward conditioning, and equality fusion
//! of the two directions — one factor-graph workload. Long trajectories
//! run on the golden engine; a device-sized chain runs the very same
//! graph on the cycle-accurate simulator. Reports filter vs smoother
//! RMSE across trajectories.
//!
//! Run: `cargo run --release --example gaussian_smoother`

use fgp_repro::apps::smoother::SmootherProblem;
use fgp_repro::engine::Session;
use fgp_repro::fgp::FgpConfig;

fn main() -> anyhow::Result<()> {
    println!("=== Gaussian smoother (forward-backward GMP) ===\n");
    let mut golden = Session::golden();
    println!("{:>6} {:>14} {:>14} {:>10}", "seed", "filter RMSE", "smoother RMSE", "gain");
    let mut total_gain = 0.0;
    let trials = 8;
    for seed in 0..trials {
        let p = SmootherProblem::synthetic(80, 200 + seed);
        let out = golden.run(&p)?.outcome;
        let gain = out.filter_rmse / out.smoother_rmse.max(1e-12);
        total_gain += gain;
        println!(
            "{seed:>6} {:>14.4} {:>14.4} {:>9.2}x",
            out.filter_rmse, out.smoother_rmse, gain
        );
    }
    println!("\nmean smoothing gain: {:.2}x", total_gain / trials as f64);

    // marginal-variance picture on one run
    let p = SmootherProblem::synthetic(60, 300);
    let out = golden.run(&p)?.outcome;
    let first = out.marginals.first().unwrap().trace_cov();
    let mid = out.marginals[30].trace_cov();
    let last = out.marginals.last().unwrap().trace_cov();
    println!(
        "marginal tr(V): start {first:.4}  middle {mid:.4}  end {last:.4} \
         (interior states see two-sided information)"
    );
    assert!(out.smoother_rmse <= out.filter_rmse + 1e-9);

    // the same graph on the device (a chain whose working set fits the
    // 64-kbit message memory)
    let small = SmootherProblem::synthetic(8, 400);
    let g = golden.run(&small)?;
    let f = Session::fgp_sim(FgpConfig::default()).run(&small)?;
    println!(
        "\ndevice run (8 steps): smoother RMSE {:.4} (golden {:.4}), {} cycles",
        f.quality, g.quality, f.cycles
    );

    println!("\ngaussian_smoother OK");
    Ok(())
}
