//! E16 — serving-tier SLO: multi-tenant throughput, tail latency,
//! admission control, and kill/resume failover under churn.
//!
//! Two phases against real TCP servers on ephemeral ports:
//!
//! 1. **soak** — N tenant streams (sticky + coalesced) push samples
//!    concurrently while a churn driver kills and revives farm devices
//!    mid-run. Every stream must finish with zero lost or duplicated
//!    samples and a final state **bitwise identical** to folding its
//!    sequence through a local single-device farm; the server's `STATS`
//!    snapshot supplies p50/p95/p99 and per-tenant throughput. A
//!    directed sentinel kill (drain a chunk, kill the pinned device,
//!    finish on the survivor) makes >= 1 failover deterministic even
//!    when scripted churn races the concurrent drains.
//! 2. **admission demo** — a second server with a zero-refill quota and
//!    a tiny in-flight window, driven past both limits, so the
//!    trajectory always records non-zero `QuotaExceeded`/`Busy`
//!    rejections (deterministically, not by racing the soak).
//!
//! Emits **`BENCH_serving.json`** (validated in CI against
//! `scripts/bench_serving.schema.json`) and **exits non-zero** if any
//! sample was lost, any stream diverged from its reference, no failover
//! happened under churn, or no admission rejection was exercised.
//!
//! Run: `cargo bench --bench serving_slo [-- --smoke]`

use std::time::{Duration, Instant};

use anyhow::Result;
use fgp_repro::benchutil::{banner, fmt_dur, json_arr, json_num, json_obj, json_str, write_json};
use fgp_repro::coordinator::{CnRequestData, FgpFarm, RoutePolicy};
use fgp_repro::fgp::FgpConfig;
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::serve::{
    FgpServe, QuotaPolicy, ServeClient, ServeConfig, ServeReply, ServeRequest, StatsSnapshot,
    StreamMode,
};
use fgp_repro::testutil::Rng;

fn msg(rng: &mut Rng, n: usize) -> GaussMessage {
    GaussMessage::new(
        (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
        CMatrix::random_psd(rng, n, 1.0).scale(0.15),
    )
}

fn sample(rng: &mut Rng, n: usize) -> (GaussMessage, CMatrix) {
    (msg(rng, n), CMatrix::random(rng, n, n).scale(0.3))
}

struct StreamReportRow {
    tenant: String,
    mode: &'static str,
    samples_done: u64,
    expected: u64,
    failovers: u32,
    bitwise_ok: bool,
}

struct SoakResult {
    rows: Vec<StreamReportRow>,
    stats: StatsSnapshot,
    wall: Duration,
}

/// Phase 1: concurrent tenant streams under scripted device churn.
fn soak(tenants: usize, per_stream: usize, churn_cycles: usize) -> Result<SoakResult> {
    let cfg = ServeConfig { devices: 2, chunk: 8, ..ServeConfig::default() };
    let srv = FgpServe::start(cfg)?;
    let addr = srv.addr().to_string();

    // per-tenant sequences + bitwise references via a local farm
    let reference = FgpFarm::start(1, FgpConfig::default(), RoutePolicy::RoundRobin)?;
    let mut priors = Vec::new();
    let mut sequences = Vec::new();
    let mut wants = Vec::new();
    for t in 0..tenants {
        let mut rng = Rng::new(900 + t as u64);
        let prior = msg(&mut rng, 4);
        let seq: Vec<_> = (0..per_stream).map(|_| sample(&mut rng, 4)).collect();
        let mut state = prior.clone();
        for (y, a) in &seq {
            state =
                reference.update(CnRequestData { x: state.clone(), y: y.clone(), a: a.clone() })?;
        }
        priors.push(prior);
        sequences.push(seq);
        wants.push(state);
    }

    let farm = srv.farm();
    let t0 = Instant::now();
    let mut rows = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..tenants)
            .map(|t| {
                let addr = addr.clone();
                let prior = priors[t].clone();
                let seq = sequences[t].clone();
                scope.spawn(move || {
                    let tenant = format!("tenant-{t:02}");
                    // every fourth stream takes the coalesced path
                    let mode = if t % 4 == 3 { StreamMode::Coalesced } else { StreamMode::Sticky };
                    let mut client = ServeClient::connect(addr.as_str(), &tenant).unwrap();
                    let (id, _) = client.open_stream(&tenant, mode, prior).unwrap();
                    for batch in seq.chunks(8) {
                        client.push(id, batch.to_vec()).unwrap();
                    }
                    let closed = client.close_stream(id).unwrap();
                    (tenant, mode, closed)
                })
            })
            .collect();

        // scripted churn: kill/revive each device in turn, never both at
        // once, always ending with every member alive
        for _ in 0..churn_cycles {
            for d in 0..2 {
                farm.kill_device(d).unwrap();
                std::thread::sleep(Duration::from_millis(15));
                farm.revive_device(d).unwrap();
                std::thread::sleep(Duration::from_millis(5));
            }
        }

        handles
            .into_iter()
            .enumerate()
            .map(|(t, h)| {
                let (tenant, mode, closed) = h.join().unwrap();
                StreamReportRow {
                    tenant,
                    mode: match mode {
                        StreamMode::Sticky => "sticky",
                        StreamMode::Coalesced => "coalesced",
                    },
                    samples_done: closed.samples_done,
                    expected: per_stream as u64,
                    failovers: closed.failovers,
                    bitwise_ok: closed.state.dist(&wants[t]) == 0.0,
                }
            })
            .collect::<Vec<_>>()
    });

    // Directed kill-and-resume: a sentinel stream drains one chunk so
    // its device pin is live, loses that device, and must fail over to
    // finish — deterministic, so the trajectory records >= 1 failover
    // even when the scripted churn races the concurrent drains.
    let mut rng = Rng::new(4242);
    let prior = msg(&mut rng, 4);
    let seq: Vec<_> = (0..12).map(|_| sample(&mut rng, 4)).collect();
    let mut want = prior.clone();
    for (y, a) in &seq {
        want = reference.update(CnRequestData { x: want, y: y.clone(), a: a.clone() })?;
    }
    let mut client = ServeClient::connect(addr.as_str(), "sentinel")?;
    let (id, device) = client.open_stream("sentinel", StreamMode::Sticky, prior)?;
    client.push(id, seq[..4].to_vec())?;
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let st = client.poll(id)?;
        if st.samples_done == 4 && st.pending == 0 {
            break;
        }
        anyhow::ensure!(Instant::now() < deadline, "sentinel stream never drained");
        std::thread::sleep(Duration::from_millis(1));
    }
    farm.kill_device(device as usize)?;
    client.push(id, seq[4..].to_vec())?;
    let closed = client.close_stream(id)?;
    farm.revive_device(device as usize)?;
    rows.push(StreamReportRow {
        tenant: "sentinel".to_string(),
        mode: "sticky",
        samples_done: closed.samples_done,
        expected: seq.len() as u64,
        failovers: closed.failovers,
        bitwise_ok: closed.state.dist(&want) == 0.0,
    });

    let wall = t0.elapsed();
    let stats = srv.stats();
    Ok(SoakResult { rows, stats, wall })
}

/// Phase 2: deterministic quota + window rejections on a fenced server.
fn admission_demo() -> Result<StatsSnapshot> {
    let cfg = ServeConfig {
        quota: QuotaPolicy { rate: 0.0, burst: 16.0 },
        max_inflight: 8,
        ..ServeConfig::default()
    };
    let srv = FgpServe::start(cfg)?;
    let mut rng = Rng::new(7);
    let mut greedy = ServeClient::connect(srv.addr(), "greedy")?;

    // a push larger than the whole window is an immediate Busy
    let prior = msg(&mut rng, 4);
    let (id, _) = greedy.open_stream("burst", StreamMode::Sticky, prior)?;
    let oversized: Vec<_> = (0..9).map(|_| sample(&mut rng, 4)).collect();
    match greedy.call(&ServeRequest::Push { stream: id, samples: oversized })? {
        ServeReply::Busy { .. } => {}
        other => anyhow::bail!("expected Busy for an oversized push, got {other:?}"),
    }

    // 16 token burst, zero refill: the 17th one-shot is a QuotaExceeded
    let mut quota_rejections = 0;
    for _ in 0..17 {
        let (y, a) = sample(&mut rng, 4);
        match greedy.call(&ServeRequest::CnUpdate { x: msg(&mut rng, 4), y, a })? {
            ServeReply::Output { .. } => {}
            ServeReply::QuotaExceeded { .. } => quota_rejections += 1,
            other => anyhow::bail!("unexpected reply {other:?}"),
        }
    }
    anyhow::ensure!(quota_rejections >= 1, "quota demo produced no rejection");
    greedy.close_stream(id)?;
    Ok(srv.stats())
}

fn latency_json(s: &StatsSnapshot) -> String {
    json_obj(&[
        ("completed", s.latency.completed.to_string()),
        ("failed", s.latency.failed.to_string()),
        ("mean_ns", s.latency.mean_ns.to_string()),
        ("p50_ns", s.latency.p50_ns.to_string()),
        ("p95_ns", s.latency.p95_ns.to_string()),
        ("p99_ns", s.latency.p99_ns.to_string()),
    ])
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (tenants, per_stream, churn_cycles) = if smoke { (4, 48, 1) } else { (6, 256, 3) };

    banner("serving soak: tenant streams under device churn");
    let soaked = soak(tenants, per_stream, churn_cycles)?;
    let total_samples: u64 = soaked.rows.iter().map(|r| r.samples_done).sum();
    let lost: i64 = soaked
        .rows
        .iter()
        .map(|r| r.expected as i64 - r.samples_done as i64)
        .sum();
    let all_bitwise = soaked.rows.iter().all(|r| r.bitwise_ok);
    let throughput = total_samples as f64 / soaked.wall.as_secs_f64();

    println!(
        "{:<12} {:>10} {:>10} {:>10} {:>10} {:>9}",
        "tenant", "mode", "served", "expected", "failovers", "bitwise"
    );
    for r in &soaked.rows {
        println!(
            "{:<12} {:>10} {:>10} {:>10} {:>10} {:>9}",
            r.tenant, r.mode, r.samples_done, r.expected, r.failovers, r.bitwise_ok
        );
    }
    println!(
        "\n{total_samples} samples in {} -> {throughput:.0} samples/s across {tenants} tenants",
        fmt_dur(soaked.wall)
    );
    println!(
        "latency: p50 {} p95 {} p99 {} | failovers {} | busy rejections {}",
        fmt_dur(Duration::from_nanos(soaked.stats.latency.p50_ns)),
        fmt_dur(Duration::from_nanos(soaked.stats.latency.p95_ns)),
        fmt_dur(Duration::from_nanos(soaked.stats.latency.p99_ns)),
        soaked.stats.failovers,
        soaked.stats.rejected_busy,
    );

    banner("admission demo: deterministic quota + window rejections");
    let demo = admission_demo()?;
    println!(
        "quota rejections {} | busy rejections {} | admitted {}",
        demo.rejected_quota, demo.rejected_busy, demo.admitted
    );

    // --- machine-readable trajectory
    let per_tenant: Vec<String> = soaked
        .stats
        .tenants
        .iter()
        .map(|t| {
            json_obj(&[
                ("tenant", json_str(&t.tenant)),
                ("requests", t.requests.to_string()),
                ("samples", t.samples.to_string()),
                ("rejected_quota", t.rejected_quota.to_string()),
                ("rejected_busy", t.rejected_busy.to_string()),
            ])
        })
        .collect();
    let streams: Vec<String> = soaked
        .rows
        .iter()
        .map(|r| {
            json_obj(&[
                ("tenant", json_str(&r.tenant)),
                ("mode", json_str(r.mode)),
                ("samples_done", r.samples_done.to_string()),
                ("expected", r.expected.to_string()),
                ("failovers", r.failovers.to_string()),
                ("bitwise_identical", r.bitwise_ok.to_string()),
            ])
        })
        .collect();
    let doc = json_obj(&[
        ("bench", json_str("serving_slo")),
        ("mode", json_str(if smoke { "smoke" } else { "full" })),
        ("devices", "2".to_string()),
        ("tenants", tenants.to_string()),
        ("samples_per_stream", per_stream.to_string()),
        ("total_samples", total_samples.to_string()),
        ("wall_s", json_num(soaked.wall.as_secs_f64())),
        ("throughput_samples_per_s", json_num(throughput)),
        ("latency", latency_json(&soaked.stats)),
        (
            "soak",
            json_obj(&[
                ("admitted", soaked.stats.admitted.to_string()),
                ("rejected_busy", soaked.stats.rejected_busy.to_string()),
                ("failovers", soaked.stats.failovers.to_string()),
                ("lost_samples", lost.to_string()),
                ("bitwise_identical", all_bitwise.to_string()),
                ("streams", json_arr(&streams)),
            ]),
        ),
        (
            "admission_demo",
            json_obj(&[
                ("rejected_quota", demo.rejected_quota.to_string()),
                ("rejected_busy", demo.rejected_busy.to_string()),
                ("admitted", demo.admitted.to_string()),
            ]),
        ),
        ("per_tenant", json_arr(&per_tenant)),
    ]);
    write_json("BENCH_serving.json", &doc)?;
    println!("\nwrote BENCH_serving.json");

    // --- hard gates: the serving tier's acceptance criteria
    let mut failed = false;
    if lost != 0 {
        eprintln!("GATE: {lost} samples lost (or duplicated) under churn");
        failed = true;
    }
    if !all_bitwise {
        eprintln!("GATE: a stream diverged from its local bitwise reference");
        failed = true;
    }
    if soaked.stats.failovers == 0 {
        eprintln!("GATE: churn produced zero failovers - the soak exercised nothing");
        failed = true;
    }
    if demo.rejected_quota == 0 {
        eprintln!("GATE: no quota rejection was exercised");
        failed = true;
    }
    if demo.rejected_busy == 0 {
        eprintln!("GATE: no admission-window rejection was exercised");
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    Ok(())
}
