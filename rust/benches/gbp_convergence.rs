//! E12 (extension) — loopy-GBP convergence and throughput.
//!
//! Three axes:
//!   1. convergence: iterations / final belief delta vs damping η on a
//!      cyclic grid (golden engine — pure algorithm behaviour);
//!   2. policy economy: synchronous rounds vs residual-priority
//!      ("wildfire") scheduling, in messages sent to convergence;
//!   3. device throughput: simulated cycles per GBP round on the
//!      cycle-accurate FGP, and the farm's sharding headroom
//!      (cycles/round ÷ devices).
//!
//! Run: `cargo bench --bench gbp_convergence`
//! CI smoke (tiny grid, few iterations): add `-- --smoke`.

use fgp_repro::apps::grid::GridDenoise;
use fgp_repro::benchutil::{banner, fmt_dur};
use fgp_repro::coordinator::{FgpFarm, RoutePolicy};
use fgp_repro::engine::Session;
use fgp_repro::fgp::FgpConfig;
use fgp_repro::gbp::{
    ConvergenceCriteria, FarmExecutor, GbpOptions, GbpSolver, IterationPolicy,
};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // smoke sizes are chosen so the undamped run still CONVERGES —
    // that assertion (below) is what makes this a CI regression gate,
    // not just a table printer
    let (rows, cols, max_iters, tol) =
        if smoke { (2, 2, 20, 1e-3) } else { (4, 4, 120, 1e-6) };
    let p = GridDenoise::synthetic(rows, cols, 0.04, 42);
    println!(
        "loopy GBP on a {rows}x{cols} grid ({} vars, {} factors){}",
        p.rows * p.cols,
        p.model()?.num_factors(),
        if smoke { " [smoke]" } else { "" }
    );

    banner("convergence vs damping (golden engine, synchronous rounds)");
    println!(
        "{:>6} {:>8} {:>12} {:>14} {:>10}",
        "eta", "iters", "stop", "final delta", "wall"
    );
    for eta in [0.0, 0.2, 0.4, 0.7] {
        let opts = GbpOptions {
            policy: IterationPolicy::Synchronous { eta_damping: eta },
            criteria: ConvergenceCriteria { tol, max_iters, divergence: 1e3 },
            ..Default::default()
        };
        let t0 = Instant::now();
        let out = p.run(&mut Session::golden(), opts)?;
        println!(
            "{eta:>6.1} {:>8} {:>12} {:>14.2e} {:>10}",
            out.report.iterations,
            format!("{:?}", out.report.stop),
            out.report.final_delta,
            fmt_dur(t0.elapsed())
        );
        // regression gate: no damping level may diverge, and the
        // undamped run must actually converge on this grid
        if out.report.stop == fgp_repro::gbp::StopReason::Diverged {
            anyhow::bail!("GBP diverged at eta={eta} (delta {})", out.report.final_delta);
        }
        if eta == 0.0 && !out.report.converged() {
            anyhow::bail!(
                "undamped GBP no longer converges on the {rows}x{cols} grid: {:?} after {} iters",
                out.report.stop,
                out.report.iterations
            );
        }
    }

    banner("policy economy (engine work to convergence, golden)");
    println!(
        "{:>12} {:>10} {:>10} {:>10} {:>12}",
        "policy", "iters", "messages", "beliefs", "stop"
    );
    let sync_opts = GbpOptions {
        criteria: ConvergenceCriteria { tol, max_iters, divergence: 1e3 },
        ..Default::default()
    };
    let out = p.run(&mut Session::golden(), sync_opts)?;
    println!(
        "{:>12} {:>10} {:>10} {:>10} {:>12}",
        "sync",
        out.report.iterations,
        out.report.messages_sent,
        out.report.beliefs_computed,
        format!("{:?}", out.report.stop)
    );
    let wild_opts = GbpOptions {
        policy: IterationPolicy::Residual { batch: 6, eta_damping: 0.0 },
        criteria: ConvergenceCriteria {
            tol,
            max_iters: max_iters * 10,
            divergence: 1e3,
        },
        ..Default::default()
    };
    let out = p.run(&mut Session::golden(), wild_opts)?;
    println!(
        "{:>12} {:>10} {:>10} {:>10} {:>12}",
        "wildfire",
        out.report.iterations,
        out.report.messages_sent,
        out.report.beliefs_computed,
        format!("{:?}", out.report.stop)
    );

    banner("device throughput (cycle-accurate FGP, one synchronous round)");
    let device_opts = GbpOptions {
        policy: IterationPolicy::Synchronous { eta_damping: 0.0 },
        criteria: ConvergenceCriteria { tol: 0.0, max_iters: 1, divergence: 1e9 },
        init_var: 4.0,
        ..Default::default()
    };
    let model = p.model()?;
    let edges = fgp_repro::gbp::directed_edges(&model).len();
    let mut solver = GbpSolver::new(model.clone(), device_opts)?;
    let mut sim = Session::fgp_sim(FgpConfig::default());
    let t0 = Instant::now();
    let _ = solver.run(&mut sim)?;
    let wall = t0.elapsed();
    // second solver, same session: every program shape is now cached
    let mut warm = GbpSolver::new(model.clone(), device_opts)?;
    let t0 = Instant::now();
    let _ = warm.run(&mut sim)?;
    let warm_wall = t0.elapsed();
    let stats = sim.cache_stats();
    println!("directed edges/round: {edges}, messages sent: {}", solver.messages_sent());
    println!(
        "cold round {} -> warm round {} (program cache: {} hits / {} misses / {} resident)",
        fmt_dur(wall),
        fmt_dur(warm_wall),
        stats.hits,
        stats.misses,
        stats.programs
    );

    banner("farm sharding (3 devices, round-robin)");
    let farm = FgpFarm::start(3, FgpConfig::default(), RoutePolicy::RoundRobin)?;
    let mut sharded = GbpSolver::new(model, device_opts)?;
    let t0 = Instant::now();
    let _ = sharded.run(&mut FarmExecutor { farm: &farm })?;
    println!(
        "sharded round {} across {:?} device-cycles",
        fmt_dur(t0.elapsed()),
        farm.load_profile()
    );

    println!("\ngbp_convergence OK");
    Ok(())
}
