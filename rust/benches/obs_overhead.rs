//! E17 — telemetry overhead and export: the observability tier's
//! regression gate.
//!
//! Three phases:
//!
//! 1. **span µbench** — the raw cost of recording one span into the
//!    lock-free ring, and the cost of the *disabled* hook (a single
//!    branch — the price every instrumented hot path pays when
//!    telemetry is off).
//! 2. **serve overhead** — the same one-shot workload (identical seeds)
//!    driven through two TCP servers, telemetry on vs. off. The outputs
//!    must be **bitwise identical** (invariant 7) and the enabled/
//!    disabled wall-clock ratio must stay under `MAX_OVERHEAD_RATIO`.
//! 3. **export** — a fresh traced server serves one request; its span
//!    tree is exported as Chrome trace-event JSON to
//!    **`BENCH_obs_trace.json`** (structurally validated in CI by
//!    `scripts/check_trace_json.py`) and printed as a flame summary.
//!
//! Emits **`BENCH_obs.json`** (validated in CI against
//! `scripts/bench_obs.schema.json`, whose `maximum` on
//! `overhead_ratio` re-pins the gate at the schema layer) and **exits
//! non-zero** if outputs diverge, the overhead gate trips, the disabled
//! server records any span, or the exported trace is missing a layer.
//!
//! Run: `cargo bench --bench obs_overhead [-- --smoke]`

use std::time::{Duration, Instant};

use anyhow::Result;
use fgp_repro::benchutil::{banner, fmt_dur, json_num, json_obj, json_str, time_fn, write_json};
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::obs::{chrome_trace, flame_summary, Telemetry, TelemetryConfig, TraceContext};
use fgp_repro::serve::{FgpServe, ServeClient, ServeConfig};
use fgp_repro::testutil::Rng;

/// Hard ceiling on (telemetry on) / (telemetry off) serve wall time.
/// The request path is a TCP round trip plus a device dispatch; a span
/// is a clock read and one CAS, so even generous CI jitter fits here.
const MAX_OVERHEAD_RATIO: f64 = 1.5;

fn msg(rng: &mut Rng, n: usize) -> GaussMessage {
    GaussMessage::new(
        (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
        CMatrix::random_psd(rng, n, 1.0).scale(0.15),
    )
}

fn sample(rng: &mut Rng, n: usize) -> (GaussMessage, CMatrix) {
    (msg(rng, n), CMatrix::random(rng, n, n).scale(0.3))
}

/// Mean cost of one enabled span record (ring write + clock read).
fn enabled_span_ns(iters: u32) -> f64 {
    let tel = Telemetry::new(TelemetryConfig::on());
    let ctx = TraceContext::mint();
    let t = time_fn(iters / 10, iters, || {
        let t0 = tel.now_ns();
        tel.span(ctx.child(), ctx.span_id, "bench.span", "bench", t0, 1);
    });
    t.mean.as_nanos() as f64
}

/// Mean cost of the disabled hook — the branch instrumented call sites
/// pay when the master switch is off.
fn disabled_span_ns(iters: u32) -> f64 {
    let tel = Telemetry::new(TelemetryConfig::default());
    let ctx = TraceContext::mint();
    let t = time_fn(iters / 10, iters, || {
        if tel.enabled() {
            let t0 = tel.now_ns();
            tel.span(ctx.child(), ctx.span_id, "bench.span", "bench", t0, 1);
        }
        std::hint::black_box(&tel);
    });
    t.mean.as_nanos() as f64
}

/// Drive `requests` identical one-shots through a server and return
/// (wall time, outputs, server). Inputs are pre-generated and a warmup
/// request populates the program cache, so the timed loop measures the
/// steady-state request path only.
fn serve_wall(
    telemetry: TelemetryConfig,
    requests: usize,
) -> Result<(Duration, Vec<GaussMessage>, FgpServe)> {
    let srv = FgpServe::start(ServeConfig { telemetry, ..ServeConfig::default() })?;
    let mut client = ServeClient::connect_traced(srv.addr(), "bench", srv.telemetry())?;
    let mut rng = Rng::new(7777);
    let inputs: Vec<_> = (0..requests)
        .map(|_| {
            let x = msg(&mut rng, 4);
            let (y, a) = sample(&mut rng, 4);
            (x, y, a)
        })
        .collect();
    let (wx, wy, wa) = inputs[0].clone();
    client.cn_update(wx, wy, wa)?;
    let t0 = Instant::now();
    let mut outs = Vec::with_capacity(requests);
    for (x, y, a) in inputs {
        outs.push(client.cn_update(x, y, a)?);
    }
    Ok((t0.elapsed(), outs, srv))
}

/// Phase 3: one traced request on a fresh server, exported.
fn export_one_trace() -> Result<(String, String, usize)> {
    let srv = FgpServe::start(ServeConfig {
        telemetry: TelemetryConfig::on(),
        ..ServeConfig::default()
    })?;
    let mut client = ServeClient::connect_traced(srv.addr(), "export", srv.telemetry())?;
    let mut rng = Rng::new(11);
    let x = msg(&mut rng, 4);
    let (y, a) = sample(&mut rng, 4);
    client.cn_update(x, y, a)?;
    let trace = client.last_trace_id();
    let spans: Vec<_> = srv
        .telemetry()
        .spans()
        .snapshot()
        .into_iter()
        .filter(|s| s.trace_id == trace)
        .collect();
    Ok((chrome_trace(&spans), flame_summary(&spans, trace), spans.len()))
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (span_iters, requests) = if smoke { (20_000u32, 64usize) } else { (200_000, 512) };

    banner("span µbench: ring record vs. disabled hook");
    let on_ns = enabled_span_ns(span_iters);
    let off_ns = disabled_span_ns(span_iters);
    println!("enabled span record: {on_ns:.1} ns | disabled hook: {off_ns:.1} ns");

    banner("serve overhead: identical workload, telemetry on vs. off");
    let (wall_on, outs_on, srv_on) = serve_wall(TelemetryConfig::on(), requests)?;
    let (wall_off, outs_off, srv_off) = serve_wall(TelemetryConfig::default(), requests)?;
    let ratio = wall_on.as_secs_f64() / wall_off.as_secs_f64().max(1e-9);
    let bitwise = outs_on == outs_off;
    let spans_on = srv_on.telemetry().spans().snapshot().len();
    let spans_off = srv_off.telemetry().spans().snapshot().len();
    let dropped_on = srv_on.telemetry().spans().dropped();
    println!(
        "{requests} requests: on {} | off {} | ratio {ratio:.3} (gate {MAX_OVERHEAD_RATIO}) | \
         bitwise {bitwise}",
        fmt_dur(wall_on),
        fmt_dur(wall_off)
    );
    println!("spans recorded: on {spans_on} (dropped {dropped_on}) | off {spans_off}");

    banner("export: one request, client to device cycles");
    let (chrome, flame, trace_spans) = export_one_trace()?;
    write_json("BENCH_obs_trace.json", &chrome)?;
    print!("{flame}");
    println!("wrote BENCH_obs_trace.json ({trace_spans} spans)");
    let full_chain = ["client.request", "serve.cn_update", "farm.device", "engine.execute", "fgp.run"]
        .iter()
        .all(|name| chrome.contains(&format!("\"name\":\"{name}\"")));

    // --- machine-readable trajectory
    let doc = json_obj(&[
        ("bench", json_str("obs_overhead")),
        ("mode", json_str(if smoke { "smoke" } else { "full" })),
        ("requests", requests.to_string()),
        ("span_record_ns", json_num(on_ns)),
        ("disabled_hook_ns", json_num(off_ns)),
        ("wall_on_s", json_num(wall_on.as_secs_f64())),
        ("wall_off_s", json_num(wall_off.as_secs_f64())),
        ("overhead_ratio", json_num(ratio)),
        ("max_overhead_ratio", json_num(MAX_OVERHEAD_RATIO)),
        ("bitwise_identical", bitwise.to_string()),
        ("spans_on", spans_on.to_string()),
        ("spans_dropped_on", dropped_on.to_string()),
        ("spans_off", spans_off.to_string()),
        ("trace_spans", trace_spans.to_string()),
        ("trace_full_chain", full_chain.to_string()),
    ]);
    write_json("BENCH_obs.json", &doc)?;
    println!("\nwrote BENCH_obs.json");

    // --- hard gates: the observability tier's acceptance criteria
    let mut failed = false;
    if !bitwise {
        eprintln!("GATE: telemetry changed served outputs (invariant 7 violated)");
        failed = true;
    }
    if ratio > MAX_OVERHEAD_RATIO {
        eprintln!("GATE: telemetry overhead ratio {ratio:.3} > {MAX_OVERHEAD_RATIO}");
        failed = true;
    }
    if spans_off != 0 {
        eprintln!("GATE: disabled server recorded {spans_off} spans");
        failed = true;
    }
    if spans_on == 0 {
        eprintln!("GATE: enabled server recorded no spans - the bench measured nothing");
        failed = true;
    }
    if !full_chain {
        eprintln!("GATE: exported trace is missing a layer of the client-to-device chain");
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    Ok(())
}
