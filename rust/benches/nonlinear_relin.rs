//! E13 (extension) — nonlinear relinearization: convergence and cost.
//!
//! Three axes:
//!   1. convergence: rounds to the Gauss–Newton fixed point for the
//!      EKF and sigma-point linearizers on the bearing-only tracker
//!      (golden engine — pure algorithm behaviour), with divergence as
//!      a hard failure (the CI regression gate);
//!   2. accuracy: tracker RMSE vs. the dense per-step Gauss–Newton
//!      reference, EKF vs. UKF;
//!   3. device cost: simulated cycles per relinearization round on the
//!      cycle-accurate FGP, and the program-cache hit rate across
//!      rounds and steps (one compile must serve the whole track).
//!
//! Run: `cargo bench --bench nonlinear_relin`
//! CI smoke (short track, fewer rounds): add `-- --smoke`.

use fgp_repro::apps::bearing::BearingProblem;
use fgp_repro::benchutil::{banner, fmt_dur};
use fgp_repro::engine::Session;
use fgp_repro::fgp::FgpConfig;
use fgp_repro::nonlinear::{FirstOrder, Linearizer, SigmaPoint};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (steps, sensors, rounds) = if smoke { (4, 3, 2) } else { (12, 4, 4) };
    let p = BearingProblem::synthetic(steps, sensors, 1e-4, 17);
    println!(
        "bearing-only tracking: {steps} steps, {sensors} sensors{}",
        if smoke { " [smoke]" } else { "" }
    );

    banner("convergence & accuracy (golden engine)");
    println!(
        "{:>6} {:>10} {:>12} {:>12} {:>10}",
        "lin", "rounds", "rmse", "vs GN ref", "wall"
    );
    let reference = p.reference_track()?;
    let ukf = SigmaPoint::default();
    let linearizers: [(&str, &dyn Linearizer); 2] = [("ekf", &FirstOrder), ("ukf", &ukf)];
    for (tag, lin) in linearizers {
        let t0 = Instant::now();
        let out = p.track(&mut Session::golden(), lin, rounds)?;
        let worst = BearingProblem::max_deviation(&out.estimates, &reference);
        println!(
            "{tag:>6} {:>10} {:>12.5} {:>12.2e} {:>10}",
            out.rounds_total,
            out.rmse,
            worst,
            fmt_dur(t0.elapsed())
        );
        // regression gate: neither linearizer may diverge, and both
        // must stay in the reference's regime
        if out.diverged {
            anyhow::bail!("{tag} tracker diverged on the bearing-only workload");
        }
        if out.rmse > 0.1 {
            anyhow::bail!("{tag} tracker rmse {} left the reference regime", out.rmse);
        }
    }

    banner("device cost (cycle-accurate FGP)");
    let mut sim = Session::fgp_sim(FgpConfig::default());
    let t0 = Instant::now();
    let out = p.track(&mut sim, &FirstOrder, rounds)?;
    let stats = sim.cache_stats();
    if out.diverged {
        anyhow::bail!("device tracker diverged");
    }
    println!(
        "rounds {} | rmse {:.5} | cache {} miss / {} hits | wall {}",
        out.rounds_total,
        out.rmse,
        stats.misses,
        stats.hits,
        fmt_dur(t0.elapsed())
    );
    if stats.misses != 1 {
        anyhow::bail!(
            "expected one compile for the whole track (fixed sweep shape), got {} misses",
            stats.misses
        );
    }

    println!("\nnonlinear_relin OK");
    Ok(())
}
