//! E15 (extension) — EM parameter estimation: accuracy and cost.
//!
//! Three axes:
//!   1. accuracy: EM-recovered observation-noise variance vs the
//!      synthetic truth on the RLS fixture, and the adaptive channel
//!      estimate's rel MSE vs the known-parameter baseline;
//!   2. rounds-to-converge: batch EM (obs noise, starting 10x and 0.1x
//!      off) and adaptive-Kalman process noise (filtered/lag-one EM —
//!      slower near the fixed point, by design streamable);
//!   3. device cost: EM rounds on the cycle-accurate FGP, with the
//!      program-cache contract (one compile for all rounds) as a hard
//!      gate, plus online EM riding the steady-state stream.
//!
//! Run: `cargo bench --bench em_convergence`
//! CI smoke (small fixture, few rounds): add `-- --smoke`.

use std::time::Instant;

use fgp_repro::apps::kalman::{AdaptiveKalman, KalmanProblem};
use fgp_repro::apps::rls::{NoiseEmRls, RlsProblem};
use fgp_repro::benchutil::{banner, fmt_dur};
use fgp_repro::em::{EmDriver, EmOptions, OnlineEm};
use fgp_repro::engine::Session;
use fgp_repro::fgp::FgpConfig;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (sections, kalman_steps, kalman_rounds) =
        if smoke { (32, 24, 6) } else { (256, 240, 150) };
    let true_sigma2 = 0.01;
    let true_q = 2e-3;

    banner("RLS observation noise: EM vs known parameter (golden)");
    let p = RlsProblem::synthetic(4, sections, true_sigma2, 17);
    let known = Session::golden().run(&p)?;
    println!(
        "{:>8} {:>12} {:>10} {:>12} {:>12} {:>10}",
        "start", "sigma2_hat", "rel err", "rounds", "rel MSE", "wall"
    );
    println!(
        "{:>8} {:>12} {:>10} {:>12} {:>12.6} {:>10}",
        "known", "-", "-", "-", known.outcome.rel_mse, "-"
    );
    for mult in [10.0, 0.1] {
        let mut em = NoiseEmRls::new(p.clone(), true_sigma2 * mult);
        let t0 = Instant::now();
        let report = EmDriver::new().run(&mut Session::golden(), &mut em)?;
        let rel = (report.values[0] - true_sigma2).abs() / true_sigma2;
        println!(
            "{:>8} {:>12.6} {:>10.4} {:>12} {:>12.6} {:>10}",
            format!("{mult}x"),
            report.values[0],
            rel,
            report.rounds,
            em.outcome()?.rel_mse,
            fmt_dur(t0.elapsed())
        );
        if !report.log_likelihood.windows(2).all(|w| w[1] >= w[0] - 1e-7 * w[0].abs()) {
            anyhow::bail!("log-likelihood decreased across EM rounds");
        }
        if !smoke && rel > 0.05 {
            anyhow::bail!("EM noise recovery left the 5% regime: rel err {rel}");
        }
        if smoke && rel > 0.5 {
            anyhow::bail!("smoke EM noise recovery diverged: rel err {rel}");
        }
    }

    banner("Kalman process noise: filtered/lag-one EM (golden)");
    let kp = KalmanProblem::synthetic(kalman_steps, 9);
    let mut em = AdaptiveKalman::new(kp, true_q * 10.0);
    let driver = EmDriver::with_options(EmOptions {
        max_rounds: kalman_rounds,
        tol: 1e-3,
        divergence: 1e6,
    });
    let t0 = Instant::now();
    let report = driver.run(&mut Session::golden(), &mut em)?;
    let ratio = report.values[0] / true_q;
    println!(
        "q_hat {:.3e} (true {true_q:.1e}) | ratio {ratio:.2} | rounds {} | stop {:?} | wall {}",
        report.values[0],
        report.rounds,
        report.stop,
        fmt_dur(t0.elapsed())
    );
    // lag-one EM converges slowly on short series: the accuracy gate is
    // only meaningful at the full fixture size
    if !smoke && !(0.2..=5.0).contains(&ratio) {
        anyhow::bail!("adaptive process noise left the truth's regime: ratio {ratio}");
    }
    if !ratio.is_finite() || ratio > 12.0 {
        anyhow::bail!("adaptive process noise diverged: ratio {ratio}");
    }

    banner("device cost (cycle-accurate FGP) + cache contract");
    let mut sim = Session::fgp_sim(FgpConfig::default());
    let mut em = NoiseEmRls::new(p.clone(), true_sigma2 * 10.0);
    let rounds = if smoke { 4 } else { 8 };
    let t0 = Instant::now();
    let report = EmDriver::with_options(EmOptions {
        max_rounds: rounds,
        tol: 0.0,
        divergence: 1e9,
    })
    .run(&mut sim, &mut em)?;
    let stats = sim.cache_stats();
    println!(
        "rounds {} | sigma2_hat {:.6} | cache {} miss / {} hits | wall {}",
        report.rounds,
        report.values[0],
        stats.misses,
        stats.hits,
        fmt_dur(t0.elapsed())
    );
    if stats.misses != 1 {
        anyhow::bail!(
            "expected one compile for all EM rounds (fixed chain shape), got {} misses",
            stats.misses
        );
    }
    if report.cached[1..].iter().any(|c| !*c) {
        anyhow::bail!("an EM round after the first missed the program cache");
    }

    banner("online EM riding the steady-state stream (fgp-sim)");
    let stream_p = RlsProblem::synthetic(4, if smoke { 128 } else { 512 }, true_sigma2, 1);
    let online = OnlineEm::new(stream_p, true_sigma2 * 10.0);
    let t0 = Instant::now();
    let sr = Session::fgp_sim(FgpConfig::default()).run_stream(&online)?;
    let rel = (sr.outcome.sigma2 - true_sigma2).abs() / true_sigma2;
    println!(
        "samples {} | chunk {} | sigma2_hat {:.6} (rel err {rel:.3}) | compiles {} | wall {}",
        sr.samples,
        sr.chunk,
        sr.outcome.sigma2,
        sr.compiles,
        fmt_dur(t0.elapsed())
    );
    if !rel.is_finite() || rel > 1.0 {
        anyhow::bail!("online EM estimate diverged: rel err {rel}");
    }

    println!("\nem_convergence OK");
    Ok(())
}
