//! E18 — operational intelligence: the health layer's regression gate.
//!
//! Three phases:
//!
//! 1. **inertness** — the same sticky-stream workload (identical seeds)
//!    driven through a health-ON server and a default (health-OFF)
//!    server. The final stream states must be **bitwise identical**
//!    (ARCHITECTURE invariant 7, extended), and the OFF server must
//!    report a watcher that never existed (`enabled: false`, zero
//!    snapshots, zero device clock reads).
//! 2. **clean run** — the health-ON server from phase 1, with a
//!    collecting alert sink attached before traffic: after a bounded
//!    number of watcher snapshots over a healthy farm, **zero alerts**
//!    may have fired (no false positives) and every SLO reads healthy.
//! 3. **degraded run** — a fresh health-ON server with a scripted delay
//!    injected into one device: the `DeviceOutlier` detector must fire
//!    within `MAX_SNAPSHOTS_TO_FIRE` watcher snapshots of the
//!    degradation, sticky streams must *drain* off the slow member
//!    (`serve.drains` ≥ 1) with **zero lost samples**, and the final
//!    states must be bitwise identical to an undegraded replay.
//!
//! Emits **`BENCH_health.json`** (validated in CI against
//! `scripts/bench_health.schema.json`) and **`BENCH_health_prom.txt`**
//! (the degraded server's registry in Prometheus text exposition,
//! validated by `scripts/check_prom_text.py`), and **exits non-zero**
//! if any gate above trips.
//!
//! Run: `cargo bench --bench health_slo [-- --smoke]`

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use fgp_repro::benchutil::{banner, json_num, json_obj, json_str, write_json};
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::obs::export::prometheus_text;
use fgp_repro::obs::health::{AlertKind, AlertState, HealthConfig, SloDef, VecSink};
use fgp_repro::serve::{FgpServe, ServeClient, ServeConfig, StreamMode};
use fgp_repro::testutil::Rng;

/// Upper bound on watcher snapshots between the scripted degradation
/// and the `DeviceOutlier` firing edge. The detector needs the slow
/// device's EWMA to cross `device_factor` × the live median and then
/// `fire_after` consecutive breaching snapshots; at a 5 ms cadence this
/// bound is ~3 s of wall time — far past any healthy CI run.
const MAX_SNAPSHOTS_TO_FIRE: u64 = 600;

/// Scripted per-dispatch delay injected into the degraded device (ms).
const DEGRADE_DELAY_MS: u64 = 4;

fn msg(rng: &mut Rng, n: usize) -> GaussMessage {
    GaussMessage::new(
        (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
        CMatrix::random_psd(rng, n, 1.0).scale(0.15),
    )
}

fn sample(rng: &mut Rng, n: usize) -> (GaussMessage, CMatrix) {
    (msg(rng, n), CMatrix::random(rng, n, n).scale(0.3))
}

/// The bench's health config: 5 ms watcher cadence, fire after 2
/// breaching snapshots, one SLO for the bench tenant. `min_activity`
/// is raised past the default because the first few watcher windows of
/// a cold server see mostly compile misses — a real signal the cache
/// detector must not judge on a handful of events during warmup.
fn bench_health() -> HealthConfig {
    let mut h = HealthConfig::on();
    h.watch.interval_ms = 5;
    h.watch.fire_after = 2;
    h.watch.min_activity = 32;
    h.slos.push(SloDef::new("bench", 0, 0.05));
    h
}

/// Drive `rounds` × `per_round` samples onto two sticky streams with the
/// given seed and return the two final states + per-stream sample count.
/// The workload is a pure function of the seed, so two servers fed the
/// same seed must serve bitwise-identical states.
fn run_workload(
    srv: &FgpServe,
    seed: u64,
    rounds: usize,
    per_round: usize,
) -> Result<(Vec<GaussMessage>, u64)> {
    let mut client = ServeClient::connect(srv.addr(), "bench")?;
    let mut rng = Rng::new(seed);
    let priors = [msg(&mut rng, 4), msg(&mut rng, 4)];
    let mut ids = Vec::new();
    for (i, p) in priors.iter().enumerate() {
        let (id, _) = client.open_stream(&format!("wl{i}"), StreamMode::Sticky, p.clone())?;
        ids.push(id);
    }
    for _ in 0..rounds {
        for id in &ids {
            let batch: Vec<_> = (0..per_round).map(|_| sample(&mut rng, 4)).collect();
            client.push(*id, batch)?;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let mut states = Vec::new();
    let mut done = 0;
    for id in ids {
        let closed = client.close_stream(id)?;
        done += closed.samples_done;
        states.push(closed.state);
    }
    Ok((states, done))
}

/// Phase 3: degrade device 1 mid-workload, wait for the outlier alert
/// and the drain, and account for every pushed sample.
struct DegradedRun {
    snapshots_to_fire: u64,
    fired: bool,
    drains: u64,
    pushed: [u64; 2],
    done: [u64; 2],
    states: [GaussMessage; 2],
    fed: [Vec<(GaussMessage, CMatrix)>; 2],
    priors: [GaussMessage; 2],
    slow_score: f64,
    fast_score: f64,
    prom_text: String,
}

fn degraded_run(seed: u64, warm_rounds: usize) -> Result<DegradedRun> {
    let srv = FgpServe::start(ServeConfig { health: bench_health(), ..ServeConfig::default() })?;
    let sink = Arc::new(VecSink::new());
    srv.add_alert_sink(Box::new(Arc::clone(&sink)));
    let mut client = ServeClient::connect(srv.addr(), "bench")?;
    let mut rng = Rng::new(seed);
    let priors = [msg(&mut rng, 4), msg(&mut rng, 4)];
    let (id_a, dev_a) = client.open_stream("da", StreamMode::Sticky, priors[0].clone())?;
    let (id_b, _) = client.open_stream("db", StreamMode::Sticky, priors[1].clone())?;
    // round-robin spread the pins; identify the stream on device 1
    let slow_id = if dev_a == 1 { id_a } else { id_b };
    let ids = [id_a, id_b];
    let mut fed: [Vec<(GaussMessage, CMatrix)>; 2] = [Vec::new(), Vec::new()];
    let mut feed = |client: &mut ServeClient, rng: &mut Rng, fed: &mut [Vec<_>; 2], rounds| {
        for _ in 0..rounds {
            for (slot, id) in ids.iter().enumerate() {
                let batch: Vec<_> = (0..3).map(|_| sample(rng, 4)).collect();
                fed[slot].extend(batch.iter().cloned());
                client.push(*id, batch).unwrap();
            }
            std::thread::sleep(Duration::from_millis(3));
        }
    };

    // warm both devices' EWMAs, then inject the degradation
    feed(&mut client, &mut rng, &mut fed, warm_rounds);
    let snap0 = srv.health().snapshots;
    srv.farm().set_device_delay(1, DEGRADE_DELAY_MS)?;

    // keep traffic flowing until the outlier fires and the pin moves
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut fired_at = None;
    let mut drained = false;
    while (fired_at.is_none() || !drained) && Instant::now() < deadline {
        feed(&mut client, &mut rng, &mut fed, 1);
        if fired_at.is_none() {
            let outlier = sink.events().iter().any(|a| {
                a.kind == AlertKind::DeviceOutlier
                    && a.state == AlertState::Firing
                    && a.subject == "farm.device1"
            });
            if outlier {
                fired_at = Some(srv.health().snapshots);
            }
        }
        drained = client.poll(slow_id)?.device != 1;
    }

    let health = srv.health();
    let score = |d: u32| {
        health.devices.iter().find(|h| h.device == d).map(|h| h.score).unwrap_or(-1.0)
    };
    let stats = srv.stats();
    let closed_a = client.close_stream(id_a)?;
    let closed_b = client.close_stream(id_b)?;
    let prom_text = prometheus_text(&srv.stats().telemetry);
    srv.shutdown();
    Ok(DegradedRun {
        snapshots_to_fire: fired_at.map(|s| s.saturating_sub(snap0)).unwrap_or(u64::MAX),
        fired: fired_at.is_some(),
        drains: stats.telemetry.counter("serve.drains").unwrap_or(0),
        pushed: [fed[0].len() as u64, fed[1].len() as u64],
        done: [closed_a.samples_done, closed_b.samples_done],
        states: [closed_a.state, closed_b.state],
        fed,
        priors,
        slow_score: score(1),
        fast_score: score(0),
        prom_text,
    })
}

/// Replay the degraded run's exact samples on a plain (health-off,
/// undegraded) server and return the final states.
fn replay(run: &DegradedRun) -> Result<[GaussMessage; 2]> {
    let srv = FgpServe::start(ServeConfig::default())?;
    let mut client = ServeClient::connect(srv.addr(), "bench")?;
    let mut states = Vec::new();
    for slot in 0..2 {
        let (id, _) = client.open_stream("replay", StreamMode::Sticky, run.priors[slot].clone())?;
        for chunk in run.fed[slot].chunks(16) {
            client.push(id, chunk.to_vec())?;
        }
        states.push(client.close_stream(id)?.state);
    }
    srv.shutdown();
    Ok([states.remove(0), states.remove(0)])
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (rounds, per_round, clean_snapshots, warm_rounds) =
        if smoke { (6usize, 3usize, 12u64, 4usize) } else { (20, 4, 40, 8) };

    banner("phase 1: disabled health is bitwise inert");
    let cfg_on = ServeConfig { health: bench_health(), ..ServeConfig::default() };
    let srv_on = FgpServe::start(cfg_on)?;
    let sink = Arc::new(VecSink::new());
    srv_on.add_alert_sink(Box::new(Arc::clone(&sink)));
    let (states_on, done_on) = run_workload(&srv_on, 4242, rounds, per_round)?;
    let srv_off = FgpServe::start(ServeConfig::default())?;
    let (states_off, done_off) = run_workload(&srv_off, 4242, rounds, per_round)?;
    let bitwise_disabled = states_on == states_off && done_on == done_off;
    let off_health = srv_off.health();
    let off_inert = !off_health.enabled
        && off_health.snapshots == 0
        && off_health.devices.iter().all(|d| d.ewma_ns == 0);
    println!(
        "{done_on} samples each way | bitwise {bitwise_disabled} | off server inert {off_inert}"
    );
    srv_off.shutdown();

    banner("phase 2: clean run fires nothing");
    let deadline = Instant::now() + Duration::from_secs(30);
    while srv_on.health().snapshots < clean_snapshots && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    let clean = srv_on.health();
    let slos_healthy = clean.slos.iter().all(|s| s.healthy);
    let false_positives = sink.len() as u64;
    println!(
        "{} snapshots | {} alert(s) fired | SLOs healthy {slos_healthy}",
        clean.snapshots, false_positives
    );
    srv_on.shutdown();

    banner("phase 3: degraded device fires, drains, loses nothing");
    let run = degraded_run(99, warm_rounds)?;
    let lost = (run.pushed[0] - run.done[0]) + (run.pushed[1] - run.done[1]);
    let ref_states = replay(&run)?;
    let bitwise_degraded = run.states[0] == ref_states[0] && run.states[1] == ref_states[1];
    println!(
        "outlier fired {} ({} snapshots after degradation, gate {MAX_SNAPSHOTS_TO_FIRE}) | \
         drains {} | lost {lost} | bitwise {bitwise_degraded}",
        run.fired, run.snapshots_to_fire, run.drains
    );
    println!("device scores: slow {:.3} | fast {:.3}", run.slow_score, run.fast_score);
    std::fs::write("BENCH_health_prom.txt", &run.prom_text)?;
    println!("wrote BENCH_health_prom.txt ({} lines)", run.prom_text.lines().count());

    // --- machine-readable trajectory
    let doc = json_obj(&[
        ("bench", json_str("health_slo")),
        ("mode", json_str(if smoke { "smoke" } else { "full" })),
        ("devices", "2".to_string()),
        ("samples_inert", done_on.to_string()),
        ("bitwise_disabled", bitwise_disabled.to_string()),
        ("off_server_inert", off_inert.to_string()),
        ("clean_snapshots", clean.snapshots.to_string()),
        ("false_positives", false_positives.to_string()),
        ("slos_healthy", slos_healthy.to_string()),
        ("outlier_fired", run.fired.to_string()),
        ("snapshots_to_fire", run.snapshots_to_fire.to_string()),
        ("max_snapshots_to_fire", MAX_SNAPSHOTS_TO_FIRE.to_string()),
        ("drains", run.drains.to_string()),
        ("samples_pushed", (run.pushed[0] + run.pushed[1]).to_string()),
        ("samples_lost", lost.to_string()),
        ("bitwise_degraded", bitwise_degraded.to_string()),
        ("slow_device_score", json_num(run.slow_score)),
        ("fast_device_score", json_num(run.fast_score)),
    ]);
    write_json("BENCH_health.json", &doc)?;
    println!("\nwrote BENCH_health.json");

    // --- hard gates: the health layer's acceptance criteria
    let mut failed = false;
    if !bitwise_disabled {
        eprintln!("GATE: the health layer changed served outputs (invariant 7 violated)");
        failed = true;
    }
    if !off_inert {
        eprintln!("GATE: the disabled server ran a watcher or read device clocks");
        failed = true;
    }
    if false_positives != 0 {
        eprintln!("GATE: {false_positives} alert(s) fired on a healthy farm");
        failed = true;
    }
    if !slos_healthy {
        eprintln!("GATE: a healthy run reads an unhealthy SLO");
        failed = true;
    }
    if !run.fired {
        eprintln!("GATE: the DeviceOutlier detector never fired on the degraded device");
        failed = true;
    }
    if run.snapshots_to_fire > MAX_SNAPSHOTS_TO_FIRE {
        eprintln!(
            "GATE: detector took {} snapshots (> {MAX_SNAPSHOTS_TO_FIRE}) to fire",
            run.snapshots_to_fire
        );
        failed = true;
    }
    if run.drains < 1 {
        eprintln!("GATE: no sticky stream drained off the degraded device");
        failed = true;
    }
    if lost != 0 {
        eprintln!("GATE: {lost} sample(s) lost across the drain");
        failed = true;
    }
    if !bitwise_degraded {
        eprintln!("GATE: draining changed served outputs vs. the undegraded replay");
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    println!("health_slo OK");
    Ok(())
}
