//! E2 — regenerates the **§V area report**: 3.11 mm² at UMC180 with the
//! 30% memories / 60% systolic array / 10% datapath+control split, plus
//! the model's extrapolation over array size and memory capacity.
//!
//! Run: `cargo bench --bench area_breakdown`

use fgp_repro::benchutil::banner;
use fgp_repro::model::area::AreaModel;
use fgp_repro::paper;

fn main() {
    let model = AreaModel::default();

    banner("§V area — paper configuration (n=4, 64 kbit)");
    let b = model.paper_configuration();
    let f = b.fractions();
    println!("{:<26} {:>10} {:>10}", "", "modeled", "paper");
    println!("{:<26} {:>9.2}mm² {:>9.2}mm²", "total", b.total(), paper::FGP_AREA_MM2);
    println!(
        "{:<26} {:>9.0}% {:>9.0}%",
        "memories",
        f[0] * 100.0,
        paper::FGP_AREA_SPLIT[0] * 100.0
    );
    println!(
        "{:<26} {:>9.0}% {:>9.0}%",
        "systolic array",
        f[1] * 100.0,
        paper::FGP_AREA_SPLIT[1] * 100.0
    );
    println!(
        "{:<26} {:>9.0}% {:>9.0}%",
        "datapath + control",
        f[2] * 100.0,
        paper::FGP_AREA_SPLIT[2] * 100.0
    );

    banner("extrapolation: area vs array size (64 kbit memory)");
    println!("{:>4} {:>12} {:>10} {:>10} {:>10}", "n", "total mm²", "mem %", "array %", "ctrl %");
    for n in [2usize, 4, 6, 8] {
        let b = model.breakdown(n, 64);
        let f = b.fractions();
        println!(
            "{n:>4} {:>12.2} {:>10.0} {:>10.0} {:>10.0}",
            b.total(),
            f[0] * 100.0,
            f[1] * 100.0,
            f[2] * 100.0
        );
    }

    banner("extrapolation: area vs memory capacity (n=4)");
    println!("{:>8} {:>12} {:>10}", "kbit", "total mm²", "mem %");
    for kbit in [32usize, 64, 128, 256] {
        let b = model.breakdown(4, kbit);
        println!("{kbit:>8} {:>12.2} {:>10.0}", b.total(), b.fractions()[0] * 100.0);
    }
}
