//! E10 (extension) — the batched XLA offload path: latency/throughput of
//! compound-node updates through the PJRT artifacts, single vs batched,
//! plus the end-to-end coordinator (queue + batcher) overhead.
//!
//! Requires `make artifacts`; prints a skip notice otherwise.
//!
//! Run: `cargo bench --bench xla_offload`

use std::time::Duration;

use fgp_repro::benchutil::{banner, fmt_dur, time_for};
use fgp_repro::coordinator::backend::{Backend, CnRequestData, GoldenBackend, XlaBatchBackend, XlaBackend};
use fgp_repro::coordinator::{BatchPolicy, CnServer, ServerConfig};
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::runtime::RuntimeClient;
use fgp_repro::testutil::Rng;

fn request(rng: &mut Rng, n: usize) -> CnRequestData {
    CnRequestData {
        x: GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, n, 1.0).scale(0.15),
        ),
        y: GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, n, 1.0).scale(0.15),
        ),
        a: CMatrix::random(rng, n, n).scale(0.3),
    }
}

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        println!("artifacts/ not built — run `make artifacts` first; skipping xla_offload");
        return Ok(());
    }
    let n = fgp_repro::paper::N;
    let mut rng = Rng::new(3);
    let reqs: Vec<CnRequestData> = (0..256).map(|_| request(&mut rng, n)).collect();

    banner("engine latency per CN update (direct, no queue)");
    // golden f64
    let mut golden = GoldenBackend;
    let mut i = 0;
    let g = time_for(Duration::from_millis(500), || {
        golden.cn_update(&reqs[i % reqs.len()]).unwrap();
        i += 1;
    });
    println!(
        "{:<28} {:>12}  (p50 {}, p95 {})",
        "golden f64 (rust)",
        fmt_dur(g.mean),
        fmt_dur(g.p50),
        fmt_dur(g.p95)
    );

    // xla single
    let mut xla1 = XlaBackend::new(RuntimeClient::load(&artifacts)?);
    let mut i = 0;
    let x1 = time_for(Duration::from_secs(1), || {
        xla1.cn_update(&reqs[i % reqs.len()]).unwrap();
        i += 1;
    });
    println!(
        "{:<28} {:>12}  (p50 {}, p95 {})",
        "xla single (PJRT dispatch)",
        fmt_dur(x1.mean),
        fmt_dur(x1.p50),
        fmt_dur(x1.p95)
    );

    // xla batched, full batch
    let xlab = XlaBatchBackend::new(RuntimeClient::load(&artifacts)?);
    let mut xlab = match xlab {
        Ok(b) => b,
        Err(e) => return Err(e),
    };
    let bsz = xlab.max_batch();
    let batch: Vec<CnRequestData> = reqs[..bsz.min(reqs.len())].to_vec();
    let xb = time_for(Duration::from_secs(1), || {
        let out = xlab.cn_update_batch(&batch);
        assert!(out.iter().all(|r| r.is_ok()));
    });
    println!(
        "{:<28} {:>12}  ({} per request, batch {bsz})",
        "xla batched (one dispatch)",
        fmt_dur(xb.mean),
        fmt_dur(xb.mean / bsz as u32)
    );

    banner("batched dispatch amortization: per-request cost vs batch size");
    println!("{:>8} {:>14} {:>16}", "batch", "dispatch", "per request");
    for sz in [1usize, 2, 4, 8, 16, 32] {
        if sz > bsz {
            break;
        }
        let batch: Vec<CnRequestData> = reqs[..sz].to_vec();
        let t = time_for(Duration::from_millis(700), || {
            let out = xlab.cn_update_batch(&batch);
            assert!(out.iter().all(|r| r.is_ok()));
        });
        println!("{sz:>8} {:>14} {:>16}", fmt_dur(t.mean), fmt_dur(t.mean / sz as u32));
    }

    banner("end-to-end coordinator (queue + batcher + xla batched)");
    for max_batch in [1usize, 8, 32] {
        let artifacts2 = artifacts.clone();
        let server = CnServer::start(
            move || Ok(Box::new(XlaBatchBackend::new(RuntimeClient::load(&artifacts2)?)?) as _),
            ServerConfig {
                batch: BatchPolicy { max_batch, max_wait: Duration::from_millis(1) },
            },
        )?;
        let client = server.client();
        let t0 = std::time::Instant::now();
        let total = 512usize;
        let pending: Vec<_> = (0..total)
            .map(|k| client.submit(reqs[k % reqs.len()].clone()))
            .collect();
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let dt = t0.elapsed();
        println!(
            "max_batch {max_batch:>3}: {total} reqs in {} -> {:.0} CN/s | {}",
            fmt_dur(dt),
            total as f64 / dt.as_secs_f64(),
            client.metrics().report()
        );
        server.shutdown();
    }
    Ok(())
}
