//! E3 — regenerates **Fig. 7**: the compiler's message-memory identifier
//! optimization (unoptimized vs optimized schedules), plus the loop
//! compression of §IV and the allocator score-policy ablation.
//!
//! The paper shows the 2-section RLS graph; we print that case and sweep
//! the section count to show the optimized mapping is O(1) while the
//! unoptimized one grows linearly — the property that makes the 64-kbit
//! message memory sufficient.
//!
//! Run: `cargo bench --bench fig7_compiler`

use fgp_repro::benchutil::{banner, fmt_dur, time_fn};
use fgp_repro::compiler::{compile, AllocOptions, CompileOptions, ScorePolicy};
use fgp_repro::gmp::matrix::CMatrix;
use fgp_repro::gmp::{FactorGraph, Schedule};
use fgp_repro::paper;
use fgp_repro::testutil::Rng;

fn rls_graph(sections: usize) -> (FactorGraph, Schedule) {
    let mut rng = Rng::new(7);
    let n = paper::N;
    let a_list: Vec<CMatrix> =
        (0..sections).map(|_| CMatrix::random(&mut rng, n, n).scale(0.3)).collect();
    let mut g = FactorGraph::new();
    g.rls_chain(n, &a_list);
    let s = Schedule::forward_sweep(&g);
    (g, s)
}

fn main() -> anyhow::Result<()> {
    banner("Fig. 7 — the paper's 2-section RLS example");
    let (g, s) = rls_graph(2);
    let c = compile(&g, &s, &CompileOptions::default())?;
    println!(
        "identifiers: {} unoptimized -> {} optimized",
        c.stats.slots_unoptimized, c.stats.slots_optimized
    );
    println!("compiled listing (Listing 2 shape):\n{}", c.listing());

    banner("identifier count vs sections (unopt grows, opt constant)");
    println!(
        "{:>10} {:>14} {:>12} {:>16} {:>16}",
        "sections", "unoptimized", "optimized", "instrs (flat)", "instrs (loop)"
    );
    for sections in [1usize, 2, 4, 8, 16, 32, 64] {
        let (g, s) = rls_graph(sections);
        let c = compile(&g, &s, &CompileOptions::default())?;
        println!(
            "{sections:>10} {:>14} {:>12} {:>16} {:>16}",
            c.stats.slots_unoptimized,
            c.stats.slots_optimized,
            c.stats.instrs_uncompressed,
            c.stats.instrs_compressed
        );
    }

    banner("score-policy ablation (8-section RLS)");
    println!("{:>22} {:>10}", "policy", "slots");
    for policy in [
        ScorePolicy::MostRecentlyFreed,
        ScorePolicy::LowestIndex,
        ScorePolicy::LeastRecentlyFreed,
    ] {
        let (g, s) = rls_graph(8);
        let c = compile(
            &g,
            &s,
            &CompileOptions {
                alloc: AllocOptions { policy, ..Default::default() },
                ..Default::default()
            },
        )?;
        println!("{:>22} {:>10}", format!("{policy:?}"), c.stats.slots_optimized);
    }

    banner("compile time (host)");
    for sections in [8usize, 64] {
        let (g, s) = rls_graph(sections);
        let t = time_fn(3, 50, || {
            let _ = compile(&g, &s, &CompileOptions::default()).unwrap();
        });
        println!(
            "{sections:>4} sections: {} mean (p50 {}, p95 {})",
            fmt_dur(t.mean),
            fmt_dur(t.p50),
            fmt_dur(t.p95)
        );
    }
    Ok(())
}
