//! E1 + E14 — **Table II** throughput, plus the streaming steady-state
//! reproduction that backs it.
//!
//! The paper's headline claim (§VI, Table II) is *steady-state
//! throughput*: the FGP computes an RLS channel-estimation update faster
//! than a TI C66x DSP because the program is loaded once and samples
//! stream through. This bench regenerates both halves:
//!
//! 1. the Table II rows — measured FGP cycles per compound-node update
//!    vs the C66x analytic model, normalized to a common technology
//!    node (the paper's own comparison method);
//! 2. the serving-surface half — `Session::run_stream` (compile once,
//!    stream samples) against equivalent repeated per-call
//!    `Session::run` dispatches on the same RLS sample stream, per
//!    engine, in host msgs/sec.
//!
//! Emits a machine-readable **`BENCH_throughput.json`** (validated in CI
//! against `scripts/bench_throughput.schema.json`) so every future PR
//! has a perf trajectory to beat, and **exits non-zero** if streaming
//! throughput regresses below the per-call path on the fgp-sim engine.
//!
//! Run: `cargo bench --bench table2_throughput [-- --smoke]`

use std::collections::HashMap;
use std::time::{Duration, Instant};

use anyhow::Result;
use fgp_repro::apps::rls::RlsProblem;
use fgp_repro::benchutil::{
    banner, fmt_dur, json_arr, json_num, json_obj, json_str, time_for, write_json,
};
use fgp_repro::coordinator::backend::{Backend, CnRequestData, FgpSimBackend};
use fgp_repro::dsp::C66xModel;
use fgp_repro::engine::{bind_streamed, preload_id, Execution, Session, Workload};
use fgp_repro::fgp::FgpConfig;
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::gmp::{FactorGraph, MsgId, Schedule};
use fgp_repro::kernels;
use fgp_repro::model::scaling::{normalized_throughput, ProcessorPoint};
use fgp_repro::paper;
use fgp_repro::runtime::RuntimeClient;
use fgp_repro::testutil::Rng;

// ---------------------------------------------------------------------
// per-call baseline: the workload a Session::run client dispatches per
// received symbol (one compound-observation section)
// ---------------------------------------------------------------------

struct OneSection {
    prior: GaussMessage,
    y: GaussMessage,
    a: CMatrix,
}

impl Workload for OneSection {
    type Outcome = GaussMessage;

    fn name(&self) -> &str {
        "rls_one_section"
    }

    fn n(&self) -> usize {
        self.prior.dim()
    }

    fn model(&self) -> Result<(FactorGraph, Schedule)> {
        let mut g = FactorGraph::new();
        g.rls_chain(self.n(), std::slice::from_ref(&self.a));
        let s = Schedule::forward_sweep(&g);
        Ok((g, s))
    }

    fn inputs(
        &self,
        graph: &FactorGraph,
        schedule: &Schedule,
    ) -> Result<HashMap<MsgId, GaussMessage>> {
        let mut map = HashMap::new();
        map.insert(preload_id(graph, schedule, "msg_prior")?, self.prior.clone());
        bind_streamed(graph, schedule, std::slice::from_ref(&self.y), &mut map)?;
        Ok(map)
    }

    fn outcome(&self, exec: &Execution) -> Result<GaussMessage> {
        exec.output().cloned()
    }

    fn quality(&self, outcome: &GaussMessage) -> f64 {
        outcome.trace_cov()
    }

    fn tolerance(&self) -> f64 {
        0.05
    }
}

/// Process the whole sample stream through repeated per-call
/// `Session::run` dispatches; returns the final posterior mean.
fn per_call_pass(session: &mut Session, p: &RlsProblem) -> Result<Vec<c64>> {
    let mut prior = p.prior.clone();
    for k in 0..p.sections {
        let w = OneSection {
            prior,
            y: p.observations[k].clone(),
            a: p.regressors[k].clone(),
        };
        prior = session.run(&w)?.outcome;
    }
    Ok(prior.mean)
}

/// Best wall time of `reps` passes (sessions stay warm across reps, so
/// the best pass is the steady-state one); returns the last result too.
fn best_of<R>(reps: usize, mut f: impl FnMut() -> Result<R>) -> Result<(R, Duration)> {
    let mut best = Duration::MAX;
    let mut out = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        let r = f()?;
        best = best.min(t0.elapsed());
        out = Some(r);
    }
    Ok((out.expect("reps >= 1"), best))
}

struct EngineRow {
    engine: String,
    stream_msgs_per_s: f64,
    per_call_msgs_per_s: f64,
    speedup: f64,
    cycles_per_update: u64,
    kernel_path: String,
}

/// A random CN request within the device's input-scaling contract.
fn request(rng: &mut Rng, n: usize) -> CnRequestData {
    CnRequestData {
        x: GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, n, 1.0).scale(0.15),
        ),
        y: GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, n, 1.0).scale(0.15),
        ),
        a: CMatrix::random(rng, n, n).scale(0.3),
    }
}

/// Stream-vs-per-call comparison of one engine on one RLS sample stream.
fn engine_row(
    mut stream_session: Session,
    mut percall_session: Session,
    p: &RlsProblem,
    reps: usize,
) -> Result<EngineRow> {
    let engine = stream_session.engine_kind().to_string();
    let (report, stream_dt) = best_of(reps, || stream_session.run_stream(p))?;
    let (h_percall, percall_dt) = best_of(reps, || per_call_pass(&mut percall_session, p))?;

    // the two paths must agree on the estimate — streaming is an
    // execution strategy, not a different algorithm (the xla engine
    // accumulates in f32, and its fused-chain vs per-dispatch orderings
    // differ at that precision)
    let d: f64 = report
        .outcome
        .h_hat
        .iter()
        .zip(&h_percall)
        .map(|(a, b)| (*a - *b).abs2())
        .sum::<f64>()
        .sqrt();
    let tol = if engine == "xla" { 1e-2 } else { 1e-9 };
    assert!(d < tol, "{engine}: stream vs per-call estimate diverged: {d}");

    let samples = p.sections as f64;
    let stream_rate = samples / stream_dt.as_secs_f64();
    let percall_rate = samples / percall_dt.as_secs_f64();
    // which update-kernel implementation served this engine's arithmetic
    let kernel_path = match engine.as_str() {
        "fgp-sim" => kernels::kernel_path(p.prior.dim()).to_string(),
        "golden" => "interpreted-f64".to_string(),
        _ => "xla-aot".to_string(),
    };
    Ok(EngineRow {
        engine,
        stream_msgs_per_s: stream_rate,
        per_call_msgs_per_s: percall_rate,
        speedup: stream_rate / percall_rate,
        cycles_per_update: report.cycles_per_sample(),
        kernel_path,
    })
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n = paper::N;
    let samples = if smoke { 512 } else { 8192 };
    let reps = if smoke { 2 } else { 3 };

    // --- measured FGP cycles: run the compiled CN program once
    let mut sim = FgpSimBackend::new(FgpConfig::default())?;
    let mut rng = Rng::new(1);
    let req = request(&mut rng, n);
    sim.cn_update(&req)?;
    let fgp_cycles = sim.device_cycles;

    // --- DSP analytic model (the paper's own estimation method)
    let dsp_model = C66xModel::default();
    let dsp_cycles = dsp_model.compound_node_cycles(n);

    let fgp_tp = normalized_throughput(&ProcessorPoint::fgp(fgp_cycles), 40.0);
    let dsp_tp = normalized_throughput(&ProcessorPoint::c66x(dsp_cycles), 40.0);
    let paper_speedup = normalized_throughput(&ProcessorPoint::fgp(paper::FGP_CN_CYCLES), 40.0)
        / normalized_throughput(&ProcessorPoint::c66x(paper::DSP_CN_CYCLES), 40.0);

    banner("Table II — throughput comparison, FGP vs DSP");
    println!("{:<42} {:>16} {:>16}", "Processor", "FGP (this work)", "TI C66x");
    println!("{:<42} {:>16} {:>16}", "CMOS technology [nm]", 180, 40);
    println!("{:<42} {:>16} {:>16}", "Max. freq. [MHz]", 130, 1250);
    println!(
        "{:<42} {:>16} {:>16}",
        "cycles for CN msg. update [measured]", fgp_cycles, dsp_cycles
    );
    println!(
        "{:<42} {:>16} {:>16}",
        "cycles for CN msg. update [paper]",
        paper::FGP_CN_CYCLES,
        paper::DSP_CN_CYCLES
    );
    println!(
        "{:<42} {:>16.2e} {:>16.2e}",
        "Normalized max. throughput [CN/s]", fgp_tp, dsp_tp
    );
    println!("{:<42} {:>16.2e} {:>16.2e}", "  (paper)", 2.25e6, 1.16e6);
    println!("\nspeedup: {:.2}x (paper: {:.2}x)", fgp_tp / dsp_tp, paper_speedup);

    // --- DSP breakdown (the inversion-dominance argument)
    banner("C66x CN-update cycle breakdown (estimation per paper method)");
    let b = dsp_model.compound_node_breakdown(n);
    println!("  T1 = V_X A^H matmul        {:>6}", b.t1_matmul);
    println!("  G matmul + add             {:>6}", b.g_matmul_add);
    println!("  G^-1 inversion (ref [11])  {:>6}", b.inversion);
    println!("  gain matmul                {:>6}", b.gain_matmul);
    println!("  Schur matmul + sub         {:>6}", b.schur_matmul_sub);
    println!("  mean update                {:>6}", b.mean_update);
    println!("  total                      {:>6}", b.total());

    // --- streaming steady state vs per-call dispatch (E14): the same
    // RLS sample stream served both ways, per engine
    banner("steady-state serving: run_stream vs repeated Session::run (host)");
    let p = RlsProblem::synthetic(n, samples, 0.01, 42);
    let mut rows = Vec::new();
    rows.push(engine_row(Session::golden(), Session::golden(), &p, reps)?);
    rows.push(engine_row(
        Session::fgp_sim(FgpConfig::default()),
        Session::fgp_sim(FgpConfig::default()),
        &p,
        reps,
    )?);
    // XLA rides along when the AOT artifacts are built
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.txt").exists() {
        match (RuntimeClient::load(&artifacts), RuntimeClient::load(&artifacts)) {
            (Ok(rt_a), Ok(rt_b)) => {
                rows.push(engine_row(Session::xla(rt_a), Session::xla(rt_b), &p, reps)?)
            }
            _ => eprintln!("artifacts present but failed to load; skipping xla row"),
        }
    }

    println!(
        "{:<10} {:>16} {:>18} {:>10} {:>14} {:>14}",
        "engine", "stream [msg/s]", "per-call [msg/s]", "speedup", "cycles/update", "kernel path"
    );
    for r in &rows {
        println!(
            "{:<10} {:>16.0} {:>18.0} {:>9.2}x {:>14} {:>14}",
            r.engine,
            r.stream_msgs_per_s,
            r.per_call_msgs_per_s,
            r.speedup,
            r.cycles_per_update,
            r.kernel_path
        );
    }

    // --- multi-PE systolic scaling (the Table II "N processing
    // elements" column): PE count is a cycle knob only — the estimate
    // must be bitwise-identical at every N, and N = 1 must reproduce the
    // paper's 260-cycle compound-node update exactly.
    banner("multi-PE systolic scaling (N processing elements)");
    let mut pe_rows_json = Vec::new();
    let mut h_ref: Option<Vec<c64>> = None;
    println!(
        "{:<8} {:>16} {:>18} {:>18} {:>14}",
        "n_pes", "cycles/update", "device [msg/s]", "stream [msg/s]", "kernel path"
    );
    for n_pes in [1usize, 2, 4] {
        let cfg = FgpConfig::with_pes(n_pes);
        let mut session = Session::fgp_sim(cfg);
        let (report, dt) = best_of(reps, || session.run_stream(&p))?;
        match &h_ref {
            None => h_ref = Some(report.outcome.h_hat.clone()),
            Some(h) => assert!(
                h.iter().zip(&report.outcome.h_hat).all(|(a, b)| a == b),
                "n_pes={n_pes}: estimate must be bitwise-identical to single-PE"
            ),
        }
        let device_cycles = cfg.multi_pe.batch_cycles(&cfg.timing, n, samples);
        let per_update = device_cycles as f64 / samples as f64;
        if n_pes == 1 {
            assert_eq!(
                per_update,
                paper::FGP_CN_CYCLES as f64,
                "one PE must cost exactly the paper's Table II cycles"
            );
        }
        let device_rate = paper::FGP_FREQ_MHZ * 1e6 * samples as f64 / device_cycles as f64;
        let stream_rate = samples as f64 / dt.as_secs_f64();
        println!(
            "{:<8} {:>16.1} {:>18.0} {:>18.0} {:>14}",
            n_pes,
            per_update,
            device_rate,
            stream_rate,
            kernels::kernel_path(n)
        );
        pe_rows_json.push(json_obj(&[
            ("n_pes", n_pes.to_string()),
            ("cycles_per_update", json_num(per_update)),
            ("device_msgs_per_s", json_num(device_rate)),
            ("stream_msgs_per_s", json_num(stream_rate)),
            ("kernel_path", json_str(kernels::kernel_path(n))),
            ("bitwise_identical_to_single_pe", "true".to_string()),
        ]));
    }

    // --- single-CN host latency (continuity with earlier trajectories)
    banner("simulator host latency per CN update");
    let reqs: Vec<CnRequestData> = {
        let mut rng = Rng::new(2);
        (0..64).map(|_| request(&mut rng, n)).collect()
    };
    let mut i = 0;
    let t = time_for(Duration::from_millis(if smoke { 200 } else { 1000 }), || {
        sim.cn_update(&reqs[i % reqs.len()]).unwrap();
        i += 1;
    });
    println!(
        "simulated CN update: {} mean (p50 {}, p95 {}; {} sim-CN/s host, {} iters)",
        fmt_dur(t.mean),
        fmt_dur(t.p50),
        fmt_dur(t.p95),
        (1.0 / t.mean.as_secs_f64().max(1e-12)) as u64,
        t.iters
    );

    // --- machine-readable trajectory
    let engines_json: Vec<String> = rows
        .iter()
        .map(|r| {
            json_obj(&[
                ("engine", json_str(&r.engine)),
                ("workload", json_str("rls_stream")),
                ("stream_msgs_per_s", json_num(r.stream_msgs_per_s)),
                ("per_call_msgs_per_s", json_num(r.per_call_msgs_per_s)),
                ("stream_speedup_vs_per_call", json_num(r.speedup)),
                ("cycles_per_update", r.cycles_per_update.to_string()),
                ("kernel_path", json_str(&r.kernel_path)),
            ])
        })
        .collect();
    let doc = json_obj(&[
        ("bench", json_str("table2_throughput")),
        ("mode", json_str(if smoke { "smoke" } else { "full" })),
        ("samples", samples.to_string()),
        (
            "table2",
            json_obj(&[
                ("fgp_cycles_per_cn_measured", fgp_cycles.to_string()),
                ("fgp_cycles_per_cn_paper", paper::FGP_CN_CYCLES.to_string()),
                ("dsp_cycles_per_cn_model", dsp_cycles.to_string()),
                ("dsp_cycles_per_cn_paper", paper::DSP_CN_CYCLES.to_string()),
                ("fgp_normalized_cn_per_s", json_num(fgp_tp)),
                ("dsp_normalized_cn_per_s", json_num(dsp_tp)),
                ("speedup_vs_dsp", json_num(fgp_tp / dsp_tp)),
                ("paper_speedup", json_num(paper_speedup)),
            ]),
        ),
        ("engines", json_arr(&engines_json)),
        ("multi_pe", json_arr(&pe_rows_json)),
    ]);
    write_json("BENCH_throughput.json", &doc)?;
    println!("\nwrote BENCH_throughput.json");

    // --- regression gate: streaming must never lose to per-call on the
    // device engine (the whole point of the steady-state path; the E14
    // acceptance target is >= 2x)
    let sim_row = rows
        .iter()
        .find(|r| r.engine == "fgp-sim")
        .expect("fgp-sim row always present");
    if sim_row.speedup < 1.0 {
        eprintln!(
            "REGRESSION: streaming throughput {:.0} msg/s fell below per-call {:.0} msg/s \
             ({:.2}x) on fgp-sim",
            sim_row.stream_msgs_per_s, sim_row.per_call_msgs_per_s, sim_row.speedup
        );
        std::process::exit(1);
    }
    if sim_row.speedup < 2.0 {
        eprintln!(
            "warning: fgp-sim streaming speedup {:.2}x is below the 2x steady-state target",
            sim_row.speedup
        );
    }
    Ok(())
}
