//! E1 — regenerates **Table II**: throughput comparison, FGP vs DSP.
//!
//! Prints the same rows the paper reports: technology node, max clock,
//! cycles per compound-node (CN) message update, and normalized maximum
//! throughput in CN/s. The FGP cycle count is *measured* by running the
//! compiled CN program on the cycle-accurate simulator; the DSP count
//! comes from the C66x cost model (the paper's own estimation method).
//! Also times the simulator itself (host wall-clock per simulated CN).
//!
//! Run: `cargo bench --bench table2_throughput`

use fgp_repro::benchutil::{banner, fmt_dur, time_for};
use fgp_repro::coordinator::backend::{Backend, CnRequestData, FgpSimBackend};
use fgp_repro::dsp::C66xModel;
use fgp_repro::fgp::FgpConfig;
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::model::scaling::{normalized_throughput, ProcessorPoint};
use fgp_repro::paper;
use fgp_repro::testutil::Rng;
use std::time::Duration;

fn request(rng: &mut Rng, n: usize) -> CnRequestData {
    CnRequestData {
        x: GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, n, 1.0).scale(0.15),
        ),
        y: GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, n, 1.0).scale(0.15),
        ),
        a: CMatrix::random(rng, n, n).scale(0.3),
    }
}

fn main() -> anyhow::Result<()> {
    let n = paper::N;

    // --- measured FGP cycles: run the compiled CN program once
    let mut sim = FgpSimBackend::new(FgpConfig::default())?;
    let mut rng = Rng::new(1);
    let req = request(&mut rng, n);
    sim.cn_update(&req)?;
    let fgp_cycles = sim.device_cycles;

    // --- DSP model
    let dsp_model = C66xModel::default();
    let dsp_cycles = dsp_model.compound_node_cycles(n);

    let fgp_pt = ProcessorPoint::fgp(fgp_cycles);
    let dsp_pt = ProcessorPoint::c66x(dsp_cycles);
    let fgp_tp = normalized_throughput(&fgp_pt, 40.0);
    let dsp_tp = normalized_throughput(&dsp_pt, 40.0);

    banner("Table II — throughput comparison, FGP vs DSP");
    println!("{:<42} {:>16} {:>16}", "Processor", "FGP (this work)", "TI C66x");
    println!("{:<42} {:>16} {:>16}", "CMOS technology [nm]", 180, 40);
    println!("{:<42} {:>16} {:>16}", "Max. freq. [MHz]", 130, 1250);
    println!("{:<42} {:>16} {:>16}", "cycles for CN msg. update [measured]", fgp_cycles, dsp_cycles);
    println!(
        "{:<42} {:>16} {:>16}",
        "cycles for CN msg. update [paper]",
        paper::FGP_CN_CYCLES,
        paper::DSP_CN_CYCLES
    );
    println!(
        "{:<42} {:>16.2e} {:>16.2e}",
        "Normalized max. throughput [CN/s]", fgp_tp, dsp_tp
    );
    println!(
        "{:<42} {:>16.2e} {:>16.2e}",
        "  (paper)", 2.25e6, 1.16e6
    );
    println!("\nspeedup: {:.2}x (paper: ~2x)", fgp_tp / dsp_tp);

    // --- DSP breakdown (the inversion-dominance argument)
    banner("C66x CN-update cycle breakdown (estimation per paper method)");
    let b = dsp_model.compound_node_breakdown(n);
    println!("  T1 = V_X A^H matmul        {:>6}", b.t1_matmul);
    println!("  G matmul + add             {:>6}", b.g_matmul_add);
    println!("  G^-1 inversion (ref [11])  {:>6}", b.inversion);
    println!("  gain matmul                {:>6}", b.gain_matmul);
    println!("  Schur matmul + sub         {:>6}", b.schur_matmul_sub);
    println!("  mean update                {:>6}", b.mean_update);
    println!("  total                      {:>6}", b.total());

    // --- simulator host performance (perf-pass tracking)
    banner("simulator host performance");
    let mut rng = Rng::new(2);
    let reqs: Vec<CnRequestData> = (0..64).map(|_| request(&mut rng, n)).collect();
    let mut i = 0;
    let (mean, iters) = time_for(Duration::from_secs(1), || {
        let r = &reqs[i % reqs.len()];
        i += 1;
        sim.cn_update(r).unwrap();
    });
    println!(
        "simulated CN update: {} wall ({} sim-CN/s host, {iters} iters)",
        fmt_dur(mean),
        (1.0 / mean.as_secs_f64()) as u64
    );
    Ok(())
}
