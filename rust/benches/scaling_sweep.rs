//! E8 (extension) — cycles and normalized throughput vs state size n,
//! FGP (measured on the simulator's timing model) against the C66x cost
//! model. Shows where the FGP's Faddeev advantage comes from: the DSP
//! pays the explicit-inversion cost (cubic, [11]-anchored) while the
//! systolic array folds it into the elimination pass.
//!
//! Run: `cargo bench --bench scaling_sweep`

use fgp_repro::benchutil::banner;
use fgp_repro::dsp::C66xModel;
use fgp_repro::fgp::TimingModel;
use fgp_repro::model::scaling::{normalized_throughput, ProcessorPoint};

fn main() {
    let timing = TimingModel::default();
    let dsp = C66xModel::default();

    banner("CN-update cycles vs state size n");
    println!(
        "{:>4} {:>12} {:>12} {:>10} {:>14} {:>14}",
        "n", "FGP cycles", "DSP cycles", "speedup*", "FGP CN/s@40", "DSP CN/s@40"
    );
    for n in [2usize, 3, 4, 6, 8] {
        let f = timing.compound_node_cycles(n);
        let d = dsp.compound_node_cycles(n);
        let ftp = normalized_throughput(&ProcessorPoint::fgp(f), 40.0);
        let dtp = normalized_throughput(&ProcessorPoint::c66x(d), 40.0);
        println!(
            "{n:>4} {f:>12} {d:>12} {:>9.2}x {:>14.2e} {:>14.2e}",
            ftp / dtp,
            ftp,
            dtp
        );
    }
    println!("* normalized to a common 40 nm node, t_pd ~ 1/s (Table II method)");

    banner("FGP per-instruction cycle budget vs n");
    println!(
        "{:>4} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "n", "mma", "mms", "mms.v", "fad", "smm"
    );
    for n in [2usize, 4, 6, 8] {
        println!(
            "{n:>4} {:>8} {:>8} {:>8} {:>8} {:>8}",
            timing.matrix_pass(n),
            timing.matrix_pass(n),
            timing.vector_pass(n),
            timing.faddeev_pass(n),
            timing.store_pass(n)
        );
    }

    banner("where the DSP loses: inversion share of its CN update");
    println!("{:>4} {:>12} {:>12} {:>8}", "n", "inversion", "total", "share");
    for n in [2usize, 4, 6, 8] {
        let b = dsp.compound_node_breakdown(n);
        println!(
            "{n:>4} {:>12} {:>12} {:>7.0}%",
            b.inversion,
            b.total(),
            100.0 * b.inversion as f64 / b.total() as f64
        );
    }
}
