//! E9 (extension) — fixed-point precision ablation: RLS estimation
//! quality vs Q-format fraction bits, at fixed 16/24/32-bit datapath
//! widths. Quantifies the §V "fix point number representation" choice:
//! the 16-bit datapath hits an accuracy floor when the posterior
//! covariance shrinks to a few LSBs, which wider formats push out.
//!
//! Run: `cargo bench --bench precision_ablation`

use fgp_repro::apps::rls::RlsProblem;
use fgp_repro::benchutil::banner;
use fgp_repro::engine::Session;
use fgp_repro::fgp::FgpConfig;
use fgp_repro::fixed::QFormat;
use fgp_repro::paper;

fn main() -> anyhow::Result<()> {
    let n = paper::N;
    let sections = 24;
    let sigma2 = 0.02;
    let seeds = [11u64, 23, 47];

    banner("RLS rel-MSE vs fixed-point format (24 sections, QPSK)");
    let mut golden_session = Session::golden();
    let p0 = RlsProblem::synthetic(n, sections, sigma2, seeds[0]);
    let golden = golden_session.run(&p0)?.quality;
    println!("f64 golden reference rel MSE: {golden:.5}\n");

    println!("{:>10} {:>8} {:>14} {:>14}", "format", "width", "mean rel MSE", "worst rel MSE");
    for (int_bits, frac_bits) in [
        (5u32, 10u32), // the silicon's 16-bit Q5.10
        (5, 12),
        (5, 14),
        (5, 18), // 24-bit
        (5, 22),
        (5, 26), // 32-bit
    ] {
        let fmt = QFormat::new(int_bits, frac_bits);
        let cfg = FgpConfig { fmt, ..Default::default() };
        // one session per format: the datapath width is engine state,
        // but all three seeds share the compiled program
        let mut session = Session::fgp_sim(cfg);
        let mut sum = 0.0;
        let mut worst: f64 = 0.0;
        for &seed in &seeds {
            let p = RlsProblem::synthetic(n, sections, sigma2, seed);
            let out = session.run(&p)?;
            sum += out.quality;
            worst = worst.max(out.quality);
        }
        println!(
            "{:>10} {:>8} {:>14.5} {:>14.5}",
            format!("Q{int_bits}.{frac_bits}"),
            fmt.width(),
            sum / seeds.len() as f64,
            worst
        );
    }

    banner("accuracy floor vs chain length at Q5.10 (fixed-point RLS drift)");
    let mut q510 = Session::fgp_sim(FgpConfig::default());
    println!("{:>10} {:>14} {:>14}", "sections", "golden MSE", "Q5.10 MSE");
    for s in [8usize, 16, 32, 64] {
        let p = RlsProblem::synthetic(n, s, sigma2, seeds[0]);
        let g = golden_session.run(&p)?.quality;
        let f = q510.run(&p)?.quality;
        println!("{s:>10} {g:>14.5} {f:>14.5}");
    }
    println!(
        "\n(the Q5.10 floor: once tr(V) approaches a few LSBs the quantized\n\
         covariance stalls — wider fractions push the floor out, the E9 axis)"
    );
    Ok(())
}
