//! E9 — fixed-point precision ablation, upgraded to the
//! quantization-conformance harness behind the fixed-point production
//! path: per-width **error vs the golden f64 engine asserted against
//! the analytic bound** ([`PrecisionModel::error_bound`]), per-width
//! **throughput/area/power/energy rows** extending Table II, the
//! **adaptive-precision policy** ([`PrecisionModel::pick_format`]), and
//! the per-width saturation counts the production path reports through
//! the metrics registry.
//!
//! Emits a machine-readable **`BENCH_precision.json`** (validated in CI
//! against `scripts/bench_precision.schema.json`) and **exits non-zero**
//! if any width's measured error escapes its asserted bound — the bound
//! is the contract the fixed production path ships under.
//!
//! Run: `cargo bench --bench precision_ablation [-- --smoke]`
//!
//! [`PrecisionModel::error_bound`]: fgp_repro::model::PrecisionModel::error_bound
//! [`PrecisionModel::pick_format`]: fgp_repro::model::PrecisionModel::pick_format

use std::time::Instant;

use fgp_repro::apps::rls::RlsProblem;
use fgp_repro::benchutil::{banner, json_arr, json_num, json_obj, json_str, write_json};
use fgp_repro::engine::{Precision, Session};
use fgp_repro::fixed::{raw, QFormat};
use fgp_repro::model::{condition_estimate, PrecisionModel};
use fgp_repro::paper;

/// The E9 sweep: the silicon's 16-bit Q5.10 up through a 32-bit word.
const SWEEP: [(u32, u32); 6] = [(5, 10), (5, 12), (5, 14), (5, 18), (5, 22), (5, 26)];

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n = paper::N;
    let sections = if smoke { 16 } else { 24 };
    let sigma2 = 0.02;
    let seeds: &[u64] = if smoke { &[11] } else { &[11, 23, 47] };
    let reps = if smoke { 1 } else { 2 };
    let model = PrecisionModel::default();

    // --- golden f64 references, one per seed
    let mut golden = Session::golden();
    let problems: Vec<RlsProblem> =
        seeds.iter().map(|&s| RlsProblem::synthetic(n, sections, sigma2, s)).collect();
    let refs: Vec<_> = problems
        .iter()
        .map(|p| golden.run(p).map(|out| out.outcome))
        .collect::<Result<_, _>>()?;
    let golden_mse = refs.iter().map(|r| r.rel_mse).sum::<f64>() / refs.len() as f64;

    // the workload's condition estimate drives the per-width bound; all
    // seeds share the shape (same prior, same sigma2), so take the worst
    let cond = problems
        .iter()
        .map(|p| {
            let sects: Vec<_> =
                p.observations.iter().cloned().zip(p.regressors.iter().cloned()).collect();
            condition_estimate(&p.prior, &sects)
        })
        .fold(1.0f64, f64::max);

    banner("per-width conformance vs the golden f64 engine");
    println!("f64 golden mean rel MSE: {golden_mse:.5}  (condition estimate {cond:.1})\n");
    println!(
        "{:>8} {:>6} {:>13} {:>12} {:>12} {:>7} {:>12} {:>10} {:>9} {:>12}",
        "format",
        "width",
        "max|err|",
        "bound",
        "mean MSE",
        "sats",
        "stream msg/s",
        "area mm2",
        "power W",
        "energy nJ/CN"
    );

    let mut violations = 0usize;
    let mut width_rows = Vec::new();
    for (int_bits, frac_bits) in SWEEP {
        let fmt = QFormat::new(int_bits, frac_bits);
        let bound = model.error_bound(fmt, sections, cond);
        // one session per format, routed by the production Precision
        // knob (the same constructor Session::run_stream clients use)
        let mut session = Session::with_precision(Precision::Fixed(fmt));
        raw::take_saturations(); // drain any prior activity
        let mut max_err = 0.0f64;
        let mut mse_sum = 0.0;
        for (p, golden_out) in problems.iter().zip(&refs) {
            // the production path is the streamed one; batch must agree
            // bitwise on fgp-sim (chunk-invariance invariant)
            let stream = session.run_stream(p)?;
            let batch = session.run(p)?;
            assert!(
                stream.outcome.h_hat == batch.outcome.h_hat,
                "q{int_bits}.{frac_bits}: stream vs batch must be bitwise-identical on fgp-sim"
            );
            let err = stream
                .outcome
                .h_hat
                .iter()
                .zip(&golden_out.h_hat)
                .map(|(a, b)| (*a - *b).abs())
                .fold(0.0f64, f64::max);
            max_err = max_err.max(err);
            mse_sum += stream.outcome.rel_mse;
        }
        let sats = raw::take_saturations();
        let mean_mse = mse_sum / problems.len() as f64;

        // host streaming throughput at this width (best of `reps`)
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = Instant::now();
            session.run_stream(&problems[0])?;
            best = best.min(t0.elapsed().as_secs_f64());
        }
        raw::take_saturations(); // timing reruns don't belong to the row
        let rate = sections as f64 / best;

        // Table II extension rows from the width-scaled analytic model
        let area = model.breakdown(n, paper::MEMORY_KBIT, fmt).total();
        let power = model.power_point(fmt, paper::FGP_CN_CYCLES);
        let within = max_err <= bound;
        if !within {
            violations += 1;
        }
        println!(
            "{:>8} {:>6} {:>13.6} {:>12.6} {:>12.5} {:>7} {:>12.0} {:>10.3} {:>9.4} {:>12.1}{}",
            format!("Q{int_bits}.{frac_bits}"),
            fmt.width(),
            max_err,
            bound,
            mean_mse,
            sats,
            rate,
            area,
            power.power_w,
            power.energy_per_cn_nj(),
            if within { "" } else { "  << BOUND VIOLATED" }
        );
        width_rows.push(json_obj(&[
            ("format", json_str(&format!("q{int_bits}.{frac_bits}"))),
            ("width_bits", fmt.width().to_string()),
            ("frac_bits", frac_bits.to_string()),
            ("max_abs_error_vs_golden", json_num(max_err)),
            ("error_bound", json_num(bound)),
            ("within_bound", within.to_string()),
            ("mean_rel_mse", json_num(mean_mse)),
            ("saturations", sats.to_string()),
            ("stream_msgs_per_s", json_num(rate)),
            ("area_mm2", json_num(area)),
            ("power_w", json_num(power.power_w)),
            ("energy_per_cn_nj", json_num(power.energy_per_cn_nj())),
        ]));
    }

    // --- the adaptive-precision policy: narrowest width per target
    banner("adaptive-precision policy (narrowest width meeting a target)");
    let sweep: Vec<QFormat> = SWEEP.iter().map(|&(i, f)| QFormat::new(i, f)).collect();
    let targets = [1.0, 0.25, 0.05, 1e-3, 1e-12];
    let mut policy_rows = Vec::new();
    let mut last_width = 0u32;
    println!("{:>12} {:>10}", "target", "picked");
    for &target in &targets {
        let picked = model.pick_format(target, sections, cond, &sweep);
        let label = picked
            .map_or("f64 (none qualifies)".to_string(), |f| Precision::Fixed(f).to_string());
        println!("{target:>12.0e} {label:>10}");
        // tighter targets must never pick a narrower word
        if let Some(f) = picked {
            assert!(f.width() >= last_width, "policy must widen as targets tighten");
            last_width = f.width();
        } else {
            last_width = u32::MAX;
        }
        policy_rows.push(json_obj(&[
            ("target", json_num(target)),
            (
                "picked",
                picked.map_or("null".to_string(), |f| json_str(&Precision::Fixed(f).to_string())),
            ),
        ]));
    }

    // --- machine-readable trajectory
    let doc = json_obj(&[
        ("bench", json_str("precision_ablation")),
        ("mode", json_str(if smoke { "smoke" } else { "full" })),
        ("sections", sections.to_string()),
        ("seeds", seeds.len().to_string()),
        ("golden_mean_rel_mse", json_num(golden_mse)),
        ("condition_estimate", json_num(cond)),
        ("widths", json_arr(&width_rows)),
        ("policy", json_arr(&policy_rows)),
    ]);
    write_json("BENCH_precision.json", &doc)?;
    println!("\nwrote BENCH_precision.json");

    // --- conformance gate: the bound is the shipping contract
    if violations > 0 {
        eprintln!("CONFORMANCE FAILURE: {violations} width(s) exceeded the asserted error bound");
        std::process::exit(1);
    }
    Ok(())
}
