//! Integration: the cycle-accurate FGP simulator against the f64 golden
//! GMP rules, through the full compile-load-stream-run-readback flow.

use fgp_repro::compiler::{compile, CompileOptions};
use fgp_repro::fgp::processor::{Command, NoFeed, Reply};
use fgp_repro::fgp::{Fgp, FgpConfig, MessageMemory, StateMemory};
use fgp_repro::fixed::QFormat;
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::gmp::{nodes, FactorGraph, NodeKind, Schedule};
use fgp_repro::testutil::{proptest_cases, Rng};

fn scaled_msg(rng: &mut Rng, n: usize, scale: f64) -> GaussMessage {
    GaussMessage::new(
        (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
        CMatrix::random_psd(rng, n, 1.0).scale(scale),
    )
}

/// Compile + run a CN chain of the given length, compare to golden.
fn run_chain(rng: &mut Rng, sections: usize, fmt: QFormat) -> (f64, u64) {
    let n = 4;
    let a_list: Vec<CMatrix> =
        (0..sections).map(|_| CMatrix::random(rng, n, n).scale(0.3)).collect();
    let mut g = FactorGraph::new();
    g.rls_chain(n, &a_list);
    let sched = Schedule::forward_sweep(&g);
    let compiled = compile(&g, &sched, &CompileOptions::default()).unwrap();

    let prior = scaled_msg(rng, n, 0.15);
    let ys: Vec<GaussMessage> = (0..sections).map(|_| scaled_msg(rng, n, 0.1)).collect();

    let mut fgp = Fgp::new(FgpConfig { fmt, ..Default::default() });
    fgp.pm.load(&compiled.program.to_image()).unwrap();
    fgp.msgmem.write_message(compiled.memmap.preloads[0].1, &prior);
    let obs_slot = compiled.memmap.streams[0].1;
    let st_slot = compiled.memmap.state_streams[0].1;

    let ys2 = ys.clone();
    let a2 = a_list.clone();
    let mut feed = move |s: usize, mem: &mut MessageMemory, st: &mut StateMemory| -> bool {
        if s >= ys2.len() {
            return false;
        }
        mem.write_message(obs_slot, &ys2[s]);
        st.write_matrix(st_slot, &a2[s]);
        true
    };
    let stats = fgp.run_program(1, &mut feed).unwrap();

    let mut want = prior;
    for (y, a) in ys.iter().zip(&a_list) {
        want = nodes::compound_observation(&want, y, a, true).unwrap();
    }
    let got = fgp.msgmem.read_message(compiled.memmap.outputs[0].1);
    (got.dist(&want), stats.cycles)
}

#[test]
fn chains_of_many_lengths_match_golden() {
    let mut rng = Rng::new(1);
    for sections in [1usize, 2, 3, 5, 10] {
        let (dist, cycles) = run_chain(&mut rng, sections, QFormat::q5_10());
        assert!(dist < 0.4, "sections={sections}: dist {dist}");
        assert_eq!(
            cycles,
            FgpConfig::default().timing.compound_node_cycles(4) * sections as u64
        );
    }
}

#[test]
fn wide_format_is_numerically_transparent() {
    let mut rng = Rng::new(2);
    for sections in [1usize, 4, 8] {
        let (dist, _) = run_chain(&mut rng, sections, QFormat::new(8, 20));
        assert!(dist < 1e-3, "sections={sections}: dist {dist}");
    }
}

#[test]
fn property_random_compound_nodes_match() {
    // conservative scaling: random PSD draws at 0.15 occasionally produce
    // conditioning that amplifies Q5.10 quantization past 1.0; 0.1/0.25
    // stays inside the envelope for all seeds (outliers are the E9 axis)
    proptest_cases(25, |rng| {
        let n = 4;
        let x = scaled_msg(rng, n, 0.1);
        let y = scaled_msg(rng, n, 0.1);
        let a = CMatrix::random(rng, n, n).scale(0.25);
        let mut g = FactorGraph::new();
        g.rls_chain(n, &[a.clone()]);
        let sched = Schedule::forward_sweep(&g);
        let compiled = compile(&g, &sched, &CompileOptions::default()).unwrap();
        let mut fgp = Fgp::new(FgpConfig::default());
        fgp.pm.load(&compiled.program.to_image()).unwrap();
        fgp.msgmem.write_message(compiled.memmap.preloads[0].1, &x);
        fgp.msgmem.write_message(compiled.memmap.streams[0].1, &y);
        fgp.statemem.write_matrix(compiled.memmap.state_streams[0].1, &a);
        fgp.run_program(1, &mut NoFeed).unwrap();
        let got = fgp.msgmem.read_message(compiled.memmap.outputs[0].1);
        let want = nodes::compound_observation(&x, &y, &a, true).unwrap();
        let d = got.dist(&want);
        assert!(d < 0.1, "dist {d}");
    });
}

#[test]
fn multiply_and_add_nodes_execute_on_device() {
    // graph: multiply by A, then add a preloaded noise message
    let mut rng = Rng::new(3);
    let n = 4;
    let a = CMatrix::random(&mut rng, n, n).scale(0.4);
    let mut g = FactorGraph::new();
    let a_sid = g.add_state(a.clone());
    let x_e = g.add_input_edge(n, "x");
    let q_e = g.add_input_edge(n, "q");
    let mid = g.add_edge(n, "mid");
    let out = g.add_edge(n, "out");
    g.add_node(NodeKind::Multiply { a: a_sid }, vec![x_e], mid, "mul");
    g.add_node(NodeKind::Add, vec![mid, q_e], out, "add");
    g.mark_output(out);
    let sched = Schedule::forward_sweep(&g);
    let compiled = compile(&g, &sched, &CompileOptions::default()).unwrap();

    let x = scaled_msg(&mut rng, n, 0.15);
    let q = scaled_msg(&mut rng, n, 0.1);

    let mut fgp = Fgp::new(FgpConfig::default());
    fgp.pm.load(&compiled.program.to_image()).unwrap();
    // bind preloads by label
    for (mid_, slot) in &compiled.memmap.preloads {
        let edge = sched.inputs.iter().find(|(m, _)| m == mid_).unwrap().1;
        match g.edges[edge.0].label.as_str() {
            "x" => fgp.msgmem.write_message(*slot, &x),
            "q" => fgp.msgmem.write_message(*slot, &q),
            other => panic!("unexpected input {other}"),
        }
    }
    for (sid, slot) in &compiled.memmap.state_preloads {
        let m = if sid.0 == 0 { a.clone() } else { CMatrix::identity(n) };
        fgp.statemem.write_matrix(*slot, &m);
    }
    fgp.run_program(1, &mut NoFeed).unwrap();
    let got = fgp.msgmem.read_message(compiled.memmap.outputs[0].1);
    let want = nodes::add(&nodes::multiply(&x, &a), &q);
    let d = got.dist(&want);
    assert!(d < 0.05, "dist {d}");
}

#[test]
fn command_protocol_full_session() {
    // the Fig. 5 host session: load program, write inputs, start, read
    let mut rng = Rng::new(4);
    let n = 4;
    let a = CMatrix::random(&mut rng, n, n).scale(0.3);
    let mut g = FactorGraph::new();
    g.rls_chain(n, &[a.clone()]);
    let sched = Schedule::forward_sweep(&g);
    let compiled = compile(&g, &sched, &CompileOptions::default()).unwrap();

    let mut fgp = Fgp::new(FgpConfig::default());
    let x = scaled_msg(&mut rng, n, 0.15);
    let y = scaled_msg(&mut rng, n, 0.1);

    assert!(matches!(
        fgp.execute_command(Command::LoadProgram(compiled.program.to_image())),
        Reply::Loaded { instrs: 7 }
    ));
    assert!(matches!(
        fgp.execute_command(Command::WriteMessage {
            slot: compiled.memmap.preloads[0].1,
            msg: x.clone()
        }),
        Reply::Ok
    ));
    assert!(matches!(
        fgp.execute_command(Command::WriteMessage {
            slot: compiled.memmap.streams[0].1,
            msg: y.clone()
        }),
        Reply::Ok
    ));
    assert!(matches!(
        fgp.execute_command(Command::WriteState {
            slot: compiled.memmap.state_streams[0].1,
            a: a.clone()
        }),
        Reply::Ok
    ));
    let stats = match fgp.execute_command(Command::StartProgram { id: 1 }) {
        Reply::Finished(s) => s,
        other => panic!("unexpected {other:?}"),
    };
    assert!(stats.cycles > 0);
    let got = match fgp.execute_command(Command::ReadMessage {
        slot: compiled.memmap.outputs[0].1,
    }) {
        Reply::Message(m) => m,
        other => panic!("unexpected {other:?}"),
    };
    let want = nodes::compound_observation(&x, &y, &a, true).unwrap();
    assert!(got.dist(&want) < 0.1);
}

#[test]
fn saturation_outside_contract_does_not_panic() {
    // grossly out-of-scale inputs must saturate, not crash (failure
    // injection for the fixed-point datapath)
    let mut rng = Rng::new(5);
    let n = 4;
    let a = CMatrix::random(&mut rng, n, n).scale(10.0);
    let mut g = FactorGraph::new();
    g.rls_chain(n, &[a.clone()]);
    let sched = Schedule::forward_sweep(&g);
    let compiled = compile(&g, &sched, &CompileOptions::default()).unwrap();
    let mut fgp = Fgp::new(FgpConfig::default());
    fgp.pm.load(&compiled.program.to_image()).unwrap();
    let big = GaussMessage::isotropic(n, 1000.0);
    fgp.msgmem.write_message(compiled.memmap.preloads[0].1, &big);
    fgp.msgmem.write_message(compiled.memmap.streams[0].1, &big);
    fgp.statemem.write_matrix(compiled.memmap.state_streams[0].1, &a);
    let stats = fgp.run_program(1, &mut NoFeed).unwrap();
    assert!(stats.cycles > 0); // completed despite saturation
}
