//! Integration: the Listing 1 → Listing 2 compile flow end to end —
//! graph → schedule → assembler text → binary image → reload → run —
//! including the E4 check that the output has the paper's program shape.

use fgp_repro::compiler::{compile, CompileOptions};
use fgp_repro::fgp::processor::NoFeed;
use fgp_repro::fgp::{Fgp, FgpConfig};
use fgp_repro::gmp::matrix::CMatrix;
use fgp_repro::gmp::{FactorGraph, Schedule};
use fgp_repro::isa::{parse_listing, Instr, MemoryImage, Program};
use fgp_repro::testutil::Rng;

fn rls(sections: usize, seed: u64) -> (FactorGraph, Schedule) {
    let mut rng = Rng::new(seed);
    let n = 4;
    let a_list: Vec<CMatrix> =
        (0..sections).map(|_| CMatrix::random(&mut rng, n, n).scale(0.3)).collect();
    let mut g = FactorGraph::new();
    g.rls_chain(n, &a_list);
    let s = Schedule::forward_sweep(&g);
    (g, s)
}

/// E4: the 2-section RLS compiles to the paper's Listing 2 shape.
#[test]
fn compile_listing2() {
    let (g, s) = rls(2, 1);
    let c = compile(&g, &s, &CompileOptions::default()).unwrap();
    let mnemonics: Vec<&str> = c.program.instrs.iter().map(|i| i.mnemonic()).collect();
    assert_eq!(
        mnemonics,
        vec!["prg", "mma", "mms", "mms", "fad", "smm", "loop", "halt"],
        "listing:\n{}",
        c.listing()
    );
    // the paper compresses its 2 sections with loop
    assert!(matches!(c.program.instrs[6], Instr::Loop { count: 2, body: 5 }));
}

/// Text → binary → text round-trips (the assembler/disassembler pair).
#[test]
fn asm_image_roundtrip() {
    let (g, s) = rls(4, 2);
    let c = compile(&g, &s, &CompileOptions::default()).unwrap();
    let text = c.listing();
    let reparsed = Program::new(parse_listing(&text).unwrap());
    assert_eq!(reparsed, c.program);
    let image = reparsed.to_image();
    let reloaded = Program::from_image(&MemoryImage { bytes: image.bytes }).unwrap();
    assert_eq!(reloaded, c.program);
}

/// Compressed and straight-line programs produce identical results on
/// the device (the loop instruction's semantic equivalence).
#[test]
fn compressed_and_flat_agree_on_device() {
    let mut rng = Rng::new(3);
    let n = 4;
    let sections = 3;
    let a_list: Vec<CMatrix> =
        (0..sections).map(|_| CMatrix::random(&mut rng, n, n).scale(0.3)).collect();

    let (g, s) = {
        let mut g = FactorGraph::new();
        g.rls_chain(n, &a_list);
        let s = Schedule::forward_sweep(&g);
        (g, s)
    };
    let compressed = compile(&g, &s, &CompileOptions::default()).unwrap();
    let flat = compile(
        &g,
        &s,
        &CompileOptions { compress_loops: false, ..Default::default() },
    )
    .unwrap();
    assert_eq!(compressed.program.unrolled(), flat.program.unrolled());

    use fgp_repro::gmp::message::GaussMessage;
    let prior = GaussMessage::isotropic(n, 0.5);
    let y = GaussMessage::isotropic(n, 0.1);

    let run = |compiled: &fgp_repro::compiler::CompiledProgram| {
        let mut fgp = Fgp::new(FgpConfig::default());
        fgp.pm.load(&compiled.program.to_image()).unwrap();
        fgp.msgmem.write_message(compiled.memmap.preloads[0].1, &prior);
        fgp.msgmem.write_message(compiled.memmap.streams[0].1, &y);
        // constant regressor for all sections so flat/looped feeds agree
        fgp.statemem
            .write_matrix(compiled.memmap.state_streams[0].1, &a_list[0]);
        fgp.run_program(1, &mut NoFeed).unwrap();
        fgp.msgmem.read_message(compiled.memmap.outputs[0].1)
    };
    let a_out = run(&compressed);
    let b_out = run(&flat);
    assert!(a_out.dist(&b_out) < 1e-12, "dist {}", a_out.dist(&b_out));
}

/// Memory-capacity errors surface as typed compile errors, not panics.
#[test]
fn capacity_errors_are_typed() {
    use fgp_repro::compiler::{AllocOptions, CompileError};
    let (g, s) = rls(16, 4);
    let err = compile(
        &g,
        &s,
        &CompileOptions {
            optimize_memory: false,
            alloc: AllocOptions { optimize: false, capacity: 3, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap_err();
    assert!(matches!(err, CompileError::OutOfMemory { .. }));

    let err2 = compile(
        &g,
        &s,
        &CompileOptions { compress_loops: false, pm_capacity: 10, ..Default::default() },
    )
    .unwrap_err();
    assert!(matches!(err2, CompileError::ProgramTooLong { .. }));
}

/// The program image stays within the 64-kbit PM budget even for long
/// chains (thanks to loop compression).
#[test]
fn pm_budget_holds_for_long_chains() {
    let (g, s) = rls(64, 5);
    let c = compile(&g, &s, &CompileOptions::default()).unwrap();
    assert!(c.program.to_image().bits() < 64 * 1024);
    assert_eq!(c.program.instrs.len(), 8);
}

/// Every instruction the compiler can emit decodes back identically
/// after a trip through the binary image.
#[test]
fn emitted_instructions_roundtrip_binary() {
    let (g, s) = rls(8, 6);
    for opts in [
        CompileOptions::default(),
        CompileOptions { optimize_memory: false, ..Default::default() },
        CompileOptions { compress_loops: false, ..Default::default() },
    ] {
        let c = compile(&g, &s, &opts).unwrap();
        for i in &c.program.instrs {
            assert_eq!(&Instr::decode(i.encode()).unwrap(), i);
        }
    }
}
