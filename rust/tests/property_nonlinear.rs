//! Property suite for the nonlinear subsystem (ISSUE 3 contract):
//!
//! 1. both linearizers are **exact** (≤ 1e-9) on affine `h(x) = Hx + b`;
//! 2. sigma-point mean weights sum to 1, and the unscented transform
//!    reproduces the mean/covariance of a linear pushforward;
//! 3. the iterated driver's fixed point on the range model matches a
//!    reference Gauss–Newton solve.

use std::sync::Arc;

use fgp_repro::engine::Session;
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::nonlinear::{
    gauss_newton, real_symmetric, FirstOrder, IteratedRelinearization, Linearizer,
    NonlinearFactor, NonlinearProblem, RelinOptions, SigmaPoint,
};
use fgp_repro::testutil::{proptest_cases, Rng};

const N: usize = 4;

/// A random real affine map `h(x) = Hx + b` over `m` components,
/// packaged as a nonlinear factor (the linearizers do not know it is
/// affine).
fn affine_factor(rng: &mut Rng, m: usize) -> (NonlinearFactor, Vec<Vec<f64>>, Vec<f64>) {
    let h: Vec<Vec<f64>> = (0..m)
        .map(|_| (0..N).map(|_| rng.range(-1.0, 1.0)).collect())
        .collect();
    let b: Vec<f64> = (0..m).map(|_| rng.range(-0.5, 0.5)).collect();
    let z: Vec<f64> = (0..m).map(|_| rng.range(-0.5, 0.5)).collect();
    let hm = h.clone();
    let bm = b.clone();
    let f = NonlinearFactor::new(
        N,
        m,
        Arc::new(move |x: &[f64]| {
            hm.iter()
                .zip(&bm)
                .map(|(row, bi)| row.iter().zip(x).map(|(a, v)| a * v).sum::<f64>() + bi)
                .collect()
        }),
        z,
        1e-2,
    )
    .unwrap();
    (f, h, b)
}

fn real_belief(rng: &mut Rng) -> GaussMessage {
    let mean: Vec<c64> = (0..N).map(|_| c64::new(rng.range(-0.5, 0.5), 0.0)).collect();
    // real SPD covariance: M M^T + ridge
    let mut m = CMatrix::zeros(N, N);
    for i in 0..N {
        for j in 0..N {
            m[(i, j)] = c64::new(rng.range(-0.4, 0.4), 0.0);
        }
    }
    let cov = m.matmul(&m.transpose()).add(&CMatrix::scaled_identity(N, 0.05));
    GaussMessage::new(mean, cov)
}

fn assert_exact_on_affine(linearizer: &dyn Linearizer) {
    proptest_cases(25, |rng| {
        let m = 1 + rng.below(N);
        let (f, h, b) = affine_factor(rng, m);
        let at = real_belief(rng);
        let lin = linearizer.linearize(&f, &at).unwrap();
        // A must equal H (padded), to 1e-9
        for i in 0..N {
            for j in 0..N {
                let want = if i < m { h[i][j] } else { 0.0 };
                assert!(
                    (lin.a[(i, j)].re - want).abs() < 1e-9 && lin.a[(i, j)].im.abs() < 1e-9,
                    "{}: A[{i}][{j}] = {} want {want}",
                    linearizer.name(),
                    lin.a[(i, j)]
                );
            }
        }
        // pseudo-measurement must equal z - b exactly (h(x0) - Hx0 = b)
        for i in 0..m {
            assert!(
                (lin.obs.mean[i].re - (f.z[i] - b[i])).abs() < 1e-9,
                "{}: z_eff[{i}] = {} want {}",
                linearizer.name(),
                lin.obs.mean[i],
                f.z[i] - b[i]
            );
        }
        // no curvature -> no residual: cov stays the pure noise
        let noise = CMatrix::scaled_identity(N, f.noise_var);
        assert!(
            lin.obs.cov.dist(&noise) < 1e-9,
            "{}: residual on affine h: {}",
            linearizer.name(),
            lin.obs.cov.dist(&noise)
        );
    });
}

#[test]
fn first_order_is_exact_on_affine_h() {
    assert_exact_on_affine(&FirstOrder);
}

#[test]
fn sigma_point_is_exact_on_affine_h() {
    assert_exact_on_affine(&SigmaPoint::default());
}

#[test]
fn sigma_weights_sum_to_one() {
    for (alpha, beta, kappa) in [(1.0, 2.0, None), (0.8, 2.0, Some(0.5)), (1.2, 0.0, Some(1.0))] {
        let sp = SigmaPoint { alpha, beta, kappa };
        let (wm, _) = sp.weights(N);
        assert_eq!(wm.len(), 2 * N + 1);
        let sum: f64 = wm.iter().sum();
        assert!(
            (sum - 1.0).abs() < 1e-12,
            "mean weights sum to {sum} for alpha={alpha} kappa={kappa:?}"
        );
    }
}

#[test]
fn unscented_transform_reproduces_linear_pushforward_moments() {
    proptest_cases(25, |rng| {
        let m = 1 + rng.below(N);
        let (f, h, _) = affine_factor(rng, m);
        let at = real_belief(rng);
        let s = SigmaPoint::default().unscented_stats(&f, &at).unwrap();
        // ybar = H xbar + b, Pyy = H P H^T, Pxy = P H^T — compare
        // against the dense products
        let mut hm = CMatrix::zeros(m, N);
        for i in 0..m {
            for j in 0..N {
                hm[(i, j)] = c64::new(h[i][j], 0.0);
            }
        }
        let want_y = f.eval(&s.xbar).unwrap();
        for i in 0..m {
            assert!((s.ybar[i] - want_y[i]).abs() < 1e-9, "ybar[{i}]");
        }
        // real symmetric part of the belief covariance (the matrix the
        // UT itself operates on)
        let p = real_symmetric(&at.cov);
        let want_pyy = hm.matmul(&p).matmul(&hm.transpose());
        let want_pxy = p.matmul(&hm.transpose());
        assert!(s.pyy.dist(&want_pyy) < 1e-9, "Pyy dist {}", s.pyy.dist(&want_pyy));
        assert!(s.pxy.dist(&want_pxy) < 1e-9, "Pxy dist {}", s.pxy.dist(&want_pxy));
    });
}

/// The range model the driver contract is pinned on: anchors ranging a
/// hidden position, exactly `apps/toa`'s geometry.
fn range_problem(rng: &mut Rng, anchors: usize) -> NonlinearProblem {
    let target = (rng.range(0.3, 0.7), rng.range(0.3, 0.7));
    let factors = (0..anchors)
        .map(|i| {
            let th = 2.0 * std::f64::consts::PI * i as f64 / anchors as f64;
            let (ax, ay) = (0.5 + 0.5 * th.cos(), 0.5 + 0.5 * th.sin());
            let d = ((target.0 - ax).powi(2) + (target.1 - ay).powi(2)).sqrt();
            let z = d + rng.normal() * 1e-2;
            NonlinearFactor::new(
                N,
                1,
                Arc::new(move |x: &[f64]| {
                    vec![((x[0] - ax).powi(2) + (x[1] - ay).powi(2)).sqrt()]
                }),
                vec![z],
                1e-3,
            )
            .unwrap()
        })
        .collect();
    let mut mean = vec![c64::ZERO; N];
    mean[0] = c64::new(0.5, 0.0);
    mean[1] = c64::new(0.5, 0.0);
    NonlinearProblem {
        n: N,
        prior: GaussMessage::new(mean, CMatrix::scaled_identity(N, 0.25)),
        motion: None,
        factors,
    }
}

#[test]
fn iterated_driver_fixed_point_matches_gauss_newton() {
    proptest_cases(8, |rng| {
        let problem = range_problem(rng, 5);
        let gn = gauss_newton(&problem, 60, 1e-13).unwrap();
        // only the Jacobian linearizer shares GN's exact fixed point;
        // the sigma-point variant is pinned (looser) in the next test
        let driver = IteratedRelinearization::with_options(
            &FirstOrder,
            RelinOptions { max_rounds: 30, tol: 1e-12, ..Default::default() },
        );
        let report = driver.run(&mut Session::golden(), &problem).unwrap();
        assert!(report.converged(), "driver stopped with {:?}", report.stop);
        for i in 0..2 {
            assert!(
                (report.belief.mean[i].re - gn.mean[i].re).abs() < 1e-7,
                "mean[{i}]: driver {} vs GN {}",
                report.belief.mean[i],
                gn.mean[i]
            );
        }
        // Laplace covariance at the shared fixed point
        assert!(
            report.belief.cov.dist(&gn.cov) < 1e-6,
            "cov dist {}",
            report.belief.cov.dist(&gn.cov)
        );
    });
}

#[test]
fn sigma_point_driver_lands_near_the_same_fixed_point() {
    let mut rng = Rng::new(11);
    let problem = range_problem(&mut rng, 5);
    let gn = gauss_newton(&problem, 60, 1e-13).unwrap();
    let ukf = SigmaPoint::default();
    let driver = IteratedRelinearization::with_options(
        &ukf,
        RelinOptions { max_rounds: 30, tol: 1e-10, ..Default::default() },
    );
    let report = driver.run(&mut Session::golden(), &problem).unwrap();
    // statistical linearization differs from the Jacobian under
    // curvature, so the fixed points agree approximately, not exactly
    for i in 0..2 {
        assert!(
            (report.belief.mean[i].re - gn.mean[i].re).abs() < 5e-3,
            "mean[{i}]: ukf {} vs GN {}",
            report.belief.mean[i],
            gn.mean[i]
        );
    }
}

#[test]
fn linear_problem_converges_in_one_relinearization() {
    // affine h: the first sweep already sits at the fixed point, so the
    // second round's linearization-point delta is (numerically) zero
    let mut rng = Rng::new(5);
    let (f, _, _) = affine_factor(&mut rng, 2);
    let problem = NonlinearProblem {
        n: N,
        prior: real_belief(&mut rng),
        motion: None,
        factors: vec![f],
    };
    let driver = IteratedRelinearization::with_options(
        &FirstOrder,
        RelinOptions { max_rounds: 5, tol: 1e-9, ..Default::default() },
    );
    let report = driver.run(&mut Session::golden(), &problem).unwrap();
    assert!(report.converged());
    // numeric-Jacobian roundoff may cost one extra confirmation round
    assert!(report.rounds <= 3, "affine problem took {} rounds", report.rounds);
}
