//! Cross-engine conformance for the loopy-GBP subsystem — the contract
//! from `gbp`'s module docs:
//!
//! 1. tree-graph GBP reproduces the scheduled-sweep golden result (the
//!    smoother's two-pass program is the same factorization);
//! 2. cyclic-grid GBP converges and its marginals match the dense
//!    information-form solve on the golden engine *and* on the
//!    cycle-accurate FGP simulator (within the fixed-point tolerance);
//! 3. an `FgpFarm`-sharded round is bitwise identical to a
//!    single-device round.

use fgp_repro::apps::grid::GridDenoise;
use fgp_repro::apps::posechain::PoseChain;
use fgp_repro::apps::rls::RlsProblem;
use fgp_repro::apps::smoother::SmootherProblem;
use fgp_repro::coordinator::{FgpFarm, RoutePolicy};
use fgp_repro::engine::Session;
use fgp_repro::fgp::FgpConfig;
use fgp_repro::gbp::{
    ConvergenceCriteria, FarmExecutor, GbpModel, GbpOptions, GbpSolver, IterationPolicy,
    StopReason,
};
use fgp_repro::gmp::matrix::CMatrix;
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::gmp::nodes;

/// Mirror a `SmootherProblem` as a GBP chain model over its *filtered*
/// states: the prior pushed through the first transition becomes the
/// chain head's prior, each transition is a pairwise factor, each
/// observation a unary factor, and the smoother's vague backward-pass
/// initialization is the tail's prior.
fn smoother_as_gbp(p: &SmootherProblem) -> GbpModel {
    let n = p.prior.dim();
    let q = GaussMessage::isotropic(n, p.q_var);
    let mut m = GbpModel::new(n);
    let mut ids = Vec::with_capacity(p.steps);
    for k in 0..p.steps {
        let prior = if k == 0 {
            // the message entering the first observation update:
            // A·prior + Q (same golden ops the scheduled sweep runs)
            Some(nodes::add(&nodes::multiply(&p.prior, &p.a), &q))
        } else if k == p.steps - 1 {
            // the backward pass's vague initialization acts as a prior
            Some(GaussMessage::isotropic(n, p.back_var))
        } else {
            None
        };
        ids.push(m.add_variable(prior, format!("x{k}")).unwrap());
    }
    for (k, obs) in p.observations.iter().enumerate() {
        m.add_unary(ids[k], p.c.clone(), obs.clone()).unwrap();
    }
    for k in 0..p.steps - 1 {
        m.add_pairwise(ids[k], ids[k + 1], p.a.clone(), q.clone()).unwrap();
    }
    m
}

#[test]
fn tree_gbp_reproduces_the_scheduled_sweep() {
    let p = SmootherProblem::synthetic(6, 13);
    // reference: the exact two-pass scheduled program through the
    // golden engine (the path every tier-1 workload uses)
    let sweep = Session::golden().run(&p).unwrap().outcome;

    let model = smoother_as_gbp(&p);
    assert!(!model.has_cycle());
    let report = fgp_repro::gbp::solve(
        model,
        GbpOptions {
            criteria: ConvergenceCriteria { tol: 1e-10, max_iters: 40, divergence: 1e6 },
            ..Default::default()
        },
        &mut Session::golden(),
    )
    .unwrap();
    assert!(report.converged(), "{:?}", report.stop);
    assert_eq!(report.beliefs.len(), sweep.marginals.len());
    for (k, (gbp, sched)) in report.beliefs.iter().zip(&sweep.marginals).enumerate() {
        let d = gbp.dist(sched);
        assert!(
            d < 1e-9 * (1.0 + sched.cov.max_abs()),
            "step {k}: GBP vs scheduled sweep dist {d}"
        );
    }
}

#[test]
fn grid_converges_and_matches_dense_on_golden() {
    let p = GridDenoise::synthetic(3, 3, 0.04, 17);
    let model = p.model().unwrap();
    assert!(model.has_cycle());
    let dense = model.dense_marginals().unwrap();
    let out = p
        .run(
            &mut Session::golden(),
            GbpOptions {
                // acceptance: belief-delta < 1e-6 on a cyclic grid
                criteria: ConvergenceCriteria { tol: 1e-6, max_iters: 100, divergence: 1e3 },
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(out.report.stop, StopReason::Converged);
    assert!(out.report.final_delta < 1e-6);
    for (k, (got, want)) in out.report.beliefs.iter().zip(&dense).enumerate() {
        let mean_err = got
            .mean
            .iter()
            .zip(&want.mean)
            .map(|(a, b)| (*a - *b).abs())
            .fold(0.0, f64::max);
        // Gaussian BP: exact means at the fixed point; covariances
        // approximate on cyclic graphs (Weiss & Freeman 2001)
        assert!(mean_err < 1e-5, "pixel {k} mean err {mean_err}");
        assert!(
            got.cov.dist(&want.cov) < 0.1,
            "pixel {k} cov err {}",
            got.cov.dist(&want.cov)
        );
    }
}

#[test]
fn grid_marginals_track_dense_on_the_device() {
    // the same cyclic workload with every inner update on the Q5.10
    // cycle-accurate simulator; fixed-point tolerance on the marginals
    let p = GridDenoise::synthetic(3, 3, 0.04, 17);
    let dense = p.model().unwrap().dense_marginals().unwrap();
    // undamped on the device: η=0 skips the host-side weight-form
    // round-trip, so every number the solver commits came off the
    // fixed-point datapath
    let opts = GbpOptions {
        policy: IterationPolicy::Synchronous { eta_damping: 0.0 },
        criteria: ConvergenceCriteria { tol: 2e-2, max_iters: 40, divergence: 1e3 },
        init_var: 4.0,
        ..Default::default()
    };
    let out = p.run(&mut Session::fgp_sim(FgpConfig::default()), opts).unwrap();
    assert_ne!(out.report.stop, StopReason::Diverged, "{:?}", out.report.delta_history);
    let tolerance = 0.15; // documented fixed-point slack for this workload
    for (k, (got, want)) in out.report.beliefs.iter().zip(&dense).enumerate() {
        let mean_err = got
            .mean
            .iter()
            .zip(&want.mean)
            .map(|(a, b)| (*a - *b).abs())
            .fold(0.0, f64::max);
        assert!(
            mean_err < tolerance,
            "pixel {k}: device mean err {mean_err} exceeds {tolerance}"
        );
    }
    // the denoised field must still beat the raw observations
    assert!(out.rmse < out.noisy_rmse, "rmse {} vs noisy {}", out.rmse, out.noisy_rmse);
}

#[test]
fn farm_sharded_round_is_bitwise_identical_to_single_device() {
    let p = GridDenoise::synthetic(2, 2, 0.04, 23);
    let model = p.model().unwrap();
    // fixed two rounds, undamped (η=0 commits engine outputs verbatim)
    let opts = GbpOptions {
        policy: IterationPolicy::Synchronous { eta_damping: 0.0 },
        criteria: ConvergenceCriteria { tol: 0.0, max_iters: 2, divergence: 1e9 },
        init_var: 4.0,
        ..Default::default()
    };

    let mut single = GbpSolver::new(model.clone(), opts).unwrap();
    let mut session = Session::fgp_sim(FgpConfig::default());
    let single_report = single.run(&mut session).unwrap();

    let farm = FgpFarm::start(3, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
    let mut sharded = GbpSolver::new(model, opts).unwrap();
    let sharded_report = sharded.run(&mut FarmExecutor { farm: &farm }).unwrap();

    // every device ran work: the round really was sharded
    let loads = farm.load_profile();
    assert!(loads.iter().all(|c| *c > 0), "round not sharded: {loads:?}");

    for (f, (a, b)) in single
        .state()
        .forward
        .iter()
        .zip(&sharded.state().forward)
        .enumerate()
    {
        assert!(a.dist(b) == 0.0, "forward message {f} differs across executors");
    }
    for (f, (a, b)) in single
        .state()
        .backward
        .iter()
        .zip(&sharded.state().backward)
        .enumerate()
    {
        assert!(a.dist(b) == 0.0, "backward message {f} differs across executors");
    }
    for (v, (a, b)) in single_report
        .beliefs
        .iter()
        .zip(&sharded_report.beliefs)
        .enumerate()
    {
        assert!(a.dist(b) == 0.0, "belief {v} differs across executors");
    }
}

#[test]
fn pose_loop_conforms_on_the_device() {
    let p = PoseChain::synthetic(6, 0.004, 9);
    let golden = p
        .run(
            &mut Session::golden(),
            GbpOptions {
                // weakly-anchored rings contract slowly (~0.88/round)
                criteria: ConvergenceCriteria { tol: 1e-6, max_iters: 400, divergence: 1e3 },
                ..Default::default()
            },
        )
        .unwrap();
    assert!(golden.report.converged(), "{:?}", golden.report.stop);
    let device = p
        .run(
            &mut Session::fgp_sim(FgpConfig::default()),
            GbpOptions {
                policy: IterationPolicy::Synchronous { eta_damping: 0.0 },
                criteria: ConvergenceCriteria { tol: 2e-2, max_iters: 60, divergence: 1e3 },
                init_var: 4.0,
                ..Default::default()
            },
        )
        .unwrap();
    assert_ne!(device.report.stop, StopReason::Diverged);
    // fixed-point estimate stays in the golden regime
    assert!(
        device.rmse <= golden.rmse + 0.15,
        "device rmse {} vs golden {}",
        device.rmse,
        golden.rmse
    );
}

#[test]
fn one_session_serves_scheduled_and_loopy_workloads() {
    // the §I thesis extended: one device session runs a compiled
    // scheduled sweep AND the loopy solver's compound-node rounds
    let mut sim = Session::fgp_sim(FgpConfig::default());
    let rls = RlsProblem::synthetic(4, 8, 0.02, 31);
    assert!(sim.run(&rls).is_ok());

    let p = GridDenoise::synthetic(2, 2, 0.04, 33);
    let out = p
        .run(
            &mut sim,
            GbpOptions {
                policy: IterationPolicy::Synchronous { eta_damping: 0.0 },
                criteria: ConvergenceCriteria { tol: 2e-2, max_iters: 10, divergence: 1e3 },
                init_var: 4.0,
                ..Default::default()
            },
        )
        .unwrap();
    assert_ne!(out.report.stop, StopReason::Diverged);
    // GBP rounds reuse cached programs: after round one, every edge
    // shape is a cache hit
    let stats = sim.cache_stats();
    assert!(stats.hits > stats.misses, "{stats:?}");
}

#[test]
fn model_shapes_are_device_checked() {
    // a GBP model over n=6 cannot run on the n=4 device: typed error,
    // no panic
    let mut m = GbpModel::new(6);
    let a = m.add_variable(Some(GaussMessage::isotropic(6, 1.0)), "a").unwrap();
    let b = m.add_variable(Some(GaussMessage::isotropic(6, 1.0)), "b").unwrap();
    m.add_pairwise(a, b, CMatrix::identity(6), GaussMessage::isotropic(6, 0.1)).unwrap();
    let err = fgp_repro::gbp::solve(
        m,
        GbpOptions::default(),
        &mut Session::fgp_sim(FgpConfig::default()),
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("n=4"), "{err:#}");
}
