//! The EM subsystem contract, across layers (S15):
//!
//! 1. **Recovery** — EM recovers the synthetic ground-truth
//!    observation-noise variance on the RLS fixture to ≤ 5 % relative
//!    error (golden engine; the batch acceptance pin).
//! 2. **Cache observability** — on fgp-sim every EM round after the
//!    first hits the session program cache: rounds rebind data, never
//!    reshape the model.
//! 3. **GBP marginals** — a GBP solve's beliefs serve as the E-step's
//!    posterior marginals unchanged (tree model: exact EM).
//! 4. **Serving** — online EM wrapped around a recursive stream rides a
//!    sticky farm stream unchanged, bitwise identical to one session.

use fgp_repro::apps::kalman::{AdaptiveKalman, KalmanProblem};
use fgp_repro::apps::rls::{NoiseEmRls, RlsProblem};
use fgp_repro::coordinator::{FgpFarm, RoutePolicy};
use fgp_repro::em::{
    EmDriver, EmOptions, EmParameter, Evidence, ObsNoiseVar, OnlineEm, SuffStats,
};
use fgp_repro::engine::{Session, StreamingWorkload};
use fgp_repro::fgp::FgpConfig;
use fgp_repro::gbp::{solve, GbpModel, GbpOptions};
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::testutil::Rng;

/// Acceptance pin: ≤ 5 % relative recovery of sigma^2 on the RLS
/// fixture, starting 10x off.
#[test]
fn em_recovers_rls_noise_within_five_percent() {
    let true_sigma2 = 0.01;
    let p = RlsProblem::synthetic(4, 256, true_sigma2, 17);
    let mut em = NoiseEmRls::new(p, true_sigma2 * 10.0);
    let report = EmDriver::new().run(&mut Session::golden(), &mut em).unwrap();
    assert!(report.converged(), "stop {:?}", report.stop);
    let rel = (report.values[0] - true_sigma2).abs() / true_sigma2;
    assert!(rel <= 0.05, "sigma2 {} rel err {rel}", report.values[0]);
}

/// Acceptance pin: on fgp-sim, every round after the first is a
/// program-cache hit (the rounds change message data only).
#[test]
fn em_rounds_hit_program_cache_on_fgp_sim() {
    let p = RlsProblem::synthetic(4, 48, 0.01, 17);
    let mut em = NoiseEmRls::new(p, 0.1);
    let mut session = Session::fgp_sim(FgpConfig::default());
    let driver = EmDriver::with_options(EmOptions {
        max_rounds: 6,
        tol: 0.0, // force all six rounds
        divergence: 1e9,
    });
    let report = driver.run(&mut session, &mut em).unwrap();
    assert_eq!(report.rounds, 6);
    assert_eq!(report.cached.len(), 6);
    assert!(!report.cached[0], "first round must compile");
    assert!(
        report.cached[1..].iter().all(|c| *c),
        "every round after the first must hit the cache: {:?}",
        report.cached
    );
    let stats = session.cache_stats();
    assert_eq!(stats.misses, 1, "{stats:?}");
    assert_eq!(stats.hits, 5, "{stats:?}");
}

/// The adaptive-Kalman E-step (a per-sample stream) shows the same
/// cache shape: one compile for the chunk model, hits from then on.
#[test]
fn adaptive_kalman_rounds_hit_cache_on_fgp_sim() {
    let p = KalmanProblem::synthetic(16, 5);
    let mut em = AdaptiveKalman::new(p, 0.02);
    let mut session = Session::fgp_sim(FgpConfig::default());
    let driver = EmDriver::with_options(EmOptions {
        max_rounds: 3,
        tol: 0.0,
        divergence: 1e9,
    });
    let report = driver.run(&mut session, &mut em).unwrap();
    assert_eq!(report.cached, vec![false, true, true]);
    assert_eq!(session.cache_stats().misses, 1);
}

/// GBP beliefs are the E-step's marginals: estimate the unary-factor
/// noise of a chain (tree) model from the solved beliefs. On a tree the
/// beliefs are exact marginals, so this is exact EM.
#[test]
fn gbp_marginals_drive_em_noise_estimate() {
    let n = 4;
    let vars = 8;
    let true_sigma2 = 0.05;
    let mut rng = Rng::new(11);
    // generative walk: x_0 ~ N(0, I), x_{v+1} = x_v + w, w ~ CN(0, 0.2 I)
    let mut truth: Vec<Vec<c64>> = Vec::with_capacity(vars);
    let mut x: Vec<c64> = (0..n)
        .map(|_| c64::new(rng.normal(), rng.normal()))
        .collect();
    truth.push(x.clone());
    for _ in 1..vars {
        for xi in x.iter_mut() {
            let s = (0.2f64 / 2.0).sqrt();
            *xi = *xi + c64::new(rng.normal() * s, rng.normal() * s);
        }
        truth.push(x.clone());
    }
    let observations: Vec<Vec<c64>> = truth
        .iter()
        .map(|xv| {
            xv.iter()
                .map(|xi| {
                    let s = (true_sigma2 / 2.0).sqrt();
                    *xi + c64::new(rng.normal() * s, rng.normal() * s)
                })
                .collect()
        })
        .collect();

    let build = |sigma2: f64| -> GbpModel {
        let mut m = GbpModel::new(n);
        let ids: Vec<_> = (0..vars)
            .map(|v| {
                // the generative prior on x_0; a vague proper prior on
                // the chain tail (a prior-less end variable with one
                // pairwise factor would leave an improper cavity)
                let prior = if v == 0 {
                    Some(GaussMessage::isotropic(n, 1.0))
                } else if v == vars - 1 {
                    Some(GaussMessage::isotropic(n, 10.0))
                } else {
                    None
                };
                m.add_variable(prior, format!("x{v}")).unwrap()
            })
            .collect();
        for v in 1..vars {
            m.add_pairwise(
                ids[v - 1],
                ids[v],
                CMatrix::identity(n),
                GaussMessage::isotropic(n, 0.2),
            )
            .unwrap();
        }
        for (v, y) in observations.iter().enumerate() {
            m.add_unary(
                ids[v],
                CMatrix::identity(n),
                GaussMessage::observation(y, sigma2),
            )
            .unwrap();
        }
        m
    };

    let identity = CMatrix::identity(n);
    let observed: Vec<usize> = (0..n).collect();
    let mut noise = ObsNoiseVar::new(true_sigma2 * 10.0);
    let mut session = Session::golden();
    for _ in 0..12 {
        let report = solve(build(noise.value()), GbpOptions::default(), &mut session).unwrap();
        assert!(report.converged(), "GBP stop {:?}", report.stop);
        let mut acc = SuffStats::default();
        for (belief, y) in report.marginals().iter().zip(&observations) {
            noise
                .accumulate(
                    &Evidence::Observation {
                        marginal: belief,
                        a: &identity,
                        y,
                        observed: &observed,
                    },
                    &mut acc,
                )
                .unwrap();
        }
        noise.m_step(&acc).unwrap();
    }
    let got = noise.value();
    let rel = (got - true_sigma2).abs() / true_sigma2;
    // 8 vars x 4 complex components: the ML estimate itself carries
    // ~1/sqrt(32) sampling error; the EM must land in its regime and
    // far from the 10x start
    assert!(rel < 1.0, "sigma2 {got} rel err {rel}");
    assert!(got < true_sigma2 * 3.0 && got > true_sigma2 / 3.0, "sigma2 {got}");
}

/// Online EM over a sticky farm stream is bitwise identical to the same
/// stream on a single fgp-sim session — the coordinator serves the
/// adaptive wrapper unchanged.
#[test]
fn online_em_rides_sticky_farm_stream_unchanged() {
    let true_sigma2 = 0.01;
    let make = || OnlineEm::new(RlsProblem::synthetic(4, 512, true_sigma2, 1), 0.1);

    let single = make();
    let report = Session::fgp_sim(FgpConfig::default()).run_stream(&single).unwrap();
    assert_eq!(report.samples, 512);

    let farm = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
    let farmed = make();
    let stream = farm.open_stream(&farmed).unwrap();
    let run = stream.run_to_end().unwrap();
    assert_eq!(run.samples, 512);
    let outcome = farmed.stream_outcome(&run).unwrap();

    // bitwise identical: same chunking, same device arithmetic, same
    // adaptation trajectory
    assert_eq!(report.final_state.dist(&run.final_state), 0.0);
    assert_eq!(report.outcome.sigma2, outcome.sigma2);
    // and the estimate actually adapted away from the 10x start
    let rel = (outcome.sigma2 - true_sigma2).abs() / true_sigma2;
    assert!(rel < 0.5, "online sigma2 {} rel err {rel}", outcome.sigma2);
}

/// Online EM on golden (chunk 1) and fgp-sim (chunked) both land near
/// the truth: per-chunk accumulation is an execution granularity, not a
/// different estimator.
#[test]
fn online_em_is_chunking_robust() {
    let true_sigma2 = 0.01;
    let golden = OnlineEm::new(RlsProblem::synthetic(4, 512, true_sigma2, 9), 0.1);
    let g = Session::golden().run_stream(&golden).unwrap();
    let sim = OnlineEm::new(RlsProblem::synthetic(4, 512, true_sigma2, 9), 0.1);
    let f = Session::fgp_sim(FgpConfig::default()).run_stream(&sim).unwrap();
    let rg = (g.outcome.sigma2 - true_sigma2).abs() / true_sigma2;
    let rf = (f.outcome.sigma2 - true_sigma2).abs() / true_sigma2;
    assert!(rg < 0.15, "golden online sigma2 {} rel {rg}", g.outcome.sigma2);
    assert!(rf < 0.5, "fgp-sim online sigma2 {} rel {rf}", f.outcome.sigma2);
}
