//! Integration: the streaming steady-state execution path (E14).
//!
//! The contract under test: `Session::run_stream` is an *execution
//! strategy*, never a different algorithm —
//!
//! * stream == repeated/batch `Session::run` **exactly** on the golden
//!   engine and **bitwise** on the cycle-accurate simulator (the
//!   posterior's fixed-point slot round-trips through f64 losslessly at
//!   chunk boundaries);
//! * the steady-state chunk program compiles once and is a cache hit for
//!   every later chunk and stream;
//! * tail chunks (stream length not a multiple of the chunk) stay
//!   exact — via a one-off tail program on the simulator and `A = 0`
//!   identity-section padding on the XLA chain artifact;
//! * farm streams are sticky (one device per stream) and identical to a
//!   single-session run, including under concurrent clients;
//! * the coalescer batches across concurrent recursive streams without
//!   mixing their recursions;
//! * the fixed-point production path: a session that declares
//!   `Precision::Fixed(fmt)` streams bitwise-identically to its own
//!   batch run at every width, stays within the analytic error bound vs
//!   the golden f64 engine, and a *declared* width on the farm/coalescer
//!   path lands on exactly the same bits as devices *configured* at
//!   that width.

use fgp_repro::apps::bearing::BearingProblem;
use fgp_repro::apps::kalman::KalmanProblem;
use fgp_repro::apps::rls::RlsProblem;
use fgp_repro::apps::smoother::SmootherProblem;
use fgp_repro::coordinator::backend::{Backend, FgpSimBackend, GoldenBackend};
use fgp_repro::coordinator::{CnStream, FarmCnBackend, FgpFarm, RoutePolicy, StreamCoalescer};
use fgp_repro::engine::{Precision, Session, StreamBinder, StreamingWorkload};
use fgp_repro::fgp::FgpConfig;
use fgp_repro::fixed::QFormat;
use fgp_repro::model::{condition_estimate, PrecisionModel};
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::gmp::nodes;
use fgp_repro::nonlinear::FirstOrder;
use fgp_repro::testutil::Rng;

fn vec_dist(a: &[c64], b: &[c64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (*x - *y).abs2()).sum::<f64>().sqrt()
}

// ---------------------------------------------------------------------
// stream == batch conformance
// ---------------------------------------------------------------------

#[test]
fn rls_stream_matches_batch_run_on_golden() {
    // 70 samples: one full default chunk (64) plus a 6-sample tail
    let p = RlsProblem::synthetic(4, 70, 0.01, 3);
    let batch = Session::golden().run(&p).unwrap();
    let stream = Session::golden().run_stream(&p).unwrap();
    assert_eq!(stream.samples, 70);
    // golden streams run sample-at-a-time: a boundary per sample
    assert_eq!(stream.chunks, 70);
    assert_eq!(stream.compiles, 0);
    // identical op sequence => identical f64 estimate
    assert_eq!(vec_dist(&stream.outcome.h_hat, &batch.outcome.h_hat), 0.0);
}

#[test]
fn rls_stream_is_bitwise_identical_on_fgp_sim() {
    let p = RlsProblem::synthetic(4, 70, 0.01, 3);
    let batch = Session::fgp_sim(FgpConfig::default()).run(&p).unwrap();
    let stream = Session::fgp_sim(FgpConfig::default()).run_stream(&p).unwrap();
    // the posterior slot's fixed-point value round-trips through f64
    // losslessly at the chunk boundary, so chunked streaming is bitwise
    // identical to the single 70-section program
    assert_eq!(vec_dist(&stream.outcome.h_hat, &batch.outcome.h_hat), 0.0);
    // honest cycle accounting: same sections, same simulated cycles
    assert_eq!(stream.sections, batch.sections);
    assert_eq!(stream.cycles, batch.cycles);
    assert_eq!(stream.cycles_per_sample(), FgpConfig::default().timing.compound_node_cycles(4));
}

#[test]
fn stream_compiles_chunk_and_tail_once_then_hits() {
    let p = RlsProblem::synthetic(4, 70, 0.01, 9);
    let mut sim = Session::fgp_sim(FgpConfig::default());
    let first = sim.run_stream(&p).unwrap();
    // one full 64-sample chunk + one 6-sample tail => two programs
    assert_eq!((first.chunks, first.compiles, first.cache_hits), (2, 2, 0));
    let second = sim.run_stream(&p).unwrap();
    assert_eq!(second.compiles, 0);
    assert_eq!(second.cache_hits, 2);
    let stats = sim.cache_stats();
    assert_eq!((stats.misses, stats.hits, stats.programs), (2, 2, 2), "{stats:?}");
}

#[test]
fn kalman_stream_matches_batch_on_both_engines() {
    let p = KalmanProblem::synthetic(20, 5);
    let g_batch = Session::golden().run(&p).unwrap();
    let g_stream = Session::golden().run_stream(&p).unwrap();
    assert_eq!(vec_dist(&g_stream.outcome.estimate, &g_batch.outcome.estimate), 0.0);

    let f_batch = Session::fgp_sim(FgpConfig::default()).run(&p).unwrap();
    let f_stream = Session::fgp_sim(FgpConfig::default()).run_stream(&p).unwrap();
    assert_eq!(vec_dist(&f_stream.outcome.estimate, &f_batch.outcome.estimate), 0.0);
    // three store handshakes per time step, streamed or batched
    assert_eq!(f_stream.sections, 3 * 20);
    assert_eq!(f_stream.sections, f_batch.sections);
}

#[test]
fn smoother_stream_is_exactly_the_forward_filter() {
    let p = SmootherProblem::synthetic(40, 7);
    let batch = Session::golden().run(&p).unwrap();
    let stream = Session::golden().run_stream(&p).unwrap();
    // the stream serves the filtered (forward) posterior; the batch
    // two-pass graph computes the same forward chain before smoothing
    let last_filtered = batch.outcome.filtered.last().unwrap();
    assert_eq!(stream.outcome.final_filtered.dist(last_filtered), 0.0);
    assert!(stream.outcome.pos_error.is_finite());
}

#[test]
fn smoother_stream_runs_on_the_device() {
    let p = SmootherProblem::synthetic(20, 13);
    let golden = Session::golden().run_stream(&p).unwrap();
    let device = Session::fgp_sim(FgpConfig::default()).run_stream(&p).unwrap();
    assert!(device.cycles > 0);
    assert_eq!(device.sections, 3 * 20);
    // forward filtering only: the quantized posterior must stay in the
    // golden regime (the batch Workload's cross-engine contract)
    assert!(
        device.outcome.final_filtered.dist(&golden.outcome.final_filtered) < 0.25,
        "device vs golden filtered dist {}",
        device.outcome.final_filtered.dist(&golden.outcome.final_filtered)
    );
}

// ---------------------------------------------------------------------
// nonlinear streams (state-dependent binding, chunk == 1)
// ---------------------------------------------------------------------

#[test]
fn bearing_stream_equals_single_round_tracking_on_golden() {
    let p = BearingProblem::synthetic(8, 4, 1e-4, 3);
    // rounds = 1 relinearizes once at the predicted mean per step —
    // exactly the streaming semantics
    let track = p.track(&mut Session::golden(), &FirstOrder, 1).unwrap();
    let stream = Session::golden().run_stream(&p.stream(&FirstOrder)).unwrap();
    assert_eq!(stream.outcome.estimates.len(), track.estimates.len());
    for (s, t) in stream.outcome.estimates.iter().zip(&track.estimates) {
        assert!((s.0 - t.0).abs() < 1e-12 && (s.1 - t.1).abs() < 1e-12, "{s:?} vs {t:?}");
    }
    assert!(!stream.outcome.diverged);
}

#[test]
fn bearing_stream_runs_hot_on_one_compiled_program() {
    let p = BearingProblem::synthetic(5, 4, 1e-3, 7);
    let mut sim = Session::fgp_sim(FgpConfig::default());
    let stream = sim.run_stream(&p.stream(&FirstOrder)).unwrap();
    assert_eq!(stream.samples, 5);
    // the sweep shape is fixed: one compile for the whole track
    assert_eq!(stream.compiles, 1);
    assert_eq!(stream.cache_hits, 0);
    assert!(!stream.outcome.diverged);
    assert!(stream.outcome.rmse < 0.15, "device stream rmse {}", stream.outcome.rmse);
    // identical to per-step tracking with one relinearization round
    let track = p.track(&mut Session::fgp_sim(FgpConfig::default()), &FirstOrder, 1).unwrap();
    for (s, t) in stream.outcome.estimates.iter().zip(&track.estimates) {
        assert!((s.0 - t.0).abs() < 1e-12 && (s.1 - t.1).abs() < 1e-12, "{s:?} vs {t:?}");
    }
}

// ---------------------------------------------------------------------
// tail padding (the XLA chain-artifact contract)
// ---------------------------------------------------------------------

#[test]
fn a_zero_section_is_an_identity_update() {
    // the pad the XLA stream path relies on: A = 0 zeroes the gain, so
    // a padded section returns the prior untouched
    let mut rng = Rng::new(5);
    let x = GaussMessage::new(
        (0..4).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
        CMatrix::random_psd(&mut rng, 4, 1.0).scale(0.15),
    );
    let y = GaussMessage::new(vec![c64::ZERO; 4], CMatrix::scaled_identity(4, 0.01));
    let zero = CMatrix::zeros(4, 4);
    for faddeev in [false, true] {
        let out = nodes::compound_observation(&x, &y, &zero, faddeev).unwrap();
        assert!(out.dist(&x) < 1e-12, "faddeev={faddeev}: dist {}", out.dist(&x));
    }
}

#[test]
fn padded_chunk_equals_unpadded_tail_on_golden() {
    let p = RlsProblem::synthetic(4, 2, 0.01, 11);
    // a 4-sample binder fed 2 real samples + 2 identity pads must yield
    // the same posterior as folding just the 2 real samples
    let mut binder = StreamBinder::build(&p, 4).unwrap();
    assert!(binder.paddable());
    let real: Vec<_> = (0..2)
        .map(|k| p.next_sample(k, &p.prior).unwrap().unwrap())
        .collect();
    let pad = binder.pad_sample(&real[1]);
    let batch = [real[0].clone(), real[1].clone(), pad.clone(), pad];
    binder.bind(&p.initial_state(), &batch).unwrap();
    let d = Session::golden()
        .dispatch(&binder.graph, &binder.schedule, &binder.inputs, &Default::default())
        .unwrap();
    let padded_out = d.exec.output().unwrap().clone();

    let mut want = p.prior.clone();
    for k in 0..2 {
        want = nodes::compound_observation(&want, &p.observations[k], &p.regressors[k], false)
            .unwrap();
    }
    assert!(padded_out.dist(&want) < 1e-12, "dist {}", padded_out.dist(&want));
}

// ---------------------------------------------------------------------
// farm streams: sticky routing + concurrent identity
// ---------------------------------------------------------------------

#[test]
fn farm_stream_is_identical_to_a_session_stream() {
    let p = RlsProblem::synthetic(4, 70, 0.01, 17);
    let reference = Session::fgp_sim(FgpConfig::default()).run_stream(&p).unwrap();
    let farm = FgpFarm::start(1, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
    let stream = farm.open_stream(&p).unwrap();
    assert_eq!(stream.device(), 0);
    let run = stream.run_to_end().unwrap();
    assert_eq!(run.samples, 70);
    assert_eq!(run.final_state.dist(&reference.final_state), 0.0);
}

#[test]
fn two_concurrent_farm_streams_stay_sticky_and_identical() {
    let p1 = RlsProblem::synthetic(4, 70, 0.01, 21);
    let p2 = RlsProblem::synthetic(4, 66, 0.02, 22);
    let solo1 = Session::fgp_sim(FgpConfig::default()).run_stream(&p1).unwrap();
    let solo2 = Session::fgp_sim(FgpConfig::default()).run_stream(&p2).unwrap();

    let farm = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
    // open on the main thread: round-robin pins stream 1 -> device 0,
    // stream 2 -> device 1
    let s1 = farm.open_stream(&p1).unwrap();
    let s2 = farm.open_stream(&p2).unwrap();
    assert_ne!(s1.device(), s2.device());
    let (r1, r2) = std::thread::scope(|scope| {
        let h1 = scope.spawn(move || s1.run_to_end().unwrap());
        let h2 = scope.spawn(move || s2.run_to_end().unwrap());
        (h1.join().unwrap(), h2.join().unwrap())
    });
    // sharded serving must not change a single bit of either stream
    assert_eq!(r1.final_state.dist(&solo1.final_state), 0.0);
    assert_eq!(r2.final_state.dist(&solo2.final_state), 0.0);
    let loads = farm.load_profile();
    assert!(loads.iter().all(|c| *c > 0), "both devices must have served: {loads:?}");
}

// ---------------------------------------------------------------------
// coalesced concurrent streams on the device backend
// ---------------------------------------------------------------------

#[test]
fn coalescer_keeps_stream_identity_on_the_device_backend() {
    let mut rng = Rng::new(31);
    let msg = |rng: &mut Rng| {
        GaussMessage::new(
            (0..4).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, 4, 1.0).scale(0.15),
        )
    };
    let lens = [5usize, 3];
    let mut streams = Vec::new();
    let mut priors = Vec::new();
    let mut samples = Vec::new();
    for &len in &lens {
        let prior = msg(&mut rng);
        let mut s = CnStream::new(prior.clone());
        let data: Vec<(GaussMessage, CMatrix)> = (0..len)
            .map(|_| (msg(&mut rng), CMatrix::random(&mut rng, 4, 4).scale(0.3)))
            .collect();
        for (y, a) in &data {
            s.push(y.clone(), a.clone());
        }
        streams.push(s);
        priors.push(prior);
        samples.push(data);
    }
    let mut coalesced = FgpSimBackend::new(FgpConfig::default()).unwrap();
    let total = StreamCoalescer::drain(&mut coalesced, &mut streams).unwrap();
    assert_eq!(total, 8);
    // reference: each stream served alone on a fresh device
    for (i, s) in streams.iter().enumerate() {
        let mut solo = FgpSimBackend::new(FgpConfig::default()).unwrap();
        let mut want = priors[i].clone();
        for (y, a) in &samples[i] {
            want = solo
                .cn_update(&fgp_repro::coordinator::CnRequestData {
                    x: want,
                    y: y.clone(),
                    a: a.clone(),
                })
                .unwrap();
        }
        assert_eq!(s.state.dist(&want), 0.0, "stream {i}");
    }
}

#[test]
fn coalescer_survives_streams_draining_at_different_times() {
    // golden backend; the short stream drains first, later ticks run
    // under-full ("tail") batches
    let mut rng = Rng::new(41);
    let msg = |rng: &mut Rng| {
        GaussMessage::new(
            (0..4).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, 4, 1.0).scale(0.15),
        )
    };
    let mut streams = [CnStream::new(msg(&mut rng)), CnStream::new(msg(&mut rng))];
    for _ in 0..6 {
        let y = msg(&mut rng);
        streams[0].push(y, CMatrix::random(&mut rng, 4, 4).scale(0.3));
    }
    streams[1].push(msg(&mut rng), CMatrix::random(&mut rng, 4, 4).scale(0.3));
    let mut backend = GoldenBackend;
    assert_eq!(StreamCoalescer::tick(&mut backend, &mut streams).unwrap(), 2);
    assert_eq!(StreamCoalescer::tick(&mut backend, &mut streams).unwrap(), 1);
    assert_eq!(StreamCoalescer::drain(&mut backend, &mut streams).unwrap(), 4);
    assert_eq!(streams[0].samples_done, 6);
    assert_eq!(streams[1].samples_done, 1);
}

// ---------------------------------------------------------------------
// fixed-point production path: declared precision, stream == batch
// ---------------------------------------------------------------------

#[test]
fn fixed_point_stream_equals_batch_bitwise_and_stays_within_the_golden_bound() {
    let p = RlsProblem::synthetic(4, 70, 0.01, 3);
    let golden = Session::golden().run(&p).unwrap();
    let sections: Vec<_> =
        p.observations.iter().cloned().zip(p.regressors.iter().cloned()).collect();
    let cond = condition_estimate(&p.prior, &sections);
    let model = PrecisionModel::default();
    // pinned per-Q-format fixture: widening the word must never move the
    // estimate further from the golden engine
    let mut last_err = f64::INFINITY;
    for fmt in [QFormat::q5_10(), QFormat::new(5, 14), QFormat::new(8, 20)] {
        let mut session = Session::with_precision(Precision::Fixed(fmt));
        let stream = session.run_stream(&p).unwrap();
        let batch = session.run(&p).unwrap();
        // stream and batch share the scalar/SoA fixed kernels: chunked
        // streaming must be bitwise identical to the one-shot fold
        assert_eq!(
            vec_dist(&stream.outcome.h_hat, &batch.outcome.h_hat),
            0.0,
            "{fmt:?}: fixed stream vs batch must be bitwise identical"
        );
        let err = stream
            .outcome
            .h_hat
            .iter()
            .zip(&golden.outcome.h_hat)
            .map(|(a, b)| (*a - *b).abs())
            .fold(0.0f64, f64::max);
        assert!(err > 0.0, "{fmt:?}: the quantized datapath must actually be on the path");
        let bound = model.error_bound(fmt, 70, cond);
        assert!(err <= bound, "{fmt:?}: error {err} escapes the asserted bound {bound}");
        assert!(err <= last_err, "{fmt:?}: a wider word must not drift further from golden");
        last_err = err;
    }
}

#[test]
fn coalescer_with_declared_precision_matches_devices_configured_at_that_width() {
    // the serving tier's coalesced fixed path: a DECLARED width on
    // default-width devices must land on the same bits as a solo fold on
    // a device CONFIGURED at that width
    let fmt = QFormat::new(8, 20);
    let mut rng = Rng::new(53);
    let msg = |rng: &mut Rng| {
        GaussMessage::new(
            (0..4).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, 4, 1.0).scale(0.15),
        )
    };
    let lens = [5usize, 3];
    let mut streams = Vec::new();
    let mut priors = Vec::new();
    let mut samples = Vec::new();
    for &len in &lens {
        let prior = msg(&mut rng);
        let mut s = CnStream::new(prior.clone());
        let data: Vec<(GaussMessage, CMatrix)> = (0..len)
            .map(|_| (msg(&mut rng), CMatrix::random(&mut rng, 4, 4).scale(0.3)))
            .collect();
        for (y, a) in &data {
            s.push(y.clone(), a.clone());
        }
        streams.push(s);
        priors.push(prior);
        samples.push(data);
    }
    let farm = std::sync::Arc::new(
        FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap(),
    );
    let mut backend = FarmCnBackend::with_precision(std::sync::Arc::clone(&farm), fmt);
    assert_eq!(StreamCoalescer::drain(&mut backend, &mut streams).unwrap(), 8);
    for (i, s) in streams.iter().enumerate() {
        let mut solo = FgpSimBackend::new(FgpConfig { fmt, ..FgpConfig::default() }).unwrap();
        let mut want = priors[i].clone();
        for (y, a) in &samples[i] {
            want = solo
                .cn_update(&fgp_repro::coordinator::CnRequestData {
                    x: want,
                    y: y.clone(),
                    a: a.clone(),
                })
                .unwrap();
        }
        assert_eq!(s.state.dist(&want), 0.0, "stream {i}: declared width must equal configured");
    }
}

// ---------------------------------------------------------------------
// XLA (artifacts-gated): fused chunking + batched tail padding
// ---------------------------------------------------------------------

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.txt").exists()
}

#[test]
fn xla_stream_pads_tail_chunks_through_the_chain_artifact() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use fgp_repro::runtime::RuntimeClient;
    let rt = RuntimeClient::load(artifacts_dir()).unwrap();
    let sections = rt.manifest.sections;
    // one full fused chunk + a 3-sample tail that must be padded with
    // A = 0 identity sections up to the artifact's baked length
    let p = RlsProblem::synthetic(rt.manifest.n, sections + 3, 0.01, 19);
    let golden = Session::golden().run_stream(&p).unwrap();
    let stream = Session::xla(rt).run_stream(&p).unwrap();
    assert_eq!(stream.samples, (sections + 3) as u64);
    assert_eq!(stream.chunks, 2);
    let d = vec_dist(&stream.outcome.h_hat, &golden.outcome.h_hat);
    assert!(d < 1e-2, "xla stream vs golden dist {d}");
}

#[test]
fn cn_update_batched_tail_padding_is_lossless() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use fgp_repro::runtime::RuntimeClient;
    let rt = RuntimeClient::load(artifacts_dir()).unwrap();
    let n = rt.manifest.n;
    let batch = rt.manifest.batch;
    let mut rng = Rng::new(23);
    let msg = |rng: &mut Rng| {
        GaussMessage::new(
            (0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect(),
            CMatrix::random_psd(rng, n, 0.3),
        )
    };
    // the most under-full tail batch (1 request) and a nearly-full one
    for len in [1usize, batch - 1] {
        let reqs: Vec<(GaussMessage, GaussMessage, CMatrix)> = (0..len)
            .map(|_| (msg(&mut rng), msg(&mut rng), CMatrix::random(&mut rng, n, n)))
            .collect();
        let out = rt.cn_update_batched(&reqs).unwrap();
        assert_eq!(out.len(), len);
        for (i, (x, y, a)) in reqs.iter().enumerate() {
            let single = rt.cn_update(x, y, a).unwrap();
            let d = out[i].dist(&single);
            assert!(d < 1e-4 * (1.0 + single.cov.max_abs()), "len {len}, req {i}: dist {d}");
        }
    }
}
