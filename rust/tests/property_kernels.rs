//! Differential kernel-conformance suite (PR 9).
//!
//! The SoA layout, the shape-monomorphized kernels, and the multi-PE
//! cycle model are performance knobs — never semantics. This suite pins
//! that contract differentially:
//!
//! * SoA slot banks round-trip the seed AoS [`MsgSlot`] encoding bitwise
//!   across dimensions 2–8 and Q-formats, including saturation fixtures;
//! * every shape-specialized kernel is bitwise-equal to an *interpreted*
//!   per-element reference written in scalar [`CFix`] arithmetic (the
//!   seed path), on random full-rail fixed-point inputs;
//! * the fused [`kernels::cn_update_batch`] entry is bitwise-equal to
//!   dispatching each request through the cycle-accurate program path;
//! * `CMatrix::schur_direct` and `CMatrix::schur_faddeev` agree
//!   (tolerance-bounded) on random PSD inputs across dimensions 2–8;
//! * PE count changes cycles, never values: a multi-PE device produces
//!   bitwise-identical messages.

use fgp_repro::coordinator::{Backend, CnRequestData, FgpSimBackend};
use fgp_repro::fgp::{FgpConfig, MessageMemory, MsgSlot, SlotBank};
use fgp_repro::fixed::raw::{self, Rails};
use fgp_repro::fixed::{CFix, Fix, QFormat};
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::kernels::{self, CnBatch, CnScratch, CPlanes};
use fgp_repro::testutil::{proptest_cases, Rng};

/// Formats exercised by the layout round-trip: the paper's Q5.10, a wide
/// format, and a deliberately narrow one (saturation-heavy).
const FORMATS: [QFormat; 3] =
    [QFormat::q5_10(), QFormat::new(8, 20), QFormat::new(2, 6)];

/// A random raw anywhere on the format's rails (both ends inclusive), so
/// downstream arithmetic regularly saturates.
fn random_raw(rng: &mut Rng, fmt: QFormat) -> i64 {
    let span = (fmt.max_raw() - fmt.min_raw() + 1) as u64;
    (rng.next_u64() % span) as i64 + fmt.min_raw()
}

fn random_cfix(rng: &mut Rng, fmt: QFormat, len: usize) -> Vec<CFix> {
    (0..len)
        .map(|_| CFix {
            re: Fix { raw: random_raw(rng, fmt), fmt },
            im: Fix { raw: random_raw(rng, fmt), fmt },
        })
        .collect()
}

fn raws(v: &[CFix]) -> Vec<(i64, i64)> {
    v.iter().map(|z| (z.re.raw, z.im.raw)).collect()
}

// ---------------------------------------------------------------------
// Layout: SoA banks vs seed AoS slots
// ---------------------------------------------------------------------

#[test]
fn slot_bank_roundtrips_aos_bitwise_across_dims_and_formats() {
    proptest_cases(20, |rng| {
        for fmt in FORMATS {
            for n in 2..=8usize {
                let aos = random_cfix(rng, fmt, n * n);
                let mut bank = SlotBank::new(n * n, fmt, 3);
                bank.write_cfix(2, &aos);
                // AoS readback is bit-identical ...
                assert_eq!(raws(&bank.read_cfix(2)), raws(&aos), "n={n}");
                // ... and the plane view exposes exactly the same raws.
                let p = bank.planes(2);
                for (i, z) in aos.iter().enumerate() {
                    assert_eq!((p.re[i], p.im[i]), (z.re.raw, z.im.raw));
                }
                // untouched neighbour slots stay zero (no stride bleed)
                assert!(bank.planes(1).re.iter().all(|&x| x == 0));
            }
        }
    });
}

/// Quantizing a message through the planar [`MessageMemory`] write path
/// must equal quantizing through the seed AoS [`MsgSlot`] encoder —
/// including values far outside the format's range (rail saturation).
#[test]
fn message_memory_quantization_matches_aos_slot_incl_saturation() {
    proptest_cases(10, |rng| {
        for fmt in FORMATS {
            for n in 2..=8usize {
                // lane 0 pinned far past every format's range; the rest
                // scattered around it so some lanes land in range too
                let mut mean: Vec<c64> = (0..n)
                    .map(|_| c64::new(rng.range(-600.0, 600.0), rng.range(-600.0, 600.0)))
                    .collect();
                mean[0] = c64::new(1.0e4, -1.0e4);
                let msg =
                    GaussMessage::new(mean, CMatrix::random_psd(rng, n, 1.0).scale(40.0));
                let mut mem = MessageMemory::new(n, fmt, 2);
                mem.write_message(1, &msg);
                let got = mem.read(1);
                let want = MsgSlot::from_message(&msg, fmt);
                assert_eq!(raws(&got.v), raws(&want.v), "cov n={n}");
                assert_eq!(raws(&got.m), raws(&want.m), "mean n={n}");
                // saturated lanes really sit on the rails
                let on_rail = got
                    .m
                    .iter()
                    .filter(|z| z.re.raw == fmt.max_raw() || z.re.raw == fmt.min_raw())
                    .count();
                assert!(on_rail > 0, "fixture must exercise saturation (n={n})");
            }
        }
    });
}

// ---------------------------------------------------------------------
// Interpreted scalar reference (the seed per-element path)
// ---------------------------------------------------------------------

fn elem(m: &[CFix], n: usize, i: usize, k: usize, herm: bool) -> CFix {
    if herm { m[k * n + i].conj() } else { m[i * n + k] }
}

/// Scalar-`CFix` mma/mms: `addend = None` → out = (∓) A·B with `neg` on
/// the sum; `Some(c)` → out = (∓c) + A·B with `neg` on the addend.
fn ref_mat_mul(
    n: usize,
    fmt: QFormat,
    a: &[CFix],
    a_herm: bool,
    b: &[CFix],
    b_herm: bool,
    addend: Option<&[CFix]>,
    neg: bool,
) -> Vec<CFix> {
    let mut out = vec![CFix::zero(fmt); n * n];
    for i in 0..n {
        for j in 0..n {
            let mut acc = match addend {
                Some(c) => {
                    if neg {
                        c[i * n + j].neg()
                    } else {
                        c[i * n + j]
                    }
                }
                None => CFix::zero(fmt),
            };
            for k in 0..n {
                acc = acc.add(elem(a, n, i, k, a_herm).mul(elem(b, n, k, j, b_herm)));
            }
            if addend.is_none() && neg {
                acc = acc.neg();
            }
            out[i * n + j] = acc;
        }
    }
    out
}

fn ref_mat_vec(
    n: usize,
    fmt: QFormat,
    a: &[CFix],
    a_herm: bool,
    v: &[CFix],
    addend: Option<&[CFix]>,
    neg: bool,
) -> Vec<CFix> {
    let mut out = vec![CFix::zero(fmt); n];
    for i in 0..n {
        let mut acc = match addend {
            Some(c) => {
                if neg {
                    c[i].neg()
                } else {
                    c[i]
                }
            }
            None => CFix::zero(fmt),
        };
        for k in 0..n {
            acc = acc.add(elem(a, n, i, k, a_herm).mul(v[k]));
        }
        if addend.is_none() && neg {
            acc = acc.neg();
        }
        out[i] = acc;
    }
    out
}

/// Scalar-`CFix` Faddeev over [[G, B | y], [C, D | x]]: partial pivoting
/// among the G rows on saturated |.|², divide-then-multiply-subtract row
/// elimination, D-quadrant extraction.
#[allow(clippy::too_many_arguments)]
fn ref_faddeev(
    n: usize,
    fmt: QFormat,
    g: &[CFix],
    b: &[CFix],
    b_herm: bool,
    c: &[CFix],
    d: &[CFix],
    y: &[CFix],
    x: &[CFix],
) -> (Vec<CFix>, Vec<CFix>) {
    let rows = 2 * n;
    let cols = 2 * n + 1;
    let mut w = vec![CFix::zero(fmt); rows * cols];
    for i in 0..n {
        for j in 0..n {
            w[i * cols + j] = g[i * n + j];
            w[i * cols + n + j] = elem(b, n, i, j, b_herm);
            w[(n + i) * cols + j] = c[i * n + j];
            w[(n + i) * cols + n + j] = d[i * n + j];
        }
        w[i * cols + 2 * n] = y[i];
        w[(n + i) * cols + 2 * n] = x[i];
    }
    for k in 0..n {
        let mut piv = k;
        let mut pmax = w[k * cols + k].abs2().raw;
        for i in k + 1..n {
            let v = w[i * cols + k].abs2().raw;
            if v > pmax {
                piv = i;
                pmax = v;
            }
        }
        if piv != k {
            for j in 0..cols {
                w.swap(k * cols + j, piv * cols + j);
            }
        }
        let p = w[k * cols + k];
        for i in k + 1..rows {
            let lead = w[i * cols + k];
            if lead.re.raw == 0 && lead.im.raw == 0 {
                continue;
            }
            let f = lead.div(p);
            for j in k..cols {
                w[i * cols + j] = w[i * cols + j].sub(f.mul(w[k * cols + j]));
            }
        }
    }
    let mut mat = vec![CFix::zero(fmt); n * n];
    let mut vec_out = vec![CFix::zero(fmt); n];
    for i in 0..n {
        for j in 0..n {
            mat[i * n + j] = w[(n + i) * cols + n + j];
        }
        vec_out[i] = w[(n + i) * cols + 2 * n];
    }
    (mat, vec_out)
}

// ---------------------------------------------------------------------
// Kernels vs the interpreted reference, bitwise
// ---------------------------------------------------------------------

#[test]
fn mat_mul_kernel_bitwise_matches_interpreted_reference() {
    proptest_cases(25, |rng| {
        let fmt = QFormat::q5_10();
        let r = Rails::of(fmt);
        // 2..=8 crosses every mono instantiation and the generic body
        for n in 2..=8usize {
            let a = random_cfix(rng, fmt, n * n);
            let b = random_cfix(rng, fmt, n * n);
            let c = random_cfix(rng, fmt, n * n);
            let (pa, pb, pc) =
                (CPlanes::from_cfix(&a), CPlanes::from_cfix(&b), CPlanes::from_cfix(&c));
            for (a_herm, b_herm, addend, neg) in [
                (false, true, false, false),
                (false, false, true, false),
                (true, false, true, true),
                (false, false, false, true),
            ] {
                let mut out = CPlanes::default();
                let add_ref = addend.then_some(pc.as_ref());
                kernels::mat_mul(n, r, pa.as_ref(), a_herm, pb.as_ref(), b_herm, add_ref, neg, &mut out);
                let want =
                    ref_mat_mul(n, fmt, &a, a_herm, &b, b_herm, addend.then_some(&c[..]), neg);
                assert_eq!(out, CPlanes::from_cfix(&want), "n={n} flags {a_herm}/{b_herm}/{addend}/{neg}");
            }
        }
    });
}

#[test]
fn mat_vec_kernel_bitwise_matches_interpreted_reference() {
    proptest_cases(25, |rng| {
        let fmt = QFormat::q5_10();
        let r = Rails::of(fmt);
        for n in 2..=8usize {
            let a = random_cfix(rng, fmt, n * n);
            let v = random_cfix(rng, fmt, n);
            let c = random_cfix(rng, fmt, n);
            let (pa, pv, pc) =
                (CPlanes::from_cfix(&a), CPlanes::from_cfix(&v), CPlanes::from_cfix(&c));
            for (a_herm, addend, neg) in
                [(false, true, true), (true, false, false), (false, false, true)]
            {
                let mut out = CPlanes::default();
                let add_ref = addend.then_some(pc.as_ref());
                kernels::mat_vec(n, r, pa.as_ref(), a_herm, pv.as_ref(), add_ref, neg, &mut out);
                let want = ref_mat_vec(n, fmt, &a, a_herm, &v, addend.then_some(&c[..]), neg);
                assert_eq!(out, CPlanes::from_cfix(&want), "n={n}");
            }
        }
    });
}

#[test]
fn faddeev_kernel_bitwise_matches_interpreted_reference() {
    proptest_cases(25, |rng| {
        let fmt = QFormat::q5_10();
        let r = Rails::of(fmt);
        for n in 2..=8usize {
            let g = random_cfix(rng, fmt, n * n);
            let b = random_cfix(rng, fmt, n * n);
            let c = random_cfix(rng, fmt, n * n);
            let d = random_cfix(rng, fmt, n * n);
            let y = random_cfix(rng, fmt, n);
            let x = random_cfix(rng, fmt, n);
            let (pg, pb, pc, pd, py, px) = (
                CPlanes::from_cfix(&g),
                CPlanes::from_cfix(&b),
                CPlanes::from_cfix(&c),
                CPlanes::from_cfix(&d),
                CPlanes::from_cfix(&y),
                CPlanes::from_cfix(&x),
            );
            let (mut w, mut mat, mut vecp) =
                (CPlanes::default(), CPlanes::default(), CPlanes::default());
            kernels::faddeev(
                n,
                r,
                pg.as_ref(),
                pb.as_ref(),
                true,
                pc.as_ref(),
                pd.as_ref(),
                py.as_ref(),
                px.as_ref(),
                &mut w,
                &mut mat,
                &mut vecp,
            );
            let (want_mat, want_vec) = ref_faddeev(n, fmt, &g, &b, true, &c, &d, &y, &x);
            assert_eq!(mat, CPlanes::from_cfix(&want_mat), "n={n} Schur quadrant");
            assert_eq!(vecp, CPlanes::from_cfix(&want_vec), "n={n} mean column");
        }
    });
}

/// Deterministic saturation fixture: every operand pinned to a rail.
/// Kernel and interpreted reference must agree raw-for-raw even when
/// every intermediate clamps.
#[test]
fn kernels_match_reference_on_all_rails_fixture() {
    let fmt = QFormat::q5_10();
    let r = Rails::of(fmt);
    for n in [2usize, 4, 8] {
        for rail in [fmt.max_raw(), fmt.min_raw()] {
            let z = CFix { re: Fix { raw: rail, fmt }, im: Fix { raw: rail, fmt } };
            let a = vec![z; n * n];
            let pa = CPlanes::from_cfix(&a);
            let mut out = CPlanes::default();
            kernels::mat_mul(n, r, pa.as_ref(), false, pa.as_ref(), true, None, false, &mut out);
            let want = ref_mat_mul(n, fmt, &a, false, &a, true, None, false);
            assert_eq!(out, CPlanes::from_cfix(&want), "n={n} rail={rail}");
        }
    }
}

// ---------------------------------------------------------------------
// Fused CN batch vs the cycle-accurate program path
// ---------------------------------------------------------------------

fn scaled_request(rng: &mut Rng, n: usize) -> CnRequestData {
    CnRequestData {
        x: GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, n, 1.0).scale(0.15),
        ),
        y: GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, n, 1.0).scale(0.15),
        ),
        a: CMatrix::random(rng, n, n).scale(0.3),
    }
}

/// End to end: the fused SoA batch kernel against the interpreted
/// compile-load-stream-run-readback device path, raw-for-raw.
#[test]
fn cn_batch_kernel_bitwise_matches_device_program_path() {
    let n = 4;
    let fmt = QFormat::q5_10();
    let mut device = FgpSimBackend::new(FgpConfig::default()).unwrap();
    let mut rng = Rng::new(0x9e37);
    let reqs: Vec<_> = (0..6).map(|_| scaled_request(&mut rng, n)).collect();

    let mut batch = CnBatch::new(n);
    for r in &reqs {
        let sx = MsgSlot::from_message(&r.x, fmt);
        let sy = MsgSlot::from_message(&r.y, fmt);
        let qa: Vec<CFix> = (0..n)
            .flat_map(|i| (0..n).map(move |j| (i, j)))
            .map(|(i, j)| CFix::from_f64(r.a[(i, j)].re, r.a[(i, j)].im, fmt))
            .collect();
        batch.push(&sx.v, &sx.m, &sy.v, &sy.m, &qa);
    }
    let (mut out_v, mut out_m) = (CPlanes::default(), CPlanes::default());
    kernels::cn_update_batch(fmt, &batch, &mut out_v, &mut out_m, &mut CnScratch::default());

    for (lane, req) in reqs.iter().enumerate() {
        let dev = device.cn_update(req).unwrap();
        let want = MsgSlot::from_message(&dev, fmt);
        let got_v = out_v.slice(lane * n * n..(lane + 1) * n * n).to_cfix(fmt);
        let got_m = out_m.slice(lane * n..(lane + 1) * n).to_cfix(fmt);
        assert_eq!(raws(&got_v), raws(&want.v), "lane {lane} cov");
        assert_eq!(raws(&got_m), raws(&want.m), "lane {lane} mean");
    }
    assert_eq!(kernels::kernel_path(n), "soa-mono-n4");
}

/// PE count is a cycle knob only: a 4-PE device returns bitwise-identical
/// messages to the single-PE device in fewer simulated cycles.
#[test]
fn multi_pe_device_is_bitwise_identical_to_single_pe() {
    let mut one = FgpSimBackend::new(FgpConfig::default()).unwrap();
    let mut four = FgpSimBackend::new(FgpConfig::with_pes(4)).unwrap();
    let mut rng = Rng::new(0xf00d);
    let reqs: Vec<_> = (0..8).map(|_| scaled_request(&mut rng, 4)).collect();
    let a = one.cn_update_batch(&reqs);
    let b = four.cn_update_batch(&reqs);
    for (x, y) in a.iter().zip(&b) {
        let (x, y) = (x.as_ref().unwrap(), y.as_ref().unwrap());
        assert_eq!(x.mean, y.mean);
        assert_eq!(x.cov.dist(&y.cov), 0.0);
    }
    assert!(four.device_cycles < one.device_cycles, "4 PEs must be faster");
}

// ---------------------------------------------------------------------
// Schur identities (the algorithm the array implements)
// ---------------------------------------------------------------------

/// `schur_direct` (solve-based) and `schur_faddeev` (elimination-based)
/// compute the same D − C·G⁻¹·B on well-conditioned PSD blocks, 2–8.
#[test]
fn schur_direct_matches_schur_faddeev_on_random_psd() {
    proptest_cases(20, |rng| {
        for n in 2..=8usize {
            let g = CMatrix::random_psd(rng, n, 1.0);
            let b = CMatrix::random(rng, n, n);
            let c = b.hermitian();
            let d = CMatrix::random_psd(rng, n, 1.0);
            let direct = CMatrix::schur_direct(&g, &b, &c, &d).expect("PSD + ridge is invertible");
            let fad = CMatrix::schur_faddeev(&g, &b, &c, &d).expect("pivoted elimination");
            let scale = 1.0 + d.dist(&CMatrix::zeros(n, n));
            let err = direct.dist(&fad) / scale;
            assert!(err < 1e-9, "n={n}: relative Schur disagreement {err}");
        }
    });
}

/// The raw primitive layer itself: saturating ops agree with the scalar
/// Fix wrappers on the rails (the SoA kernels' foundation).
#[test]
fn raw_primitives_match_fix_wrappers_on_rails() {
    let fmt = QFormat::q5_10();
    let r = Rails::of(fmt);
    let hi = Fix { raw: fmt.max_raw(), fmt };
    let lo = Fix { raw: fmt.min_raw(), fmt };
    assert_eq!(raw::add(hi.raw, hi.raw, r), hi.add(hi).raw);
    assert_eq!(raw::sub(lo.raw, hi.raw, r), lo.sub(hi).raw);
    assert_eq!(raw::neg(lo.raw, r), lo.neg().raw);
    assert_eq!(raw::mul(hi.raw, hi.raw, r), hi.mul(hi).raw);
}
