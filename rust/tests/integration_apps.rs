//! Integration: the application layer (RLS / Kalman / LMMSE / ToA) across
//! engines — golden f64, the cycle-accurate simulator, and (when built)
//! the XLA artifacts — all through the same `Session::run` surface.

use fgp_repro::apps::kalman::KalmanProblem;
use fgp_repro::apps::lmmse::{ser_sweep, LmmseProblem};
use fgp_repro::apps::rls::RlsProblem;
use fgp_repro::apps::toa::ToaProblem;
use fgp_repro::engine::Session;
use fgp_repro::fgp::FgpConfig;

#[test]
fn rls_full_stack_consistency() {
    let p = RlsProblem::synthetic(4, 16, 0.02, 101);
    let golden = Session::golden().run(&p).unwrap();
    let fgp = Session::fgp_sim(FgpConfig::default()).run(&p).unwrap();
    assert!(golden.quality < 0.1, "golden {}", golden.quality);
    assert!(fgp.quality < 0.6, "fgp {}", fgp.quality); // Q5.10 floor (E9)
    // compile stats present when run through the device, absent on golden
    let stats = fgp.compile_stats.unwrap();
    assert_eq!(stats.slots_optimized, 2);
    assert!(golden.compile_stats.is_none());
}

#[test]
fn rls_snr_ordering() {
    // lower noise -> better estimate (golden path)
    let mut golden = Session::golden();
    let low = golden.run(&RlsProblem::synthetic(4, 32, 0.002, 7)).unwrap();
    let high = golden.run(&RlsProblem::synthetic(4, 32, 0.2, 7)).unwrap();
    assert!(low.quality < high.quality);
}

#[test]
fn kalman_full_stack_consistency() {
    let p = KalmanProblem::synthetic(15, 11);
    let golden = Session::golden().run(&p).unwrap();
    let fgp = Session::fgp_sim(FgpConfig::default()).run(&p).unwrap();
    assert!(golden.quality < 0.3);
    assert!(fgp.quality < golden.quality + 0.4);
}

#[test]
fn lmmse_cross_engine_ser() {
    let mut golden = Session::golden();
    let mut sim = Session::fgp_sim(FgpConfig::default());
    let g = ser_sweep(&mut golden, 4, &[5.0, 15.0], 15).unwrap();
    let f = ser_sweep(&mut sim, 4, &[5.0, 15.0], 15).unwrap();
    // both engines improve with SNR and stay within a few % of each other
    assert!(g[1].1 <= g[0].1);
    assert!(f[1].1 <= f[0].1 + 0.02);
    assert!((g[1].1 - f[1].1).abs() < 0.1);
    // 30 blocks, one program shape, one compile
    let stats = sim.cache_stats();
    assert_eq!((stats.misses, stats.hits), (1, 29));
}

#[test]
fn lmmse_handles_zero_noise_block() {
    let p = LmmseProblem::synthetic(4, 1e-6, 3);
    let o = Session::golden().run(&p).unwrap().outcome;
    assert_eq!(o.symbol_errors, 0);
    assert!(o.rel_mse < 1e-3);
}

#[test]
fn toa_cross_engine() {
    let p = ToaProblem::synthetic(8, 1e-3, 13);
    let g = p.run(&mut Session::golden(), 2).unwrap();
    let f = p.run(&mut Session::fgp_sim(FgpConfig::default()), 2).unwrap();
    assert!(g.error < 0.05, "golden {}", g.error);
    assert!(f.error < 0.2, "sim {}", f.error);
}

#[test]
fn xla_rls_matches_golden_when_artifacts_present() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = fgp_repro::runtime::RuntimeClient::load(&artifacts).unwrap();
    let sections = rt.manifest.sections;
    let n = rt.manifest.n;
    let mut xla = Session::xla(rt);
    let p = RlsProblem::synthetic(n, sections, 0.02, 77);
    let x = xla.run(&p).unwrap();
    let golden = Session::golden().run(&p).unwrap();
    assert!(
        (x.quality - golden.quality).abs() < 5e-3,
        "xla {} vs golden {}",
        x.quality,
        golden.quality
    );
}
