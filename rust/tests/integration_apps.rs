//! Integration: the application layer (RLS / Kalman / LMMSE / ToA) across
//! engines — golden f64, the cycle-accurate simulator, and (when built)
//! the XLA artifacts.

use fgp_repro::apps::kalman::KalmanProblem;
use fgp_repro::apps::lmmse::{ser_sweep, LmmseProblem};
use fgp_repro::apps::rls::RlsProblem;
use fgp_repro::apps::toa::ToaProblem;
use fgp_repro::coordinator::backend::{FgpSimBackend, GoldenBackend};
use fgp_repro::fgp::FgpConfig;

#[test]
fn rls_full_stack_consistency() {
    let p = RlsProblem::synthetic(4, 16, 0.02, 101);
    let golden = p.golden().unwrap();
    let fgp = p.run_on_fgp().unwrap();
    assert!(golden.rel_mse < 0.1, "golden {}", golden.rel_mse);
    assert!(fgp.rel_mse < 0.6, "fgp {}", fgp.rel_mse); // Q5.10 floor (E9)
    // compile stats present when run through the device
    let stats = fgp.compile_stats.unwrap();
    assert_eq!(stats.slots_optimized, 2);
}

#[test]
fn rls_snr_ordering() {
    // lower noise -> better estimate (golden path)
    let low = RlsProblem::synthetic(4, 32, 0.002, 7).golden().unwrap();
    let high = RlsProblem::synthetic(4, 32, 0.2, 7).golden().unwrap();
    assert!(low.rel_mse < high.rel_mse);
}

#[test]
fn kalman_full_stack_consistency() {
    let p = KalmanProblem::synthetic(15, 11);
    let golden = p.golden().unwrap();
    let fgp = p.run_on_fgp().unwrap();
    assert!(golden.pos_error < 0.3);
    assert!(fgp.pos_error < golden.pos_error + 0.4);
}

#[test]
fn lmmse_cross_engine_ser() {
    let mut golden = GoldenBackend;
    let mut sim = FgpSimBackend::new(FgpConfig::default()).unwrap();
    let g = ser_sweep(&mut golden, 4, &[5.0, 15.0], 15).unwrap();
    let f = ser_sweep(&mut sim, 4, &[5.0, 15.0], 15).unwrap();
    // both engines improve with SNR and stay within a few % of each other
    assert!(g[1].1 <= g[0].1);
    assert!(f[1].1 <= f[0].1 + 0.02);
    assert!((g[1].1 - f[1].1).abs() < 0.1);
}

#[test]
fn lmmse_handles_zero_noise_block() {
    let p = LmmseProblem::synthetic(4, 1e-6, 3);
    let o = p.run_on(&mut GoldenBackend).unwrap();
    assert_eq!(o.symbol_errors, 0);
    assert!(o.rel_mse < 1e-3);
}

#[test]
fn toa_cross_engine() {
    let p = ToaProblem::synthetic(8, 1e-3, 13);
    let g = p.run_on(&mut GoldenBackend, 2).unwrap();
    let mut sim = FgpSimBackend::new(FgpConfig::default()).unwrap();
    let f = p.run_on(&mut sim, 2).unwrap();
    assert!(g.error < 0.05, "golden {}", g.error);
    assert!(f.error < 0.2, "sim {}", f.error);
}

#[test]
fn xla_rls_matches_golden_when_artifacts_present() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = fgp_repro::runtime::RuntimeClient::load(&artifacts).unwrap();
    let p = RlsProblem::synthetic(rt.manifest.n, rt.manifest.sections, 0.02, 77);
    let xla = p.run_on_xla(&rt).unwrap();
    let golden = p.golden().unwrap();
    assert!(
        (xla.rel_mse - golden.rel_mse).abs() < 5e-3,
        "xla {} vs golden {}",
        xla.rel_mse,
        golden.rel_mse
    );
}
