//! Property tests for the GBP invariants, on the crate's own
//! deterministic property harness (`testutil::proptest_cases`):
//!
//! 1. damping is a convex combination in information form, so it
//!    preserves Hermitian positive-definite information matrices for
//!    any admissible η;
//! 2. on tree graphs, converged GBP beliefs equal the exact dense
//!    information-form solve to 1e-9 (belief propagation is exact on
//!    trees — and the scheduled sweeps are just trees, so this is the
//!    bridge between the two solver families).

use fgp_repro::engine::Session;
use fgp_repro::gbp::{damp, solve, ConvergenceCriteria, GbpModel, GbpOptions};
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::testutil::{proptest_cases, Rng};

fn random_msg(rng: &mut Rng, n: usize) -> GaussMessage {
    GaussMessage::new(
        (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
        CMatrix::random_psd(rng, n, 0.5),
    )
}

/// z^H W z for a random probe z (positive for positive-definite W).
fn quad_form(rng: &mut Rng, w: &CMatrix) -> f64 {
    let n = w.rows;
    let z: Vec<c64> = (0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect();
    let wz = w.matvec(&z);
    z.iter()
        .zip(&wz)
        .map(|(a, b)| (a.conj() * *b).re)
        .sum()
}

#[test]
fn damping_preserves_spd_information_matrices() {
    proptest_cases(40, |rng| {
        let n = 2 + rng.below(4);
        let old = random_msg(rng, n);
        let new = random_msg(rng, n);
        let eta = rng.range(0.0, 0.95);
        let damped = damp(&old, &new, eta).expect("damping proper messages stays proper");
        let (w, _) = damped
            .to_weight_form()
            .expect("damped covariance must stay invertible");
        // Hermitian...
        assert!(
            w.hermitian_defect() < 1e-7 * (1.0 + w.max_abs()),
            "hermitian defect {}",
            w.hermitian_defect()
        );
        // ...and positive definite along random probes
        for _ in 0..5 {
            let q = quad_form(rng, &w);
            assert!(q > 0.0, "information matrix lost positivity: z^H W z = {q}");
        }
    });
}

#[test]
fn damping_interpolates_information() {
    // the damped weight matrix is exactly (1-η)W_new + ηW_old
    proptest_cases(30, |rng| {
        let n = 2 + rng.below(3);
        let old = random_msg(rng, n);
        let new = random_msg(rng, n);
        let eta = rng.range(0.05, 0.9);
        let damped = damp(&old, &new, eta).unwrap();
        let (wo, _) = old.to_weight_form().unwrap();
        let (wn, _) = new.to_weight_form().unwrap();
        let (wd, _) = damped.to_weight_form().unwrap();
        let want = wn.scale(1.0 - eta).add(&wo.scale(eta));
        assert!(
            wd.dist(&want) < 1e-6 * (1.0 + want.max_abs()),
            "dist {}",
            wd.dist(&want)
        );
    });
}

/// Random tree (chain) models: proper priors everywhere, invertible
/// Hermitian-PD pairwise states, a unary observation on every variable.
fn random_chain(rng: &mut Rng, n: usize, vars: usize) -> GbpModel {
    let mut m = GbpModel::new(n);
    let ids: Vec<_> = (0..vars)
        .map(|i| m.add_variable(Some(random_msg(rng, n)), format!("x{i}")).unwrap())
        .collect();
    for i in 0..vars - 1 {
        // Hermitian PD + ridge: always invertible
        let a = CMatrix::random_psd(rng, n, 1.0).scale(0.3);
        let noise = GaussMessage::isotropic(n, rng.range(0.05, 0.3));
        m.add_pairwise(ids[i], ids[i + 1], a, noise).unwrap();
    }
    for (i, id) in ids.iter().enumerate() {
        let c = CMatrix::random(rng, n, n).scale(0.4);
        let obs = random_msg(rng, n);
        m.add_unary(*id, c, obs).unwrap_or_else(|e| panic!("unary {i}: {e:#}"));
    }
    m
}

#[test]
fn tree_gbp_equals_dense_solve() {
    // BP is exact on trees. The dense reference goes through one big
    // LU solve (different arithmetic path, condition-amplified), so
    // the bound here is 1e-8; the bit-for-bit 1e-9 contract against
    // the *scheduled sweep* (same arithmetic) lives in
    // integration_gbp::tree_gbp_reproduces_the_scheduled_sweep.
    proptest_cases(12, |rng| {
        let n = 3;
        let vars = 3 + rng.below(3);
        let model = random_chain(rng, n, vars);
        assert!(!model.has_cycle());
        let dense = model.dense_marginals().expect("proper tree model");
        let report = solve(
            model,
            GbpOptions {
                criteria: ConvergenceCriteria { tol: 1e-10, max_iters: 60, divergence: 1e6 },
                ..Default::default()
            },
            &mut Session::golden(),
        )
        .expect("tree solve");
        assert!(report.converged(), "tree GBP must converge: {:?}", report.stop);
        for (k, (got, want)) in report.beliefs.iter().zip(&dense).enumerate() {
            let scale = 1.0 + want.cov.max_abs();
            assert!(
                got.dist(want) < 1e-8 * scale,
                "belief {k} differs from dense solve by {} (scale {scale})",
                got.dist(want)
            );
        }
    });
}
