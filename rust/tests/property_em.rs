//! EM properties pinned against the dense log-likelihood reference.
//!
//! Exact EM (the RLS fixture: a single static state, so the engine's
//! posterior *is* the full joint posterior) must never decrease the
//! data log-likelihood, from any starting value, on any instance. The
//! estimate must also be a pure function of the data *set*, not the
//! section order.

use fgp_repro::apps::rls::{NoiseEmRls, RlsProblem};
use fgp_repro::em::{EmDriver, EmOptions};
use fgp_repro::engine::Session;
use fgp_repro::testutil::proptest_cases;

/// The monotone-ascent acceptance pin: per-round dense log-likelihood
/// is non-decreasing for random fixtures, noise levels and starts.
#[test]
fn log_likelihood_never_decreases() {
    proptest_cases(12, |rng| {
        let sections = 16 + rng.below(48);
        let sigma2 = 0.002 + 0.02 * rng.uniform();
        let seed = rng.next_u64();
        // starting guess anywhere from 0.1x to 20x the truth
        let mult = (rng.range((0.1f64).ln(), (20.0f64).ln())).exp();
        let p = RlsProblem::synthetic(4, sections, sigma2, seed);
        let mut em = NoiseEmRls::new(p, sigma2 * mult);
        let driver = EmDriver::with_options(EmOptions {
            max_rounds: 8,
            tol: 1e-9,
            divergence: 1e9,
        });
        let report = driver.run(&mut Session::golden(), &mut em).unwrap();
        assert!(report.log_likelihood.len() >= 2);
        for w in report.log_likelihood.windows(2) {
            let slack = 1e-7 * w[0].abs().max(1.0);
            assert!(
                w[1] >= w[0] - slack,
                "log-likelihood decreased: {} -> {} (S={sections}, sigma2={sigma2}, mult={mult})",
                w[0],
                w[1]
            );
        }
    });
}

/// The EM fixed point depends on the data set, not the section order:
/// reversing the sections changes nothing (the posterior is a product
/// of section likelihoods).
#[test]
fn em_estimate_is_section_order_invariant() {
    proptest_cases(6, |rng| {
        let sigma2 = 0.005 + 0.01 * rng.uniform();
        let p = RlsProblem::synthetic(4, 32, sigma2, rng.next_u64());
        let mut reversed = p.clone();
        reversed.regressors.reverse();
        reversed.observations.reverse();
        reversed.symbols.reverse();
        let opts = EmOptions { max_rounds: 16, tol: 1e-10, divergence: 1e9 };
        let mut fwd = NoiseEmRls::new(p, sigma2 * 8.0);
        let mut rev = NoiseEmRls::new(reversed, sigma2 * 8.0);
        let a = EmDriver::with_options(opts).run(&mut Session::golden(), &mut fwd).unwrap();
        let b = EmDriver::with_options(opts).run(&mut Session::golden(), &mut rev).unwrap();
        let (x, y) = (a.values[0], b.values[0]);
        assert!(
            (x - y).abs() <= 1e-6 * x.abs().max(y.abs()),
            "order-dependent estimate: {x} vs {y}"
        );
    });
}
