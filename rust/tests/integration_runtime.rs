//! Integration: PJRT runtime vs the Rust golden GMP rules.
//!
//! Loads the real AOT artifacts (built by `make artifacts`) and checks
//! the XLA-executed compound-node / RLS numerics against
//! `gmp::nodes::compound_observation`. This is the cross-layer proof:
//! L1 Pallas kernel == L2 JAX model == L3 golden rules.

use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::gmp::nodes;
use fgp_repro::runtime::RuntimeClient;
use fgp_repro::testutil::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.txt").exists()
}

fn random_msg(rng: &mut Rng, n: usize, scale: f64) -> GaussMessage {
    GaussMessage::new(
        (0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect(),
        CMatrix::random_psd(rng, n, 0.3).scale(scale),
    )
}

#[test]
fn cn_update_matches_golden() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = RuntimeClient::load(artifacts_dir()).unwrap();
    let n = rt.manifest.n;
    let mut rng = Rng::new(1);
    for seed in 0..5u64 {
        let mut rng2 = Rng::new(seed + 100);
        let x = random_msg(&mut rng2, n, 1.0);
        let y = random_msg(&mut rng2, n, 1.0);
        let a = CMatrix::random(&mut rng, n, n);
        let got = rt.cn_update(&x, &y, &a).unwrap();
        let want = nodes::compound_observation(&x, &y, &a, true).unwrap();
        let d = got.dist(&want);
        let scale = 1.0 + want.cov.max_abs();
        assert!(d < 1e-3 * scale, "seed {seed}: xla vs golden dist {d}");
    }
}

#[test]
fn cn_update_batched_matches_single() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = RuntimeClient::load(artifacts_dir()).unwrap();
    let n = rt.manifest.n;
    let mut rng = Rng::new(7);
    let reqs: Vec<(GaussMessage, GaussMessage, CMatrix)> = (0..5)
        .map(|_| {
            (
                random_msg(&mut rng, n, 1.0),
                random_msg(&mut rng, n, 1.0),
                CMatrix::random(&mut rng, n, n),
            )
        })
        .collect();
    let batched = rt.cn_update_batched(&reqs).unwrap();
    assert_eq!(batched.len(), 5);
    for (i, (x, y, a)) in reqs.iter().enumerate() {
        let single = rt.cn_update(x, y, a).unwrap();
        let d = batched[i].dist(&single);
        assert!(d < 1e-4 * (1.0 + single.cov.max_abs()), "req {i}: dist {d}");
    }
}

#[test]
fn batch_overflow_is_error() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = RuntimeClient::load(artifacts_dir()).unwrap();
    let n = rt.manifest.n;
    let batch = rt.manifest.batch;
    let mut rng = Rng::new(7);
    let reqs: Vec<_> = (0..batch + 1)
        .map(|_| {
            (
                random_msg(&mut rng, n, 1.0),
                random_msg(&mut rng, n, 1.0),
                CMatrix::random(&mut rng, n, n),
            )
        })
        .collect();
    assert!(rt.cn_update_batched(&reqs).is_err());
}

#[test]
fn rls_chain_matches_sequential_golden() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = RuntimeClient::load(artifacts_dir()).unwrap();
    let n = rt.manifest.n;
    let sections = rt.manifest.sections;
    let sigma2 = 0.1f64;
    let mut rng = Rng::new(3);
    let prior = GaussMessage::isotropic(n, 2.0);
    let a_seq: Vec<CMatrix> = (0..sections).map(|_| CMatrix::random(&mut rng, n, n)).collect();
    let y_seq: Vec<GaussMessage> = (0..sections)
        .map(|_| {
            GaussMessage::observation(
                &(0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect::<Vec<_>>(),
                sigma2,
            )
        })
        .collect();

    let got = rt.rls_chain(&prior, &a_seq, &y_seq, sigma2 as f32).unwrap();
    assert_eq!(got.len(), sections);

    // golden sequential reference
    let mut msg = prior.clone();
    for (i, (a, y)) in a_seq.iter().zip(&y_seq).enumerate() {
        msg = nodes::compound_observation(&msg, y, a, true).unwrap();
        let d = got[i].dist(&msg);
        // f32 accumulation across sections: allow growing tolerance
        let tol = 5e-3 * (1.0 + msg.cov.max_abs()) * (1.0 + i as f64 * 0.15);
        assert!(d < tol, "section {i}: dist {d} (tol {tol})");
    }
}

#[test]
fn missing_artifacts_dir_errors_cleanly() {
    let err = match RuntimeClient::load("/nonexistent/path") {
        Ok(_) => panic!("load should fail"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest"), "{msg}");
}
