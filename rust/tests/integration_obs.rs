//! Integration: end-to-end telemetry (the observability tentpole).
//!
//! The contracts under test:
//!
//! * **correlation** — one traced request against a live [`FgpServe`]
//!   yields ONE span tree: every span carries the client's trace id, and
//!   parent links walk from the device's per-opcode cycle spans up
//!   through the engine, farm, and serve layers to the client's root
//!   span — across real TCP and three thread hops;
//! * **exporters** — the same spans render as structurally valid Chrome
//!   trace-event JSON and as a non-empty flame summary;
//! * **inertness (invariant 7)** — with telemetry disabled (the
//!   default), the served numbers are bitwise identical to the enabled
//!   run and the span ring stays empty;
//! * **interop** — a wire-version-1 peer (hand-encoded legacy `Hello`)
//!   still handshakes, is never sent a trace envelope or a telemetry
//!   `Stats` section, and decodes every reply.

use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::obs::{chrome_trace, flame_summary, SpanRecord, TelemetryConfig};
use fgp_repro::serve::{
    decode_reply, read_frame, FgpServe, ServeClient, ServeConfig, ServeReply, StreamMode,
};
use fgp_repro::testutil::Rng;

fn msg(rng: &mut Rng, n: usize) -> GaussMessage {
    GaussMessage::new(
        (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
        CMatrix::random_psd(rng, n, 1.0).scale(0.15),
    )
}

fn sample(rng: &mut Rng, n: usize) -> (GaussMessage, CMatrix) {
    (msg(rng, n), CMatrix::random(rng, n, n).scale(0.3))
}

fn traced_server() -> FgpServe {
    FgpServe::start(ServeConfig { telemetry: TelemetryConfig::on(), ..ServeConfig::default() })
        .unwrap()
}

/// Spans belonging to one trace, waited for until `want` distinct span
/// names have shown up (the engine room records asynchronously).
fn spans_of(srv: &FgpServe, trace_id: u64, want: &[&str]) -> Vec<SpanRecord> {
    let tel = srv.telemetry();
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let spans: Vec<SpanRecord> =
            tel.spans().snapshot().into_iter().filter(|s| s.trace_id == trace_id).collect();
        if want.iter().all(|w| spans.iter().any(|s| s.name == *w)) {
            return spans;
        }
        assert!(
            Instant::now() < deadline,
            "trace {trace_id:#x} never grew {want:?}; has {:?}",
            spans.iter().map(|s| s.name).collect::<Vec<_>>()
        );
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Walk parent links from `span` to a root; panics on a broken link or
/// a cycle. Returns the root's span id.
fn root_of(spans: &[SpanRecord], mut span: &SpanRecord) -> u64 {
    for _ in 0..64 {
        if span.parent_id == 0 {
            return span.span_id;
        }
        span = spans
            .iter()
            .find(|s| s.span_id == span.parent_id)
            .unwrap_or_else(|| panic!("span {} has a dangling parent", span.name));
    }
    panic!("parent chain did not terminate");
}

#[test]
fn one_request_is_one_correlated_tree_from_client_to_device_cycles() {
    let srv = traced_server();
    let mut client =
        ServeClient::connect_traced(srv.addr(), "alice", srv.telemetry()).unwrap();
    assert_eq!(client.negotiated_version(), 2);
    let mut rng = Rng::new(91);

    // --- one-shot: the synchronous tree is complete when the reply is
    let x = msg(&mut rng, 4);
    let (y, a) = sample(&mut rng, 4);
    client.cn_update(x, y, a).unwrap();
    let trace = client.last_trace_id();
    assert_ne!(trace, 0);
    let spans = spans_of(
        &srv,
        trace,
        &["client.request", "serve.cn_update", "serve.gate", "serve.execute", "farm.device",
          "engine.execute", "fgp.run"],
    );
    // every span in the trace hangs off the client's root span
    let root = spans.iter().find(|s| s.name == "client.request").unwrap();
    assert_eq!(root.parent_id, 0, "the client span is the root");
    for s in &spans {
        assert_eq!(s.trace_id, trace);
        assert_eq!(root_of(&spans, s), root.span_id, "{} is orphaned", s.name);
    }
    // the device layer rescaled its cycle phases under fgp.run
    let run = spans.iter().find(|s| s.name == "fgp.run").unwrap();
    assert!(run.a0 > 0, "fgp.run carries the cycle count");
    assert!(
        spans.iter().any(|s| s.layer == "fgp" && s.parent_id == run.span_id),
        "no per-opcode phase spans under fgp.run: {spans:?}"
    );

    // --- streamed: the async engine-room spans join the push's trace
    let prior = msg(&mut rng, 4);
    let samples: Vec<_> = (0..6).map(|_| sample(&mut rng, 4)).collect();
    let (id, _) = client.open_stream("traced", StreamMode::Sticky, prior).unwrap();
    client.push(id, samples).unwrap();
    let push_trace = client.last_trace_id();
    assert_ne!(push_trace, trace, "each call mints a fresh trace");
    let push_spans = spans_of(
        &srv,
        push_trace,
        &["client.request", "serve.push", "serve.queue_wait", "serve.chunk", "farm.device",
          "fgp.run"],
    );
    let push_root = push_spans.iter().find(|s| s.name == "client.request").unwrap();
    for s in &push_spans {
        assert_eq!(root_of(&push_spans, s), push_root.span_id, "{} is orphaned", s.name);
    }
    client.close_stream(id).unwrap();

    // --- exporters accept the real trace
    let chrome = chrome_trace(&spans);
    assert!(chrome.starts_with("{\"traceEvents\":["));
    assert!(chrome.ends_with("]}"));
    assert!(chrome.contains("\"fgp.run\""));
    assert!(chrome.contains("\"ph\":\"X\""));
    let flame = flame_summary(&spans, trace);
    assert!(flame.contains("client.request"), "{flame}");
    assert!(flame.contains("fgp.run"), "{flame}");

    // --- the wire Stats carries the unified registry for a v2 peer
    let stats = client.stats().unwrap();
    assert!(!stats.telemetry.is_empty());
    assert!(stats.telemetry.counter("engine.cache_hit").is_some());
    assert!(stats.telemetry.counter("serve.admitted").unwrap() >= 1);
    let device_cycles: u64 = ["mma", "mms", "fad", "smm"]
        .iter()
        .filter_map(|op| stats.telemetry.counter(&format!("fgp.cycles.{op}")))
        .sum();
    assert!(device_cycles > 0, "no per-opcode cycle counters reached the wire");
    assert!(stats.telemetry.histogram("serve.latency").is_some());
}

#[test]
fn disabled_telemetry_is_bitwise_inert() {
    let run = |cfg: ServeConfig| {
        let srv = FgpServe::start(cfg).unwrap();
        let mut client = ServeClient::connect_traced(srv.addr(), "t", srv.telemetry()).unwrap();
        let mut rng = Rng::new(97);
        let prior = msg(&mut rng, 4);
        let samples: Vec<_> = (0..7).map(|_| sample(&mut rng, 4)).collect();
        let (id, _) = client.open_stream("inert", StreamMode::Sticky, prior).unwrap();
        client.push(id, samples).unwrap();
        let closed = client.close_stream(id).unwrap();
        let x = msg(&mut rng, 4);
        let (y, a) = sample(&mut rng, 4);
        let one = client.cn_update(x, y, a).unwrap();
        (closed.state, one, srv)
    };

    let (state_on, one_on, srv_on) =
        run(ServeConfig { telemetry: TelemetryConfig::on(), ..ServeConfig::default() });
    let (state_off, one_off, srv_off) = run(ServeConfig::default());

    // invariant 7: identical numbers, span for span of work
    assert_eq!(state_on, state_off, "telemetry changed a served stream result");
    assert_eq!(one_on, one_off, "telemetry changed a one-shot result");

    // the disabled ring records nothing and drops nothing
    let off = srv_off.telemetry();
    assert!(!off.enabled());
    assert!(off.spans().snapshot().is_empty());
    assert_eq!(off.spans().dropped(), 0);
    assert!(!srv_on.telemetry().spans().snapshot().is_empty());

    // registry counters run either way — the STATS reply depends on them
    for srv in [&srv_on, &srv_off] {
        let t = srv.stats().telemetry;
        assert!(t.counter("engine.cache_hit").is_some(), "counters must survive the off switch");
        assert!(t.counter("serve.admitted").unwrap() >= 1);
    }
}

#[test]
fn wire_version_1_peer_interoperates() {
    let srv = traced_server();
    let mut sock = TcpStream::connect(srv.addr()).unwrap();
    sock.set_nodelay(true).unwrap();

    // a pre-telemetry peer's Hello: tag 1 + tenant, length-framed by hand
    let mut hello = vec![1u8];
    hello.extend_from_slice(&(6u32.to_le_bytes()));
    hello.extend_from_slice(b"legacy");
    let mut frame = (hello.len() as u32).to_le_bytes().to_vec();
    frame.extend_from_slice(&hello);
    sock.write_all(&frame).unwrap();
    let reply = read_frame(&mut sock).unwrap().unwrap();
    match decode_reply(&reply).unwrap() {
        // the server downgrades to the peer's generation
        ServeReply::Welcome { version } => assert_eq!(version, 1),
        other => panic!("expected Welcome, got {other:?}"),
    }

    // Stats to a v1 peer must omit the telemetry section: the reply is
    // the exact v1 byte shape (legacy tag), which this decode pins
    let stats_req = vec![10u8];
    let mut frame = (stats_req.len() as u32).to_le_bytes().to_vec();
    frame.extend_from_slice(&stats_req);
    sock.write_all(&frame).unwrap();
    let reply = read_frame(&mut sock).unwrap().unwrap();
    assert_eq!(reply[0], 8, "v1 peers get the legacy Stats tag");
    match decode_reply(&reply).unwrap() {
        ServeReply::Stats(s) => assert!(s.telemetry.is_empty()),
        other => panic!("expected Stats, got {other:?}"),
    }

    // meanwhile a v2 client on the same server still gets the full reply
    let mut v2 = ServeClient::connect(srv.addr(), "modern").unwrap();
    assert_eq!(v2.negotiated_version(), 2);
    assert!(v2.stats().unwrap().telemetry.counter("serve.admitted").is_some());
}

#[test]
fn untraced_client_against_a_traced_server_stays_silent_clientside() {
    // no client telemetry handle: no envelope goes out, yet the server
    // still records its own (server-rooted) spans — and the results are
    // the servable numbers either way
    let srv = traced_server();
    let mut client = ServeClient::connect(srv.addr(), "plain").unwrap();
    let mut rng = Rng::new(101);
    let x = msg(&mut rng, 4);
    let (y, a) = sample(&mut rng, 4);
    client.cn_update(x, y, a).unwrap();
    assert_eq!(client.last_trace_id(), 0, "untraced clients mint nothing");
    let spans = srv.telemetry().spans().snapshot();
    let cn = spans.iter().find(|s| s.name == "serve.cn_update").unwrap();
    assert_eq!(cn.parent_id, 0, "server-minted request spans are roots");
    assert!(!spans.iter().any(|s| s.name == "client.request"));
}
