//! Property suite: randomized invariants across all substrates
//! (deterministic xorshift cases; failing seeds are reported for replay).

use fgp_repro::compiler::{compile, loopcomp, AllocOptions, CompileOptions, ScorePolicy};
use fgp_repro::fixed::{CFix, Fix, QFormat};
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::gmp::{nodes, FactorGraph, Schedule};
use fgp_repro::isa::{parse_line, Instr, Program};
use fgp_repro::testutil::{proptest_cases, Rng};

// ---------------------------------------------------------------------
// fixed point
// ---------------------------------------------------------------------

#[test]
fn prop_fix_add_is_commutative_and_monotone() {
    proptest_cases(300, |rng| {
        let fmt = QFormat::q5_10();
        let a = Fix::from_f64(rng.range(-20.0, 20.0), fmt);
        let b = Fix::from_f64(rng.range(-20.0, 20.0), fmt);
        assert_eq!(a.add(b), b.add(a));
        let c = Fix::from_f64(rng.range(0.0, 5.0), fmt);
        assert!(a.add(c).raw >= a.raw); // adding non-negative never decreases
    });
}

#[test]
fn prop_cfix_mul_conjugate_gives_abs2() {
    proptest_cases(300, |rng| {
        let fmt = QFormat::q5_10();
        let z = CFix::from_f64(rng.range(-3.0, 3.0), rng.range(-3.0, 3.0), fmt);
        let zz = z.mul(z.conj());
        // z * conj(z) is real and matches |z|^2
        assert!(zz.im.to_f64().abs() < 4.0 * fmt.resolution());
        let direct = z.abs2().to_f64();
        assert!((zz.re.to_f64() - direct).abs() < 8.0 * fmt.resolution());
    });
}

#[test]
fn prop_division_inverts_multiplication() {
    proptest_cases(200, |rng| {
        let fmt = QFormat::new(5, 16); // wide enough for the tolerance
        let a = CFix::from_f64(rng.range(-2.0, 2.0), rng.range(-2.0, 2.0), fmt);
        let b = CFix::from_f64(rng.range(0.7, 2.0), rng.range(0.7, 2.0), fmt);
        let q = a.mul(b).div(b);
        let (qr, qi) = q.to_c64();
        let (ar, ai) = a.to_c64();
        assert!((qr - ar).abs() < 0.01, "{qr} vs {ar}");
        assert!((qi - ai).abs() < 0.01, "{qi} vs {ai}");
    });
}

// ---------------------------------------------------------------------
// golden linear algebra / node rules
// ---------------------------------------------------------------------

#[test]
fn prop_schur_faddeev_equals_direct() {
    proptest_cases(100, |rng| {
        let n = 2 + rng.below(5);
        let m = 2 + rng.below(5);
        let g = CMatrix::random_psd(rng, n, 0.5);
        let b = CMatrix::random(rng, n, m);
        let c = CMatrix::random(rng, m, n);
        let d = CMatrix::random(rng, m, m);
        let f = CMatrix::schur_faddeev(&g, &b, &c, &d).unwrap();
        let s = CMatrix::schur_direct(&g, &b, &c, &d).unwrap();
        assert!(f.dist(&s) < 1e-7 * (1.0 + s.max_abs()));
    });
}

#[test]
fn prop_compound_node_information_never_increases_uncertainty() {
    proptest_cases(100, |rng| {
        let n = 2 + rng.below(4);
        let x = GaussMessage::new(
            (0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect(),
            CMatrix::random_psd(rng, n, 0.5),
        );
        let y = GaussMessage::new(
            (0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect(),
            CMatrix::random_psd(rng, n, 0.5),
        );
        let a = CMatrix::random(rng, n, n);
        let z = nodes::compound_observation(&x, &y, &a, true).unwrap();
        assert!(z.trace_cov() <= x.trace_cov() + 1e-9);
        // posterior covariance stays Hermitian PSD-ish
        assert!(z.cov.hermitian_defect() < 1e-7 * (1.0 + z.cov.max_abs()));
    });
}

// ---------------------------------------------------------------------
// ISA
// ---------------------------------------------------------------------

fn random_instr(rng: &mut Rng) -> Instr {
    use fgp_repro::isa::{OperandSrc, ACC};
    let slot = |rng: &mut Rng| if rng.uniform() < 0.1 { ACC } else { rng.below(200) as u8 };
    let operand = |rng: &mut Rng| {
        if rng.uniform() < 0.5 {
            OperandSrc::Msg(slot(rng))
        } else {
            OperandSrc::State(rng.below(16) as u8)
        }
    };
    match rng.below(7) {
        0 => Instr::Mma {
            a: operand(rng),
            a_herm: rng.uniform() < 0.5,
            b: operand(rng),
            b_herm: rng.uniform() < 0.5,
            neg: rng.uniform() < 0.5,
            vec: rng.uniform() < 0.5,
        },
        1 => Instr::Mms {
            a: operand(rng),
            a_herm: rng.uniform() < 0.5,
            b: operand(rng),
            b_herm: rng.uniform() < 0.5,
            c: slot(rng),
            neg: rng.uniform() < 0.5,
            vec: rng.uniform() < 0.5,
        },
        2 => Instr::Fad {
            g: slot(rng),
            b: slot(rng),
            b_herm: rng.uniform() < 0.5,
            c: slot(rng),
            d: slot(rng),
        },
        3 => Instr::Smm { dst: rng.below(255) as u8 },
        4 => Instr::Loop { count: (rng.below(60000) + 1) as u16, body: (rng.below(255) + 1) as u8 },
        5 => Instr::Prg { id: rng.below(255) as u8 },
        _ => Instr::Halt,
    }
}

#[test]
fn prop_isa_binary_and_text_roundtrip() {
    proptest_cases(2000, |rng| {
        let i = random_instr(rng);
        assert_eq!(Instr::decode(i.encode()).unwrap(), i);
        let text = format!("{i}");
        assert_eq!(parse_line(&text, 1).unwrap().unwrap(), i, "text: {text}");
    });
}

#[test]
fn prop_program_image_roundtrip() {
    proptest_cases(100, |rng| {
        let len = 1 + rng.below(40);
        let instrs: Vec<Instr> = (0..len).map(|_| random_instr(rng)).collect();
        let p = Program::new(instrs);
        let back = Program::from_image(&p.to_image()).unwrap();
        assert_eq!(back, p);
    });
}

// ---------------------------------------------------------------------
// compiler
// ---------------------------------------------------------------------

#[test]
fn prop_loop_compression_preserves_unrolled_stream() {
    use fgp_repro::isa::OperandSrc;
    proptest_cases(150, |rng| {
        // random stream with deliberate repetition: pick a small alphabet
        let alphabet: Vec<Instr> = (0..3)
            .map(|k| Instr::Smm { dst: k as u8 })
            .chain((0..2).map(|k| Instr::Mma {
                a: OperandSrc::Msg(k as u8),
                a_herm: false,
                b: OperandSrc::State(0),
                b_herm: true,
                neg: false,
                vec: false,
            }))
            .collect();
        let len = 2 + rng.below(30);
        let instrs: Vec<Instr> =
            (0..len).map(|_| alphabet[rng.below(alphabet.len())].clone()).collect();
        let c = loopcomp::compress(&instrs);
        let p = Program::new(c.instrs);
        assert_eq!(p.unrolled(), instrs, "looped: {:?}", c.looped);
    });
}

#[test]
fn prop_allocator_valid_across_policies_and_sizes() {
    proptest_cases(60, |rng| {
        let sections = 1 + rng.below(20);
        let n = 4;
        let a_list: Vec<CMatrix> =
            (0..sections).map(|_| CMatrix::random(rng, n, n)).collect();
        let mut g = FactorGraph::new();
        g.rls_chain(n, &a_list);
        let s = Schedule::forward_sweep(&g);
        let policy = match rng.below(3) {
            0 => ScorePolicy::MostRecentlyFreed,
            1 => ScorePolicy::LowestIndex,
            _ => ScorePolicy::LeastRecentlyFreed,
        };
        let c = compile(
            &g,
            &s,
            &CompileOptions {
                alloc: AllocOptions { policy, ..Default::default() },
                ..Default::default()
            },
        )
        .unwrap();
        // optimized slot count is O(1) for chains under every policy
        assert!(c.stats.slots_optimized <= 3, "{policy:?}: {}", c.stats.slots_optimized);
        // all referenced slots stay below the allocated count
        for i in &c.program.instrs {
            if let Instr::Smm { dst } = i {
                assert!((*dst as usize) < c.memmap.num_slots);
            }
        }
    });
}

#[test]
fn prop_compile_deterministic() {
    proptest_cases(30, |rng| {
        let sections = 1 + rng.below(10);
        let n = 4;
        let a_list: Vec<CMatrix> =
            (0..sections).map(|_| CMatrix::random(rng, n, n)).collect();
        let mut g = FactorGraph::new();
        g.rls_chain(n, &a_list);
        let s = Schedule::forward_sweep(&g);
        let c1 = compile(&g, &s, &CompileOptions::default()).unwrap();
        let c2 = compile(&g, &s, &CompileOptions::default()).unwrap();
        assert_eq!(c1.program, c2.program);
    });
}
