//! Cross-app conformance: every `Workload` runs on the golden engine and
//! the FGP simulator **through the same `Session::run` call**, the
//! fixed-point quality tracks golden within the app's documented
//! tolerance, and the cycle accounting matches the timing model.

use fgp_repro::apps::kalman::KalmanProblem;
use fgp_repro::apps::lmmse::LmmseProblem;
use fgp_repro::apps::receiver::{ReceiverEqualize, ReceiverProblem, ReceiverTraining};
use fgp_repro::apps::rls::RlsProblem;
use fgp_repro::apps::smoother::SmootherProblem;
use fgp_repro::apps::toa::ToaProblem;
use fgp_repro::engine::{EngineKind, RunReport, Session, Workload};
use fgp_repro::fgp::FgpConfig;
use fgp_repro::nonlinear::{FirstOrder, RelinSweep};

/// Run one workload on both engines and enforce the conformance
/// contract: `quality_fgp <= quality_golden + tolerance`.
fn conform<W: Workload>(w: &W) -> (RunReport<W::Outcome>, RunReport<W::Outcome>) {
    let mut golden = Session::golden();
    let mut sim = Session::fgp_sim(FgpConfig::default());
    let g = golden.run(w).unwrap_or_else(|e| panic!("{} golden: {e:#}", w.name()));
    let f = sim.run(w).unwrap_or_else(|e| panic!("{} fgp-sim: {e:#}", w.name()));
    assert_eq!(g.engine, EngineKind::Golden);
    assert_eq!(f.engine, EngineKind::FgpSim);
    // golden has no cycle model; the device must account cycles
    assert_eq!(g.cycles, 0, "{}", w.name());
    assert!(f.cycles > 0, "{}", w.name());
    assert!(
        f.quality <= g.quality + w.tolerance(),
        "{}: fgp quality {} vs golden {} (tolerance {})",
        w.name(),
        f.quality,
        g.quality,
        w.tolerance()
    );
    (g, f)
}

fn cn_cycles(n: usize) -> u64 {
    FgpConfig::default().timing.compound_node_cycles(n)
}

#[test]
fn rls_conforms_and_accounts_cycles() {
    let p = RlsProblem::synthetic(4, 24, 0.02, 11);
    let (_, f) = conform(&p);
    // pure compound-node chain: S sections at the Table II CN rate
    assert_eq!(f.sections, 24);
    assert_eq!(f.cycles, cn_cycles(4) * 24);
    assert_eq!(f.cycles_per_section, cn_cycles(4));
}

#[test]
fn lmmse_conforms_and_accounts_cycles() {
    let p = LmmseProblem::synthetic(4, 0.01, 23);
    let (_, f) = conform(&p);
    assert_eq!(f.sections, 1);
    assert_eq!(f.cycles, cn_cycles(4));
}

#[test]
fn kalman_conforms_with_constant_section_cost() {
    let (_, f_short) = conform(&KalmanProblem::synthetic(10, 5));
    let (_, f_long) = conform(&KalmanProblem::synthetic(20, 5));
    // three store handshakes per time step
    assert_eq!(f_short.sections, 30);
    assert_eq!(f_long.sections, 60);
    // the timing model is per-node: doubling the chain doubles the cycles
    assert_eq!(f_short.cycles * 2, f_long.cycles);
}

#[test]
fn toa_sweep_conforms_and_accounts_cycles() {
    let p = ToaProblem::synthetic(6, 1e-3, 7);
    let problem = p.nonlinear_problem(4).unwrap();
    let sweep =
        RelinSweep::linearize_at(&problem, &problem.predicted_prior(), &FirstOrder).unwrap();
    let (_, f) = conform(&sweep);
    // one compound-node section per anchor
    assert_eq!(f.sections, 6);
    assert_eq!(f.cycles, cn_cycles(4) * 6);
}

#[test]
fn smoother_conforms_on_device_sized_chains() {
    let p = SmootherProblem::synthetic(8, 13);
    let (g, f) = conform(&p);
    // one store per node: 3T forward + (4T - 3) backward/marginal
    assert_eq!(f.sections, 7 * 8 - 3);
    // smoothing still beats filtering on both engines
    assert!(g.outcome.smoother_rmse <= g.outcome.filter_rmse + 1e-9);
}

#[test]
fn receiver_stages_conform() {
    let p = ReceiverProblem::synthetic(4, 1, 24, 16, 0.005, 7);
    let training = ReceiverTraining { problem: &p, frame: 0 };
    let (_, f) = conform(&training);
    // section 0 has no leakage node: 24 observations -> 24 + 23 stores
    assert_eq!(f.sections, 24 + 23);

    let frame = &p.frames[0];
    let eq = ReceiverEqualize {
        problem: &p,
        h: p.channel.toeplitz(4),
        rx_block: frame.rx_payload[..4].to_vec(),
        tx_block: frame.payload[..4].to_vec(),
    };
    let (_, f) = conform(&eq);
    assert_eq!(f.cycles, cn_cycles(4));
}

#[test]
fn second_run_of_same_shape_skips_compile() {
    let mut sim = Session::fgp_sim(FgpConfig::default());
    let p = RlsProblem::synthetic(4, 16, 0.02, 3);
    let first = sim.run(&p).unwrap();
    assert!(!first.cached);
    // same shape, fresh data: the program cache must serve the hit
    let p2 = RlsProblem::synthetic(4, 16, 0.05, 99);
    let second = sim.run(&p2).unwrap();
    assert!(second.cached, "second run of the same shape must skip compile()");
    let stats = sim.cache_stats();
    assert_eq!((stats.misses, stats.hits, stats.programs), (1, 1, 1));
    // a different shape is a miss again
    let p3 = RlsProblem::synthetic(4, 8, 0.02, 3);
    let third = sim.run(&p3).unwrap();
    assert!(!third.cached);
    assert_eq!(sim.cache_stats().misses, 2);
}

#[test]
fn one_session_serves_every_app() {
    // the §I promise, literally: one processor (session), every workload
    let mut sim = Session::fgp_sim(FgpConfig::default());
    let rls = RlsProblem::synthetic(4, 16, 0.02, 1);
    let kalman = KalmanProblem::synthetic(10, 2);
    let lmmse = LmmseProblem::synthetic(4, 0.01, 3);
    let smoother = SmootherProblem::synthetic(8, 4);
    assert!(sim.run(&rls).is_ok());
    assert!(sim.run(&kalman).is_ok());
    assert!(sim.run(&lmmse).is_ok());
    assert!(sim.run(&smoother).is_ok());
    let toa = ToaProblem::synthetic(6, 1e-3, 5);
    assert!(toa.run(&mut sim, 2).is_ok());
    let receiver = ReceiverProblem::synthetic(4, 1, 16, 8, 0.01, 6);
    assert!(receiver.run(&mut sim).is_ok());
    // six app families, each shape compiled exactly once
    let stats = sim.cache_stats();
    assert!(stats.hits > 0);
    assert!(stats.programs >= 5, "{stats:?}");
}
