//! Integration: the operational-intelligence layer (health tentpole).
//!
//! The contracts under test:
//!
//! * **wire** — a v2 client's `Health` round-trips against a live
//!   server: enabled servers report watcher progress, SLO status and
//!   per-device scores; disabled servers answer `enabled: false` but
//!   still expose device identity;
//! * **detection + routing** — a device degraded by an injected delay
//!   is flagged by the outlier detector within a bounded number of
//!   snapshots, and sticky streams pinned to it are *drained* (re-pinned
//!   proactively, counted under `serve.drains`) with zero lost samples
//!   and a final state bitwise identical to an undegraded run;
//! * **inertness (invariant 7 extension)** — health off (the default)
//!   spawns no watcher and serves bitwise-identical numbers;
//! * **interop** — a wire-version-1 peer that sends the `Health` tag is
//!   refused with a non-retryable error, never a reply shape its
//!   generation cannot decode.

use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::obs::health::{AlertKind, HealthConfig, SloDef};
use fgp_repro::serve::{
    decode_reply, read_frame, FgpServe, ServeClient, ServeConfig, ServeReply, StreamMode,
};
use fgp_repro::testutil::Rng;

fn msg(rng: &mut Rng, n: usize) -> GaussMessage {
    GaussMessage::new(
        (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
        CMatrix::random_psd(rng, n, 1.0).scale(0.15),
    )
}

fn sample(rng: &mut Rng, n: usize) -> (GaussMessage, CMatrix) {
    (msg(rng, n), CMatrix::random(rng, n, n).scale(0.3))
}

/// A health config tuned for test time scales: 5 ms sampling, fire
/// after 2 breaching snapshots, one SLO for the test tenant.
fn fast_health() -> HealthConfig {
    let mut h = HealthConfig::on();
    h.watch.interval_ms = 5;
    h.watch.fire_after = 2;
    h.slos.push(SloDef::new("t", 0, 0.05));
    h
}

#[test]
fn health_round_trips_enabled_and_disabled() {
    // enabled server: the watcher makes progress and the reply says so
    let srv = FgpServe::start(ServeConfig { health: fast_health(), ..ServeConfig::default() })
        .unwrap();
    let mut client = ServeClient::connect(srv.addr(), "t").unwrap();
    assert_eq!(client.negotiated_version(), 2);
    let deadline = Instant::now() + Duration::from_secs(30);
    let snap = loop {
        let snap = client.health().unwrap();
        if snap.snapshots >= 3 {
            break snap;
        }
        assert!(Instant::now() < deadline, "watcher never sampled: {snap:?}");
        std::thread::sleep(Duration::from_millis(2));
    };
    assert!(snap.enabled);
    assert_eq!(snap.devices.len(), 2);
    assert!(snap.devices.iter().all(|d| d.live));
    assert_eq!(snap.slos.len(), 1, "the configured SLO is evaluated");
    assert_eq!(snap.slos[0].tenant, "t");
    // the server-side accessor agrees with the wire
    assert!(srv.health().enabled);
    srv.shutdown();

    // disabled server: no watcher, but device identity still answers
    let srv = FgpServe::start(ServeConfig::default()).unwrap();
    let mut client = ServeClient::connect(srv.addr(), "t").unwrap();
    let snap = client.health().unwrap();
    assert!(!snap.enabled);
    assert_eq!(snap.snapshots, 0);
    assert!(snap.slos.is_empty() && snap.alerts.is_empty());
    assert_eq!(snap.devices.len(), 2);
    assert!(
        snap.devices.iter().all(|d| d.live && d.ewma_ns == 0),
        "health off must read no clocks: {snap:?}"
    );
    srv.shutdown();
}

/// Push `rounds` × `per_round` samples onto both streams, alternating,
/// with a short pause so the engine room interleaves chunks and the
/// watcher samples in between. Returns everything pushed per stream.
fn feed(
    client: &mut ServeClient,
    rng: &mut Rng,
    ids: [u64; 2],
    rounds: usize,
    per_round: usize,
) -> [Vec<(GaussMessage, CMatrix)>; 2] {
    let mut fed: [Vec<(GaussMessage, CMatrix)>; 2] = [Vec::new(), Vec::new()];
    for _ in 0..rounds {
        for (slot, id) in ids.iter().enumerate() {
            let batch: Vec<_> = (0..per_round).map(|_| sample(rng, 4)).collect();
            fed[slot].extend(batch.iter().cloned());
            client.push(*id, batch).unwrap();
        }
        std::thread::sleep(Duration::from_millis(3));
    }
    fed
}

#[test]
fn degraded_device_fires_outlier_and_drains_sticky_streams_losslessly() {
    let srv = FgpServe::start(ServeConfig { health: fast_health(), ..ServeConfig::default() })
        .unwrap();
    let mut client = ServeClient::connect(srv.addr(), "t").unwrap();
    let mut rng = Rng::new(314);

    // two sticky streams; round-robin pins them to different devices
    let prior_a = msg(&mut rng, 4);
    let prior_b = msg(&mut rng, 4);
    let (id_a, dev_a) = client.open_stream("a", StreamMode::Sticky, prior_a.clone()).unwrap();
    let (id_b, dev_b) = client.open_stream("b", StreamMode::Sticky, prior_b.clone()).unwrap();
    assert_ne!(dev_a, dev_b, "round-robin spreads fresh pins");
    let (slow_id, slow_dev) = if dev_a == 1 { (id_a, dev_a) } else { (id_b, dev_b) };
    assert_eq!(slow_dev, 1);

    // warm both devices' EWMAs with fast traffic, then degrade device 1
    feed(&mut client, &mut rng, [id_a, id_b], 4, 3);
    srv.farm().set_device_delay(1, 4).unwrap();

    // keep traffic flowing; the outlier detector and the drain both key
    // off the EWMA gap that this traffic creates
    let mut fed = feed(&mut client, &mut rng, [id_a, id_b], 6, 3);
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let pin = client.poll(slow_id).unwrap().device;
        if pin != 1 {
            break; // drained off the slow device
        }
        assert!(
            Instant::now() < deadline,
            "stream never drained off the degraded device: {:?}",
            srv.health()
        );
        let more = feed(&mut client, &mut rng, [id_a, id_b], 1, 3);
        fed[0].extend(more[0].iter().cloned());
        fed[1].extend(more[1].iter().cloned());
    }

    // the move is visible in the drain counter, and the detector flags
    // the slow device within the watcher's bounded hysteresis
    let stats = srv.stats();
    assert!(stats.telemetry.counter("serve.drains").unwrap() >= 1);
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let h = srv.health();
        let outlier = h.alerts.iter().any(|a| {
            a.kind == AlertKind::DeviceOutlier && a.subject == "farm.device1"
        });
        if outlier {
            assert!(h.alerts_total >= 1);
            let slow = h.devices.iter().find(|d| d.device == 1).unwrap();
            let fast = h.devices.iter().find(|d| d.device == 0).unwrap();
            assert!(slow.score < fast.score, "routing score orders the members: {h:?}");
            break;
        }
        assert!(Instant::now() < deadline, "outlier alert never fired: {h:?}");
        std::thread::sleep(Duration::from_millis(5));
    }

    // zero loss: every pushed sample is executed and the final states
    // are bitwise identical to an undegraded, health-off server fed the
    // exact same samples (chunk invariance + drain-before-dispatch)
    let closed_a = client.close_stream(id_a).unwrap();
    let closed_b = client.close_stream(id_b).unwrap();
    assert_eq!(closed_a.samples_done, fed[0].len() as u64);
    assert_eq!(closed_b.samples_done, fed[1].len() as u64);
    srv.shutdown();

    let plain = FgpServe::start(ServeConfig::default()).unwrap();
    let mut ref_client = ServeClient::connect(plain.addr(), "t").unwrap();
    for (slot, prior, closed) in [(0usize, &prior_a, &closed_a), (1usize, &prior_b, &closed_b)] {
        let (id, _) = ref_client.open_stream("ref", StreamMode::Sticky, prior.clone()).unwrap();
        for chunk in fed[slot].chunks(16) {
            ref_client.push(id, chunk.to_vec()).unwrap();
        }
        let reference = ref_client.close_stream(id).unwrap();
        assert_eq!(reference.samples_done, closed.samples_done);
        assert_eq!(reference.state, closed.state, "draining changed served numbers");
    }
    plain.shutdown();
}

#[test]
fn wire_version_1_peer_is_refused_health() {
    let srv = FgpServe::start(ServeConfig { health: fast_health(), ..ServeConfig::default() })
        .unwrap();
    let mut sock = TcpStream::connect(srv.addr()).unwrap();
    sock.set_nodelay(true).unwrap();

    // a pre-health peer's Hello: legacy tag 1 + tenant, framed by hand
    let mut hello = vec![1u8];
    hello.extend_from_slice(&(6u32.to_le_bytes()));
    hello.extend_from_slice(b"legacy");
    let mut frame = (hello.len() as u32).to_le_bytes().to_vec();
    frame.extend_from_slice(&hello);
    sock.write_all(&frame).unwrap();
    let reply = read_frame(&mut sock).unwrap().unwrap();
    match decode_reply(&reply).unwrap() {
        ServeReply::Welcome { version } => assert_eq!(version, 1),
        other => panic!("expected Welcome, got {other:?}"),
    }

    // the bare Health tag gets a typed, non-retryable refusal — the
    // server never sends a v1 peer a reply tag it cannot decode
    let frame = [1u32.to_le_bytes().as_slice(), &[11u8]].concat();
    sock.write_all(&frame).unwrap();
    let reply = read_frame(&mut sock).unwrap().unwrap();
    match decode_reply(&reply).unwrap() {
        ServeReply::Error { retryable, message } => {
            assert!(!retryable);
            assert!(message.contains("version 2"), "{message}");
        }
        other => panic!("expected Error, got {other:?}"),
    }

    // a v2 client on the same server still gets the full reply
    let mut v2 = ServeClient::connect(srv.addr(), "modern").unwrap();
    assert!(v2.health().unwrap().enabled);
    srv.shutdown();
}
