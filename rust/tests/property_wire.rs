//! Property tests for the serve wire codec (E16, satellite).
//!
//! Three properties, swept across **every** variant of every frame
//! family (`ServeRequest`, `ServeReply`, the Fig. 5 `Command`/`Reply`
//! device protocol, and the `FGCK` checkpoint image):
//!
//! 1. **bit-exact round trip** — `decode(encode(v)) == v`, and
//!    re-encoding the decoded value reproduces the *same bytes*
//!    (catching bit-level aliases PartialEq forgives, like `-0.0`);
//! 2. **truncation is total** — decoding any strict prefix of a valid
//!    payload returns a typed error, never panics, never a wrong value;
//! 3. **trailing bytes are rejected** — a valid payload plus garbage is
//!    a `Trailing` error, so frames cannot smuggle extra state.
//!
//! Payloads use awkward floats (`0.1 + 0.2`, `-0.0`, subnormals,
//! `1e308`) so "round trip" means IEEE-754 bits, not approximate value.

use fgp_repro::coordinator::MetricsSnapshot;
use fgp_repro::engine::StreamCheckpoint;
use fgp_repro::fgp::processor::{Command, FsmState, Reply};
use fgp_repro::fgp::RunStats;
use fgp_repro::fixed::QFormat;
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::isa::MemoryImage;
use fgp_repro::obs::health::{
    Alert, AlertKind, AlertSeverity, AlertState, DeviceHealth, HealthSnapshot, SloStatus,
};
use fgp_repro::obs::{HistSummary, RegistrySnapshot, TraceContext};
use fgp_repro::serve::{
    decode_checkpoint, decode_reply, decode_request, decode_request_traced, encode_checkpoint,
    encode_reply, encode_request, encode_request_traced, read_frame, write_frame, ServeReply,
    ServeRequest, StatsSnapshot, StreamMode, TenantSnapshot, WireError, MAX_FRAME, WIRE_VERSION,
};
use fgp_repro::serve::wire::{decode_command, decode_device_reply, encode_command, encode_device_reply};
use fgp_repro::testutil::Rng;

/// Floats chosen to break any codec that is less than bit-exact.
const AWKWARD: [f64; 6] = [0.1 + 0.2, -0.0, f64::MIN_POSITIVE / 2.0, 1e308, -3.5, 0.0];

fn awkward_msg(rng: &mut Rng, n: usize) -> GaussMessage {
    let mut k = 0usize;
    let mut next = |rng: &mut Rng| {
        k += 1;
        if k % 3 == 0 {
            AWKWARD[k % AWKWARD.len()]
        } else {
            rng.range(-2.0, 2.0)
        }
    };
    let mean = (0..n).map(|_| c64::new(next(rng), next(rng))).collect();
    let mut cov = CMatrix::zeros(n, n);
    for z in cov.data_mut() {
        *z = c64::new(next(rng), next(rng));
    }
    GaussMessage { mean, cov }
}

fn awkward_matrix(rng: &mut Rng, r: usize, c: usize) -> CMatrix {
    let mut m = CMatrix::zeros(r, c);
    for (i, z) in m.data_mut().iter_mut().enumerate() {
        *z = c64::new(AWKWARD[i % AWKWARD.len()], rng.range(-1.0, 1.0));
    }
    m
}

fn every_request(rng: &mut Rng) -> Vec<ServeRequest> {
    vec![
        // both wire generations of the handshake: version 1 keeps the
        // legacy tag (canonical-encoding identity), anything else rides
        // the versioned tag
        ServeRequest::Hello { tenant: "tenant-α".into(), version: 1 },
        ServeRequest::Hello { tenant: "tenant-α".into(), version: WIRE_VERSION },
        ServeRequest::Hello { tenant: "v0-probe".into(), version: 0 },
        ServeRequest::CnUpdate {
            x: awkward_msg(rng, 4),
            y: awkward_msg(rng, 4),
            a: awkward_matrix(rng, 4, 4),
        },
        ServeRequest::Chain {
            prior: awkward_msg(rng, 3),
            sections: (0..3).map(|_| (awkward_msg(rng, 3), awkward_matrix(rng, 3, 3))).collect(),
        },
        ServeRequest::OpenStream {
            name: "rls_channel_stream".into(),
            mode: StreamMode::Sticky,
            prior: awkward_msg(rng, 2),
            precision: None,
        },
        // version-2 generation: a declared fixed-point format rides a
        // new tag, so both generations must round-trip independently
        ServeRequest::OpenStream {
            name: "rls_channel_stream_q".into(),
            mode: StreamMode::Coalesced,
            prior: awkward_msg(rng, 2),
            precision: Some(QFormat::q5_10()),
        },
        ServeRequest::Push {
            stream: u64::MAX,
            samples: vec![(awkward_msg(rng, 2), awkward_matrix(rng, 2, 2))],
        },
        ServeRequest::Poll { stream: 7 },
        ServeRequest::CloseStream { stream: 0 },
        ServeRequest::Checkpoint { stream: 42 },
        ServeRequest::Resume {
            name: "rls_channel_stream".into(),
            mode: StreamMode::Coalesced,
            checkpoint: vec![0xde, 0xad, 0xbe, 0xef],
            precision: None,
        },
        ServeRequest::Resume {
            name: "rls_channel_stream_q".into(),
            mode: StreamMode::Sticky,
            checkpoint: vec![0xde, 0xad, 0xbe, 0xef],
            precision: Some(QFormat::new(8, 20)),
        },
        ServeRequest::Stats,
        ServeRequest::Health,
    ]
}

/// A fully-populated health snapshot with awkward floats in every f64
/// field (burn rates, scores, thresholds) so round-trip means bits.
fn awkward_health() -> HealthSnapshot {
    HealthSnapshot {
        enabled: true,
        snapshots: u64::MAX / 3,
        alerts_total: 2,
        slos: vec![SloStatus {
            tenant: "tenant-α".into(),
            p99_objective_ns: 1_000_000,
            error_budget: 0.1 + 0.2,
            p99_ns: 767,
            burn_short: -0.0,
            burn_long: 1e308,
            requests: 1000,
            errors: 3,
            healthy: false,
        }],
        alerts: vec![Alert {
            kind: AlertKind::SloBurn,
            state: AlertState::Firing,
            severity: AlertSeverity::Critical,
            subject: "tenant.tenant-α".into(),
            value: f64::MIN_POSITIVE / 2.0,
            threshold: 1.0,
            t_ns: u64::MAX,
            message: "burn 33.30×/33.30× (short/long) against budget 0.01".into(),
        }],
        devices: vec![
            DeviceHealth {
                device: 0,
                live: true,
                requests: 100,
                errors: 0,
                ewma_ns: 1_000,
                score: 1.0,
            },
            DeviceHealth {
                device: 1,
                live: false,
                requests: 7,
                errors: 9,
                ewma_ns: 0,
                score: -0.0,
            },
        ],
    }
}

fn every_reply(rng: &mut Rng) -> Vec<ServeReply> {
    vec![
        ServeReply::Welcome { version: 1 },
        ServeReply::Output { msg: awkward_msg(rng, 4) },
        ServeReply::StreamOpened { stream: 9, device: 3 },
        ServeReply::Ack { stream: 9, accepted: 16, pending: 1024 },
        ServeReply::StreamState {
            stream: 9,
            samples_done: u64::MAX / 2,
            pending: 0,
            device: 1,
            failovers: 2,
            state: awkward_msg(rng, 4),
        },
        ServeReply::Closed {
            stream: 9,
            samples_done: 512,
            failovers: 0,
            state: awkward_msg(rng, 2),
        },
        ServeReply::CheckpointData { bytes: (0..=255u8).collect() },
        ServeReply::Stats(StatsSnapshot {
            latency: MetricsSnapshot {
                completed: 100,
                failed: 1,
                mean_ns: 12_345,
                p50_ns: 10_000,
                p95_ns: 50_000,
                p99_ns: 90_000,
            },
            admitted: 101,
            rejected_busy: 7,
            rejected_quota: 3,
            failovers: 2,
            tenants: vec![
                TenantSnapshot {
                    tenant: "alice".into(),
                    requests: 50,
                    samples: 400,
                    rejected_quota: 3,
                    rejected_busy: 0,
                },
                TenantSnapshot::default(),
            ],
            telemetry: RegistrySnapshot::default(),
        }),
        // the wire-version-2 Stats shape: a populated telemetry section
        // flips the reply onto the versioned tag
        ServeReply::Stats(StatsSnapshot {
            latency: MetricsSnapshot::default(),
            admitted: 1,
            rejected_busy: 0,
            rejected_quota: 0,
            failovers: 0,
            tenants: Vec::new(),
            telemetry: {
                let mut t = RegistrySnapshot::new();
                t.push_counter("engine.cache_hit", u64::MAX);
                t.push_counter("fgp.cycles.fad", 167);
                t.histograms.push(HistSummary {
                    name: "serve.latency".into(),
                    count: 40,
                    mean_ns: 75_250,
                    p50_ns: 767,
                    p95_ns: 98_303,
                    p99_ns: 98_303,
                });
                t.sort();
                t
            },
        }),
        ServeReply::Busy { retry_ms: 5 },
        ServeReply::QuotaExceeded { retry_ms: u32::MAX },
        ServeReply::Error { retryable: true, message: "device 1 stopped".into() },
        ServeReply::Health(awkward_health()),
        ServeReply::Health(HealthSnapshot::disabled(Vec::new())),
    ]
}

fn every_command(rng: &mut Rng) -> Vec<Command> {
    vec![
        Command::LoadProgram(MemoryImage { bytes: (0..64u8).collect() }),
        Command::StartProgram { id: 3 },
        Command::WriteMessage { slot: 7, msg: awkward_msg(rng, 4) },
        Command::WriteState { slot: 1, a: awkward_matrix(rng, 4, 4) },
        Command::ReadMessage { slot: 0 },
        Command::Status,
    ]
}

fn every_device_reply(rng: &mut Rng) -> Vec<Reply> {
    vec![
        Reply::Ok,
        Reply::Loaded { instrs: 4096 },
        Reply::Finished(RunStats {
            cycles: u64::MAX,
            instructions: 1,
            datapath_cycles: 2,
            sections: 3,
        }),
        Reply::Message(awkward_msg(rng, 4)),
        Reply::Status { state: FsmState::Idle, cycles: 0 },
        Reply::Status { state: FsmState::Running, cycles: 17 },
        Reply::Status { state: FsmState::Done, cycles: 260 },
        Reply::Error("bad slot".into()),
    ]
}

/// Assert the three codec properties for one (encode, decode) pair.
fn check_codec<T: PartialEq + std::fmt::Debug>(
    value: &T,
    encode: impl Fn(&T) -> Vec<u8>,
    decode: impl Fn(&[u8]) -> Result<T, WireError>,
    label: &str,
) {
    let bytes = encode(value);
    // 1) value round trip + byte-identical re-encode (true bit-exactness)
    let back = decode(&bytes).unwrap_or_else(|e| panic!("{label}: decode failed: {e}"));
    assert_eq!(&back, value, "{label}: value changed over the wire");
    assert_eq!(encode(&back), bytes, "{label}: re-encode is not byte-identical");
    // 2) every strict prefix errors, never panics, never mis-decodes
    for cut in 0..bytes.len() {
        assert!(decode(&bytes[..cut]).is_err(), "{label}: prefix of {cut} bytes decoded");
    }
    // 3) trailing garbage is rejected
    let mut extended = bytes;
    extended.push(0xAA);
    assert_eq!(
        decode(&extended),
        Err(WireError::Trailing { extra: 1 }),
        "{label}: trailing byte accepted"
    );
}

#[test]
fn every_serve_request_round_trips_bit_exactly() {
    let mut rng = Rng::new(11);
    for req in every_request(&mut rng) {
        check_codec(&req, encode_request, decode_request, &format!("{req:?}"));
    }
}

#[test]
fn every_serve_reply_round_trips_bit_exactly() {
    let mut rng = Rng::new(13);
    for reply in every_reply(&mut rng) {
        check_codec(&reply, encode_reply, decode_reply, &format!("{reply:?}"));
    }
}

#[test]
fn every_device_command_and_reply_round_trips_bit_exactly() {
    let mut rng = Rng::new(17);
    for cmd in every_command(&mut rng) {
        check_codec(&cmd, encode_command, decode_command, &format!("{cmd:?}"));
    }
    for reply in every_device_reply(&mut rng) {
        check_codec(
            &reply,
            encode_device_reply,
            decode_device_reply,
            &format!("{reply:?}"),
        );
    }
}

#[test]
fn checkpoint_image_round_trips_and_validates() {
    let mut rng = Rng::new(19);
    let ckpt = StreamCheckpoint {
        stream_name: "rls_channel_stream".into(),
        samples: 12345,
        state: awkward_msg(&mut rng, 4),
        boundaries: vec![awkward_msg(&mut rng, 4), awkward_msg(&mut rng, 2)],
    };
    let bytes = encode_checkpoint(&ckpt);
    let back = decode_checkpoint(&bytes).unwrap();
    assert_eq!(back.stream_name, ckpt.stream_name);
    assert_eq!(back.samples, ckpt.samples);
    assert_eq!(back.state, ckpt.state);
    assert_eq!(back.boundaries, ckpt.boundaries);
    assert_eq!(encode_checkpoint(&back), bytes, "re-encode must be byte-identical");
    for cut in 0..bytes.len() {
        assert!(decode_checkpoint(&bytes[..cut]).is_err(), "prefix of {cut} bytes decoded");
    }

    // corrupt magic and unknown version are typed rejections
    let mut bad_magic = bytes.clone();
    bad_magic[0] = b'X';
    assert!(matches!(
        decode_checkpoint(&bad_magic),
        Err(WireError::BadTag { what: "checkpoint magic", .. })
    ));
    let mut bad_version = bytes;
    bad_version[4] = 99;
    assert_eq!(
        decode_checkpoint(&bad_version),
        Err(WireError::BadTag { what: "checkpoint version", tag: 99 })
    );
}

#[test]
fn nan_payloads_survive_bitwise_even_without_equality() {
    // NaN breaks PartialEq, so pin it at the byte level instead
    let msg = GaussMessage {
        mean: vec![c64::new(f64::NAN, -0.0)],
        cov: CMatrix::zeros(1, 1),
    };
    let req = ServeRequest::CnUpdate { x: msg.clone(), y: msg.clone(), a: CMatrix::zeros(1, 1) };
    let bytes = encode_request(&req);
    let back = decode_request(&bytes).unwrap();
    assert_eq!(encode_request(&back), bytes, "NaN bits must survive the round trip");
    match back {
        ServeRequest::CnUpdate { x, .. } => {
            assert_eq!(x.mean[0].re.to_bits(), f64::NAN.to_bits());
            assert_eq!(x.mean[0].im.to_bits(), (-0.0f64).to_bits());
        }
        other => panic!("wrong variant: {other:?}"),
    }
}

#[test]
fn frames_at_the_cap_pass_and_one_byte_over_fails() {
    // exactly MAX_FRAME is legal end to end
    let payload = vec![0x5Au8; MAX_FRAME];
    let mut sink = Vec::new();
    write_frame(&mut sink, &payload).unwrap();
    let back = read_frame(&mut sink.as_slice()).unwrap().unwrap();
    assert_eq!(back.len(), MAX_FRAME);
    assert_eq!(back, payload);
    // one byte over is rejected on both sides without allocating
    assert!(write_frame(&mut Vec::new(), &vec![0u8; MAX_FRAME + 1]).is_err());
    let mut corrupt = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
    corrupt.extend_from_slice(&[0, 0, 0]);
    assert!(read_frame(&mut corrupt.as_slice()).is_err());
}

#[test]
fn trace_envelope_round_trips_and_every_prefix_errors() {
    let mut rng = Rng::new(23);
    let ctx = TraceContext { trace_id: 0xDEAD_BEEF_0BAD_F00D, span_id: u64::MAX };
    for req in every_request(&mut rng) {
        // without a context the traced encoder is byte-identical to the
        // bare one, and the traced decoder accepts bare frames
        let bare = encode_request(&req);
        assert_eq!(encode_request_traced(&req, None), bare, "{req:?}: None envelope added bytes");
        let (back, got) = decode_request_traced(&bare).unwrap();
        assert_eq!(back, req);
        assert_eq!(got, None);

        // with a context: 17-byte envelope, ids bit-exact, payload
        // re-encodes byte-identically
        let traced = encode_request_traced(&req, Some(&ctx));
        assert_eq!(traced.len(), bare.len() + 17, "{req:?}: envelope size");
        let (back, got) = decode_request_traced(&traced).unwrap();
        assert_eq!(back, req, "{req:?}: payload changed under the envelope");
        assert_eq!(got, Some(ctx), "{req:?}: context changed over the wire");
        assert_eq!(encode_request_traced(&back, got.as_ref()), traced, "{req:?}: re-encode");

        // totality holds through the envelope too: every strict prefix
        // errors, trailing bytes are rejected
        for cut in 0..traced.len() {
            assert!(
                decode_request_traced(&traced[..cut]).is_err(),
                "{req:?}: prefix of {cut} bytes decoded"
            );
        }
        let mut extended = traced;
        extended.push(0xAA);
        assert!(decode_request_traced(&extended).is_err(), "{req:?}: trailing byte accepted");
    }
}

#[test]
fn legacy_v1_hello_bytes_still_decode() {
    // hand-built v1 frame: tag 1, then the tenant string — exactly what
    // a pre-telemetry peer puts on the wire
    let mut old = vec![1u8];
    old.extend_from_slice(&(5u32.to_le_bytes()));
    old.extend_from_slice(b"alice");
    let req = decode_request(&old).unwrap();
    assert_eq!(req, ServeRequest::Hello { tenant: "alice".into(), version: 1 });
    // and the canonical re-encode of a version-1 Hello IS the v1 frame
    assert_eq!(encode_request(&req), old);
    // a v1 peer never sends the envelope marker, and the traced decoder
    // hands its frames through untouched
    let (back, ctx) = decode_request_traced(&old).unwrap();
    assert_eq!(back, req);
    assert_eq!(ctx, None);
}

#[test]
fn version_gated_tags_are_pinned() {
    // the interop story depends on exact tag bytes, not just round
    // trips: a v1 server dispatches on the leading byte, so pin the
    // values the version gate reasons about
    assert_eq!(encode_request(&ServeRequest::Health), vec![11], "Health request is a bare tag");
    assert_eq!(encode_request(&ServeRequest::Stats), vec![10], "Stats request is a bare tag");
    // a Stats reply with empty telemetry emits the exact v1 frame
    // (legacy tag 8); any telemetry flips it onto the versioned tag 12
    let legacy = encode_reply(&ServeReply::Stats(StatsSnapshot::default()));
    assert_eq!(legacy[0], 8, "empty-telemetry Stats must keep the legacy tag");
    let mut telemetry = RegistrySnapshot::new();
    telemetry.push_counter("engine.cache_hit", 1);
    let v2 = encode_reply(&ServeReply::Stats(StatsSnapshot {
        telemetry,
        ..StatsSnapshot::default()
    }));
    assert_eq!(v2[0], 12, "populated-telemetry Stats must ride the versioned tag");
    // the health surface is new in v2 and never reuses a v1 tag
    let health = encode_reply(&ServeReply::Health(HealthSnapshot::disabled(Vec::new())));
    assert_eq!(health[0], 13, "Health reply tag moved");
}

#[test]
fn hostile_length_prefixes_cannot_force_allocation() {
    // a payload claiming a huge vector must fail fast: the decoder
    // validates element counts against the remaining bytes
    let mut evil = vec![2u8]; // CnUpdate tag
    evil.extend_from_slice(&u32::MAX.to_le_bytes()); // mean length: 4 billion
    let err = decode_request(&evil).unwrap_err();
    assert!(
        matches!(err, WireError::Truncated { .. } | WireError::FrameTooLarge { .. }),
        "{err:?}"
    );
}
