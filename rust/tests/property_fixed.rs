//! Property suite for the fixed-point substrate (`fixed::raw` and the
//! PEborder divider) — the arithmetic the entire fixed-point production
//! path bottoms out in (scalar `Fix`/`CFix`, the SoA kernels, and the
//! cycle-accurate simulator all call these same functions in the same
//! order).
//!
//! Four layers:
//!
//! 1. **exhaustive small widths** — every raw pair of a 4- and a 6-bit
//!    format through add/sub/neg/mul/div against an independent `i128`
//!    reference, so saturation and rounding boundaries are covered by
//!    enumeration, not sampling;
//! 2. **randomized wide formats** — sat/add/mul/cdiv at Q5.10 and
//!    Q8.20 vs the `i128` reference (and the bit-serial divider
//!    recurrence for every division);
//! 3. **rail edge cases** — the two's-complement asymmetry analogs of
//!    `i64::MIN`: `neg(min_raw)` and `div(min_raw, -1)` must saturate
//!    to `max_raw`, never wrap;
//! 4. **pinned rounding-tie fixtures** — the divider rounds ties away
//!    from zero, the multiplier rounds ties toward +∞; both conventions
//!    are pinned so a "harmless" rounding change cannot slip through.
//!
//! Plus the saturation-counter contract the production path observes
//! (`fixed.saturations`): clean runs count zero, every rail clamp counts
//! one, a zero-denominator `cdiv` counts two, and `take_saturations`
//! reads-and-resets.

use fgp_repro::fixed::raw::{self, Rails};
use fgp_repro::fixed::{QFormat, Radix2Divider};
use fgp_repro::testutil::{proptest_cases, Rng};

/// Independent saturating clamp in i128 (the reference output stage).
fn ref_sat(x: i128, r: Rails) -> i64 {
    x.clamp(r.min as i128, r.max as i128) as i64
}

/// Reference multiply: full-width product, round-half-toward-+∞ on the
/// discarded fraction bits (arithmetic shift), then clamp.
fn ref_mul(a: i64, b: i64, r: Rails) -> i64 {
    let prod = a as i128 * b as i128;
    let half = 1i128 << (r.frac_bits - 1);
    ref_sat((prod + half) >> r.frac_bits, r)
}

/// Reference divide: the hardware's own bit-serial restoring recurrence,
/// then clamp.
fn ref_div(num: i64, den: i64, r: Rails) -> i64 {
    ref_sat(Radix2Divider::divide_raw_bitserial(num, den, r.frac_bits) as i128, r)
}

/// An in-rails raw value.
fn draw(rng: &mut Rng, r: Rails) -> i64 {
    let span = (r.max - r.min + 1) as u64;
    r.min + (rng.next_u64() % span) as i64
}

#[test]
fn exhaustive_small_widths_match_the_i128_reference() {
    // every pair of raw values of a 4-bit and a 6-bit word: saturation
    // and rounding boundaries are covered by enumeration
    for fmt in [QFormat::new(1, 2), QFormat::new(2, 3)] {
        let r = Rails::of(fmt);
        for a in r.min..=r.max {
            for b in r.min..=r.max {
                assert_eq!(raw::add(a, b, r), ref_sat(a as i128 + b as i128, r), "{a}+{b}");
                assert_eq!(raw::sub(a, b, r), ref_sat(a as i128 - b as i128, r), "{a}-{b}");
                assert_eq!(raw::mul(a, b, r), ref_mul(a, b, r), "{a}*{b} at {fmt:?}");
                if b != 0 {
                    assert_eq!(raw::div(a, b, r), ref_div(a, b, r), "{a}/{b} at {fmt:?}");
                }
            }
            assert_eq!(raw::neg(a, r), ref_sat(-(a as i128), r), "-({a})");
        }
    }
}

#[test]
fn exhaustive_small_width_saturation_is_exact_at_the_rails() {
    let fmt = QFormat::new(2, 3);
    let r = Rails::of(fmt);
    // just outside each rail clamps; the rails themselves pass through
    assert_eq!(raw::sat(r.max, r), r.max);
    assert_eq!(raw::sat(r.min, r), r.min);
    assert_eq!(raw::sat(r.max + 1, r), r.max);
    assert_eq!(raw::sat(r.min - 1, r), r.min);
    for x in (r.min - 70)..=(r.max + 70) {
        assert_eq!(raw::sat(x, r), ref_sat(x as i128, r));
    }
}

#[test]
fn randomized_ops_match_the_i128_reference_at_production_widths() {
    for fmt in [QFormat::q5_10(), QFormat::new(8, 20)] {
        let r = Rails::of(fmt);
        proptest_cases(4000, |rng| {
            let (a, b) = (draw(rng, r), draw(rng, r));
            assert_eq!(raw::add(a, b, r), ref_sat(a as i128 + b as i128, r));
            assert_eq!(raw::sub(a, b, r), ref_sat(a as i128 - b as i128, r));
            assert_eq!(raw::mul(a, b, r), ref_mul(a, b, r));
            if b != 0 {
                assert_eq!(raw::div(a, b, r), ref_div(a, b, r));
            }
        });
    }
}

#[test]
fn randomized_cdiv_matches_a_structural_i128_reference() {
    // cdiv is the paper's Fig. 4 sequence: numerator products on the
    // multipliers, |den|^2 on the abs path, two real divisions on the
    // single divider — mirrored here step by step in i128 arithmetic
    // with the bit-serial divider as the division reference
    let fmt = QFormat::q5_10();
    let r = Rails::of(fmt);
    proptest_cases(2000, |rng| {
        let (ar, ai) = (draw(rng, r), draw(rng, r));
        let (br, bi) = (draw(rng, r), draw(rng, r));
        let den = ref_sat(ref_mul(br, br, r) as i128 + ref_mul(bi, bi, r) as i128, r);
        let got = raw::cdiv(ar, ai, br, bi, r);
        if den == 0 {
            assert_eq!(got, (r.max, r.max), "zero |den|^2 rails both components");
            return;
        }
        let num_re = ref_sat(ref_mul(ar, br, r) as i128 + ref_mul(ai, bi, r) as i128, r);
        let num_im = ref_sat(ref_mul(ai, br, r) as i128 - ref_mul(ar, bi, r) as i128, r);
        assert_eq!(got, (ref_div(num_re, den, r), ref_div(num_im, den, r)));
    });
}

#[test]
fn min_raw_negation_and_division_saturate_instead_of_wrapping() {
    // the i64::MIN analog of two's-complement rails: |min| = max + 1, so
    // negating the minimum or dividing it by -1 exceeds the positive
    // rail and must clamp, never wrap
    for fmt in [QFormat::new(2, 3), QFormat::q5_10(), QFormat::new(8, 20)] {
        let r = Rails::of(fmt);
        raw::take_saturations();
        assert_eq!(raw::neg(r.min, r), r.max, "{fmt:?}: -min saturates to max");
        assert_eq!(raw::take_saturations(), 1);
        let minus_one = -(1i64 << r.frac_bits);
        assert_eq!(raw::div(r.min, minus_one, r), r.max, "{fmt:?}: min / -1 saturates");
        assert_eq!(raw::take_saturations(), 1);
        // the mirror cases stay exactly representable
        assert_eq!(raw::neg(r.max, r), -r.max);
        assert_eq!(raw::div(r.max, minus_one, r), -r.max);
        assert_eq!(raw::take_saturations(), 0, "in-range results never count");
    }
}

#[test]
fn divider_rounding_ties_are_pinned_away_from_zero() {
    // frac_bits = 0 keeps the fixtures readable: quotient 0.5 → 1,
    // 1.5 → 2, 2.5 → 3, mirrored for negative quotients
    assert_eq!(Radix2Divider::divide_raw(1, 2, 0), 1);
    assert_eq!(Radix2Divider::divide_raw(-1, 2, 0), -1);
    assert_eq!(Radix2Divider::divide_raw(1, -2, 0), -1);
    assert_eq!(Radix2Divider::divide_raw(-1, -2, 0), 1);
    assert_eq!(Radix2Divider::divide_raw(3, 2, 0), 2);
    assert_eq!(Radix2Divider::divide_raw(-3, 2, 0), -2);
    assert_eq!(Radix2Divider::divide_raw(5, 2, 0), 3);
    // non-ties truncate-then-round normally: 1/3 → 0, 2/3 → 1
    assert_eq!(Radix2Divider::divide_raw(1, 3, 0), 0);
    assert_eq!(Radix2Divider::divide_raw(2, 3, 0), 1);
    // the same tie in a production format: 1 LSB / 2.0 in Q5.10 is a
    // half-LSB quotient and rounds up to 1 LSB
    assert_eq!(Radix2Divider::divide_raw(1, 2 << 10, 10), 1);
    assert_eq!(Radix2Divider::divide_raw(-1, 2 << 10, 10), -1);
    // every pinned fixture also holds for the bit-serial recurrence
    for (num, den, frac) in
        [(1i64, 2i64, 0u32), (-1, 2, 0), (3, 2, 0), (5, 2, 0), (1, 2 << 10, 10)]
    {
        assert_eq!(
            Radix2Divider::divide_raw(num, den, frac),
            Radix2Divider::divide_raw_bitserial(num, den, frac),
        );
    }
}

#[test]
fn multiplier_rounding_ties_are_pinned_toward_positive_infinity() {
    // the PEmult rounds with (prod + half) >> frac — an arithmetic
    // shift, so exact half-LSB products round toward +∞ on BOTH signs
    // (unlike the divider, which rounds away from zero): the asymmetry
    // is hardware behaviour and must not "get fixed"
    let r = Rails::of(QFormat::q5_10());
    let half_lsb_product = 1i64 << 9; // raw product of 2^-1 LSB²
    assert_eq!(raw::mul(1, half_lsb_product, r), 1, "+0.5 LSB rounds up");
    assert_eq!(raw::mul(-1, half_lsb_product, r), 0, "-0.5 LSB rounds up to zero");
    assert_eq!(raw::mul(3, half_lsb_product, r), 2, "+1.5 LSB rounds to 2");
    assert_eq!(raw::mul(-3, half_lsb_product, r), -1, "-1.5 LSB rounds to -1");
}

// ---------------------------------------------------------------------
// the saturation-counter contract (`fixed.saturations`)
// ---------------------------------------------------------------------

#[test]
fn clean_arithmetic_counts_zero_saturations() {
    let r = Rails::of(QFormat::q5_10());
    raw::take_saturations();
    let one = 1i64 << r.frac_bits;
    for a in [-3 * one, -one, 0, one, 2 * one] {
        raw::add(a, one, r);
        raw::sub(a, one, r);
        raw::neg(a, r);
        raw::mul(a, one / 2, r);
        raw::div(a, 2 * one, r);
        raw::cdiv(a, one, one, one / 2, r);
    }
    assert_eq!(raw::saturation_count(), 0, "in-range arithmetic must not count");
}

#[test]
fn every_rail_clamp_counts_exactly_once() {
    let r = Rails::of(QFormat::new(2, 3));
    raw::take_saturations();
    raw::add(r.max, 1, r); // +1
    assert_eq!(raw::saturation_count(), 1);
    raw::sub(r.min, 1, r); // +1
    assert_eq!(raw::saturation_count(), 2);
    raw::mul(r.max, r.max, r); // +1
    assert_eq!(raw::saturation_count(), 3);
    raw::sat(0, r); // in-range: +0
    assert_eq!(raw::saturation_count(), 3);
}

#[test]
fn zero_denominator_cdiv_counts_two_rail_events() {
    let r = Rails::of(QFormat::q5_10());
    raw::take_saturations();
    let out = raw::cdiv(1 << r.frac_bits, 0, 0, 0, r);
    assert_eq!(out, (r.max, r.max), "both components rail");
    assert_eq!(raw::take_saturations(), 2, "one event per railed component");
}

#[test]
fn take_saturations_reads_and_resets() {
    let r = Rails::of(QFormat::new(2, 3));
    raw::take_saturations();
    raw::add(r.max, r.max, r);
    raw::add(r.max, r.max, r);
    assert_eq!(raw::saturation_count(), 2, "peek does not reset");
    assert_eq!(raw::take_saturations(), 2, "take returns the count");
    assert_eq!(raw::take_saturations(), 0, "and resets it");
    assert_eq!(raw::saturation_count(), 0);
}
