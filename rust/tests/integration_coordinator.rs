//! Integration: the coordinator serving layer — concurrency, batching,
//! workload-request routing, shutdown, device protocol, and the XLA
//! backend when available.

use std::sync::atomic::Ordering;
use std::time::Duration;

use fgp_repro::coordinator::backend::{CnRequestData, FgpSimBackend, GoldenBackend};
use fgp_repro::coordinator::{BatchPolicy, CnServer, FgpDevice, ProtocolError, ServerConfig};
use fgp_repro::fgp::processor::{Command, Reply};
use fgp_repro::fgp::FgpConfig;
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::testutil::Rng;

fn request(rng: &mut Rng, n: usize) -> CnRequestData {
    CnRequestData {
        x: GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, n, 1.0).scale(0.15),
        ),
        y: GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, n, 1.0).scale(0.15),
        ),
        a: CMatrix::random(rng, n, n).scale(0.3),
    }
}

#[test]
fn golden_server_concurrent_correctness() {
    let server =
        CnServer::start(|| Ok(Box::new(GoldenBackend) as _), ServerConfig::default()).unwrap();
    let mut joins = Vec::new();
    for t in 0..8u64 {
        let client = server.client();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(900 + t);
            for _ in 0..20 {
                let req = request(&mut rng, 4);
                let got = client.update(req.clone()).unwrap();
                let want = fgp_repro::gmp::nodes::compound_observation(
                    &req.x, &req.y, &req.a, false,
                )
                .unwrap();
                assert!(got.dist(&want) < 1e-9);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    assert_eq!(server.client().metrics().completed.load(Ordering::Relaxed), 160);
    server.shutdown();
}

#[test]
fn fgp_sim_server_works_behind_queue() {
    let server = CnServer::start(
        || Ok(Box::new(FgpSimBackend::new(FgpConfig::default())?) as _),
        ServerConfig {
            batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        },
    )
    .unwrap();
    let client = server.client();
    let mut rng = Rng::new(42);
    for _ in 0..12 {
        let req = request(&mut rng, 4);
        let got = client.update(req.clone()).unwrap();
        let want =
            fgp_repro::gmp::nodes::compound_observation(&req.x, &req.y, &req.a, true).unwrap();
        assert!(got.dist(&want) < 0.05, "dist {}", got.dist(&want));
    }
    server.shutdown();
}

#[test]
fn server_shutdown_is_clean_with_live_clients() {
    let server =
        CnServer::start(|| Ok(Box::new(GoldenBackend) as _), ServerConfig::default()).unwrap();
    let client = server.client(); // clone outlives the server
    server.shutdown();
    // post-shutdown submissions fail gracefully, with a typed error
    let mut rng = Rng::new(1);
    let err = client.update(request(&mut rng, 4)).unwrap_err();
    assert!(
        err.is::<fgp_repro::coordinator::ServerClosed>(),
        "expected ServerClosed, got {err:#}"
    );
}

#[test]
fn fgp_sim_server_routes_workload_requests() {
    use fgp_repro::apps::rls::RlsProblem;
    use fgp_repro::coordinator::WorkloadRequest;
    use fgp_repro::engine::Workload;

    let server = CnServer::start(
        || Ok(Box::new(FgpSimBackend::new(FgpConfig::default())?) as _),
        ServerConfig::default(),
    )
    .unwrap();
    let client = server.client();
    // interleave CN updates (batched path) and chain workloads (program
    // path) through the same queue
    let mut rng = Rng::new(9);
    for seed in 0..3 {
        let cn = client.update(request(&mut rng, 4)).unwrap();
        assert!(cn.dim() == 4);
        let p = RlsProblem::synthetic(4, 8, 0.02, 60 + seed);
        let exec = client
            .run_workload(WorkloadRequest::from_workload(&p).unwrap())
            .unwrap();
        assert_eq!(exec.stats.sections, 8);
        let outcome = p.outcome(&exec).unwrap();
        assert!(outcome.rel_mse.is_finite());
    }
    assert_eq!(
        client.metrics().completed.load(Ordering::Relaxed),
        6
    );
    server.shutdown();
}

#[test]
fn boot_failure_reported_synchronously() {
    let result = CnServer::start(
        || Err(anyhow::anyhow!("backend exploded")),
        ServerConfig::default(),
    );
    assert!(result.is_err());
    assert!(format!("{:#}", result.err().unwrap()).contains("exploded"));
}

#[test]
fn device_protocol_survives_slot_abuse() {
    let dev = FgpDevice::start(FgpConfig::default());
    // out-of-range slots must surface typed device errors, and the
    // device must keep serving afterwards
    for slot in [200u8, 255] {
        match dev.read_message(slot) {
            Err(ProtocolError::Device(e)) => assert!(e.contains("out of range"), "{e}"),
            other => panic!("expected typed device error, got {other:?}"),
        }
    }
    assert!(matches!(dev.command(Command::Status), Ok(Reply::Status { .. })));
    drop(dev);
}

#[test]
fn xla_batch_server_when_artifacts_present() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use fgp_repro::coordinator::backend::XlaBatchBackend;
    use fgp_repro::runtime::RuntimeClient;
    let server = CnServer::start(
        move || Ok(Box::new(XlaBatchBackend::new(RuntimeClient::load(&artifacts)?)?) as _),
        ServerConfig {
            batch: BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(2) },
        },
    )
    .unwrap();
    let client = server.client();
    let mut rng = Rng::new(5);
    let reqs: Vec<CnRequestData> = (0..48).map(|_| request(&mut rng, 4)).collect();
    let pending: Vec<_> = reqs.iter().map(|r| client.submit(r.clone())).collect();
    for (rx, req) in pending.into_iter().zip(&reqs) {
        let got = rx.recv().unwrap().unwrap();
        let want =
            fgp_repro::gmp::nodes::compound_observation(&req.x, &req.y, &req.a, false).unwrap();
        assert!(got.dist(&want) < 1e-3 * (1.0 + want.cov.max_abs()));
    }
    assert!(client.metrics().mean_batch_size() > 1.0, "batching must engage");
    server.shutdown();
}
