//! Integration: the network serving tier (E16).
//!
//! Everything here runs over real TCP against an ephemeral-port
//! [`FgpServe`]. The contracts under test:
//!
//! * **identity** — a one-shot update, a chunked sticky stream, and a
//!   coalesced stream served over the wire are *bitwise* identical to
//!   folding the same samples through a local farm (the codec moves f64
//!   as raw bits; the engine's chunk invariance does the rest);
//! * **admission** — an exhausted tenant bucket is a deterministic
//!   `QuotaExceeded`, a full in-flight window is an explicit `Busy`,
//!   and both are visible in the `STATS` counters;
//! * **failover** — killing a stream's pinned device mid-run loses and
//!   duplicates nothing: the stream re-pins, finishes bitwise-identical
//!   to the uninterrupted reference, and a checkpoint taken before the
//!   kill resumes bitwise-identically on a *fresh server*;
//! * **churn soak** — four concurrent tenant streams (sticky and
//!   coalesced) survive scripted kill/revive cycles with zero lost or
//!   duplicated samples.

use std::time::{Duration, Instant};

use fgp_repro::coordinator::{CnRequestData, FgpFarm, RoutePolicy};
use fgp_repro::fgp::FgpConfig;
use fgp_repro::fixed::QFormat;
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::serve::{
    FgpServe, QuotaPolicy, ServeClient, ServeConfig, ServeReply, ServeRequest, StreamMode,
};
use fgp_repro::testutil::Rng;

fn msg(rng: &mut Rng, n: usize) -> GaussMessage {
    GaussMessage::new(
        (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
        CMatrix::random_psd(rng, n, 1.0).scale(0.15),
    )
}

fn sample(rng: &mut Rng, n: usize) -> (GaussMessage, CMatrix) {
    (msg(rng, n), CMatrix::random(rng, n, n).scale(0.3))
}

/// The bitwise reference: fold the samples one at a time through a
/// local single-device farm. Chunk invariance (pinned by
/// `integration_streaming.rs`) makes any server-side chunking of the
/// same sequence bitwise identical to this.
fn reference_fold(prior: &GaussMessage, samples: &[(GaussMessage, CMatrix)]) -> GaussMessage {
    let farm = FgpFarm::start(1, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
    let mut state = prior.clone();
    for (y, a) in samples {
        state = farm
            .update(CnRequestData { x: state.clone(), y: y.clone(), a: a.clone() })
            .unwrap();
    }
    state
}

fn serve(cfg: ServeConfig) -> (FgpServe, String) {
    let srv = FgpServe::start(cfg).unwrap();
    let addr = srv.addr().to_string();
    (srv, addr)
}

/// Poll until the stream has committed `want` samples with an empty
/// queue (so a checkpoint taken next has a deterministic cursor).
fn wait_drained(client: &mut ServeClient, stream: u64, want: u64) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let st = client.poll(stream).unwrap();
        if st.samples_done == want && st.pending == 0 {
            return;
        }
        assert!(Instant::now() < deadline, "stream stuck at {st:?}, want {want}");
        std::thread::sleep(Duration::from_millis(1));
    }
}

// ---------------------------------------------------------------------
// wire identity
// ---------------------------------------------------------------------

#[test]
fn one_shot_cn_and_chain_over_tcp_match_the_farm() {
    let (_srv, addr) = serve(ServeConfig::default());
    let mut client = ServeClient::connect(addr.as_str(), "alice").unwrap();
    let mut rng = Rng::new(61);

    let x = msg(&mut rng, 4);
    let (y, a) = sample(&mut rng, 4);
    let served = client.cn_update(x.clone(), y.clone(), a.clone()).unwrap();
    let local = FgpFarm::start(1, FgpConfig::default(), RoutePolicy::RoundRobin)
        .unwrap()
        .update(CnRequestData { x, y: y.clone(), a: a.clone() })
        .unwrap();
    assert_eq!(served.dist(&local), 0.0, "one-shot must be bitwise identical");

    let prior = msg(&mut rng, 4);
    let sections: Vec<_> = (0..5).map(|_| sample(&mut rng, 4)).collect();
    let chained = client.chain(prior.clone(), sections.clone()).unwrap();
    let want = reference_fold(&prior, &sections);
    assert_eq!(chained.dist(&want), 0.0, "chain must be bitwise identical");
}

#[test]
fn sticky_and_coalesced_streams_are_bitwise_identical_over_the_wire() {
    let cfg = ServeConfig { chunk: 4, ..ServeConfig::default() };
    let (_srv, addr) = serve(cfg);
    let mut rng = Rng::new(67);
    let prior = msg(&mut rng, 4);
    let samples: Vec<_> = (0..10).map(|_| sample(&mut rng, 4)).collect();
    let want = reference_fold(&prior, &samples);

    for mode in [StreamMode::Sticky, StreamMode::Coalesced] {
        let mut client = ServeClient::connect(addr.as_str(), "alice").unwrap();
        let (id, _device) = client.open_stream("wire_identity", mode, prior.clone()).unwrap();
        // uneven pushes: chunking must not depend on arrival framing
        for batch in [&samples[..3], &samples[3..8], &samples[8..]] {
            let (accepted, _) = client.push(id, batch.to_vec()).unwrap();
            assert_eq!(accepted as usize, batch.len());
        }
        let closed = client.close_stream(id).unwrap();
        assert_eq!(closed.samples_done, 10);
        assert_eq!(closed.state.dist(&want), 0.0, "{mode:?} stream must be bitwise identical");
    }
}

// ---------------------------------------------------------------------
// admission control
// ---------------------------------------------------------------------

#[test]
fn exhausted_tenant_quota_is_a_deterministic_rejection() {
    // rate 0: the bucket never refills, so the outcome is exact
    let cfg = ServeConfig {
        quota: QuotaPolicy { rate: 0.0, burst: 3.0 },
        ..ServeConfig::default()
    };
    let (srv, addr) = serve(cfg);
    let mut greedy = ServeClient::connect(addr.as_str(), "greedy").unwrap();
    let mut rng = Rng::new(71);
    let request = |rng: &mut Rng| {
        let (y, a) = sample(rng, 4);
        ServeRequest::CnUpdate { x: msg(rng, 4), y, a }
    };
    for _ in 0..3 {
        assert!(matches!(greedy.call(&request(&mut rng)).unwrap(), ServeReply::Output { .. }));
    }
    assert!(matches!(
        greedy.call(&request(&mut rng)).unwrap(),
        ServeReply::QuotaExceeded { .. }
    ));
    // quotas are per tenant: a different tenant is unaffected
    let mut polite = ServeClient::connect(addr.as_str(), "polite").unwrap();
    assert!(matches!(polite.call(&request(&mut rng)).unwrap(), ServeReply::Output { .. }));

    let stats = srv.stats();
    assert_eq!(stats.rejected_quota, 1);
    let row = stats.tenants.iter().find(|t| t.tenant == "greedy").unwrap();
    assert_eq!(row.rejected_quota, 1);
    assert_eq!(row.samples, 3);
}

#[test]
fn full_admission_window_replies_busy_not_queueing() {
    let cfg = ServeConfig { max_inflight: 4, ..ServeConfig::default() };
    let (srv, addr) = serve(cfg);
    let mut client = ServeClient::connect(addr.as_str(), "alice").unwrap();
    let mut rng = Rng::new(73);
    let prior = msg(&mut rng, 4);
    let (id, _) = client.open_stream("windowed", StreamMode::Sticky, prior.clone()).unwrap();
    // a 5-sample push can never fit a 4-unit window: refused outright
    let five: Vec<_> = (0..5).map(|_| sample(&mut rng, 4)).collect();
    assert!(matches!(
        client.call(&ServeRequest::Push { stream: id, samples: five }).unwrap(),
        ServeReply::Busy { .. }
    ));
    // four fit; the retrying helper rides out transient fullness
    let four: Vec<_> = (0..4).map(|_| sample(&mut rng, 4)).collect();
    let (accepted, _) = client.push(id, four.clone()).unwrap();
    assert_eq!(accepted, 4);
    let closed = client.close_stream(id).unwrap();
    assert_eq!(closed.samples_done, 4);
    assert_eq!(closed.state.dist(&reference_fold(&prior, &four)), 0.0);
    assert!(srv.stats().rejected_busy >= 1);
}

#[test]
fn stats_exports_ordered_percentiles_and_tenant_rows() {
    let (_srv, addr) = serve(ServeConfig::default());
    let mut rng = Rng::new(79);
    for tenant in ["beta", "alpha"] {
        let mut client = ServeClient::connect(addr.as_str(), tenant).unwrap();
        for _ in 0..5 {
            let (y, a) = sample(&mut rng, 4);
            client.cn_update(msg(&mut rng, 4), y, a).unwrap();
        }
    }
    let mut observer = ServeClient::connect(addr.as_str(), "observer").unwrap();
    let stats = observer.stats().unwrap();
    assert!(stats.latency.completed >= 10);
    assert_eq!(stats.latency.failed, 0);
    assert!(stats.latency.mean_ns > 0);
    assert!(
        stats.latency.p50_ns <= stats.latency.p95_ns
            && stats.latency.p95_ns <= stats.latency.p99_ns,
        "percentiles must be ordered: {:?}",
        stats.latency
    );
    assert!(stats.admitted >= 10);
    let names: Vec<&str> = stats.tenants.iter().map(|t| t.tenant.as_str()).collect();
    assert!(names.windows(2).all(|w| w[0] <= w[1]), "tenant rows sorted: {names:?}");
    for tenant in ["alpha", "beta"] {
        let row = stats.tenants.iter().find(|t| t.tenant == tenant).unwrap();
        assert_eq!(row.samples, 5, "{tenant}");
    }
}

// ---------------------------------------------------------------------
// checkpoint / failover conformance (the E16 acceptance gate)
// ---------------------------------------------------------------------

#[test]
fn kill_checkpoint_and_resume_are_bitwise_identical() {
    let cfg = ServeConfig { devices: 2, chunk: 3, ..ServeConfig::default() };
    let (srv, addr) = serve(cfg.clone());
    let mut rng = Rng::new(83);
    let prior = msg(&mut rng, 4);
    let samples: Vec<_> = (0..12).map(|_| sample(&mut rng, 4)).collect();
    let want = reference_fold(&prior, &samples);

    let mut client = ServeClient::connect(addr.as_str(), "alice").unwrap();
    let (id, device) = client.open_stream("conform", StreamMode::Sticky, prior.clone()).unwrap();
    client.push(id, samples[..6].to_vec()).unwrap();
    wait_drained(&mut client, id, 6);
    let ckpt = client.checkpoint(id).unwrap();

    // kill the pinned device while the stream is live, then keep pushing
    assert!(srv.farm().kill_device(device as usize).unwrap());
    client.push(id, samples[6..].to_vec()).unwrap();
    let closed = client.close_stream(id).unwrap();
    assert_eq!(closed.samples_done, 12, "no sample lost or duplicated across the kill");
    assert!(closed.failovers >= 1, "the stream must have re-pinned");
    assert_eq!(
        closed.state.dist(&want),
        0.0,
        "post-failover stream must be bitwise identical to the uninterrupted fold"
    );
    assert!(srv.stats().failovers >= 1);

    // the checkpoint taken before the kill resumes on a FRESH server
    // and finishes bitwise-identically too
    let (_srv2, addr2) = serve(cfg);
    let mut resumed = ServeClient::connect(addr2.as_str(), "alice").unwrap();
    let (rid, _) = resumed.resume("conform", StreamMode::Sticky, ckpt.clone()).unwrap();
    resumed.push(rid, samples[6..].to_vec()).unwrap();
    let rclosed = resumed.close_stream(rid).unwrap();
    assert_eq!(rclosed.samples_done, 12, "resumed cursor continues from the checkpoint");
    assert_eq!(rclosed.state.dist(&want), 0.0, "resume must be bitwise identical");

    // a checkpoint only resumes the stream it names
    match resumed.call(&ServeRequest::Resume {
        name: "other".into(),
        mode: StreamMode::Sticky,
        checkpoint: ckpt,
        precision: None,
    }) {
        Ok(ServeReply::Error { retryable: false, message }) => {
            assert!(message.contains("conform"), "{message}")
        }
        other => panic!("expected a name-mismatch error, got {other:?}"),
    }
}

// ---------------------------------------------------------------------
// declared fixed-point precision over the wire (the v2 request field)
// ---------------------------------------------------------------------

/// The declared-width bitwise reference: fold the samples one at a time
/// through a local single-device farm whose devices are *configured* at
/// `fmt`. A stream that merely *declares* `fmt` over the wire must land
/// on exactly these bits — declared and configured width share
/// `fixed::raw` and the SoA kernels, so they are identical by
/// construction.
fn reference_fold_fixed(
    fmt: QFormat,
    prior: &GaussMessage,
    samples: &[(GaussMessage, CMatrix)],
) -> GaussMessage {
    let cfg = FgpConfig { fmt, ..FgpConfig::default() };
    let farm = FgpFarm::start(1, cfg, RoutePolicy::RoundRobin).unwrap();
    let mut state = prior.clone();
    for (y, a) in samples {
        state = farm
            .update(CnRequestData { x: state.clone(), y: y.clone(), a: a.clone() })
            .unwrap();
    }
    state
}

#[test]
fn declared_precision_streams_are_bitwise_identical_over_the_wire() {
    // the server's devices default to the silicon's Q5.10; each stream
    // below DECLARES Q8.20 at open, so the wire field — not the server
    // config — must decide the arithmetic, on both stream paths
    let fmt = QFormat::new(8, 20);
    let cfg = ServeConfig { chunk: 4, ..ServeConfig::default() };
    let (_srv, addr) = serve(cfg);
    let mut rng = Rng::new(89);
    let prior = msg(&mut rng, 4);
    let samples: Vec<_> = (0..10).map(|_| sample(&mut rng, 4)).collect();
    let want = reference_fold_fixed(fmt, &prior, &samples);

    for mode in [StreamMode::Sticky, StreamMode::Coalesced] {
        let mut client = ServeClient::connect(addr.as_str(), "alice").unwrap();
        let (id, _device) =
            client.open_stream_fixed("wire_identity_q", mode, prior.clone(), fmt).unwrap();
        // uneven pushes again: declared width must survive rechunking
        for batch in [&samples[..3], &samples[3..8], &samples[8..]] {
            let (accepted, _) = client.push(id, batch.to_vec()).unwrap();
            assert_eq!(accepted as usize, batch.len());
        }
        let closed = client.close_stream(id).unwrap();
        assert_eq!(closed.samples_done, 10);
        assert_eq!(
            closed.state.dist(&want),
            0.0,
            "{mode:?}: a declared-width stream must be bitwise identical to a farm configured at that width"
        );
    }
}

#[test]
fn declared_precision_survives_failover_checkpoint_and_resume() {
    let fmt = QFormat::new(8, 20);
    let cfg = ServeConfig { devices: 2, chunk: 3, ..ServeConfig::default() };
    let (srv, addr) = serve(cfg.clone());
    let mut rng = Rng::new(91);
    let prior = msg(&mut rng, 4);
    let samples: Vec<_> = (0..12).map(|_| sample(&mut rng, 4)).collect();
    let want = reference_fold_fixed(fmt, &prior, &samples);

    let mut client = ServeClient::connect(addr.as_str(), "alice").unwrap();
    let (id, device) =
        client.open_stream_fixed("conform_q", StreamMode::Sticky, prior.clone(), fmt).unwrap();
    client.push(id, samples[..6].to_vec()).unwrap();
    wait_drained(&mut client, id, 6);
    let ckpt = client.checkpoint(id).unwrap();

    // a mid-stream kill re-pins the stream; the REPLACEMENT device must
    // keep computing at the declared width, not fall back to its config
    assert!(srv.farm().kill_device(device as usize).unwrap());
    client.push(id, samples[6..].to_vec()).unwrap();
    let closed = client.close_stream(id).unwrap();
    assert_eq!(closed.samples_done, 12);
    assert!(closed.failovers >= 1, "the stream must have re-pinned");
    assert_eq!(closed.state.dist(&want), 0.0, "failover must not change the declared width");

    // precision is a session property, not part of the checkpoint image:
    // the resume RE-DECLARES the width on a fresh server and must finish
    // bitwise-identically
    let (_srv2, addr2) = serve(cfg);
    let mut resumed = ServeClient::connect(addr2.as_str(), "alice").unwrap();
    let (rid, _) =
        resumed.resume_fixed("conform_q", StreamMode::Sticky, ckpt, fmt).unwrap();
    resumed.push(rid, samples[6..].to_vec()).unwrap();
    let rclosed = resumed.close_stream(rid).unwrap();
    assert_eq!(rclosed.samples_done, 12);
    assert_eq!(rclosed.state.dist(&want), 0.0, "resume must keep the declared width");
}

#[test]
fn fixed_saturations_are_observable_over_the_stats_wire() {
    let (_srv, addr) = serve(ServeConfig::default());
    let mut client = ServeClient::connect(addr.as_str(), "alice").unwrap();

    // clean edge: a deliberately well-conditioned stream at a wide word
    // (Q9.20, rails ±512) — every intermediate stays far inside the
    // rails, so the wire-visible counter must stay at exactly zero
    let prior = GaussMessage::new(
        vec![c64::new(0.2, -0.1); 4],
        CMatrix::scaled_identity(4, 0.5),
    );
    let clean: Vec<_> = (0..5)
        .map(|k| {
            (
                GaussMessage::new(
                    vec![c64::new(0.1 + 0.05 * k as f64, 0.05); 4],
                    CMatrix::scaled_identity(4, 0.2),
                ),
                CMatrix::identity(4).scale(0.6),
            )
        })
        .collect();
    let (id, _) = client
        .open_stream_fixed("clean_q", StreamMode::Sticky, prior, QFormat::new(9, 20))
        .unwrap();
    client.push(id, clean).unwrap();
    client.close_stream(id).unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(
        stats.telemetry.counter("fixed.saturations").unwrap_or(0),
        0,
        "a clean run must report zero saturations over the wire"
    );

    // hot edge: Q1.14 rails sit at ±2, and 1.9 × 1.9 products clamp —
    // the same counter must now be visible and nonzero
    let railed = GaussMessage::new(
        vec![c64::new(1.9, 0.0); 4],
        CMatrix::scaled_identity(4, 0.05),
    );
    let (id, _) = client
        .open_stream_fixed("hot_q", StreamMode::Sticky, railed.clone(), QFormat::new(1, 14))
        .unwrap();
    client.push(id, vec![(railed, CMatrix::identity(4).scale(1.9))]).unwrap();
    client.close_stream(id).unwrap();
    let stats = client.stats().unwrap();
    assert!(
        stats.telemetry.counter("fixed.saturations").unwrap_or(0) > 0,
        "railed operands must surface in the wire-visible counter"
    );
}

#[test]
fn churn_soak_four_tenant_streams_lose_nothing() {
    const PER_STREAM: usize = 24;
    let cfg = ServeConfig { devices: 2, chunk: 4, ..ServeConfig::default() };
    let (srv, addr) = serve(cfg);

    // per-tenant sample sequences + their bitwise references
    let mut priors = Vec::new();
    let mut sequences = Vec::new();
    let mut wants = Vec::new();
    for t in 0..4 {
        let mut rng = Rng::new(100 + t as u64);
        let prior = msg(&mut rng, 4);
        let seq: Vec<_> = (0..PER_STREAM).map(|_| sample(&mut rng, 4)).collect();
        wants.push(reference_fold(&prior, &seq));
        priors.push(prior);
        sequences.push(seq);
    }

    let farm = srv.farm();
    std::thread::scope(|scope| {
        let clients: Vec<_> = (0..4)
            .map(|t| {
                let addr = addr.clone();
                let prior = priors[t].clone();
                let seq = sequences[t].clone();
                scope.spawn(move || {
                    let tenant = format!("tenant-{t}");
                    // mixed modes: the soak must hold for both paths
                    let mode = if t == 3 { StreamMode::Coalesced } else { StreamMode::Sticky };
                    let mut client = ServeClient::connect(addr.as_str(), &tenant).unwrap();
                    let (id, _) = client.open_stream(&tenant, mode, prior).unwrap();
                    for batch in seq.chunks(4) {
                        client.push(id, batch.to_vec()).unwrap();
                    }
                    client.close_stream(id).unwrap()
                })
            })
            .collect();

        // scripted churn while the streams run: kill/revive each device
        // in turn, never both at once, ending with everything alive
        for _ in 0..3 {
            for d in 0..2 {
                farm.kill_device(d).unwrap();
                std::thread::sleep(Duration::from_millis(10));
                farm.revive_device(d).unwrap();
                std::thread::sleep(Duration::from_millis(5));
            }
        }

        for (t, handle) in clients.into_iter().enumerate() {
            let closed = handle.join().unwrap();
            assert_eq!(
                closed.samples_done, PER_STREAM as u64,
                "tenant {t}: zero lost or duplicated samples under churn"
            );
            assert_eq!(
                closed.state.dist(&wants[t]),
                0.0,
                "tenant {t}: churn must not change a single bit"
            );
        }
    });

    let stats = srv.stats();
    assert_eq!(stats.latency.failed, 0, "churn must surface as failovers, not failures");
    for t in 0..4 {
        let row = stats.tenants.iter().find(|r| r.tenant == format!("tenant-{t}")).unwrap();
        assert_eq!(row.samples, PER_STREAM as u64, "tenant {t} accounting");
    }
}
