//! End-to-end contract of the nonlinear subsystem (ISSUE 3):
//!
//! * EKF and UKF bearing-only tracking conform to the dense
//!   Gauss–Newton reference on the golden engine **and** stay in its
//!   regime on the cycle-accurate device;
//! * every round after the first of a relinearization sweep is a
//!   session program-cache **hit** (fixed graph shape);
//! * the same sweeps serve through an [`FgpFarm`] via the raw
//!   workload-request path, matching the single-device result;
//! * nonlinear factors inside loopy GBP run on the device and match
//!   the linearized dense reference on golden.

use std::sync::Arc;

use fgp_repro::apps::bearing::BearingProblem;
use fgp_repro::apps::rangechain::RangeChain;
use fgp_repro::apps::toa::ToaProblem;
use fgp_repro::coordinator::{FgpFarm, RoutePolicy};
use fgp_repro::engine::Session;
use fgp_repro::fgp::FgpConfig;
use fgp_repro::gbp::{ConvergenceCriteria, FarmExecutor, GbpOptions, IterationPolicy};
use fgp_repro::nonlinear::{
    FirstOrder, IteratedRelinearization, Linearizer, RelinOptions, SigmaPoint,
};

#[test]
fn bearing_ekf_and_ukf_conform_to_dense_reference_on_golden_and_device() {
    let p = BearingProblem::synthetic(6, 4, 1e-4, 5);
    let reference = p.reference_track().unwrap();
    let ukf = SigmaPoint::default();
    let linearizers: [(&str, &dyn Linearizer, f64); 2] =
        [("ekf", &FirstOrder, 1e-4), ("ukf", &ukf, 0.05)];
    for (tag, lin, golden_tol) in linearizers {
        let golden = p.track(&mut Session::golden(), lin, 5).unwrap();
        assert!(!golden.diverged, "{tag} diverged on golden");
        let d = BearingProblem::max_deviation(&golden.estimates, &reference);
        assert!(d < golden_tol, "{tag} golden vs reference: {d}");
        let device = p.track(&mut Session::fgp_sim(FgpConfig::default()), lin, 2).unwrap();
        assert!(!device.diverged, "{tag} diverged on the device");
        let d = BearingProblem::max_deviation(&device.estimates, &reference);
        assert!(d < 0.1, "{tag} device vs reference: {d}");
    }
}

#[test]
fn round_two_of_a_relinearization_sweep_is_a_cache_hit() {
    let mut sim = Session::fgp_sim(FgpConfig::default());
    let p = ToaProblem::synthetic(6, 1e-3, 13);
    let problem = p.nonlinear_problem(4).unwrap();
    let driver = IteratedRelinearization::with_options(
        &FirstOrder,
        RelinOptions { max_rounds: 3, tol: 0.0, ..Default::default() },
    );
    let report = driver.run(&mut sim, &problem).unwrap();
    // tol = 0 forces every round to run; the shape never changes
    assert_eq!(report.rounds, 3);
    assert_eq!(report.cached, vec![false, true, true], "round >= 2 must hit the cache");
    let stats = sim.cache_stats();
    assert_eq!((stats.misses, stats.hits), (1, 2), "{stats:?}");
}

#[test]
fn sweeps_serve_through_a_farm_and_match_a_single_device() {
    let p = ToaProblem::synthetic(6, 1e-3, 17);
    let problem = p.nonlinear_problem(4).unwrap();
    let driver = IteratedRelinearization::with_options(
        &FirstOrder,
        RelinOptions { max_rounds: 2, tol: 0.0, ..Default::default() },
    );
    // single simulated device through the session path
    let single = driver
        .run(&mut Session::fgp_sim(FgpConfig::default()), &problem)
        .unwrap();
    // the same sweeps as raw workload requests over a 3-device farm
    let farm = FgpFarm::start(3, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
    let mut exec = FarmExecutor { farm: &farm };
    let farmed = driver.run_with(&mut exec, &problem).unwrap();
    // deterministic simulator, self-contained requests: identical
    assert!(
        farmed.belief.dist(&single.belief) == 0.0,
        "farm vs single device differ by {}",
        farmed.belief.dist(&single.belief)
    );
}

#[test]
fn bearing_tracker_runs_on_a_farm() {
    let p = BearingProblem::synthetic(4, 3, 1e-3, 9);
    let farm = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::LeastLoaded).unwrap();
    let mut exec = FarmExecutor { farm: &farm };
    let out = p.track_with(&mut exec, &FirstOrder, 2).unwrap();
    assert!(!out.diverged);
    assert!(out.rmse < 0.15, "farm-tracked rmse {}", out.rmse);
}

#[test]
fn nonlinear_gbp_runs_on_the_device_in_goldens_regime() {
    let opts = GbpOptions {
        policy: IterationPolicy::Synchronous { eta_damping: 0.3 },
        criteria: ConvergenceCriteria { tol: 1e-5, max_iters: 120, divergence: 1e3 },
        ..Default::default()
    };
    let p = RangeChain::synthetic(5, 0.004, 1e-3, 12);
    let golden = p.run(&mut Session::golden(), opts, Arc::new(FirstOrder)).unwrap();
    assert!(golden.report.converged(), "golden stop {:?}", golden.report.stop);
    let mut sim = Session::fgp_sim(FgpConfig::default());
    let device = p.run(&mut sim, opts, Arc::new(FirstOrder)).unwrap();
    // quantization keeps the device from the exact fixed point, but the
    // estimate must stay in golden's regime
    assert!(
        device.rmse <= golden.rmse + 0.1,
        "device rmse {} vs golden {}",
        device.rmse,
        golden.rmse
    );
    // per-shape compiles are amortized across rounds: far fewer misses
    // than dispatches
    let stats = sim.cache_stats();
    assert!(stats.hits > stats.misses, "{stats:?}");
}

#[test]
fn toa_estimate_error_is_unchanged_on_the_seed_fixture() {
    // the ISSUE 3 acceptance pin: rebuilding toa on the subsystem must
    // not cost accuracy on the seed fixtures
    let mut golden = Session::golden();
    let p = ToaProblem::synthetic(6, 1e-4, 3);
    let o = p.run(&mut golden, 3).unwrap();
    assert!(o.error < 0.05, "seed fixture error {}", o.error);
    let f = ToaProblem::synthetic(8, 1e-3, 13)
        .run(&mut Session::fgp_sim(FgpConfig::default()), 2)
        .unwrap();
    assert!(f.error < 0.2, "device fixture error {}", f.error);
}
