//! Minimal benchmarking support (no criterion in the vendored set).
//!
//! Each `rust/benches/*.rs` target is a `harness = false` binary that
//! prints the rows of one paper table/figure. This module provides the
//! shared timing / formatting helpers so the benches stay declarative,
//! plus a tiny JSON emitter (no serde in the vendored crate set) every
//! bench uses to publish machine-readable `BENCH_*.json` trajectories —
//! `rust/benches/table2_throughput.rs` writes `BENCH_throughput.json`
//! with it, and CI validates the result against
//! `scripts/bench_throughput.schema.json`.

use std::path::Path;
use std::time::{Duration, Instant};

/// Summary of repeated timings: mean plus tail percentiles (serving
/// latency is a distribution, not a point).
#[derive(Clone, Copy, Debug, Default)]
pub struct TimingStats {
    /// Mean per-iteration wall time.
    pub mean: Duration,
    /// Median per-iteration wall time.
    pub p50: Duration,
    /// 95th-percentile per-iteration wall time.
    pub p95: Duration,
    /// Total wall time across all iterations.
    pub total: Duration,
    /// Iterations measured.
    pub iters: u32,
}

fn stats_from(mut samples: Vec<Duration>, total: Duration) -> TimingStats {
    // zero-iteration guard: no division, all-zero percentiles
    if samples.is_empty() {
        return TimingStats { total, ..TimingStats::default() };
    }
    let iters = samples.len() as u32;
    samples.sort();
    TimingStats {
        mean: total / iters,
        p50: percentile(&samples, 50),
        p95: percentile(&samples, 95),
        total,
        iters,
    }
}

/// Nearest-rank percentile of a non-empty sorted slice.
fn percentile(sorted: &[Duration], p: usize) -> Duration {
    let rank = (sorted.len() * p).div_ceil(100).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Measure `f` over `iters` runs after `warmup` runs.
pub fn time_fn(warmup: u32, iters: u32, mut f: impl FnMut()) -> TimingStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    let t0 = Instant::now();
    for _ in 0..iters {
        let s = Instant::now();
        f();
        samples.push(s.elapsed());
    }
    stats_from(samples, t0.elapsed())
}

/// Run until at least `min_time` has elapsed (one warmup run first).
pub fn time_for(min_time: Duration, mut f: impl FnMut()) -> TimingStats {
    // warmup
    f();
    let mut samples = Vec::new();
    let t0 = Instant::now();
    while t0.elapsed() < min_time {
        let s = Instant::now();
        f();
        samples.push(s.elapsed());
    }
    stats_from(samples, t0.elapsed())
}

/// Print a section banner.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

/// Format a Duration as adaptive human units.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

// ---------------------------------------------------------------------
// JSON emission (machine-readable bench trajectories)
// ---------------------------------------------------------------------

/// Escape a string for a JSON string literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render a JSON string value.
pub fn json_str(s: &str) -> String {
    format!("\"{}\"", json_escape(s))
}

/// Render a JSON number (`null` for non-finite values, which JSON
/// cannot carry).
pub fn json_num(v: f64) -> String {
    if v.is_finite() {
        v.to_string()
    } else {
        "null".to_string()
    }
}

/// Render a JSON object from already-rendered field values.
pub fn json_obj(fields: &[(&str, String)]) -> String {
    let body: Vec<String> = fields
        .iter()
        .map(|(k, v)| format!("{}:{}", json_str(k), v))
        .collect();
    format!("{{{}}}", body.join(","))
}

/// Render a JSON array from already-rendered items.
pub fn json_arr(items: &[String]) -> String {
    format!("[{}]", items.join(","))
}

/// Write a rendered JSON document (with a trailing newline).
pub fn write_json(path: impl AsRef<Path>, root: &str) -> std::io::Result<()> {
    std::fs::write(path, format!("{root}\n"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_counts() {
        let mut n = 0u64;
        let t = time_fn(1, 10, || n += 1);
        assert_eq!(n, 11);
        assert_eq!(t.iters, 10);
        assert!(t.total >= t.mean);
        assert!(t.p95 >= t.p50);
    }

    #[test]
    fn zero_iterations_is_all_zero_not_a_panic() {
        let t = time_fn(0, 0, || {});
        assert_eq!(t.iters, 0);
        assert_eq!(t.mean, Duration::ZERO);
        assert_eq!(t.p50, Duration::ZERO);
        assert_eq!(t.p95, Duration::ZERO);
    }

    #[test]
    fn percentiles_are_nearest_rank() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_nanos).collect();
        assert_eq!(percentile(&samples, 50), Duration::from_nanos(50));
        assert_eq!(percentile(&samples, 95), Duration::from_nanos(95));
        let one = vec![Duration::from_nanos(7)];
        assert_eq!(percentile(&one, 50), Duration::from_nanos(7));
        assert_eq!(percentile(&one, 95), Duration::from_nanos(7));
    }

    /// One sample: every percentile IS that sample — the nearest-rank
    /// clamp must never index past either end.
    #[test]
    fn single_sample_pins_all_percentiles() {
        let one = vec![Duration::from_micros(3)];
        for p in [0, 1, 50, 95, 99, 100] {
            assert_eq!(percentile(&one, p), Duration::from_micros(3), "p{p}");
        }
        let t = stats_from(one.clone(), Duration::from_micros(3));
        assert_eq!(t.iters, 1);
        assert_eq!(t.p50, Duration::from_micros(3));
        assert_eq!(t.p95, Duration::from_micros(3));
        assert_eq!(t.mean, Duration::from_micros(3));
    }

    /// Two samples: nearest-rank p50 is the LOWER sample (rank
    /// ceil(2·50/100) = 1), p95 the upper (rank ceil(2·95/100) = 2) —
    /// the indexing convention this module promises.
    #[test]
    fn two_samples_split_at_the_median_rank() {
        let a = Duration::from_nanos(10);
        let b = Duration::from_nanos(30);
        // stats_from sorts, so insertion order must not matter
        for samples in [vec![a, b], vec![b, a]] {
            let t = stats_from(samples, a + b);
            assert_eq!(t.p50, a, "p50 is the lower of two (nearest rank)");
            assert_eq!(t.p95, b, "p95 is the upper of two");
            assert_eq!(t.mean, Duration::from_nanos(20));
            assert_eq!(t.iters, 2);
        }
    }

    /// All-equal inputs: every statistic collapses to that value, at
    /// any sample count.
    #[test]
    fn all_equal_samples_collapse_every_statistic() {
        for count in [1usize, 2, 3, 97] {
            let v = Duration::from_nanos(42);
            let samples = vec![v; count];
            let t = stats_from(samples, v * count as u32);
            assert_eq!(t.p50, v, "count {count}");
            assert_eq!(t.p95, v, "count {count}");
            assert_eq!(t.mean, v, "count {count}");
            assert_eq!(t.iters, count as u32);
        }
    }

    /// p0 must clamp to the first sample, p100 to the last (the
    /// `clamp(1, len)` in the nearest-rank formula).
    #[test]
    fn percentile_extremes_clamp_to_ends() {
        let samples: Vec<Duration> = (1..=10).map(Duration::from_nanos).collect();
        assert_eq!(percentile(&samples, 0), Duration::from_nanos(1));
        assert_eq!(percentile(&samples, 100), Duration::from_nanos(10));
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_dur(Duration::from_nanos(12)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(12)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(12)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }

    #[test]
    fn json_composition() {
        let row = json_obj(&[
            ("engine", json_str("fgp-sim")),
            ("msgs_per_s", json_num(2.5e5)),
            ("cycles", "260".to_string()),
        ]);
        let doc = json_obj(&[("engines", json_arr(&[row]))]);
        assert_eq!(
            doc,
            "{\"engines\":[{\"engine\":\"fgp-sim\",\"msgs_per_s\":250000,\"cycles\":260}]}"
        );
    }

    #[test]
    fn json_escapes_and_non_finite() {
        assert_eq!(json_str("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
        assert_eq!(json_num(f64::NAN), "null");
        assert_eq!(json_num(f64::INFINITY), "null");
        assert_eq!(json_num(1.5), "1.5");
    }
}
