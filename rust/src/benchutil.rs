//! Minimal benchmarking support (no criterion in the vendored set).
//!
//! Each `rust/benches/*.rs` target is a `harness = false` binary that
//! prints the rows of one paper table/figure. This module provides the
//! shared timing / formatting helpers so the benches stay declarative.

use std::time::{Duration, Instant};

/// Measure the mean wall time of `f` over `iters` runs after `warmup`
/// runs, returning (mean, total).
pub fn time_fn(warmup: u32, iters: u32, mut f: impl FnMut()) -> (Duration, Duration) {
    for _ in 0..warmup {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let total = t0.elapsed();
    (total / iters.max(1), total)
}

/// Run until at least `min_time` has elapsed; returns (mean, iters).
pub fn time_for(min_time: Duration, mut f: impl FnMut()) -> (Duration, u32) {
    // warmup
    f();
    let t0 = Instant::now();
    let mut iters = 0u32;
    while t0.elapsed() < min_time {
        f();
        iters += 1;
    }
    (t0.elapsed() / iters.max(1), iters)
}

/// Print a section banner.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

/// Format a Duration as adaptive human units.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_counts() {
        let mut n = 0u64;
        let (mean, total) = time_fn(1, 10, || n += 1);
        assert_eq!(n, 11);
        assert!(total >= mean);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_dur(Duration::from_nanos(12)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(12)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(12)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
