//! S2 — Gaussian message passing (GMP) golden library.
//!
//! Double-precision reference implementation of everything the FGP
//! computes: complex linear algebra ([`matrix`]), Gaussian messages in
//! both parameterizations ([`message`]), the node update rules of paper
//! Fig. 1 ([`nodes`]), and factor-graph construction plus message
//! schedules ([`graph`], [`schedule`]).
//!
//! This is the semantic ground truth: the cycle-accurate simulator, the
//! Pallas kernels, and the PJRT runtime are all validated against it.

pub mod graph;
pub mod matrix;
pub mod message;
pub mod nodes;
pub mod schedule;

pub use graph::{EdgeId, FactorGraph, NodeId, NodeKind};
pub use matrix::{c64, CMatrix, CVector};
pub use message::GaussMessage;
pub use schedule::{MsgId, Schedule, ScheduleError, ScheduleStep, StepOp};
