//! Factor-graph representation (paper §I, Fig. 6).
//!
//! A factor graph here is a collection of typed nodes connected by edges;
//! each edge carries a Gaussian message. The builder API mirrors the
//! paper's Matlab front-end (Listing 1): the user describes sections of
//! the graph in a high-level way and derives a [`super::Schedule`] from
//! it, which the compiler then turns into FGP assembler.

use super::matrix::CMatrix;

/// Identifies a node within a [`FactorGraph`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// Identifies an edge (a variable / message site) within a [`FactorGraph`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EdgeId(pub usize);

/// Identifies a state matrix stored in the FGP's state memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StateId(pub usize);

/// The node types of paper Fig. 1.
#[derive(Clone, Debug)]
pub enum NodeKind {
    /// Equality constraint: all connected variables equal.
    Equality,
    /// Additive constraint: out = in1 + in2.
    Add,
    /// Multiplier: out = A * in.
    Multiply { a: StateId },
    /// Compound observation node (multiplier A into adder observed via an
    /// observation edge) — the node Table II benchmarks.
    CompoundObservation { a: StateId },
    /// Compound equality-multiplier node (weight-form dual).
    CompoundEquality { a: StateId },
}

/// A node and the edges it connects.
#[derive(Clone, Debug)]
pub struct Node {
    /// The node's update rule.
    pub kind: NodeKind,
    /// Incoming message edges (order is meaningful per node kind).
    pub inputs: Vec<EdgeId>,
    /// Outgoing message edge.
    pub output: EdgeId,
    /// Optional human-readable label (used in compiler diagnostics).
    pub label: String,
}

/// An edge: a variable of dimension `dim` with an optional external role.
#[derive(Clone, Debug)]
pub struct Edge {
    /// Variable dimension.
    pub dim: usize,
    /// True if the message on this edge is loaded from outside (prior /
    /// observation) rather than produced by a node.
    pub is_input: bool,
    /// True if the message on this edge must be readable after execution.
    pub is_output: bool,
    /// Input edges in the same stream group share one message-memory slot:
    /// the host refills it via the Data-in port between loop iterations
    /// (observations of a sectioned graph — see compiler docs).
    pub stream_group: Option<u32>,
    /// Human-readable name (diagnostics, input binding).
    pub label: String,
}

/// A factor graph plus its state-matrix table.
#[derive(Clone, Debug, Default)]
pub struct FactorGraph {
    /// Nodes in insertion order.
    pub nodes: Vec<Node>,
    /// Edges in insertion order.
    pub edges: Vec<Edge>,
    /// State-matrix table (indexed by `StateId`).
    pub states: Vec<CMatrix>,
    /// Per-state stream group: states in the same group share one physical
    /// state-memory slot and are fed by the host per section (e.g. the
    /// per-symbol regressor of the RLS chain). `None` = resident state.
    pub state_stream_groups: Vec<Option<u32>>,
}

impl FactorGraph {
    /// An empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a state matrix (the node-defining `A` of Fig. 1) in state
    /// memory and return its id.
    pub fn add_state(&mut self, a: CMatrix) -> StateId {
        self.states.push(a);
        self.state_stream_groups.push(None);
        StateId(self.states.len() - 1)
    }

    /// Register a state matrix streamed by the host per section: every
    /// state in `group` shares one physical state-memory slot.
    pub fn add_streamed_state(&mut self, group: u32, a: CMatrix) -> StateId {
        let id = self.add_state(a);
        self.state_stream_groups[id.0] = Some(group);
        id
    }

    /// Add an internal edge of the given dimension.
    pub fn add_edge(&mut self, dim: usize, label: impl Into<String>) -> EdgeId {
        self.edges.push(Edge {
            dim,
            is_input: false,
            is_output: false,
            stream_group: None,
            label: label.into(),
        });
        EdgeId(self.edges.len() - 1)
    }

    /// An edge whose message is supplied externally before execution.
    pub fn add_input_edge(&mut self, dim: usize, label: impl Into<String>) -> EdgeId {
        let e = self.add_edge(dim, label);
        self.edges[e.0].is_input = true;
        e
    }

    /// An input edge refilled by the host per section (stream group).
    pub fn add_streamed_input_edge(
        &mut self,
        dim: usize,
        group: u32,
        label: impl Into<String>,
    ) -> EdgeId {
        let e = self.add_input_edge(dim, label);
        self.edges[e.0].stream_group = Some(group);
        e
    }

    /// Mark an edge's message as a program output.
    pub fn mark_output(&mut self, e: EdgeId) {
        self.edges[e.0].is_output = true;
    }

    /// Add a node connecting `inputs` to `output` (arity-checked).
    pub fn add_node(
        &mut self,
        kind: NodeKind,
        inputs: Vec<EdgeId>,
        output: EdgeId,
        label: impl Into<String>,
    ) -> NodeId {
        self.validate_arity(&kind, &inputs);
        self.nodes.push(Node { kind, inputs, output, label: label.into() });
        NodeId(self.nodes.len() - 1)
    }

    fn validate_arity(&self, kind: &NodeKind, inputs: &[EdgeId]) {
        let want = match kind {
            NodeKind::Equality | NodeKind::Add => 2,
            NodeKind::Multiply { .. } => 1,
            NodeKind::CompoundObservation { .. } | NodeKind::CompoundEquality { .. } => 2,
        };
        assert_eq!(inputs.len(), want, "node arity mismatch for {kind:?}");
    }

    /// The state matrix behind an id.
    pub fn state(&self, id: StateId) -> &CMatrix {
        &self.states[id.0]
    }

    /// Edges that must be loaded before the program runs.
    pub fn input_edges(&self) -> impl Iterator<Item = EdgeId> + '_ {
        self.edges
            .iter()
            .enumerate()
            .filter(|(_, e)| e.is_input)
            .map(|(i, _)| EdgeId(i))
    }

    /// Edges whose messages are read back after the program runs.
    pub fn output_edges(&self) -> impl Iterator<Item = EdgeId> + '_ {
        self.edges
            .iter()
            .enumerate()
            .filter(|(_, e)| e.is_output)
            .map(|(i, _)| EdgeId(i))
    }

    // ------------------------------------------------------------------
    // High-level builders (the "Matlab front-end" of Listing 1)
    // ------------------------------------------------------------------

    /// Build the paper's Fig. 6 RLS channel-estimation chain:
    /// `sections` compound-observation nodes threading the channel state,
    /// each with its own regressor state matrix `a_list[i]` and an
    /// observation input edge. Returns (state edges, observation edges).
    pub fn rls_chain(
        &mut self,
        n: usize,
        a_list: &[CMatrix],
    ) -> (Vec<EdgeId>, Vec<EdgeId>) {
        let prior = self.add_input_edge(n, "msg_prior");
        self.cn_sections(n, prior, a_list)
    }

    /// Append a run of compound-observation sections threading the state
    /// from `from`: per section one streamed state matrix and one
    /// streamed observation input edge (both stream group 0 — the
    /// host-refilled convention every chain workload shares), marking
    /// the final edge as the program output. Returns (state edges
    /// including `from`, observation edges). This is the chain body of
    /// [`FactorGraph::rls_chain`], reusable after an arbitrary prelude
    /// (e.g. a motion-model multiplier/adder).
    pub fn cn_sections(
        &mut self,
        n: usize,
        from: EdgeId,
        a_list: &[CMatrix],
    ) -> (Vec<EdgeId>, Vec<EdgeId>) {
        let mut state_edges = Vec::with_capacity(a_list.len() + 1);
        let mut obs_edges = Vec::with_capacity(a_list.len());
        state_edges.push(from);
        let mut prev = from;
        for (i, a) in a_list.iter().enumerate() {
            let sid = self.add_streamed_state(0, a.clone());
            let obs = self.add_streamed_input_edge(n, 0, format!("msg_Y{i}"));
            let out = self.add_edge(n, format!("msg_X{}", i + 1));
            self.add_node(
                NodeKind::CompoundObservation { a: sid },
                vec![prev, obs],
                out,
                format!("section{i}"),
            );
            obs_edges.push(obs);
            state_edges.push(out);
            prev = out;
        }
        self.mark_output(prev);
        (state_edges, obs_edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn rls_chain_has_expected_shape() {
        let mut rng = Rng::new(1);
        let mut g = FactorGraph::new();
        let a_list: Vec<CMatrix> = (0..3).map(|_| CMatrix::random(&mut rng, 4, 4)).collect();
        let (states, obs) = g.rls_chain(4, &a_list);
        assert_eq!(g.nodes.len(), 3);
        assert_eq!(states.len(), 4);
        assert_eq!(obs.len(), 3);
        assert_eq!(g.states.len(), 3);
        // prior + 3 observations are inputs
        assert_eq!(g.input_edges().count(), 4);
        // last state edge is the output
        let outs: Vec<EdgeId> = g.output_edges().collect();
        assert_eq!(outs, vec![*states.last().unwrap()]);
    }

    #[test]
    #[should_panic(expected = "arity mismatch")]
    fn wrong_arity_panics() {
        let mut g = FactorGraph::new();
        let e1 = g.add_edge(4, "x");
        let out = g.add_edge(4, "z");
        g.add_node(NodeKind::Equality, vec![e1], out, "bad");
    }
}
