//! Gaussian messages in both parameterizations (paper §I).
//!
//! GMP exchanges either a mean vector `m` with covariance `V`, or the
//! transformed pair `Wm` with weight matrix `W = V^{-1}` — the dual form
//! that makes the equality node additive. Conversions require a solve,
//! which is why the hardware prefers schedules that stay in one form.

use super::matrix::{c64, CMatrix, CVector};

/// A (scaled) multivariate Gaussian message.
///
/// `PartialEq` is exact bit-level equality of every component (via f64
/// comparison) — used by the wire-codec round-trip tests and the
/// bitwise failover conformance contract, **not** a numerical
/// closeness test; use [`GaussMessage::dist`] for that.
#[derive(Clone, Debug, PartialEq)]
pub struct GaussMessage {
    /// Mean vector `m`.
    pub mean: CVector,
    /// Covariance matrix `V` (Hermitian PSD).
    pub cov: CMatrix,
}

impl GaussMessage {
    /// A message from mean and covariance (dimensions must agree).
    pub fn new(mean: CVector, cov: CMatrix) -> Self {
        assert_eq!(mean.len(), cov.rows);
        assert!(cov.is_square());
        GaussMessage { mean, cov }
    }

    /// Dimension of the variable the message is about.
    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    /// Zero-mean message with covariance `v * I` (a vague / noise prior).
    pub fn isotropic(n: usize, v: f64) -> Self {
        GaussMessage {
            mean: vec![c64::ZERO; n],
            cov: CMatrix::scaled_identity(n, v),
        }
    }

    /// Point observation `y` with noise covariance `sigma2 * I`.
    pub fn observation(y: &[c64], sigma2: f64) -> Self {
        GaussMessage {
            mean: y.to_vec(),
            cov: CMatrix::scaled_identity(y.len(), sigma2),
        }
    }

    /// Weight form `(W, Wm)` with `W = V^{-1}`; `None` if V is singular.
    pub fn to_weight_form(&self) -> Option<(CMatrix, CVector)> {
        let w = self.cov.inverse()?;
        let wm = w.matvec(&self.mean);
        Some((w, wm))
    }

    /// Reconstruct from weight form; `None` if W is singular.
    pub fn from_weight_form(w: &CMatrix, wm: &[c64]) -> Option<Self> {
        let v = w.inverse()?;
        let m = v.matvec(wm);
        Some(GaussMessage { mean: m, cov: v })
    }

    /// Total uncertainty `Re tr(V)`.
    pub fn trace_cov(&self) -> f64 {
        self.cov.trace().re
    }

    /// Max-abs distance between two messages (mean and covariance).
    pub fn dist(&self, other: &GaussMessage) -> f64 {
        let dm = self
            .mean
            .iter()
            .zip(&other.mean)
            .map(|(a, b)| (*a - *b).abs())
            .fold(0.0, f64::max);
        dm.max(self.cov.dist(&other.cov))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{proptest_cases, Rng};

    fn random_msg(rng: &mut Rng, n: usize) -> GaussMessage {
        let cov = CMatrix::random_psd(rng, n, 0.5);
        let mean = (0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect();
        GaussMessage::new(mean, cov)
    }

    #[test]
    fn weight_form_roundtrip() {
        proptest_cases(40, |rng| {
            let n = 3 + rng.below(3);
            let msg = random_msg(rng, n);
            let (w, wm) = msg.to_weight_form().unwrap();
            let back = GaussMessage::from_weight_form(&w, &wm).unwrap();
            assert!(back.dist(&msg) < 1e-7, "dist {}", back.dist(&msg));
        });
    }

    #[test]
    fn isotropic_has_expected_trace() {
        let m = GaussMessage::isotropic(4, 2.5);
        assert!((m.trace_cov() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn observation_carries_value() {
        let y = vec![c64::new(1.0, -1.0), c64::new(0.5, 2.0)];
        let m = GaussMessage::observation(&y, 0.1);
        assert_eq!(m.mean, y);
        assert!((m.cov[(0, 0)].re - 0.1).abs() < 1e-12);
    }

    #[test]
    fn singular_cov_has_no_weight_form() {
        let m = GaussMessage::new(vec![c64::ZERO; 2], CMatrix::zeros(2, 2));
        assert!(m.to_weight_form().is_none());
    }
}
