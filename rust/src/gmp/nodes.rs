//! Node message-update rules (paper Fig. 1) — the f64 golden semantics.
//!
//! The FGP supports simple nodes (equality `=`, addition `+`, matrix
//! multiplier `A`) and compound nodes composed of two simple nodes. The
//! compound *observation* node (multiplier feeding an adder) is the
//! workhorse — its update is the Kalman measurement update, and it is the
//! node Table II benchmarks. Every rule here returns the outgoing message
//! given the incoming ones.

use super::matrix::{c64, CMatrix, CVector};
use super::message::GaussMessage;

/// Errors a node update can raise (singular matrices only — shapes are
/// asserted because they are programming errors, not data errors).
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum NodeError {
    /// A matrix that must be invertible was singular (context named).
    #[error("singular matrix encountered in {0}")]
    Singular(&'static str),
}

/// Equality node: Z s.t. X = Y = Z. Natural in weight form:
/// `W_Z = W_X + W_Y`, `(Wm)_Z = (Wm)_X + (Wm)_Y` (Fig. 1).
pub fn equality(x: &GaussMessage, y: &GaussMessage) -> Result<GaussMessage, NodeError> {
    let (wx, wxm) = x.to_weight_form().ok_or(NodeError::Singular("equality: V_X"))?;
    let (wy, wym) = y.to_weight_form().ok_or(NodeError::Singular("equality: V_Y"))?;
    let wz = wx.add(&wy);
    let wzm: CVector = wxm.iter().zip(&wym).map(|(a, b)| *a + *b).collect();
    GaussMessage::from_weight_form(&wz, &wzm).ok_or(NodeError::Singular("equality: W_Z"))
}

/// Additive node: Z = X + Y. Natural in moment form:
/// `m_Z = m_X + m_Y`, `V_Z = V_X + V_Y` (Fig. 1).
pub fn add(x: &GaussMessage, y: &GaussMessage) -> GaussMessage {
    assert_eq!(x.dim(), y.dim());
    GaussMessage {
        mean: x.mean.iter().zip(&y.mean).map(|(a, b)| *a + *b).collect(),
        cov: x.cov.add(&y.cov),
    }
}

/// Matrix-multiplier node: Y = A X.
/// `m_Y = A m_X`, `V_Y = A V_X A^H` (Fig. 1).
pub fn multiply(x: &GaussMessage, a: &CMatrix) -> GaussMessage {
    assert_eq!(a.cols, x.dim());
    GaussMessage {
        mean: a.matvec(&x.mean),
        cov: a.matmul(&x.cov).matmul(&a.hermitian()),
    }
}

/// Compound **observation** node (multiplier A into an adder observed as Y):
/// the message towards Z (paper Fig. 2 dataflow / Kalman measurement
/// update):
///
/// ```text
///   G   = V_Y + A V_X A^H
///   V_Z = V_X - V_X A^H G^{-1} A V_X
///   m_Z = m_X + V_X A^H G^{-1} (m_Y - A m_X)
/// ```
///
/// `faddeev = true` routes the Schur complement through the elimination
/// scheme the systolic array uses (identical result, same algorithm the
/// hardware runs); `false` uses a direct solve (the "DSP way").
pub fn compound_observation(
    x: &GaussMessage,
    y: &GaussMessage,
    a: &CMatrix,
    faddeev: bool,
) -> Result<GaussMessage, NodeError> {
    let n = x.dim();
    assert_eq!(a.cols, n);
    assert_eq!(a.rows, y.dim());
    let ah = a.hermitian();
    let t1 = x.cov.matmul(&ah); // V_X A^H              (mma)
    let avx = a.matmul(&x.cov); // A V_X = t1^H for Hermitian V_X
    let g = y.cov.add(&a.matmul(&t1)); // G             (mms)

    let vz = if faddeev {
        CMatrix::schur_faddeev(&g, &avx, &t1, &x.cov)
            .ok_or(NodeError::Singular("compound: G (faddeev)"))?
    } else {
        CMatrix::schur_direct(&g, &avx, &t1, &x.cov)
            .ok_or(NodeError::Singular("compound: G (direct)"))?
    };

    // innovation r = m_Y - A m_X, gain column = G^{-1} r
    let amx = a.matvec(&x.mean);
    let r: CVector = y.mean.iter().zip(&amx).map(|(a, b)| *a - *b).collect();
    let mut rm = CMatrix::zeros(r.len(), 1);
    for (i, v) in r.iter().enumerate() {
        rm[(i, 0)] = *v;
    }
    let ginv_r = g.solve(&rm).ok_or(NodeError::Singular("compound: G (mean)"))?;
    let ginv_r: CVector = (0..ginv_r.rows).map(|i| ginv_r[(i, 0)]).collect();
    let corr = t1.matvec(&ginv_r);
    let mz: CVector = x.mean.iter().zip(&corr).map(|(m, c)| *m + *c).collect();

    Ok(GaussMessage::new(mz, vz))
}

/// Compound **equality-multiplier** node in weight form (the dual
/// compound of Fig. 1): for Y = A X with equality constraint, the
/// weight-form update towards Z is
///
/// ```text
///   W_Z    = W_X + A^H W_Y A
///   (Wm)_Z = (Wm)_X + A^H (Wm)_Y
/// ```
pub fn compound_equality_weight(
    wx: &CMatrix,
    wxm: &[c64],
    wy: &CMatrix,
    wym: &[c64],
    a: &CMatrix,
) -> (CMatrix, CVector) {
    let ah = a.hermitian();
    let wz = wx.add(&ah.matmul(wy).matmul(a));
    let aw = ah.matvec(wym);
    let wzm = wxm.iter().zip(&aw).map(|(x, y)| *x + *y).collect();
    (wz, wzm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{proptest_cases, Rng};

    fn random_msg(rng: &mut Rng, n: usize) -> GaussMessage {
        GaussMessage::new(
            (0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect(),
            CMatrix::random_psd(rng, n, 0.5),
        )
    }

    #[test]
    fn equality_in_weight_form_is_additive() {
        proptest_cases(30, |rng| {
            let n = 2 + rng.below(3);
            let x = random_msg(rng, n);
            let y = random_msg(rng, n);
            let z = equality(&x, &y).unwrap();
            let (wx, _) = x.to_weight_form().unwrap();
            let (wy, _) = y.to_weight_form().unwrap();
            let (wz, _) = z.to_weight_form().unwrap();
            assert!(wz.dist(&wx.add(&wy)) < 1e-6 * (1.0 + wz.max_abs()));
        });
    }

    #[test]
    fn equality_reduces_uncertainty() {
        proptest_cases(30, |rng| {
            let n = 3;
            let x = random_msg(rng, n);
            let y = random_msg(rng, n);
            let z = equality(&x, &y).unwrap();
            assert!(z.trace_cov() <= x.trace_cov() + 1e-9);
            assert!(z.trace_cov() <= y.trace_cov() + 1e-9);
        });
    }

    #[test]
    fn add_node_sums_moments() {
        let mut rng = Rng::new(5);
        let x = random_msg(&mut rng, 3);
        let y = random_msg(&mut rng, 3);
        let z = add(&x, &y);
        assert!((z.trace_cov() - x.trace_cov() - y.trace_cov()).abs() < 1e-10);
        for i in 0..3 {
            assert!((z.mean[i] - (x.mean[i] + y.mean[i])).abs() < 1e-12);
        }
    }

    #[test]
    fn multiply_by_identity_is_noop() {
        let mut rng = Rng::new(6);
        let x = random_msg(&mut rng, 4);
        let z = multiply(&x, &CMatrix::identity(4));
        assert!(z.dist(&x) < 1e-12);
    }

    #[test]
    fn multiply_keeps_cov_hermitian() {
        proptest_cases(30, |rng| {
            let x = random_msg(rng, 4);
            let a = CMatrix::random(rng, 4, 4);
            let z = multiply(&x, &a);
            assert!(z.cov.hermitian_defect() < 1e-9 * (1.0 + z.cov.max_abs()));
        });
    }

    #[test]
    fn compound_faddeev_matches_direct() {
        proptest_cases(60, |rng| {
            let n = 2 + rng.below(4);
            let x = random_msg(rng, n);
            let y = random_msg(rng, n);
            let a = CMatrix::random(rng, n, n);
            let zf = compound_observation(&x, &y, &a, true).unwrap();
            let zd = compound_observation(&x, &y, &a, false).unwrap();
            assert!(zf.dist(&zd) < 1e-7 * (1.0 + zf.cov.max_abs()), "dist {}", zf.dist(&zd));
        });
    }

    #[test]
    fn compound_shrinks_covariance() {
        proptest_cases(30, |rng| {
            let x = random_msg(rng, 4);
            let y = random_msg(rng, 4);
            let a = CMatrix::random(rng, 4, 4);
            let z = compound_observation(&x, &y, &a, true).unwrap();
            assert!(z.trace_cov() <= x.trace_cov() + 1e-9);
        });
    }

    #[test]
    fn compound_with_vague_observation_is_noop() {
        // V_Y -> infinity means no information: V_Z ~ V_X, m_Z ~ m_X
        let mut rng = Rng::new(9);
        let x = random_msg(&mut rng, 3);
        let y = GaussMessage::isotropic(3, 1e9);
        let a = CMatrix::identity(3);
        let z = compound_observation(&x, &y, &a, false).unwrap();
        assert!(z.cov.dist(&x.cov) < 1e-5 * x.cov.max_abs() * 10.0);
    }

    #[test]
    fn compound_with_exact_observation_pins_mean() {
        // V_Y -> 0 through identity A: posterior mean == observation
        let mut rng = Rng::new(10);
        let x = random_msg(&mut rng, 3);
        let yv: Vec<c64> = (0..3).map(|_| c64::new(rng.normal(), rng.normal())).collect();
        let y = GaussMessage::observation(&yv, 1e-9);
        let z = compound_observation(&x, &y, &CMatrix::identity(3), false).unwrap();
        for i in 0..3 {
            assert!((z.mean[i] - yv[i]).abs() < 1e-4);
        }
        assert!(z.trace_cov() < 1e-6);
    }

    #[test]
    fn compound_equality_weight_matches_moment_path() {
        // Verify the dual-form compound against converting through moments.
        proptest_cases(20, |rng| {
            let n = 3;
            let x = random_msg(rng, n);
            let y = random_msg(rng, n);
            let a = CMatrix::random_psd(rng, n, 1.0); // invertible A
            let (wx, wxm) = x.to_weight_form().unwrap();
            let (wy, wym) = y.to_weight_form().unwrap();
            let (wz, wzm) = compound_equality_weight(&wx, &wxm, &wy, &wym, &a);
            let z = GaussMessage::from_weight_form(&wz, &wzm).unwrap();
            // moment path: pass Y's message backwards through A, then equality
            let ainv = a.inverse().unwrap();
            let y_through = multiply(&y, &ainv);
            let expect = equality(&x, &y_through).unwrap();
            assert!(z.dist(&expect) < 1e-5 * (1.0 + expect.cov.max_abs()), "dist {}", z.dist(&expect));
        });
    }

    #[test]
    fn singular_inputs_error_not_panic() {
        let x = GaussMessage::new(vec![c64::ZERO; 2], CMatrix::zeros(2, 2));
        let y = GaussMessage::isotropic(2, 1.0);
        assert_eq!(
            equality(&x, &y).unwrap_err(),
            NodeError::Singular("equality: V_X")
        );
    }
}
