//! Complex dense linear algebra for the GMP golden model.
//!
//! Self-contained (the vendored crate set has no `num-complex` /
//! `nalgebra`): a small `c64` complex scalar and a dense row-major
//! [`CMatrix`] with exactly the operations the message update rules need —
//! multiply, Hermitian transpose, LU solve with partial pivoting, and the
//! Schur complement both directly and via the Faddeev elimination the
//! hardware uses.

use std::fmt;
use std::ops::{Add, Div, Mul, Neg, Sub};

/// Complex double-precision scalar.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
#[allow(non_camel_case_types)]
pub struct c64 {
    /// Real part.
    pub re: f64,
    /// Imaginary part.
    pub im: f64,
}

impl c64 {
    /// The additive identity.
    pub const ZERO: c64 = c64 { re: 0.0, im: 0.0 };
    /// The multiplicative identity.
    pub const ONE: c64 = c64 { re: 1.0, im: 0.0 };

    /// A complex number from parts.
    pub fn new(re: f64, im: f64) -> Self {
        c64 { re, im }
    }

    /// Complex conjugate.
    pub fn conj(self) -> Self {
        c64 { re: self.re, im: -self.im }
    }

    /// |z|^2.
    pub fn abs2(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    /// Modulus `|z|`.
    pub fn abs(self) -> f64 {
        self.abs2().sqrt()
    }
}

impl Add for c64 {
    type Output = c64;
    fn add(self, r: c64) -> c64 {
        c64::new(self.re + r.re, self.im + r.im)
    }
}

impl Sub for c64 {
    type Output = c64;
    fn sub(self, r: c64) -> c64 {
        c64::new(self.re - r.re, self.im - r.im)
    }
}

impl Mul for c64 {
    type Output = c64;
    fn mul(self, r: c64) -> c64 {
        c64::new(
            self.re * r.re - self.im * r.im,
            self.re * r.im + self.im * r.re,
        )
    }
}

impl Mul<f64> for c64 {
    type Output = c64;
    fn mul(self, r: f64) -> c64 {
        c64::new(self.re * r, self.im * r)
    }
}

impl Div for c64 {
    type Output = c64;
    fn div(self, r: c64) -> c64 {
        let d = r.abs2();
        c64::new(
            (self.re * r.re + self.im * r.im) / d,
            (self.im * r.re - self.re * r.im) / d,
        )
    }
}

impl Neg for c64 {
    type Output = c64;
    fn neg(self) -> c64 {
        c64::new(-self.re, -self.im)
    }
}

impl fmt::Display for c64 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.im >= 0.0 {
            write!(f, "{:.4}+{:.4}i", self.re, self.im)
        } else {
            write!(f, "{:.4}{:.4}i", self.re, self.im)
        }
    }
}

/// Complex column vector.
pub type CVector = Vec<c64>;

/// Dense row-major complex matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct CMatrix {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    data: Vec<c64>,
}

impl CMatrix {
    /// An all-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        CMatrix { rows, cols, data: vec![c64::ZERO; rows * cols] }
    }

    /// The n x n identity.
    pub fn identity(n: usize) -> Self {
        let mut m = CMatrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = c64::ONE;
        }
        m
    }

    /// Diagonal matrix `x * I`.
    pub fn scaled_identity(n: usize, x: f64) -> Self {
        let mut m = CMatrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = c64::new(x, 0.0);
        }
        m
    }

    /// A matrix from row vectors (must be rectangular).
    pub fn from_rows(rows: &[Vec<c64>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |row| row.len());
        assert!(rows.iter().all(|row| row.len() == c), "ragged rows");
        CMatrix { rows: r, cols: c, data: rows.concat() }
    }

    /// Row-major element storage.
    pub fn data(&self) -> &[c64] {
        &self.data
    }

    /// Mutable row-major element storage.
    pub fn data_mut(&mut self) -> &mut [c64] {
        &mut self.data
    }

    /// True when rows == cols.
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Hermitian (conjugate) transpose.
    pub fn hermitian(&self) -> CMatrix {
        let mut out = CMatrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)].conj();
            }
        }
        out
    }

    /// Plain transpose (no conjugation).
    pub fn transpose(&self) -> CMatrix {
        let mut out = CMatrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Element-wise sum.
    pub fn add(&self, rhs: &CMatrix) -> CMatrix {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let mut out = self.clone();
        for (o, r) in out.data.iter_mut().zip(&rhs.data) {
            *o = *o + *r;
        }
        out
    }

    /// Element-wise difference.
    pub fn sub(&self, rhs: &CMatrix) -> CMatrix {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let mut out = self.clone();
        for (o, r) in out.data.iter_mut().zip(&rhs.data) {
            *o = *o - *r;
        }
        out
    }

    /// Element-wise negation.
    pub fn neg(&self) -> CMatrix {
        let mut out = self.clone();
        for o in out.data.iter_mut() {
            *o = -*o;
        }
        out
    }

    /// Multiply every element by a real scalar.
    pub fn scale(&self, s: f64) -> CMatrix {
        let mut out = self.clone();
        for o in out.data.iter_mut() {
            *o = *o * s;
        }
        out
    }

    /// Matrix product.
    pub fn matmul(&self, rhs: &CMatrix) -> CMatrix {
        assert_eq!(self.cols, rhs.rows, "matmul dim mismatch");
        let mut out = CMatrix::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == c64::ZERO {
                    continue;
                }
                for j in 0..rhs.cols {
                    out[(i, j)] = out[(i, j)] + aik * rhs[(k, j)];
                }
            }
        }
        out
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[c64]) -> CVector {
        assert_eq!(self.cols, x.len(), "matvec dim mismatch");
        (0..self.rows)
            .map(|i| {
                (0..self.cols)
                    .map(|j| self[(i, j)] * x[j])
                    .fold(c64::ZERO, |a, b| a + b)
            })
            .collect()
    }

    /// Sum of the diagonal.
    pub fn trace(&self) -> c64 {
        assert!(self.is_square());
        (0..self.rows).map(|i| self[(i, i)]).fold(c64::ZERO, |a, b| a + b)
    }

    /// Max absolute entry (for tolerances).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().map(|z| z.abs()).fold(0.0, f64::max)
    }

    /// Frobenius distance to another matrix.
    pub fn dist(&self, rhs: &CMatrix) -> f64 {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        self.data
            .iter()
            .zip(&rhs.data)
            .map(|(a, b)| (*a - *b).abs2())
            .sum::<f64>()
            .sqrt()
    }

    /// Solve A X = B via LU with partial pivoting (A = self, square).
    pub fn solve(&self, b: &CMatrix) -> Option<CMatrix> {
        assert!(self.is_square());
        assert_eq!(self.rows, b.rows);
        let n = self.rows;
        let m = b.cols;
        // augmented working copy
        let mut a = self.clone();
        let mut x = b.clone();
        for k in 0..n {
            // partial pivot
            let (mut piv, mut pmax) = (k, a[(k, k)].abs());
            for i in k + 1..n {
                let v = a[(i, k)].abs();
                if v > pmax {
                    piv = i;
                    pmax = v;
                }
            }
            if pmax < 1e-300 {
                return None; // singular
            }
            if piv != k {
                for j in 0..n {
                    let (r1, r2) = (a[(k, j)], a[(piv, j)]);
                    a[(k, j)] = r2;
                    a[(piv, j)] = r1;
                }
                for j in 0..m {
                    let (r1, r2) = (x[(k, j)], x[(piv, j)]);
                    x[(k, j)] = r2;
                    x[(piv, j)] = r1;
                }
            }
            let inv_piv = c64::ONE / a[(k, k)];
            for i in k + 1..n {
                let f = a[(i, k)] * inv_piv;
                if f == c64::ZERO {
                    continue;
                }
                for j in k..n {
                    a[(i, j)] = a[(i, j)] - f * a[(k, j)];
                }
                for j in 0..m {
                    x[(i, j)] = x[(i, j)] - f * x[(k, j)];
                }
            }
        }
        // back substitution
        for k in (0..n).rev() {
            let inv_piv = c64::ONE / a[(k, k)];
            for j in 0..m {
                let mut s = x[(k, j)];
                for i in k + 1..n {
                    s = s - a[(k, i)] * x[(i, j)];
                }
                x[(k, j)] = s * inv_piv;
            }
        }
        Some(x)
    }

    /// Matrix inverse (via [`CMatrix::solve`] against the identity).
    pub fn inverse(&self) -> Option<CMatrix> {
        self.solve(&CMatrix::identity(self.rows))
    }

    /// Schur complement `D - C G^{-1} B` computed directly (the "DSP way").
    pub fn schur_direct(g: &CMatrix, b: &CMatrix, c: &CMatrix, d: &CMatrix) -> Option<CMatrix> {
        let ginv_b = g.solve(b)?;
        Some(d.sub(&c.matmul(&ginv_b)))
    }

    /// Schur complement via **Faddeev elimination** of `[[G, B], [C, D]]`
    /// with partial pivoting over the G-rows — the same algorithm the
    /// FGP's systolic array executes (paper §II). Row swaps during
    /// pivoting are the PEmult "swap" mode.
    pub fn schur_faddeev(g: &CMatrix, b: &CMatrix, c: &CMatrix, d: &CMatrix) -> Option<CMatrix> {
        let n = g.rows;
        assert!(g.is_square() && d.is_square());
        assert_eq!(b.rows, n);
        assert_eq!(c.cols, n);
        let rows = n + c.rows;
        let cols = n + b.cols;
        let mut w = CMatrix::zeros(rows, cols);
        for i in 0..n {
            for j in 0..n {
                w[(i, j)] = g[(i, j)];
            }
            for j in 0..b.cols {
                w[(i, n + j)] = b[(i, j)];
            }
        }
        for i in 0..c.rows {
            for j in 0..n {
                w[(n + i, j)] = c[(i, j)];
            }
            for j in 0..d.cols {
                w[(n + i, n + j)] = d[(i, j)];
            }
        }
        for k in 0..n {
            // pivot among remaining G-rows only (the triangular border
            // sees only the top block)
            let (mut piv, mut pmax) = (k, w[(k, k)].abs());
            for i in k + 1..n {
                let v = w[(i, k)].abs();
                if v > pmax {
                    piv = i;
                    pmax = v;
                }
            }
            if pmax < 1e-300 {
                return None;
            }
            if piv != k {
                for j in 0..cols {
                    let (r1, r2) = (w[(k, j)], w[(piv, j)]);
                    w[(k, j)] = r2;
                    w[(piv, j)] = r1;
                }
            }
            let inv_piv = c64::ONE / w[(k, k)];
            for i in k + 1..rows {
                let f = w[(i, k)] * inv_piv;
                if f == c64::ZERO {
                    continue;
                }
                for j in k..cols {
                    w[(i, j)] = w[(i, j)] - f * w[(k, j)];
                }
            }
        }
        let mut out = CMatrix::zeros(d.rows, d.cols);
        for i in 0..d.rows {
            for j in 0..d.cols {
                out[(i, j)] = w[(n + i, n + j)];
            }
        }
        Some(out)
    }

    /// Random complex matrix (test/workload helper).
    pub fn random(rng: &mut crate::testutil::Rng, rows: usize, cols: usize) -> CMatrix {
        let mut m = CMatrix::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = c64::new(rng.normal(), rng.normal());
        }
        m
    }

    /// Random Hermitian positive-definite matrix `M M^H + ridge I`.
    pub fn random_psd(rng: &mut crate::testutil::Rng, n: usize, ridge: f64) -> CMatrix {
        let m = CMatrix::random(rng, n, n);
        m.matmul(&m.hermitian())
            .add(&CMatrix::scaled_identity(n, ridge))
    }

    /// Hermitian-symmetry defect (0 for exactly Hermitian matrices).
    pub fn hermitian_defect(&self) -> f64 {
        self.dist(&self.hermitian())
    }
}

impl std::ops::Index<(usize, usize)> for CMatrix {
    type Output = c64;
    fn index(&self, (i, j): (usize, usize)) -> &c64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for CMatrix {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut c64 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Display for CMatrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for i in 0..self.rows {
            for j in 0..self.cols {
                write!(f, "{}\t", self[(i, j)])?;
            }
            writeln!(f)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{proptest_cases, Rng};

    #[test]
    fn identity_is_matmul_neutral() {
        let mut rng = Rng::new(1);
        let a = CMatrix::random(&mut rng, 4, 4);
        let i = CMatrix::identity(4);
        assert!(a.matmul(&i).dist(&a) < 1e-12);
        assert!(i.matmul(&a).dist(&a) < 1e-12);
    }

    #[test]
    fn hermitian_is_involution() {
        proptest_cases(50, |rng| {
            let a = CMatrix::random(rng, 3, 5);
            assert!(a.hermitian().hermitian().dist(&a) < 1e-12);
        });
    }

    #[test]
    fn matmul_hermitian_reverses() {
        proptest_cases(50, |rng| {
            let a = CMatrix::random(rng, 3, 4);
            let b = CMatrix::random(rng, 4, 2);
            let lhs = a.matmul(&b).hermitian();
            let rhs = b.hermitian().matmul(&a.hermitian());
            assert!(lhs.dist(&rhs) < 1e-10);
        });
    }

    #[test]
    fn solve_recovers_solution() {
        proptest_cases(50, |rng| {
            let n = 2 + rng.below(5);
            let a = CMatrix::random_psd(rng, n, 0.5);
            let x = CMatrix::random(rng, n, 2);
            let b = a.matmul(&x);
            let got = a.solve(&b).expect("psd is nonsingular");
            assert!(got.dist(&x) < 1e-8 * (1.0 + x.max_abs()));
        });
    }

    #[test]
    fn inverse_times_self_is_identity() {
        proptest_cases(30, |rng| {
            let n = 2 + rng.below(4);
            let a = CMatrix::random_psd(rng, n, 1.0);
            let inv = a.inverse().unwrap();
            assert!(a.matmul(&inv).dist(&CMatrix::identity(n)) < 1e-8);
        });
    }

    #[test]
    fn singular_solve_returns_none() {
        let a = CMatrix::zeros(3, 3);
        assert!(a.solve(&CMatrix::identity(3)).is_none());
    }

    #[test]
    fn faddeev_matches_direct_schur() {
        proptest_cases(60, |rng| {
            let n = 2 + rng.below(4);
            let m = 2 + rng.below(4);
            let g = CMatrix::random_psd(rng, n, 0.5);
            let b = CMatrix::random(rng, n, m);
            let c = CMatrix::random(rng, m, n);
            let d = CMatrix::random(rng, m, m);
            let fad = CMatrix::schur_faddeev(&g, &b, &c, &d).unwrap();
            let dir = CMatrix::schur_direct(&g, &b, &c, &d).unwrap();
            assert!(
                fad.dist(&dir) < 1e-8 * (1.0 + dir.max_abs()),
                "dist {}",
                fad.dist(&dir)
            );
        });
    }

    #[test]
    fn faddeev_identity_g_degenerates_to_mms() {
        let mut rng = Rng::new(3);
        let g = CMatrix::identity(4);
        let b = CMatrix::random(&mut rng, 4, 4);
        let c = CMatrix::random(&mut rng, 4, 4);
        let d = CMatrix::random(&mut rng, 4, 4);
        let fad = CMatrix::schur_faddeev(&g, &b, &c, &d).unwrap();
        assert!(fad.dist(&d.sub(&c.matmul(&b))) < 1e-10);
    }

    #[test]
    fn faddeev_singular_g_returns_none() {
        let g = CMatrix::zeros(2, 2);
        let b = CMatrix::identity(2);
        let c = CMatrix::identity(2);
        let d = CMatrix::identity(2);
        assert!(CMatrix::schur_faddeev(&g, &b, &c, &d).is_none());
    }

    #[test]
    fn psd_has_positive_diagonal() {
        proptest_cases(30, |rng| {
            let v = CMatrix::random_psd(rng, 4, 0.1);
            for i in 0..4 {
                assert!(v[(i, i)].re > 0.0);
                assert!(v[(i, i)].im.abs() < 1e-10);
            }
            assert!(v.hermitian_defect() < 1e-10);
        });
    }

    #[test]
    fn matvec_matches_matmul() {
        proptest_cases(30, |rng| {
            let a = CMatrix::random(rng, 4, 3);
            let x: CVector = (0..3).map(|_| c64::new(rng.normal(), rng.normal())).collect();
            let via_vec = a.matvec(&x);
            let mut xm = CMatrix::zeros(3, 1);
            for (i, v) in x.iter().enumerate() {
                xm[(i, 0)] = *v;
            }
            let via_mat = a.matmul(&xm);
            for i in 0..4 {
                assert!((via_vec[i] - via_mat[(i, 0)]).abs() < 1e-12);
            }
        });
    }
}
