//! Message-update schedules (paper §IV, Fig. 7 left).
//!
//! A [`Schedule`] is the ordered list of node updates derived from a
//! [`FactorGraph`]: "a message update schedule is first derived from the
//! high level description". Each step names the node, its input message
//! ids and the output message id. Message ids at this level are *virtual*
//! (one per distinct message); the compiler's allocator later remaps them
//! onto physical memory slots (Fig. 7 right).
//!
//! The schedule can be executed directly against the golden node rules —
//! that execution is the semantic reference for both the FGP simulator
//! and the compiled program.

use std::collections::HashMap;

use super::graph::{EdgeId, FactorGraph, NodeId, NodeKind, StateId};
use super::message::GaussMessage;
use super::nodes::{self, NodeError};

/// Virtual message identifier (pre-allocation).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MsgId(pub usize);

/// Errors raised while executing a schedule against the golden rules.
///
/// A malformed schedule (a step consuming a message id that no earlier
/// step produced and no input binding supplied) is *data* reaching
/// [`crate::engine::Session::run`] from callers, not a programming
/// invariant of this crate, so it surfaces as a typed error rather than
/// a panic.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum ScheduleError {
    /// A step consumed a message no earlier step produced.
    #[error("schedule step {step} uses undefined message {msg}")]
    UndefinedMessage { step: usize, msg: usize },
    /// A node update failed (singular matrix).
    #[error(transparent)]
    Node(#[from] NodeError),
}

/// What a schedule step computes.
#[derive(Clone, Debug, PartialEq)]
pub enum StepOp {
    /// Equality node update `Z` from `X`, `Y`.
    Equality { x: MsgId, y: MsgId },
    /// Additive node update `Z = X + Y`.
    Add { x: MsgId, y: MsgId },
    /// Multiplier node update `Y = A X`.
    Multiply { x: MsgId, a: StateId },
    /// Compound observation update (multiplier into adder, observed).
    CompoundObservation { x: MsgId, y: MsgId, a: StateId },
    /// Compound equality-multiplier update in weight form.
    CompoundEquality { x: MsgId, y: MsgId, a: StateId },
}

impl StepOp {
    /// Message ids this op consumes.
    pub fn inputs(&self) -> Vec<MsgId> {
        match self {
            StepOp::Equality { x, y }
            | StepOp::Add { x, y }
            | StepOp::CompoundObservation { x, y, .. }
            | StepOp::CompoundEquality { x, y, .. } => vec![*x, *y],
            StepOp::Multiply { x, .. } => vec![*x],
        }
    }

    /// State matrix this op references, if any.
    pub fn state(&self) -> Option<StateId> {
        match self {
            StepOp::Multiply { a, .. }
            | StepOp::CompoundObservation { a, .. }
            | StepOp::CompoundEquality { a, .. } => Some(*a),
            _ => None,
        }
    }
}

/// One node update in the schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct ScheduleStep {
    /// The graph node this step executes.
    pub node: NodeId,
    /// The update rule and its operands.
    pub op: StepOp,
    /// Virtual id of the produced message.
    pub out: MsgId,
}

/// An ordered message-update schedule plus the external bindings.
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    /// Steps in execution order.
    pub steps: Vec<ScheduleStep>,
    /// Messages loaded before execution: (virtual id, source edge).
    pub inputs: Vec<(MsgId, EdgeId)>,
    /// Messages read back after execution: (virtual id, edge).
    pub outputs: Vec<(MsgId, EdgeId)>,
    /// Streamed inputs: (virtual id, stream group) — refilled by the host
    /// per section instead of preloaded (see compiler docs).
    pub streams: Vec<(MsgId, u32)>,
    /// Total number of virtual message ids.
    pub num_msgs: usize,
}

impl Schedule {
    /// Derive the forward-sweep schedule of a graph: nodes in insertion
    /// order, one virtual message id per edge. This mirrors the paper's
    /// compiler front-end which walks the Matlab loop in program order.
    pub fn forward_sweep(graph: &FactorGraph) -> Schedule {
        // Every edge gets a distinct virtual id (Fig. 7 left: "each
        // message has an identifier assigned").
        let edge_msg: HashMap<EdgeId, MsgId> = (0..graph.edges.len())
            .map(|i| (EdgeId(i), MsgId(i)))
            .collect();

        let mut steps = Vec::with_capacity(graph.nodes.len());
        for (i, node) in graph.nodes.iter().enumerate() {
            let get = |e: EdgeId| edge_msg[&e];
            let op = match &node.kind {
                NodeKind::Equality => StepOp::Equality {
                    x: get(node.inputs[0]),
                    y: get(node.inputs[1]),
                },
                NodeKind::Add => StepOp::Add {
                    x: get(node.inputs[0]),
                    y: get(node.inputs[1]),
                },
                NodeKind::Multiply { a } => StepOp::Multiply { x: get(node.inputs[0]), a: *a },
                NodeKind::CompoundObservation { a } => StepOp::CompoundObservation {
                    x: get(node.inputs[0]),
                    y: get(node.inputs[1]),
                    a: *a,
                },
                NodeKind::CompoundEquality { a } => StepOp::CompoundEquality {
                    x: get(node.inputs[0]),
                    y: get(node.inputs[1]),
                    a: *a,
                },
            };
            steps.push(ScheduleStep { node: NodeId(i), op, out: get(node.output) });
        }

        Schedule {
            steps,
            inputs: graph.input_edges().map(|e| (edge_msg[&e], e)).collect(),
            outputs: graph.output_edges().map(|e| (edge_msg[&e], e)).collect(),
            streams: graph
                .edges
                .iter()
                .enumerate()
                .filter_map(|(i, e)| e.stream_group.map(|g| (edge_msg[&EdgeId(i)], g)))
                .collect(),
            num_msgs: graph.edges.len(),
        }
    }

    /// Is this message a streamed input (host-refilled per section)?
    pub fn is_streamed(&self, id: MsgId) -> bool {
        self.streams.iter().any(|(m, _)| *m == id)
    }

    /// Execute the schedule with the golden f64 node rules.
    ///
    /// `initial` binds input virtual ids to messages. Returns the full
    /// message environment (virtual id -> message).
    pub fn execute_golden(
        &self,
        graph: &FactorGraph,
        initial: &HashMap<MsgId, GaussMessage>,
        faddeev: bool,
    ) -> Result<HashMap<MsgId, GaussMessage>, ScheduleError> {
        let mut env: HashMap<MsgId, GaussMessage> = initial.clone();
        for (i, step) in self.steps.iter().enumerate() {
            let msg = |id: &MsgId| -> Result<&GaussMessage, ScheduleError> {
                env.get(id)
                    .ok_or(ScheduleError::UndefinedMessage { step: i, msg: id.0 })
            };
            let out = match &step.op {
                StepOp::Equality { x, y } => nodes::equality(msg(x)?, msg(y)?)?,
                StepOp::Add { x, y } => nodes::add(msg(x)?, msg(y)?),
                StepOp::Multiply { x, a } => nodes::multiply(msg(x)?, graph.state(*a)),
                StepOp::CompoundObservation { x, y, a } => {
                    nodes::compound_observation(msg(x)?, msg(y)?, graph.state(*a), faddeev)?
                }
                StepOp::CompoundEquality { x, y, a } => {
                    // weight-form dual executed through moment conversion
                    let (wx, wxm) = msg(x)?
                        .to_weight_form()
                        .ok_or(NodeError::Singular("schedule: V_X weight"))?;
                    let (wy, wym) = msg(y)?
                        .to_weight_form()
                        .ok_or(NodeError::Singular("schedule: V_Y weight"))?;
                    let (wz, wzm) =
                        nodes::compound_equality_weight(&wx, &wxm, &wy, &wym, graph.state(*a));
                    GaussMessage::from_weight_form(&wz, &wzm)
                        .ok_or(NodeError::Singular("schedule: W_Z"))?
                }
            };
            env.insert(step.out, out);
        }
        Ok(env)
    }

    /// Ids which are live (still needed) at each step — used by tests and
    /// by the compiler's allocator. Entry `i` is the set of ids that must
    /// survive *past* step i's execution.
    pub fn liveness(&self) -> Vec<Vec<MsgId>> {
        let mut live_after = vec![Vec::new(); self.steps.len()];
        let mut live: Vec<MsgId> = self.outputs.iter().map(|(m, _)| *m).collect();
        for (i, step) in self.steps.iter().enumerate().rev() {
            live.retain(|m| *m != step.out);
            live_after[i] = live.clone();
            for input in step.op.inputs() {
                if !live.contains(&input) {
                    live.push(input);
                }
            }
        }
        live_after
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmp::matrix::{c64, CMatrix};
    use crate::testutil::Rng;

    fn rls_setup(sections: usize) -> (FactorGraph, Schedule, HashMap<MsgId, GaussMessage>) {
        let mut rng = Rng::new(42);
        let n = 4;
        let mut g = FactorGraph::new();
        let a_list: Vec<CMatrix> = (0..sections).map(|_| CMatrix::random(&mut rng, n, n)).collect();
        let (_states, _obs) = g.rls_chain(n, &a_list);
        let sched = Schedule::forward_sweep(&g);
        let mut init = HashMap::new();
        for (mid, eid) in &sched.inputs {
            let label = &g.edges[eid.0].label;
            let msg = if label == "msg_prior" {
                GaussMessage::isotropic(n, 10.0)
            } else {
                let y: Vec<c64> = (0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect();
                GaussMessage::observation(&y, 0.1)
            };
            init.insert(*mid, msg);
        }
        (g, sched, init)
    }

    #[test]
    fn forward_sweep_orders_sections() {
        let (_g, sched, _init) = rls_setup(3);
        assert_eq!(sched.steps.len(), 3);
        // each step consumes the previous step's output
        for w in sched.steps.windows(2) {
            assert!(w[1].op.inputs().contains(&w[0].out));
        }
    }

    #[test]
    fn execute_golden_produces_all_outputs() {
        let (g, sched, init) = rls_setup(3);
        let env = sched.execute_golden(&g, &init, true).unwrap();
        for (mid, _) in &sched.outputs {
            assert!(env.contains_key(mid));
        }
        // chain shrinks uncertainty monotonically
        let prior_tr = init[&sched.inputs[0].0].trace_cov();
        let out_tr = env[&sched.outputs[0].0].trace_cov();
        assert!(out_tr < prior_tr);
    }

    #[test]
    fn faddeev_and_direct_execution_agree() {
        let (g, sched, init) = rls_setup(4);
        let env_f = sched.execute_golden(&g, &init, true).unwrap();
        let env_d = sched.execute_golden(&g, &init, false).unwrap();
        for (mid, _) in &sched.outputs {
            let d = env_f[mid].dist(&env_d[mid]);
            assert!(d < 1e-7 * (1.0 + env_d[mid].cov.max_abs()), "dist {d}");
        }
    }

    #[test]
    fn liveness_shrinks_to_outputs() {
        let (_g, sched, _init) = rls_setup(3);
        let live = sched.liveness();
        // after the last step only nothing extra is live (the output is
        // produced by the last step itself)
        assert!(live.last().unwrap().is_empty());
        // intermediate chain messages die immediately after use
        for l in &live {
            assert!(l.len() <= sched.num_msgs);
        }
    }

    #[test]
    fn undefined_message_is_a_typed_error_not_a_panic() {
        let (g, sched, mut init) = rls_setup(2);
        // drop the binding for the second section's observation: step 1
        // then consumes a message nothing defines
        let missing = sched.steps[1].op.inputs()[1];
        init.remove(&missing);
        let err = sched.execute_golden(&g, &init, false).unwrap_err();
        assert_eq!(err, ScheduleError::UndefinedMessage { step: 1, msg: missing.0 });
        assert!(format!("{err}").contains("undefined message"));
    }

    #[test]
    fn node_errors_still_surface_through_schedule_error() {
        let (g, sched, mut init) = rls_setup(1);
        // a zero-covariance prior makes the equality-form conversions
        // inside the compound update singular only if abused; instead
        // force a singular G by zeroing both covariances
        for msg in init.values_mut() {
            *msg = GaussMessage::new(msg.mean.clone(), CMatrix::zeros(4, 4));
        }
        let err = sched.execute_golden(&g, &init, false).unwrap_err();
        assert!(matches!(err, ScheduleError::Node(_)), "{err:?}");
    }

    #[test]
    fn liveness_keeps_required_inputs() {
        let (_g, sched, _init) = rls_setup(2);
        let live = sched.liveness();
        // observation of section 1 must be live after step 0
        let obs1 = sched.steps[1].op.inputs()[1];
        assert!(live[0].contains(&obs1));
    }
}
