//! Complex ⇄ real block embedding, mirroring `python/compile/kernels/ref.py`.
//!
//! `blk(M) = [[Re M, -Im M], [Im M, Re M]]` (2n x 2n, row-major f32);
//! complex vectors map to `[Re; Im]`. The Python oracle tests pin the
//! convention; these functions must match it bit-for-layout so literals
//! round-trip through the AOT artifacts.

use crate::gmp::matrix::{c64, CMatrix, CVector};

/// Complex n x n matrix -> row-major (2n)^2 block-real f32 buffer.
pub fn blk_matrix(m: &CMatrix) -> Vec<f32> {
    let n = m.rows;
    assert!(m.is_square());
    let d = 2 * n;
    let mut out = vec![0f32; d * d];
    for i in 0..n {
        for j in 0..n {
            let z = m[(i, j)];
            out[i * d + j] = z.re as f32; //  Re
            out[i * d + n + j] = -z.im as f32; // -Im
            out[(n + i) * d + j] = z.im as f32; //  Im
            out[(n + i) * d + n + j] = z.re as f32; //  Re
        }
    }
    out
}

/// Block-real (2n)^2 buffer -> complex n x n (reads the left block column).
pub fn unblk_matrix(b: &[f32], n: usize) -> CMatrix {
    let d = 2 * n;
    assert_eq!(b.len(), d * d, "block buffer size");
    let mut m = CMatrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            m[(i, j)] = c64::new(b[i * d + j] as f64, b[(n + i) * d + j] as f64);
        }
    }
    m
}

/// Complex vector -> stacked [Re; Im] f32 buffer.
pub fn blk_vector(v: &[c64]) -> Vec<f32> {
    let n = v.len();
    let mut out = vec![0f32; 2 * n];
    for (i, z) in v.iter().enumerate() {
        out[i] = z.re as f32;
        out[n + i] = z.im as f32;
    }
    out
}

/// Stacked [Re; Im] buffer -> complex vector.
pub fn unblk_vector(b: &[f32]) -> CVector {
    let n = b.len() / 2;
    (0..n).map(|i| c64::new(b[i] as f64, b[n + i] as f64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{proptest_cases, Rng};

    #[test]
    fn matrix_roundtrip() {
        proptest_cases(50, |rng| {
            let n = 1 + rng.below(6);
            let m = CMatrix::random(rng, n, n);
            let back = unblk_matrix(&blk_matrix(&m), n);
            assert!(back.dist(&m) < 1e-6 * (1.0 + m.max_abs()));
        });
    }

    #[test]
    fn vector_roundtrip() {
        proptest_cases(50, |rng| {
            let n = 1 + rng.below(8);
            let v: Vec<c64> = (0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect();
            let back = unblk_vector(&blk_vector(&v));
            for (a, b) in v.iter().zip(&back) {
                assert!((*a - *b).abs() < 1e-6);
            }
        });
    }

    #[test]
    fn block_multiplication_isomorphism() {
        // blk(A) * blk(B) == blk(A*B) — the property the kernels rely on.
        let mut rng = Rng::new(9);
        let n = 3;
        let a = CMatrix::random(&mut rng, n, n);
        let b = CMatrix::random(&mut rng, n, n);
        let ab = a.matmul(&b);
        let (ba, bb) = (blk_matrix(&a), blk_matrix(&b));
        let d = 2 * n;
        let mut prod = vec![0f32; d * d];
        for i in 0..d {
            for k in 0..d {
                for j in 0..d {
                    prod[i * d + j] += ba[i * d + k] * bb[k * d + j];
                }
            }
        }
        let back = unblk_matrix(&prod, n);
        assert!(back.dist(&ab) < 1e-4 * (1.0 + ab.max_abs()));
    }

    #[test]
    fn block_transpose_is_hermitian() {
        let mut rng = Rng::new(10);
        let n = 3;
        let a = CMatrix::random(&mut rng, n, n);
        let ba = blk_matrix(&a);
        let d = 2 * n;
        let mut t = vec![0f32; d * d];
        for i in 0..d {
            for j in 0..d {
                t[j * d + i] = ba[i * d + j];
            }
        }
        let back = unblk_matrix(&t, n);
        assert!(back.dist(&a.hermitian()) < 1e-6 * (1.0 + a.max_abs()));
    }

    #[test]
    fn layout_matches_python_convention() {
        // spot-check the exact element placement against ref.py's blk()
        let mut m = CMatrix::zeros(1, 1);
        m[(0, 0)] = c64::new(2.0, 3.0);
        assert_eq!(blk_matrix(&m), vec![2.0, -3.0, 3.0, 2.0]);
        let v = vec![c64::new(1.0, -4.0)];
        assert_eq!(blk_vector(&v), vec![1.0, -4.0]);
    }
}
