//! S8 — PJRT runtime: load and execute the AOT artifacts from Rust.
//!
//! The L2/L1 layers (JAX model + Pallas kernels) are lowered **once** at
//! build time to HLO text (`make artifacts`); this module loads those
//! artifacts through the PJRT C API (the `xla` crate), compiles them on
//! the CPU client and exposes typed entry points. Python never runs on
//! the request path — the Rust binary is self-contained once
//! `artifacts/` exists.
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod blockform;
pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::gmp::matrix::CMatrix;
use crate::gmp::message::GaussMessage;

pub use blockform::{blk_matrix, blk_vector, unblk_matrix, unblk_vector};
pub use manifest::Manifest;

/// A loaded, compiled artifact set ready to execute.
pub struct RuntimeClient {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// The artifact manifest (shapes baked at AOT time).
    pub manifest: Manifest,
    /// Directory the artifacts were loaded from.
    pub dir: PathBuf,
}

impl RuntimeClient {
    /// Load every artifact listed in `dir/manifest.txt` and compile it on
    /// the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.txt"))
            .context("reading artifacts manifest (run `make artifacts`)")?;
        let client = xla::PjRtClient::cpu()?;
        let mut executables = HashMap::new();
        for entry in &manifest.entries {
            let path = dir.join(format!("{}.hlo.txt", entry.name));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", entry.name))?;
            executables.insert(entry.name.clone(), exe);
        }
        Ok(RuntimeClient { client, executables, manifest, dir })
    }

    /// Platform string of the PJRT backend (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// True when an artifact with this name is loaded.
    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.executables
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))
    }

    /// Raw execute: f32 literals in, 2-tuple of f32 literals out.
    fn execute2(&self, name: &str, inputs: &[xla::Literal]) -> Result<(Vec<f32>, Vec<f32>)> {
        let exe = self.exe(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let (a, b) = result.to_tuple2()?;
        Ok((a.to_vec::<f32>()?, b.to_vec::<f32>()?))
    }

    /// One compound-node update on the XLA path (the fused Pallas kernel
    /// lowered into `cn_update.hlo.txt`).
    pub fn cn_update(
        &self,
        x: &GaussMessage,
        y: &GaussMessage,
        a: &CMatrix,
    ) -> Result<GaussMessage> {
        let n = x.dim();
        let m = 2 * n as i64;
        let lit = |mat: &CMatrix| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(&blk_matrix(mat)).reshape(&[m, m])?)
        };
        let vx = lit(&x.cov)?;
        let vy = lit(&y.cov)?;
        let am = lit(a)?;
        let mx = xla::Literal::vec1(&blk_vector(&x.mean));
        let my = xla::Literal::vec1(&blk_vector(&y.mean));
        let (vz, mz) = self.execute2("cn_update", &[vx, vy, am, mx, my])?;
        Ok(GaussMessage::new(unblk_vector(&mz), unblk_matrix(&vz, n)))
    }

    /// Batched compound-node updates (`cn_update_batched.hlo.txt`). The
    /// batch size is baked into the artifact; an under-full **tail
    /// batch** is padded by replicating the last request up to the baked
    /// batch and truncated on return (padding never alters the first
    /// `reqs.len()` results — each lane is independent; pinned by
    /// `rust/tests/integration_streaming.rs` when artifacts are built).
    pub fn cn_update_batched(
        &self,
        reqs: &[(GaussMessage, GaussMessage, CMatrix)],
    ) -> Result<Vec<GaussMessage>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let entry = self
            .manifest
            .entry("cn_update_batched")
            .context("cn_update_batched not in manifest")?;
        let batch = entry.batch().context("batched artifact has no batch dim")?;
        if reqs.len() > batch {
            bail!("batch too large: {} > artifact batch {batch}", reqs.len());
        }
        let n = reqs[0].0.dim();
        let m = 2 * n;
        let (mut vx, mut vy, mut am) = (Vec::new(), Vec::new(), Vec::new());
        let (mut mx, mut my) = (Vec::new(), Vec::new());
        for i in 0..batch {
            let (x, y, a) = &reqs[i.min(reqs.len() - 1)];
            vx.extend(blk_matrix(&x.cov));
            vy.extend(blk_matrix(&y.cov));
            am.extend(blk_matrix(a));
            mx.extend(blk_vector(&x.mean));
            my.extend(blk_vector(&y.mean));
        }
        let dims = [batch as i64, m as i64, m as i64];
        let vdims = [batch as i64, m as i64];
        let inputs = [
            xla::Literal::vec1(&vx).reshape(&dims)?,
            xla::Literal::vec1(&vy).reshape(&dims)?,
            xla::Literal::vec1(&am).reshape(&dims)?,
            xla::Literal::vec1(&mx).reshape(&vdims)?,
            xla::Literal::vec1(&my).reshape(&vdims)?,
        ];
        let (vz, mz) = self.execute2("cn_update_batched", &inputs)?;
        let mut out = Vec::with_capacity(reqs.len());
        for i in 0..reqs.len() {
            let vz_i = &vz[i * m * m..(i + 1) * m * m];
            let mz_i = &mz[i * m..(i + 1) * m];
            out.push(GaussMessage::new(unblk_vector(mz_i), unblk_matrix(vz_i, n)));
        }
        Ok(out)
    }

    /// Full RLS chain (`rls_chain.hlo.txt`): returns the posterior after
    /// every section. Sections count is baked into the artifact.
    pub fn rls_chain(
        &self,
        prior: &GaussMessage,
        a_seq: &[CMatrix],
        y_seq: &[GaussMessage],
        sigma2: f32,
    ) -> Result<Vec<GaussMessage>> {
        let entry = self.manifest.entry("rls_chain").context("rls_chain not in manifest")?;
        let sections = entry.leading_dim().context("rls artifact has no section dim")?;
        if a_seq.len() != sections || y_seq.len() != sections {
            bail!(
                "rls_chain artifact expects exactly {sections} sections, got {}",
                a_seq.len()
            );
        }
        let n = prior.dim();
        let m = 2 * n;
        let v0 = xla::Literal::vec1(&blk_matrix(&prior.cov)).reshape(&[m as i64, m as i64])?;
        let m0 = xla::Literal::vec1(&blk_vector(&prior.mean));
        let mut aseq = Vec::new();
        let mut yseq = Vec::new();
        for (a, y) in a_seq.iter().zip(y_seq) {
            aseq.extend(blk_matrix(a));
            yseq.extend(blk_vector(&y.mean));
        }
        let inputs = [
            v0,
            m0,
            xla::Literal::vec1(&aseq).reshape(&[sections as i64, m as i64, m as i64])?,
            xla::Literal::vec1(&yseq).reshape(&[sections as i64, m as i64])?,
            xla::Literal::vec1(&[sigma2]).reshape(&[])?,
        ];
        let (v_seq, m_seq) = self.execute2("rls_chain", &inputs)?;
        let mut out = Vec::with_capacity(sections);
        for i in 0..sections {
            let v_i = &v_seq[i * m * m..(i + 1) * m * m];
            let m_i = &m_seq[i * m..(i + 1) * m];
            out.push(GaussMessage::new(unblk_vector(m_i), unblk_matrix(v_i, n)));
        }
        Ok(out)
    }
}
