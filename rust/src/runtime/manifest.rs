//! Artifact manifest parsing (written by `python/compile/aot.py`).
//!
//! Format (one artifact per line after the header):
//!
//! ```text
//! n=4 batch=32 sections=64
//! cn_update inputs=f32[8x8],f32[8x8],f32[8x8],f32[8],f32[8] outputs=2
//! ```
//!
//! The Rust loader validates its marshalling against these shapes at
//! startup so a stale `artifacts/` directory fails fast instead of
//! producing garbage numerics.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// One artifact's signature.
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    /// Artifact name (file stem).
    pub name: String,
    /// Input shapes, each a dim list (empty = scalar).
    pub inputs: Vec<Vec<usize>>,
    /// Number of outputs the artifact returns.
    pub outputs: usize,
}

impl ManifestEntry {
    /// Leading dimension of the first rank-3 input (the batch of a
    /// batched artifact or the section count of a chain).
    pub fn leading_dim(&self) -> Option<usize> {
        self.inputs.iter().find(|s| s.len() == 3).map(|s| s[0])
    }

    /// Batch size of a batched artifact (alias of [`Self::leading_dim`]).
    pub fn batch(&self) -> Option<usize> {
        self.leading_dim()
    }
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Header parameters (n, batch, sections).
    pub n: usize,
    /// Batch dimension baked into batched artifacts.
    pub batch: usize,
    /// Chain length baked into the `rls_chain` artifact.
    pub sections: usize,
    /// Artifact signatures in manifest order.
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Load and parse a manifest file.
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Manifest::parse(&text)
    }

    /// Parse manifest text (header line + one line per artifact).
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines.next().context("empty manifest")?;
        let mut m = Manifest::default();
        for kv in header.split_whitespace() {
            let (k, v) = kv.split_once('=').context("bad header field")?;
            let v: usize = v.parse().context("bad header value")?;
            match k {
                "n" => m.n = v,
                "batch" => m.batch = v,
                "sections" => m.sections = v,
                other => bail!("unknown header key {other}"),
            }
        }
        for line in lines {
            let mut parts = line.split_whitespace();
            let name = parts.next().context("missing artifact name")?.to_string();
            let mut inputs = Vec::new();
            let mut outputs = 0;
            for field in parts {
                if let Some(sig) = field.strip_prefix("inputs=") {
                    for shape in sig.split(',') {
                        let dims = shape
                            .strip_prefix("f32[")
                            .and_then(|s| s.strip_suffix(']'))
                            .with_context(|| format!("bad shape '{shape}'"))?;
                        if dims == "scalar" {
                            inputs.push(vec![]);
                        } else {
                            inputs.push(
                                dims.split('x')
                                    .map(|d| d.parse::<usize>().context("bad dim"))
                                    .collect::<Result<Vec<_>>>()?,
                            );
                        }
                    }
                } else if let Some(o) = field.strip_prefix("outputs=") {
                    outputs = o.parse().context("bad outputs")?;
                } else {
                    bail!("unknown manifest field '{field}'");
                }
            }
            m.entries.push(ManifestEntry { name, inputs, outputs });
        }
        Ok(m)
    }

    /// The entry with the given name, if present.
    pub fn entry(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
n=4 batch=32 sections=64
cn_update inputs=f32[8x8],f32[8x8],f32[8x8],f32[8],f32[8] outputs=2
cn_update_batched inputs=f32[32x8x8],f32[32x8x8],f32[32x8x8],f32[32x8],f32[32x8] outputs=2
rls_chain inputs=f32[8x8],f32[8],f32[64x8x8],f32[64x8],f32[scalar] outputs=2
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!((m.n, m.batch, m.sections), (4, 32, 64));
        assert_eq!(m.entries.len(), 3);
        let cn = m.entry("cn_update").unwrap();
        assert_eq!(cn.inputs.len(), 5);
        assert_eq!(cn.inputs[0], vec![8, 8]);
        assert_eq!(cn.inputs[3], vec![8]);
        assert_eq!(cn.outputs, 2);
    }

    #[test]
    fn leading_dims() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entry("cn_update_batched").unwrap().batch(), Some(32));
        assert_eq!(m.entry("rls_chain").unwrap().leading_dim(), Some(64));
        assert_eq!(m.entry("cn_update").unwrap().leading_dim(), None);
    }

    #[test]
    fn scalar_input_parses() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let rls = m.entry("rls_chain").unwrap();
        assert_eq!(rls.inputs[4], Vec::<usize>::new());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("").is_err());
        assert!(Manifest::parse("n=4\nfoo inputs=bad[3]").is_err());
        assert!(Manifest::parse("bogus=1").is_err());
    }
}
