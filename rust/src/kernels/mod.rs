//! Data-oriented message kernels: SoA planes + shape-monomorphized updates.
//!
//! The interpreted datapath of PRs 1–8 walked arrays of [`CFix`] — 48-byte
//! elements carrying a [`QFormat`] per component — so the compound-node
//! inner loops were bound on memory shuffling, not arithmetic. This module
//! is the layout layer underneath the simulator's hot paths:
//!
//! * [`CPlanes`] / [`PlaneRef`] — struct-of-arrays storage: one contiguous
//!   `i64` plane per complex component. 8 bytes per lane per plane, planes
//!   `memcpy`-able, inner loops autovectorizable.
//! * Shape-specialized kernels — every update kernel has one
//!   `#[inline(always)]` body parameterized on the runtime dimension, plus
//!   monomorphized instantiations for n ∈ {2, 4, 8} (the paper's n = 4 and
//!   its power-of-two neighbours) selected by [`mat_mul`]/[`mat_vec`]/
//!   [`faddeev`]. Monomorphization turns the dimension into a compile-time
//!   constant so LLVM unrolls and vectorizes; the *arithmetic* is the
//!   single shared body either way.
//! * [`CnBatch`] / [`cn_update_batch`] — the fused compound-node batch
//!   entry: lanes stored SoA across the batch, tail-padded to a multiple
//!   of [`CN_BATCH_BLOCK`], each lane executing the exact five-instruction
//!   section sequence the compiler emits (see `compiler::lower`).
//!
//! # Bitwise-conformance contract
//!
//! Layout is a performance knob, never semantics. Every kernel bottoms out
//! in [`crate::fixed::raw`] — the same saturating/rounding scalar
//! primitives, called in the same order, as the interpreted [`Fix`]/
//! [`CFix`] path. Kernel outputs are therefore bit-identical to the seed
//! AoS path by construction; `rust/tests/property_kernels.rs` pins this
//! differentially across dimensions, Q-formats, and saturation fixtures.

use crate::fixed::raw::{self, Rails};
use crate::fixed::{CFix, Fix, QFormat};

/// Owned SoA complex buffer: separate contiguous re/im raw planes.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CPlanes {
    /// Real raw plane.
    pub re: Vec<i64>,
    /// Imaginary raw plane.
    pub im: Vec<i64>,
}

impl CPlanes {
    /// A zeroed buffer of `len` complex lanes.
    pub fn zeroed(len: usize) -> Self {
        CPlanes { re: vec![0; len], im: vec![0; len] }
    }

    /// Number of complex lanes.
    pub fn len(&self) -> usize {
        self.re.len()
    }

    /// True when the buffer holds no lanes.
    pub fn is_empty(&self) -> bool {
        self.re.is_empty()
    }

    /// Zero every lane, keeping capacity.
    pub fn fill_zero(&mut self) {
        self.re.fill(0);
        self.im.fill(0);
    }

    /// Resize to `len` lanes, zero-filling new ones.
    pub fn resize_zeroed(&mut self, len: usize) {
        self.re.resize(len, 0);
        self.im.resize(len, 0);
    }

    /// Replace contents with a copy of `src` (two plane memcpys).
    pub fn copy_from(&mut self, src: PlaneRef) {
        self.re.clear();
        self.re.extend_from_slice(src.re);
        self.im.clear();
        self.im.extend_from_slice(src.im);
    }

    /// Gather an AoS slice into fresh planes.
    pub fn from_cfix(src: &[CFix]) -> Self {
        CPlanes {
            re: src.iter().map(|z| z.re.raw).collect(),
            im: src.iter().map(|z| z.im.raw).collect(),
        }
    }

    /// Scatter back to the AoS encoding (a materialized view; the hot
    /// paths stay on the planes).
    pub fn to_cfix(&self, fmt: QFormat) -> Vec<CFix> {
        self.re
            .iter()
            .zip(&self.im)
            .map(|(&re, &im)| CFix { re: Fix { raw: re, fmt }, im: Fix { raw: im, fmt } })
            .collect()
    }

    /// One lane as a scalar.
    pub fn get(&self, i: usize, fmt: QFormat) -> CFix {
        CFix { re: Fix { raw: self.re[i], fmt }, im: Fix { raw: self.im[i], fmt } }
    }

    /// Borrow the planes.
    pub fn as_ref(&self) -> PlaneRef<'_> {
        PlaneRef { re: &self.re, im: &self.im }
    }

    /// Borrow a sub-range of lanes.
    pub fn slice(&self, range: std::ops::Range<usize>) -> PlaneRef<'_> {
        PlaneRef { re: &self.re[range.clone()], im: &self.im[range] }
    }
}

/// Borrowed SoA complex view (the kernel operand type).
#[derive(Clone, Copy, Debug)]
pub struct PlaneRef<'a> {
    /// Real raw plane.
    pub re: &'a [i64],
    /// Imaginary raw plane.
    pub im: &'a [i64],
}

impl<'a> PlaneRef<'a> {
    /// A view over two equal-length raw planes.
    pub fn new(re: &'a [i64], im: &'a [i64]) -> Self {
        debug_assert_eq!(re.len(), im.len());
        PlaneRef { re, im }
    }

    /// Number of complex lanes.
    pub fn len(&self) -> usize {
        self.re.len()
    }

    /// True when the view holds no lanes.
    pub fn is_empty(&self) -> bool {
        self.re.is_empty()
    }

    /// Materialize the AoS encoding of this view.
    pub fn to_cfix(&self, fmt: QFormat) -> Vec<CFix> {
        self.re
            .iter()
            .zip(self.im)
            .map(|(&re, &im)| CFix { re: Fix { raw: re, fmt }, im: Fix { raw: im, fmt } })
            .collect()
    }
}

/// Which kernel instantiation serves dimension `n` (reported by the
/// throughput bench and the examples).
pub fn kernel_path(n: usize) -> &'static str {
    match n {
        2 => "soa-mono-n2",
        4 => "soa-mono-n4",
        8 => "soa-mono-n8",
        _ => "soa-generic",
    }
}

/// Read operand element (i, k) through the Transpose unit when `herm`
/// (Hermitian transpose: swap indices, negate im with saturation —
/// exactly [`CFix::conj`]).
#[inline(always)]
fn op_elem(op: PlaneRef, n: usize, i: usize, k: usize, herm: bool, r: Rails) -> (i64, i64) {
    if herm {
        let idx = k * n + i;
        (op.re[idx], raw::neg(op.im[idx], r))
    } else {
        let idx = i * n + k;
        (op.re[idx], op.im[idx])
    }
}

/// The one matrix-product body (`mma`/`mms`, matrix side).
///
/// `addend = None` is `mma`: out = (∓) A·B, `neg` negating the summed
/// product. `addend = Some(c)` is `mms`: out = (∓c) + A·B, `neg` negating
/// the addend — the op-order contract of `SystolicArray::{mma,mms}_matrix`.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn mat_mul_body(
    n: usize,
    r: Rails,
    a: PlaneRef,
    a_herm: bool,
    b: PlaneRef,
    b_herm: bool,
    addend: Option<PlaneRef>,
    neg: bool,
    out: &mut CPlanes,
) {
    out.resize_zeroed(n * n);
    for i in 0..n {
        for j in 0..n {
            let (mut acc_re, mut acc_im) = match addend {
                Some(c) => {
                    let (cr, ci) = (c.re[i * n + j], c.im[i * n + j]);
                    if neg {
                        (raw::neg(cr, r), raw::neg(ci, r))
                    } else {
                        (cr, ci)
                    }
                }
                None => (0, 0),
            };
            for k in 0..n {
                let (ar, ai) = op_elem(a, n, i, k, a_herm, r);
                let (br, bi) = op_elem(b, n, k, j, b_herm, r);
                let (pr, pi) = raw::cmul(ar, ai, br, bi, r);
                acc_re = raw::add(acc_re, pr, r);
                acc_im = raw::add(acc_im, pi, r);
            }
            if addend.is_none() && neg {
                acc_re = raw::neg(acc_re, r);
                acc_im = raw::neg(acc_im, r);
            }
            out.re[i * n + j] = acc_re;
            out.im[i * n + j] = acc_im;
        }
    }
}

#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn mat_mul_mono<const N: usize>(
    r: Rails,
    a: PlaneRef,
    a_herm: bool,
    b: PlaneRef,
    b_herm: bool,
    addend: Option<PlaneRef>,
    neg: bool,
    out: &mut CPlanes,
) {
    mat_mul_body(N, r, a, a_herm, b, b_herm, addend, neg, out)
}

/// Matrix `mma`/`mms` kernel with shape dispatch (see [`kernel_path`]).
#[allow(clippy::too_many_arguments)]
pub fn mat_mul(
    n: usize,
    r: Rails,
    a: PlaneRef,
    a_herm: bool,
    b: PlaneRef,
    b_herm: bool,
    addend: Option<PlaneRef>,
    neg: bool,
    out: &mut CPlanes,
) {
    match n {
        2 => mat_mul_mono::<2>(r, a, a_herm, b, b_herm, addend, neg, out),
        4 => mat_mul_mono::<4>(r, a, a_herm, b, b_herm, addend, neg, out),
        8 => mat_mul_mono::<8>(r, a, a_herm, b, b_herm, addend, neg, out),
        _ => mat_mul_body(n, r, a, a_herm, b, b_herm, addend, neg, out),
    }
}

/// The one mean-pipeline body (`mma`/`mms`, vector side); same
/// addend/neg contract as [`mat_mul_body`].
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn mat_vec_body(
    n: usize,
    r: Rails,
    a: PlaneRef,
    a_herm: bool,
    v: PlaneRef,
    addend: Option<PlaneRef>,
    neg: bool,
    out: &mut CPlanes,
) {
    out.resize_zeroed(n);
    for i in 0..n {
        let (mut acc_re, mut acc_im) = match addend {
            Some(c) => {
                let (cr, ci) = (c.re[i], c.im[i]);
                if neg {
                    (raw::neg(cr, r), raw::neg(ci, r))
                } else {
                    (cr, ci)
                }
            }
            None => (0, 0),
        };
        for k in 0..n {
            let (ar, ai) = op_elem(a, n, i, k, a_herm, r);
            let (pr, pi) = raw::cmul(ar, ai, v.re[k], v.im[k], r);
            acc_re = raw::add(acc_re, pr, r);
            acc_im = raw::add(acc_im, pi, r);
        }
        if addend.is_none() && neg {
            acc_re = raw::neg(acc_re, r);
            acc_im = raw::neg(acc_im, r);
        }
        out.re[i] = acc_re;
        out.im[i] = acc_im;
    }
}

#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn mat_vec_mono<const N: usize>(
    r: Rails,
    a: PlaneRef,
    a_herm: bool,
    v: PlaneRef,
    addend: Option<PlaneRef>,
    neg: bool,
    out: &mut CPlanes,
) {
    mat_vec_body(N, r, a, a_herm, v, addend, neg, out)
}

/// Mean-pipeline `mma`/`mms` kernel with shape dispatch.
#[allow(clippy::too_many_arguments)]
pub fn mat_vec(
    n: usize,
    r: Rails,
    a: PlaneRef,
    a_herm: bool,
    v: PlaneRef,
    addend: Option<PlaneRef>,
    neg: bool,
    out: &mut CPlanes,
) {
    match n {
        2 => mat_vec_mono::<2>(r, a, a_herm, v, addend, neg, out),
        4 => mat_vec_mono::<4>(r, a, a_herm, v, addend, neg, out),
        8 => mat_vec_mono::<8>(r, a, a_herm, v, addend, neg, out),
        _ => mat_vec_body(n, r, a, a_herm, v, addend, neg, out),
    }
}

/// The one Faddeev body: triangularize the G columns of the doubled
/// working set with partial pivoting among the G rows, eliminating all
/// rows below each pivot; the Schur complement lands in `mat_out`, the
/// mean column in `vec_out`. Identical op order to
/// `SystolicArray::faddeev` (pivot compare on saturated |.|², skip on
/// exactly-zero lead, divide-then-multiply-subtract row updates).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn faddeev_body(
    n: usize,
    r: Rails,
    g: PlaneRef,
    b: PlaneRef,
    b_herm: bool,
    c: PlaneRef,
    d: PlaneRef,
    y: PlaneRef,
    x: PlaneRef,
    w: &mut CPlanes,
    mat_out: &mut CPlanes,
    vec_out: &mut CPlanes,
) {
    let rows = 2 * n;
    let cols = 2 * n + 1;
    w.resize_zeroed(rows * cols);
    for i in 0..n {
        for j in 0..n {
            w.re[i * cols + j] = g.re[i * n + j];
            w.im[i * cols + j] = g.im[i * n + j];
            let (br, bi) = op_elem(b, n, i, j, b_herm, r);
            w.re[i * cols + n + j] = br;
            w.im[i * cols + n + j] = bi;
            w.re[(n + i) * cols + j] = c.re[i * n + j];
            w.im[(n + i) * cols + j] = c.im[i * n + j];
            w.re[(n + i) * cols + n + j] = d.re[i * n + j];
            w.im[(n + i) * cols + n + j] = d.im[i * n + j];
        }
        w.re[i * cols + 2 * n] = y.re[i];
        w.im[i * cols + 2 * n] = y.im[i];
        w.re[(n + i) * cols + 2 * n] = x.re[i];
        w.im[(n + i) * cols + 2 * n] = x.im[i];
    }

    for k in 0..n {
        // PEborder pivot search: max |.|^2 among remaining G rows.
        let mut piv = k;
        let mut pmax = raw::cabs2(w.re[k * cols + k], w.im[k * cols + k], r);
        for i in k + 1..n {
            let v = raw::cabs2(w.re[i * cols + k], w.im[i * cols + k], r);
            if v > pmax {
                piv = i;
                pmax = v;
            }
        }
        if piv != k {
            // PEmult swap mode: exchange the two rows.
            for j in 0..cols {
                w.re.swap(k * cols + j, piv * cols + j);
                w.im.swap(k * cols + j, piv * cols + j);
            }
        }
        let (pr, pi) = (w.re[k * cols + k], w.im[k * cols + k]);
        // Eliminate every row below the pivot (including the D rows).
        for i in k + 1..rows {
            let (lr, li) = (w.re[i * cols + k], w.im[i * cols + k]);
            if lr == 0 && li == 0 {
                continue;
            }
            let (fr, fi) = raw::cdiv(lr, li, pr, pi, r); // PEborder division
            for j in k..cols {
                let (sr, si) = raw::cmul(fr, fi, w.re[k * cols + j], w.im[k * cols + j], r);
                w.re[i * cols + j] = raw::sub(w.re[i * cols + j], sr, r);
                w.im[i * cols + j] = raw::sub(w.im[i * cols + j], si, r);
            }
        }
    }

    mat_out.resize_zeroed(n * n);
    vec_out.resize_zeroed(n);
    for i in 0..n {
        for j in 0..n {
            mat_out.re[i * n + j] = w.re[(n + i) * cols + n + j];
            mat_out.im[i * n + j] = w.im[(n + i) * cols + n + j];
        }
        vec_out.re[i] = w.re[(n + i) * cols + 2 * n];
        vec_out.im[i] = w.im[(n + i) * cols + 2 * n];
    }
}

#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn faddeev_mono<const N: usize>(
    r: Rails,
    g: PlaneRef,
    b: PlaneRef,
    b_herm: bool,
    c: PlaneRef,
    d: PlaneRef,
    y: PlaneRef,
    x: PlaneRef,
    w: &mut CPlanes,
    mat_out: &mut CPlanes,
    vec_out: &mut CPlanes,
) {
    faddeev_body(N, r, g, b, b_herm, c, d, y, x, w, mat_out, vec_out)
}

/// Faddeev kernel with shape dispatch.
#[allow(clippy::too_many_arguments)]
pub fn faddeev(
    n: usize,
    r: Rails,
    g: PlaneRef,
    b: PlaneRef,
    b_herm: bool,
    c: PlaneRef,
    d: PlaneRef,
    y: PlaneRef,
    x: PlaneRef,
    w: &mut CPlanes,
    mat_out: &mut CPlanes,
    vec_out: &mut CPlanes,
) {
    match n {
        2 => faddeev_mono::<2>(r, g, b, b_herm, c, d, y, x, w, mat_out, vec_out),
        4 => faddeev_mono::<4>(r, g, b, b_herm, c, d, y, x, w, mat_out, vec_out),
        8 => faddeev_mono::<8>(r, g, b, b_herm, c, d, y, x, w, mat_out, vec_out),
        _ => faddeev_body(n, r, g, b, b_herm, c, d, y, x, w, mat_out, vec_out),
    }
}

// ---------------------------------------------------------------------
// Fused compound-node batch entry
// ---------------------------------------------------------------------

/// Lanes per batch block: batches are tail-padded to a multiple of this
/// so the lane loop is uniform (pad lanes replicate the last real lane;
/// their outputs are discarded by the caller reading only `len` lanes).
pub const CN_BATCH_BLOCK: usize = 4;

/// A batch of compound-node requests in SoA form: one plane pair per
/// operand (`V_X`, `m_X`, `V_Y`, `m_Y`, `A`), lanes contiguous across the
/// batch. Built once per coalescer tick and reused.
#[derive(Clone, Debug, Default)]
pub struct CnBatch {
    /// Message dimension.
    pub n: usize,
    /// Real (unpadded) request count.
    pub len: usize,
    vx: CPlanes,
    mx: CPlanes,
    vy: CPlanes,
    my: CPlanes,
    a: CPlanes,
}

impl CnBatch {
    /// An empty batch for dimension `n`.
    pub fn new(n: usize) -> Self {
        CnBatch { n, len: 0, ..Default::default() }
    }

    /// Lane count including tail padding.
    pub fn padded_len(&self) -> usize {
        self.len.div_ceil(CN_BATCH_BLOCK) * CN_BATCH_BLOCK
    }

    /// Drop all lanes, keeping capacity.
    pub fn clear(&mut self) {
        self.len = 0;
        for p in [&mut self.vx, &mut self.mx, &mut self.vy, &mut self.my, &mut self.a] {
            p.re.clear();
            p.im.clear();
        }
    }

    /// Append one quantized request (AoS slices, e.g. from
    /// `MsgSlot::from_message`).
    pub fn push(&mut self, vx: &[CFix], mx: &[CFix], vy: &[CFix], my: &[CFix], a: &[CFix]) {
        let n = self.n;
        assert_eq!(vx.len(), n * n);
        assert_eq!(mx.len(), n);
        assert_eq!(vy.len(), n * n);
        assert_eq!(my.len(), n);
        assert_eq!(a.len(), n * n);
        for (plane, src) in [
            (&mut self.vx, vx),
            (&mut self.mx, mx),
            (&mut self.vy, vy),
            (&mut self.my, my),
            (&mut self.a, a),
        ] {
            plane.re.extend(src.iter().map(|z| z.re.raw));
            plane.im.extend(src.iter().map(|z| z.im.raw));
        }
        self.len += 1;
    }

    fn lane_mat(plane: &CPlanes, n: usize, lane: usize) -> PlaneRef<'_> {
        PlaneRef {
            re: &plane.re[lane * n * n..(lane + 1) * n * n],
            im: &plane.im[lane * n * n..(lane + 1) * n * n],
        }
    }

    fn lane_vec(plane: &CPlanes, n: usize, lane: usize) -> PlaneRef<'_> {
        PlaneRef { re: &plane.re[lane * n..(lane + 1) * n], im: &plane.im[lane * n..(lane + 1) * n] }
    }
}

/// Reusable per-batch scratch (the five architectural planes + Faddeev
/// working set) so steady-state batching allocates nothing.
#[derive(Clone, Debug, Default)]
pub struct CnScratch {
    accum: CPlanes,
    shift: CPlanes,
    vshift: CPlanes,
    w: CPlanes,
    fmat: CPlanes,
    fvec: CPlanes,
}

/// Execute every lane of `batch` through the compiled compound-node
/// section sequence (`compiler::lower::lower_compound_observation`):
///
/// 1. `mma`  — accum  = V_X · Aᴴ            (T1)
/// 2. `mms`  — shift  = V_Y + A · accum     (G)
/// 3. `mms v`— vshift = −m_Y + A · m_X      (negated innovation)
/// 4. `fad`  — Faddeev over [[G, T1ᴴ | r], [T1, V_X | m_X]]
/// 5. store  — posterior (V_Z, m_Z) into the output planes
///
/// The same five kernel calls the processor's FSM issues per section, so
/// each lane's output is bit-identical to dispatching that request
/// through the interpreted program path. Outputs are written SoA at the
/// same lane offsets; pad lanes (if the caller padded) fall out of the
/// uniform loop and are simply never read back.
pub fn cn_update_batch(
    fmt: QFormat,
    batch: &CnBatch,
    out_v: &mut CPlanes,
    out_m: &mut CPlanes,
    scratch: &mut CnScratch,
) {
    let n = batch.n;
    let r = Rails::of(fmt);
    if batch.len == 0 {
        out_v.resize_zeroed(0);
        out_m.resize_zeroed(0);
        return;
    }
    out_v.resize_zeroed(batch.len * n * n);
    out_m.resize_zeroed(batch.len * n);
    // The lane loop runs over the block-padded trip count: tail lanes
    // replicate the last real request so every block is full-width, and
    // their stores are skipped (outputs sized to the real length).
    for lane in 0..batch.padded_len() {
        let src = lane.min(batch.len - 1);
        let vx = CnBatch::lane_mat(&batch.vx, n, src);
        let mx = CnBatch::lane_vec(&batch.mx, n, src);
        let vy = CnBatch::lane_mat(&batch.vy, n, src);
        let my = CnBatch::lane_vec(&batch.my, n, src);
        let a = CnBatch::lane_mat(&batch.a, n, src);
        // 1: accum = V_X * A^H
        mat_mul(n, r, vx, false, a, true, None, false, &mut scratch.accum);
        // 2: shift = V_Y + A * accum
        mat_mul(n, r, a, false, scratch.accum.as_ref(), false, Some(vy), false, &mut scratch.shift);
        // 3: vshift = -m_Y + A * m_X
        mat_vec(n, r, a, false, mx, Some(my), true, &mut scratch.vshift);
        // 4: fad over [[shift, accum^H | vshift], [accum, V_X | m_X]]
        faddeev(
            n,
            r,
            scratch.shift.as_ref(),
            scratch.accum.as_ref(),
            true,
            scratch.accum.as_ref(),
            vx,
            scratch.vshift.as_ref(),
            mx,
            &mut scratch.w,
            &mut scratch.fmat,
            &mut scratch.fvec,
        );
        // 5: store the posterior planes at this lane's offsets
        out_v.re[lane * n * n..(lane + 1) * n * n].copy_from_slice(&scratch.fmat.re);
        out_v.im[lane * n * n..(lane + 1) * n * n].copy_from_slice(&scratch.fmat.im);
        out_m.re[lane * n..(lane + 1) * n].copy_from_slice(&scratch.fvec.re);
        out_m.im[lane * n..(lane + 1) * n].copy_from_slice(&scratch.fvec.im);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{proptest_cases, Rng};

    const FMT: QFormat = QFormat::q5_10();

    fn random_planes(rng: &mut Rng, len: usize) -> CPlanes {
        let span = 2 * FMT.max_raw() as u64 + 1;
        CPlanes {
            re: (0..len).map(|_| (rng.next_u64() % span) as i64 + FMT.min_raw()).collect(),
            im: (0..len).map(|_| (rng.next_u64() % span) as i64 + FMT.min_raw()).collect(),
        }
    }

    #[test]
    fn planes_roundtrip_cfix_bitwise() {
        proptest_cases(50, |rng| {
            let p = random_planes(rng, 16);
            let aos = p.to_cfix(FMT);
            let back = CPlanes::from_cfix(&aos);
            assert_eq!(p, back);
        });
    }

    /// The monomorphized instantiations and the generic body must be the
    /// same arithmetic — pin it on the dispatch boundary dims.
    #[test]
    fn mono_matches_generic_bitwise() {
        proptest_cases(40, |rng| {
            for n in [2usize, 4, 8] {
                let r = Rails::of(FMT);
                let a = random_planes(rng, n * n);
                let b = random_planes(rng, n * n);
                let c = random_planes(rng, n * n);
                let mut out_mono = CPlanes::default();
                let mut out_gen = CPlanes::default();
                mat_mul(n, r, a.as_ref(), false, b.as_ref(), true, Some(c.as_ref()), true, &mut out_mono);
                mat_mul_body(n, r, a.as_ref(), false, b.as_ref(), true, Some(c.as_ref()), true, &mut out_gen);
                assert_eq!(out_mono, out_gen, "n={n}");
            }
        });
    }

    #[test]
    fn cn_batch_pads_to_block_multiple() {
        let mut batch = CnBatch::new(2);
        assert_eq!(batch.padded_len(), 0);
        let z = vec![CFix::zero(FMT); 4];
        let zv = vec![CFix::zero(FMT); 2];
        for want in [4, 4, 4, 4, 8] {
            batch.push(&z, &zv, &z, &zv, &z);
            assert_eq!(batch.padded_len(), want);
            assert_eq!(batch.padded_len() % CN_BATCH_BLOCK, 0);
        }
        batch.clear();
        assert_eq!((batch.len, batch.padded_len()), (0, 0));
    }
}
