//! FGP memories (Fig. 5): program memory, message memory, state memory.
//!
//! A message-memory slot holds one Gaussian message: an n x n complex
//! covariance (or weight) matrix plus its n-element mean column, in
//! fixed-point. At the paper's configuration (n = 4, 16-bit words, 64
//! kbit) this is 640 bits/slot, so ~50 usable slots alongside the PM —
//! the reason long chains stream their observations (see compiler docs).
//!
//! # Storage layout (PR 9)
//!
//! Slots are stored **struct-of-arrays**: each bank keeps one contiguous
//! `i64` raw plane per complex component ([`SlotBank`]), so the datapath
//! kernels ([`crate::kernels`]) stream over flat planes instead of
//! chasing 48-byte `CFix` elements. Layout is invisible at the API
//! boundary — [`MsgSlot`] remains the AoS view type, and
//! [`MessageMemory::read`]/[`StateMemory::read`] materialize it on
//! demand — and is pinned bitwise against the seed AoS encoding by
//! `rust/tests/property_kernels.rs`.

use crate::fixed::{CFix, Fix, QFormat};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::isa::MemoryImage;
use crate::kernels::PlaneRef;

/// One message slot: matrix part + mean column (AoS view type; storage
/// itself is planar, see [`SlotBank`]).
#[derive(Clone, Debug)]
pub struct MsgSlot {
    /// Row-major n x n matrix part.
    pub v: Vec<CFix>,
    /// Mean column (n).
    pub m: Vec<CFix>,
}

impl MsgSlot {
    /// A zeroed slot for dimension `n`.
    pub fn zero(n: usize, fmt: QFormat) -> Self {
        MsgSlot { v: vec![CFix::zero(fmt); n * n], m: vec![CFix::zero(fmt); n] }
    }

    /// Quantize a golden message into the slot format.
    pub fn from_message(msg: &GaussMessage, fmt: QFormat) -> Self {
        let n = msg.dim();
        let mut v = Vec::with_capacity(n * n);
        for i in 0..n {
            for j in 0..n {
                let z = msg.cov[(i, j)];
                v.push(CFix::from_f64(z.re, z.im, fmt));
            }
        }
        let m = msg.mean.iter().map(|z| CFix::from_f64(z.re, z.im, fmt)).collect();
        MsgSlot { v, m }
    }

    /// Read back as a golden message (dequantize).
    pub fn to_message(&self, n: usize) -> GaussMessage {
        let mut cov = CMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let (re, im) = self.v[i * n + j].to_c64();
                cov[(i, j)] = c64::new(re, im);
            }
        }
        let mean = self
            .m
            .iter()
            .map(|z| {
                let (re, im) = z.to_c64();
                c64::new(re, im)
            })
            .collect();
        GaussMessage::new(mean, cov)
    }

    /// Storage size in bits (16-bit real/imag words at the given format).
    pub fn bits(n: usize, fmt: QFormat) -> usize {
        (n * n + n) * 2 * fmt.width() as usize
    }
}

/// A bank of fixed-stride slots stored as two contiguous raw planes
/// (separate re/im `i64` planes across all slots). The SoA primitive
/// under [`MessageMemory`] and [`StateMemory`].
#[derive(Clone, Debug)]
pub struct SlotBank {
    /// Storage fixed-point format.
    pub fmt: QFormat,
    /// Complex lanes per slot.
    pub stride: usize,
    re: Vec<i64>,
    im: Vec<i64>,
}

impl SlotBank {
    /// A zeroed bank of `num_slots` slots of `stride` lanes each.
    pub fn new(stride: usize, fmt: QFormat, num_slots: usize) -> Self {
        SlotBank { fmt, stride, re: vec![0; stride * num_slots], im: vec![0; stride * num_slots] }
    }

    /// Number of addressable slots.
    pub fn num_slots(&self) -> usize {
        if self.stride == 0 { 0 } else { self.re.len() / self.stride }
    }

    /// Borrow one slot's planes.
    pub fn planes(&self, slot: usize) -> PlaneRef<'_> {
        let base = slot * self.stride;
        PlaneRef::new(&self.re[base..base + self.stride], &self.im[base..base + self.stride])
    }

    /// Overwrite one slot from borrowed planes.
    pub fn write_planes(&mut self, slot: usize, src: PlaneRef) {
        assert_eq!(src.len(), self.stride, "slot stride mismatch");
        let base = slot * self.stride;
        self.re[base..base + self.stride].copy_from_slice(src.re);
        self.im[base..base + self.stride].copy_from_slice(src.im);
    }

    /// Scatter an AoS slice into one slot.
    pub fn write_cfix(&mut self, slot: usize, src: &[CFix]) {
        assert_eq!(src.len(), self.stride, "slot stride mismatch");
        let base = slot * self.stride;
        for (k, z) in src.iter().enumerate() {
            self.re[base + k] = z.re.raw;
            self.im[base + k] = z.im.raw;
        }
    }

    /// Quantize one f64 complex value into a lane of `slot`.
    pub fn quantize_into(&mut self, slot: usize, lane: usize, re: f64, im: f64) {
        let z = CFix::from_f64(re, im, self.fmt);
        let idx = slot * self.stride + lane;
        self.re[idx] = z.re.raw;
        self.im[idx] = z.im.raw;
    }

    /// Materialize one slot as the AoS encoding.
    pub fn read_cfix(&self, slot: usize) -> Vec<CFix> {
        let base = slot * self.stride;
        (0..self.stride)
            .map(|k| CFix {
                re: Fix { raw: self.re[base + k], fmt: self.fmt },
                im: Fix { raw: self.im[base + k], fmt: self.fmt },
            })
            .collect()
    }
}

/// Message memory: addressable slots behind the Data-in/out ports.
/// Storage is two [`SlotBank`]s (matrix-part and mean-column planes).
#[derive(Clone, Debug)]
pub struct MessageMemory {
    /// Message dimension per slot.
    pub n: usize,
    /// Storage fixed-point format.
    pub fmt: QFormat,
    mat: SlotBank,
    mean: SlotBank,
}

impl MessageMemory {
    /// A zeroed memory of `num_slots` slots.
    pub fn new(n: usize, fmt: QFormat, num_slots: usize) -> Self {
        MessageMemory {
            n,
            fmt,
            mat: SlotBank::new(n * n, fmt, num_slots),
            mean: SlotBank::new(n, fmt, num_slots),
        }
    }

    /// Number of addressable slots.
    pub fn num_slots(&self) -> usize {
        self.mat.num_slots()
    }

    /// Total capacity in bits (compare against the 64-kbit budget).
    pub fn bits(&self) -> usize {
        self.num_slots() * MsgSlot::bits(self.n, self.fmt)
    }

    /// Write a full slot (covariance + mean planes).
    pub fn write(&mut self, slot: u8, data: MsgSlot) {
        assert_eq!(data.v.len(), self.n * self.n);
        assert_eq!(data.m.len(), self.n);
        self.mat.write_cfix(slot as usize, &data.v);
        self.mean.write_cfix(slot as usize, &data.m);
    }

    /// Host-side store of a golden message (Data-in port): quantizes
    /// straight into the planes, no intermediate AoS buffer.
    pub fn write_message(&mut self, slot: u8, msg: &GaussMessage) {
        assert_eq!(msg.dim(), self.n, "message dim mismatch");
        let n = self.n;
        for i in 0..n {
            for j in 0..n {
                let z = msg.cov[(i, j)];
                self.mat.quantize_into(slot as usize, i * n + j, z.re, z.im);
            }
        }
        for (i, z) in msg.mean.iter().enumerate() {
            self.mean.quantize_into(slot as usize, i, z.re, z.im);
        }
    }

    /// Materialize a slot as its AoS view (kept for golden/diff paths;
    /// the datapath reads [`Self::mat_planes`]/[`Self::mean_planes`]).
    pub fn read(&self, slot: u8) -> MsgSlot {
        MsgSlot { v: self.mat.read_cfix(slot as usize), m: self.mean.read_cfix(slot as usize) }
    }

    /// Borrow a slot's matrix-part planes.
    pub fn mat_planes(&self, slot: u8) -> PlaneRef<'_> {
        self.mat.planes(slot as usize)
    }

    /// Borrow a slot's mean-column planes.
    pub fn mean_planes(&self, slot: u8) -> PlaneRef<'_> {
        self.mean.planes(slot as usize)
    }

    /// Datapath store (the Smm handshake): overwrite a slot from the
    /// array's result planes.
    pub fn write_planes(&mut self, slot: u8, mat: PlaneRef, mean: PlaneRef) {
        self.mat.write_planes(slot as usize, mat);
        self.mean.write_planes(slot as usize, mean);
    }

    /// Host-side read-back (Data-out port).
    pub fn read_message(&self, slot: u8) -> GaussMessage {
        self.read(slot).to_message(self.n)
    }
}

/// State memory: the per-node A matrices (Fig. 5 "Mem A"), one planar
/// [`SlotBank`] of n x n slots.
#[derive(Clone, Debug)]
pub struct StateMemory {
    /// Matrix dimension per slot.
    pub n: usize,
    /// Storage fixed-point format.
    pub fmt: QFormat,
    bank: SlotBank,
}

impl StateMemory {
    /// A zeroed state memory of `num_slots` slots.
    pub fn new(n: usize, fmt: QFormat, num_slots: usize) -> Self {
        StateMemory { n, fmt, bank: SlotBank::new(n * n, fmt, num_slots) }
    }

    /// Number of addressable slots.
    pub fn num_slots(&self) -> usize {
        self.bank.num_slots()
    }

    /// Total storage in bits (capacity accounting).
    pub fn bits(&self) -> usize {
        self.num_slots() * self.n * self.n * 2 * self.fmt.width() as usize
    }

    /// Quantize and store an n x n state matrix (straight into planes).
    pub fn write_matrix(&mut self, slot: u8, a: &CMatrix) {
        assert_eq!((a.rows, a.cols), (self.n, self.n), "state matrix must be n x n");
        for i in 0..self.n {
            for j in 0..self.n {
                let z = a[(i, j)];
                self.bank.quantize_into(slot as usize, i * self.n + j, z.re, z.im);
            }
        }
    }

    /// Materialize a slot's AoS view.
    pub fn read(&self, slot: u8) -> Vec<CFix> {
        self.bank.read_cfix(slot as usize)
    }

    /// Borrow a slot's planes (the datapath operand path).
    pub fn planes(&self, slot: u8) -> PlaneRef<'_> {
        self.bank.planes(slot as usize)
    }
}

/// Program memory: 64-bit instruction words plus the prg directory.
#[derive(Clone, Debug, Default)]
pub struct ProgramMemory {
    /// Raw 64-bit instruction words.
    pub words: Vec<u64>,
}

impl ProgramMemory {
    /// Load a binary image (the `load_program` command's payload).
    pub fn load(&mut self, image: &MemoryImage) -> Result<usize, crate::isa::IsaError> {
        let program = crate::isa::Program::from_image(image)?;
        program.validate()?;
        self.words = program.instrs.iter().map(|i| i.encode()).collect();
        Ok(self.words.len())
    }

    /// Total storage in bits (capacity accounting).
    pub fn bits(&self) -> usize {
        self.words.len() * 64
    }

    /// Fetch the instruction word at `addr`, if in range.
    pub fn fetch(&self, addr: usize) -> Option<u64> {
        self.words.get(addr).copied()
    }

    /// Directory lookup: PM address right after the `prg id` marker.
    pub fn start_of(&self, id: u8) -> Option<usize> {
        let want = crate::isa::Instr::Prg { id }.encode();
        self.words.iter().position(|w| *w == want).map(|a| a + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmp::matrix::CMatrix;
    use crate::testutil::{proptest_cases, Rng};

    const FMT: QFormat = QFormat::q5_10();

    #[test]
    fn message_roundtrip_within_quantization() {
        proptest_cases(50, |rng| {
            let n = 4;
            let msg = GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-3.0, 3.0), rng.range(-3.0, 3.0))).collect(),
                CMatrix::random_psd(rng, n, 0.2).scale(0.2),
            );
            let slot = MsgSlot::from_message(&msg, FMT);
            let back = slot.to_message(n);
            // dist is Frobenius over n^2 entries: half-LSB/component error
            // accumulates to at most n * resolution
            let tol = n as f64 * FMT.resolution();
            assert!(back.dist(&msg) <= tol, "dist {}", back.dist(&msg));
        });
    }

    #[test]
    fn paper_slot_budget() {
        // n=4, 16-bit: 640 bits/slot; 64 kbit feeds ~100 slots without PM.
        assert_eq!(MsgSlot::bits(4, FMT), 640);
        let mem = MessageMemory::new(4, FMT, 48);
        assert!(mem.bits() <= 64 * 1024, "48 slots fit the 64-kbit budget");
    }

    /// The planar banks and the AoS MsgSlot encoding are the same data:
    /// write through either surface, read back bit-identical raws.
    #[test]
    fn soa_bank_roundtrips_aos_slot_bitwise() {
        proptest_cases(25, |rng| {
            let n = 4;
            let msg = GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-40.0, 40.0), rng.range(-40.0, 40.0))).collect(),
                CMatrix::random_psd(rng, n, 0.4).scale(8.0),
            );
            let slot = MsgSlot::from_message(&msg, FMT);
            let mut mem = MessageMemory::new(n, FMT, 4);
            // Path A: AoS write.
            mem.write(1, slot.clone());
            // Path B: direct-quantizing planar write.
            mem.write_message(2, &msg);
            let a = mem.read(1);
            let b = mem.read(2);
            for (x, y) in a.v.iter().zip(&slot.v) {
                assert_eq!((x.re.raw, x.im.raw), (y.re.raw, y.im.raw));
            }
            for (x, y) in a.v.iter().zip(&b.v) {
                assert_eq!((x.re.raw, x.im.raw), (y.re.raw, y.im.raw));
            }
            for (x, y) in a.m.iter().zip(&b.m) {
                assert_eq!((x.re.raw, x.im.raw), (y.re.raw, y.im.raw));
            }
            // The plane view shows the same raws the AoS view decodes.
            let planes = mem.mat_planes(1);
            for (k, z) in a.v.iter().enumerate() {
                assert_eq!((planes.re[k], planes.im[k]), (z.re.raw, z.im.raw));
            }
        });
    }

    #[test]
    fn state_memory_roundtrip() {
        let mut rng = Rng::new(3);
        let mut sm = StateMemory::new(4, FMT, 4);
        let a = CMatrix::random(&mut rng, 4, 4);
        sm.write_matrix(2, &a);
        let v = sm.read(2);
        for i in 0..4 {
            for j in 0..4 {
                let (re, im) = v[i * 4 + j].to_c64();
                assert!((re - a[(i, j)].re).abs() <= FMT.resolution());
                assert!((im - a[(i, j)].im).abs() <= FMT.resolution());
            }
        }
    }

    #[test]
    fn program_memory_load_and_directory() {
        use crate::isa::{Instr, Program};
        let p = Program::new(vec![
            Instr::Prg { id: 1 },
            Instr::Smm { dst: 0 },
            Instr::Halt,
            Instr::Prg { id: 7 },
            Instr::Smm { dst: 1 },
            Instr::Halt,
        ]);
        let mut pm = ProgramMemory::default();
        let n = pm.load(&p.to_image()).unwrap();
        assert_eq!(n, 6);
        assert_eq!(pm.start_of(1), Some(1));
        assert_eq!(pm.start_of(7), Some(4));
        assert_eq!(pm.start_of(3), None);
        assert!(pm.bits() <= 64 * 1024);
    }

    #[test]
    fn corrupt_image_rejected() {
        let mut pm = ProgramMemory::default();
        let img = MemoryImage { bytes: vec![1, 2, 3] };
        assert!(pm.load(&img).is_err());
    }
}
