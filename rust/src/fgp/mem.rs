//! FGP memories (Fig. 5): program memory, message memory, state memory.
//!
//! A message-memory slot holds one Gaussian message: an n x n complex
//! covariance (or weight) matrix plus its n-element mean column, in
//! fixed-point. At the paper's configuration (n = 4, 16-bit words, 64
//! kbit) this is 640 bits/slot, so ~50 usable slots alongside the PM —
//! the reason long chains stream their observations (see compiler docs).

use crate::fixed::{CFix, QFormat};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::isa::MemoryImage;

/// One message slot: matrix part + mean column.
#[derive(Clone, Debug)]
pub struct MsgSlot {
    /// Row-major n x n matrix part.
    pub v: Vec<CFix>,
    /// Mean column (n).
    pub m: Vec<CFix>,
}

impl MsgSlot {
    /// A zeroed slot for dimension `n`.
    pub fn zero(n: usize, fmt: QFormat) -> Self {
        MsgSlot { v: vec![CFix::zero(fmt); n * n], m: vec![CFix::zero(fmt); n] }
    }

    /// Quantize a golden message into the slot format.
    pub fn from_message(msg: &GaussMessage, fmt: QFormat) -> Self {
        let n = msg.dim();
        let mut v = Vec::with_capacity(n * n);
        for i in 0..n {
            for j in 0..n {
                let z = msg.cov[(i, j)];
                v.push(CFix::from_f64(z.re, z.im, fmt));
            }
        }
        let m = msg.mean.iter().map(|z| CFix::from_f64(z.re, z.im, fmt)).collect();
        MsgSlot { v, m }
    }

    /// Read back as a golden message (dequantize).
    pub fn to_message(&self, n: usize) -> GaussMessage {
        let mut cov = CMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let (re, im) = self.v[i * n + j].to_c64();
                cov[(i, j)] = c64::new(re, im);
            }
        }
        let mean = self
            .m
            .iter()
            .map(|z| {
                let (re, im) = z.to_c64();
                c64::new(re, im)
            })
            .collect();
        GaussMessage::new(mean, cov)
    }

    /// Storage size in bits (16-bit real/imag words at the given format).
    pub fn bits(n: usize, fmt: QFormat) -> usize {
        (n * n + n) * 2 * fmt.width() as usize
    }
}

/// Message memory: addressable slots behind the Data-in/out ports.
#[derive(Clone, Debug)]
pub struct MessageMemory {
    /// Message dimension per slot.
    pub n: usize,
    /// Storage fixed-point format.
    pub fmt: QFormat,
    slots: Vec<MsgSlot>,
}

impl MessageMemory {
    /// A zeroed memory of `num_slots` slots.
    pub fn new(n: usize, fmt: QFormat, num_slots: usize) -> Self {
        MessageMemory { n, fmt, slots: vec![MsgSlot::zero(n, fmt); num_slots] }
    }

    /// Number of addressable slots.
    pub fn num_slots(&self) -> usize {
        self.slots.len()
    }

    /// Total capacity in bits (compare against the 64-kbit budget).
    pub fn bits(&self) -> usize {
        self.slots.len() * MsgSlot::bits(self.n, self.fmt)
    }

    /// Write a full slot (covariance + mean planes).
    pub fn write(&mut self, slot: u8, data: MsgSlot) {
        assert_eq!(data.v.len(), self.n * self.n);
        assert_eq!(data.m.len(), self.n);
        self.slots[slot as usize] = data;
    }

    /// Host-side store of a golden message (Data-in port).
    pub fn write_message(&mut self, slot: u8, msg: &GaussMessage) {
        assert_eq!(msg.dim(), self.n, "message dim mismatch");
        self.write(slot, MsgSlot::from_message(msg, self.fmt));
    }

    /// Read a slot's raw fixed-point planes.
    pub fn read(&self, slot: u8) -> &MsgSlot {
        &self.slots[slot as usize]
    }

    /// Host-side read-back (Data-out port).
    pub fn read_message(&self, slot: u8) -> GaussMessage {
        self.slots[slot as usize].to_message(self.n)
    }
}

/// State memory: the per-node A matrices (Fig. 5 "Mem A").
#[derive(Clone, Debug)]
pub struct StateMemory {
    /// Matrix dimension per slot.
    pub n: usize,
    /// Storage fixed-point format.
    pub fmt: QFormat,
    slots: Vec<Vec<CFix>>,
}

impl StateMemory {
    /// A zeroed state memory of `num_slots` slots.
    pub fn new(n: usize, fmt: QFormat, num_slots: usize) -> Self {
        StateMemory { n, fmt, slots: vec![vec![CFix::zero(fmt); n * n]; num_slots] }
    }

    /// Number of addressable slots.
    pub fn num_slots(&self) -> usize {
        self.slots.len()
    }

    /// Total storage in bits (capacity accounting).
    pub fn bits(&self) -> usize {
        self.slots.len() * self.n * self.n * 2 * self.fmt.width() as usize
    }

    /// Quantize and store an n x n state matrix.
    pub fn write_matrix(&mut self, slot: u8, a: &CMatrix) {
        assert_eq!((a.rows, a.cols), (self.n, self.n), "state matrix must be n x n");
        let mut v = Vec::with_capacity(self.n * self.n);
        for i in 0..self.n {
            for j in 0..self.n {
                let z = a[(i, j)];
                v.push(CFix::from_f64(z.re, z.im, self.fmt));
            }
        }
        self.slots[slot as usize] = v;
    }

    /// Read a slot's raw fixed-point values.
    pub fn read(&self, slot: u8) -> &[CFix] {
        &self.slots[slot as usize]
    }
}

/// Program memory: 64-bit instruction words plus the prg directory.
#[derive(Clone, Debug, Default)]
pub struct ProgramMemory {
    /// Raw 64-bit instruction words.
    pub words: Vec<u64>,
}

impl ProgramMemory {
    /// Load a binary image (the `load_program` command's payload).
    pub fn load(&mut self, image: &MemoryImage) -> Result<usize, crate::isa::IsaError> {
        let program = crate::isa::Program::from_image(image)?;
        program.validate()?;
        self.words = program.instrs.iter().map(|i| i.encode()).collect();
        Ok(self.words.len())
    }

    /// Total storage in bits (capacity accounting).
    pub fn bits(&self) -> usize {
        self.words.len() * 64
    }

    /// Fetch the instruction word at `addr`, if in range.
    pub fn fetch(&self, addr: usize) -> Option<u64> {
        self.words.get(addr).copied()
    }

    /// Directory lookup: PM address right after the `prg id` marker.
    pub fn start_of(&self, id: u8) -> Option<usize> {
        let want = crate::isa::Instr::Prg { id }.encode();
        self.words.iter().position(|w| *w == want).map(|a| a + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmp::matrix::CMatrix;
    use crate::testutil::{proptest_cases, Rng};

    const FMT: QFormat = QFormat::q5_10();

    #[test]
    fn message_roundtrip_within_quantization() {
        proptest_cases(50, |rng| {
            let n = 4;
            let msg = GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-3.0, 3.0), rng.range(-3.0, 3.0))).collect(),
                CMatrix::random_psd(rng, n, 0.2).scale(0.2),
            );
            let slot = MsgSlot::from_message(&msg, FMT);
            let back = slot.to_message(n);
            // dist is Frobenius over n^2 entries: half-LSB/component error
            // accumulates to at most n * resolution
            let tol = n as f64 * FMT.resolution();
            assert!(back.dist(&msg) <= tol, "dist {}", back.dist(&msg));
        });
    }

    #[test]
    fn paper_slot_budget() {
        // n=4, 16-bit: 640 bits/slot; 64 kbit feeds ~100 slots without PM.
        assert_eq!(MsgSlot::bits(4, FMT), 640);
        let mem = MessageMemory::new(4, FMT, 48);
        assert!(mem.bits() <= 64 * 1024, "48 slots fit the 64-kbit budget");
    }

    #[test]
    fn state_memory_roundtrip() {
        let mut rng = Rng::new(3);
        let mut sm = StateMemory::new(4, FMT, 4);
        let a = CMatrix::random(&mut rng, 4, 4);
        sm.write_matrix(2, &a);
        let v = sm.read(2);
        for i in 0..4 {
            for j in 0..4 {
                let (re, im) = v[i * 4 + j].to_c64();
                assert!((re - a[(i, j)].re).abs() <= FMT.resolution());
                assert!((im - a[(i, j)].im).abs() <= FMT.resolution());
            }
        }
    }

    #[test]
    fn program_memory_load_and_directory() {
        use crate::isa::{Instr, Program};
        let p = Program::new(vec![
            Instr::Prg { id: 1 },
            Instr::Smm { dst: 0 },
            Instr::Halt,
            Instr::Prg { id: 7 },
            Instr::Smm { dst: 1 },
            Instr::Halt,
        ]);
        let mut pm = ProgramMemory::default();
        let n = pm.load(&p.to_image()).unwrap();
        assert_eq!(n, 6);
        assert_eq!(pm.start_of(1), Some(1));
        assert_eq!(pm.start_of(7), Some(4));
        assert_eq!(pm.start_of(3), None);
        assert!(pm.bits() <= 64 * 1024);
    }

    #[test]
    fn corrupt_image_rejected() {
        let mut pm = ProgramMemory::default();
        let img = MemoryImage { bytes: vec![1, 2, 3] };
        assert!(pm.load(&img).is_err());
    }
}
