//! Execution tracing + instruction-level profiling.
//!
//! The silicon exposes only a status port; the simulator can afford a
//! full trace. [`Profiler`] accumulates per-opcode instruction counts
//! and cycle totals (the data behind EXPERIMENTS.md's cycle budgets) and
//! an optional bounded instruction trace for debugging compiled
//! programs — the software analogue of a logic-analyzer capture.

use std::fmt;

use crate::isa::{Instr, Opcode};

/// Per-opcode aggregate.
#[derive(Clone, Copy, Debug, Default)]
pub struct OpcodeStats {
    /// Instructions executed with this opcode.
    pub count: u64,
    /// Cycles spent in this opcode.
    pub cycles: u64,
}

/// One trace record (bounded capture).
#[derive(Clone, Debug)]
pub struct TraceRecord {
    /// PM address the instruction was fetched from.
    pub addr: usize,
    /// Cycle at which execution of this instruction began.
    pub start_cycle: u64,
    /// Cycles the instruction occupied the datapath.
    pub cycles: u64,
    /// The decoded instruction.
    pub instr: Instr,
}

/// Instruction-level profiler + bounded trace.
#[derive(Debug)]
pub struct Profiler {
    per_opcode: [OpcodeStats; 7],
    records: Vec<TraceRecord>,
    capacity: usize,
    dropped: u64,
}

impl Profiler {
    /// `capacity` bounds the retained trace (0 = profile only).
    pub fn new(capacity: usize) -> Self {
        Profiler { per_opcode: [OpcodeStats::default(); 7], records: Vec::new(), capacity, dropped: 0 }
    }

    /// Record one executed instruction.
    pub fn record(&mut self, addr: usize, start_cycle: u64, cycles: u64, instr: &Instr) {
        let idx = opcode_index(instr);
        self.per_opcode[idx].count += 1;
        self.per_opcode[idx].cycles += cycles;
        if self.records.len() < self.capacity {
            self.records.push(TraceRecord { addr, start_cycle, cycles, instr: instr.clone() });
        } else {
            self.dropped += 1;
        }
    }

    /// Aggregate statistics for one opcode.
    pub fn stats(&self, op: Opcode) -> OpcodeStats {
        self.per_opcode[op as usize]
    }

    /// Total cycles across all opcodes.
    pub fn total_cycles(&self) -> u64 {
        self.per_opcode.iter().map(|s| s.cycles).sum()
    }

    /// Total instructions across all opcodes.
    pub fn total_instructions(&self) -> u64 {
        self.per_opcode.iter().map(|s| s.count).sum()
    }

    /// Per-instruction records (up to the capacity).
    pub fn records(&self) -> &[TraceRecord] {
        &self.records
    }

    /// Records not retained — the capacity filled, or zero-capacity
    /// profile-only mode. The accounting invariant
    /// `records().len() + dropped() == total_instructions()` holds for
    /// every capacity (pinned by `bounded_capture_accounts_for_every_record`).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Fraction of datapath cycles spent in the Faddeev pass — the
    /// utilization argument for the triangular extension.
    pub fn faddeev_share(&self) -> f64 {
        let total = self.total_cycles();
        if total == 0 {
            return 0.0;
        }
        self.stats(Opcode::Fad).cycles as f64 / total as f64
    }
}

fn opcode_index(instr: &Instr) -> usize {
    (match instr {
        Instr::Halt => Opcode::Halt,
        Instr::Mma { .. } => Opcode::Mma,
        Instr::Mms { .. } => Opcode::Mms,
        Instr::Fad { .. } => Opcode::Fad,
        Instr::Smm { .. } => Opcode::Smm,
        Instr::Loop { .. } => Opcode::Loop,
        Instr::Prg { .. } => Opcode::Prg,
    }) as usize
}

impl fmt::Display for Profiler {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{:<8} {:>10} {:>12} {:>8}", "opcode", "count", "cycles", "share")?;
        let total = self.total_cycles().max(1);
        for (name, op) in [
            ("mma", Opcode::Mma),
            ("mms", Opcode::Mms),
            ("fad", Opcode::Fad),
            ("smm", Opcode::Smm),
        ] {
            let s = self.stats(op);
            writeln!(
                f,
                "{name:<8} {:>10} {:>12} {:>7.1}%",
                s.count,
                s.cycles,
                100.0 * s.cycles as f64 / total as f64
            )?;
        }
        writeln!(
            f,
            "trace: {} retained, {} dropped ({} instructions recorded)",
            self.records.len(),
            self.dropped,
            self.total_instructions()
        )?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::OperandSrc;

    fn mma() -> Instr {
        Instr::Mma {
            a: OperandSrc::Msg(0),
            a_herm: false,
            b: OperandSrc::State(0),
            b_herm: true,
            neg: false,
            vec: false,
        }
    }

    #[test]
    fn aggregates_per_opcode() {
        let mut p = Profiler::new(16);
        p.record(0, 0, 22, &mma());
        p.record(1, 22, 167, &Instr::Fad { g: 255, b: 255, b_herm: true, c: 255, d: 0 });
        p.record(2, 189, 10, &Instr::Smm { dst: 1 });
        assert_eq!(p.stats(Opcode::Mma).count, 1);
        assert_eq!(p.stats(Opcode::Fad).cycles, 167);
        assert_eq!(p.total_cycles(), 199);
        assert_eq!(p.total_instructions(), 3);
        assert!(p.faddeev_share() > 0.8);
    }

    #[test]
    fn trace_is_bounded() {
        let mut p = Profiler::new(2);
        for i in 0..5 {
            p.record(i, i as u64, 1, &mma());
        }
        assert_eq!(p.records().len(), 2);
        assert_eq!(p.dropped(), 3);
        assert_eq!(p.total_instructions(), 5); // profiling still complete
    }

    #[test]
    fn bounded_capture_accounts_for_every_record() {
        for capacity in [0usize, 2, 8] {
            let mut p = Profiler::new(capacity);
            for i in 0..5 {
                p.record(i, i as u64, 1, &mma());
            }
            assert_eq!(
                p.records().len() as u64 + p.dropped(),
                p.total_instructions(),
                "retained + dropped must equal recorded at capacity {capacity}"
            );
            let text = format!("{p}");
            assert!(text.contains("retained"), "report must expose the accounting: {text}");
            assert!(text.contains(&format!("{} dropped", p.dropped())));
        }
    }

    #[test]
    fn display_reports_shares() {
        let mut p = Profiler::new(0);
        p.record(0, 0, 50, &mma());
        p.record(1, 50, 50, &Instr::Smm { dst: 0 });
        let text = format!("{p}");
        assert!(text.contains("mma"));
        assert!(text.contains("50.0%"));
    }
}
