//! The configurable systolic array (paper §II, Figs. 3–5).
//!
//! An n x n rectangular grid of `PEmult` cells plus a triangular
//! `PEborder` extension. Values are computed bit-accurately in fixed
//! point; cycle counts come from the wavefront timing model below.
//!
//! Since PR 9 the value planes are **struct-of-arrays** ([`CPlanes`]:
//! one contiguous raw `i64` plane per complex component) and the
//! per-instruction arithmetic is executed by the shape-specialized
//! kernels in [`crate::kernels`]. Both are layout/performance knobs
//! only: the kernels bottom out in the same `fixed::raw` primitives in
//! the same order as the seed AoS interpreter, so results are
//! bit-identical (pinned by `rust/tests/property_kernels.rs`).
//!
//! # Timing model
//!
//! Fixed by the paper:
//! * a complex multiply occupies one `PEmult` for **4 cycles** (one real
//!   multiplier + one real adder, Fig. 3); the adder is free in 2 of the
//!   4 cycles, which is what lets `mms` fold its addition in at no cost;
//! * the `PEborder` divider is a sequential radix-2 unit producing a
//!   16-bit quotient in **4 cycles** (footnote 2); a complex division
//!   needs |den|² (2 mults + add), 4 numerator mults, and two sequential
//!   real divisions on the single divider: 2 + 2 + 2x4 = 12 cycles;
//! * operands stream in skewed one cycle per row/column hop;
//! * instruction words are 64-bit and issue through a 16-bit PM port:
//!   **4 cycles** fetch+decode per instruction.
//!
//! Derived per-instruction counts (n = array size):
//!
//! * `mma`/`mms` (matrix): PE(i,j) executes its k-th MAC in cycles
//!   `4k+i+j .. 4k+i+j+3`, so the array drains at `4n + 2(n-1)` cycles.
//! * `mma`/`mms` (mean pipeline): one column of PEs, `4n + (n-1)`.
//! * `fad`: n pivot steps over the doubled (2n x 2n+1) working set.
//!   Pivot step k: pivot search on the border (`pivot_select`), one
//!   complex division pipeline (latency `div_latency`, overlapped across
//!   rows), then the row-update wavefront: `2n-1-k` rows, each needing
//!   `ceil((2n+1-k)/n)` column passes of 4 cycles, with `rows_in_flight`
//!   rows pipelined through the grid concurrently.
//! * `smm`: the store port moves `port_words` complex words per cycle.
//!
//! With the default parameters the n=4 compound-node update measures
//! **exactly 260 cycles** — the paper's Table II number (see
//! EXPERIMENTS.md E1).
//!
//! # Multi-PE mode (PR 9)
//!
//! [`MultiPeModel`] scales the paper's architecture out to P independent
//! PE array instances fed by one sequencer: sections issue round-robin
//! across PEs with a cross-PE wavefront skew of [`MultiPeModel::skew`]
//! cycles per hop (operand broadcast ripples down the PE chain), and all
//! PEs share [`MultiPeModel::store_ports`] message-memory store ports,
//! so concurrent `smm`s serialize. PE count is a throughput knob only —
//! values are still computed sequentially per section, so outputs are
//! bit-identical at every P (the Table II "N processing elements"
//! column measures cycles, never values).

use crate::fixed::raw::Rails;
use crate::fixed::{CFix, QFormat, Radix2Divider};
use crate::kernels::{self, CPlanes, PlaneRef};

/// Array timing parameters (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct TimingModel {
    /// Cycles per complex multiply on a PEmult (paper: 4).
    pub cmul: u64,
    /// Latency of a complex division on the PEborder (derived: 12).
    pub div_latency: u64,
    /// Border cycles to select a pivot row (abs-compare wavefront).
    pub pivot_select: u64,
    /// Rows concurrently in flight through the elimination wavefront.
    pub rows_in_flight: u64,
    /// Complex words per cycle through the store port.
    pub port_words: u64,
    /// Instruction fetch+decode cycles (64-bit word via 16-bit port: 4).
    pub fetch: u64,
}

impl Default for TimingModel {
    fn default() -> Self {
        TimingModel {
            cmul: CFix::MUL_CYCLES,
            div_latency: 2 + 2 + 2 * Radix2Divider::default_latency(),
            pivot_select: 2,
            rows_in_flight: 2,
            port_words: 2,
            fetch: 4,
        }
    }
}

impl TimingModel {
    /// Cycles for an n x n matrix `mma`/`mms` pass.
    pub fn matrix_pass(&self, n: usize) -> u64 {
        self.cmul * n as u64 + 2 * (n as u64 - 1)
    }

    /// Cycles for a mean-pipeline (vector) pass.
    pub fn vector_pass(&self, n: usize) -> u64 {
        self.cmul * n as u64 + (n as u64 - 1)
    }

    /// Cycles for the Faddeev pass over the doubled matrix (n pivots).
    pub fn faddeev_pass(&self, n: usize) -> u64 {
        let n = n as u64;
        let mut total = 0;
        for k in 0..n {
            let rows = 2 * n - 1 - k; // rows below the pivot
            let cols = 2 * n + 1 - k; // active columns incl. mean column
            let passes_per_row = cols.div_ceil(n);
            let update = (rows * passes_per_row * self.cmul).div_ceil(self.rows_in_flight);
            total += self.pivot_select + self.div_latency + update;
        }
        // final drain of the wavefront through the grid
        total + 2 * n + 1
    }

    /// Cycles for `smm` (store n x n matrix + n mean words).
    pub fn store_pass(&self, n: usize) -> u64 {
        ((n * n + n) as u64).div_ceil(self.port_words)
    }

    /// Cycles one compound-node section spends on the datapath proper
    /// (everything except the shared-port store) — the portion that
    /// overlaps across PEs in multi-PE mode.
    pub fn datapath_pass(&self, n: usize) -> u64 {
        self.compound_node_cycles(n) - self.store_pass(n)
    }

    /// Cycles for the benchmark compound-node update (fetch + 4 datapath
    /// + store) — the quantity Table II reports. Exactly 260 at n = 4
    /// with the default parameters.
    pub fn compound_node_cycles(&self, n: usize) -> u64 {
        5 * self.fetch
            + self.matrix_pass(n)            // mma: T1
            + self.matrix_pass(n)            // mms: G
            + self.vector_pass(n)            // mms v: innovation
            + self.faddeev_pass(n)           // fad
            + self.store_pass(n) // smm
    }
}

/// One section's cost split for the multi-PE fold: datapath cycles
/// (overlappable across PEs) vs store cycles (serialized through the
/// shared ports).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SectionCost {
    /// Fetch + datapath cycles for the section (everything except smm).
    pub compute: u64,
    /// Store cycles through one port (the smm pass).
    pub store: u64,
}

/// Multi-PE scaling model: P array instances, cross-PE issue skew, and
/// shared store-port contention. Cycle accounting only — values never
/// depend on `n_pes` (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MultiPeModel {
    /// Number of PE array instances (1 = the paper's processor).
    pub n_pes: usize,
    /// Issue-skew cycles between adjacent PEs in a wave (operand
    /// broadcast hop latency).
    pub skew: u64,
    /// Message-memory store ports shared by all PEs.
    pub store_ports: u64,
}

impl Default for MultiPeModel {
    fn default() -> Self {
        MultiPeModel { n_pes: 1, skew: 2, store_ports: 1 }
    }
}

impl MultiPeModel {
    /// A model with `n_pes` PEs and default skew/port parameters.
    pub fn with_pes(n_pes: usize) -> Self {
        MultiPeModel { n_pes: n_pes.max(1), ..Default::default() }
    }

    /// Cycles for one wave of `active <= n_pes` uniform compound-node
    /// sections: the last PE starts `(active-1)*skew` cycles late, all
    /// datapaths overlap, and the `active` stores serialize through the
    /// shared ports. Reduces to `compound_node_cycles` when
    /// `active == 1` and `store_ports == 1`.
    pub fn wave_cycles(&self, t: &TimingModel, n: usize, active: usize) -> u64 {
        if active == 0 {
            return 0;
        }
        let a = active.min(self.n_pes) as u64;
        (a - 1) * self.skew + t.datapath_pass(n) + (a * t.store_pass(n)).div_ceil(self.store_ports)
    }

    /// Cycles for one wave of heterogeneous per-section costs (records
    /// issue to PEs in order; uniform costs reduce to [`Self::wave_cycles`]).
    pub fn wave_cycles_records(&self, costs: &[SectionCost]) -> u64 {
        if costs.is_empty() {
            return 0;
        }
        let drain = costs
            .iter()
            .enumerate()
            .map(|(i, c)| i as u64 * self.skew + c.compute)
            .max()
            .unwrap_or(0);
        let stores: u64 = costs.iter().map(|c| c.store).sum();
        drain + stores.div_ceil(self.store_ports)
    }

    /// Total cycles to run `sections` uniform compound-node sections:
    /// full waves of `n_pes` plus one tail wave. `n_pes == 1` is exactly
    /// `sections * compound_node_cycles(n)`.
    pub fn batch_cycles(&self, t: &TimingModel, n: usize, sections: usize) -> u64 {
        let p = self.n_pes.max(1);
        let full = sections / p;
        let tail = sections % p;
        full as u64 * self.wave_cycles(t, n, p) + self.wave_cycles(t, n, tail)
    }

    /// Fold heterogeneous section costs into total cycles (waves of
    /// `n_pes` in issue order).
    pub fn batch_cycles_records(&self, costs: &[SectionCost]) -> u64 {
        let p = self.n_pes.max(1);
        costs.chunks(p).map(|wave| self.wave_cycles_records(wave)).sum()
    }

    /// Perfect-parallelism floor: no schedule beats `compound / n_pes`
    /// cycles per update.
    pub fn per_update_floor(&self, t: &TimingModel, n: usize) -> f64 {
        t.compound_node_cycles(n) as f64 / self.n_pes.max(1) as f64
    }

    /// Store-port contention ceiling: each update moves one slot through
    /// the shared ports, so per-update cycles can never drop below
    /// `store_pass / store_ports`.
    pub fn store_floor(&self, t: &TimingModel, n: usize) -> f64 {
        t.store_pass(n) as f64 / self.store_ports as f64
    }
}

/// Which register plane a result landed in (§II accumulator chaining).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Plane {
    /// StateReg bank written by `mma` (accum mode).
    Accum,
    /// StateReg bank written by `mms` (shift mode) and `fad`.
    Shift,
}

/// The systolic array: SoA value planes + timing.
#[derive(Clone, Debug)]
pub struct SystolicArray {
    /// Matrix dimension the array is configured for.
    pub n: usize,
    /// Datapath fixed-point format.
    pub fmt: QFormat,
    /// Per-operation cycle model.
    pub timing: TimingModel,
    /// Matrix planes (row-major n x n).
    pub accum: CPlanes,
    /// Shift plane (operand staging), row-major n x n.
    pub shift: CPlanes,
    /// Mean-pipeline planes (n).
    pub vaccum: CPlanes,
    /// Mean-pipeline shift plane (n).
    pub vshift: CPlanes,
    /// Last-written planes (what `smm` commits).
    pub last_mat: Plane,
    /// Last-written mean plane (what `smm` commits).
    pub last_vec: Plane,
    /// Reusable output/working buffers (perf: zero steady-state alloc).
    scratch_mat: CPlanes,
    scratch_vec: CPlanes,
    scratch_w: CPlanes,
}

/// A matrix operand streamed into the array (already transposed/negated
/// by the Transpose/Select units if requested).
#[derive(Clone, Copy)]
pub struct MatOperand<'a> {
    /// Operand planes, row-major n x n.
    pub data: PlaneRef<'a>,
    /// Read through the Transpose unit (Hermitian transpose).
    pub herm: bool,
}

impl SystolicArray {
    /// An array of dimension `n` with zeroed planes.
    pub fn new(n: usize, fmt: QFormat, timing: TimingModel) -> Self {
        SystolicArray {
            n,
            fmt,
            timing,
            accum: CPlanes::zeroed(n * n),
            shift: CPlanes::zeroed(n * n),
            vaccum: CPlanes::zeroed(n),
            vshift: CPlanes::zeroed(n),
            last_mat: Plane::Accum,
            last_vec: Plane::Accum,
            scratch_mat: CPlanes::zeroed(n * n),
            scratch_vec: CPlanes::zeroed(n),
            scratch_w: CPlanes::zeroed(2 * n * (2 * n + 1)),
        }
    }

    fn rails(&self) -> Rails {
        Rails::of(self.fmt)
    }

    /// `mma` (matrix): accum = (∓) opA * opB. Returns cycles.
    pub fn mma_matrix(&mut self, a: MatOperand, b: MatOperand, neg: bool) -> u64 {
        let n = self.n;
        kernels::mat_mul(n, self.rails(), a.data, a.herm, b.data, b.herm, None, neg, &mut self.scratch_mat);
        std::mem::swap(&mut self.accum, &mut self.scratch_mat);
        self.last_mat = Plane::Accum;
        self.timing.matrix_pass(n)
    }

    /// `mma` (mean pipeline): vaccum = (∓) opA * vec.
    pub fn mma_vector(&mut self, a: MatOperand, vec: PlaneRef, neg: bool) -> u64 {
        let n = self.n;
        kernels::mat_vec(n, self.rails(), a.data, a.herm, vec, None, neg, &mut self.scratch_vec);
        std::mem::swap(&mut self.vaccum, &mut self.scratch_vec);
        self.last_vec = Plane::Accum;
        self.timing.vector_pass(n)
    }

    /// `mms` (matrix): shift = (∓ addend) + opA * opB.
    pub fn mms_matrix(&mut self, a: MatOperand, b: MatOperand, addend: PlaneRef, neg: bool) -> u64 {
        let n = self.n;
        kernels::mat_mul(
            n,
            self.rails(),
            a.data,
            a.herm,
            b.data,
            b.herm,
            Some(addend),
            neg,
            &mut self.scratch_mat,
        );
        std::mem::swap(&mut self.shift, &mut self.scratch_mat);
        self.last_mat = Plane::Shift;
        self.timing.matrix_pass(n)
    }

    /// `mms` (mean pipeline): vshift = (∓ addend) + opA * vec.
    pub fn mms_vector(&mut self, a: MatOperand, vec: PlaneRef, addend: PlaneRef, neg: bool) -> u64 {
        let n = self.n;
        kernels::mat_vec(n, self.rails(), a.data, a.herm, vec, Some(addend), neg, &mut self.scratch_vec);
        std::mem::swap(&mut self.vshift, &mut self.scratch_vec);
        self.last_vec = Plane::Shift;
        self.timing.vector_pass(n)
    }

    /// `fad`: Faddeev elimination over the doubled working set
    ///
    /// ```text
    ///   [[ G (n x n),  B (n x n), y (n) ],
    ///    [ C (n x n),  D (n x n), x (n) ]]
    /// ```
    ///
    /// Triangularizes the G-block columns with **partial pivoting** (row
    /// swaps among the G rows — the PEmult swap mode), eliminating all
    /// rows below each pivot; the Schur complement `D - C G^{-1} B` lands
    /// in the shift plane and `x - C G^{-1} y` in the vshift plane.
    /// Divisions run through the PEborder's radix-2 divider model.
    #[allow(clippy::too_many_arguments)]
    pub fn faddeev(
        &mut self,
        g: PlaneRef,
        b: MatOperand,
        c: PlaneRef,
        d: PlaneRef,
        y: PlaneRef,
        x: PlaneRef,
    ) -> u64 {
        let n = self.n;
        let r = self.rails();
        let mut w = std::mem::take(&mut self.scratch_w);
        kernels::faddeev(
            n,
            r,
            g,
            b.data,
            b.herm,
            c,
            d,
            y,
            x,
            &mut w,
            &mut self.shift,
            &mut self.vshift,
        );
        self.scratch_w = w;
        self.last_mat = Plane::Shift;
        self.last_vec = Plane::Shift;
        self.timing.faddeev_pass(n)
    }

    /// The matrix plane `smm` would store.
    pub fn result_matrix(&self) -> PlaneRef<'_> {
        match self.last_mat {
            Plane::Accum => self.accum.as_ref(),
            Plane::Shift => self.shift.as_ref(),
        }
    }

    /// The mean plane `smm` would store.
    pub fn result_vector(&self) -> PlaneRef<'_> {
        match self.last_vec {
            Plane::Accum => self.vaccum.as_ref(),
            Plane::Shift => self.vshift.as_ref(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmp::matrix::{c64, CMatrix};
    use crate::testutil::{proptest_cases, Rng};

    const FMT: QFormat = QFormat::q5_10();

    fn to_planes(m: &CMatrix) -> CPlanes {
        let mut v = Vec::new();
        for i in 0..m.rows {
            for j in 0..m.cols {
                v.push(CFix::from_f64(m[(i, j)].re, m[(i, j)].im, FMT));
            }
        }
        CPlanes::from_cfix(&v)
    }

    fn from_planes(p: PlaneRef, n: usize) -> CMatrix {
        let mut m = CMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let z = CFix {
                    re: crate::fixed::Fix { raw: p.re[i * n + j], fmt: FMT },
                    im: crate::fixed::Fix { raw: p.im[i * n + j], fmt: FMT },
                };
                let (re, im) = z.to_c64();
                m[(i, j)] = c64::new(re, im);
            }
        }
        m
    }

    fn array(n: usize) -> SystolicArray {
        SystolicArray::new(n, FMT, TimingModel::default())
    }

    #[test]
    fn mma_matches_golden_matmul() {
        proptest_cases(40, |rng| {
            let n = 4;
            let a = CMatrix::random(rng, n, n).scale(0.5);
            let b = CMatrix::random(rng, n, n).scale(0.5);
            let mut arr = array(n);
            let fa = to_planes(&a);
            let fb = to_planes(&b);
            let cycles = arr.mma_matrix(
                MatOperand { data: fa.as_ref(), herm: false },
                MatOperand { data: fb.as_ref(), herm: false },
                false,
            );
            assert_eq!(cycles, 22); // 4*4 + 2*3
            let got = from_planes(arr.accum.as_ref(), n);
            let want = a.matmul(&b);
            assert!(got.dist(&want) < 0.1, "dist {}", got.dist(&want));
        });
    }

    #[test]
    fn mma_hermitian_flag() {
        let mut rng = Rng::new(5);
        let n = 4;
        let a = CMatrix::random(&mut rng, n, n).scale(0.5);
        let b = CMatrix::random(&mut rng, n, n).scale(0.5);
        let mut arr = array(n);
        let fa = to_planes(&a);
        let fb = to_planes(&b);
        arr.mma_matrix(
            MatOperand { data: fa.as_ref(), herm: false },
            MatOperand { data: fb.as_ref(), herm: true },
            false,
        );
        let got = from_planes(arr.accum.as_ref(), n);
        let want = a.matmul(&b.hermitian());
        assert!(got.dist(&want) < 0.1);
    }

    #[test]
    fn mms_negates_addend_not_product() {
        let mut rng = Rng::new(6);
        let n = 4;
        let a = CMatrix::random(&mut rng, n, n).scale(0.4);
        let b = CMatrix::random(&mut rng, n, n).scale(0.4);
        let cmat = CMatrix::random(&mut rng, n, n).scale(0.4);
        let mut arr = array(n);
        let (fa, fb, fc) = (to_planes(&a), to_planes(&b), to_planes(&cmat));
        arr.mms_matrix(
            MatOperand { data: fa.as_ref(), herm: false },
            MatOperand { data: fb.as_ref(), herm: false },
            fc.as_ref(),
            true,
        );
        let got = from_planes(arr.shift.as_ref(), n);
        let want = a.matmul(&b).sub(&cmat);
        assert!(got.dist(&want) < 0.1, "dist {}", got.dist(&want));
    }

    #[test]
    fn faddeev_matches_golden_schur() {
        proptest_cases(30, |rng| {
            let n = 4;
            // well-scaled PD g keeps fixed point accurate
            let g = CMatrix::random_psd(rng, n, 1.0).scale(0.15);
            let b = CMatrix::random(rng, n, n).scale(0.4);
            let c = CMatrix::random(rng, n, n).scale(0.4);
            let d = CMatrix::random(rng, n, n).scale(0.4);
            let mut arr = array(n);
            let (fg, fb, fc, fd) = (to_planes(&g), to_planes(&b), to_planes(&c), to_planes(&d));
            let zero = CPlanes::zeroed(n);
            let cycles = arr.faddeev(
                fg.as_ref(),
                MatOperand { data: fb.as_ref(), herm: false },
                fc.as_ref(),
                fd.as_ref(),
                zero.as_ref(),
                zero.as_ref(),
            );
            assert!(cycles > 0);
            let got = from_planes(arr.shift.as_ref(), n);
            let want = CMatrix::schur_direct(&g, &b, &c, &d).unwrap();
            assert!(got.dist(&want) < 0.35, "dist {}", got.dist(&want));
        });
    }

    #[test]
    fn faddeev_needs_pivoting_on_zero_leading_entry() {
        // g with a zero top-left entry but PD-after-permutation structure:
        // without row swaps the first division would blow up.
        let n = 2;
        let mut g = CMatrix::zeros(2, 2);
        g[(0, 1)] = c64::new(1.0, 0.0);
        g[(1, 0)] = c64::new(1.0, 0.0);
        let b = CMatrix::identity(2);
        let c = CMatrix::identity(2);
        let d = CMatrix::zeros(2, 2);
        let mut arr = array(n);
        let (fg, fb, fc, fd) = (to_planes(&g), to_planes(&b), to_planes(&c), to_planes(&d));
        let zero = CPlanes::zeroed(n);
        arr.faddeev(
            fg.as_ref(),
            MatOperand { data: fb.as_ref(), herm: false },
            fc.as_ref(),
            fd.as_ref(),
            zero.as_ref(),
            zero.as_ref(),
        );
        let got = from_planes(arr.shift.as_ref(), n);
        // D - C g^{-1} B = -g^{-1} = -[[0,1],[1,0]]
        assert!((got[(0, 1)].re + 1.0).abs() < 0.01, "{got}");
        assert!((got[(1, 0)].re + 1.0).abs() < 0.01, "{got}");
    }

    #[test]
    fn compound_node_cycle_count_matches_paper_exactly() {
        let t = TimingModel::default();
        assert_eq!(
            t.compound_node_cycles(4),
            crate::paper::FGP_CN_CYCLES,
            "n=4 CN update must be the paper's Table II 260 cycles"
        );
    }

    #[test]
    fn cycle_counts_scale_with_n() {
        let t = TimingModel::default();
        let mut prev = 0;
        for n in [2usize, 4, 6, 8] {
            let c = t.compound_node_cycles(n);
            assert!(c > prev, "cycles must grow with n");
            prev = c;
        }
    }

    #[test]
    fn planes_track_last_writer() {
        let mut arr = array(2);
        let id = to_planes(&CMatrix::identity(2));
        arr.mma_matrix(
            MatOperand { data: id.as_ref(), herm: false },
            MatOperand { data: id.as_ref(), herm: false },
            false,
        );
        assert_eq!(arr.last_mat, Plane::Accum);
        let z = CPlanes::zeroed(4);
        arr.mms_matrix(
            MatOperand { data: id.as_ref(), herm: false },
            MatOperand { data: id.as_ref(), herm: false },
            z.as_ref(),
            false,
        );
        assert_eq!(arr.last_mat, Plane::Shift);
    }

    // ---- multi-PE model (ISSUE 9 satellite) ----

    #[test]
    fn multi_pe_single_pe_reproduces_paper_cycles_exactly() {
        let t = TimingModel::default();
        let m = MultiPeModel::default();
        assert_eq!(m.n_pes, 1);
        assert_eq!(m.wave_cycles(&t, 4, 1), crate::paper::FGP_CN_CYCLES);
        for sections in [1usize, 7, 64, 1024] {
            assert_eq!(
                m.batch_cycles(&t, 4, sections),
                sections as u64 * t.compound_node_cycles(4),
                "n_pes=1 must be exactly sections x 260"
            );
        }
    }

    #[test]
    fn multi_pe_per_update_monotone_non_increasing() {
        let t = TimingModel::default();
        let sections = 1024;
        let mut prev = f64::INFINITY;
        for p in [1usize, 2, 4, 8, 16] {
            let m = MultiPeModel::with_pes(p);
            let per = m.batch_cycles(&t, 4, sections) as f64 / sections as f64;
            assert!(
                per <= prev + 1e-9,
                "per-update cycles must not increase with PEs: P={p} gives {per} > {prev}"
            );
            prev = per;
        }
    }

    #[test]
    fn multi_pe_respects_floor_and_store_ceiling() {
        let t = TimingModel::default();
        let sections = 1024;
        for p in [1usize, 2, 4, 8, 32, 128] {
            let m = MultiPeModel::with_pes(p);
            let per = m.batch_cycles(&t, 4, sections) as f64 / sections as f64;
            assert!(
                per + 1e-9 >= m.per_update_floor(&t, 4),
                "P={p}: {per} beats the perfect-parallelism floor"
            );
            assert!(
                per + 1e-9 >= m.store_floor(&t, 4),
                "P={p}: {per} beats the shared store-port ceiling"
            );
        }
        // With enough PEs the shared store port becomes the binding
        // constraint: the model must saturate at it, not scale past it.
        let big = MultiPeModel::with_pes(128);
        let per = big.batch_cycles(&t, 4, sections) as f64 / sections as f64;
        assert!(per < 2.0 * big.store_floor(&t, 4), "store port must bind at high P, got {per}");
    }

    #[test]
    fn multi_pe_heterogeneous_fold_matches_uniform_closed_form() {
        let t = TimingModel::default();
        for p in [1usize, 2, 4, 8] {
            let m = MultiPeModel::with_pes(p);
            let cost = SectionCost {
                compute: t.datapath_pass(4),
                store: t.store_pass(4),
            };
            for sections in [1usize, 3, 8, 17] {
                let costs = vec![cost; sections];
                assert_eq!(
                    m.batch_cycles_records(&costs),
                    m.batch_cycles(&t, 4, sections),
                    "uniform records must reduce to the closed form (P={p}, s={sections})"
                );
            }
        }
    }
}
