//! The configurable systolic array (paper §II, Figs. 3–5).
//!
//! An n x n rectangular grid of `PEmult` cells plus a triangular
//! `PEborder` extension. Values are computed bit-accurately in fixed
//! point; cycle counts come from the wavefront timing model below.
//!
//! # Timing model
//!
//! Fixed by the paper:
//! * a complex multiply occupies one `PEmult` for **4 cycles** (one real
//!   multiplier + one real adder, Fig. 3); the adder is free in 2 of the
//!   4 cycles, which is what lets `mms` fold its addition in at no cost;
//! * the `PEborder` divider is a sequential radix-2 unit producing a
//!   16-bit quotient in **4 cycles** (footnote 2); a complex division
//!   needs |den|² (2 mults + add), 4 numerator mults, and two sequential
//!   real divisions on the single divider: 2 + 2 + 2x4 = 12 cycles;
//! * operands stream in skewed one cycle per row/column hop.
//!
//! Derived per-instruction counts (n = array size):
//!
//! * `mma`/`mms` (matrix): PE(i,j) executes its k-th MAC in cycles
//!   `4k+i+j .. 4k+i+j+3`, so the array drains at `4n + 2(n-1)` cycles.
//! * `mma`/`mms` (mean pipeline): one column of PEs, `4n + (n-1)`.
//! * `fad`: n pivot steps over the doubled (2n x 2n+1) working set.
//!   Pivot step k: pivot search on the border (`pivot_select`), one
//!   complex division pipeline (latency `div_latency`, overlapped across
//!   rows), then the row-update wavefront: `2n-1-k` rows, each needing
//!   `ceil((2n+1-k)/n)` column passes of 4 cycles, with `rows_in_flight`
//!   rows pipelined through the grid concurrently.
//! * `smm`: the store port moves `port_words` complex words per cycle.
//!
//! With the default parameters the n=4 compound-node update measures
//! ~260 cycles — the paper's Table II number (see EXPERIMENTS.md E1 for
//! the exact measured value).

use crate::fixed::{CFix, QFormat, Radix2Divider};

/// Array timing parameters (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct TimingModel {
    /// Cycles per complex multiply on a PEmult (paper: 4).
    pub cmul: u64,
    /// Latency of a complex division on the PEborder (derived: 12).
    pub div_latency: u64,
    /// Border cycles to select a pivot row (abs-compare wavefront).
    pub pivot_select: u64,
    /// Rows concurrently in flight through the elimination wavefront.
    pub rows_in_flight: u64,
    /// Complex words per cycle through the store port.
    pub port_words: u64,
    /// Instruction fetch+decode cycles.
    pub fetch: u64,
}

impl Default for TimingModel {
    fn default() -> Self {
        TimingModel {
            cmul: CFix::MUL_CYCLES,
            div_latency: 2 + 2 + 2 * Radix2Divider::default_latency(),
            pivot_select: 2,
            rows_in_flight: 2,
            port_words: 2,
            fetch: 1,
        }
    }
}

impl TimingModel {
    /// Cycles for an n x n matrix `mma`/`mms` pass.
    pub fn matrix_pass(&self, n: usize) -> u64 {
        self.cmul * n as u64 + 2 * (n as u64 - 1)
    }

    /// Cycles for a mean-pipeline (vector) pass.
    pub fn vector_pass(&self, n: usize) -> u64 {
        self.cmul * n as u64 + (n as u64 - 1)
    }

    /// Cycles for the Faddeev pass over the doubled matrix (n pivots).
    pub fn faddeev_pass(&self, n: usize) -> u64 {
        let n = n as u64;
        let mut total = 0;
        for k in 0..n {
            let rows = 2 * n - 1 - k; // rows below the pivot
            let cols = 2 * n + 1 - k; // active columns incl. mean column
            let passes_per_row = cols.div_ceil(n);
            let update = (rows * passes_per_row * self.cmul).div_ceil(self.rows_in_flight);
            total += self.pivot_select + self.div_latency + update;
        }
        // final drain of the wavefront through the grid
        total + 2 * n + 1
    }

    /// Cycles for `smm` (store n x n matrix + n mean words).
    pub fn store_pass(&self, n: usize) -> u64 {
        ((n * n + n) as u64).div_ceil(self.port_words)
    }

    /// Cycles for the benchmark compound-node update (fetch + 4 datapath
    /// + store) — the quantity Table II reports.
    pub fn compound_node_cycles(&self, n: usize) -> u64 {
        5 * self.fetch
            + self.matrix_pass(n)            // mma: T1
            + self.matrix_pass(n)            // mms: G
            + self.vector_pass(n)            // mms v: innovation
            + self.faddeev_pass(n)           // fad
            + self.store_pass(n) // smm
    }
}

/// Which register plane a result landed in (§II accumulator chaining).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Plane {
    /// StateReg bank written by `mma` (accum mode).
    Accum,
    /// StateReg bank written by `mms` (shift mode) and `fad`.
    Shift,
}

/// The systolic array: value planes + timing.
#[derive(Clone, Debug)]
pub struct SystolicArray {
    /// Matrix dimension the array is configured for.
    pub n: usize,
    /// Datapath fixed-point format.
    pub fmt: QFormat,
    /// Per-operation cycle model.
    pub timing: TimingModel,
    /// Matrix planes (row-major n x n).
    pub accum: Vec<CFix>,
    /// Shift plane (operand staging), row-major n x n.
    pub shift: Vec<CFix>,
    /// Mean-pipeline planes (n).
    pub vaccum: Vec<CFix>,
    /// Mean-pipeline shift plane (n).
    pub vshift: Vec<CFix>,
    /// Last-written planes (what `smm` commits).
    pub last_mat: Plane,
    /// Last-written mean plane (what `smm` commits).
    pub last_vec: Plane,
    /// Reusable output/working buffers (perf: zero steady-state alloc).
    scratch_mat: Vec<CFix>,
    scratch_vec: Vec<CFix>,
    scratch_w: Vec<CFix>,
}

/// A matrix operand streamed into the array (already transposed/negated
/// by the Transpose/Select units if requested).
pub struct MatOperand<'a> {
    /// Operand values, row-major n x n.
    pub data: &'a [CFix],
    /// Read through the Transpose unit (Hermitian transpose).
    pub herm: bool,
}

impl SystolicArray {
    /// An array of dimension `n` with zeroed planes.
    pub fn new(n: usize, fmt: QFormat, timing: TimingModel) -> Self {
        SystolicArray {
            n,
            fmt,
            timing,
            accum: vec![CFix::zero(fmt); n * n],
            shift: vec![CFix::zero(fmt); n * n],
            vaccum: vec![CFix::zero(fmt); n],
            vshift: vec![CFix::zero(fmt); n],
            last_mat: Plane::Accum,
            last_vec: Plane::Accum,
            scratch_mat: vec![CFix::zero(fmt); n * n],
            scratch_vec: vec![CFix::zero(fmt); n],
            scratch_w: vec![CFix::zero(fmt); 2 * n * (2 * n + 1)],
        }
    }

    fn at(data: &[CFix], n: usize, i: usize, j: usize, herm: bool) -> CFix {
        if herm {
            data[j * n + i].conj()
        } else {
            data[i * n + j]
        }
    }

    /// `mma` (matrix): accum = (∓) opA * opB. Returns cycles.
    pub fn mma_matrix(&mut self, a: MatOperand, b: MatOperand, neg: bool) -> u64 {
        let n = self.n;
        for i in 0..n {
            for j in 0..n {
                let mut acc = CFix::zero(self.fmt);
                for k in 0..n {
                    let prod = Self::at(a.data, n, i, k, a.herm)
                        .mul(Self::at(b.data, n, k, j, b.herm));
                    acc = acc.add(prod);
                }
                self.scratch_mat[i * n + j] = if neg { acc.neg() } else { acc };
            }
        }
        std::mem::swap(&mut self.accum, &mut self.scratch_mat);
        self.last_mat = Plane::Accum;
        self.timing.matrix_pass(n)
    }

    /// `mma` (mean pipeline): vaccum = (∓) opA * vec.
    pub fn mma_vector(&mut self, a: MatOperand, vec: &[CFix], neg: bool) -> u64 {
        let n = self.n;
        for i in 0..n {
            let mut acc = CFix::zero(self.fmt);
            for k in 0..n {
                acc = acc.add(Self::at(a.data, n, i, k, a.herm).mul(vec[k]));
            }
            self.scratch_vec[i] = if neg { acc.neg() } else { acc };
        }
        std::mem::swap(&mut self.vaccum, &mut self.scratch_vec);
        self.last_vec = Plane::Accum;
        self.timing.vector_pass(n)
    }

    /// `mms` (matrix): shift = (∓ addend) + opA * opB.
    pub fn mms_matrix(&mut self, a: MatOperand, b: MatOperand, addend: &[CFix], neg: bool) -> u64 {
        let n = self.n;
        for i in 0..n {
            for j in 0..n {
                let mut acc = addend[i * n + j];
                if neg {
                    acc = acc.neg();
                }
                for k in 0..n {
                    acc = acc.add(
                        Self::at(a.data, n, i, k, a.herm).mul(Self::at(b.data, n, k, j, b.herm)),
                    );
                }
                self.scratch_mat[i * n + j] = acc;
            }
        }
        std::mem::swap(&mut self.shift, &mut self.scratch_mat);
        self.last_mat = Plane::Shift;
        self.timing.matrix_pass(n)
    }

    /// `mms` (mean pipeline): vshift = (∓ addend) + opA * vec.
    pub fn mms_vector(&mut self, a: MatOperand, vec: &[CFix], addend: &[CFix], neg: bool) -> u64 {
        let n = self.n;
        for i in 0..n {
            let mut acc = addend[i];
            if neg {
                acc = acc.neg();
            }
            for k in 0..n {
                acc = acc.add(Self::at(a.data, n, i, k, a.herm).mul(vec[k]));
            }
            self.scratch_vec[i] = acc;
        }
        std::mem::swap(&mut self.vshift, &mut self.scratch_vec);
        self.last_vec = Plane::Shift;
        self.timing.vector_pass(n)
    }

    /// `fad`: Faddeev elimination over the doubled working set
    ///
    /// ```text
    ///   [[ G (n x n),  B (n x n), y (n) ],
    ///    [ C (n x n),  D (n x n), x (n) ]]
    /// ```
    ///
    /// Triangularizes the G-block columns with **partial pivoting** (row
    /// swaps among the G rows — the PEmult swap mode), eliminating all
    /// rows below each pivot; the Schur complement `D - C G^{-1} B` lands
    /// in the shift plane and `x - C G^{-1} y` in the vshift plane.
    /// Divisions run through the PEborder's radix-2 divider model.
    #[allow(clippy::too_many_arguments)]
    pub fn faddeev(
        &mut self,
        g: &[CFix],
        b: MatOperand,
        c: &[CFix],
        d: &[CFix],
        y: &[CFix],
        x: &[CFix],
    ) -> u64 {
        let n = self.n;
        let rows = 2 * n;
        let cols = 2 * n + 1;
        let mut w = std::mem::take(&mut self.scratch_w);
        w.resize(rows * cols, CFix::zero(self.fmt));
        for i in 0..n {
            for j in 0..n {
                w[i * cols + j] = g[i * n + j];
                w[i * cols + n + j] = Self::at(b.data, n, i, j, b.herm);
                w[(n + i) * cols + j] = c[i * n + j];
                w[(n + i) * cols + n + j] = d[i * n + j];
            }
            w[i * cols + 2 * n] = y[i];
            w[(n + i) * cols + 2 * n] = x[i];
        }

        for k in 0..n {
            // PEborder pivot search: max |.|^2 among remaining G rows.
            let mut piv = k;
            let mut pmax = w[k * cols + k].abs2();
            for i in k + 1..n {
                let v = w[i * cols + k].abs2();
                if v.raw > pmax.raw {
                    piv = i;
                    pmax = v;
                }
            }
            if piv != k {
                // PEmult swap mode: exchange the two rows.
                for j in 0..cols {
                    w.swap(k * cols + j, piv * cols + j);
                }
            }
            let pivot = w[k * cols + k];
            // Eliminate every row below the pivot (including the D rows).
            for i in k + 1..rows {
                let lead = w[i * cols + k];
                if lead.is_zero() {
                    continue;
                }
                let f = lead.div(pivot); // PEborder complex division
                for j in k..cols {
                    let sub = f.mul(w[k * cols + j]);
                    w[i * cols + j] = w[i * cols + j].sub(sub);
                }
            }
        }

        for i in 0..n {
            for j in 0..n {
                self.shift[i * n + j] = w[(n + i) * cols + n + j];
            }
            self.vshift[i] = w[(n + i) * cols + 2 * n];
        }
        self.scratch_w = w;
        self.last_mat = Plane::Shift;
        self.last_vec = Plane::Shift;
        self.timing.faddeev_pass(n)
    }

    /// The matrix plane `smm` would store.
    pub fn result_matrix(&self) -> &[CFix] {
        match self.last_mat {
            Plane::Accum => &self.accum,
            Plane::Shift => &self.shift,
        }
    }

    /// The mean plane `smm` would store.
    pub fn result_vector(&self) -> &[CFix] {
        match self.last_vec {
            Plane::Accum => &self.vaccum,
            Plane::Shift => &self.vshift,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmp::matrix::{c64, CMatrix};
    use crate::testutil::{proptest_cases, Rng};

    const FMT: QFormat = QFormat::q5_10();

    fn to_fix(m: &CMatrix) -> Vec<CFix> {
        let mut v = Vec::new();
        for i in 0..m.rows {
            for j in 0..m.cols {
                v.push(CFix::from_f64(m[(i, j)].re, m[(i, j)].im, FMT));
            }
        }
        v
    }

    fn from_fix(v: &[CFix], n: usize) -> CMatrix {
        let mut m = CMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let (re, im) = v[i * n + j].to_c64();
                m[(i, j)] = c64::new(re, im);
            }
        }
        m
    }

    fn array(n: usize) -> SystolicArray {
        SystolicArray::new(n, FMT, TimingModel::default())
    }

    #[test]
    fn mma_matches_golden_matmul() {
        proptest_cases(40, |rng| {
            let n = 4;
            let a = CMatrix::random(rng, n, n).scale(0.5);
            let b = CMatrix::random(rng, n, n).scale(0.5);
            let mut arr = array(n);
            let fa = to_fix(&a);
            let fb = to_fix(&b);
            let cycles = arr.mma_matrix(
                MatOperand { data: &fa, herm: false },
                MatOperand { data: &fb, herm: false },
                false,
            );
            assert_eq!(cycles, 22); // 4*4 + 2*3
            let got = from_fix(&arr.accum, n);
            let want = a.matmul(&b);
            assert!(got.dist(&want) < 0.1, "dist {}", got.dist(&want));
        });
    }

    #[test]
    fn mma_hermitian_flag() {
        let mut rng = Rng::new(5);
        let n = 4;
        let a = CMatrix::random(&mut rng, n, n).scale(0.5);
        let b = CMatrix::random(&mut rng, n, n).scale(0.5);
        let mut arr = array(n);
        let fa = to_fix(&a);
        let fb = to_fix(&b);
        arr.mma_matrix(
            MatOperand { data: &fa, herm: false },
            MatOperand { data: &fb, herm: true },
            false,
        );
        let got = from_fix(&arr.accum, n);
        let want = a.matmul(&b.hermitian());
        assert!(got.dist(&want) < 0.1);
    }

    #[test]
    fn mms_negates_addend_not_product() {
        let mut rng = Rng::new(6);
        let n = 4;
        let a = CMatrix::random(&mut rng, n, n).scale(0.4);
        let b = CMatrix::random(&mut rng, n, n).scale(0.4);
        let cmat = CMatrix::random(&mut rng, n, n).scale(0.4);
        let mut arr = array(n);
        let (fa, fb, fc) = (to_fix(&a), to_fix(&b), to_fix(&cmat));
        arr.mms_matrix(
            MatOperand { data: &fa, herm: false },
            MatOperand { data: &fb, herm: false },
            &fc,
            true,
        );
        let got = from_fix(&arr.shift, n);
        let want = a.matmul(&b).sub(&cmat);
        assert!(got.dist(&want) < 0.1, "dist {}", got.dist(&want));
    }

    #[test]
    fn faddeev_matches_golden_schur() {
        proptest_cases(30, |rng| {
            let n = 4;
            // well-scaled PD g keeps fixed point accurate
            let g = CMatrix::random_psd(rng, n, 1.0).scale(0.15);
            let b = CMatrix::random(rng, n, n).scale(0.4);
            let c = CMatrix::random(rng, n, n).scale(0.4);
            let d = CMatrix::random(rng, n, n).scale(0.4);
            let mut arr = array(n);
            let (fg, fb, fc, fd) = (to_fix(&g), to_fix(&b), to_fix(&c), to_fix(&d));
            let zero = vec![CFix::zero(FMT); n];
            let cycles = arr.faddeev(
                &fg,
                MatOperand { data: &fb, herm: false },
                &fc,
                &fd,
                &zero,
                &zero,
            );
            assert!(cycles > 0);
            let got = from_fix(&arr.shift, n);
            let want = CMatrix::schur_direct(&g, &b, &c, &d).unwrap();
            assert!(got.dist(&want) < 0.35, "dist {}", got.dist(&want));
        });
    }

    #[test]
    fn faddeev_needs_pivoting_on_zero_leading_entry() {
        // g with a zero top-left entry but PD-after-permutation structure:
        // without row swaps the first division would blow up.
        let n = 2;
        let mut g = CMatrix::zeros(2, 2);
        g[(0, 1)] = c64::new(1.0, 0.0);
        g[(1, 0)] = c64::new(1.0, 0.0);
        let b = CMatrix::identity(2);
        let c = CMatrix::identity(2);
        let d = CMatrix::zeros(2, 2);
        let mut arr = array(n);
        let (fg, fb, fc, fd) = (to_fix(&g), to_fix(&b), to_fix(&c), to_fix(&d));
        let zero = vec![CFix::zero(FMT); n];
        arr.faddeev(&fg, MatOperand { data: &fb, herm: false }, &fc, &fd, &zero, &zero);
        let got = from_fix(&arr.shift, n);
        // D - C g^{-1} B = -g^{-1} = -[[0,1],[1,0]]
        assert!((got[(0, 1)].re + 1.0).abs() < 0.01, "{got}");
        assert!((got[(1, 0)].re + 1.0).abs() < 0.01, "{got}");
    }

    #[test]
    fn compound_node_cycle_count_near_paper() {
        let t = TimingModel::default();
        let cycles = t.compound_node_cycles(4);
        let paper = crate::paper::FGP_CN_CYCLES as f64;
        let rel = (cycles as f64 - paper).abs() / paper;
        assert!(
            rel < 0.10,
            "CN cycles {cycles} should be within 10% of the paper's 260"
        );
    }

    #[test]
    fn cycle_counts_scale_with_n() {
        let t = TimingModel::default();
        let mut prev = 0;
        for n in [2usize, 4, 6, 8] {
            let c = t.compound_node_cycles(n);
            assert!(c > prev, "cycles must grow with n");
            prev = c;
        }
    }

    #[test]
    fn planes_track_last_writer() {
        let mut arr = array(2);
        let id = to_fix(&CMatrix::identity(2));
        arr.mma_matrix(
            MatOperand { data: &id, herm: false },
            MatOperand { data: &id, herm: false },
            false,
        );
        assert_eq!(arr.last_mat, Plane::Accum);
        let z = vec![CFix::zero(FMT); 4];
        arr.mms_matrix(
            MatOperand { data: &id, herm: false },
            MatOperand { data: &id, herm: false },
            &z,
            false,
        );
        assert_eq!(arr.last_mat, Plane::Shift);
    }
}
