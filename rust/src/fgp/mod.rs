//! S5 — The FGP: a cycle-accurate simulator of the paper's processor.
//!
//! Substitutes for the UMC180 silicon (see DESIGN.md). The model is
//! *bit-accurate* in value (every arithmetic operation goes through the
//! [`crate::fixed`] fixed-point types, including the sequential radix-2
//! divider and the saturation behaviour) and *cycle-accurate* at the
//! wavefront level (per-instruction cycle counts derive from the systolic
//! dataflow of §II with the paper's fixed latencies: 4-cycle complex
//! multiply on one real multiplier per PEmult, 4-cycle radix-2 divider in
//! the PEborder; see [`array::TimingModel`]).
//!
//! Structure mirrors Fig. 5:
//! * [`mem`] — program memory, message memory, state memory;
//! * [`array`] — the systolic array (rectangular PEmult grid + triangular
//!   PEborder extension) with its accumulate/shift planes;
//! * [`processor`] — instruction fetch/decode, the FSM, the command
//!   interface (`load_program` / `start_program` / status replies) and the
//!   Data-in/out ports.
//!
//! Since PR 9 both memories and the array's register planes store values
//! **struct-of-arrays** (contiguous raw re/im planes, [`SlotBank`] /
//! [`crate::kernels::CPlanes`]) and the per-instruction arithmetic runs
//! through the shape-specialized kernels in [`crate::kernels`]; a
//! [`MultiPeModel`] scales the cycle model out to N processing
//! elements. Both are performance knobs only — outputs are bit-identical
//! to the seed AoS single-PE interpreter at every layout and PE count
//! (`rust/tests/property_kernels.rs`).
//!
//! # Input-scaling contract
//!
//! Like any 16-bit fixed-point signal chain, the device computes
//! accurately only for *block-scaled* operands: covariances ≲ 1 (well
//! conditioned, smallest eigenvalue ≫ 1 LSB), state-matrix entries ≲ 1,
//! means within ±1. Within that envelope the Q5.10 datapath tracks the
//! f64 golden rules to ~1e-2; outside it the Faddeev elimination's
//! intermediates can reach the saturation rails, exactly as the silicon
//! would. The host (`crate::coordinator` / `crate::apps`) owns the
//! scaling, the same division of labour the paper's §IV flow implies.

pub mod array;
pub mod mem;
pub mod processor;
pub mod trace;

pub use array::{MultiPeModel, SectionCost, SystolicArray, TimingModel};
pub use mem::{MessageMemory, MsgSlot, ProgramMemory, SlotBank, StateMemory};
pub use processor::{Fgp, FgpConfig, FgpError, ProtocolError, RunStats};
pub use trace::{Profiler, TraceRecord};
