//! The FGP processor: FSM, instruction issue, command interface (Fig. 5).
//!
//! "An instruction is fetched from the PM, decoded and forwarded to a
//! finite state machine which generates the necessary control signals for
//! the PEs as well as for the Transpose-, Select- and Mask-unit." The FSM
//! here executes one instruction at a time against the [`SystolicArray`],
//! accumulating the cycle count the silicon would take.
//!
//! ## Command interface (§III)
//!
//! "The FGP can be controlled from an external processor via a set of
//! commands. Each command gets replied by a status message." —
//! [`Command`]/[`Reply`] implement that contract; the L3 coordinator
//! (`crate::coordinator`) drives it, including streaming observations
//! into the message memory between sections (the Data-in port).
//!
//! ## Multi-PE mode (PR 9)
//!
//! [`FgpConfig::multi_pe`] scales the device out to P array instances
//! (see [`MultiPeModel`]): the FSM still executes sections sequentially
//! — so values, and therefore memory contents and outputs, are
//! **bit-identical at every P** — but cycle accounting folds the
//! per-section costs into cross-PE waves with issue skew and shared
//! store-port serialization. `n_pes = 1` is exactly the paper's
//! processor, cycle for cycle.

use crate::fixed::QFormat;
use crate::gmp::matrix::CMatrix;
use crate::gmp::message::GaussMessage;
use crate::isa::{Instr, IsaError, MemoryImage, OperandSrc, ACC};
use crate::kernels::{CPlanes, PlaneRef};

use super::array::{MatOperand, MultiPeModel, SectionCost, SystolicArray, TimingModel};
use super::mem::{MessageMemory, ProgramMemory, StateMemory};

/// Static configuration (the synthesis parameters of §V).
#[derive(Clone, Copy, Debug)]
pub struct FgpConfig {
    /// State-matrix size (paper: 4).
    pub n: usize,
    /// Fixed-point format (paper: 16-bit datapath).
    pub fmt: QFormat,
    /// Message-memory slots.
    pub msg_slots: usize,
    /// State-memory slots.
    pub state_slots: usize,
    /// Per-operation cycle model.
    pub timing: TimingModel,
    /// Multi-PE scaling model (default: 1 PE — the paper's processor).
    pub multi_pe: MultiPeModel,
}

impl Default for FgpConfig {
    fn default() -> Self {
        FgpConfig {
            n: crate::paper::N,
            fmt: QFormat::q5_10(),
            msg_slots: 48,
            state_slots: 16,
            timing: TimingModel::default(),
            multi_pe: MultiPeModel::default(),
        }
    }
}

impl FgpConfig {
    /// The default configuration scaled out to `n_pes` PE instances.
    pub fn with_pes(n_pes: usize) -> Self {
        FgpConfig { multi_pe: MultiPeModel::with_pes(n_pes), ..Default::default() }
    }
}

/// Errors the processor can raise.
#[derive(Debug, thiserror::Error)]
pub enum FgpError {
    /// Instruction decode failed.
    #[error("isa error: {0}")]
    Isa(#[from] IsaError),
    /// `start_program` named an id the PM directory lacks.
    #[error("no program with id {0} loaded")]
    NoSuchProgram(u8),
    /// A message/state slot address beyond the configured memory.
    #[error("slot {0} out of range")]
    BadSlot(u8),
    /// The datapath raised an arithmetic error mid-program.
    #[error("datapath error at PM[{addr}]: {msg}")]
    Datapath { addr: usize, msg: String },
    /// A command arrived while a program was running.
    #[error("processor is busy")]
    Busy,
}

/// FSM states (Fig. 5: "state transitions are triggered from external
/// commands").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsmState {
    /// Awaiting commands.
    Idle,
    /// Executing a program.
    Running,
    /// Program finished; results readable.
    Done,
}

/// External-processor commands (§III).
///
/// `PartialEq` is exact (bit-level on message payloads): it exists for
/// the wire-codec round-trip property tests in
/// `rust/tests/property_wire.rs`.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    /// Load one or multiple programs into the PM.
    LoadProgram(MemoryImage),
    /// Start program `id` from the PM.
    StartProgram { id: u8 },
    /// Write a message into message memory (Data-in port).
    WriteMessage { slot: u8, msg: GaussMessage },
    /// Write a state matrix (Mem-A port).
    WriteState { slot: u8, a: CMatrix },
    /// Read a message back (Data-out port).
    ReadMessage { slot: u8 },
    /// Query processor status.
    Status,
}

/// Status replies (§III: "Each command gets replied by a status message").
///
/// `PartialEq` is exact, for the same round-trip tests as [`Command`]'s.
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    /// Command accepted.
    Ok,
    /// Program image loaded (instruction count echoed).
    Loaded { instrs: usize },
    /// Program ran to completion.
    Finished(RunStats),
    /// A message read back from the memory.
    Message(GaussMessage),
    /// Current FSM state and cycle counter.
    Status { state: FsmState, cycles: u64 },
    /// Command failed (human-readable reason).
    Error(String),
}

/// Typed Fig. 5 protocol errors. Everything a host can observe going
/// wrong on the command channel, as data — an error status from the
/// device, a reply variant that does not match the issued command, or a
/// dead device thread all surface as `Err`, never as a panic in the
/// caller's `match` arms. (Re-exported as `coordinator::ProtocolError`;
/// defined here, next to [`Command`]/[`Reply`], because in-process
/// hosts driving [`Fgp::execute_command`] directly need the same typed
/// path as the threaded device.)
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum ProtocolError {
    /// The device replied `Reply::Error` (bad slot, missing program, ...).
    #[error("device error reply: {0}")]
    Device(String),
    /// The reply variant does not match the issued command.
    #[error("unexpected reply to {command}: {reply}")]
    UnexpectedReply { command: &'static str, reply: String },
    /// The device thread is gone (stopped, or it died mid-command).
    #[error("device closed")]
    DeviceClosed,
}

impl Reply {
    /// Project this reply into the value a command expects:
    /// `Reply::Error` becomes [`ProtocolError::Device`], and a reply
    /// the picker rejects becomes [`ProtocolError::UnexpectedReply`].
    pub fn expect<T>(
        self,
        command: &'static str,
        pick: impl FnOnce(Reply) -> Result<T, Reply>,
    ) -> Result<T, ProtocolError> {
        match self {
            Reply::Error(e) => Err(ProtocolError::Device(e)),
            other => pick(other).map_err(|r| ProtocolError::UnexpectedReply {
                command,
                reply: format!("{r:?}"),
            }),
        }
    }
}

/// Cycle/instruction statistics for one program run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RunStats {
    /// Total simulated cycles (multi-PE wave-folded when `n_pes > 1`).
    pub cycles: u64,
    /// Instructions executed.
    pub instructions: u64,
    /// Datapath-only cycles (excludes fetch and store).
    pub datapath_cycles: u64,
    /// Loop iterations executed (sections processed).
    pub sections: u64,
}

/// Host feed: called once before each section so the external processor
/// can stream the section's observation(s) and state matrix into the
/// shared slots (see compiler docs on streaming). Return `false` to stop
/// after the current data (end of stream).
pub trait HostFeed {
    /// Refill shared slots before `section` executes; false ends the stream.
    fn feed(&mut self, section: usize, mem: &mut MessageMemory, states: &mut StateMemory) -> bool;
}

/// A no-op feed for programs whose inputs are fully preloaded.
pub struct NoFeed;

impl HostFeed for NoFeed {
    fn feed(&mut self, _: usize, _: &mut MessageMemory, _: &mut StateMemory) -> bool {
        true
    }
}

impl<F> HostFeed for F
where
    F: FnMut(usize, &mut MessageMemory, &mut StateMemory) -> bool,
{
    fn feed(&mut self, s: usize, m: &mut MessageMemory, st: &mut StateMemory) -> bool {
        self(s, m, st)
    }
}

/// Reusable operand staging buffers (the Select/Mask unit latches),
/// SoA planes since PR 9.
///
/// The hot path copies each operand once into these persistent buffers —
/// semantically the operand registers at the array's edge — so steady-state
/// execution performs no heap allocation (perf pass, EXPERIMENTS.md §Perf),
/// and the copies themselves are flat `i64` plane memcpys.
#[derive(Default)]
struct OpScratch {
    a: CPlanes,
    b: CPlanes,
    c: CPlanes,
    d: CPlanes,
    y: CPlanes,
    dm: CPlanes,
}

/// The FGP processor.
pub struct Fgp {
    /// Dimensions, capacities and timing the device was built with.
    pub config: FgpConfig,
    /// Program memory (instruction words + prg directory).
    pub pm: ProgramMemory,
    /// Message memory behind the Data-in/out ports.
    pub msgmem: MessageMemory,
    /// State memory (the per-node A matrices).
    pub statemem: StateMemory,
    /// The systolic array datapath.
    pub array: SystolicArray,
    state: FsmState,
    total_cycles: u64,
    scratch: OpScratch,
}

impl Fgp {
    /// A powered-on idle device.
    pub fn new(config: FgpConfig) -> Self {
        Fgp {
            pm: ProgramMemory::default(),
            msgmem: MessageMemory::new(config.n, config.fmt, config.msg_slots),
            statemem: StateMemory::new(config.n, config.fmt, config.state_slots),
            array: SystolicArray::new(config.n, config.fmt, config.timing),
            state: FsmState::Idle,
            total_cycles: 0,
            scratch: OpScratch::default(),
            config,
        }
    }

    /// Current FSM state.
    pub fn state(&self) -> FsmState {
        self.state
    }

    /// Lifetime cycle counter (all runs).
    pub fn total_cycles(&self) -> u64 {
        self.total_cycles
    }

    /// Execute one external command (the co-processor protocol).
    pub fn execute_command(&mut self, cmd: Command) -> Reply {
        match cmd {
            Command::LoadProgram(image) => match self.pm.load(&image) {
                Ok(n) => Reply::Loaded { instrs: n },
                Err(e) => Reply::Error(format!("{e}")),
            },
            Command::StartProgram { id } => match self.run_program(id, &mut NoFeed) {
                Ok(stats) => Reply::Finished(stats),
                Err(e) => Reply::Error(format!("{e}")),
            },
            Command::WriteMessage { slot, msg } => {
                if (slot as usize) >= self.msgmem.num_slots() {
                    return Reply::Error(format!("{}", FgpError::BadSlot(slot)));
                }
                self.msgmem.write_message(slot, &msg);
                Reply::Ok
            }
            Command::WriteState { slot, a } => {
                if (slot as usize) >= self.statemem.num_slots() {
                    return Reply::Error(format!("{}", FgpError::BadSlot(slot)));
                }
                self.statemem.write_matrix(slot, &a);
                Reply::Ok
            }
            Command::ReadMessage { slot } => {
                if (slot as usize) >= self.msgmem.num_slots() {
                    return Reply::Error(format!("{}", FgpError::BadSlot(slot)));
                }
                Reply::Message(self.msgmem.read_message(slot))
            }
            Command::Status => Reply::Status { state: self.state, cycles: self.total_cycles },
        }
    }

    /// Run program `id` to completion.
    ///
    /// `feed` is invoked with section index 0 before execution and again
    /// after every `smm` commit (the FSM's store handshake is the Data-in
    /// synchronization point): the host streams the *next* section's
    /// observation/state into the shared slots. When `feed` returns
    /// `false` the input stream is exhausted and the FSM exits the `loop`
    /// at its next back-edge instead of re-entering the body.
    pub fn run_program(&mut self, id: u8, feed: &mut dyn HostFeed) -> Result<RunStats, FgpError> {
        self.run_program_profiled(id, feed, None)
    }

    /// [`Fgp::run_program`] with an optional instruction-level profiler
    /// attached (see [`super::trace::Profiler`]).
    pub fn run_program_profiled(
        &mut self,
        id: u8,
        feed: &mut dyn HostFeed,
        mut profiler: Option<&mut super::trace::Profiler>,
    ) -> Result<RunStats, FgpError> {
        if self.state == FsmState::Running {
            return Err(FgpError::Busy);
        }
        let start = self.pm.start_of(id).ok_or(FgpError::NoSuchProgram(id))?;
        self.state = FsmState::Running;
        let mut stats = RunStats::default();
        let mut exhausted = !feed.feed(0, &mut self.msgmem, &mut self.statemem);

        // Multi-PE accounting: per-section cost records folded into
        // cross-PE waves after the run (values are computed sequentially
        // regardless, so only the cycle count depends on n_pes).
        let multi_pe = self.config.multi_pe;
        let mut section_costs: Vec<SectionCost> = Vec::new();
        let mut section_mark: u64 = 0;

        // at most one active loop (the ISA has no nested loops)
        let mut active: Option<(usize, u16)> = None; // (loop instr addr, remaining passes)
        let mut pc = start;
        loop {
            let word = match self.pm.fetch(pc) {
                Some(w) => w,
                None => break, // ran off the PM: implicit halt
            };
            let instr = Instr::decode(word)?;
            stats.instructions += 1;
            // Program-control instructions are handled by the FSM's
            // address generator with zero issue overhead (standard
            // zero-overhead looping); only datapath instructions pay the
            // fetch/decode cycle.
            if instr.is_datapath() || matches!(instr, Instr::Smm { .. }) {
                stats.cycles += self.config.timing.fetch;
            }
            match instr {
                Instr::Halt | Instr::Prg { .. } => break, // next program starts
                Instr::Loop { count, body } => {
                    let body_start = pc - body as usize;
                    match active {
                        Some((laddr, ref mut remaining)) if laddr == pc => {
                            if *remaining > 0 && !exhausted {
                                *remaining -= 1;
                                pc = body_start;
                            } else {
                                active = None;
                                pc += 1;
                            }
                        }
                        _ => {
                            if count > 1 && !exhausted {
                                // pass 1 ran inline; schedule passes 2..count
                                active = Some((pc, count - 2));
                                pc = body_start;
                            } else {
                                pc += 1;
                            }
                        }
                    }
                    continue;
                }
                other => {
                    let start_cycle = stats.cycles;
                    let c = self.execute_datapath(&other, pc)?;
                    stats.cycles += c;
                    stats.datapath_cycles += c;
                    if let Some(p) = profiler.as_deref_mut() {
                        p.record(pc, start_cycle, c, &other);
                    }
                    if matches!(other, Instr::Smm { .. }) {
                        // store handshake: a section committed; stream the
                        // next section's inputs
                        stats.sections += 1;
                        if multi_pe.n_pes > 1 {
                            let total = stats.cycles - section_mark;
                            section_costs.push(SectionCost { compute: total - c, store: c });
                            section_mark = stats.cycles;
                        }
                        if !exhausted {
                            exhausted = !feed.feed(
                                stats.sections as usize,
                                &mut self.msgmem,
                                &mut self.statemem,
                            );
                        }
                    }
                }
            }
            pc += 1;
        }

        if multi_pe.n_pes > 1 && !section_costs.is_empty() {
            // Fold the sequentially-accumulated section costs into
            // cross-PE waves; cycles outside any section (a trailing
            // non-smm epilogue) stay serial.
            let epilogue = stats.cycles - section_mark;
            stats.cycles = multi_pe.batch_cycles_records(&section_costs) + epilogue;
        }

        self.total_cycles += stats.cycles;
        self.state = FsmState::Done;
        Ok(stats)
    }

    /// Resolve a matrix operand through the Select / Transpose units.
    fn mat_operand<'a>(
        array: &'a SystolicArray,
        msgmem: &'a MessageMemory,
        statemem: &'a StateMemory,
        src: &OperandSrc,
        herm: bool,
    ) -> MatOperand<'a> {
        match src {
            OperandSrc::Msg(s) if *s == ACC => MatOperand { data: array.accum.as_ref(), herm },
            OperandSrc::Msg(s) => MatOperand { data: msgmem.mat_planes(*s), herm },
            OperandSrc::State(s) => MatOperand { data: statemem.planes(*s), herm },
        }
    }

    /// Resolve the vector side of an operand (mean pipeline / Mask unit).
    fn vec_operand<'a>(
        array: &'a SystolicArray,
        msgmem: &'a MessageMemory,
        src: &OperandSrc,
    ) -> PlaneRef<'a> {
        match src {
            OperandSrc::Msg(s) if *s == ACC => array.vaccum.as_ref(),
            OperandSrc::Msg(s) => msgmem.mean_planes(*s),
            OperandSrc::State(_) => panic!("state memory has no mean column"),
        }
    }

    fn execute_datapath(&mut self, instr: &Instr, addr: usize) -> Result<u64, FgpError> {
        let n = self.config.n;
        let check_msg = |s: &u8| -> Result<(), FgpError> {
            if *s != ACC && (*s as usize) >= self.msgmem.num_slots() {
                return Err(FgpError::BadSlot(*s));
            }
            Ok(())
        };
        let check_operand = |o: &OperandSrc| -> Result<(), FgpError> {
            match o {
                OperandSrc::Msg(s) => check_msg(s),
                OperandSrc::State(s) => {
                    if (*s as usize) >= self.statemem.num_slots() {
                        return Err(FgpError::BadSlot(*s));
                    }
                    Ok(())
                }
            }
        };
        // stage operands into the persistent scratch latches (one planar
        // copy, zero steady-state allocation)
        let mut s = std::mem::take(&mut self.scratch);
        let cycles = match instr {
            Instr::Mma { a, a_herm, b, b_herm, neg, vec } => {
                check_operand(a)?;
                check_operand(b)?;
                s.a.copy_from(
                    Self::mat_operand(&self.array, &self.msgmem, &self.statemem, a, *a_herm).data,
                );
                if *vec {
                    s.b.copy_from(Self::vec_operand(&self.array, &self.msgmem, b));
                    self.array.mma_vector(
                        MatOperand { data: s.a.as_ref(), herm: *a_herm },
                        s.b.as_ref(),
                        *neg,
                    )
                } else {
                    s.b.copy_from(
                        Self::mat_operand(&self.array, &self.msgmem, &self.statemem, b, *b_herm)
                            .data,
                    );
                    self.array.mma_matrix(
                        MatOperand { data: s.a.as_ref(), herm: *a_herm },
                        MatOperand { data: s.b.as_ref(), herm: *b_herm },
                        *neg,
                    )
                }
            }
            Instr::Mms { a, a_herm, b, b_herm, c, neg, vec } => {
                check_operand(a)?;
                check_operand(b)?;
                check_msg(c)?;
                s.a.copy_from(
                    Self::mat_operand(&self.array, &self.msgmem, &self.statemem, a, *a_herm).data,
                );
                if *vec {
                    s.b.copy_from(Self::vec_operand(&self.array, &self.msgmem, b));
                    s.c.copy_from(if *c == ACC {
                        self.array.vshift.as_ref()
                    } else {
                        self.msgmem.mean_planes(*c)
                    });
                    self.array.mms_vector(
                        MatOperand { data: s.a.as_ref(), herm: *a_herm },
                        s.b.as_ref(),
                        s.c.as_ref(),
                        *neg,
                    )
                } else {
                    s.b.copy_from(
                        Self::mat_operand(&self.array, &self.msgmem, &self.statemem, b, *b_herm)
                            .data,
                    );
                    s.c.copy_from(if *c == ACC {
                        self.array.shift.as_ref()
                    } else {
                        self.msgmem.mat_planes(*c)
                    });
                    self.array.mms_matrix(
                        MatOperand { data: s.a.as_ref(), herm: *a_herm },
                        MatOperand { data: s.b.as_ref(), herm: *b_herm },
                        s.c.as_ref(),
                        *neg,
                    )
                }
            }
            Instr::Fad { g, b, b_herm, c, d } => {
                check_msg(g)?;
                check_msg(b)?;
                check_msg(c)?;
                check_msg(d)?;
                if *d == ACC {
                    self.scratch = s;
                    return Err(FgpError::Datapath {
                        addr,
                        msg: "fad D quadrant must come from message memory".into(),
                    });
                }
                // quadrant G from the shift plane when acc, B/C from accum
                s.a.copy_from(if *g == ACC {
                    self.array.shift.as_ref()
                } else {
                    self.msgmem.mat_planes(*g)
                });
                s.b.copy_from(if *b == ACC {
                    self.array.accum.as_ref()
                } else {
                    self.msgmem.mat_planes(*b)
                });
                s.c.copy_from(if *c == ACC {
                    self.array.accum.as_ref()
                } else {
                    self.msgmem.mat_planes(*c)
                });
                s.d.copy_from(self.msgmem.mat_planes(*d));
                s.dm.copy_from(self.msgmem.mean_planes(*d));
                // extended mean column: top = vshift (innovation), bottom = D's mean
                s.y.copy_from(if *g == ACC {
                    self.array.vshift.as_ref()
                } else {
                    self.msgmem.mean_planes(*g)
                });
                self.array.faddeev(
                    s.a.as_ref(),
                    MatOperand { data: s.b.as_ref(), herm: *b_herm },
                    s.c.as_ref(),
                    s.d.as_ref(),
                    s.y.as_ref(),
                    s.dm.as_ref(),
                )
            }
            Instr::Smm { dst } => {
                check_msg(dst)?;
                if *dst == ACC {
                    self.scratch = s;
                    return Err(FgpError::Datapath { addr, msg: "smm cannot target acc".into() });
                }
                // planar store: two memcpys per plane pair, no AoS
                // materialization on the hot path
                self.msgmem.write_planes(
                    *dst,
                    self.array.result_matrix(),
                    self.array.result_vector(),
                );
                self.config.timing.store_pass(n)
            }
            other => {
                self.scratch = s;
                return Err(FgpError::Datapath {
                    addr,
                    msg: format!("{} is not a datapath instruction", other.mnemonic()),
                });
            }
        };
        self.scratch = s;
        Ok(cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::gmp::matrix::{c64, CMatrix};
    use crate::gmp::{FactorGraph, Schedule};
    use crate::testutil::Rng;

    fn scaled_msg(rng: &mut Rng, n: usize, scale: f64) -> GaussMessage {
        GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-1.0, 1.0), rng.range(-1.0, 1.0))).collect(),
            CMatrix::random_psd(rng, n, 0.3).scale(scale),
        )
    }

    /// Compile + run a single compound node on the simulator; compare
    /// against the golden rule. The core end-to-end datapath test.
    #[test]
    fn single_compound_node_matches_golden() {
        let mut rng = Rng::new(11);
        let n = 4;
        let mut g = FactorGraph::new();
        let a = CMatrix::random(&mut rng, n, n).scale(0.5);
        let a_list = vec![a.clone()];
        let (_, _) = g.rls_chain(n, &a_list);
        let sched = Schedule::forward_sweep(&g);
        let compiled = compile(&g, &sched, &CompileOptions::default()).unwrap();

        let mut fgp = Fgp::new(FgpConfig::default());
        assert!(matches!(
            fgp.execute_command(Command::LoadProgram(compiled.program.to_image())),
            Reply::Loaded { .. }
        ));

        let x = scaled_msg(&mut rng, n, 0.15);
        let y = scaled_msg(&mut rng, n, 0.15);

        // preload prior, stream slot and state
        let prior_slot = compiled.memmap.preloads[0].1;
        fgp.msgmem.write_message(prior_slot, &x);
        let (_, obs_slot, _) = compiled.memmap.streams[0];
        fgp.msgmem.write_message(obs_slot, &y);
        let (_, st_slot, _) = compiled.memmap.state_streams[0];
        fgp.statemem.write_matrix(st_slot, &a);

        let stats = fgp.run_program(1, &mut NoFeed).unwrap();
        assert!(stats.cycles > 0);
        assert_eq!(stats.sections, 1);

        let out_slot = compiled.memmap.outputs[0].1;
        let got = fgp.msgmem.read_message(out_slot);
        let want = crate::gmp::nodes::compound_observation(&x, &y, &a, true).unwrap();
        let d = got.dist(&want);
        assert!(d < 0.15, "fixed-point vs golden dist {d}");
    }

    #[test]
    fn compound_node_cycles_match_timing_model() {
        // One section: total = CN cycles per the timing model.
        let mut rng = Rng::new(13);
        let n = 4;
        let mut g = FactorGraph::new();
        let a = CMatrix::random(&mut rng, n, n).scale(0.5);
        g.rls_chain(n, &[a.clone()]);
        let sched = Schedule::forward_sweep(&g);
        let compiled = compile(&g, &sched, &CompileOptions::default()).unwrap();

        let mut fgp = Fgp::new(FgpConfig::default());
        fgp.pm.load(&compiled.program.to_image()).unwrap();
        let stats = fgp.run_program(1, &mut NoFeed).unwrap();
        assert_eq!(
            stats.cycles,
            fgp.config.timing.compound_node_cycles(n),
            "one section must cost exactly one CN update"
        );
    }

    /// Typed protocol helpers: every reply flows through
    /// [`Reply::expect`], so a mismatched or error reply is a
    /// [`ProtocolError`] value, never a panic.
    fn status_of(fgp: &mut Fgp) -> Result<(FsmState, u64), ProtocolError> {
        fgp.execute_command(Command::Status).expect("Status", |r| match r {
            Reply::Status { state, cycles } => Ok((state, cycles)),
            other => Err(other),
        })
    }

    fn start_program(fgp: &mut Fgp, id: u8) -> Result<RunStats, ProtocolError> {
        fgp.execute_command(Command::StartProgram { id }).expect("StartProgram", |r| match r {
            Reply::Finished(stats) => Ok(stats),
            other => Err(other),
        })
    }

    fn write_message(fgp: &mut Fgp, slot: u8, msg: GaussMessage) -> Result<(), ProtocolError> {
        fgp.execute_command(Command::WriteMessage { slot, msg }).expect(
            "WriteMessage",
            |r| match r {
                Reply::Ok => Ok(()),
                other => Err(other),
            },
        )
    }

    #[test]
    fn status_and_command_protocol() -> Result<(), ProtocolError> {
        let mut fgp = Fgp::new(FgpConfig::default());
        let (state, cycles) = status_of(&mut fgp)?;
        assert_eq!(state, FsmState::Idle);
        assert_eq!(cycles, 0);
        // starting a missing program is a typed device error
        let err = start_program(&mut fgp, 9).unwrap_err();
        assert!(matches!(&err, ProtocolError::Device(e) if e.contains("no program")), "{err}");
        // bad slot write
        let err = write_message(&mut fgp, 200, GaussMessage::isotropic(4, 1.0)).unwrap_err();
        assert!(matches!(&err, ProtocolError::Device(e) if e.contains("out of range")), "{err}");
        // a reply the picker rejects is a typed mismatch, not a panic
        let err = fgp
            .execute_command(Command::Status)
            .expect("Status", |r| -> Result<(), Reply> { Err(r) })
            .unwrap_err();
        assert!(matches!(&err, ProtocolError::UnexpectedReply { command: "Status", .. }), "{err}");
        Ok(())
    }

    fn rls_feed_setup(
        rng: &mut Rng,
        sections: usize,
    ) -> (crate::compiler::CompiledProgram, Vec<CMatrix>, GaussMessage, Vec<GaussMessage>) {
        let n = 4;
        let a_list: Vec<CMatrix> =
            (0..sections).map(|_| CMatrix::random(rng, n, n).scale(0.4)).collect();
        let mut g = FactorGraph::new();
        g.rls_chain(n, &a_list);
        let sched = Schedule::forward_sweep(&g);
        let compiled = compile(&g, &sched, &CompileOptions::default()).unwrap();
        let prior = scaled_msg(rng, n, 0.2);
        let ys: Vec<GaussMessage> = (0..sections).map(|_| scaled_msg(rng, n, 0.1)).collect();
        (compiled, a_list, prior, ys)
    }

    fn run_rls_feed(
        config: FgpConfig,
        compiled: &crate::compiler::CompiledProgram,
        a_list: &[CMatrix],
        prior: &GaussMessage,
        ys: &[GaussMessage],
    ) -> (Fgp, RunStats, u8) {
        let mut fgp = Fgp::new(config);
        fgp.pm.load(&compiled.program.to_image()).unwrap();
        let prior_slot = compiled.memmap.preloads[0].1;
        fgp.msgmem.write_message(prior_slot, prior);
        let (_, obs_slot, _) = compiled.memmap.streams[0];
        let (_, st_slot, _) = compiled.memmap.state_streams[0];
        let ys_feed = ys.to_vec();
        let a_feed = a_list.to_vec();
        let mut feed =
            move |section: usize, mem: &mut MessageMemory, st: &mut StateMemory| -> bool {
                if section >= ys_feed.len() {
                    return false;
                }
                mem.write_message(obs_slot, &ys_feed[section]);
                st.write_matrix(st_slot, &a_feed[section]);
                true
            };
        let stats = fgp.run_program(1, &mut feed).unwrap();
        (fgp, stats, compiled.memmap.outputs[0].1)
    }

    #[test]
    fn looped_rls_with_host_feed_matches_golden_chain() {
        let mut rng = Rng::new(17);
        let n = 4;
        let sections = 6;
        let (compiled, a_list, prior, ys) = rls_feed_setup(&mut rng, sections);
        assert!(compiled.stats.looped.is_some(), "chain must compress");

        let (fgp, stats, out_slot) =
            run_rls_feed(FgpConfig::default(), &compiled, &a_list, &prior, &ys);
        assert_eq!(stats.sections as usize, sections);

        // golden chain
        let mut want = prior.clone();
        for (y, a) in ys.iter().zip(&a_list) {
            want = crate::gmp::nodes::compound_observation(&want, y, a, true).unwrap();
        }
        let got = fgp.msgmem.read_message(out_slot);
        let d = got.dist(&want);
        assert!(d < 0.3, "looped RLS vs golden dist {d}");
        // cycle accounting: sections * CN cycles
        assert_eq!(
            stats.cycles,
            fgp.config.timing.compound_node_cycles(n) * sections as u64
        );
    }

    /// PE count is a cycle knob, never semantics: the same streamed RLS
    /// chain on 1/2/4 PEs produces bit-identical memory contents while
    /// cycles fold to the multi-PE wave model exactly.
    #[test]
    fn multi_pe_outputs_bitwise_identical_cycles_folded() {
        let mut rng = Rng::new(23);
        let n = 4;
        let sections = 6;
        let (compiled, a_list, prior, ys) = rls_feed_setup(&mut rng, sections);

        let (base_fgp, base_stats, out_slot) =
            run_rls_feed(FgpConfig::default(), &compiled, &a_list, &prior, &ys);
        let base_out = base_fgp.msgmem.read(out_slot);

        let mut prev_cycles = base_stats.cycles;
        for p in [2usize, 4] {
            let (fgp, stats, slot) =
                run_rls_feed(FgpConfig::with_pes(p), &compiled, &a_list, &prior, &ys);
            assert_eq!(slot, out_slot);
            let out = fgp.msgmem.read(slot);
            for (a, b) in out.v.iter().zip(&base_out.v) {
                assert_eq!((a.re.raw, a.im.raw), (b.re.raw, b.im.raw), "P={p} covariance raw");
            }
            for (a, b) in out.m.iter().zip(&base_out.m) {
                assert_eq!((a.re.raw, a.im.raw), (b.re.raw, b.im.raw), "P={p} mean raw");
            }
            // cycles: exactly the uniform-wave closed form, and faster
            // than the previous PE count
            assert_eq!(
                stats.cycles,
                fgp.config.multi_pe.batch_cycles(&fgp.config.timing, n, sections),
                "P={p} cycles must match the wave model"
            );
            assert!(stats.cycles < prev_cycles, "P={p} must not be slower");
            prev_cycles = stats.cycles;
        }
    }

    #[test]
    fn multiple_programs_in_pm() {
        use crate::isa::{Instr, Program};
        // program 2 does a single smm (stores zero planes)
        let p = Program::new(vec![
            Instr::Prg { id: 1 },
            Instr::Smm { dst: 0 },
            Instr::Halt,
            Instr::Prg { id: 2 },
            Instr::Smm { dst: 1 },
            Instr::Halt,
        ]);
        let mut fgp = Fgp::new(FgpConfig::default());
        fgp.pm.load(&p.to_image()).unwrap();
        let s1 = fgp.run_program(1, &mut NoFeed).unwrap();
        assert_eq!(s1.instructions, 2); // smm + halt
        let s2 = fgp.run_program(2, &mut NoFeed).unwrap();
        assert_eq!(s2.instructions, 2);
    }
}
