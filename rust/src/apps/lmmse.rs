//! Block LMMSE symbol equalization (§I: "linear MMSE equalization").
//!
//! One shot of the compound-observation node: the transmitted block `x`
//! (prior: symbol power * I) is observed through the Toeplitz channel
//! matrix `H` under AWGN; the posterior mean is the LMMSE symbol
//! estimate, which we slice to the constellation and score by symbol
//! error rate. Exactly the "symbol detection/equalization" program the
//! paper imagines sharing the PM with the RLS estimator (§III).

use anyhow::Result;

use crate::coordinator::backend::{Backend, CnRequestData};
use crate::gmp::matrix::c64;
use crate::gmp::message::GaussMessage;
use crate::testutil::Rng;

use super::channel::{Constellation, MultipathChannel};

/// A block-equalization problem.
#[derive(Clone, Debug)]
pub struct LmmseProblem {
    pub n: usize,
    pub constellation: Constellation,
    pub channel: MultipathChannel,
    pub noise_var: f64,
    /// Transmitted symbols (ground truth).
    pub tx: Vec<c64>,
    /// Received block.
    pub rx: Vec<c64>,
}

/// Equalization outcome.
#[derive(Clone, Debug)]
pub struct LmmseOutcome {
    pub estimate: Vec<c64>,
    pub decisions: Vec<c64>,
    pub symbol_errors: usize,
    pub rel_mse: f64,
}

impl LmmseProblem {
    pub fn synthetic(n: usize, noise_var: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // dominant first tap keeps the block well conditioned at n=4
        let mut channel = MultipathChannel::random(&mut rng, 2, 0.08);
        channel.taps[0] = channel.taps[0] + c64::new(0.8, 0.0);
        let constellation = Constellation::Qpsk;
        let tx: Vec<c64> = (0..n).map(|_| constellation.draw(&mut rng)).collect();
        let rx = channel.transmit(&mut rng, &tx, noise_var);
        LmmseProblem { n, constellation, channel, noise_var, tx, rx }
    }

    /// The compound-node request implementing the equalizer:
    /// prior V_X = 0.25 I (symbol power), A = H, observation (rx, σ² I).
    pub fn request(&self) -> CnRequestData {
        CnRequestData {
            x: GaussMessage::isotropic(self.n, 0.25),
            y: GaussMessage::observation(&self.rx, self.noise_var),
            a: self.channel.toeplitz(self.n),
        }
    }

    /// Run on any backend and score.
    pub fn run_on(&self, backend: &mut dyn Backend) -> Result<LmmseOutcome> {
        let posterior = backend.cn_update(&self.request())?;
        let estimate = posterior.mean;
        let decisions: Vec<c64> =
            estimate.iter().map(|z| self.constellation.slice(*z)).collect();
        let symbol_errors = decisions
            .iter()
            .zip(&self.tx)
            .filter(|(d, t)| (**d - **t).abs() > 1e-9)
            .count();
        let num: f64 = estimate.iter().zip(&self.tx).map(|(a, b)| (*a - *b).abs2()).sum();
        let den: f64 = self.tx.iter().map(|a| a.abs2()).sum();
        Ok(LmmseOutcome { estimate, decisions, symbol_errors, rel_mse: num / den })
    }
}

/// Sweep SNR: mean SER over `trials` blocks per point (bench helper).
pub fn ser_sweep(
    backend: &mut dyn Backend,
    n: usize,
    snrs_db: &[f64],
    trials: u64,
) -> Result<Vec<(f64, f64)>> {
    let mut out = Vec::with_capacity(snrs_db.len());
    for &snr in snrs_db {
        // symbol power 0.25 -> noise var for the target SNR
        let noise_var = 0.25 / 10f64.powf(snr / 10.0);
        let mut errors = 0usize;
        let mut symbols = 0usize;
        for t in 0..trials {
            let p = LmmseProblem::synthetic(n, noise_var, 1000 + t * 7 + snr as u64);
            let o = p.run_on(backend)?;
            errors += o.symbol_errors;
            symbols += n;
        }
        out.push((snr, errors as f64 / symbols as f64));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{FgpSimBackend, GoldenBackend};
    use crate::fgp::FgpConfig;

    #[test]
    fn golden_equalizer_beats_no_equalizer_at_high_snr() {
        let mut golden = GoldenBackend;
        let mut total_err = 0;
        for seed in 0..10 {
            let p = LmmseProblem::synthetic(4, 0.002, seed);
            let o = p.run_on(&mut golden).unwrap();
            total_err += o.symbol_errors;
        }
        assert!(total_err <= 1, "errors at 21 dB: {total_err}");
    }

    #[test]
    fn ser_decreases_with_snr() {
        let mut golden = GoldenBackend;
        let sweep = ser_sweep(&mut golden, 4, &[0.0, 10.0, 20.0], 20).unwrap();
        assert!(sweep[0].1 >= sweep[2].1, "sweep {sweep:?}");
    }

    #[test]
    fn fgp_equalizer_matches_golden_decisions_mostly() {
        let mut sim = FgpSimBackend::new(FgpConfig::default()).unwrap();
        let mut golden = GoldenBackend;
        let mut agree = 0;
        let mut total = 0;
        for seed in 0..8 {
            let p = LmmseProblem::synthetic(4, 0.01, 50 + seed);
            let s = p.run_on(&mut sim).unwrap();
            let g = p.run_on(&mut golden).unwrap();
            for (a, b) in s.decisions.iter().zip(&g.decisions) {
                total += 1;
                if (*a - *b).abs() < 1e-9 {
                    agree += 1;
                }
            }
        }
        assert!(agree * 10 >= total * 9, "{agree}/{total} decisions agree");
    }
}
