//! Block LMMSE symbol equalization (§I: "linear MMSE equalization").
//!
//! One shot of the compound-observation node: the transmitted block `x`
//! (prior: symbol power * I) is observed through the Toeplitz channel
//! matrix `H` under AWGN; the posterior mean is the LMMSE symbol
//! estimate, which we slice to the constellation and score by symbol
//! error rate. Exactly the "symbol detection/equalization" program the
//! paper imagines sharing the PM with the RLS estimator (§III) — here a
//! single-section [`Workload`], the second-smallest model in the crate.

use std::collections::HashMap;

use anyhow::Result;

use crate::engine::{bind_streamed, preload_id, Execution, Session, Workload};
use crate::gmp::matrix::c64;
use crate::gmp::message::GaussMessage;
use crate::gmp::{FactorGraph, MsgId, Schedule};
use crate::testutil::Rng;

use super::channel::{Constellation, MultipathChannel};

/// A block-equalization problem.
#[derive(Clone, Debug)]
pub struct LmmseProblem {
    /// Block size (device dimension).
    pub n: usize,
    /// Constellation the payload is drawn from.
    pub constellation: Constellation,
    /// The frequency-selective channel.
    pub channel: MultipathChannel,
    /// AWGN variance at the receiver.
    pub noise_var: f64,
    /// Transmitted symbols (ground truth).
    pub tx: Vec<c64>,
    /// Received block.
    pub rx: Vec<c64>,
}

/// Equalization outcome.
#[derive(Clone, Debug)]
pub struct LmmseOutcome {
    /// Soft symbol estimates (posterior means).
    pub estimate: Vec<c64>,
    /// Hard decisions (nearest constellation point).
    pub decisions: Vec<c64>,
    /// Hard-decision errors against the transmitted block.
    pub symbol_errors: usize,
    /// Relative MSE of the soft estimates vs the sent symbols.
    pub rel_mse: f64,
}

impl LmmseProblem {
    /// Generate a random equalization instance.
    pub fn synthetic(n: usize, noise_var: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // dominant first tap keeps the block well conditioned at n=4
        let mut channel = MultipathChannel::random(&mut rng, 2, 0.08);
        channel.taps[0] = channel.taps[0] + c64::new(0.8, 0.0);
        let constellation = Constellation::Qpsk;
        let tx: Vec<c64> = (0..n).map(|_| constellation.draw(&mut rng)).collect();
        let rx = channel.transmit(&mut rng, &tx, noise_var);
        LmmseProblem { n, constellation, channel, noise_var, tx, rx }
    }
}

impl Workload for LmmseProblem {
    type Outcome = LmmseOutcome;

    fn name(&self) -> &str {
        "lmmse_equalizer"
    }

    fn n(&self) -> usize {
        self.n
    }

    /// One compound-observation section with the channel's Toeplitz
    /// matrix as the (streamed) state — same program shape as the
    /// coordinator's CN microbench, so sessions share the compilation.
    fn model(&self) -> Result<(FactorGraph, Schedule)> {
        let mut g = FactorGraph::new();
        g.rls_chain(self.n, &[self.channel.toeplitz(self.n)]);
        let s = Schedule::forward_sweep(&g);
        Ok((g, s))
    }

    fn inputs(
        &self,
        graph: &FactorGraph,
        schedule: &Schedule,
    ) -> Result<HashMap<MsgId, GaussMessage>> {
        let mut map = HashMap::new();
        // prior V_X = 0.25 I (symbol power)
        map.insert(
            preload_id(graph, schedule, "msg_prior")?,
            GaussMessage::isotropic(self.n, 0.25),
        );
        let obs = GaussMessage::observation(&self.rx, self.noise_var);
        bind_streamed(graph, schedule, std::slice::from_ref(&obs), &mut map)?;
        Ok(map)
    }

    fn outcome(&self, exec: &Execution) -> Result<LmmseOutcome> {
        let estimate = exec.output()?.mean.clone();
        let decisions: Vec<c64> =
            estimate.iter().map(|z| self.constellation.slice(*z)).collect();
        let symbol_errors = decisions
            .iter()
            .zip(&self.tx)
            .filter(|(d, t)| (**d - **t).abs() > 1e-9)
            .count();
        let num: f64 = estimate.iter().zip(&self.tx).map(|(a, b)| (*a - *b).abs2()).sum();
        let den: f64 = self.tx.iter().map(|a| a.abs2()).sum();
        Ok(LmmseOutcome { estimate, decisions, symbol_errors, rel_mse: num / den })
    }

    fn quality(&self, outcome: &LmmseOutcome) -> f64 {
        outcome.rel_mse
    }

    fn tolerance(&self) -> f64 {
        0.15
    }
}

/// Sweep SNR: mean SER over `trials` blocks per point (bench helper).
/// Every block shares one program shape, so the session compiles once.
pub fn ser_sweep(
    session: &mut Session,
    n: usize,
    snrs_db: &[f64],
    trials: u64,
) -> Result<Vec<(f64, f64)>> {
    let mut out = Vec::with_capacity(snrs_db.len());
    for &snr in snrs_db {
        // symbol power 0.25 -> noise var for the target SNR
        let noise_var = 0.25 / 10f64.powf(snr / 10.0);
        let mut errors = 0usize;
        let mut symbols = 0usize;
        for t in 0..trials {
            let p = LmmseProblem::synthetic(n, noise_var, 1000 + t * 7 + snr as u64);
            let o = session.run(&p)?;
            errors += o.outcome.symbol_errors;
            symbols += n;
        }
        out.push((snr, errors as f64 / symbols as f64));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fgp::FgpConfig;

    #[test]
    fn golden_equalizer_beats_no_equalizer_at_high_snr() {
        let mut golden = Session::golden();
        let mut total_err = 0;
        for seed in 0..10 {
            let p = LmmseProblem::synthetic(4, 0.002, seed);
            let o = golden.run(&p).unwrap();
            total_err += o.outcome.symbol_errors;
        }
        assert!(total_err <= 1, "errors at 21 dB: {total_err}");
    }

    #[test]
    fn ser_decreases_with_snr() {
        let mut golden = Session::golden();
        let sweep = ser_sweep(&mut golden, 4, &[0.0, 10.0, 20.0], 20).unwrap();
        assert!(sweep[0].1 >= sweep[2].1, "sweep {sweep:?}");
    }

    #[test]
    fn fgp_equalizer_matches_golden_decisions_mostly() {
        let mut sim = Session::fgp_sim(FgpConfig::default());
        let mut golden = Session::golden();
        let mut agree = 0;
        let mut total = 0;
        for seed in 0..8 {
            let p = LmmseProblem::synthetic(4, 0.01, 50 + seed);
            let s = sim.run(&p).unwrap();
            let g = golden.run(&p).unwrap();
            for (a, b) in s.outcome.decisions.iter().zip(&g.outcome.decisions) {
                total += 1;
                if (*a - *b).abs() < 1e-9 {
                    agree += 1;
                }
            }
        }
        assert!(agree * 10 >= total * 9, "{agree}/{total} decisions agree");
        // one program shape across all 8 blocks
        let stats = sim.cache_stats();
        assert_eq!((stats.misses, stats.hits), (1, 7));
    }
}
