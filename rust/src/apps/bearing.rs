//! Bearing-only target tracking: EKF vs. sigma-point (UKF) on the FGP.
//!
//! Fixed sensors measure only the **bearing** (angle) to a moving
//! target — the classic hard nonlinear tracking problem: a single
//! bearing carries no range information, so position emerges from
//! triangulating several sensors and fusing over time through the
//! motion model. Each time step is one [`NonlinearProblem`]: the
//! constant-velocity motion model rides *inside* the sweep graph as a
//! multiplier + adder prelude, followed by one relinearized
//! compound-observation section per sensor — predict + update as a
//! single fixed-shape workload, so every round of every step after the
//! very first is a program-cache hit.
//!
//! The state is `[px, py, vx, vy]` (real, embedded in the device's
//! 4-dim complex state). Sensors sit west of the field, so bearings
//! stay inside (−π/2, π/2) and never wrap. The pluggable
//! [`Linearizer`] makes this *the* EKF-vs-UKF comparison app: the same
//! problem, the same engine, only the linearization rule differs.

use anyhow::{bail, Context, Result};
use std::sync::Arc;

use crate::engine::{Session, StreamRun, StreamSample, StreamingWorkload};
use crate::gbp::RoundExecutor;
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::gmp::{FactorGraph, Schedule};
use crate::nonlinear::{
    gauss_newton, IteratedRelinearization, Linearizer, NonlinearFactor, NonlinearProblem,
    RelinOptions, RelinStop, RelinSweep,
};
use crate::testutil::Rng;

/// A bearing-only tracking scenario.
#[derive(Clone, Debug)]
pub struct BearingProblem {
    /// Fixed sensor positions.
    pub sensors: Vec<(f64, f64)>,
    /// True state per step: `[px, py, vx, vy]`.
    pub truth: Vec<[f64; 4]>,
    /// Measured bearings, `[step][sensor]` (radians).
    pub bearings: Vec<Vec<f64>>,
    /// Track length in samples.
    pub steps: usize,
    /// Bearing noise variance (rad²).
    pub noise_var: f64,
    /// Process noise variance on the velocity components.
    pub process_var: f64,
    /// Floor applied to the observation variance every estimator uses
    /// (data is still generated at `noise_var`). Set to the Q5.10-safe
    /// default by [`BearingProblem::synthetic`]; lower it for
    /// pure-golden noise-sweep studies.
    pub obs_var_floor: f64,
    /// Sample interval (seconds) of the constant-velocity model.
    pub dt: f64,
}

/// Result of one tracking run.
#[derive(Clone, Debug)]
pub struct TrackOutcome {
    /// Estimated positions per step.
    pub estimates: Vec<(f64, f64)>,
    /// Position RMSE against the true track.
    pub rmse: f64,
    /// Relinearization rounds across all steps.
    pub rounds_total: usize,
    /// True if any step's relinearization diverged.
    pub diverged: bool,
}

impl BearingProblem {
    /// Target crossing the unit field with constant velocity plus a
    /// little process noise; `num_sensors` sensors on the western edge.
    pub fn synthetic(steps: usize, num_sensors: usize, noise_var: f64, seed: u64) -> Self {
        assert!(steps >= 1 && num_sensors >= 2, "need steps and at least two sensors");
        let mut rng = Rng::new(seed);
        let sensors: Vec<(f64, f64)> = (0..num_sensors)
            .map(|i| (-0.4, 0.1 + 0.8 * i as f64 / (num_sensors.max(2) - 1) as f64))
            .collect();
        let dt = 1.0;
        let process_var = 1e-5;
        let mut state = [0.2, 0.3, 0.045, 0.025];
        let mut truth = Vec::with_capacity(steps);
        let mut bearings = Vec::with_capacity(steps);
        for _ in 0..steps {
            state[0] += state[2] * dt;
            state[1] += state[3] * dt;
            state[2] += rng.normal() * process_var.sqrt();
            state[3] += rng.normal() * process_var.sqrt();
            truth.push(state);
            bearings.push(
                sensors
                    .iter()
                    .map(|&(sx, sy)| {
                        (state[1] - sy).atan2(state[0] - sx) + rng.normal() * noise_var.sqrt()
                    })
                    .collect(),
            );
        }
        BearingProblem {
            sensors,
            truth,
            bearings,
            steps,
            noise_var,
            process_var,
            obs_var_floor: 2e-3,
            dt,
        }
    }

    /// Constant-velocity transition matrix.
    pub fn motion_matrix(&self, n: usize) -> CMatrix {
        let mut f = CMatrix::identity(n);
        f[(0, 2)] = c64::new(self.dt, 0.0);
        f[(1, 3)] = c64::new(self.dt, 0.0);
        f
    }

    /// Process-noise message (zero mean; tiny position jitter keeps the
    /// covariance comfortably positive on the fixed-point datapath).
    pub fn process_noise(&self, n: usize) -> GaussMessage {
        let mut q = CMatrix::zeros(n, n);
        q[(0, 0)] = c64::new(1e-6, 0.0);
        q[(1, 1)] = c64::new(1e-6, 0.0);
        q[(2, 2)] = c64::new(self.process_var, 0.0);
        q[(3, 3)] = c64::new(self.process_var, 0.0);
        GaussMessage::new(vec![c64::ZERO; n], q)
    }

    /// Initial belief: centered on the field with a position spread
    /// that keeps the sigma points clear of the sensor line (the UT
    /// must never straddle a bearing singularity), small velocity
    /// uncertainty.
    pub fn initial_belief(n: usize) -> GaussMessage {
        let mut mean = vec![c64::ZERO; n];
        mean[0] = c64::new(0.5, 0.0);
        mean[1] = c64::new(0.5, 0.0);
        let mut cov = CMatrix::zeros(n, n);
        cov[(0, 0)] = c64::new(0.04, 0.0);
        cov[(1, 1)] = c64::new(0.04, 0.0);
        cov[(2, 2)] = c64::new(0.01, 0.0);
        cov[(3, 3)] = c64::new(0.01, 0.0);
        GaussMessage::new(mean, cov)
    }

    /// One time step as a [`NonlinearProblem`]: motion prelude + one
    /// bearing factor per sensor (analytic Jacobians). The observation
    /// noise every estimator weights with is floored at
    /// `obs_var_floor` (device-safe default; tune the field directly
    /// for golden-only studies below the floor).
    pub fn step_problem(&self, step: usize, prior: GaussMessage) -> Result<NonlinearProblem> {
        let n = prior.dim();
        let var = self.noise_var.max(self.obs_var_floor);
        let factors = self
            .sensors
            .iter()
            .zip(&self.bearings[step])
            .map(|(&(sx, sy), &z)| {
                let h = move |x: &[f64]| vec![(x[1] - sy).atan2(x[0] - sx)];
                let jac = move |x: &[f64]| {
                    let dx = x[0] - sx;
                    let dy = x[1] - sy;
                    let r2 = (dx * dx + dy * dy).max(1e-9);
                    let mut row = vec![0.0; x.len()];
                    row[0] = -dy / r2;
                    row[1] = dx / r2;
                    vec![row]
                };
                Ok(NonlinearFactor::new(n, 1, Arc::new(h), vec![z], var)?
                    .with_jacobian(Arc::new(jac)))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(NonlinearProblem {
            n,
            prior,
            motion: Some((self.motion_matrix(n), self.process_noise(n))),
            factors,
        })
    }

    /// Track through a session with the given linearizer, `rounds`
    /// relinearization rounds per step.
    pub fn track(
        &self,
        session: &mut Session,
        linearizer: &dyn Linearizer,
        rounds: usize,
    ) -> Result<TrackOutcome> {
        self.track_impl(linearizer, rounds, |driver, problem| driver.run(session, problem))
    }

    /// Track through any [`RoundExecutor`] — e.g. an
    /// [`crate::coordinator::FgpFarm`] serving the sweeps.
    pub fn track_with(
        &self,
        exec: &mut dyn RoundExecutor,
        linearizer: &dyn Linearizer,
        rounds: usize,
    ) -> Result<TrackOutcome> {
        self.track_impl(linearizer, rounds, |driver, problem| driver.run_with(exec, problem))
    }

    fn track_impl(
        &self,
        linearizer: &dyn Linearizer,
        rounds: usize,
        mut run: impl FnMut(
            &IteratedRelinearization,
            &NonlinearProblem,
        ) -> Result<crate::nonlinear::RelinReport>,
    ) -> Result<TrackOutcome> {
        let n = crate::paper::N;
        let driver = IteratedRelinearization::with_options(
            linearizer,
            RelinOptions { max_rounds: rounds.max(1), tol: 1e-7, ..Default::default() },
        );
        let mut belief = Self::initial_belief(n);
        let mut estimates = Vec::with_capacity(self.steps);
        let mut rounds_total = 0;
        let mut diverged = false;
        for step in 0..self.steps {
            let problem = self.step_problem(step, belief)?;
            let report = run(&driver, &problem)?;
            rounds_total += report.rounds;
            diverged |= report.stop == RelinStop::Diverged;
            estimates.push((report.belief.mean[0].re, report.belief.mean[1].re));
            belief = report.belief;
        }
        Ok(TrackOutcome { estimates, rmse: self.rmse(&estimates), rounds_total, diverged })
    }

    /// Dense reference track: per-step Gauss–Newton MAP solves threaded
    /// through the same motion model (no engine involved).
    pub fn reference_track(&self) -> Result<Vec<GaussMessage>> {
        let n = crate::paper::N;
        let mut belief = Self::initial_belief(n);
        let mut out = Vec::with_capacity(self.steps);
        for step in 0..self.steps {
            let problem = self.step_problem(step, belief)?;
            let post = gauss_newton(&problem, 50, 1e-12)?;
            out.push(post.clone());
            belief = post;
        }
        Ok(out)
    }

    fn rmse(&self, estimates: &[(f64, f64)]) -> f64 {
        let se: f64 = estimates
            .iter()
            .zip(&self.truth)
            .map(|(e, t)| (e.0 - t[0]).powi(2) + (e.1 - t[1]).powi(2))
            .sum();
        (se / self.steps as f64).sqrt()
    }

    /// The tracking problem on the streaming surface, with a chosen
    /// linearization rule (see [`BearingStream`]).
    pub fn stream<'a>(&'a self, linearizer: &'a dyn Linearizer) -> BearingStream<'a> {
        BearingStream { problem: self, linearizer }
    }

    /// Worst per-step positional deviation of a track from a reference
    /// (e.g. [`BearingProblem::reference_track`]) — the conformance
    /// metric the tests and the bench gate share.
    pub fn max_deviation(estimates: &[(f64, f64)], reference: &[GaussMessage]) -> f64 {
        estimates
            .iter()
            .zip(reference)
            .map(|(e, w)| ((e.0 - w.mean[0].re).powi(2) + (e.1 - w.mean[1].re).powi(2)).sqrt())
            .fold(0.0, f64::max)
    }
}

/// Bearing-only tracking on the streaming surface: one sample per time
/// step, each linearized **once** at the predicted mean (filter mode —
/// semantically `BearingProblem::track` with a single relinearization
/// round per step, which is what a steady-state deployment serves;
/// iterated relinearization remains the batch path). Sample binding
/// depends on the current belief, so the stream declares
/// `max_chunk() == 1` and the driver reads the posterior back after
/// every sample — the sweep *shape* is still fixed, so the whole track
/// runs on one compiled program.
pub struct BearingStream<'a> {
    /// The tracking problem being streamed.
    pub problem: &'a BearingProblem,
    /// Linearizer used for the per-sample relinearization.
    pub linearizer: &'a dyn Linearizer,
}

impl StreamingWorkload for BearingStream<'_> {
    type StreamOutcome = TrackOutcome;

    fn stream_name(&self) -> &str {
        "bearing_stream"
    }

    fn state_dim(&self) -> usize {
        crate::paper::N
    }

    fn max_chunk(&self) -> usize {
        1 // sample binding relinearizes at the current belief
    }

    fn stream_model(&self, chunk: usize) -> Result<(FactorGraph, Schedule)> {
        if chunk != 1 {
            bail!("bearing sample binding is state-dependent; the stream runs sample-at-a-time");
        }
        // every step's sweep has the same shape; step 0 at the initial
        // belief is as good a template as any
        let n = crate::paper::N;
        let problem = self
            .problem
            .step_problem(0, BearingProblem::initial_belief(n))?;
        let sweep =
            RelinSweep::linearize_at(&problem, &problem.predicted_prior(), self.linearizer)?;
        crate::engine::Workload::model(&sweep)
    }

    fn state_label(&self) -> &str {
        "msg_prior"
    }

    fn constant_inputs(&self) -> Vec<(String, GaussMessage)> {
        vec![(
            "msg_q".to_string(),
            self.problem.process_noise(crate::paper::N),
        )]
    }

    fn initial_state(&self) -> GaussMessage {
        BearingProblem::initial_belief(crate::paper::N)
    }

    fn next_sample(&self, k: usize, state: &GaussMessage) -> Result<Option<StreamSample>> {
        if k >= self.problem.steps {
            return Ok(None);
        }
        let problem = self.problem.step_problem(k, state.clone())?;
        let at = problem.predicted_prior();
        let mut messages = Vec::with_capacity(problem.factors.len());
        let mut states = Vec::with_capacity(problem.factors.len());
        for (i, f) in problem.factors.iter().enumerate() {
            let lin = self
                .linearizer
                .linearize(f, &at)
                .with_context(|| format!("linearizing sensor {i} at sample {k}"))?;
            messages.push(lin.obs);
            states.push(lin.a);
        }
        Ok(Some(StreamSample { messages, states }))
    }

    fn stream_outcome(&self, run: &StreamRun) -> Result<TrackOutcome> {
        // max_chunk == 1 makes every boundary a per-sample posterior
        let estimates: Vec<(f64, f64)> = run
            .boundaries
            .iter()
            .map(|b| (b.mean[0].re, b.mean[1].re))
            .collect();
        let diverged = estimates.iter().any(|e| !e.0.is_finite() || !e.1.is_finite());
        Ok(TrackOutcome {
            rmse: self.problem.rmse(&estimates),
            estimates,
            rounds_total: run.samples as usize,
            diverged,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fgp::FgpConfig;
    use crate::nonlinear::{FirstOrder, SigmaPoint};

    #[test]
    fn ekf_and_ukf_both_track_on_golden() {
        let p = BearingProblem::synthetic(8, 4, 1e-4, 3);
        let ekf = p.track(&mut Session::golden(), &FirstOrder, 3).unwrap();
        let ukf = p.track(&mut Session::golden(), &SigmaPoint::default(), 3).unwrap();
        assert!(!ekf.diverged && !ukf.diverged);
        assert!(ekf.rmse < 0.05, "ekf rmse {}", ekf.rmse);
        assert!(ukf.rmse < 0.05, "ukf rmse {}", ukf.rmse);
    }

    #[test]
    fn tracker_conforms_to_gauss_newton_reference() {
        let p = BearingProblem::synthetic(6, 4, 1e-4, 5);
        let reference = p.reference_track().unwrap();
        let ekf = p.track(&mut Session::golden(), &FirstOrder, 6).unwrap();
        let d = BearingProblem::max_deviation(&ekf.estimates, &reference);
        assert!(d < 1e-4, "EKF vs reference: {d}");
        // the UT residual widens the effective noise while the belief is
        // wide (step 0), so the UKF tracks the Jacobian reference
        // approximately, not exactly
        let ukf = p.track(&mut Session::golden(), &SigmaPoint::default(), 6).unwrap();
        let d = BearingProblem::max_deviation(&ukf.estimates, &reference);
        assert!(d < 0.05, "UKF vs reference: {d}");
    }

    #[test]
    fn device_tracks_and_caches_across_rounds_and_steps() {
        let p = BearingProblem::synthetic(5, 4, 1e-3, 7);
        let mut sim = Session::fgp_sim(FgpConfig::default());
        let out = p.track(&mut sim, &FirstOrder, 2).unwrap();
        assert!(!out.diverged);
        assert!(out.rmse < 0.15, "device rmse {}", out.rmse);
        // one shape for every round of every step: exactly one compile
        let stats = sim.cache_stats();
        assert_eq!(stats.misses, 1, "{stats:?}");
        assert_eq!(stats.hits as usize, out.rounds_total - 1, "{stats:?}");
    }
}
