//! The §III multi-program baseband receiver.
//!
//! "In order to host multiple programs in the PM, the `prg` instruction
//! was introduced ... For example a baseband receiver might store one
//! program for RLS channel estimation and another one for symbol
//! detection/equalization." — this module builds exactly that receiver
//! out of two [`Workload`]s sharing one [`Session`]:
//!
//! * [`ReceiverTraining`] — the Fig. 6 RLS chain estimating the channel
//!   from a training preamble, with a per-section additive *leakage*
//!   node (RLS exponential forgetting in graph form, see
//!   [`COV_LEAKAGE`]);
//! * [`ReceiverEqualize`] — a block-LMMSE equalizer whose state matrix
//!   is the Toeplitz matrix of the *estimated* channel, streamed in per
//!   block.
//!
//! The session's program cache plays the role the merged `prg 1`/`prg 2`
//! PM image plays on silicon: both program shapes are compiled once and
//! reused for every frame and block ([`ReceiverProblem::compile_receiver`]
//! still builds the literal merged image for the §III PM story). Scored
//! end-to-end by symbol error rate against a genie receiver that knows
//! the channel.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::compiler::{compile, CompileOptions, CompiledProgram};
use crate::engine::{bind_streamed, preload_id, Execution, Session, Workload};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::gmp::{FactorGraph, NodeKind, Schedule};
use crate::gmp::MsgId;
use crate::isa::{Instr, Program};
use crate::testutil::Rng;

use super::channel::{regressor_matrix, Constellation, MultipathChannel};

/// A frame: training preamble + payload symbols through one channel.
#[derive(Clone, Debug)]
pub struct Frame {
    /// Transmitted training symbols (known to the receiver).
    pub training: Vec<c64>,
    /// Transmitted payload symbols (ground truth for SER).
    pub payload: Vec<c64>,
    /// Received training symbols (channel + noise).
    pub rx_training: Vec<c64>,
    /// Received payload symbols.
    pub rx_payload: Vec<c64>,
}

/// The receiver scenario: channel, noise, frames.
#[derive(Clone, Debug)]
pub struct ReceiverProblem {
    /// Channel order / block size (device dimension).
    pub n: usize,
    /// AWGN variance at the receiver.
    pub noise_var: f64,
    /// The frequency-selective channel (hidden from the receiver).
    pub channel: MultipathChannel,
    /// Frames to process.
    pub frames: Vec<Frame>,
    /// Constellation of training and payload symbols.
    pub constellation: Constellation,
}

/// Host-side covariance floor: observation covariances below ~20 LSBs of
/// the Q5.10 datapath make the Faddeev pivots quantization-dominated
/// (saturation blow-up, see E9). Real fixed-point receivers regularize
/// the same way; the floor only weakens the (already optimistic) noise
/// model, it never changes the data.
const OBS_COV_FLOOR: f64 = 0.02;

/// Per-section diagonal leakage added to the running posterior — the
/// fixed-point equivalent of RLS exponential forgetting (keeps the
/// quantized covariance PSD and away from the LSB collapse of E9).
/// Expressed as an additive node fed by a preloaded zero-mean message,
/// so the forgetting is part of the compiled program rather than
/// host-side slot fiddling.
const COV_LEAKAGE: f64 = 0.01;

/// End-to-end receiver outcome.
#[derive(Clone, Debug)]
pub struct ReceiverOutcome {
    /// Channel-estimate relative MSE after training.
    pub channel_mse: f64,
    /// Payload symbol errors / payload symbols.
    pub ser: f64,
    /// Same receiver with genie channel knowledge (lower bound).
    pub genie_ser: f64,
    /// Total simulated device cycles across both program shapes.
    pub cycles: u64,
}

/// Channel estimation over one frame's preamble.
#[derive(Clone, Debug)]
pub struct ReceiverTraining<'p> {
    /// The receiver scenario.
    pub problem: &'p ReceiverProblem,
    /// Which frame's preamble to train on.
    pub frame: usize,
}

/// Training outcome.
#[derive(Clone, Debug)]
pub struct TrainingOutcome {
    /// Channel estimate after the preamble.
    pub h_hat: Vec<c64>,
    /// MSE of the estimate against the true taps.
    pub channel_mse: f64,
}

/// Block-LMMSE equalization of one payload block through a given
/// channel matrix (estimated or genie).
#[derive(Clone, Debug)]
pub struct ReceiverEqualize<'p> {
    /// The receiver scenario.
    pub problem: &'p ReceiverProblem,
    /// Channel matrix the equalizer assumes (estimated or genie).
    pub h: CMatrix,
    /// Received payload block.
    pub rx_block: Vec<c64>,
    /// Transmitted payload block (ground truth for SER).
    pub tx_block: Vec<c64>,
}

/// Equalization outcome for one block.
#[derive(Clone, Debug)]
pub struct EqualizeOutcome {
    /// Hard symbol decisions.
    pub decisions: Vec<c64>,
    /// Decision errors against the transmitted block.
    pub symbol_errors: usize,
}

impl ReceiverProblem {
    /// Generate a random multi-frame receiver scenario.
    pub fn synthetic(
        n: usize,
        frames: usize,
        training_len: usize,
        payload_len: usize,
        noise_var: f64,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let mut channel = MultipathChannel::random(&mut rng, n, 0.1);
        channel.taps[0] = channel.taps[0] + c64::new(0.7, 0.0); // dominant tap
        let constellation = Constellation::Qpsk;
        let mut out_frames = Vec::with_capacity(frames);
        for _ in 0..frames {
            let training: Vec<c64> =
                (0..training_len).map(|_| constellation.draw(&mut rng)).collect();
            let payload: Vec<c64> =
                (0..payload_len).map(|_| constellation.draw(&mut rng)).collect();
            let rx_training = channel.transmit(&mut rng, &training, noise_var);
            let rx_payload = channel.transmit(&mut rng, &payload, noise_var);
            out_frames.push(Frame { training, payload, rx_training, rx_payload });
        }
        ReceiverProblem { n, noise_var, channel, frames: out_frames, constellation }
    }

    /// Compile both programs into ONE program-memory image (§III).
    ///
    /// Returns (image holder, RLS program contract, LMMSE program
    /// contract). The LMMSE program is compiled with `prg 2` and its
    /// state (the estimated-channel Toeplitz matrix) in a streamed slot.
    pub fn compile_receiver(&self) -> Result<(Program, CompiledProgram, CompiledProgram)> {
        // program 1: RLS over the training length
        let regressors: Vec<CMatrix> = (0..self.frames[0].training.len())
            .map(|i| regressor_matrix(&self.frames[0].training, i, self.n))
            .collect();
        let mut g1 = FactorGraph::new();
        g1.rls_chain(self.n, &regressors);
        let s1 = Schedule::forward_sweep(&g1);
        let rls = compile(&g1, &s1, &CompileOptions { program_id: 1, ..Default::default() })
            .context("compiling RLS program")?;

        // program 2: one compound node (LMMSE block equalizer), H streamed
        let mut g2 = FactorGraph::new();
        g2.rls_chain(self.n, &[CMatrix::identity(self.n)]);
        let s2 = Schedule::forward_sweep(&g2);
        let lmmse = compile(&g2, &s2, &CompileOptions { program_id: 2, ..Default::default() })
            .context("compiling LMMSE program")?;

        // merge the PM images: program 1 instructions (sans halt) + halt,
        // then program 2's stream
        let mut instrs: Vec<Instr> = rls.program.instrs.clone();
        instrs.extend(lmmse.program.instrs.iter().cloned());
        let merged = Program::new(instrs);
        merged.validate().context("merged PM image")?;
        Ok((merged, rls, lmmse))
    }

    /// Run the full receive chain (training + per-block equalization,
    /// estimated channel and genie bound) on whatever engine the session
    /// drives.
    pub fn run(&self, session: &mut Session) -> Result<ReceiverOutcome> {
        let mut cycles = 0u64;
        let mut channel_mse_acc = 0.0;
        let mut errors = 0usize;
        let mut genie_errors = 0usize;
        let mut total_syms = 0usize;

        let genie_toeplitz = self.channel.toeplitz(self.n);
        for fi in 0..self.frames.len() {
            // ---- program shape 1: channel estimation over the preamble
            let training = ReceiverTraining { problem: self, frame: fi };
            let rep = session.run(&training)?;
            cycles += rep.cycles;
            channel_mse_acc += rep.outcome.channel_mse;
            let h_toeplitz =
                MultipathChannel { taps: rep.outcome.h_hat.clone() }.toeplitz(self.n);

            // ---- program shape 2: equalize the payload block-by-block
            let frame = &self.frames[fi];
            for (tx_blk, rx_blk) in
                frame.payload.chunks(self.n).zip(frame.rx_payload.chunks(self.n))
            {
                if tx_blk.len() < self.n {
                    break; // partial tail block not equalized
                }
                for (est_h, err_counter) in
                    [(&h_toeplitz, &mut errors), (&genie_toeplitz, &mut genie_errors)]
                {
                    let eq = ReceiverEqualize {
                        problem: self,
                        h: est_h.clone(),
                        rx_block: rx_blk.to_vec(),
                        tx_block: tx_blk.to_vec(),
                    };
                    let rep = session.run(&eq)?;
                    cycles += rep.cycles;
                    *err_counter += rep.outcome.symbol_errors;
                }
                total_syms += self.n;
            }
        }

        Ok(ReceiverOutcome {
            channel_mse: channel_mse_acc / self.frames.len() as f64,
            ser: errors as f64 / total_syms.max(1) as f64,
            genie_ser: genie_errors as f64 / total_syms.max(1) as f64,
            cycles,
        })
    }
}

impl Workload for ReceiverTraining<'_> {
    type Outcome = TrainingOutcome;

    fn name(&self) -> &str {
        "receiver_training"
    }

    fn n(&self) -> usize {
        self.problem.n
    }

    /// The RLS chain with an additive leakage node between sections:
    /// section 0 is a plain compound observation; sections k>0 first add
    /// the zero-mean leakage message, then observe.
    fn model(&self) -> Result<(FactorGraph, Schedule)> {
        let n = self.problem.n;
        let frame = &self.problem.frames[self.frame];
        let mut g = FactorGraph::new();
        let prior = g.add_input_edge(n, "msg_prior");
        let leak = g.add_input_edge(n, "msg_leak");
        let mut prev = prior;
        for k in 0..frame.rx_training.len() {
            let sid = g.add_streamed_state(0, regressor_matrix(&frame.training, k, n));
            let obs = g.add_streamed_input_edge(n, 0, format!("msg_Y{k}"));
            if k > 0 {
                let leaked = g.add_edge(n, format!("leaked{k}"));
                g.add_node(NodeKind::Add, vec![prev, leak], leaked, format!("leak{k}"));
                prev = leaked;
            }
            let post = g.add_edge(n, format!("post{k}"));
            g.add_node(
                NodeKind::CompoundObservation { a: sid },
                vec![prev, obs],
                post,
                format!("sec{k}"),
            );
            prev = post;
        }
        g.mark_output(prev);
        let s = Schedule::forward_sweep(&g);
        Ok((g, s))
    }

    fn inputs(
        &self,
        graph: &FactorGraph,
        schedule: &Schedule,
    ) -> Result<HashMap<MsgId, GaussMessage>> {
        let n = self.problem.n;
        let frame = &self.problem.frames[self.frame];
        let noise_var = self.problem.noise_var.max(OBS_COV_FLOOR);
        let mut map = HashMap::new();
        map.insert(preload_id(graph, schedule, "msg_prior")?, GaussMessage::isotropic(n, 1.0));
        map.insert(
            preload_id(graph, schedule, "msg_leak")?,
            GaussMessage::isotropic(n, COV_LEAKAGE),
        );
        let obs: Vec<GaussMessage> = frame
            .rx_training
            .iter()
            .map(|rx| {
                let mut y = vec![c64::ZERO; n];
                y[0] = *rx;
                GaussMessage::observation(&y, noise_var)
            })
            .collect();
        bind_streamed(graph, schedule, &obs, &mut map)?;
        Ok(map)
    }

    fn outcome(&self, exec: &Execution) -> Result<TrainingOutcome> {
        let h_hat = exec.output()?.mean.clone();
        let num: f64 = self
            .problem
            .channel
            .taps
            .iter()
            .zip(&h_hat)
            .map(|(a, b)| (*a - *b).abs2())
            .sum();
        let den: f64 = self.problem.channel.taps.iter().map(|a| a.abs2()).sum();
        Ok(TrainingOutcome { h_hat, channel_mse: num / den })
    }

    fn quality(&self, outcome: &TrainingOutcome) -> f64 {
        outcome.channel_mse
    }

    fn tolerance(&self) -> f64 {
        0.25
    }
}

impl Workload for ReceiverEqualize<'_> {
    type Outcome = EqualizeOutcome;

    fn name(&self) -> &str {
        "receiver_equalize"
    }

    fn n(&self) -> usize {
        self.problem.n
    }

    fn model(&self) -> Result<(FactorGraph, Schedule)> {
        let mut g = FactorGraph::new();
        g.rls_chain(self.problem.n, std::slice::from_ref(&self.h));
        let s = Schedule::forward_sweep(&g);
        Ok((g, s))
    }

    fn inputs(
        &self,
        graph: &FactorGraph,
        schedule: &Schedule,
    ) -> Result<HashMap<MsgId, GaussMessage>> {
        let n = self.problem.n;
        let mut map = HashMap::new();
        map.insert(
            preload_id(graph, schedule, "msg_prior")?,
            GaussMessage::isotropic(n, 0.25),
        );
        let obs = GaussMessage::observation(
            &self.rx_block,
            self.problem.noise_var.max(OBS_COV_FLOOR),
        );
        bind_streamed(graph, schedule, std::slice::from_ref(&obs), &mut map)?;
        Ok(map)
    }

    fn outcome(&self, exec: &Execution) -> Result<EqualizeOutcome> {
        let est = exec.output()?.mean.clone();
        let decisions: Vec<c64> = est
            .iter()
            .map(|z| self.problem.constellation.slice(*z))
            .collect();
        let symbol_errors = decisions
            .iter()
            .zip(&self.tx_block)
            .filter(|(d, t)| (**d - **t).abs() > 1e-9)
            .count();
        Ok(EqualizeOutcome { decisions, symbol_errors })
    }

    fn quality(&self, outcome: &EqualizeOutcome) -> f64 {
        outcome.symbol_errors as f64 / self.problem.n as f64
    }

    /// Per-block SER is quantized to multiples of 1/n; allow one extra
    /// wrong symbol against golden.
    fn tolerance(&self) -> f64 {
        0.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fgp::FgpConfig;

    #[test]
    fn merged_pm_hosts_both_programs() {
        let p = ReceiverProblem::synthetic(4, 1, 8, 8, 0.01, 3);
        let (merged, _, _) = p.compile_receiver().unwrap();
        assert_eq!(merged.start_of(1).is_some(), true);
        assert_eq!(merged.start_of(2).is_some(), true);
        assert!(merged.to_image().bits() < 64 * 1024);
    }

    #[test]
    fn receiver_decodes_at_high_snr() {
        let p = ReceiverProblem::synthetic(4, 2, 24, 16, 0.005, 7);
        let mut sim = Session::fgp_sim(FgpConfig::default());
        let out = p.run(&mut sim).unwrap();
        assert!(out.channel_mse < 0.3, "channel MSE {}", out.channel_mse);
        // estimated-channel SER within reach of the genie bound
        assert!(out.ser <= out.genie_ser + 0.15, "ser {} genie {}", out.ser, out.genie_ser);
        assert!(out.cycles > 0);
        // one compile per program shape, everything else cache hits
        let stats = sim.cache_stats();
        assert_eq!(stats.misses, 2, "{stats:?}");
        assert!(stats.hits > 0, "{stats:?}");
    }

    #[test]
    fn ser_degrades_with_noise() {
        let mut sim = Session::fgp_sim(FgpConfig::default());
        let clean = ReceiverProblem::synthetic(4, 1, 24, 24, 0.002, 9)
            .run(&mut sim)
            .unwrap();
        let noisy = ReceiverProblem::synthetic(4, 1, 24, 24, 0.3, 9)
            .run(&mut sim)
            .unwrap();
        assert!(clean.ser <= noisy.ser + 1e-9, "clean {} noisy {}", clean.ser, noisy.ser);
    }

    #[test]
    fn golden_receiver_is_a_valid_reference() {
        let p = ReceiverProblem::synthetic(4, 1, 24, 16, 0.005, 21);
        let golden = p.run(&mut Session::golden()).unwrap();
        let fgp = p.run(&mut Session::fgp_sim(FgpConfig::default())).unwrap();
        assert!(golden.cycles == 0 && fgp.cycles > 0);
        assert!(fgp.channel_mse <= golden.channel_mse + 0.25);
    }
}
