//! The §III multi-program baseband receiver.
//!
//! "In order to host multiple programs in the PM, the `prg` instruction
//! was introduced ... For example a baseband receiver might store one
//! program for RLS channel estimation and another one for symbol
//! detection/equalization." — this module builds exactly that receiver:
//!
//! * **program 1**: the Fig. 6 RLS chain estimating the channel from a
//!   training preamble;
//! * **program 2**: a block-LMMSE equalizer whose state matrix is the
//!   Toeplitz matrix of the *estimated* channel, streamed in by the
//!   host between frames.
//!
//! One PM image holds both (`prg 1` / `prg 2` directory); the host
//! alternates `start_program` commands per frame — the full
//! hardware/software interaction story of §III–IV, scored end-to-end by
//! symbol error rate against a genie receiver that knows the channel.

use anyhow::{Context, Result};

use crate::compiler::{compile, CompileOptions, CompiledProgram};
use crate::fgp::processor::NoFeed;
use crate::fgp::{Fgp, FgpConfig, MessageMemory, StateMemory};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::gmp::{FactorGraph, Schedule};
use crate::isa::{Instr, Program};
use crate::testutil::Rng;

use super::channel::{regressor_matrix, Constellation, MultipathChannel};

/// A frame: training preamble + payload symbols through one channel.
#[derive(Clone, Debug)]
pub struct Frame {
    pub training: Vec<c64>,
    pub payload: Vec<c64>,
    pub rx_training: Vec<c64>,
    pub rx_payload: Vec<c64>,
}

/// The receiver scenario: channel, noise, frames.
#[derive(Clone, Debug)]
pub struct ReceiverProblem {
    pub n: usize,
    pub noise_var: f64,
    pub channel: MultipathChannel,
    pub frames: Vec<Frame>,
    pub constellation: Constellation,
}

/// Host-side covariance floor: observation covariances below ~20 LSBs of
/// the Q5.10 datapath make the Faddeev pivots quantization-dominated
/// (saturation blow-up, see E9). Real fixed-point receivers regularize
/// the same way; the floor only weakens the (already optimistic) noise
/// model, it never changes the data.
const OBS_COV_FLOOR: f64 = 0.02;

/// Per-section diagonal leakage added to the running posterior by the
/// host between sections — the fixed-point equivalent of RLS exponential
/// forgetting (keeps the quantized covariance PSD and away from the LSB
/// collapse of E9). Applied through the Data-in/out ports like any other
/// host-side message manipulation.
const COV_LEAKAGE: f64 = 0.01;

/// End-to-end receiver outcome.
#[derive(Clone, Debug)]
pub struct ReceiverOutcome {
    /// Channel-estimate relative MSE after training.
    pub channel_mse: f64,
    /// Payload symbol errors / payload symbols.
    pub ser: f64,
    /// Same receiver with genie channel knowledge (lower bound).
    pub genie_ser: f64,
    /// Total simulated device cycles across both programs.
    pub cycles: u64,
}

impl ReceiverProblem {
    pub fn synthetic(
        n: usize,
        frames: usize,
        training_len: usize,
        payload_len: usize,
        noise_var: f64,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let mut channel = MultipathChannel::random(&mut rng, n, 0.1);
        channel.taps[0] = channel.taps[0] + c64::new(0.7, 0.0); // dominant tap
        let constellation = Constellation::Qpsk;
        let mut out_frames = Vec::with_capacity(frames);
        for _ in 0..frames {
            let training: Vec<c64> =
                (0..training_len).map(|_| constellation.draw(&mut rng)).collect();
            let payload: Vec<c64> =
                (0..payload_len).map(|_| constellation.draw(&mut rng)).collect();
            let rx_training = channel.transmit(&mut rng, &training, noise_var);
            let rx_payload = channel.transmit(&mut rng, &payload, noise_var);
            out_frames.push(Frame { training, payload, rx_training, rx_payload });
        }
        ReceiverProblem { n, noise_var, channel, frames: out_frames, constellation }
    }

    /// Compile both programs into ONE program-memory image (§III).
    ///
    /// Returns (image holder, RLS program contract, LMMSE program
    /// contract). The LMMSE program is compiled with `prg 2` and its
    /// state (the estimated-channel Toeplitz matrix) in a streamed slot.
    pub fn compile_receiver(&self) -> Result<(Program, CompiledProgram, CompiledProgram)> {
        // program 1: RLS over the training length
        let regressors: Vec<CMatrix> = (0..self.frames[0].training.len())
            .map(|i| regressor_matrix(&self.frames[0].training, i, self.n))
            .collect();
        let mut g1 = FactorGraph::new();
        g1.rls_chain(self.n, &regressors);
        let s1 = Schedule::forward_sweep(&g1);
        let rls = compile(&g1, &s1, &CompileOptions { program_id: 1, ..Default::default() })
            .context("compiling RLS program")?;

        // program 2: one compound node (LMMSE block equalizer), H streamed
        let mut g2 = FactorGraph::new();
        g2.rls_chain(self.n, &[CMatrix::identity(self.n)]);
        let s2 = Schedule::forward_sweep(&g2);
        let lmmse = compile(&g2, &s2, &CompileOptions { program_id: 2, ..Default::default() })
            .context("compiling LMMSE program")?;

        // merge the PM images: program 1 instructions (sans halt) + halt,
        // then program 2's stream
        let mut instrs: Vec<Instr> = rls.program.instrs.clone();
        instrs.extend(lmmse.program.instrs.iter().cloned());
        let merged = Program::new(instrs);
        merged.validate().context("merged PM image")?;
        Ok((merged, rls, lmmse))
    }

    /// Run the full receive chain on the device.
    pub fn run_on_fgp(&self) -> Result<ReceiverOutcome> {
        let (merged, rls, lmmse) = self.compile_receiver()?;
        let mut fgp = Fgp::new(FgpConfig::default());
        fgp.pm.load(&merged.to_image())?;

        let mut cycles = 0u64;
        let mut channel_mse_acc = 0.0;
        let mut errors = 0usize;
        let mut genie_errors = 0usize;
        let mut total_syms = 0usize;

        for frame in &self.frames {
            // ---- program 1: channel estimation over the preamble
            let prior = GaussMessage::isotropic(self.n, 1.0);
            fgp.msgmem.write_message(rls.memmap.preloads[0].1, &prior);
            let obs_slot = rls.memmap.streams[0].1;
            let st_slot = rls.memmap.state_streams[0].1;
            let training = frame.training.clone();
            let rx_training = frame.rx_training.clone();
            let n = self.n;
            let noise_var = self.noise_var.max(OBS_COV_FLOOR);
            let state_slot = rls.memmap.preloads[0].1; // posterior lives in place
            let mut feed =
                move |s: usize, mem: &mut MessageMemory, st: &mut StateMemory| -> bool {
                    if s >= rx_training.len() {
                        return false;
                    }
                    if s > 0 {
                        // RLS forgetting: leak the posterior covariance so
                        // quantization cannot collapse it (see COV_LEAKAGE)
                        let mut post = mem.read_message(state_slot);
                        post.cov = post
                            .cov
                            .add(&CMatrix::scaled_identity(n, COV_LEAKAGE));
                        mem.write_message(state_slot, &post);
                    }
                    let mut y = vec![c64::ZERO; n];
                    y[0] = rx_training[s];
                    mem.write_message(obs_slot, &GaussMessage::observation(&y, noise_var));
                    st.write_matrix(st_slot, &regressor_matrix(&training, s, n));
                    true
                };
            let stats = fgp.run_program(1, &mut feed)?;
            cycles += stats.cycles;
            let h_est = fgp.msgmem.read_message(rls.memmap.outputs[0].1).mean;

            let num: f64 = self
                .channel
                .taps
                .iter()
                .zip(&h_est)
                .map(|(a, b)| (*a - *b).abs2())
                .sum();
            let den: f64 = self.channel.taps.iter().map(|a| a.abs2()).sum();
            channel_mse_acc += num / den;

            // ---- program 2: equalize the payload block-by-block
            let h_toeplitz = MultipathChannel { taps: h_est.clone() }.toeplitz(self.n);
            let genie_toeplitz = self.channel.toeplitz(self.n);
            for block in frame.payload.chunks(self.n).zip(frame.rx_payload.chunks(self.n)) {
                let (tx_blk, rx_blk) = block;
                if tx_blk.len() < self.n {
                    break; // partial tail block not equalized
                }
                for (est_h, err_counter) in
                    [(&h_toeplitz, &mut errors), (&genie_toeplitz, &mut genie_errors)]
                {
                    fgp.msgmem.write_message(
                        lmmse.memmap.preloads[0].1,
                        &GaussMessage::isotropic(self.n, 0.25),
                    );
                    fgp.msgmem.write_message(
                        lmmse.memmap.streams[0].1,
                        &GaussMessage::observation(rx_blk, self.noise_var.max(OBS_COV_FLOOR)),
                    );
                    fgp.statemem.write_matrix(lmmse.memmap.state_streams[0].1, est_h);
                    let stats = fgp.run_program(2, &mut NoFeed)?;
                    cycles += stats.cycles;
                    let est = fgp.msgmem.read_message(lmmse.memmap.outputs[0].1).mean;
                    for (z, tx) in est.iter().zip(tx_blk) {
                        let dec = self.constellation.slice(*z);
                        if (dec - *tx).abs() > 1e-9 {
                            *err_counter += 1;
                        }
                    }
                }
                total_syms += self.n;
            }
        }

        Ok(ReceiverOutcome {
            channel_mse: channel_mse_acc / self.frames.len() as f64,
            ser: errors as f64 / total_syms.max(1) as f64,
            genie_ser: genie_errors as f64 / total_syms.max(1) as f64,
            cycles,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merged_pm_hosts_both_programs() {
        let p = ReceiverProblem::synthetic(4, 1, 8, 8, 0.01, 3);
        let (merged, _, _) = p.compile_receiver().unwrap();
        assert_eq!(merged.start_of(1).is_some(), true);
        assert_eq!(merged.start_of(2).is_some(), true);
        assert!(merged.to_image().bits() < 64 * 1024);
    }

    #[test]
    fn receiver_decodes_at_high_snr() {
        let p = ReceiverProblem::synthetic(4, 2, 24, 16, 0.005, 7);
        let out = p.run_on_fgp().unwrap();
        assert!(out.channel_mse < 0.3, "channel MSE {}", out.channel_mse);
        // estimated-channel SER within reach of the genie bound
        assert!(out.ser <= out.genie_ser + 0.15, "ser {} genie {}", out.ser, out.genie_ser);
        assert!(out.cycles > 0);
    }

    #[test]
    fn ser_degrades_with_noise() {
        let clean = ReceiverProblem::synthetic(4, 1, 24, 24, 0.002, 9)
            .run_on_fgp()
            .unwrap();
        let noisy = ReceiverProblem::synthetic(4, 1, 24, 24, 0.3, 9)
            .run_on_fgp()
            .unwrap();
        assert!(clean.ser <= noisy.ser + 1e-9, "clean {} noisy {}", clean.ser, noisy.ser);
    }
}

