//! 2-D grid smoothing/denoising as loopy GBP — the canonical cyclic
//! workload (every interior plaquette of the grid is a cycle, so the
//! paper's scheduled compiler cannot serve it; `gbp` can, while every
//! inner update still runs on the device).
//!
//! A scalar field is observed pixel-wise in Gaussian noise; smoothness
//! factors tie 4-neighbours together. The model is the classic Gaussian
//! MRF: unary factors `y_rc = x_rc + v` observe the **full embedded
//! state** (the field in component 0, calibration zeros in the unused
//! components — full-rank anchoring keeps the synchronous iteration
//! contractive on every component), pairwise factors
//! `x_neighbour = x + w`. All operands stay inside the device's
//! input-scaling contract (field within ±0.5, covariances ≲ 1).

use anyhow::Result;

use crate::gbp::{solve, GbpModel, GbpOptions, GbpReport, RoundExecutor, VarId};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::testutil::Rng;

/// A grid denoising problem (field in component 0 of an n-dim state).
#[derive(Clone, Debug)]
pub struct GridDenoise {
    /// Grid rows.
    pub rows: usize,
    /// Grid columns.
    pub cols: usize,
    /// State dimension (4 = the device size).
    pub n: usize,
    /// True field, row-major.
    pub truth: Vec<f64>,
    /// Noisy pixel observations, row-major.
    pub noisy: Vec<f64>,
    /// Observation noise variance.
    pub obs_var: f64,
    /// Smoothness (pairwise process) variance — smaller couples harder.
    pub smooth_var: f64,
    /// Weak proper prior variance per variable (anchors the unobserved
    /// state components so the joint information matrix stays proper).
    pub prior_var: f64,
}

/// Denoising outcome.
#[derive(Clone, Debug)]
pub struct GridOutcome {
    /// The underlying GBP solve report (iterations, stop reason).
    pub report: GbpReport,
    /// Posterior field estimate, row-major.
    pub estimate: Vec<f64>,
    /// RMSE of the estimate against the true field.
    pub rmse: f64,
    /// RMSE of the raw observations (the number to beat).
    pub noisy_rmse: f64,
}

impl GridDenoise {
    /// A smooth synthetic field (low-frequency sinusoid within ±0.35)
    /// observed in Gaussian noise.
    pub fn synthetic(rows: usize, cols: usize, obs_var: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut truth = Vec::with_capacity(rows * cols);
        let mut noisy = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                // half a period across each axis: neighbour steps stay
                // well below the noise floor, so smoothing pays off
                let t = 0.35
                    * (std::f64::consts::PI * (r as f64 + 0.5) / rows as f64).sin()
                    * (std::f64::consts::PI * (c as f64 + 0.5) / cols as f64).cos();
                truth.push(t);
                noisy.push(t + rng.normal() * obs_var.sqrt());
            }
        }
        GridDenoise {
            rows,
            cols,
            n: crate::paper::N,
            truth,
            noisy,
            obs_var,
            smooth_var: 0.05,
            prior_var: 1.0,
        }
    }

    fn at(&self, r: usize, c: usize) -> usize {
        r * self.cols + c
    }

    /// Build the Gaussian-MRF model: one variable per pixel, a weak
    /// prior + a full-state unary observation each (the field in
    /// component 0, calibration zeros elsewhere — full-rank anchoring
    /// keeps the synchronous iteration contractive on every component),
    /// and smoothness links between 4-neighbours (rightward and
    /// downward, covering every edge once).
    pub fn model(&self) -> Result<GbpModel> {
        let n = self.n;
        let mut m = GbpModel::new(n);
        let mut ids = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                let v = m.add_variable(
                    Some(GaussMessage::isotropic(n, self.prior_var)),
                    format!("px{r}_{c}"),
                )?;
                let mut y = vec![c64::ZERO; n];
                y[0] = c64::new(self.noisy[self.at(r, c)], 0.0);
                m.add_unary(
                    v,
                    CMatrix::identity(n),
                    GaussMessage::new(y, CMatrix::scaled_identity(n, self.obs_var)),
                )?;
                ids.push(v);
            }
        }
        let smooth = GaussMessage::isotropic(n, self.smooth_var);
        for r in 0..self.rows {
            for c in 0..self.cols {
                if c + 1 < self.cols {
                    m.add_pairwise(
                        ids[self.at(r, c)],
                        ids[self.at(r, c + 1)],
                        CMatrix::identity(n),
                        smooth.clone(),
                    )?;
                }
                if r + 1 < self.rows {
                    m.add_pairwise(
                        ids[self.at(r, c)],
                        ids[self.at(r + 1, c)],
                        CMatrix::identity(n),
                        smooth.clone(),
                    )?;
                }
            }
        }
        Ok(m)
    }

    fn rmse_of(&self, field: &[f64]) -> f64 {
        let se: f64 = field
            .iter()
            .zip(&self.truth)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        (se / self.truth.len() as f64).sqrt()
    }

    /// RMSE of the raw observations.
    pub fn noisy_rmse(&self) -> f64 {
        self.rmse_of(&self.noisy)
    }

    /// Solve with loopy GBP through any executor.
    pub fn run(&self, exec: &mut dyn RoundExecutor, opts: GbpOptions) -> Result<GridOutcome> {
        let report = solve(self.model()?, opts, exec)?;
        let estimate: Vec<f64> = report.beliefs.iter().map(|b| b.mean[0].re).collect();
        let rmse = self.rmse_of(&estimate);
        Ok(GridOutcome { report, estimate, rmse, noisy_rmse: self.noisy_rmse() })
    }

    /// Marginal of pixel (r, c) from a report.
    pub fn marginal<'r>(&self, report: &'r GbpReport, r: usize, c: usize) -> &'r GaussMessage {
        &report.beliefs[self.at(r, c)]
    }

    /// Variable id of pixel (r, c).
    pub fn var(&self, r: usize, c: usize) -> VarId {
        VarId(self.at(r, c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Session;

    #[test]
    fn grid_model_is_cyclic_and_valid() {
        let p = GridDenoise::synthetic(3, 3, 0.04, 7);
        let m = p.model().unwrap();
        assert_eq!(m.num_vars(), 9);
        // 9 unary + 12 pairwise
        assert_eq!(m.num_factors(), 9 + 12);
        assert!(m.has_cycle(), "a 2-D grid has plaquette cycles");
        m.validate().unwrap();
    }

    #[test]
    fn denoising_beats_the_raw_observations() {
        let p = GridDenoise::synthetic(4, 4, 0.04, 11);
        let out = p.run(&mut Session::golden(), GbpOptions::default()).unwrap();
        assert!(out.report.converged(), "stop {:?}", out.report.stop);
        assert!(
            out.rmse < out.noisy_rmse,
            "smoothing must denoise: rmse {} vs noisy {}",
            out.rmse,
            out.noisy_rmse
        );
    }

    #[test]
    fn grid_means_match_dense_solve_on_golden() {
        let p = GridDenoise::synthetic(3, 3, 0.04, 13);
        let model = p.model().unwrap();
        let dense = model.dense_marginals().unwrap();
        let out = p.run(&mut Session::golden(), GbpOptions::default()).unwrap();
        assert!(out.report.converged());
        for (got, want) in out.report.beliefs.iter().zip(&dense) {
            let mean_err = got
                .mean
                .iter()
                .zip(&want.mean)
                .map(|(a, b)| (*a - *b).abs())
                .fold(0.0, f64::max);
            assert!(mean_err < 1e-5, "mean err {mean_err}");
        }
    }
}
