//! Fixed-interval Gaussian smoother as two-pass GMP (§I ref [3]).
//!
//! The forward pass is the Kalman filter (moment-form messages, compound
//! observation nodes); the backward pass sends weight-form messages
//! against the arrows (compound equality-multiplier nodes, the Fig. 1
//! dual); the smoothed marginal at each step is the **equality node** of
//! the two directions. This is the only app exercising all five node
//! update rules — and both message parameterizations — in one algorithm.

use anyhow::Result;

use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::gmp::nodes;
use crate::testutil::Rng;

/// A linear-Gaussian state-space smoothing problem.
#[derive(Clone, Debug)]
pub struct SmootherProblem {
    pub steps: usize,
    pub a: CMatrix,
    pub c: CMatrix,
    pub q_var: f64,
    pub r_var: f64,
    pub truth: Vec<Vec<c64>>,
    pub observations: Vec<GaussMessage>,
    pub prior: GaussMessage,
}

/// Smoothing outcome.
#[derive(Clone, Debug)]
pub struct SmootherOutcome {
    /// Filtered (forward-only) position RMSE over the trajectory.
    pub filter_rmse: f64,
    /// Smoothed (forward+backward) position RMSE.
    pub smoother_rmse: f64,
    /// Smoothed marginals.
    pub marginals: Vec<GaussMessage>,
}

impl SmootherProblem {
    /// Scalar random-walk observed in noise, embedded in n=4 (device
    /// size) with the walk in component 0.
    pub fn synthetic(steps: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let n = 4;
        let a = CMatrix::identity(n); // random walk
        let mut c = CMatrix::zeros(n, n);
        c[(0, 0)] = c64::ONE;
        let q_var: f64 = 0.02;
        let r_var: f64 = 0.1;
        let mut x = vec![c64::ZERO; n];
        let mut truth = Vec::with_capacity(steps);
        let mut observations = Vec::with_capacity(steps);
        for _ in 0..steps {
            x[0] = x[0] + c64::new(rng.normal() * q_var.sqrt(), 0.0);
            let mut y = vec![c64::ZERO; n];
            y[0] = x[0] + c64::new(rng.normal() * r_var.sqrt(), 0.0);
            truth.push(x.clone());
            observations.push(GaussMessage::observation(&y, r_var));
        }
        SmootherProblem {
            steps,
            a,
            c,
            q_var,
            r_var,
            truth,
            observations,
            prior: GaussMessage::isotropic(n, 1.0),
        }
    }

    /// Forward filtering pass; returns the per-step posteriors.
    fn forward(&self) -> Result<Vec<GaussMessage>> {
        let n = self.prior.dim();
        let q = GaussMessage::isotropic(n, self.q_var);
        let mut msg = self.prior.clone();
        let mut out = Vec::with_capacity(self.steps);
        for y in &self.observations {
            let pred = nodes::add(&nodes::multiply(&msg, &self.a), &q);
            msg = nodes::compound_observation(&pred, y, &self.c, true)?;
            out.push(msg.clone());
        }
        Ok(out)
    }

    /// Backward pass in weight form; entry k is the message flowing INTO
    /// step k from the future (vague at the last step).
    fn backward(&self) -> Result<Vec<GaussMessage>> {
        let n = self.prior.dim();
        let q = GaussMessage::isotropic(n, self.q_var);
        // start from a vague message (no future information)
        let mut back = GaussMessage::isotropic(n, 1e4);
        let mut out = vec![back.clone(); self.steps];
        for k in (0..self.steps).rev() {
            // combine the observation at k with the future message
            let obs_post =
                nodes::compound_observation(&back, &self.observations[k], &self.c, true)?;
            out[k] = back.clone();
            // propagate backwards through the dynamics: X_{k-1} = A^{-1}(X_k - W)
            // For the random walk (A = I) this is an additive widening.
            let widened = nodes::add(&obs_post, &q);
            let a_inv = self
                .a
                .inverse()
                .ok_or_else(|| anyhow::anyhow!("transition matrix not invertible"))?;
            back = nodes::multiply(&widened, &a_inv);
        }
        Ok(out)
    }

    /// Two-pass smoothing; marginal at k = equality(forward_k, backward_k).
    pub fn run_golden(&self) -> Result<SmootherOutcome> {
        let forward = self.forward()?;
        let backward = self.backward()?;
        let mut marginals = Vec::with_capacity(self.steps);
        for (f, b) in forward.iter().zip(&backward) {
            marginals.push(nodes::equality(f, b)?);
        }
        let rmse = |msgs: &[GaussMessage]| {
            let se: f64 = msgs
                .iter()
                .zip(&self.truth)
                .map(|(m, t)| (m.mean[0] - t[0]).abs2())
                .sum();
            (se / self.steps as f64).sqrt()
        };
        Ok(SmootherOutcome {
            filter_rmse: rmse(&forward),
            smoother_rmse: rmse(&marginals),
            marginals,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoother_beats_filter() {
        // the textbook property: smoothing (two-sided information) has
        // lower RMSE than filtering (one-sided) on interior states
        let mut wins = 0;
        for seed in 0..5 {
            let p = SmootherProblem::synthetic(60, 100 + seed);
            let out = p.run_golden().unwrap();
            if out.smoother_rmse <= out.filter_rmse + 1e-9 {
                wins += 1;
            }
        }
        assert!(wins >= 4, "smoother won only {wins}/5 seeds");
    }

    #[test]
    fn marginals_have_smaller_variance_than_filter() {
        let p = SmootherProblem::synthetic(40, 7);
        let forward = p.forward().unwrap();
        let out = p.run_golden().unwrap();
        // interior marginal variance <= filtered variance (equality node
        // only adds information)
        for (m, f) in out.marginals.iter().zip(&forward).take(p.steps - 1) {
            assert!(m.trace_cov() <= f.trace_cov() + 1e-6);
        }
    }

    #[test]
    fn smoother_tracks_truth() {
        let p = SmootherProblem::synthetic(80, 11);
        let out = p.run_golden().unwrap();
        assert!(out.smoother_rmse < 0.25, "rmse {}", out.smoother_rmse);
    }
}
