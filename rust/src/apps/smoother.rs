//! Fixed-interval Gaussian smoother as two-pass GMP (§I ref [3]).
//!
//! The forward pass is the Kalman filter (multiplier, additive and
//! compound-observation nodes); the backward pass runs the same node
//! types against the arrows (observation conditioning, additive widening,
//! multiplication by A⁻¹); the smoothed marginal at each step fuses the
//! two directions with a compound-observation node whose state matrix is
//! the identity — algebraically the moment-form **equality** rule
//! `V = (V_f⁻¹ + V_b⁻¹)⁻¹`, expressed with the one compound kernel the
//! datapath accelerates. The whole two-pass program is a single
//! [`Workload`]: golden for long trajectories, and (for trajectories
//! whose working set fits the 64-kbit message memory) the same graph
//! runs on the cycle-accurate device.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::engine::{
    preload_id, Execution, StreamRun, StreamSample, StreamingWorkload, Workload,
};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::gmp::{FactorGraph, MsgId, NodeKind, Schedule};
use crate::testutil::Rng;

/// A linear-Gaussian state-space smoothing problem.
#[derive(Clone, Debug)]
pub struct SmootherProblem {
    /// Trajectory length in time steps.
    pub steps: usize,
    /// State-transition matrix.
    pub a: CMatrix,
    /// Observation matrix.
    pub c: CMatrix,
    /// Process-noise variance.
    pub q_var: f64,
    /// Measurement-noise variance.
    pub r_var: f64,
    /// Ground-truth states per step.
    pub truth: Vec<Vec<c64>>,
    /// Observation messages per step.
    pub observations: Vec<GaussMessage>,
    /// Prior on the initial state.
    pub prior: GaussMessage,
    /// Variance of the vague message entering the backward pass. The
    /// default 1e4 saturates to the Q5.10 rail (~16) on the device — both
    /// are "vague" relative to the ~0.1 posteriors, so the engines agree.
    pub back_var: f64,
}

/// Smoothing outcome.
#[derive(Clone, Debug)]
pub struct SmootherOutcome {
    /// Filtered (forward-only) position RMSE over the trajectory.
    pub filter_rmse: f64,
    /// Smoothed (forward+backward) position RMSE.
    pub smoother_rmse: f64,
    /// Smoothed marginals, one per step.
    pub marginals: Vec<GaussMessage>,
    /// Forward (filtered) posteriors, one per step.
    pub filtered: Vec<GaussMessage>,
}

impl SmootherProblem {
    /// Scalar random-walk observed in noise, embedded in n=4 (device
    /// size) with the walk in component 0.
    pub fn synthetic(steps: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let n = 4;
        let a = CMatrix::identity(n); // random walk
        let mut c = CMatrix::zeros(n, n);
        c[(0, 0)] = c64::ONE;
        let q_var: f64 = 0.02;
        let r_var: f64 = 0.1;
        let mut x = vec![c64::ZERO; n];
        let mut truth = Vec::with_capacity(steps);
        let mut observations = Vec::with_capacity(steps);
        for _ in 0..steps {
            x[0] = x[0] + c64::new(rng.normal() * q_var.sqrt(), 0.0);
            let mut y = vec![c64::ZERO; n];
            y[0] = x[0] + c64::new(rng.normal() * r_var.sqrt(), 0.0);
            truth.push(x.clone());
            observations.push(GaussMessage::observation(&y, r_var));
        }
        SmootherProblem {
            steps,
            a,
            c,
            q_var,
            r_var,
            truth,
            observations,
            prior: GaussMessage::isotropic(n, 1.0),
            back_var: 1e4,
        }
    }

    /// Build the two-pass graph. Observations are consumed by both the
    /// forward and the backward pass, so they are preloaded (not
    /// streamed); per-step filtered posteriors and smoothed marginals are
    /// marked as outputs.
    pub fn build_graph(&self) -> Result<(FactorGraph, Schedule)> {
        let n = self.prior.dim();
        let a_inv = self
            .a
            .inverse()
            .ok_or_else(|| anyhow!("transition matrix not invertible"))?;
        let mut g = FactorGraph::new();
        let a_sid = g.add_state(self.a.clone());
        let c_sid = g.add_state(self.c.clone());
        let ainv_sid = g.add_state(a_inv);
        let eye_sid = g.add_state(CMatrix::identity(n));

        let prior = g.add_input_edge(n, "msg_prior");
        let q = g.add_input_edge(n, "msg_Q");
        let back_init = g.add_input_edge(n, "msg_back_init");
        let obs: Vec<_> = (0..self.steps)
            .map(|k| g.add_input_edge(n, format!("msg_Y{k}")))
            .collect();

        // forward filtering pass
        let mut posts = Vec::with_capacity(self.steps);
        let mut prev = prior;
        for k in 0..self.steps {
            let pred = g.add_edge(n, format!("pred{k}"));
            g.add_node(NodeKind::Multiply { a: a_sid }, vec![prev], pred, format!("fmul{k}"));
            let noisy = g.add_edge(n, format!("noisy{k}"));
            g.add_node(NodeKind::Add, vec![pred, q], noisy, format!("fadd{k}"));
            let post = g.add_edge(n, format!("post{k}"));
            g.add_node(
                NodeKind::CompoundObservation { a: c_sid },
                vec![noisy, obs[k]],
                post,
                format!("fobs{k}"),
            );
            g.mark_output(post);
            posts.push(post);
            prev = post;
        }

        // backward pass + marginal fusion; entry k of the backward
        // message carries obs_{k+1..} (vague at the last step)
        let mut back = back_init;
        for k in (0..self.steps).rev() {
            let marg = g.add_edge(n, format!("marg{k}"));
            g.add_node(
                NodeKind::CompoundObservation { a: eye_sid },
                vec![posts[k], back],
                marg,
                format!("marg{k}"),
            );
            g.mark_output(marg);
            if k > 0 {
                let bobs = g.add_edge(n, format!("bobs{k}"));
                g.add_node(
                    NodeKind::CompoundObservation { a: c_sid },
                    vec![back, obs[k]],
                    bobs,
                    format!("bobsn{k}"),
                );
                let wide = g.add_edge(n, format!("bwide{k}"));
                g.add_node(NodeKind::Add, vec![bobs, q], wide, format!("badd{k}"));
                let next = g.add_edge(n, format!("back{}", k - 1));
                g.add_node(
                    NodeKind::Multiply { a: ainv_sid },
                    vec![wide],
                    next,
                    format!("bmul{k}"),
                );
                back = next;
            }
        }

        let s = Schedule::forward_sweep(&g);
        Ok((g, s))
    }

    /// Forward-filter-only chain for the streaming surface: the same
    /// Multiply(A) → Add(Q) → Compound(C) triplet as the batch graph's
    /// forward pass, but with observations on streamed edges (a stream
    /// consumes each observation exactly once, so nothing needs to stay
    /// resident for a backward pass).
    fn forward_chain(&self, steps: usize) -> (FactorGraph, Schedule) {
        let n = self.prior.dim();
        let mut g = FactorGraph::new();
        let a_sid = g.add_state(self.a.clone());
        let c_sid = g.add_state(self.c.clone());
        let q = g.add_input_edge(n, "msg_Q");
        let prior = g.add_input_edge(n, "msg_prior");
        let mut prev = prior;
        for k in 0..steps {
            let pred = g.add_edge(n, format!("pred{k}"));
            g.add_node(NodeKind::Multiply { a: a_sid }, vec![prev], pred, format!("fmul{k}"));
            let noisy = g.add_edge(n, format!("noisy{k}"));
            g.add_node(NodeKind::Add, vec![pred, q], noisy, format!("fadd{k}"));
            let obs = g.add_streamed_input_edge(n, 0, format!("msg_Y{k}"));
            let post = g.add_edge(n, format!("post{k}"));
            g.add_node(
                NodeKind::CompoundObservation { a: c_sid },
                vec![noisy, obs],
                post,
                format!("fobs{k}"),
            );
            prev = post;
        }
        g.mark_output(prev);
        let s = Schedule::forward_sweep(&g);
        (g, s)
    }

    fn rmse(&self, msgs: &[GaussMessage]) -> f64 {
        let se: f64 = msgs
            .iter()
            .zip(&self.truth)
            .map(|(m, t)| (m.mean[0] - t[0]).abs2())
            .sum();
        (se / self.steps as f64).sqrt()
    }
}

impl Workload for SmootherProblem {
    type Outcome = SmootherOutcome;

    fn name(&self) -> &str {
        "gaussian_smoother"
    }

    fn n(&self) -> usize {
        self.prior.dim()
    }

    fn model(&self) -> Result<(FactorGraph, Schedule)> {
        self.build_graph()
    }

    fn inputs(
        &self,
        graph: &FactorGraph,
        schedule: &Schedule,
    ) -> Result<HashMap<MsgId, GaussMessage>> {
        let n = self.n();
        let mut map = HashMap::new();
        map.insert(preload_id(graph, schedule, "msg_prior")?, self.prior.clone());
        map.insert(
            preload_id(graph, schedule, "msg_Q")?,
            GaussMessage::isotropic(n, self.q_var),
        );
        map.insert(
            preload_id(graph, schedule, "msg_back_init")?,
            GaussMessage::isotropic(n, self.back_var),
        );
        for (k, obs) in self.observations.iter().enumerate() {
            map.insert(preload_id(graph, schedule, &format!("msg_Y{k}"))?, obs.clone());
        }
        Ok(map)
    }

    fn outcome(&self, exec: &Execution) -> Result<SmootherOutcome> {
        // outputs arrive in edge-creation order (Schedule::forward_sweep
        // walks output edges by index): the T filtered posteriors from
        // the forward pass first (k ascending), then the T smoothed
        // marginals from the backward pass (k descending) — see
        // `build_graph`
        if exec.outputs.len() != 2 * self.steps {
            bail!(
                "smoother expects {} outputs (posteriors + marginals), engine returned {}",
                2 * self.steps,
                exec.outputs.len()
            );
        }
        let filtered: Vec<GaussMessage> =
            exec.outputs[..self.steps].iter().map(|(_, _, m)| m.clone()).collect();
        let mut marginals: Vec<GaussMessage> =
            exec.outputs[self.steps..].iter().map(|(_, _, m)| m.clone()).collect();
        marginals.reverse();
        Ok(SmootherOutcome {
            filter_rmse: self.rmse(&filtered),
            smoother_rmse: self.rmse(&marginals),
            marginals,
            filtered,
        })
    }

    fn quality(&self, outcome: &SmootherOutcome) -> f64 {
        outcome.smoother_rmse
    }

    /// Quantization slack for device-sized trajectories (the two-pass
    /// working set only fits the message memory for short chains).
    fn tolerance(&self) -> f64 {
        0.25
    }
}

/// Streaming (forward-only) outcome: a smoother needs the whole
/// interval, so the *streamable* half of the problem is its forward
/// Kalman filter — what an online deployment serves while samples keep
/// arriving (the backward pass runs as the batch [`Workload`] once the
/// interval closes).
#[derive(Clone, Debug)]
pub struct FilterOutcome {
    /// Filtered posterior after the final sample.
    pub final_filtered: GaussMessage,
    /// Error of the walk component against the final true state.
    pub pos_error: f64,
}

impl StreamingWorkload for SmootherProblem {
    type StreamOutcome = FilterOutcome;

    fn stream_name(&self) -> &str {
        "smoother_forward_stream"
    }

    fn state_dim(&self) -> usize {
        self.prior.dim()
    }

    fn stream_model(&self, chunk: usize) -> Result<(FactorGraph, Schedule)> {
        Ok(self.forward_chain(chunk))
    }

    fn constant_inputs(&self) -> Vec<(String, GaussMessage)> {
        vec![(
            "msg_Q".to_string(),
            GaussMessage::isotropic(self.prior.dim(), self.q_var),
        )]
    }

    fn initial_state(&self) -> GaussMessage {
        self.prior.clone()
    }

    fn next_sample(&self, k: usize, _state: &GaussMessage) -> Result<Option<StreamSample>> {
        Ok((k < self.steps).then(|| StreamSample {
            messages: vec![self.observations[k].clone()],
            states: Vec::new(),
        }))
    }

    fn stream_outcome(&self, run: &StreamRun) -> Result<FilterOutcome> {
        let t = self.truth.last().ok_or_else(|| anyhow!("empty trajectory"))?;
        let pos_error = (run.final_state.mean[0] - t[0]).abs2().sqrt();
        Ok(FilterOutcome { final_filtered: run.final_state.clone(), pos_error })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Session;
    use crate::fgp::FgpConfig;

    #[test]
    fn smoother_beats_filter() {
        // the textbook property: smoothing (two-sided information) has
        // lower RMSE than filtering (one-sided) on interior states
        let mut golden = Session::golden();
        let mut wins = 0;
        for seed in 0..5 {
            let p = SmootherProblem::synthetic(60, 100 + seed);
            let out = golden.run(&p).unwrap().outcome;
            if out.smoother_rmse <= out.filter_rmse + 1e-9 {
                wins += 1;
            }
        }
        assert!(wins >= 4, "smoother won only {wins}/5 seeds");
    }

    #[test]
    fn marginals_have_smaller_variance_than_filter() {
        let p = SmootherProblem::synthetic(40, 7);
        let out = Session::golden().run(&p).unwrap().outcome;
        // interior marginal variance <= filtered variance (the equality
        // fusion only adds information)
        for (m, f) in out.marginals.iter().zip(&out.filtered).take(p.steps - 1) {
            assert!(m.trace_cov() <= f.trace_cov() + 1e-6);
        }
    }

    #[test]
    fn smoother_tracks_truth() {
        let p = SmootherProblem::synthetic(80, 11);
        let out = Session::golden().run(&p).unwrap();
        assert!(out.quality < 0.25, "rmse {}", out.quality);
    }

    #[test]
    fn short_chain_runs_on_the_device() {
        let p = SmootherProblem::synthetic(8, 13);
        let golden = Session::golden().run(&p).unwrap();
        let fgp = Session::fgp_sim(FgpConfig::default()).run(&p).unwrap();
        assert!(
            fgp.quality <= golden.quality + p.tolerance(),
            "fgp {} vs golden {}",
            fgp.quality,
            golden.quality
        );
        assert!(fgp.cycles > 0);
        // every node commits one store: 3T forward + (4T - 3) backward
        assert_eq!(fgp.sections, (3 * 8 + 4 * 8 - 3) as u64);
    }
}
