//! Synthetic channels, constellations and noise (S11).
//!
//! Substitutes for the radio front-end: the paper's "messages msg_Y
//! correspond to the received symbols". Everything is scaled to the
//! FGP's fixed-point input contract (symbols |s| = 0.5, channel taps
//! CN(0, tap_var) with tap_var ≤ 0.3).

use crate::gmp::matrix::{c64, CMatrix};
use crate::testutil::Rng;

/// Complex Gaussian sample with per-component variance `var/2`.
pub fn cgauss(rng: &mut Rng, var: f64) -> c64 {
    let s = (var / 2.0).sqrt();
    c64::new(rng.normal() * s, rng.normal() * s)
}

/// Constellations (training sequences for channel estimation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Constellation {
    /// QPSK at amplitude 0.5: s ∈ 0.5/√2 · {±1±i}.
    Qpsk,
    /// 16-QAM at the same mean power.
    Qam16,
}

impl Constellation {
    /// All constellation points.
    pub fn points(&self) -> Vec<c64> {
        match self {
            Constellation::Qpsk => {
                let a = 0.5 / 2f64.sqrt();
                vec![
                    c64::new(a, a),
                    c64::new(a, -a),
                    c64::new(-a, a),
                    c64::new(-a, -a),
                ]
            }
            Constellation::Qam16 => {
                // levels {±1, ±3}: E[l^2] = 5 per axis, so E|s|^2 = 10 s^2;
                // s chosen for mean power 0.25 (same as the QPSK set)
                let levels = [-3.0f64, -1.0, 1.0, 3.0];
                let s = (0.25f64 / 10.0).sqrt();
                let mut pts = Vec::with_capacity(16);
                for &re in &levels {
                    for &im in &levels {
                        pts.push(c64::new(re * s, im * s));
                    }
                }
                pts
            }
        }
    }

    /// Draw one symbol uniformly from the constellation.
    pub fn draw(&self, rng: &mut Rng) -> c64 {
        let pts = self.points();
        pts[rng.below(pts.len())]
    }

    /// Hard decision: nearest constellation point.
    pub fn slice(&self, z: c64) -> c64 {
        let pts = self.points();
        *pts.iter()
            .min_by(|a, b| {
                let da = (**a - z).abs2();
                let db = (**b - z).abs2();
                da.partial_cmp(&db).unwrap()
            })
            .unwrap()
    }
}

/// A static frequency-selective channel: `taps` complex coefficients.
#[derive(Clone, Debug)]
pub struct MultipathChannel {
    /// Complex tap coefficients, delay order.
    pub taps: Vec<c64>,
}

impl MultipathChannel {
    /// Random channel with exponentially decaying power-delay profile.
    pub fn random(rng: &mut Rng, taps: usize, tap_var: f64) -> Self {
        let coeffs = (0..taps)
            .map(|k| cgauss(rng, tap_var * 0.7f64.powi(k as i32)))
            .collect();
        MultipathChannel { taps: coeffs }
    }

    /// Number of taps (channel memory).
    pub fn order(&self) -> usize {
        self.taps.len()
    }

    /// Convolve a symbol stream (zero prehistory) and add AWGN.
    pub fn transmit(&self, rng: &mut Rng, symbols: &[c64], noise_var: f64) -> Vec<c64> {
        (0..symbols.len())
            .map(|i| {
                let mut y = cgauss(rng, noise_var);
                for (k, h) in self.taps.iter().enumerate() {
                    if i >= k {
                        y = y + *h * symbols[i - k];
                    }
                }
                y
            })
            .collect()
    }

    /// The Toeplitz channel matrix H (rows = observations) for a block of
    /// `len` symbols — the LMMSE equalizer's A.
    pub fn toeplitz(&self, len: usize) -> CMatrix {
        let mut h = CMatrix::zeros(len, len);
        for i in 0..len {
            for (k, tap) in self.taps.iter().enumerate() {
                if i >= k {
                    h[(i, i - k)] = *tap;
                }
            }
        }
        h
    }
}

/// The regressor matrix of one RLS section: the known-symbol row
/// `[s_i, s_{i-1}, .., s_{i-n+1}]` embedded as the first row of an
/// n x n matrix (remaining rows zero) — the same convention as the
/// Python oracle (`python/tests/test_model.py::make_rls_problem`).
pub fn regressor_matrix(symbols: &[c64], i: usize, n: usize) -> CMatrix {
    let mut a = CMatrix::zeros(n, n);
    for k in 0..n {
        if i >= k {
            a[(0, k)] = symbols[i - k];
        }
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qpsk_points_have_equal_power() {
        let pts = Constellation::Qpsk.points();
        assert_eq!(pts.len(), 4);
        for p in &pts {
            assert!((p.abs() - 0.5).abs() < 1e-12);
        }
    }

    #[test]
    fn qam16_mean_power_matches_qpsk() {
        let pts = Constellation::Qam16.points();
        assert_eq!(pts.len(), 16);
        let mean_p: f64 = pts.iter().map(|p| p.abs2()).sum::<f64>() / 16.0;
        assert!((mean_p - 0.25).abs() < 0.05, "mean power {mean_p}");
    }

    #[test]
    fn slicing_recovers_clean_symbols() {
        let mut rng = Rng::new(1);
        for c in [Constellation::Qpsk, Constellation::Qam16] {
            for _ in 0..50 {
                let s = c.draw(&mut rng);
                let noisy = s + cgauss(&mut rng, 1e-6);
                assert_eq!(c.slice(noisy), s);
            }
        }
    }

    #[test]
    fn noiseless_transmit_is_convolution() {
        let mut rng = Rng::new(2);
        let ch = MultipathChannel { taps: vec![c64::new(1.0, 0.0), c64::new(0.5, 0.0)] };
        let s = vec![c64::new(1.0, 0.0), c64::new(0.0, 1.0)];
        let y = ch.transmit(&mut rng, &s, 0.0);
        assert!((y[0] - s[0]).abs() < 1e-12);
        assert!((y[1] - (s[1] + s[0] * 0.5)).abs() < 1e-12);
    }

    #[test]
    fn toeplitz_matches_transmit() {
        let mut rng = Rng::new(3);
        let ch = MultipathChannel::random(&mut rng, 3, 0.2);
        let s: Vec<c64> = (0..5).map(|_| Constellation::Qpsk.draw(&mut rng)).collect();
        let y_conv = ch.transmit(&mut Rng::new(99), &s, 0.0); // noiseless path needs var=0
        let h = ch.toeplitz(5);
        let y_mat = h.matvec(&s);
        for (a, b) in y_conv.iter().zip(&y_mat) {
            assert!((*a - *b).abs() < 1e-12);
        }
    }

    #[test]
    fn regressor_rows_shift() {
        let s = vec![c64::new(1.0, 0.0), c64::new(2.0, 0.0), c64::new(3.0, 0.0)];
        let a = regressor_matrix(&s, 2, 3);
        assert!((a[(0, 0)].re - 3.0).abs() < 1e-12);
        assert!((a[(0, 1)].re - 2.0).abs() < 1e-12);
        assert!((a[(0, 2)].re - 1.0).abs() < 1e-12);
        assert!(a[(1, 0)].abs() < 1e-12);
    }

    #[test]
    fn channel_power_decays() {
        let mut rng = Rng::new(4);
        let ch = MultipathChannel::random(&mut rng, 4, 0.3);
        assert_eq!(ch.order(), 4);
    }
}
