//! Kalman-filter tracking as GMP on the FGP (§I: "Kalman filtering can
//! be expressed with Gaussian message-passing on a factor graph").
//!
//! Constant-velocity tracking with state `[px, vx, py, vy]` (real values
//! carried in the complex field): each time step is a *multiplier* node
//! (transition A), an *additive* node (process noise, a constant message
//! served from a preloaded slot), and a *compound observation* node
//! (position measurement through C) — three of the Fig. 1 node types
//! composing into a textbook filter, expressed once as a [`Workload`]
//! and runnable on any engine.

use std::collections::HashMap;

use anyhow::Result;

use crate::compiler::{compile, CompileOptions, CompiledProgram};
use crate::engine::{
    bind_streamed, preload_id, Execution, StreamRun, StreamSample, StreamingWorkload, Workload,
};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::gmp::{FactorGraph, MsgId, NodeKind, Schedule};
use crate::testutil::Rng;

/// A synthetic constant-velocity tracking problem.
#[derive(Clone, Debug)]
pub struct KalmanProblem {
    pub steps: usize,
    /// Transition matrix (4x4).
    pub a: CMatrix,
    /// Observation matrix (positions).
    pub c: CMatrix,
    /// Process noise message (zero mean, Q).
    pub q_msg: GaussMessage,
    /// Measurement noise variance.
    pub r_var: f64,
    /// Ground-truth states per step.
    pub truth: Vec<Vec<c64>>,
    /// Observation messages per step.
    pub observations: Vec<GaussMessage>,
    pub prior: GaussMessage,
}

/// Tracking outcome.
#[derive(Clone, Debug)]
pub struct KalmanOutcome {
    pub estimate: Vec<c64>,
    /// Final position error (Euclidean).
    pub pos_error: f64,
}

impl KalmanProblem {
    pub fn synthetic(steps: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let dt = 0.1;
        let mut a = CMatrix::identity(4);
        a[(0, 1)] = c64::new(dt, 0.0);
        a[(2, 3)] = c64::new(dt, 0.0);
        let mut c = CMatrix::zeros(4, 4);
        c[(0, 0)] = c64::ONE;
        c[(2, 2)] = c64::ONE;
        let q_var: f64 = 2e-3;
        let r_var: f64 = 0.02;

        let mut x = vec![
            c64::new(rng.range(-0.2, 0.2), 0.0),
            c64::new(rng.range(-0.3, 0.3), 0.0),
            c64::new(rng.range(-0.2, 0.2), 0.0),
            c64::new(rng.range(-0.3, 0.3), 0.0),
        ];
        let mut truth = Vec::with_capacity(steps);
        let mut observations = Vec::with_capacity(steps);
        for _ in 0..steps {
            x = a.matvec(&x);
            for xi in x.iter_mut() {
                *xi = *xi + c64::new(rng.normal() * q_var.sqrt(), 0.0);
            }
            let mut y = vec![c64::ZERO; 4];
            y[0] = x[0] + c64::new(rng.normal() * r_var.sqrt(), 0.0);
            y[2] = x[2] + c64::new(rng.normal() * r_var.sqrt(), 0.0);
            truth.push(x.clone());
            observations.push(GaussMessage::observation(&y, r_var));
        }
        KalmanProblem {
            steps,
            a,
            c,
            q_msg: GaussMessage::isotropic(4, q_var),
            r_var,
            truth,
            observations,
            prior: GaussMessage::isotropic(4, 0.5),
        }
    }

    /// Build the factor-graph chain: Multiply(A) → Add(Q) → Compound(C).
    pub fn build_graph(&self) -> (FactorGraph, Schedule) {
        self.filter_chain(self.steps)
    }

    /// The filter chain for an arbitrary step count — `build_graph` is
    /// the whole-problem instance, `stream_model` the per-chunk one.
    fn filter_chain(&self, steps: usize) -> (FactorGraph, Schedule) {
        let n = 4;
        let mut g = FactorGraph::new();
        let a_sid = g.add_state(self.a.clone());
        let c_sid = g.add_state(self.c.clone());
        let q_edge = g.add_input_edge(n, "msg_Q");
        let prior = g.add_input_edge(n, "msg_prior");
        let mut prev = prior;
        for i in 0..steps {
            let pred = g.add_edge(n, format!("pred{i}"));
            g.add_node(NodeKind::Multiply { a: a_sid }, vec![prev], pred, format!("mul{i}"));
            let noisy = g.add_edge(n, format!("noisy{i}"));
            g.add_node(NodeKind::Add, vec![pred, q_edge], noisy, format!("add{i}"));
            let obs = g.add_streamed_input_edge(n, 0, format!("msg_Y{i}"));
            let post = g.add_edge(n, format!("post{i}"));
            g.add_node(
                NodeKind::CompoundObservation { a: c_sid },
                vec![noisy, obs],
                post,
                format!("obs{i}"),
            );
            prev = post;
        }
        g.mark_output(prev);
        let s = Schedule::forward_sweep(&g);
        (g, s)
    }

    /// Compiler-report helper; execution goes through `Session::run`.
    pub fn compile_program(&self) -> Result<CompiledProgram> {
        let (g, s) = self.build_graph();
        Ok(compile(&g, &s, &CompileOptions::default())?)
    }
}

impl Workload for KalmanProblem {
    type Outcome = KalmanOutcome;

    fn name(&self) -> &str {
        "kalman_tracking"
    }

    fn n(&self) -> usize {
        4
    }

    fn model(&self) -> Result<(FactorGraph, Schedule)> {
        Ok(self.build_graph())
    }

    fn inputs(
        &self,
        graph: &FactorGraph,
        schedule: &Schedule,
    ) -> Result<HashMap<MsgId, GaussMessage>> {
        let mut map = HashMap::new();
        map.insert(preload_id(graph, schedule, "msg_Q")?, self.q_msg.clone());
        map.insert(preload_id(graph, schedule, "msg_prior")?, self.prior.clone());
        bind_streamed(graph, schedule, &self.observations, &mut map)?;
        Ok(map)
    }

    fn outcome(&self, exec: &Execution) -> Result<KalmanOutcome> {
        let estimate = exec.output()?.mean.clone();
        let t = self.truth.last().expect("non-empty trajectory");
        let dx = (estimate[0] - t[0]).abs2() + (estimate[2] - t[2]).abs2();
        Ok(KalmanOutcome { estimate, pos_error: dx.sqrt() })
    }

    fn quality(&self, outcome: &KalmanOutcome) -> f64 {
        outcome.pos_error
    }

    /// Fixed-point slack on the final position fix.
    fn tolerance(&self) -> f64 {
        0.4
    }
}

/// Steady-state serving form: the per-step predict + update triplet is
/// the recursive section; observations stream in, `msg_Q` rides along
/// as a constant preload, and the filtered posterior threads through as
/// the recursive state.
impl StreamingWorkload for KalmanProblem {
    type StreamOutcome = KalmanOutcome;

    fn stream_name(&self) -> &str {
        "kalman_stream"
    }

    fn state_dim(&self) -> usize {
        4
    }

    fn stream_model(&self, chunk: usize) -> Result<(FactorGraph, Schedule)> {
        Ok(self.filter_chain(chunk))
    }

    fn constant_inputs(&self) -> Vec<(String, GaussMessage)> {
        vec![("msg_Q".to_string(), self.q_msg.clone())]
    }

    fn initial_state(&self) -> GaussMessage {
        self.prior.clone()
    }

    fn next_sample(&self, k: usize, _state: &GaussMessage) -> Result<Option<StreamSample>> {
        Ok((k < self.steps).then(|| StreamSample {
            messages: vec![self.observations[k].clone()],
            states: Vec::new(),
        }))
    }

    fn stream_outcome(&self, run: &StreamRun) -> Result<KalmanOutcome> {
        let estimate = run.final_state.mean.clone();
        let t = self.truth.last().expect("non-empty trajectory");
        let dx = (estimate[0] - t[0]).abs2() + (estimate[2] - t[2]).abs2();
        Ok(KalmanOutcome { estimate, pos_error: dx.sqrt() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Session;
    use crate::fgp::FgpConfig;

    #[test]
    fn golden_tracks_position() {
        let p = KalmanProblem::synthetic(40, 3);
        let out = Session::golden().run(&p).unwrap();
        assert!(out.quality < 0.2, "pos error {}", out.quality);
    }

    #[test]
    fn graph_has_three_nodes_per_step() {
        let p = KalmanProblem::synthetic(5, 1);
        let (g, s) = p.build_graph();
        assert_eq!(g.nodes.len(), 15);
        assert_eq!(s.steps.len(), 15);
    }

    #[test]
    fn fgp_tracks_golden_regime() {
        let p = KalmanProblem::synthetic(20, 5);
        let golden = Session::golden().run(&p).unwrap();
        let fgp = Session::fgp_sim(FgpConfig::default()).run(&p).unwrap();
        assert!(
            fgp.quality < golden.quality + p.tolerance(),
            "fgp {} vs golden {}",
            fgp.quality,
            golden.quality
        );
        assert!(fgp.cycles > 0);
        // three store handshakes per time step
        assert_eq!(fgp.sections, 3 * 20);
    }

    #[test]
    fn program_compresses_across_steps() {
        let p = KalmanProblem::synthetic(12, 7);
        let c = p.compile_program().unwrap();
        assert!(c.stats.looped.is_some(), "listing:\n{}", c.listing());
        // slots stay constant regardless of steps: Q + prior-chain + obs
        assert!(c.memmap.num_slots <= 5, "{} slots", c.memmap.num_slots);
    }
}
