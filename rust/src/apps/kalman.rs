//! Kalman-filter tracking as GMP on the FGP (§I: "Kalman filtering can
//! be expressed with Gaussian message-passing on a factor graph").
//!
//! Constant-velocity tracking with state `[px, vx, py, vy]` (real values
//! carried in the complex field): each time step is a *multiplier* node
//! (transition A), an *additive* node (process noise, a constant message
//! served from a preloaded slot), and a *compound observation* node
//! (position measurement through C) — three of the Fig. 1 node types
//! composing into a textbook filter, expressed once as a [`Workload`]
//! and runnable on any engine.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::compiler::{compile, CompileOptions, CompiledProgram};
use crate::em::{EmEstimand, EmParameter, Evidence, ProcessNoiseVar, SuffStats};
use crate::engine::{
    bind_streamed, preload_id, Execution, Session, StreamRun, StreamSample, StreamingWorkload,
    Workload,
};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::gmp::{FactorGraph, MsgId, NodeKind, Schedule};
use crate::testutil::Rng;

/// A synthetic constant-velocity tracking problem.
#[derive(Clone, Debug)]
pub struct KalmanProblem {
    /// Track length in time steps.
    pub steps: usize,
    /// Transition matrix (4x4).
    pub a: CMatrix,
    /// Observation matrix (positions).
    pub c: CMatrix,
    /// Process noise message (zero mean, Q).
    pub q_msg: GaussMessage,
    /// Measurement noise variance.
    pub r_var: f64,
    /// Ground-truth states per step.
    pub truth: Vec<Vec<c64>>,
    /// Observation messages per step.
    pub observations: Vec<GaussMessage>,
    /// Prior on the initial state.
    pub prior: GaussMessage,
}

/// Tracking outcome.
#[derive(Clone, Debug)]
pub struct KalmanOutcome {
    /// Final filtered state estimate.
    pub estimate: Vec<c64>,
    /// Final position error (Euclidean).
    pub pos_error: f64,
}

impl KalmanProblem {
    /// Generate a random constant-velocity tracking instance.
    pub fn synthetic(steps: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let dt = 0.1;
        let mut a = CMatrix::identity(4);
        a[(0, 1)] = c64::new(dt, 0.0);
        a[(2, 3)] = c64::new(dt, 0.0);
        let mut c = CMatrix::zeros(4, 4);
        c[(0, 0)] = c64::ONE;
        c[(2, 2)] = c64::ONE;
        let q_var: f64 = 2e-3;
        let r_var: f64 = 0.02;

        let mut x = vec![
            c64::new(rng.range(-0.2, 0.2), 0.0),
            c64::new(rng.range(-0.3, 0.3), 0.0),
            c64::new(rng.range(-0.2, 0.2), 0.0),
            c64::new(rng.range(-0.3, 0.3), 0.0),
        ];
        let mut truth = Vec::with_capacity(steps);
        let mut observations = Vec::with_capacity(steps);
        for _ in 0..steps {
            x = a.matvec(&x);
            for xi in x.iter_mut() {
                *xi = *xi + c64::new(rng.normal() * q_var.sqrt(), 0.0);
            }
            let mut y = vec![c64::ZERO; 4];
            y[0] = x[0] + c64::new(rng.normal() * r_var.sqrt(), 0.0);
            y[2] = x[2] + c64::new(rng.normal() * r_var.sqrt(), 0.0);
            truth.push(x.clone());
            observations.push(GaussMessage::observation(&y, r_var));
        }
        KalmanProblem {
            steps,
            a,
            c,
            q_msg: GaussMessage::isotropic(4, q_var),
            r_var,
            truth,
            observations,
            prior: GaussMessage::isotropic(4, 0.5),
        }
    }

    /// Build the factor-graph chain: Multiply(A) → Add(Q) → Compound(C).
    pub fn build_graph(&self) -> (FactorGraph, Schedule) {
        self.filter_chain(self.steps)
    }

    /// The filter chain for an arbitrary step count — `build_graph` is
    /// the whole-problem instance, `stream_model` the per-chunk one.
    fn filter_chain(&self, steps: usize) -> (FactorGraph, Schedule) {
        let n = 4;
        let mut g = FactorGraph::new();
        let a_sid = g.add_state(self.a.clone());
        let c_sid = g.add_state(self.c.clone());
        let q_edge = g.add_input_edge(n, "msg_Q");
        let prior = g.add_input_edge(n, "msg_prior");
        let mut prev = prior;
        for i in 0..steps {
            let pred = g.add_edge(n, format!("pred{i}"));
            g.add_node(NodeKind::Multiply { a: a_sid }, vec![prev], pred, format!("mul{i}"));
            let noisy = g.add_edge(n, format!("noisy{i}"));
            g.add_node(NodeKind::Add, vec![pred, q_edge], noisy, format!("add{i}"));
            let obs = g.add_streamed_input_edge(n, 0, format!("msg_Y{i}"));
            let post = g.add_edge(n, format!("post{i}"));
            g.add_node(
                NodeKind::CompoundObservation { a: c_sid },
                vec![noisy, obs],
                post,
                format!("obs{i}"),
            );
            prev = post;
        }
        g.mark_output(prev);
        let s = Schedule::forward_sweep(&g);
        (g, s)
    }

    /// Compiler-report helper; execution goes through `Session::run`.
    pub fn compile_program(&self) -> Result<CompiledProgram> {
        let (g, s) = self.build_graph();
        Ok(compile(&g, &s, &CompileOptions::default())?)
    }

    /// Score a final state estimate against the trajectory's last true
    /// state (the one error metric every execution path reports).
    pub fn score(&self, estimate: Vec<c64>) -> KalmanOutcome {
        let t = self.truth.last().expect("non-empty trajectory");
        let dx = (estimate[0] - t[0]).abs2() + (estimate[2] - t[2]).abs2();
        KalmanOutcome { estimate, pos_error: dx.sqrt() }
    }
}

impl Workload for KalmanProblem {
    type Outcome = KalmanOutcome;

    fn name(&self) -> &str {
        "kalman_tracking"
    }

    fn n(&self) -> usize {
        4
    }

    fn model(&self) -> Result<(FactorGraph, Schedule)> {
        Ok(self.build_graph())
    }

    fn inputs(
        &self,
        graph: &FactorGraph,
        schedule: &Schedule,
    ) -> Result<HashMap<MsgId, GaussMessage>> {
        let mut map = HashMap::new();
        map.insert(preload_id(graph, schedule, "msg_Q")?, self.q_msg.clone());
        map.insert(preload_id(graph, schedule, "msg_prior")?, self.prior.clone());
        bind_streamed(graph, schedule, &self.observations, &mut map)?;
        Ok(map)
    }

    fn outcome(&self, exec: &Execution) -> Result<KalmanOutcome> {
        Ok(self.score(exec.output()?.mean.clone()))
    }

    fn quality(&self, outcome: &KalmanOutcome) -> f64 {
        outcome.pos_error
    }

    /// Fixed-point slack on the final position fix.
    fn tolerance(&self) -> f64 {
        0.4
    }
}

/// Steady-state serving form: the per-step predict + update triplet is
/// the recursive section; observations stream in, `msg_Q` rides along
/// as a constant preload, and the filtered posterior threads through as
/// the recursive state.
impl StreamingWorkload for KalmanProblem {
    type StreamOutcome = KalmanOutcome;

    fn stream_name(&self) -> &str {
        "kalman_stream"
    }

    fn state_dim(&self) -> usize {
        4
    }

    fn stream_model(&self, chunk: usize) -> Result<(FactorGraph, Schedule)> {
        Ok(self.filter_chain(chunk))
    }

    fn constant_inputs(&self) -> Vec<(String, GaussMessage)> {
        vec![("msg_Q".to_string(), self.q_msg.clone())]
    }

    fn initial_state(&self) -> GaussMessage {
        self.prior.clone()
    }

    fn next_sample(&self, k: usize, _state: &GaussMessage) -> Result<Option<StreamSample>> {
        Ok((k < self.steps).then(|| StreamSample {
            messages: vec![self.observations[k].clone()],
            states: Vec::new(),
        }))
    }

    fn stream_outcome(&self, run: &StreamRun) -> Result<KalmanOutcome> {
        Ok(self.score(run.final_state.mean.clone()))
    }
}

// ---------------------------------------------------------------------
// EM: adaptive process noise
// ---------------------------------------------------------------------

/// Per-sample streamed view of the filter at an explicit process-noise
/// variance: `max_chunk == 1` forces one dispatch per sample on every
/// engine, so each stream boundary is a **filtered marginal** — the
/// evidence stream the adaptive-noise E-step consumes.
struct PerSampleFilter<'p> {
    problem: &'p KalmanProblem,
    q: f64,
}

impl StreamingWorkload for PerSampleFilter<'_> {
    type StreamOutcome = Vec<GaussMessage>;

    fn stream_name(&self) -> &str {
        "kalman_em_estep"
    }

    fn state_dim(&self) -> usize {
        4
    }

    fn stream_model(&self, chunk: usize) -> Result<(FactorGraph, Schedule)> {
        self.problem.stream_model(chunk)
    }

    fn constant_inputs(&self) -> Vec<(String, GaussMessage)> {
        vec![("msg_Q".to_string(), GaussMessage::isotropic(4, self.q))]
    }

    fn initial_state(&self) -> GaussMessage {
        self.problem.prior.clone()
    }

    fn next_sample(&self, k: usize, state: &GaussMessage) -> Result<Option<StreamSample>> {
        self.problem.next_sample(k, state)
    }

    fn max_chunk(&self) -> usize {
        1
    }

    fn stream_outcome(&self, run: &StreamRun) -> Result<Vec<GaussMessage>> {
        Ok(run.boundaries.clone())
    }
}

/// Constant-velocity tracking with **unknown** process-noise variance,
/// estimated by EM ([`crate::em`]).
///
/// Each round streams the filter at the current estimate through the
/// session (one fixed chunk shape — rounds after the first are
/// program-cache hits), then runs a lag-one host recursion over the
/// engine-produced filtered marginals: the posterior of each step's
/// noise input `w_t` given `y_{1:t+1}` is closed-form from the filtered
/// state, the model matrices and the next innovation, and is exactly
/// the [`Evidence::Noise`] marginal Dauwels' variance rule consumes.
/// Filtered (rather than smoothed) marginals keep the E-step streamable
/// at the cost of slower convergence near the fixed point — see the
/// `em_convergence` bench (E15) for the trajectory.
pub struct AdaptiveKalman {
    /// The underlying tracking problem; its `q_msg` (the true synthetic
    /// process noise) is never read by the estimator.
    pub problem: KalmanProblem,
    q: ProcessNoiseVar,
}

impl AdaptiveKalman {
    /// Estimate the process noise of `problem` starting from `q0`.
    pub fn new(problem: KalmanProblem, q0: f64) -> Self {
        AdaptiveKalman { problem, q: ProcessNoiseVar::new(q0) }
    }

    /// Current process-noise estimate.
    pub fn q_hat(&self) -> f64 {
        self.q.value()
    }

    /// Run the filter at the current estimate and score the track.
    pub fn outcome(&self, session: &mut Session) -> Result<KalmanOutcome> {
        let w = PerSampleFilter { problem: &self.problem, q: self.q.value() };
        let report = session.run_stream(&w)?;
        Ok(self.problem.score(report.final_state.mean.clone()))
    }
}

impl EmEstimand for AdaptiveKalman {
    fn values(&self) -> Vec<f64> {
        vec![self.q.value()]
    }

    fn e_step(&mut self, session: &mut Session, acc: &mut [SuffStats]) -> Result<bool> {
        let n = 4;
        let q = self.q.value();
        let w = PerSampleFilter { problem: &self.problem, q };
        let report = session.run_stream(&w).context("EM E-step filter stream")?;
        let boundaries = report.outcome; // filtered marginals, one per sample
        if boundaries.len() != self.problem.observations.len() {
            anyhow::bail!(
                "stream produced {} boundaries for {} observations",
                boundaries.len(),
                self.problem.observations.len()
            );
        }
        let a = &self.problem.a;
        let c = &self.problem.c;
        let r = CMatrix::scaled_identity(n, self.problem.r_var);
        let qi = CMatrix::scaled_identity(n, q);
        let mut prev = self.problem.prior.clone();
        // the previous step's noise marginal, pending its lag-one
        // finalization: (mean, cov, Cov(x_t, w_t | y_1:t))
        let mut pend: Option<(Vec<c64>, CMatrix, CMatrix)> = None;
        for (t, y) in self.problem.observations.iter().enumerate() {
            let mp = a.matvec(&prev.mean);
            let vp = a.matmul(&prev.cov).matmul(&a.hermitian()).add(&qi);
            let s = c.matmul(&vp).matmul(&c.hermitian()).add(&r);
            let sinv = s.inverse().context("innovation covariance singular")?;
            let cmp = c.matvec(&mp);
            let nu: Vec<c64> = y.mean.iter().zip(&cmp).map(|(yo, po)| *yo - *po).collect();
            if let Some((w_mean, w_cov, p_xw)) = pend.take() {
                // finalize w_{t-1} with this innovation:
                // Cov(w_{t-1}, y_t) = P_xwᴴ Aᴴ Cᴴ
                let g = p_xw
                    .hermitian()
                    .matmul(&a.hermitian())
                    .matmul(&c.hermitian())
                    .matmul(&sinv);
                let corr = g.matvec(&nu);
                let mean: Vec<c64> =
                    w_mean.iter().zip(&corr).map(|(m, d)| *m + *d).collect();
                let cov = w_cov.sub(&g.matmul(&c.matmul(a).matmul(&p_xw)));
                let marginal = GaussMessage::new(mean, cov);
                self.q.accumulate(&Evidence::Noise { marginal: &marginal }, &mut acc[0])?;
            }
            // this step's noise conditioned on its own observation:
            // Cov(w_t, y_t) = q Cᴴ
            let kw = qi.matmul(&c.hermitian()).matmul(&sinv);
            let w_mean = kw.matvec(&nu);
            let w_cov = qi.sub(&kw.matmul(&c.matmul(&qi)));
            // Cov(x_t, w_t | y_1:t) = (I − K C) q, K = V⁻ Cᴴ S⁻¹
            let k = vp.matmul(&c.hermitian()).matmul(&sinv);
            let p_xw = CMatrix::identity(n).sub(&k.matmul(c)).scale(q);
            pend = Some((w_mean, w_cov, p_xw));
            prev = boundaries[t].clone();
        }
        // the last step's noise only ever sees its own observation
        if let Some((w_mean, w_cov, _)) = pend {
            let marginal = GaussMessage::new(w_mean, w_cov);
            self.q.accumulate(&Evidence::Noise { marginal: &marginal }, &mut acc[0])?;
        }
        Ok(report.cache_hits > 0 && report.compiles == 0)
    }

    fn m_step(&mut self, acc: &[SuffStats]) -> Result<Vec<f64>> {
        Ok(vec![self.q.m_step(&acc[0])?])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::em::{EmDriver, EmOptions};
    use crate::engine::Session;
    use crate::fgp::FgpConfig;

    #[test]
    fn golden_tracks_position() {
        let p = KalmanProblem::synthetic(40, 3);
        let out = Session::golden().run(&p).unwrap();
        assert!(out.quality < 0.2, "pos error {}", out.quality);
    }

    #[test]
    fn graph_has_three_nodes_per_step() {
        let p = KalmanProblem::synthetic(5, 1);
        let (g, s) = p.build_graph();
        assert_eq!(g.nodes.len(), 15);
        assert_eq!(s.steps.len(), 15);
    }

    #[test]
    fn fgp_tracks_golden_regime() {
        let p = KalmanProblem::synthetic(20, 5);
        let golden = Session::golden().run(&p).unwrap();
        let fgp = Session::fgp_sim(FgpConfig::default()).run(&p).unwrap();
        assert!(
            fgp.quality < golden.quality + p.tolerance(),
            "fgp {} vs golden {}",
            fgp.quality,
            golden.quality
        );
        assert!(fgp.cycles > 0);
        // three store handshakes per time step
        assert_eq!(fgp.sections, 3 * 20);
    }

    #[test]
    fn adaptive_process_noise_recovers_regime() {
        // truth q = 2e-3 (synthetic fixture); estimate starts 10x off
        let q_true = 2e-3;
        let p = KalmanProblem::synthetic(240, 9);
        let mut em = AdaptiveKalman::new(p, q_true * 10.0);
        let driver = EmDriver::with_options(EmOptions {
            max_rounds: 50,
            tol: 1e-4,
            divergence: 1e6,
        });
        let report = driver.run(&mut Session::golden(), &mut em).unwrap();
        let q_hat = report.values[0];
        assert!(
            q_hat > q_true * 0.4 && q_hat < q_true * 3.0,
            "q_hat {q_hat} left the truth's regime ({} rounds)",
            report.rounds
        );
        // at least 5x closer than the starting guess
        assert!((q_hat - q_true).abs() < q_true * 9.0 / 5.0, "q_hat {q_hat}");
        assert!((em.q_hat() - q_hat).abs() < 1e-18);
    }

    #[test]
    fn program_compresses_across_steps() {
        let p = KalmanProblem::synthetic(12, 7);
        let c = p.compile_program().unwrap();
        assert!(c.stats.looped.is_some(), "listing:\n{}", c.listing());
        // slots stay constant regardless of steps: Q + prior-chain + obs
        assert!(c.memmap.num_slots <= 5, "{} slots", c.memmap.num_slots);
    }
}
