//! Kalman-filter tracking as GMP on the FGP (§I: "Kalman filtering can
//! be expressed with Gaussian message-passing on a factor graph").
//!
//! Constant-velocity tracking with state `[px, vx, py, vy]` (real values
//! carried in the complex field): each time step is a *multiplier* node
//! (transition A), an *additive* node (process noise, a constant message
//! streamed from a preloaded slot), and a *compound observation* node
//! (position measurement through C) — three of the Fig. 1 node types
//! composing into a textbook filter.

use anyhow::Result;

use crate::compiler::{compile, CompileOptions, CompiledProgram};
use crate::fgp::{Fgp, FgpConfig, MessageMemory, StateMemory};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::gmp::{nodes, FactorGraph, NodeKind, Schedule};
use crate::testutil::Rng;

/// A synthetic constant-velocity tracking problem.
#[derive(Clone, Debug)]
pub struct KalmanProblem {
    pub steps: usize,
    /// Transition matrix (4x4).
    pub a: CMatrix,
    /// Observation matrix (positions).
    pub c: CMatrix,
    /// Process noise message (zero mean, Q).
    pub q_msg: GaussMessage,
    /// Measurement noise variance.
    pub r_var: f64,
    /// Ground-truth states per step.
    pub truth: Vec<Vec<c64>>,
    /// Observation messages per step.
    pub observations: Vec<GaussMessage>,
    pub prior: GaussMessage,
}

/// Tracking outcome.
#[derive(Clone, Debug)]
pub struct KalmanOutcome {
    pub estimate: Vec<c64>,
    /// Final position error (Euclidean).
    pub pos_error: f64,
    pub cycles: u64,
}

impl KalmanProblem {
    pub fn synthetic(steps: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let dt = 0.1;
        let mut a = CMatrix::identity(4);
        a[(0, 1)] = c64::new(dt, 0.0);
        a[(2, 3)] = c64::new(dt, 0.0);
        let mut c = CMatrix::zeros(4, 4);
        c[(0, 0)] = c64::ONE;
        c[(2, 2)] = c64::ONE;
        let q_var: f64 = 2e-3;
        let r_var: f64 = 0.02;

        let mut x = vec![
            c64::new(rng.range(-0.2, 0.2), 0.0),
            c64::new(rng.range(-0.3, 0.3), 0.0),
            c64::new(rng.range(-0.2, 0.2), 0.0),
            c64::new(rng.range(-0.3, 0.3), 0.0),
        ];
        let mut truth = Vec::with_capacity(steps);
        let mut observations = Vec::with_capacity(steps);
        for _ in 0..steps {
            x = a.matvec(&x);
            for xi in x.iter_mut() {
                *xi = *xi + c64::new(rng.normal() * q_var.sqrt(), 0.0);
            }
            let mut y = vec![c64::ZERO; 4];
            y[0] = x[0] + c64::new(rng.normal() * r_var.sqrt(), 0.0);
            y[2] = x[2] + c64::new(rng.normal() * r_var.sqrt(), 0.0);
            truth.push(x.clone());
            observations.push(GaussMessage::observation(&y, r_var));
        }
        KalmanProblem {
            steps,
            a,
            c,
            q_msg: GaussMessage::isotropic(4, q_var),
            r_var,
            truth,
            observations,
            prior: GaussMessage::isotropic(4, 0.5),
        }
    }

    /// Build the factor-graph chain: Multiply(A) → Add(Q) → Compound(C).
    pub fn build_graph(&self) -> (FactorGraph, Schedule) {
        let n = 4;
        let mut g = FactorGraph::new();
        let a_sid = g.add_state(self.a.clone());
        let c_sid = g.add_state(self.c.clone());
        let q_edge = g.add_input_edge(n, "msg_Q");
        let prior = g.add_input_edge(n, "msg_prior");
        let mut prev = prior;
        for i in 0..self.steps {
            let pred = g.add_edge(n, format!("pred{i}"));
            g.add_node(NodeKind::Multiply { a: a_sid }, vec![prev], pred, format!("mul{i}"));
            let noisy = g.add_edge(n, format!("noisy{i}"));
            g.add_node(NodeKind::Add, vec![pred, q_edge], noisy, format!("add{i}"));
            let obs = g.add_streamed_input_edge(n, 0, format!("msg_Y{i}"));
            let post = g.add_edge(n, format!("post{i}"));
            g.add_node(
                NodeKind::CompoundObservation { a: c_sid },
                vec![noisy, obs],
                post,
                format!("obs{i}"),
            );
            prev = post;
        }
        g.mark_output(prev);
        let s = Schedule::forward_sweep(&g);
        (g, s)
    }

    /// f64 golden filter.
    pub fn golden(&self) -> Result<KalmanOutcome> {
        let mut msg = self.prior.clone();
        for y in &self.observations {
            let pred = nodes::multiply(&msg, &self.a);
            let noisy = nodes::add(&pred, &self.q_msg);
            msg = nodes::compound_observation(&noisy, y, &self.c, true)?;
        }
        Ok(self.outcome(msg.mean, 0))
    }

    fn outcome(&self, estimate: Vec<c64>, cycles: u64) -> KalmanOutcome {
        let t = self.truth.last().unwrap();
        let dx = (estimate[0] - t[0]).abs2() + (estimate[2] - t[2]).abs2();
        KalmanOutcome { estimate, pos_error: dx.sqrt(), cycles }
    }

    pub fn compile_program(&self) -> Result<CompiledProgram> {
        let (g, s) = self.build_graph();
        Ok(compile(&g, &s, &CompileOptions::default())?)
    }

    /// Run on the FGP simulator, streaming observations.
    pub fn run_on_fgp(&self) -> Result<KalmanOutcome> {
        let compiled = self.compile_program()?;
        let mut fgp = Fgp::new(FgpConfig::default());
        fgp.pm.load(&compiled.program.to_image())?;

        // preload Q message and prior (matched by edge label)
        let (graph, sched) = self.build_graph();
        for (mid, slot) in &compiled.memmap.preloads {
            let edge = sched.inputs.iter().find(|(m, _)| m == mid).map(|(_, e)| *e).unwrap();
            if graph.edges[edge.0].label == "msg_Q" {
                fgp.msgmem.write_message(*slot, &self.q_msg);
            } else {
                fgp.msgmem.write_message(*slot, &self.prior);
            }
        }
        for (sid, slot) in &compiled.memmap.state_preloads {
            // state 0 = A, state 1 = C, state 2 = identity (if present)
            let m = match sid.0 {
                0 => self.a.clone(),
                1 => self.c.clone(),
                _ => CMatrix::identity(4),
            };
            fgp.statemem.write_matrix(*slot, &m);
        }

        let (_, obs_slot, _) = compiled.memmap.streams[0];
        let obs = self.observations.clone();
        let mut feed =
            move |section: usize, mem: &mut MessageMemory, _: &mut StateMemory| -> bool {
                // three smm commits per time step: step k's observation is
                // consumed by its compound node (the 3k+2-nd section) and
                // obs[k-1] dies at section 3k-1, so writing obs[sec/3] at
                // every handshake keeps the slot correct throughout
                let idx = (section / 3).min(obs.len() - 1);
                mem.write_message(obs_slot, &obs[idx]);
                section / 3 < obs.len()
            };
        let stats = fgp.run_program(1, &mut feed)?;

        let out_slot = compiled.memmap.outputs[0].1;
        let est = fgp.msgmem.read_message(out_slot).mean;
        Ok(self.outcome(est, stats.cycles))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_tracks_position() {
        let p = KalmanProblem::synthetic(40, 3);
        let out = p.golden().unwrap();
        assert!(out.pos_error < 0.2, "pos error {}", out.pos_error);
    }

    #[test]
    fn graph_has_three_nodes_per_step() {
        let p = KalmanProblem::synthetic(5, 1);
        let (g, s) = p.build_graph();
        assert_eq!(g.nodes.len(), 15);
        assert_eq!(s.steps.len(), 15);
    }

    #[test]
    fn fgp_tracks_golden_regime() {
        let p = KalmanProblem::synthetic(20, 5);
        let golden = p.golden().unwrap();
        let fgp = p.run_on_fgp().unwrap();
        assert!(
            fgp.pos_error < golden.pos_error + 0.3,
            "fgp {} vs golden {}",
            fgp.pos_error,
            golden.pos_error
        );
        assert!(fgp.cycles > 0);
    }

    #[test]
    fn program_compresses_across_steps() {
        let p = KalmanProblem::synthetic(12, 7);
        let c = p.compile_program().unwrap();
        assert!(c.stats.looped.is_some(), "listing:\n{}", c.listing());
        // slots stay constant regardless of steps: Q + prior-chain + obs
        assert!(c.memmap.num_slots <= 5, "{} slots", c.memmap.num_slots);
    }
}
