//! S10/S11 — Applications and synthetic workloads.
//!
//! The algorithm classes the paper positions the FGP for (§I: "RLS,
//! linear MMSE equalization, and Kalman filtering can be expressed with
//! Gaussian message-passing on a factor graph"). Every app implements
//! [`crate::engine::Workload`] — a factor-graph model plus host-side
//! data — and runs on any [`crate::engine::Engine`] through the same
//! [`crate::engine::Session::run`] call:
//!
//! * [`rls`] — the paper's §IV channel-estimation example (Fig. 6);
//! * [`kalman`] — constant-velocity tracking as alternating GMP nodes;
//! * [`lmmse`] — block LMMSE symbol equalization (one compound node);
//! * [`smoother`] — two-pass fixed-interval smoothing (forward filter,
//!   backward conditioning, equality fusion) as one program;
//! * [`toa`] — time-of-arrival position estimation (§I ref [6]) on the
//!   [`crate::nonlinear`] iterated-relinearization driver (repeated
//!   cache-hitting sweeps to the Gauss–Newton fixed point);
//! * [`bearing`] — bearing-only target tracking: per-step predict +
//!   update as one fixed-shape nonlinear workload, EKF vs. sigma-point
//!   linearizers compared on the same engine;
//! * [`rangechain`] — the pose loop with nonlinear per-leg range
//!   factors, relinearized inside loopy GBP each round;
//! * [`receiver`] — the §III multi-program baseband receiver, two
//!   workload shapes alternating through one session;
//! * [`channel`] — synthetic channels, constellations and AWGN sources
//!   (the "received symbols" the silicon would get from a radio);
//! * [`grid`] — 2-D grid smoothing/denoising via loopy GBP
//!   ([`crate::gbp`]): a cyclic Gaussian MRF no schedule can serve;
//! * [`posechain`] — pose-loop estimation with a loop-closure factor,
//!   the SLAM-style cyclic workload, also via [`crate::gbp`].
//!
//! The recursive apps — [`rls`], [`kalman`], [`smoother`] (its forward
//! filter) and [`bearing`] — additionally implement
//! [`crate::engine::StreamingWorkload`] and serve steady state through
//! [`crate::engine::Session::run_stream`]: compile once, stream the
//! samples (the paper's §VI throughput shape, benchmarked by
//! `rust/benches/table2_throughput.rs`).
//!
//! All workloads respect the device's input-scaling contract (see
//! [`crate::fgp`]): unit-magnitude-bounded operands, well-conditioned
//! covariances.

pub mod bearing;
pub mod channel;
pub mod grid;
pub mod kalman;
pub mod lmmse;
pub mod posechain;
pub mod rangechain;
pub mod receiver;
pub mod rls;
pub mod smoother;
pub mod toa;
