//! Time-of-arrival (ToA) position estimation as GMP (§I ref [6]).
//!
//! Anchors at known positions measure noisy ranges to a target; each
//! measurement, linearized around the running estimate, is one
//! compound-observation section refining a Gaussian belief over the 2-D
//! position (embedded in the FGP's 4-dim state: [px, py, 0, 0]). One
//! relinearization *round* — a sweep over all anchors at a fixed
//! linearization point — is a [`ToaSweep`] workload; the outer loop
//! re-runs it with updated linearizations. Because only the streamed
//! state matrices change between rounds, every round after the first is
//! a program-cache hit on the session.

use anyhow::Result;
use std::collections::HashMap;

use crate::engine::{bind_streamed, preload_id, Execution, Session, Workload};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::gmp::{FactorGraph, MsgId, Schedule};
use crate::testutil::Rng;

/// A ToA multilateration problem.
#[derive(Clone, Debug)]
pub struct ToaProblem {
    /// Anchor positions (meters, unit-scaled field [0,1]^2).
    pub anchors: Vec<(f64, f64)>,
    /// True target position.
    pub target: (f64, f64),
    /// Measured ranges (true range + noise).
    pub ranges: Vec<f64>,
    pub noise_var: f64,
}

/// Estimation outcome.
#[derive(Clone, Debug)]
pub struct ToaOutcome {
    pub estimate: (f64, f64),
    pub error: f64,
    /// Belief trace after each measurement round.
    pub trace: Vec<(f64, f64)>,
}

/// One relinearization round: a chain of compound-observation sections
/// (one per anchor) at a fixed linearization point.
#[derive(Clone, Debug)]
pub struct ToaSweep<'p> {
    pub problem: &'p ToaProblem,
    /// Belief entering the round (the chain's prior).
    pub belief: GaussMessage,
    /// Linearization point for the whole round.
    pub lin: (f64, f64),
}

/// Result of one sweep.
#[derive(Clone, Debug)]
pub struct ToaRound {
    pub belief: GaussMessage,
    pub estimate: (f64, f64),
}

impl ToaProblem {
    pub fn synthetic(num_anchors: usize, noise_var: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // anchors on the unit square's border, target inside
        let mut anchors = Vec::with_capacity(num_anchors);
        for i in 0..num_anchors {
            let t = i as f64 / num_anchors as f64;
            let p = match i % 4 {
                0 => (t, 0.0),
                1 => (1.0, t),
                2 => (1.0 - t, 1.0),
                _ => (0.0, 1.0 - t),
            };
            anchors.push(p);
        }
        let target = (rng.range(0.25, 0.75), rng.range(0.25, 0.75));
        let ranges = anchors
            .iter()
            .map(|a| {
                let d = ((a.0 - target.0).powi(2) + (a.1 - target.1).powi(2)).sqrt();
                d + rng.normal() * noise_var.sqrt()
            })
            .collect();
        ToaProblem { anchors, target, ranges, noise_var }
    }

    /// Linearized measurement row at the current estimate `p`:
    /// `r_i ≈ d_i(p) + u_i · (x - p)` with `u_i` the unit vector from
    /// anchor i to p. Returns (A, pseudo-observation message).
    fn linearize(&self, i: usize, p: (f64, f64), n: usize) -> (CMatrix, GaussMessage) {
        let a = self.anchors[i];
        let dx = p.0 - a.0;
        let dy = p.1 - a.1;
        let d = (dx * dx + dy * dy).sqrt().max(1e-6);
        let (ux, uy) = (dx / d, dy / d);
        let mut amat = CMatrix::zeros(n, n);
        amat[(0, 0)] = c64::new(ux, 0.0);
        amat[(0, 1)] = c64::new(uy, 0.0);
        // pseudo-observation: z = r_i - d(p) + u·p (scalar in dim 0)
        let z = self.ranges[i] - d + ux * p.0 + uy * p.1;
        let mut y = vec![c64::ZERO; n];
        y[0] = c64::new(z, 0.0);
        (amat, GaussMessage::observation(&y, self.noise_var.max(1e-4)))
    }

    /// Initial belief: centered on the field (position in the first two
    /// components), covariance 0.25 I.
    pub fn initial_belief(n: usize) -> GaussMessage {
        let mut mean = vec![c64::ZERO; n];
        mean[0] = c64::new(0.5, 0.0);
        mean[1] = c64::new(0.5, 0.0);
        GaussMessage::new(mean, CMatrix::scaled_identity(n, 0.25))
    }

    /// Run `rounds` sweeps over all anchors through the session,
    /// relinearizing each sweep.
    pub fn run(&self, session: &mut Session, rounds: usize) -> Result<ToaOutcome> {
        let n = 4;
        let mut belief = Self::initial_belief(n);
        let mut trace = Vec::new();
        for _ in 0..rounds {
            let lin = (belief.mean[0].re, belief.mean[1].re);
            let sweep = ToaSweep { problem: self, belief, lin };
            let round = session.run(&sweep)?;
            belief = round.outcome.belief;
            trace.push(round.outcome.estimate);
        }
        let estimate = (belief.mean[0].re, belief.mean[1].re);
        let error = ((estimate.0 - self.target.0).powi(2)
            + (estimate.1 - self.target.1).powi(2))
        .sqrt();
        Ok(ToaOutcome { estimate, error, trace })
    }
}

impl Workload for ToaSweep<'_> {
    type Outcome = ToaRound;

    fn name(&self) -> &str {
        "toa_sweep"
    }

    fn n(&self) -> usize {
        4
    }

    /// A compound-node chain with one section per anchor; the linearized
    /// measurement rows are the streamed state matrices.
    fn model(&self) -> Result<(FactorGraph, Schedule)> {
        let n = self.n();
        let a_list: Vec<CMatrix> = (0..self.problem.anchors.len())
            .map(|i| self.problem.linearize(i, self.lin, n).0)
            .collect();
        let mut g = FactorGraph::new();
        g.rls_chain(n, &a_list);
        let s = Schedule::forward_sweep(&g);
        Ok((g, s))
    }

    fn inputs(
        &self,
        graph: &FactorGraph,
        schedule: &Schedule,
    ) -> Result<HashMap<MsgId, GaussMessage>> {
        let n = self.n();
        let mut map = HashMap::new();
        map.insert(preload_id(graph, schedule, "msg_prior")?, self.belief.clone());
        let obs: Vec<GaussMessage> = (0..self.problem.anchors.len())
            .map(|i| self.problem.linearize(i, self.lin, n).1)
            .collect();
        bind_streamed(graph, schedule, &obs, &mut map)?;
        Ok(map)
    }

    fn outcome(&self, exec: &Execution) -> Result<ToaRound> {
        let belief = exec.output()?.clone();
        let estimate = (belief.mean[0].re, belief.mean[1].re);
        Ok(ToaRound { belief, estimate })
    }

    /// Position error of the round's estimate against ground truth.
    fn quality(&self, outcome: &ToaRound) -> f64 {
        ((outcome.estimate.0 - self.problem.target.0).powi(2)
            + (outcome.estimate.1 - self.problem.target.1).powi(2))
        .sqrt()
    }

    /// The Q5.10 datapath quantizes the tight range observations near
    /// the LSB; the fix must stay in the same regime as golden.
    fn tolerance(&self) -> f64 {
        0.2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fgp::FgpConfig;

    #[test]
    fn golden_locates_target() {
        let mut golden = Session::golden();
        let p = ToaProblem::synthetic(6, 1e-4, 3);
        let o = p.run(&mut golden, 3).unwrap();
        assert!(o.error < 0.05, "position error {}", o.error);
    }

    #[test]
    fn relinearization_improves() {
        // Re-sweeping the same measurements sharpens the linearization
        // point; the estimate must not drift away from the target (small
        // slack: reused observations make later rounds overconfident).
        let mut golden = Session::golden();
        let p = ToaProblem::synthetic(6, 1e-4, 5);
        let one = p.run(&mut golden, 1).unwrap();
        let three = p.run(&mut golden, 3).unwrap();
        assert!(three.error <= one.error + 0.02, "one {} three {}", one.error, three.error);
    }

    #[test]
    fn more_anchors_do_not_hurt() {
        let mut golden = Session::golden();
        let few = ToaProblem::synthetic(4, 1e-3, 11).run(&mut golden, 2).unwrap();
        let many = ToaProblem::synthetic(12, 1e-3, 11).run(&mut golden, 2).unwrap();
        assert!(many.error <= few.error + 0.05);
    }

    #[test]
    fn fgp_sim_locates_in_same_regime() {
        let mut sim = Session::fgp_sim(FgpConfig::default());
        let p = ToaProblem::synthetic(6, 1e-3, 7);
        let o = p.run(&mut sim, 2).unwrap();
        assert!(o.error < 0.2, "fixed-point position error {}", o.error);
        // both rounds share one program shape -> second round is a hit
        let stats = sim.cache_stats();
        assert_eq!((stats.misses, stats.hits), (1, 1));
    }
}
