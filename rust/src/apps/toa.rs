//! Time-of-arrival (ToA) position estimation as GMP (§I ref [6]).
//!
//! Anchors at known positions measure noisy ranges to a target; each
//! measurement, linearized around the running estimate, is one
//! compound-observation section refining a Gaussian belief over the 2-D
//! position (embedded in the FGP's 4-dim state: [px, py, 0, 0]). The
//! iterative relinearization is exactly the "factor-graph-based TOA
//! location estimator" structure of the reference.

use anyhow::Result;

use crate::coordinator::backend::{Backend, CnRequestData};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::testutil::Rng;

/// A ToA multilateration problem.
#[derive(Clone, Debug)]
pub struct ToaProblem {
    /// Anchor positions (meters, unit-scaled field [0,1]^2).
    pub anchors: Vec<(f64, f64)>,
    /// True target position.
    pub target: (f64, f64),
    /// Measured ranges (true range + noise).
    pub ranges: Vec<f64>,
    pub noise_var: f64,
}

/// Estimation outcome.
#[derive(Clone, Debug)]
pub struct ToaOutcome {
    pub estimate: (f64, f64),
    pub error: f64,
    /// Belief trace after each measurement round.
    pub trace: Vec<(f64, f64)>,
}

impl ToaProblem {
    pub fn synthetic(num_anchors: usize, noise_var: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // anchors on the unit square's border, target inside
        let mut anchors = Vec::with_capacity(num_anchors);
        for i in 0..num_anchors {
            let t = i as f64 / num_anchors as f64;
            let p = match i % 4 {
                0 => (t, 0.0),
                1 => (1.0, t),
                2 => (1.0 - t, 1.0),
                _ => (0.0, 1.0 - t),
            };
            anchors.push(p);
        }
        let target = (rng.range(0.25, 0.75), rng.range(0.25, 0.75));
        let ranges = anchors
            .iter()
            .map(|a| {
                let d = ((a.0 - target.0).powi(2) + (a.1 - target.1).powi(2)).sqrt();
                d + rng.normal() * noise_var.sqrt()
            })
            .collect();
        ToaProblem { anchors, target, ranges, noise_var }
    }

    /// Linearized measurement row at the current estimate `p`:
    /// `r_i ≈ d_i(p) + u_i · (x - p)` with `u_i` the unit vector from
    /// anchor i to p. Returns (A, pseudo-observation message).
    fn linearize(&self, i: usize, p: (f64, f64), n: usize) -> (CMatrix, GaussMessage) {
        let a = self.anchors[i];
        let dx = p.0 - a.0;
        let dy = p.1 - a.1;
        let d = (dx * dx + dy * dy).sqrt().max(1e-6);
        let (ux, uy) = (dx / d, dy / d);
        let mut amat = CMatrix::zeros(n, n);
        amat[(0, 0)] = c64::new(ux, 0.0);
        amat[(0, 1)] = c64::new(uy, 0.0);
        // pseudo-observation: z = r_i - d(p) + u·p (scalar in dim 0)
        let z = self.ranges[i] - d + ux * p.0 + uy * p.1;
        let mut y = vec![c64::ZERO; n];
        y[0] = c64::new(z, 0.0);
        (amat, GaussMessage::observation(&y, self.noise_var.max(1e-4)))
    }

    /// Run `rounds` sweeps over all anchors, relinearizing each sweep.
    pub fn run_on(&self, backend: &mut dyn Backend, rounds: usize) -> Result<ToaOutcome> {
        let n = 4;
        let mut belief = GaussMessage::new(
            vec![c64::new(0.5, 0.0), c64::new(0.5, 0.0), c64::ZERO, c64::ZERO],
            CMatrix::scaled_identity(n, 0.25),
        );
        let mut trace = Vec::new();
        for _ in 0..rounds {
            let p = (belief.mean[0].re, belief.mean[1].re);
            for i in 0..self.anchors.len() {
                let (a, y) = self.linearize(i, p, n);
                belief = backend.cn_update(&CnRequestData {
                    x: belief.clone(),
                    y,
                    a,
                })?;
            }
            trace.push((belief.mean[0].re, belief.mean[1].re));
        }
        let estimate = (belief.mean[0].re, belief.mean[1].re);
        let error = ((estimate.0 - self.target.0).powi(2)
            + (estimate.1 - self.target.1).powi(2))
        .sqrt();
        Ok(ToaOutcome { estimate, error, trace })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{FgpSimBackend, GoldenBackend};
    use crate::fgp::FgpConfig;

    #[test]
    fn golden_locates_target() {
        let mut golden = GoldenBackend;
        let p = ToaProblem::synthetic(6, 1e-4, 3);
        let o = p.run_on(&mut golden, 3).unwrap();
        assert!(o.error < 0.05, "position error {}", o.error);
    }

    #[test]
    fn relinearization_improves() {
        // Re-sweeping the same measurements sharpens the linearization
        // point; the estimate must not drift away from the target (small
        // slack: reused observations make later rounds overconfident).
        let mut golden = GoldenBackend;
        let p = ToaProblem::synthetic(6, 1e-4, 5);
        let one = p.run_on(&mut golden, 1).unwrap();
        let three = p.run_on(&mut golden, 3).unwrap();
        assert!(three.error <= one.error + 0.02, "one {} three {}", one.error, three.error);
    }

    #[test]
    fn more_anchors_do_not_hurt() {
        let mut golden = GoldenBackend;
        let few = ToaProblem::synthetic(4, 1e-3, 11).run_on(&mut golden, 2).unwrap();
        let many = ToaProblem::synthetic(12, 1e-3, 11).run_on(&mut golden, 2).unwrap();
        assert!(many.error <= few.error + 0.05);
    }

    #[test]
    fn fgp_sim_locates_in_same_regime() {
        let mut sim = FgpSimBackend::new(FgpConfig::default()).unwrap();
        let p = ToaProblem::synthetic(6, 1e-3, 7);
        let o = p.run_on(&mut sim, 2).unwrap();
        assert!(o.error < 0.15, "fixed-point position error {}", o.error);
    }
}
