//! Time-of-arrival (ToA) position estimation as nonlinear GMP (§I ref [6]).
//!
//! Anchors at known positions measure noisy ranges to a target: a
//! textbook nonlinear estimation problem, expressed here as a
//! [`NonlinearProblem`] — one range [`NonlinearFactor`] per anchor over
//! a Gaussian belief on the 2-D position (embedded in the FGP's 4-dim
//! state as `[px, py, 0, 0]`) — and solved by the
//! [`IteratedRelinearization`] driver: re-linearize at the current
//! belief, run one compound-observation sweep over all anchors, move
//! the linearization point, repeat to the Gauss–Newton fixed point.
//! The sweep's graph shape is fixed across rounds, so every round after
//! the first is a program-cache hit on the session. (This app used to
//! own a private relinearization loop; the driver in
//! [`crate::nonlinear`] is that loop, generalized.)

use std::sync::Arc;

use anyhow::Result;

use crate::engine::Session;
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::nonlinear::{
    FirstOrder, IteratedRelinearization, NonlinearFactor, NonlinearProblem, RelinOptions,
};
use crate::testutil::Rng;

/// A ToA multilateration problem.
#[derive(Clone, Debug)]
pub struct ToaProblem {
    /// Anchor positions (meters, unit-scaled field [0,1]^2).
    pub anchors: Vec<(f64, f64)>,
    /// True target position.
    pub target: (f64, f64),
    /// Measured ranges (true range + noise).
    pub ranges: Vec<f64>,
    /// Range measurement noise variance.
    pub noise_var: f64,
}

/// Estimation outcome.
#[derive(Clone, Debug)]
pub struct ToaOutcome {
    /// Estimated target position.
    pub estimate: (f64, f64),
    /// Euclidean error against the true position.
    pub error: f64,
    /// Belief trace after each relinearization round.
    pub trace: Vec<(f64, f64)>,
}

impl ToaProblem {
    /// Generate a random anchors-and-target instance.
    pub fn synthetic(num_anchors: usize, noise_var: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // anchors on the unit square's border, target inside
        let mut anchors = Vec::with_capacity(num_anchors);
        for i in 0..num_anchors {
            let t = i as f64 / num_anchors as f64;
            let p = match i % 4 {
                0 => (t, 0.0),
                1 => (1.0, t),
                2 => (1.0 - t, 1.0),
                _ => (0.0, 1.0 - t),
            };
            anchors.push(p);
        }
        let target = (rng.range(0.25, 0.75), rng.range(0.25, 0.75));
        let ranges = anchors
            .iter()
            .map(|a| {
                let d = ((a.0 - target.0).powi(2) + (a.1 - target.1).powi(2)).sqrt();
                d + rng.normal() * noise_var.sqrt()
            })
            .collect();
        ToaProblem { anchors, target, ranges, noise_var }
    }

    /// Initial belief: centered on the field (position in the first two
    /// components), covariance 0.25 I.
    pub fn initial_belief(n: usize) -> GaussMessage {
        let mut mean = vec![c64::ZERO; n];
        mean[0] = c64::new(0.5, 0.0);
        mean[1] = c64::new(0.5, 0.0);
        GaussMessage::new(mean, CMatrix::scaled_identity(n, 0.25))
    }

    /// The problem as a [`NonlinearProblem`]: one range factor per
    /// anchor (analytic Jacobian — the unit vector from anchor to
    /// estimate), the centered initial belief as prior. The observation
    /// noise is floored at 1e-4 so the Q5.10 datapath does not quantize
    /// the observation covariance to zero.
    pub fn nonlinear_problem(&self, n: usize) -> Result<NonlinearProblem> {
        let var = self.noise_var.max(1e-4);
        let factors = self
            .anchors
            .iter()
            .zip(&self.ranges)
            .map(|(&(ax, ay), &r)| {
                let h = move |x: &[f64]| {
                    vec![((x[0] - ax).powi(2) + (x[1] - ay).powi(2)).sqrt()]
                };
                let jac = move |x: &[f64]| {
                    let dx = x[0] - ax;
                    let dy = x[1] - ay;
                    let d = (dx * dx + dy * dy).sqrt().max(1e-6);
                    let mut row = vec![0.0; x.len()];
                    row[0] = dx / d;
                    row[1] = dy / d;
                    vec![row]
                };
                Ok(NonlinearFactor::new(n, 1, Arc::new(h), vec![r], var)?
                    .with_jacobian(Arc::new(jac)))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(NonlinearProblem {
            n,
            prior: Self::initial_belief(n),
            motion: None,
            factors,
        })
    }

    /// Run up to `rounds` relinearization sweeps through the session —
    /// each sweep covers all anchors at one linearization point; the
    /// driver stops early at the Gauss–Newton fixed point.
    pub fn run(&self, session: &mut Session, rounds: usize) -> Result<ToaOutcome> {
        let problem = self.nonlinear_problem(4)?;
        let driver = IteratedRelinearization::with_options(
            &FirstOrder,
            RelinOptions { max_rounds: rounds.max(1), ..Default::default() },
        );
        let report = driver.run(session, &problem)?;
        let trace: Vec<(f64, f64)> = report
            .trace
            .iter()
            .map(|b| (b.mean[0].re, b.mean[1].re))
            .collect();
        let estimate = (report.belief.mean[0].re, report.belief.mean[1].re);
        let error = ((estimate.0 - self.target.0).powi(2)
            + (estimate.1 - self.target.1).powi(2))
        .sqrt();
        Ok(ToaOutcome { estimate, error, trace })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fgp::FgpConfig;

    #[test]
    fn golden_locates_target() {
        let mut golden = Session::golden();
        let p = ToaProblem::synthetic(6, 1e-4, 3);
        let o = p.run(&mut golden, 3).unwrap();
        assert!(o.error < 0.05, "position error {}", o.error);
    }

    #[test]
    fn relinearization_improves() {
        // every round starts from the same prior — more rounds only
        // sharpen the linearization point (Gauss–Newton descent), so
        // the estimate must not drift away from the target
        let mut golden = Session::golden();
        let p = ToaProblem::synthetic(6, 1e-4, 5);
        let one = p.run(&mut golden, 1).unwrap();
        let three = p.run(&mut golden, 3).unwrap();
        // slack: the MAP optimum can sit a hair further from ground
        // truth than an early iterate when the noise draw conspires
        assert!(three.error <= one.error + 0.03, "one {} three {}", one.error, three.error);
    }

    #[test]
    fn more_anchors_do_not_hurt() {
        let mut golden = Session::golden();
        let few = ToaProblem::synthetic(4, 1e-3, 11).run(&mut golden, 2).unwrap();
        let many = ToaProblem::synthetic(12, 1e-3, 11).run(&mut golden, 2).unwrap();
        assert!(many.error <= few.error + 0.05);
    }

    #[test]
    fn fgp_sim_locates_in_same_regime() {
        let mut sim = Session::fgp_sim(FgpConfig::default());
        let p = ToaProblem::synthetic(6, 1e-3, 7);
        let o = p.run(&mut sim, 2).unwrap();
        assert!(o.error < 0.2, "fixed-point position error {}", o.error);
        // both rounds share one program shape -> second round is a hit
        let stats = sim.cache_stats();
        assert_eq!((stats.misses, stats.hits), (1, 1));
    }

    #[test]
    fn driver_fixed_point_matches_gauss_newton() {
        // the IEKF fixed point is the MAP/Gauss-Newton solution
        let mut golden = Session::golden();
        let p = ToaProblem::synthetic(6, 1e-3, 9);
        let problem = p.nonlinear_problem(4).unwrap();
        let o = p.run(&mut golden, 8).unwrap();
        let gn = crate::nonlinear::gauss_newton(&problem, 50, 1e-12).unwrap();
        let d = ((o.estimate.0 - gn.mean[0].re).powi(2)
            + (o.estimate.1 - gn.mean[1].re).powi(2))
        .sqrt();
        assert!(d < 1e-6, "driver vs gauss-newton: {d}");
    }
}
