//! The paper's §IV example: RLS/LMMSE channel estimation on the FGP.
//!
//! Fig. 6's factor graph — one compound-observation section per received
//! training symbol — built as a [`Workload`] and runnable on any engine
//! through [`crate::engine::Session`]: the f64 golden chain, the
//! cycle-accurate simulator (host streaming observations and regressors
//! exactly as the "HW-SW interaction" section describes), or the PJRT
//! `rls_chain` artifact.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::compiler::{compile, CompileOptions, CompiledProgram};
use crate::em::{
    chain_log_likelihood, EmEstimand, EmParameter, Evidence, NoiseSection, ObsNoiseVar,
    OnlineNoiseSource, OnlineSection, SuffStats,
};
use crate::engine::{
    bind_streamed, preload_id, Execution, Session, StreamRun, StreamSample, StreamingWorkload,
    Workload,
};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::gmp::{FactorGraph, MsgId, Schedule};
use crate::testutil::Rng;

use super::channel::{regressor_matrix, Constellation, MultipathChannel};

/// A synthetic channel-estimation problem instance.
#[derive(Clone, Debug)]
pub struct RlsProblem {
    /// Channel order / state dimension.
    pub n: usize,
    /// Training sections (one compound node each).
    pub sections: usize,
    /// Observation-noise variance the data was synthesized at.
    pub sigma2: f64,
    /// True channel taps (ground truth for MSE).
    pub h_true: Vec<c64>,
    /// Training symbols.
    pub symbols: Vec<c64>,
    /// Per-section regressor matrices (the streamed state A_i).
    pub regressors: Vec<CMatrix>,
    /// Per-section observation messages (the streamed msg_Y).
    pub observations: Vec<GaussMessage>,
    /// Prior on the channel state.
    pub prior: GaussMessage,
}

/// Result of running the problem on some engine.
#[derive(Clone, Debug)]
pub struct RlsOutcome {
    /// Final channel estimate.
    pub h_hat: Vec<c64>,
    /// Relative MSE ||h_hat - h||^2 / ||h||^2.
    pub rel_mse: f64,
}

impl RlsProblem {
    /// Generate a random instance (QPSK training, exponential PDP).
    pub fn synthetic(n: usize, sections: usize, sigma2: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let chan = MultipathChannel::random(&mut rng, n, 0.25);
        let symbols: Vec<c64> =
            (0..sections).map(|_| Constellation::Qpsk.draw(&mut rng)).collect();
        let received = chan.transmit(&mut rng, &symbols, sigma2);
        let mut regressors = Vec::with_capacity(sections);
        let mut observations = Vec::with_capacity(sections);
        for i in 0..sections {
            regressors.push(regressor_matrix(&symbols, i, n));
            // observation message: the received symbol in the first
            // component, noise covariance sigma2 * I (test_model.py conv.)
            let mut y = vec![c64::ZERO; n];
            y[0] = received[i];
            observations.push(GaussMessage::observation(&y, sigma2));
        }
        RlsProblem {
            n,
            sections,
            sigma2,
            h_true: chan.taps,
            symbols,
            regressors,
            observations,
            // prior at the top of the input-scaling contract
            prior: GaussMessage::isotropic(n, 1.0),
        }
    }

    /// The same instance with every observation message rebuilt at
    /// noise variance `sigma2` — the adaptive/EM path re-runs the chain
    /// at the current estimate. Only message *data* changes: the graph
    /// shape is untouched, so re-runs stay program-cache hits.
    pub fn with_noise(&self, sigma2: f64) -> RlsProblem {
        let mut p = self.clone();
        p.sigma2 = sigma2;
        p.observations = self
            .observations
            .iter()
            .map(|o| GaussMessage::observation(&o.mean, sigma2))
            .collect();
        p
    }

    /// Relative MSE of a channel estimate against the true taps.
    pub fn rel_mse(&self, h_hat: &[c64]) -> f64 {
        let num: f64 = self
            .h_true
            .iter()
            .zip(h_hat)
            .map(|(a, b)| (*a - *b).abs2())
            .sum();
        let den: f64 = self.h_true.iter().map(|a| a.abs2()).sum();
        num / den
    }

    /// Build the Fig. 6 factor graph.
    pub fn build_graph(&self) -> (FactorGraph, Schedule) {
        let mut g = FactorGraph::new();
        g.rls_chain(self.n, &self.regressors);
        let s = Schedule::forward_sweep(&g);
        (g, s)
    }

    /// Compile the graph (Listing 1 → Listing 2) — compiler-report
    /// helper; execution goes through [`crate::engine::Session::run`].
    pub fn compile_program(&self) -> Result<CompiledProgram> {
        let (g, s) = self.build_graph();
        compile(&g, &s, &CompileOptions::default()).context("compiling RLS factor graph")
    }
}

impl Workload for RlsProblem {
    type Outcome = RlsOutcome;

    fn name(&self) -> &str {
        "rls_channel_estimation"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn model(&self) -> Result<(FactorGraph, Schedule)> {
        Ok(self.build_graph())
    }

    fn inputs(
        &self,
        graph: &FactorGraph,
        schedule: &Schedule,
    ) -> Result<HashMap<MsgId, GaussMessage>> {
        let mut map = HashMap::new();
        map.insert(preload_id(graph, schedule, "msg_prior")?, self.prior.clone());
        bind_streamed(graph, schedule, &self.observations, &mut map)?;
        Ok(map)
    }

    fn outcome(&self, exec: &Execution) -> Result<RlsOutcome> {
        let h_hat = exec.output()?.mean.clone();
        Ok(RlsOutcome { rel_mse: self.rel_mse(&h_hat), h_hat })
    }

    fn quality(&self, outcome: &RlsOutcome) -> f64 {
        outcome.rel_mse
    }

    /// 16-bit fixed point hits an accuracy floor once the posterior
    /// covariance approaches the LSB (E9 sweeps this); the estimate must
    /// still be in the converged regime.
    fn tolerance(&self) -> f64 {
        0.2
    }
}

/// The steady-state serving form: one compound-observation section per
/// received training symbol, the running posterior threading through as
/// the recursive state — exactly the §VI "program loaded once, samples
/// stream through" shape Table II benchmarks.
impl StreamingWorkload for RlsProblem {
    type StreamOutcome = RlsOutcome;

    fn stream_name(&self) -> &str {
        "rls_channel_stream"
    }

    fn state_dim(&self) -> usize {
        self.n
    }

    fn stream_model(&self, chunk: usize) -> Result<(FactorGraph, Schedule)> {
        let mut g = FactorGraph::new();
        // per-sample regressors are streamed states: placeholder values,
        // rebound by the driver before every dispatch
        g.rls_chain(self.n, &vec![CMatrix::identity(self.n); chunk]);
        let s = Schedule::forward_sweep(&g);
        Ok((g, s))
    }

    fn initial_state(&self) -> GaussMessage {
        self.prior.clone()
    }

    fn next_sample(&self, k: usize, _state: &GaussMessage) -> Result<Option<StreamSample>> {
        Ok((k < self.sections).then(|| StreamSample {
            messages: vec![self.observations[k].clone()],
            states: vec![self.regressors[k].clone()],
        }))
    }

    fn stream_outcome(&self, run: &StreamRun) -> Result<RlsOutcome> {
        let h_hat = run.final_state.mean.clone();
        Ok(RlsOutcome { rel_mse: self.rel_mse(&h_hat), h_hat })
    }
}

// ---------------------------------------------------------------------
// EM: unknown observation-noise variance (the paper's example, adaptive)
// ---------------------------------------------------------------------

/// The §IV channel-estimation example with **unknown** observation-noise
/// variance, estimated by EM ([`crate::em`]): each round re-runs the
/// same Fig. 6 chain with the observation covariances rebuilt at the
/// current estimate (data only — rounds after the first are program-
/// cache hits), reads the posterior channel marginal back from the
/// engine, and commits the closed-form variance update.
pub struct NoiseEmRls {
    /// The underlying problem; `problem.sigma2` is the (hidden) truth
    /// used to synthesize the data, never read by the estimator.
    pub problem: RlsProblem,
    noise: ObsNoiseVar,
    posterior: Option<GaussMessage>,
}

impl NoiseEmRls {
    /// Estimate the noise of `problem` starting from `sigma0`.
    pub fn new(problem: RlsProblem, sigma0: f64) -> Self {
        NoiseEmRls { problem, noise: ObsNoiseVar::new(sigma0), posterior: None }
    }

    /// Current noise-variance estimate.
    pub fn sigma2(&self) -> f64 {
        self.noise.value()
    }

    /// Posterior channel marginal from the most recent E-step run.
    pub fn posterior(&self) -> Option<&GaussMessage> {
        self.posterior.as_ref()
    }

    /// Channel estimate quality at the most recent posterior.
    pub fn outcome(&self) -> Result<RlsOutcome> {
        let post = self.posterior.as_ref().context("no E-step has run yet")?;
        let h_hat = post.mean.clone();
        Ok(RlsOutcome { rel_mse: self.problem.rel_mse(&h_hat), h_hat })
    }
}

impl EmEstimand for NoiseEmRls {
    fn values(&self) -> Vec<f64> {
        vec![self.noise.value()]
    }

    fn e_step(&mut self, session: &mut Session, acc: &mut [SuffStats]) -> Result<bool> {
        let w = self.problem.with_noise(self.noise.value());
        let (graph, schedule) = w.model()?;
        let inputs = w.inputs(&graph, &schedule)?;
        let d = session
            .dispatch(&graph, &schedule, &inputs, &w.compile_options())
            .context("EM E-step chain run")?;
        let post = d.exec.output()?.clone();
        let observed = [0usize];
        for (a, o) in self.problem.regressors.iter().zip(&self.problem.observations) {
            self.noise.accumulate(
                &Evidence::Observation { marginal: &post, a, y: &o.mean, observed: &observed },
                &mut acc[0],
            )?;
        }
        self.posterior = Some(post);
        Ok(d.cached)
    }

    fn m_step(&mut self, acc: &[SuffStats]) -> Result<Vec<f64>> {
        Ok(vec![self.noise.m_step(&acc[0])?])
    }

    fn log_likelihood(&self) -> Result<Option<f64>> {
        let observed = [0usize];
        chain_log_likelihood(
            &self.problem.prior,
            self.problem
                .regressors
                .iter()
                .zip(&self.problem.observations)
                .map(|(a, o)| NoiseSection { a, y: &o.mean, observed: &observed }),
            self.noise.value(),
        )
        .map(Some)
    }
}

/// Online EM source: the stream's observation messages can be rebuilt
/// mid-flight at a fresh noise estimate ([`crate::em::OnlineEm`] wraps
/// this and rides `Session::run_stream` / farm sticky streams
/// unchanged).
impl OnlineNoiseSource for RlsProblem {
    fn sample_at(&self, k: usize, sigma2: f64) -> Result<Option<StreamSample>> {
        Ok((k < self.sections).then(|| StreamSample {
            messages: vec![GaussMessage::observation(&self.observations[k].mean, sigma2)],
            states: vec![self.regressors[k].clone()],
        }))
    }

    fn section(&self, k: usize) -> Option<OnlineSection> {
        (k < self.sections).then(|| OnlineSection {
            a: self.regressors[k].clone(),
            y: self.observations[k].mean.clone(),
            observed: vec![0],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::em::{EmDriver, EmOptions, OnlineEm};
    use crate::engine::Session;
    use crate::fgp::FgpConfig;

    #[test]
    fn golden_rls_converges() {
        let p = RlsProblem::synthetic(4, 48, 0.01, 7);
        let out = Session::golden().run(&p).unwrap();
        assert!(out.quality < 0.02, "rel MSE {}", out.quality);
    }

    #[test]
    fn golden_improves_with_sections() {
        let mut golden = Session::golden();
        let short = golden.run(&RlsProblem::synthetic(4, 6, 0.02, 9)).unwrap();
        let long = golden.run(&RlsProblem::synthetic(4, 48, 0.02, 9)).unwrap();
        assert!(long.quality < short.quality);
    }

    #[test]
    fn fgp_tracks_golden() {
        let p = RlsProblem::synthetic(4, 24, 0.02, 11);
        let golden = Session::golden().run(&p).unwrap();
        let fgp = Session::fgp_sim(FgpConfig::default()).run(&p).unwrap();
        assert!(fgp.quality < 0.25, "FGP rel MSE {}", fgp.quality);
        assert!(
            fgp.quality < golden.quality + p.tolerance(),
            "fgp {} vs golden {}",
            fgp.quality,
            golden.quality
        );
        // cycle accounting: S sections at the CN rate
        let cfg = FgpConfig::default();
        assert_eq!(fgp.cycles, cfg.timing.compound_node_cycles(4) * 24);
        assert_eq!(fgp.sections, 24);
    }

    #[test]
    fn size_mismatch_is_reported_not_panicked() {
        let p = RlsProblem::synthetic(6, 4, 0.02, 3);
        let err = Session::fgp_sim(FgpConfig::default()).run(&p).unwrap_err();
        assert!(format!("{err:#}").contains("n=6"), "{err:#}");
    }

    #[test]
    fn stream_matches_batch_on_golden() {
        let p = RlsProblem::synthetic(4, 20, 0.01, 5);
        let batch = Session::golden().run(&p).unwrap();
        let stream = Session::golden().run_stream(&p).unwrap();
        assert_eq!(stream.samples, 20);
        // same node rules in the same order: identical estimate
        assert!((stream.outcome.rel_mse - batch.outcome.rel_mse).abs() < 1e-12);
    }

    #[test]
    fn compile_stats_show_fig7_win() {
        let p = RlsProblem::synthetic(4, 16, 0.02, 13);
        let c = p.compile_program().unwrap();
        assert!(c.stats.slots_optimized < c.stats.slots_unoptimized);
        assert_eq!(c.stats.slots_optimized, 2);
        assert!(c.stats.looped.is_some());
    }

    #[test]
    fn with_noise_rebuilds_covariances_only() {
        let p = RlsProblem::synthetic(4, 8, 0.01, 3);
        let q = p.with_noise(0.04);
        assert_eq!(q.sigma2, 0.04);
        for (a, b) in p.observations.iter().zip(&q.observations) {
            assert_eq!(a.mean, b.mean);
            assert!((b.cov[(0, 0)].re - 0.04).abs() < 1e-12);
        }
        // same graph shape: the EM rounds must stay cache hits
        let (ga, sa) = p.build_graph();
        let (gb, sb) = q.build_graph();
        assert_eq!(ga.nodes.len(), gb.nodes.len());
        assert_eq!(sa.steps.len(), sb.steps.len());
    }

    #[test]
    fn em_noise_estimate_converges_to_truth() {
        let p = RlsProblem::synthetic(4, 256, 0.01, 17);
        let mut em = NoiseEmRls::new(p, 0.1); // start 10x off
        let report = EmDriver::new().run(&mut Session::golden(), &mut em).unwrap();
        assert!(report.converged(), "stop {:?}", report.stop);
        let got = report.values[0];
        assert!((got - 0.01).abs() / 0.01 < 0.05, "sigma2 {got}");
        assert!((em.sigma2() - got).abs() < 1e-15);
        // the channel estimate is still in the converged regime
        assert!(em.outcome().unwrap().rel_mse < 0.05);
        // exact EM: dense log-likelihood never decreases
        for w in report.log_likelihood.windows(2) {
            assert!(w[1] >= w[0] - 1e-7 * w[0].abs().max(1.0), "{:?}", report.log_likelihood);
        }
    }

    #[test]
    fn em_rounds_with_wrong_tol_report_max_rounds() {
        let p = RlsProblem::synthetic(4, 16, 0.02, 5);
        let mut em = NoiseEmRls::new(p, 0.2);
        let driver = EmDriver::with_options(EmOptions {
            max_rounds: 3,
            tol: 0.0,
            divergence: 1e9,
        });
        let report = driver.run(&mut Session::golden(), &mut em).unwrap();
        assert_eq!(report.rounds, 3);
        assert!(!report.converged());
    }

    #[test]
    fn online_em_tracks_noise_on_golden_stream() {
        let p = RlsProblem::synthetic(4, 512, 0.01, 1);
        let em = OnlineEm::new(p, 0.1); // start 10x off
        let report = Session::golden().run_stream(&em).unwrap();
        assert_eq!(report.samples, 512);
        let got = report.outcome.sigma2;
        assert!((got - 0.01).abs() / 0.01 < 0.15, "online sigma2 {got}");
        assert!((em.estimate() - got).abs() < 1e-15);
        // the channel estimate still converges while the noise adapts
        assert!(report.outcome.inner.rel_mse < 0.02, "rel mse {}", report.outcome.inner.rel_mse);
    }
}
