//! The paper's §IV example: RLS/LMMSE channel estimation on the FGP.
//!
//! Fig. 6's factor graph — one compound-observation section per received
//! training symbol — built, compiled (Listing 1 → Listing 2), and run on
//! the cycle-accurate simulator with the host streaming observations and
//! regressors exactly as the "HW-SW interaction" section describes.

use anyhow::{Context, Result};

use crate::compiler::{compile, CompileOptions, CompileStats, CompiledProgram};
use crate::fgp::{Fgp, FgpConfig, MessageMemory, StateMemory};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::gmp::{nodes, FactorGraph, Schedule};
use crate::testutil::Rng;

use super::channel::{regressor_matrix, Constellation, MultipathChannel};

/// A synthetic channel-estimation problem instance.
#[derive(Clone, Debug)]
pub struct RlsProblem {
    pub n: usize,
    pub sections: usize,
    pub sigma2: f64,
    /// True channel taps (ground truth for MSE).
    pub h_true: Vec<c64>,
    /// Training symbols.
    pub symbols: Vec<c64>,
    /// Per-section regressor matrices (the streamed state A_i).
    pub regressors: Vec<CMatrix>,
    /// Per-section observation messages (the streamed msg_Y).
    pub observations: Vec<GaussMessage>,
    /// Prior on the channel state.
    pub prior: GaussMessage,
}

/// Result of running the problem on some engine.
#[derive(Clone, Debug)]
pub struct RlsOutcome {
    /// Final channel estimate.
    pub h_hat: Vec<c64>,
    /// Relative MSE ||h_hat - h||^2 / ||h||^2.
    pub rel_mse: f64,
    /// Device cycles (simulator runs only).
    pub cycles: u64,
    pub cycles_per_section: u64,
    /// Compile statistics (Fig. 7 data).
    pub compile_stats: Option<CompileStats>,
}

impl RlsProblem {
    /// Generate a random instance (QPSK training, exponential PDP).
    pub fn synthetic(n: usize, sections: usize, sigma2: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let chan = MultipathChannel::random(&mut rng, n, 0.25);
        let symbols: Vec<c64> =
            (0..sections).map(|_| Constellation::Qpsk.draw(&mut rng)).collect();
        let received = chan.transmit(&mut rng, &symbols, sigma2);
        let mut regressors = Vec::with_capacity(sections);
        let mut observations = Vec::with_capacity(sections);
        for i in 0..sections {
            regressors.push(regressor_matrix(&symbols, i, n));
            // observation message: the received symbol in the first
            // component, noise covariance sigma2 * I (test_model.py conv.)
            let mut y = vec![c64::ZERO; n];
            y[0] = received[i];
            observations.push(GaussMessage::observation(&y, sigma2));
        }
        RlsProblem {
            n,
            sections,
            sigma2,
            h_true: chan.taps,
            symbols,
            regressors,
            observations,
            // prior at the top of the input-scaling contract
            prior: GaussMessage::isotropic(n, 1.0),
        }
    }

    pub fn rel_mse(&self, h_hat: &[c64]) -> f64 {
        let num: f64 = self
            .h_true
            .iter()
            .zip(h_hat)
            .map(|(a, b)| (*a - *b).abs2())
            .sum();
        let den: f64 = self.h_true.iter().map(|a| a.abs2()).sum();
        num / den
    }

    /// Build the Fig. 6 factor graph.
    pub fn build_graph(&self) -> (FactorGraph, Schedule) {
        let mut g = FactorGraph::new();
        g.rls_chain(self.n, &self.regressors);
        let s = Schedule::forward_sweep(&g);
        (g, s)
    }

    /// f64 golden chain (the semantic reference).
    pub fn golden(&self) -> Result<RlsOutcome> {
        let mut msg = self.prior.clone();
        for (a, y) in self.regressors.iter().zip(&self.observations) {
            msg = nodes::compound_observation(&msg, y, a, true)?;
        }
        let h_hat = msg.mean.clone();
        Ok(RlsOutcome {
            rel_mse: self.rel_mse(&h_hat),
            h_hat,
            cycles: 0,
            cycles_per_section: 0,
            compile_stats: None,
        })
    }

    /// Compile the graph (Listing 1 → Listing 2).
    pub fn compile_program(&self) -> Result<CompiledProgram> {
        let (g, s) = self.build_graph();
        compile(&g, &s, &CompileOptions::default()).context("compiling RLS factor graph")
    }

    /// Run on the cycle-accurate FGP simulator with host streaming.
    pub fn run_on_fgp(&self) -> Result<RlsOutcome> {
        self.run_on_fgp_with(FgpConfig::default())
    }

    pub fn run_on_fgp_with(&self, config: FgpConfig) -> Result<RlsOutcome> {
        assert_eq!(config.n, self.n, "device size must match problem size");
        let compiled = self.compile_program()?;
        let mut fgp = Fgp::new(config);
        fgp.pm.load(&compiled.program.to_image())?;

        let prior_slot = compiled.memmap.preloads[0].1;
        fgp.msgmem.write_message(prior_slot, &self.prior);
        let (_, obs_slot, _) = compiled.memmap.streams[0];
        let (_, st_slot, _) = compiled.memmap.state_streams[0];

        let obs = self.observations.clone();
        let regs = self.regressors.clone();
        let mut feed =
            move |section: usize, mem: &mut MessageMemory, st: &mut StateMemory| -> bool {
                if section >= obs.len() {
                    return false;
                }
                mem.write_message(obs_slot, &obs[section]);
                st.write_matrix(st_slot, &regs[section]);
                true
            };
        let stats = fgp.run_program(1, &mut feed)?;

        let out_slot = compiled.memmap.outputs[0].1;
        let h_hat = fgp.msgmem.read_message(out_slot).mean;
        Ok(RlsOutcome {
            rel_mse: self.rel_mse(&h_hat),
            h_hat,
            cycles: stats.cycles,
            cycles_per_section: stats.cycles / stats.sections.max(1),
            compile_stats: Some(compiled.stats),
        })
    }

    /// Run through the PJRT artifact (`rls_chain.hlo.txt`). The artifact
    /// bakes its section count; the problem must match.
    pub fn run_on_xla(&self, rt: &crate::runtime::RuntimeClient) -> Result<RlsOutcome> {
        let out = rt.rls_chain(
            &self.prior,
            &self.regressors,
            &self.observations,
            self.sigma2 as f32,
        )?;
        let h_hat = out.last().context("empty chain")?.mean.clone();
        Ok(RlsOutcome {
            rel_mse: self.rel_mse(&h_hat),
            h_hat,
            cycles: 0,
            cycles_per_section: 0,
            compile_stats: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_rls_converges() {
        let p = RlsProblem::synthetic(4, 48, 0.01, 7);
        let out = p.golden().unwrap();
        assert!(out.rel_mse < 0.02, "rel MSE {}", out.rel_mse);
    }

    #[test]
    fn golden_improves_with_sections() {
        let short = RlsProblem::synthetic(4, 6, 0.02, 9).golden().unwrap();
        let long = RlsProblem::synthetic(4, 48, 0.02, 9).golden().unwrap();
        assert!(long.rel_mse < short.rel_mse);
    }

    #[test]
    fn fgp_tracks_golden() {
        let p = RlsProblem::synthetic(4, 24, 0.02, 11);
        let golden = p.golden().unwrap();
        let fgp = p.run_on_fgp().unwrap();
        // 16-bit fixed point hits an accuracy floor once the posterior
        // covariance approaches the LSB (E9 sweeps this); the estimate
        // must still be in the converged regime.
        assert!(fgp.rel_mse < 0.25, "FGP rel MSE {}", fgp.rel_mse);
        assert!(
            fgp.rel_mse < golden.rel_mse + 0.2,
            "fgp {} vs golden {}",
            fgp.rel_mse,
            golden.rel_mse
        );
        // cycle accounting: S sections at the CN rate
        let cfg = FgpConfig::default();
        assert_eq!(fgp.cycles, cfg.timing.compound_node_cycles(4) * 24);
    }

    #[test]
    fn compile_stats_show_fig7_win() {
        let p = RlsProblem::synthetic(4, 16, 0.02, 13);
        let c = p.compile_program().unwrap();
        assert!(c.stats.slots_optimized < c.stats.slots_unoptimized);
        assert_eq!(c.stats.slots_optimized, 2);
        assert!(c.stats.looped.is_some());
    }
}
