//! The paper's §IV example: RLS/LMMSE channel estimation on the FGP.
//!
//! Fig. 6's factor graph — one compound-observation section per received
//! training symbol — built as a [`Workload`] and runnable on any engine
//! through [`crate::engine::Session`]: the f64 golden chain, the
//! cycle-accurate simulator (host streaming observations and regressors
//! exactly as the "HW-SW interaction" section describes), or the PJRT
//! `rls_chain` artifact.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::compiler::{compile, CompileOptions, CompiledProgram};
use crate::engine::{
    bind_streamed, preload_id, Execution, StreamRun, StreamSample, StreamingWorkload, Workload,
};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::gmp::{FactorGraph, MsgId, Schedule};
use crate::testutil::Rng;

use super::channel::{regressor_matrix, Constellation, MultipathChannel};

/// A synthetic channel-estimation problem instance.
#[derive(Clone, Debug)]
pub struct RlsProblem {
    pub n: usize,
    pub sections: usize,
    pub sigma2: f64,
    /// True channel taps (ground truth for MSE).
    pub h_true: Vec<c64>,
    /// Training symbols.
    pub symbols: Vec<c64>,
    /// Per-section regressor matrices (the streamed state A_i).
    pub regressors: Vec<CMatrix>,
    /// Per-section observation messages (the streamed msg_Y).
    pub observations: Vec<GaussMessage>,
    /// Prior on the channel state.
    pub prior: GaussMessage,
}

/// Result of running the problem on some engine.
#[derive(Clone, Debug)]
pub struct RlsOutcome {
    /// Final channel estimate.
    pub h_hat: Vec<c64>,
    /// Relative MSE ||h_hat - h||^2 / ||h||^2.
    pub rel_mse: f64,
}

impl RlsProblem {
    /// Generate a random instance (QPSK training, exponential PDP).
    pub fn synthetic(n: usize, sections: usize, sigma2: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let chan = MultipathChannel::random(&mut rng, n, 0.25);
        let symbols: Vec<c64> =
            (0..sections).map(|_| Constellation::Qpsk.draw(&mut rng)).collect();
        let received = chan.transmit(&mut rng, &symbols, sigma2);
        let mut regressors = Vec::with_capacity(sections);
        let mut observations = Vec::with_capacity(sections);
        for i in 0..sections {
            regressors.push(regressor_matrix(&symbols, i, n));
            // observation message: the received symbol in the first
            // component, noise covariance sigma2 * I (test_model.py conv.)
            let mut y = vec![c64::ZERO; n];
            y[0] = received[i];
            observations.push(GaussMessage::observation(&y, sigma2));
        }
        RlsProblem {
            n,
            sections,
            sigma2,
            h_true: chan.taps,
            symbols,
            regressors,
            observations,
            // prior at the top of the input-scaling contract
            prior: GaussMessage::isotropic(n, 1.0),
        }
    }

    pub fn rel_mse(&self, h_hat: &[c64]) -> f64 {
        let num: f64 = self
            .h_true
            .iter()
            .zip(h_hat)
            .map(|(a, b)| (*a - *b).abs2())
            .sum();
        let den: f64 = self.h_true.iter().map(|a| a.abs2()).sum();
        num / den
    }

    /// Build the Fig. 6 factor graph.
    pub fn build_graph(&self) -> (FactorGraph, Schedule) {
        let mut g = FactorGraph::new();
        g.rls_chain(self.n, &self.regressors);
        let s = Schedule::forward_sweep(&g);
        (g, s)
    }

    /// Compile the graph (Listing 1 → Listing 2) — compiler-report
    /// helper; execution goes through [`crate::engine::Session::run`].
    pub fn compile_program(&self) -> Result<CompiledProgram> {
        let (g, s) = self.build_graph();
        compile(&g, &s, &CompileOptions::default()).context("compiling RLS factor graph")
    }
}

impl Workload for RlsProblem {
    type Outcome = RlsOutcome;

    fn name(&self) -> &str {
        "rls_channel_estimation"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn model(&self) -> Result<(FactorGraph, Schedule)> {
        Ok(self.build_graph())
    }

    fn inputs(
        &self,
        graph: &FactorGraph,
        schedule: &Schedule,
    ) -> Result<HashMap<MsgId, GaussMessage>> {
        let mut map = HashMap::new();
        map.insert(preload_id(graph, schedule, "msg_prior")?, self.prior.clone());
        bind_streamed(graph, schedule, &self.observations, &mut map)?;
        Ok(map)
    }

    fn outcome(&self, exec: &Execution) -> Result<RlsOutcome> {
        let h_hat = exec.output()?.mean.clone();
        Ok(RlsOutcome { rel_mse: self.rel_mse(&h_hat), h_hat })
    }

    fn quality(&self, outcome: &RlsOutcome) -> f64 {
        outcome.rel_mse
    }

    /// 16-bit fixed point hits an accuracy floor once the posterior
    /// covariance approaches the LSB (E9 sweeps this); the estimate must
    /// still be in the converged regime.
    fn tolerance(&self) -> f64 {
        0.2
    }
}

/// The steady-state serving form: one compound-observation section per
/// received training symbol, the running posterior threading through as
/// the recursive state — exactly the §VI "program loaded once, samples
/// stream through" shape Table II benchmarks.
impl StreamingWorkload for RlsProblem {
    type StreamOutcome = RlsOutcome;

    fn stream_name(&self) -> &str {
        "rls_channel_stream"
    }

    fn state_dim(&self) -> usize {
        self.n
    }

    fn stream_model(&self, chunk: usize) -> Result<(FactorGraph, Schedule)> {
        let mut g = FactorGraph::new();
        // per-sample regressors are streamed states: placeholder values,
        // rebound by the driver before every dispatch
        g.rls_chain(self.n, &vec![CMatrix::identity(self.n); chunk]);
        let s = Schedule::forward_sweep(&g);
        Ok((g, s))
    }

    fn initial_state(&self) -> GaussMessage {
        self.prior.clone()
    }

    fn next_sample(&self, k: usize, _state: &GaussMessage) -> Result<Option<StreamSample>> {
        Ok((k < self.sections).then(|| StreamSample {
            messages: vec![self.observations[k].clone()],
            states: vec![self.regressors[k].clone()],
        }))
    }

    fn stream_outcome(&self, run: &StreamRun) -> Result<RlsOutcome> {
        let h_hat = run.final_state.mean.clone();
        Ok(RlsOutcome { rel_mse: self.rel_mse(&h_hat), h_hat })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Session;
    use crate::fgp::FgpConfig;

    #[test]
    fn golden_rls_converges() {
        let p = RlsProblem::synthetic(4, 48, 0.01, 7);
        let out = Session::golden().run(&p).unwrap();
        assert!(out.quality < 0.02, "rel MSE {}", out.quality);
    }

    #[test]
    fn golden_improves_with_sections() {
        let mut golden = Session::golden();
        let short = golden.run(&RlsProblem::synthetic(4, 6, 0.02, 9)).unwrap();
        let long = golden.run(&RlsProblem::synthetic(4, 48, 0.02, 9)).unwrap();
        assert!(long.quality < short.quality);
    }

    #[test]
    fn fgp_tracks_golden() {
        let p = RlsProblem::synthetic(4, 24, 0.02, 11);
        let golden = Session::golden().run(&p).unwrap();
        let fgp = Session::fgp_sim(FgpConfig::default()).run(&p).unwrap();
        assert!(fgp.quality < 0.25, "FGP rel MSE {}", fgp.quality);
        assert!(
            fgp.quality < golden.quality + p.tolerance(),
            "fgp {} vs golden {}",
            fgp.quality,
            golden.quality
        );
        // cycle accounting: S sections at the CN rate
        let cfg = FgpConfig::default();
        assert_eq!(fgp.cycles, cfg.timing.compound_node_cycles(4) * 24);
        assert_eq!(fgp.sections, 24);
    }

    #[test]
    fn size_mismatch_is_reported_not_panicked() {
        let p = RlsProblem::synthetic(6, 4, 0.02, 3);
        let err = Session::fgp_sim(FgpConfig::default()).run(&p).unwrap_err();
        assert!(format!("{err:#}").contains("n=6"), "{err:#}");
    }

    #[test]
    fn stream_matches_batch_on_golden() {
        let p = RlsProblem::synthetic(4, 20, 0.01, 5);
        let batch = Session::golden().run(&p).unwrap();
        let stream = Session::golden().run_stream(&p).unwrap();
        assert_eq!(stream.samples, 20);
        // same node rules in the same order: identical estimate
        assert!((stream.outcome.rel_mse - batch.outcome.rel_mse).abs() < 1e-12);
    }

    #[test]
    fn compile_stats_show_fig7_win() {
        let p = RlsProblem::synthetic(4, 16, 0.02, 13);
        let c = p.compile_program().unwrap();
        assert!(c.stats.slots_optimized < c.stats.slots_unoptimized);
        assert_eq!(c.stats.slots_optimized, 2);
        assert!(c.stats.looped.is_some());
    }
}
