//! Loopy pose-chain estimation (SLAM-style loop closure) as GBP.
//!
//! A vehicle traverses a closed loop of poses; odometry measures each
//! displacement in noise, and a final loop-closure factor ties the last
//! pose back to the first — which creates exactly the cycle the
//! scheduled compiler cannot serve. Dead reckoning accumulates drift
//! linearly along the chain; GBP over the cyclic model redistributes
//! the loop-closure correction over every pose (Ortiz et al. 2021 use
//! the same workload to motivate distributed GBP).
//!
//! The 2-D position rides as a **complex scalar** in component 0 of the
//! n-dim state (x + iy — the natural encoding for this crate's complex
//! datapath); odometry displacements ride as the pairwise factors'
//! noise means.

use anyhow::Result;

use crate::gbp::{solve, GbpModel, GbpOptions, GbpReport, RoundExecutor};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::testutil::Rng;

/// A closed loop of poses with noisy odometry and one loop closure.
#[derive(Clone, Debug)]
pub struct PoseChain {
    /// Number of poses around the loop.
    pub poses: usize,
    /// State dimension (4 = the device size).
    pub n: usize,
    /// True positions (complex: x + iy).
    pub truth: Vec<c64>,
    /// Measured displacements: entry k is pose k → pose k+1; the last
    /// entry is the loop closure (pose T-1 → pose 0).
    pub measured: Vec<c64>,
    /// Odometry noise variance (per complex component).
    pub odo_var: f64,
    /// Anchor prior variance on pose 0.
    pub anchor_var: f64,
    /// Weak prior variance on every other pose.
    pub prior_var: f64,
}

/// Estimation outcome.
#[derive(Clone, Debug)]
pub struct PoseOutcome {
    /// The underlying GBP solve report (iterations, stop reason).
    pub report: GbpReport,
    /// Estimated positions.
    pub estimate: Vec<c64>,
    /// RMSE of the GBP estimate against the true loop.
    pub rmse: f64,
    /// RMSE of dead reckoning (integrating raw odometry from the
    /// anchor, no loop closure) — the number to beat.
    pub dead_reckoning_rmse: f64,
}

impl PoseChain {
    /// Poses on a circle of radius 0.4, odometry = true displacement +
    /// complex Gaussian noise.
    pub fn synthetic(poses: usize, odo_var: f64, seed: u64) -> Self {
        assert!(poses >= 3, "a loop needs at least three poses");
        let mut rng = Rng::new(seed);
        let truth: Vec<c64> = (0..poses)
            .map(|k| {
                let th = 2.0 * std::f64::consts::PI * k as f64 / poses as f64;
                c64::new(0.4 * th.cos(), 0.4 * th.sin())
            })
            .collect();
        let mut measured = Vec::with_capacity(poses);
        for k in 0..poses {
            let d = truth[(k + 1) % poses] - truth[k];
            let noise = c64::new(rng.normal(), rng.normal()) * (odo_var / 2.0).sqrt();
            measured.push(d + noise);
        }
        PoseChain {
            poses,
            n: crate::paper::N,
            truth,
            measured,
            odo_var,
            anchor_var: 1e-4,
            prior_var: 1.0,
        }
    }

    /// Build the cyclic model: odometry factors around the ring (the
    /// last one is the loop closure).
    pub fn model(&self) -> Result<GbpModel> {
        let n = self.n;
        let mut m = GbpModel::new(n);
        let mut ids = Vec::with_capacity(self.poses);
        for k in 0..self.poses {
            let prior = if k == 0 {
                // anchor: pose 0 pinned at its true position
                let mut mean = vec![c64::ZERO; n];
                mean[0] = self.truth[0];
                GaussMessage::new(mean, CMatrix::scaled_identity(n, self.anchor_var))
            } else {
                GaussMessage::isotropic(n, self.prior_var)
            };
            ids.push(m.add_variable(Some(prior), format!("pose{k}"))?);
        }
        for k in 0..self.poses {
            let mut b = vec![c64::ZERO; n];
            b[0] = self.measured[k];
            m.add_pairwise(
                ids[k],
                ids[(k + 1) % self.poses],
                CMatrix::identity(n),
                GaussMessage::new(b, CMatrix::scaled_identity(n, self.odo_var)),
            )?;
        }
        Ok(m)
    }

    /// Dead reckoning: integrate raw odometry from the anchor.
    pub fn dead_reckoning(&self) -> Vec<c64> {
        let mut out = Vec::with_capacity(self.poses);
        let mut p = self.truth[0];
        out.push(p);
        for k in 0..self.poses - 1 {
            p = p + self.measured[k];
            out.push(p);
        }
        out
    }

    fn rmse_of(&self, est: &[c64]) -> f64 {
        let se: f64 = est
            .iter()
            .zip(&self.truth)
            .map(|(a, b)| (*a - *b).abs2())
            .sum();
        (se / self.poses as f64).sqrt()
    }

    /// Solve with loopy GBP through any executor.
    pub fn run(&self, exec: &mut dyn RoundExecutor, opts: GbpOptions) -> Result<PoseOutcome> {
        let report = solve(self.model()?, opts, exec)?;
        let estimate: Vec<c64> = report.beliefs.iter().map(|b| b.mean[0]).collect();
        let rmse = self.rmse_of(&estimate);
        let dead_reckoning_rmse = self.rmse_of(&self.dead_reckoning());
        Ok(PoseOutcome { report, estimate, rmse, dead_reckoning_rmse })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Session;
    use crate::gbp::ConvergenceCriteria;

    /// A weakly-anchored ring contracts at ~0.85–0.9 per synchronous
    /// round, so give it headroom beyond the default 100 iterations.
    fn opts() -> GbpOptions {
        GbpOptions {
            criteria: ConvergenceCriteria { tol: 1e-7, max_iters: 400, divergence: 1e3 },
            ..Default::default()
        }
    }

    #[test]
    fn pose_loop_is_cyclic_and_valid() {
        let p = PoseChain::synthetic(8, 0.004, 3);
        let m = p.model().unwrap();
        assert_eq!(m.num_vars(), 8);
        assert_eq!(m.num_factors(), 8);
        assert!(m.has_cycle(), "the loop closure closes a cycle");
        m.validate().unwrap();
    }

    #[test]
    fn loop_closure_beats_dead_reckoning() {
        // averaged over seeds: closing the loop redistributes drift
        let mut wins = 0;
        for seed in 0..5 {
            let p = PoseChain::synthetic(8, 0.004, 20 + seed);
            let out = p.run(&mut Session::golden(), opts()).unwrap();
            assert!(out.report.converged(), "seed {seed}: {:?}", out.report.stop);
            if out.rmse <= out.dead_reckoning_rmse + 1e-9 {
                wins += 1;
            }
        }
        assert!(wins >= 4, "loop closure won only {wins}/5 seeds");
    }

    #[test]
    fn pose_means_match_dense_solve() {
        let p = PoseChain::synthetic(6, 0.004, 5);
        let model = p.model().unwrap();
        let dense = model.dense_marginals().unwrap();
        let out = p.run(&mut Session::golden(), opts()).unwrap();
        assert!(out.report.converged(), "{:?}", out.report.stop);
        for (got, want) in out.report.beliefs.iter().zip(&dense) {
            assert!((got.mean[0] - want.mean[0]).abs() < 1e-5);
        }
    }
}
