//! Nonlinear range-factor pose chain through loopy GBP.
//!
//! The [`crate::apps::posechain`] workload with a nonlinear twist: a
//! vehicle traverses a closed loop of poses with noisy linear odometry
//! (the cycle-closing SLAM structure), and additionally measures the
//! scalar **range** it covered on each leg — a nonlinear pairwise
//! factor `z = |p_to − p_from| + v` that no linear-Gaussian model can
//! express. The GBP solver relinearizes every range factor at the
//! endpoints' current beliefs each round ([`crate::nonlinear`]; Ortiz
//! et al. 2021 use exactly this trick for nonlinear factors inside
//! loopy GBP), while every inner update still lowers onto the paper's
//! device through the engine surface.
//!
//! Positions ride as **real** coordinates in components 0 and 1 of the
//! 4-dim state (nonlinear `h` acts on the real state — unlike the
//! linear pose chain, which packs x + iy into one complex component).

use anyhow::Result;
use std::sync::Arc;

use crate::gbp::{GbpModel, GbpOptions, GbpReport, GbpSolver, RoundExecutor};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::nonlinear::{Linearizer, PairwiseNonlinear};
use crate::testutil::Rng;

/// A pose loop with linear odometry and nonlinear per-leg ranges.
#[derive(Clone, Debug)]
pub struct RangeChain {
    /// Number of poses in the loop.
    pub poses: usize,
    /// State dimension (4 = the device size).
    pub n: usize,
    /// True positions.
    pub truth: Vec<(f64, f64)>,
    /// Measured displacements: entry k is pose k → pose k+1 (the last
    /// entry closes the loop back to pose 0).
    pub odo: Vec<(f64, f64)>,
    /// Measured leg ranges `|p_{k+1} − p_k| + noise`, same indexing.
    pub ranges: Vec<f64>,
    /// Odometry noise variance.
    pub odo_var: f64,
    /// Range measurement noise variance.
    pub range_var: f64,
    /// Anchor prior variance on pose 0.
    pub anchor_var: f64,
    /// Weak prior variance on every other pose.
    pub prior_var: f64,
}

/// Estimation outcome.
#[derive(Clone, Debug)]
pub struct RangeOutcome {
    /// The underlying GBP solve report (iterations, stop reason).
    pub report: GbpReport,
    /// Estimated positions.
    pub estimate: Vec<(f64, f64)>,
    /// RMSE of the GBP estimate against the true loop.
    pub rmse: f64,
    /// RMSE of dead reckoning (raw odometry from the anchor).
    pub dead_reckoning_rmse: f64,
}

impl RangeChain {
    /// Poses on a circle of radius 0.35 centered on (0.5, 0.5);
    /// odometry = true displacement + noise, range = true leg length +
    /// noise.
    pub fn synthetic(poses: usize, odo_var: f64, range_var: f64, seed: u64) -> Self {
        assert!(poses >= 3, "a loop needs at least three poses");
        let mut rng = Rng::new(seed);
        let truth: Vec<(f64, f64)> = (0..poses)
            .map(|k| {
                let th = 2.0 * std::f64::consts::PI * k as f64 / poses as f64;
                (0.5 + 0.35 * th.cos(), 0.5 + 0.35 * th.sin())
            })
            .collect();
        let mut odo = Vec::with_capacity(poses);
        let mut ranges = Vec::with_capacity(poses);
        for k in 0..poses {
            let to = truth[(k + 1) % poses];
            let from = truth[k];
            let d = (to.0 - from.0, to.1 - from.1);
            odo.push((
                d.0 + rng.normal() * (odo_var / 2.0).sqrt(),
                d.1 + rng.normal() * (odo_var / 2.0).sqrt(),
            ));
            let leg = (d.0 * d.0 + d.1 * d.1).sqrt();
            ranges.push(leg + rng.normal() * range_var.sqrt());
        }
        RangeChain {
            poses,
            n: crate::paper::N,
            truth,
            odo,
            ranges,
            odo_var,
            range_var,
            anchor_var: 1e-4,
            prior_var: 1.0,
        }
    }

    /// Build the cyclic model: linear odometry factors around the ring
    /// plus one nonlinear range factor per leg. The range noise is
    /// floored for the Q5.10 datapath.
    pub fn model(&self) -> Result<GbpModel> {
        let n = self.n;
        let mut m = GbpModel::new(n);
        let mut ids = Vec::with_capacity(self.poses);
        for k in 0..self.poses {
            let prior = if k == 0 {
                // anchor: pose 0 pinned at its true position
                let mut mean = vec![c64::ZERO; n];
                mean[0] = c64::new(self.truth[0].0, 0.0);
                mean[1] = c64::new(self.truth[0].1, 0.0);
                GaussMessage::new(mean, CMatrix::scaled_identity(n, self.anchor_var))
            } else {
                // weak prior centered on the field keeps early
                // linearization points away from zero-length legs
                let mut mean = vec![c64::ZERO; n];
                mean[0] = c64::new(0.5, 0.0);
                mean[1] = c64::new(0.5, 0.0);
                GaussMessage::new(mean, CMatrix::scaled_identity(n, self.prior_var))
            };
            ids.push(m.add_variable(Some(prior), format!("pose{k}"))?);
        }
        for k in 0..self.poses {
            let (from, to) = (ids[k], ids[(k + 1) % self.poses]);
            let mut b = vec![c64::ZERO; n];
            b[0] = c64::new(self.odo[k].0, 0.0);
            b[1] = c64::new(self.odo[k].1, 0.0);
            m.add_pairwise(
                from,
                to,
                CMatrix::identity(n),
                GaussMessage::new(b, CMatrix::scaled_identity(n, self.odo_var)),
            )?;
            m.add_nonlinear_pairwise(
                from,
                to,
                PairwiseNonlinear::new(
                    n,
                    1,
                    Arc::new(|a: &[f64], b: &[f64]| {
                        vec![((b[0] - a[0]).powi(2) + (b[1] - a[1]).powi(2))
                            .sqrt()
                            .max(1e-6)]
                    }),
                    vec![self.ranges[k]],
                    self.range_var.max(1e-3),
                )?,
            )?;
        }
        Ok(m)
    }

    /// Dead reckoning: integrate raw odometry from the anchor.
    pub fn dead_reckoning(&self) -> Vec<(f64, f64)> {
        let mut out = Vec::with_capacity(self.poses);
        let mut p = self.truth[0];
        out.push(p);
        for k in 0..self.poses - 1 {
            p = (p.0 + self.odo[k].0, p.1 + self.odo[k].1);
            out.push(p);
        }
        out
    }

    fn rmse_of(&self, est: &[(f64, f64)]) -> f64 {
        let se: f64 = est
            .iter()
            .zip(&self.truth)
            .map(|(a, b)| (a.0 - b.0).powi(2) + (a.1 - b.1).powi(2))
            .sum();
        (se / self.poses as f64).sqrt()
    }

    /// Solve with loopy GBP (relinearizing ranges each round) through
    /// any executor.
    pub fn run(
        &self,
        exec: &mut dyn RoundExecutor,
        opts: GbpOptions,
        linearizer: Arc<dyn Linearizer>,
    ) -> Result<RangeOutcome> {
        let report = GbpSolver::with_linearizer(self.model()?, opts, linearizer)?.run(exec)?;
        let estimate: Vec<(f64, f64)> =
            report.beliefs.iter().map(|b| (b.mean[0].re, b.mean[1].re)).collect();
        let rmse = self.rmse_of(&estimate);
        let dead_reckoning_rmse = self.rmse_of(&self.dead_reckoning());
        Ok(RangeOutcome { report, estimate, rmse, dead_reckoning_rmse })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Session;
    use crate::gbp::{ConvergenceCriteria, IterationPolicy};
    use crate::nonlinear::FirstOrder;

    /// Damped synchronous rounds: relinearization plus a cycle wants a
    /// little inertia.
    fn opts() -> GbpOptions {
        GbpOptions {
            policy: IterationPolicy::Synchronous { eta_damping: 0.3 },
            criteria: ConvergenceCriteria { tol: 1e-7, max_iters: 400, divergence: 1e3 },
            ..Default::default()
        }
    }

    #[test]
    fn model_is_cyclic_and_nonlinear() {
        let p = RangeChain::synthetic(6, 0.004, 1e-3, 3);
        let m = p.model().unwrap();
        assert_eq!(m.num_vars(), 6);
        assert_eq!(m.num_factors(), 12, "odometry + range per leg");
        assert!(m.has_cycle());
        assert!(m.has_nonlinear());
        m.validate().unwrap();
        // the exact dense solve must refuse a nonlinear model
        let err = m.dense_marginals().unwrap_err();
        assert!(format!("{err:#}").contains("nonlinear"), "{err:#}");
    }

    #[test]
    fn gbp_with_ranges_converges_and_beats_dead_reckoning_rmse_bound() {
        let p = RangeChain::synthetic(6, 0.004, 1e-3, 21);
        let out = p.run(&mut Session::golden(), opts(), Arc::new(FirstOrder)).unwrap();
        assert!(out.report.converged(), "stop {:?}", out.report.stop);
        assert!(out.rmse < 0.15, "rmse {}", out.rmse);
        assert!(
            out.rmse <= out.dead_reckoning_rmse + 0.02,
            "gbp {} vs dead reckoning {}",
            out.rmse,
            out.dead_reckoning_rmse
        );
    }

    #[test]
    fn converged_means_match_linearized_dense_solve() {
        let p = RangeChain::synthetic(5, 0.004, 1e-3, 8);
        let model = p.model().unwrap();
        let out = p.run(&mut Session::golden(), opts(), Arc::new(FirstOrder)).unwrap();
        assert!(out.report.converged(), "stop {:?}", out.report.stop);
        // reference: the exact dense solve of the model linearized at
        // the converged beliefs (GBP means are exact per linear model)
        let dense = model
            .dense_marginals_linearized(&out.report.beliefs, &FirstOrder)
            .unwrap();
        for (got, want) in out.report.beliefs.iter().zip(&dense) {
            let d = ((got.mean[0].re - want.mean[0].re).powi(2)
                + (got.mean[1].re - want.mean[1].re).powi(2))
            .sqrt();
            assert!(d < 5e-3, "mean err {d}");
        }
    }

    #[test]
    fn residual_policy_is_rejected_for_nonlinear_models() {
        let p = RangeChain::synthetic(4, 0.004, 1e-3, 2);
        let bad = GbpOptions {
            policy: IterationPolicy::Residual { batch: 4, eta_damping: 0.0 },
            ..Default::default()
        };
        let err = GbpSolver::new(p.model().unwrap(), bad).unwrap_err();
        assert!(format!("{err:#}").contains("synchronous"), "{err:#}");
    }
}
