//! Programs and binary memory images (paper §III–IV).
//!
//! A [`Program`] is an instruction sequence with a `prg` directory so the
//! PM can "host multiple programs"; a [`MemoryImage`] is the binary form
//! "suitable for loading into the processor" — a small header plus the
//! 64-bit instruction words, little-endian.

use super::{Instr, IsaError, Opcode};

/// Magic bytes at the start of a memory image.
const MAGIC: &[u8; 4] = b"FGP1";

/// An assembled FGP program store (possibly several programs).
#[derive(Clone, Debug, PartialEq)]
pub struct Program {
    /// All instructions, in PM order (including `prg` markers).
    pub instrs: Vec<Instr>,
}

impl Program {
    /// A program from an instruction list.
    pub fn new(instrs: Vec<Instr>) -> Self {
        Program { instrs }
    }

    /// PM addresses of each program id (`prg` markers).
    pub fn directory(&self) -> Vec<(u8, usize)> {
        self.instrs
            .iter()
            .enumerate()
            .filter_map(|(addr, i)| match i {
                Instr::Prg { id } => Some((*id, addr)),
                _ => None,
            })
            .collect()
    }

    /// Start address (instruction after the `prg` marker) of program `id`.
    pub fn start_of(&self, id: u8) -> Option<usize> {
        self.directory()
            .into_iter()
            .find(|(pid, _)| *pid == id)
            .map(|(_, addr)| addr + 1)
    }

    /// Number of datapath instructions (used in cycle accounting tests).
    pub fn datapath_len(&self) -> usize {
        self.instrs.iter().filter(|i| i.is_datapath()).count()
    }

    /// Serialize to a loadable binary memory image.
    pub fn to_image(&self) -> MemoryImage {
        let mut bytes = Vec::with_capacity(8 + self.instrs.len() * 8);
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(self.instrs.len() as u32).to_le_bytes());
        for i in &self.instrs {
            bytes.extend_from_slice(&i.encode().to_le_bytes());
        }
        MemoryImage { bytes }
    }

    /// Parse a binary memory image.
    pub fn from_image(image: &MemoryImage) -> Result<Program, IsaError> {
        let b = &image.bytes;
        let bad = |msg: &str| IsaError::Parse { line: 0, msg: msg.into() };
        if b.len() < 8 || &b[0..4] != MAGIC {
            return Err(bad("bad magic"));
        }
        let n = u32::from_le_bytes(b[4..8].try_into().unwrap()) as usize;
        if b.len() != 8 + n * 8 {
            return Err(bad("truncated image"));
        }
        let mut instrs = Vec::with_capacity(n);
        for k in 0..n {
            let w = u64::from_le_bytes(b[8 + k * 8..16 + k * 8].try_into().unwrap());
            instrs.push(Instr::decode(w)?);
        }
        Ok(Program { instrs })
    }

    /// Sanity checks a real loader performs: loop bodies must fit before
    /// the loop instruction, and every program must be non-empty.
    pub fn validate(&self) -> Result<(), IsaError> {
        let err = |msg: String| IsaError::Parse { line: 0, msg };
        for (addr, i) in self.instrs.iter().enumerate() {
            if let Instr::Loop { body, count } = i {
                if *body as usize > addr {
                    return Err(err(format!(
                        "loop at PM[{addr}] reaches back {body} instructions past PM[0]"
                    )));
                }
                if *body == 0 || *count == 0 {
                    return Err(err(format!("degenerate loop at PM[{addr}]")));
                }
            }
        }
        Ok(())
    }

    /// Render as assembler text.
    pub fn listing(&self) -> String {
        super::format_listing(&self.instrs)
    }

    /// The expanded datapath instruction stream (loops unrolled) —
    /// what the FSM actually issues. Used by tests to compare compressed
    /// vs uncompressed programs.
    pub fn unrolled(&self) -> Vec<Instr> {
        let mut out = Vec::new();
        let mut trace: Vec<Instr> = Vec::new(); // non-control instrs seen so far
        for i in &self.instrs {
            match i {
                Instr::Loop { count, body } => {
                    let start = trace.len() - (*body as usize).min(trace.len());
                    let body_instrs: Vec<Instr> = trace[start..].to_vec();
                    // loop count is the TOTAL number of iterations; one
                    // pass already executed as straight-line code.
                    for _ in 1..*count {
                        out.extend(body_instrs.iter().cloned());
                        trace.extend(body_instrs.iter().cloned());
                    }
                }
                Instr::Prg { .. } | Instr::Halt => {}
                other => {
                    out.push(other.clone());
                    trace.push(other.clone());
                }
            }
        }
        out
    }
}

/// Binary memory image (header + little-endian instruction words).
#[derive(Clone, Debug, PartialEq)]
pub struct MemoryImage {
    /// Raw image bytes (header + little-endian words).
    pub bytes: Vec<u8>,
}

impl MemoryImage {
    /// Image size in bytes.
    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    /// True when the image has no bytes.
    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    /// Size of the image in bits (for the 64-kbit PM budget checks).
    pub fn bits(&self) -> usize {
        self.bytes.len() * 8
    }
}

/// Convenience: does this instruction start a program?
pub fn is_prg(i: &Instr) -> bool {
    matches!(i, Instr::Prg { .. })
}

/// Opcode histogram of a program (reporting/bench helper).
pub fn opcode_histogram(p: &Program) -> [usize; 7] {
    let mut h = [0usize; 7];
    for i in &p.instrs {
        let idx = match i {
            Instr::Halt => Opcode::Halt as usize,
            Instr::Mma { .. } => Opcode::Mma as usize,
            Instr::Mms { .. } => Opcode::Mms as usize,
            Instr::Fad { .. } => Opcode::Fad as usize,
            Instr::Smm { .. } => Opcode::Smm as usize,
            Instr::Loop { .. } => Opcode::Loop as usize,
            Instr::Prg { .. } => Opcode::Prg as usize,
        };
        h[idx] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{OperandSrc, ACC};

    fn sample_program() -> Program {
        Program::new(vec![
            Instr::Prg { id: 1 },
            Instr::Mma {
                a: OperandSrc::Msg(1),
                a_herm: false,
                b: OperandSrc::State(0),
                b_herm: true,
                neg: false,
                vec: false,
            },
            Instr::Mms {
                a: OperandSrc::State(0),
                a_herm: false,
                b: OperandSrc::Msg(ACC),
                b_herm: false,
                c: 2,
                neg: true,
                vec: false,
            },
            Instr::Fad { g: ACC, b: 3, b_herm: true, c: 4, d: 1 },
            Instr::Smm { dst: 4 },
            Instr::Loop { count: 3, body: 4 },
            Instr::Halt,
        ])
    }

    #[test]
    fn image_roundtrip() {
        let p = sample_program();
        let img = p.to_image();
        assert_eq!(Program::from_image(&img).unwrap(), p);
    }

    #[test]
    fn image_rejects_corruption() {
        let p = sample_program();
        let mut img = p.to_image();
        img.bytes[0] = b'X';
        assert!(Program::from_image(&img).is_err());
        let mut img2 = p.to_image();
        img2.bytes.truncate(img2.bytes.len() - 3);
        assert!(Program::from_image(&img2).is_err());
    }

    #[test]
    fn directory_finds_programs() {
        let mut instrs = sample_program().instrs;
        instrs.push(Instr::Prg { id: 2 });
        instrs.push(Instr::Smm { dst: 0 });
        let p = Program::new(instrs);
        assert_eq!(p.start_of(1), Some(1));
        assert_eq!(p.start_of(2), Some(8));
        assert_eq!(p.start_of(9), None);
    }

    #[test]
    fn unrolled_repeats_loop_body() {
        let p = sample_program();
        let u = p.unrolled();
        // body = mma mms fad smm (4 instrs), loop count 3 -> 3 * 4 = 12
        assert_eq!(u.len(), 12);
        assert_eq!(u[0], u[4]);
        assert_eq!(u[0], u[8]);
    }

    #[test]
    fn validate_rejects_bad_loops() {
        let p = Program::new(vec![Instr::Loop { count: 2, body: 4 }]);
        assert!(p.validate().is_err());
        let p2 = Program::new(vec![
            Instr::Smm { dst: 0 },
            Instr::Loop { count: 0, body: 1 },
        ]);
        assert!(p2.validate().is_err());
        assert!(sample_program().validate().is_ok());
    }

    #[test]
    fn histogram_counts() {
        let h = opcode_histogram(&sample_program());
        assert_eq!(h[Opcode::Mma as usize], 1);
        assert_eq!(h[Opcode::Loop as usize], 1);
        assert_eq!(h[Opcode::Halt as usize], 1);
    }

    #[test]
    fn image_bits_budget() {
        let p = sample_program();
        assert!(p.to_image().bits() < 64 * 1024, "PM image must fit 64 kbit");
    }
}
