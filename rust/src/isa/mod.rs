//! S3 — The FGP instruction set (paper Table I, Listing 2).
//!
//! Six instructions: three datapath-control (`mma`, `mms`, `fad`) — one per
//! systolic-array operation type of §II — and three program-control
//! (`smm`, `loop`, `prg`), plus an implicit `halt`. "The arguments of the
//! instructions are the addresses of the input and output messages in the
//! memory as well as flags for the Hermitian transpose and negation"
//! (§III). The paper's Listing 2 does not document its operand fields, so
//! this module defines a clean 64-bit encoding carrying exactly that
//! information (documented in DESIGN.md §ISA):
//!
//! ```text
//! bits 63..56 opcode   55..48 srcA   47..40 srcB   39..32 srcC
//! bits 31..24 dst      23..16 imm_lo 15..8  imm_hi
//! bit 7 AH   bit 6 BH   bit 5 NEG   bit 4 STATE_B
//! bit 3 VEC  bit 2 STATE_A          bits 1..0 reserved
//! ```
//!
//! * `STATE_A`/`STATE_B` select an operand from **state memory** (the
//!   per-node A matrices) instead of message memory.
//! * `AH`/`BH` request the Transpose unit (Hermitian transpose on read).
//! * `NEG` negates the product (for `-A(V_X A^H)` forms).
//! * `VEC` routes the operation through the **mean pipeline**: the Select
//!   and Mask units feed the n-element mean column of the message slots
//!   through the array instead of the n x n matrix. This is how one
//!   compiled compound node updates both V and m (Fig. 2 computes only the
//!   covariance; the FGP streams the mean as an extra column).
//! * slot `0xFF` (`acc`) addresses the systolic array's StateReg planes
//!   instead of memory — chained `mma`→`mms`→`fad` sequences reference
//!   intermediate results without storing them (§III: "storing
//!   intermediate results ... is not required").

use std::fmt;

pub mod program;

pub use program::{MemoryImage, Program};

/// Operand source: message memory slot or state memory slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OperandSrc {
    /// Message memory slot (or `ACC` for the array accumulator).
    Msg(u8),
    /// State memory slot (the per-node A matrices).
    State(u8),
}

impl OperandSrc {
    /// The operand's slot address.
    pub fn slot(&self) -> u8 {
        match self {
            OperandSrc::Msg(s) | OperandSrc::State(s) => *s,
        }
    }

    /// True when the operand reads state memory.
    pub fn is_state(&self) -> bool {
        matches!(self, OperandSrc::State(_))
    }
}

/// Slot value addressing the systolic array's StateReg planes.
pub const ACC: u8 = 0xFF;

/// Decoded FGP instruction.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Instr {
    /// Matrix multiplication & accumulate (PEmult *accum* mode):
    /// `accum = opA * opB`, optionally Hermitian-transposed operands,
    /// optionally negated product, optionally on the mean pipeline.
    Mma { a: OperandSrc, a_herm: bool, b: OperandSrc, b_herm: bool, neg: bool, vec: bool },
    /// Matrix multiplication & shift (PEmult *shift* mode with chained
    /// addition, §II): `shift = (∓srcC) + opA * opB` — `neg` negates the
    /// addend, which is how the innovation `A m_X - m_Y` is formed on the
    /// mean pipeline.
    Mms {
        a: OperandSrc,
        a_herm: bool,
        b: OperandSrc,
        b_herm: bool,
        c: u8,
        neg: bool,
        vec: bool,
    },
    /// Faddeev algorithm over the doubled matrix `[[G, B], [C, D]]` ->
    /// Schur complement `D - C G^{-1} B` left in the shift plane. The mean
    /// columns of G (innovation) and D ride along as the extended column.
    /// `b_herm` streams quadrant B through the Transpose unit.
    Fad { g: u8, b: u8, b_herm: bool, c: u8, d: u8 },
    /// Store the array result planes (matrix + mean) to a message slot.
    Smm { dst: u8 },
    /// Loop over the previous `body` instructions, `count` total passes
    /// ("loop over instructions (FG sections)").
    Loop { count: u16, body: u8 },
    /// Marks the start of program `id` in the PM.
    Prg { id: u8 },
    /// Stop execution (implicit at the end of each program).
    Halt,
}

/// Opcode numbers (bits 63..56).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Opcode {
    Halt = 0,
    Mma = 1,
    Mms = 2,
    Fad = 3,
    Smm = 4,
    Loop = 5,
    Prg = 6,
}

impl Opcode {
    /// Decode an opcode byte.
    pub fn from_u8(v: u8) -> Option<Opcode> {
        Some(match v {
            0 => Opcode::Halt,
            1 => Opcode::Mma,
            2 => Opcode::Mms,
            3 => Opcode::Fad,
            4 => Opcode::Smm,
            5 => Opcode::Loop,
            6 => Opcode::Prg,
            _ => return None,
        })
    }
}

/// Errors from decoding, parsing, or mismatched expectations.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum IsaError {
    /// An opcode byte outside the ISA.
    #[error("unknown opcode {0}")]
    UnknownOpcode(u8),
    /// Reserved encoding bits were set.
    #[error("reserved bits set in instruction word {0:#018x}")]
    ReservedBits(u64),
    /// Assembler text could not be parsed.
    #[error("parse error on line {line}: {msg}")]
    Parse { line: usize, msg: String },
    /// A host expected one instruction kind and decoded another — a
    /// malformed program surfaces as data, it cannot abort the process.
    #[error("expected a {expected} instruction, got {got}")]
    WrongInstr { expected: &'static str, got: &'static str },
}

const FLAG_AH: u64 = 1 << 7;
const FLAG_BH: u64 = 1 << 6;
const FLAG_NEG: u64 = 1 << 5;
const FLAG_STATE_B: u64 = 1 << 4;
const FLAG_VEC: u64 = 1 << 3;
const FLAG_STATE_A: u64 = 1 << 2;
const RESERVED_MASK: u64 = 0x3;

impl Instr {
    /// Encode into the 64-bit instruction word.
    pub fn encode(&self) -> u64 {
        let field = |v: u8, shift: u32| (v as u64) << shift;
        let flags = |ah: bool, bh: bool, neg: bool, sb: bool, vec: bool, sa: bool| {
            let mut f = 0u64;
            if ah {
                f |= FLAG_AH;
            }
            if bh {
                f |= FLAG_BH;
            }
            if neg {
                f |= FLAG_NEG;
            }
            if sb {
                f |= FLAG_STATE_B;
            }
            if vec {
                f |= FLAG_VEC;
            }
            if sa {
                f |= FLAG_STATE_A;
            }
            f
        };
        match self {
            Instr::Halt => 0,
            Instr::Mma { a, a_herm, b, b_herm, neg, vec } => {
                field(Opcode::Mma as u8, 56)
                    | field(a.slot(), 48)
                    | field(b.slot(), 40)
                    | flags(*a_herm, *b_herm, *neg, b.is_state(), *vec, a.is_state())
            }
            Instr::Mms { a, a_herm, b, b_herm, c, neg, vec } => {
                field(Opcode::Mms as u8, 56)
                    | field(a.slot(), 48)
                    | field(b.slot(), 40)
                    | field(*c, 32)
                    | flags(*a_herm, *b_herm, *neg, b.is_state(), *vec, a.is_state())
            }
            Instr::Fad { g, b, b_herm, c, d } => {
                field(Opcode::Fad as u8, 56)
                    | field(*g, 48)
                    | field(*b, 40)
                    | field(*c, 32)
                    | field(*d, 24)
                    | flags(false, *b_herm, false, false, false, false)
            }
            Instr::Smm { dst } => field(Opcode::Smm as u8, 56) | field(*dst, 24),
            Instr::Loop { count, body } => {
                field(Opcode::Loop as u8, 56)
                    | field((*count & 0xFF) as u8, 16)
                    | field((*count >> 8) as u8, 8)
                    | field(*body, 48)
            }
            Instr::Prg { id } => field(Opcode::Prg as u8, 56) | field(*id, 16),
        }
    }

    /// Decode a 64-bit instruction word.
    pub fn decode(w: u64) -> Result<Instr, IsaError> {
        if w & RESERVED_MASK != 0 {
            return Err(IsaError::ReservedBits(w));
        }
        let op = Opcode::from_u8((w >> 56) as u8).ok_or(IsaError::UnknownOpcode((w >> 56) as u8))?;
        let byte = |shift: u32| ((w >> shift) & 0xFF) as u8;
        let a_src = |slot: u8| {
            if w & FLAG_STATE_A != 0 {
                OperandSrc::State(slot)
            } else {
                OperandSrc::Msg(slot)
            }
        };
        let b_src = |slot: u8| {
            if w & FLAG_STATE_B != 0 {
                OperandSrc::State(slot)
            } else {
                OperandSrc::Msg(slot)
            }
        };
        Ok(match op {
            Opcode::Halt => Instr::Halt,
            Opcode::Mma => Instr::Mma {
                a: a_src(byte(48)),
                a_herm: w & FLAG_AH != 0,
                b: b_src(byte(40)),
                b_herm: w & FLAG_BH != 0,
                neg: w & FLAG_NEG != 0,
                vec: w & FLAG_VEC != 0,
            },
            Opcode::Mms => Instr::Mms {
                a: a_src(byte(48)),
                a_herm: w & FLAG_AH != 0,
                b: b_src(byte(40)),
                b_herm: w & FLAG_BH != 0,
                c: byte(32),
                neg: w & FLAG_NEG != 0,
                vec: w & FLAG_VEC != 0,
            },
            Opcode::Fad => Instr::Fad {
                g: byte(48),
                b: byte(40),
                b_herm: w & FLAG_BH != 0,
                c: byte(32),
                d: byte(24),
            },
            Opcode::Smm => Instr::Smm { dst: byte(24) },
            Opcode::Loop => Instr::Loop {
                count: byte(16) as u16 | ((byte(8) as u16) << 8),
                body: byte(48),
            },
            Opcode::Prg => Instr::Prg { id: byte(16) },
        })
    }

    /// Instruction mnemonic.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Instr::Mma { .. } => "mma",
            Instr::Mms { .. } => "mms",
            Instr::Fad { .. } => "fad",
            Instr::Smm { .. } => "smm",
            Instr::Loop { .. } => "loop",
            Instr::Prg { .. } => "prg",
            Instr::Halt => "halt",
        }
    }

    /// Is this a datapath-control instruction (Table I top half)?
    pub fn is_datapath(&self) -> bool {
        matches!(self, Instr::Mma { .. } | Instr::Mms { .. } | Instr::Fad { .. })
    }

    /// Project the instruction through `pick` — the typed accessor for
    /// hosts that expect a specific variant (program loaders, disasm
    /// round-trips). A mismatch is an [`IsaError::WrongInstr`] value,
    /// never a caller panic, so a malformed program cannot abort a
    /// serving process.
    pub fn expect<T>(
        &self,
        expected: &'static str,
        pick: impl FnOnce(&Instr) -> Option<T>,
    ) -> Result<T, IsaError> {
        pick(self).ok_or(IsaError::WrongInstr { expected, got: self.mnemonic() })
    }
}

fn slot_str(s: u8) -> String {
    if s == ACC {
        "acc".into()
    } else {
        format!("{s}")
    }
}

fn operand_str(src: &OperandSrc, herm: bool) -> String {
    let prefix = if src.is_state() { "s" } else { "" };
    let h = if herm { "h" } else { "" };
    format!("{prefix}{}{h}", slot_str(src.slot()))
}

fn suffix_str(neg: bool, vec: bool) -> String {
    let mut s = String::new();
    if vec {
        s.push_str(" v");
    }
    if neg {
        s.push_str(" ~");
    }
    s
}

impl fmt::Display for Instr {
    /// FGP Assembler text (the paper's mnemonics; operands are
    /// `<slot>[h]` with an `s` prefix for state memory and `acc` for the
    /// array accumulator; `v` selects the mean pipeline, `~` negates).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Instr::Mma { a, a_herm, b, b_herm, neg, vec } => {
                write!(
                    f,
                    "mma  {} {}{}",
                    operand_str(a, *a_herm),
                    operand_str(b, *b_herm),
                    suffix_str(*neg, *vec)
                )
            }
            Instr::Mms { a, a_herm, b, b_herm, c, neg, vec } => {
                write!(
                    f,
                    "mms  {} {} {}{}",
                    operand_str(a, *a_herm),
                    operand_str(b, *b_herm),
                    slot_str(*c),
                    suffix_str(*neg, *vec)
                )
            }
            Instr::Fad { g, b, b_herm, c, d } => {
                let bh = if *b_herm { "h" } else { "" };
                write!(
                    f,
                    "fad  {} {}{bh} {} {}",
                    slot_str(*g),
                    slot_str(*b),
                    slot_str(*c),
                    slot_str(*d)
                )
            }
            Instr::Smm { dst } => write!(f, "smm  {}", slot_str(*dst)),
            Instr::Loop { count, body } => write!(f, "loop {count} {body}"),
            Instr::Prg { id } => write!(f, "prg  {id}"),
            Instr::Halt => write!(f, "halt"),
        }
    }
}

/// Parse one line of FGP Assembler (inverse of `Display`).
pub fn parse_line(line: &str, lineno: usize) -> Result<Option<Instr>, IsaError> {
    let line = line.split(';').next().unwrap_or("").trim(); // ';' comments
    if line.is_empty() {
        return Ok(None);
    }
    let err = |msg: String| IsaError::Parse { line: lineno, msg };
    let mut tokens = line.split_whitespace();
    let mnem = tokens.next().unwrap();
    let rest: Vec<&str> = tokens.collect();

    fn parse_operand(tok: &str, lineno: usize) -> Result<(OperandSrc, bool), IsaError> {
        let mut t = tok;
        let is_state = t.starts_with('s')
            && t.len() > 1
            && (t[1..2].chars().all(|c| c.is_ascii_digit()) || t[1..].starts_with("acc"));
        if is_state {
            t = &t[1..];
        }
        let herm = t.ends_with('h') && t != "h" && t != "acch" || (t.ends_with('h') && t.starts_with("acc") && t != "acc");
        let t = if t.ends_with('h') && t != "h" { &t[..t.len() - 1] } else { t };
        let slot = if t == "acc" {
            ACC
        } else {
            t.parse::<u8>().map_err(|_| IsaError::Parse {
                line: lineno,
                msg: format!("bad operand '{tok}'"),
            })?
        };
        let src = if is_state { OperandSrc::State(slot) } else { OperandSrc::Msg(slot) };
        Ok((src, herm))
    }

    let vec = rest.contains(&"v");
    let neg = rest.contains(&"~");
    let args: Vec<&str> = rest.iter().filter(|t| **t != "v" && **t != "~").cloned().collect();

    let instr = match mnem {
        "mma" => {
            if args.len() != 2 {
                return Err(err("mma expects 2 operands".into()));
            }
            let (a, a_herm) = parse_operand(args[0], lineno)?;
            let (b, b_herm) = parse_operand(args[1], lineno)?;
            Instr::Mma { a, a_herm, b, b_herm, neg, vec }
        }
        "mms" => {
            if args.len() != 3 {
                return Err(err("mms expects 3 operands".into()));
            }
            let (a, a_herm) = parse_operand(args[0], lineno)?;
            let (b, b_herm) = parse_operand(args[1], lineno)?;
            let (c, _) = parse_operand(args[2], lineno)?;
            if c.is_state() {
                return Err(err("mms addend must be message memory or acc".into()));
            }
            Instr::Mms { a, a_herm, b, b_herm, c: c.slot(), neg, vec }
        }
        "fad" => {
            if args.len() != 4 {
                return Err(err("fad expects 4 operands".into()));
            }
            let (g, _) = parse_operand(args[0], lineno)?;
            let (b, b_herm) = parse_operand(args[1], lineno)?;
            let (c, _) = parse_operand(args[2], lineno)?;
            let (d, _) = parse_operand(args[3], lineno)?;
            Instr::Fad { g: g.slot(), b: b.slot(), b_herm, c: c.slot(), d: d.slot() }
        }
        "smm" => {
            if args.len() != 1 {
                return Err(err("smm expects 1 operand".into()));
            }
            Instr::Smm { dst: parse_operand(args[0], lineno)?.0.slot() }
        }
        "loop" => {
            if args.len() != 2 {
                return Err(err("loop expects count and body length".into()));
            }
            let count = args[0]
                .parse::<u16>()
                .map_err(|_| IsaError::Parse { line: lineno, msg: "bad loop count".into() })?;
            let body = args[1]
                .parse::<u8>()
                .map_err(|_| IsaError::Parse { line: lineno, msg: "bad loop body".into() })?;
            Instr::Loop { count, body }
        }
        "prg" => {
            if args.len() != 1 {
                return Err(err("prg expects 1 operand".into()));
            }
            let id = args[0]
                .parse::<u8>()
                .map_err(|_| IsaError::Parse { line: lineno, msg: "bad prg id".into() })?;
            Instr::Prg { id }
        }
        "halt" => Instr::Halt,
        other => return Err(err(format!("unknown mnemonic '{other}'"))),
    };
    Ok(Some(instr))
}

/// Parse a whole FGP Assembler listing.
pub fn parse_listing(text: &str) -> Result<Vec<Instr>, IsaError> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if let Some(instr) = parse_line(line, i + 1)? {
            out.push(instr);
        }
    }
    Ok(out)
}

/// Render a listing (inverse of [`parse_listing`]).
pub fn format_listing(instrs: &[Instr]) -> String {
    instrs.iter().map(|i| format!("{i}\n")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::proptest_cases;

    fn sample_instrs() -> Vec<Instr> {
        vec![
            Instr::Prg { id: 1 },
            Instr::Mma {
                a: OperandSrc::Msg(1),
                a_herm: false,
                b: OperandSrc::State(0),
                b_herm: true,
                neg: false,
                vec: false,
            },
            Instr::Mms {
                a: OperandSrc::State(0),
                a_herm: false,
                b: OperandSrc::Msg(ACC),
                b_herm: false,
                c: 2,
                neg: false,
                vec: false,
            },
            Instr::Mms {
                a: OperandSrc::State(0),
                a_herm: false,
                b: OperandSrc::Msg(1),
                b_herm: false,
                c: 2,
                neg: true,
                vec: true,
            },
            Instr::Fad { g: ACC, b: ACC, b_herm: true, c: ACC, d: 1 },
            Instr::Smm { dst: 4 },
            Instr::Loop { count: 300, body: 5 },
            Instr::Halt,
        ]
    }

    #[test]
    fn encode_decode_roundtrip() {
        for i in sample_instrs() {
            let w = i.encode();
            assert_eq!(Instr::decode(w).unwrap(), i, "word {w:#018x}");
        }
    }

    #[test]
    fn text_roundtrip() {
        let instrs = sample_instrs();
        let text = format_listing(&instrs);
        let parsed = parse_listing(&text).unwrap();
        assert_eq!(parsed, instrs, "listing was:\n{text}");
    }

    pub(crate) fn random_instr(rng: &mut crate::testutil::Rng) -> Instr {
        let slot = |rng: &mut crate::testutil::Rng| {
            if rng.uniform() < 0.1 {
                ACC
            } else {
                rng.below(200) as u8
            }
        };
        let operand = |rng: &mut crate::testutil::Rng| {
            if rng.uniform() < 0.5 {
                OperandSrc::Msg(slot(rng))
            } else {
                OperandSrc::State(rng.below(16) as u8)
            }
        };
        match rng.below(7) {
            0 => Instr::Mma {
                a: operand(rng),
                a_herm: rng.uniform() < 0.5,
                b: operand(rng),
                b_herm: rng.uniform() < 0.5,
                neg: rng.uniform() < 0.5,
                vec: rng.uniform() < 0.5,
            },
            1 => Instr::Mms {
                a: operand(rng),
                a_herm: rng.uniform() < 0.5,
                b: operand(rng),
                b_herm: rng.uniform() < 0.5,
                c: slot(rng),
                neg: rng.uniform() < 0.5,
                vec: rng.uniform() < 0.5,
            },
            2 => Instr::Fad {
                g: slot(rng),
                b: slot(rng),
                b_herm: rng.uniform() < 0.5,
                c: slot(rng),
                d: slot(rng),
            },
            3 => Instr::Smm { dst: rng.below(255) as u8 },
            4 => Instr::Loop {
                count: (rng.below(60000) + 1) as u16,
                body: (rng.below(255) + 1) as u8,
            },
            5 => Instr::Prg { id: rng.below(255) as u8 },
            _ => Instr::Halt,
        }
    }

    #[test]
    fn random_encode_decode_roundtrip() {
        proptest_cases(1000, |rng| {
            let i = random_instr(rng);
            assert_eq!(Instr::decode(i.encode()).unwrap(), i);
        });
    }

    #[test]
    fn random_text_roundtrip() {
        proptest_cases(1000, |rng| {
            let i = random_instr(rng);
            let text = format!("{i}");
            let parsed = parse_line(&text, 1).unwrap().unwrap();
            assert_eq!(parsed, i, "text was: {text}");
        });
    }

    #[test]
    fn unknown_opcode_rejected() {
        let w = 0x7Fu64 << 56;
        assert_eq!(Instr::decode(w), Err(IsaError::UnknownOpcode(0x7F)));
    }

    #[test]
    fn reserved_bits_rejected() {
        let w = (Opcode::Mma as u64) << 56 | 0x1;
        assert!(matches!(Instr::decode(w), Err(IsaError::ReservedBits(_))));
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let text = "; paper Listing 2 style\n\nprg 1\n  mma 1 s0h ; V_X A^H\n";
        let parsed = parse_listing(text).unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0], Instr::Prg { id: 1 });
    }

    #[test]
    fn parse_rejects_bad_arity() {
        assert!(parse_line("mma 1", 1).is_err());
        assert!(parse_line("fad 1 2 3", 1).is_err());
        assert!(parse_line("bogus 1 2", 1).is_err());
    }

    #[test]
    fn vec_and_neg_suffixes_parse() -> Result<(), IsaError> {
        let instr = parse_line("mms s0 1 2 v ~", 1)?.unwrap();
        let flags = instr.expect("mms", |i| match i {
            Instr::Mms { vec, neg, .. } => Some((*vec, *neg)),
            _ => None,
        })?;
        assert_eq!(flags, (true, true));
        Ok(())
    }

    #[test]
    fn mismatched_instruction_is_a_typed_error() {
        let err = Instr::Halt
            .expect("mms", |i| match i {
                Instr::Mms { vec, neg, .. } => Some((*vec, *neg)),
                _ => None,
            })
            .unwrap_err();
        assert_eq!(err, IsaError::WrongInstr { expected: "mms", got: "halt" });
    }
}
