//! `fgp` — command-line front-end for the FGP reproduction.
//!
//! Subcommands (hand-rolled parsing; no clap in the vendored set):
//!
//! ```text
//! fgp assemble <in.asm> <out.img>     assemble FGP assembler text to a memory image
//! fgp disasm   <in.img>               disassemble a memory image
//! fgp compile  [--sections S] [--no-opt] [--no-loop]
//!                                     compile the Fig. 6 RLS graph, print listing + stats
//! fgp run      [--sections S] [--sigma2 V] [--seed N]
//!                                     run RLS channel estimation on the simulator
//! fgp report                          print the Table II / area report
//! fgp serve    [--requests N] [--batch B]
//!                                     serve CN updates (XLA if artifacts exist)
//! fgp health   [--addr HOST:PORT] [--tenant T] [--prom]
//!                                     health/SLO snapshot of a serve front door
//!                                     (no --addr: boot a demo farm with one
//!                                     degraded device and watch it drain)
//! ```

use std::time::Instant;

use anyhow::{bail, Context, Result};

use fgp_repro::apps::rls::RlsProblem;
use fgp_repro::compiler::{compile, CompileOptions};
use fgp_repro::coordinator::backend::{CnRequestData, GoldenBackend, XlaBatchBackend};
use fgp_repro::coordinator::{BatchPolicy, CnServer, ServerConfig};
use fgp_repro::dsp::C66xModel;
use fgp_repro::engine::Session;
use fgp_repro::fgp::TimingModel;
use fgp_repro::gmp::matrix::{c64, CMatrix};
use fgp_repro::gmp::message::GaussMessage;
use fgp_repro::gmp::{FactorGraph, Schedule};
use fgp_repro::isa::{parse_listing, MemoryImage, Program};
use fgp_repro::model::area::AreaModel;
use fgp_repro::model::scaling::{normalized_throughput, ProcessorPoint};
use fgp_repro::paper;
use fgp_repro::runtime::RuntimeClient;
use fgp_repro::testutil::Rng;

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, String)>,
    switches: Vec<String>,
}

impl Args {
    fn parse(raw: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut switches = Vec::new();
        let mut i = 0;
        while i < raw.len() {
            if let Some(key) = raw[i].strip_prefix("--") {
                if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    flags.push((key.to_string(), raw[i + 1].clone()));
                    i += 2;
                } else {
                    switches.push(key.to_string());
                    i += 1;
                }
            } else {
                positional.push(raw[i].clone());
                i += 1;
            }
        }
        Args { positional, flags, switches }
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.iter().find(|(k, _)| k == key) {
            Some((_, v)) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad value for --{key}: {v}")),
            None => Ok(default),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }
}

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = raw.first() else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(&raw[1..]);
    match cmd.as_str() {
        "assemble" => cmd_assemble(&args),
        "disasm" => cmd_disasm(&args),
        "compile" => cmd_compile(&args),
        "run" => cmd_run(&args),
        "trace" => cmd_trace(&args),
        "report" => cmd_report(),
        "serve" => cmd_serve(&args),
        "health" => cmd_health(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `fgp help`)"),
    }
}

fn print_usage() {
    println!(
        "fgp — A Signal Processor for Gaussian Message Passing (reproduction)\n\n\
         usage:\n  \
         fgp assemble <in.asm> <out.img>\n  \
         fgp disasm <in.img>\n  \
         fgp compile [--sections S] [--no-opt] [--no-loop]\n  \
         fgp run [--sections S] [--sigma2 V] [--seed N]\n  \
         fgp trace [--sections S]  (instruction-level cycle profile)\n  \
         fgp report\n  \
         fgp serve [--requests N] [--batch B]\n  \
         fgp health [--addr HOST:PORT] [--tenant T] [--prom]  (SLO/alert/device health)"
    );
}

/// Run the RLS program under the instruction-level profiler and print
/// the per-opcode cycle budget (where the architecture spends its time).
fn cmd_trace(args: &Args) -> Result<()> {
    use fgp_repro::fgp::processor::NoFeed;
    use fgp_repro::fgp::{Fgp, FgpConfig, Profiler};
    use fgp_repro::gmp::message::GaussMessage;

    let sections: usize = args.get("sections", 8)?;
    let p = RlsProblem::synthetic(paper::N, sections, 0.02, args.get("seed", 1u64)?);
    let compiled = p.compile_program()?;
    let mut fgp = Fgp::new(FgpConfig::default());
    fgp.pm.load(&compiled.program.to_image())?;
    fgp.msgmem
        .write_message(compiled.memmap.preloads[0].1, &GaussMessage::isotropic(paper::N, 0.5));
    fgp.msgmem
        .write_message(compiled.memmap.streams[0].1, &GaussMessage::isotropic(paper::N, 0.1));
    fgp.statemem
        .write_matrix(compiled.memmap.state_streams[0].1, &CMatrix::identity(paper::N));
    let mut prof = Profiler::new(32);
    let stats = fgp.run_program_profiled(1, &mut NoFeed, Some(&mut prof))?;
    println!("program: {} sections, {} cycles total\n", sections, stats.cycles);
    print!("{prof}");
    println!("\nFaddeev share of datapath cycles: {:.0}%", prof.faddeev_share() * 100.0);
    println!("\nfirst records (PM addr @ start cycle, cost):");
    for r in prof.records().iter().take(6) {
        println!("  PM[{}] @ {:>5}: {:<4} ({} cycles)", r.addr, r.start_cycle, r.instr.mnemonic(), r.cycles);
    }
    Ok(())
}

fn cmd_assemble(args: &Args) -> Result<()> {
    let [input, output] = args.positional.as_slice() else {
        bail!("assemble needs <in.asm> <out.img>");
    };
    let text = std::fs::read_to_string(input).with_context(|| format!("reading {input}"))?;
    let instrs = parse_listing(&text)?;
    let program = Program::new(instrs);
    program.validate()?;
    let image = program.to_image();
    std::fs::write(output, &image.bytes).with_context(|| format!("writing {output}"))?;
    println!(
        "assembled {} instructions -> {} ({} bytes)",
        program.instrs.len(),
        output,
        image.len()
    );
    Ok(())
}

fn cmd_disasm(args: &Args) -> Result<()> {
    let [input] = args.positional.as_slice() else {
        bail!("disasm needs <in.img>");
    };
    let bytes = std::fs::read(input).with_context(|| format!("reading {input}"))?;
    let program = Program::from_image(&MemoryImage { bytes })?;
    print!("{}", program.listing());
    Ok(())
}

fn cmd_compile(args: &Args) -> Result<()> {
    let sections: usize = args.get("sections", 8)?;
    let mut rng = Rng::new(args.get("seed", 1u64)?);
    let n = paper::N;
    let a_list: Vec<CMatrix> =
        (0..sections).map(|_| CMatrix::random(&mut rng, n, n).scale(0.3)).collect();
    let mut graph = FactorGraph::new();
    graph.rls_chain(n, &a_list);
    let schedule = Schedule::forward_sweep(&graph);
    let opts = CompileOptions {
        optimize_memory: !args.has("no-opt"),
        compress_loops: !args.has("no-loop"),
        ..Default::default()
    };
    let compiled = compile(&graph, &schedule, &opts)?;
    println!("{}", compiled.listing());
    println!(
        "; slots: {} optimized / {} unoptimized | instrs: {} compressed / {} flat | loop {:?}",
        compiled.stats.slots_optimized,
        compiled.stats.slots_unoptimized,
        compiled.stats.instrs_compressed,
        compiled.stats.instrs_uncompressed,
        compiled.stats.looped,
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let sections: usize = args.get("sections", 32)?;
    let sigma2: f64 = args.get("sigma2", 0.02)?;
    let seed: u64 = args.get("seed", 2024)?;
    let p = RlsProblem::synthetic(paper::N, sections, sigma2, seed);
    let golden = Session::golden().run(&p)?;
    let fgp = Session::fgp_sim(fgp_repro::fgp::FgpConfig::default()).run(&p)?;
    println!("RLS channel estimation, {sections} sections, sigma2 {sigma2}:");
    println!("  golden rel MSE: {:.5}", golden.quality);
    println!("  FGP    rel MSE: {:.5}", fgp.quality);
    println!("  cycles: {} ({} per section)", fgp.cycles, fgp.cycles_per_section);
    Ok(())
}

fn cmd_report() -> Result<()> {
    let timing = TimingModel::default();
    let dsp = C66xModel::default();
    let n = paper::N;
    let fgp_cycles = timing.compound_node_cycles(n);
    let dsp_cycles = dsp.compound_node_cycles(n);
    let fgp_pt = ProcessorPoint::fgp(fgp_cycles);
    let dsp_pt = ProcessorPoint::c66x(dsp_cycles);

    println!("=== Table II: throughput comparison, FGP vs DSP ===");
    println!("{:<38} {:>16} {:>16}", "", "FGP (this work)", "TI C66x");
    println!("{:<38} {:>16} {:>16}", "CMOS technology [nm]", 180, 40);
    println!("{:<38} {:>16} {:>16}", "Max. freq. [MHz]", 130, 1250);
    println!(
        "{:<38} {:>16} {:>16}",
        "cycles for CN msg update (measured)", fgp_cycles, dsp_cycles
    );
    println!(
        "{:<38} {:>16} {:>16}",
        "cycles for CN msg update (paper)",
        paper::FGP_CN_CYCLES,
        paper::DSP_CN_CYCLES
    );
    println!(
        "{:<38} {:>16.2e} {:>16.2e}",
        "normalized throughput [CN/s] @40nm",
        normalized_throughput(&fgp_pt, 40.0),
        normalized_throughput(&dsp_pt, 40.0)
    );

    let area = AreaModel::default().paper_configuration();
    let f = area.fractions();
    println!("\n=== Area (UMC180, modeled; paper: 3.11 mm², 30/60/10) ===");
    println!("total: {:.2} mm²", area.total());
    println!(
        "memories {:.0}%  systolic array {:.0}%  datapath+control {:.0}%",
        f[0] * 100.0,
        f[1] * 100.0,
        f[2] * 100.0
    );

    // energy extension (E11): ref [10] anchors the C66x at 0.8 W
    use fgp_repro::model::power::PowerPoint;
    let fgp_pw = PowerPoint::fgp(fgp_cycles, area.total());
    let dsp_pw = PowerPoint::c66x(dsp_cycles);
    println!("\n=== Energy per CN update (modeled; paper reports none) ===");
    println!(
        "{:<30} {:>12.1} nJ  ({:.2} W @ {} MHz, {} nm)",
        fgp_pw.name, fgp_pw.energy_per_cn_nj(), fgp_pw.power_w, fgp_pw.freq_mhz, fgp_pw.node_nm
    );
    println!(
        "{:<30} {:>12.1} nJ  ({:.2} W @ {} MHz, {} nm)",
        dsp_pw.name, dsp_pw.energy_per_cn_nj(), dsp_pw.power_w, dsp_pw.freq_mhz, dsp_pw.node_nm
    );
    println!(
        "energy advantage: {:.1}x at native nodes, {:.1}x at a common 40 nm",
        dsp_pw.energy_per_cn_nj() / fgp_pw.energy_per_cn_nj(),
        dsp_pw.energy_per_cn_nj_at(40.0) / fgp_pw.energy_per_cn_nj_at(40.0)
    );
    Ok(())
}

/// `fgp health`: the operator's view of a serve front door. With
/// `--addr` it connects to a running server and prints its health
/// snapshot; without, it boots a self-contained demo farm with the
/// health layer on, degrades one device, and shows the watcher catching
/// it (alerts firing, sticky traffic draining to the healthy member).
fn cmd_health(args: &Args) -> Result<()> {
    use fgp_repro::obs::health::{HealthConfig, SloDef};
    use fgp_repro::obs::prometheus_text;
    use fgp_repro::serve::{FgpServe, ServeClient, ServeConfig, StreamMode};

    let addr: String = args.get("addr", String::new())?;
    let tenant: String = args.get("tenant", "cli".to_string())?;
    if !addr.is_empty() {
        let mut client = ServeClient::connect(addr.as_str(), &tenant)?;
        print!("{}", client.health()?.report());
        return Ok(());
    }

    let mut cfg = ServeConfig::default();
    cfg.health = HealthConfig::on();
    cfg.health.watch.interval_ms = 10;
    cfg.health.slos.push(SloDef::new(&tenant, 0, 0.05));
    let server = FgpServe::start(cfg)?;
    server.farm().set_device_delay(1, 4)?;
    println!("demo farm up on {} — device 1 degraded by a 4 ms injected delay\n", server.addr());

    let mut client = ServeClient::connect(server.addr(), &tenant)?;
    let n = paper::N;
    let mut rng = Rng::new(9);
    let (stream, device) =
        client.open_stream("health-demo", StreamMode::Sticky, GaussMessage::isotropic(n, 0.5))?;
    println!("sticky stream {stream} pinned to device {device}");
    for _ in 0..12 {
        let samples: Vec<(GaussMessage, CMatrix)> = (0..4)
            .map(|_| {
                (
                    GaussMessage::new(
                        (0..n)
                            .map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5)))
                            .collect(),
                        CMatrix::random_psd(&mut rng, n, 1.0).scale(0.15),
                    ),
                    CMatrix::random(&mut rng, n, n).scale(0.3),
                )
            })
            .collect();
        client.push(stream, samples)?;
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let closed = client.close_stream(stream)?;
    println!("stream drained: {} samples\n", closed.samples_done);
    print!("{}", client.health()?.report());
    if args.has("prom") {
        println!("\n--- prometheus exposition ---");
        print!("{}", prometheus_text(&server.stats().telemetry));
    }
    server.shutdown();
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let requests: usize = args.get("requests", 256)?;
    let batch: usize = args.get("batch", 32)?;
    let n = paper::N;
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let use_xla = artifacts.join("manifest.txt").exists();
    println!(
        "serving {requests} CN updates, batch {batch}, backend {}",
        if use_xla { "xla" } else { "golden" }
    );
    let server = CnServer::start(
        move || {
            if use_xla {
                Ok(Box::new(XlaBatchBackend::new(RuntimeClient::load(&artifacts)?)?) as _)
            } else {
                Ok(Box::new(GoldenBackend) as _)
            }
        },
        ServerConfig {
            batch: BatchPolicy {
                max_batch: batch,
                max_wait: std::time::Duration::from_millis(2),
            },
        },
    )?;
    let client = server.client();
    let mut rng = Rng::new(5);
    let t0 = Instant::now();
    let pending: Vec<_> = (0..requests)
        .map(|_| {
            client.submit(CnRequestData {
                x: GaussMessage::new(
                    (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
                    CMatrix::random_psd(&mut rng, n, 1.0).scale(0.15),
                ),
                y: GaussMessage::new(
                    (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
                    CMatrix::random_psd(&mut rng, n, 1.0).scale(0.15),
                ),
                a: CMatrix::random(&mut rng, n, n).scale(0.3),
            })
        })
        .collect();
    for rx in pending {
        rx.recv().map_err(|_| anyhow::anyhow!("server died"))??;
    }
    let dt = t0.elapsed();
    println!("done in {dt:?} ({:.0} CN/s)", requests as f64 / dt.as_secs_f64());
    println!("{}", client.metrics().report());
    server.shutdown();
    Ok(())
}
