//! S6 — The TI C66x DSP baseline (paper §V, Table II, refs [10][11]).
//!
//! The paper did not run silicon either: "The number of cycles the C66x
//! DSP would take for execution is estimated using the DSP's fixed-point
//! instruction set. According to [11], 768 cycles for the inversion of a
//! complex 4x4 matrix are assumed." This module reproduces that
//! estimation procedure as an explicit cost model so every number in the
//! Table II row is derivable and auditable.
//!
//! # Cost model
//!
//! The C66x core has 8 functional units; its fixed-point multiply units
//! sustain **8 16x16 MACs per cycle** (4 per .M unit via `DDOTP4`-class
//! instructions, two .M units). A complex MAC = 4 real MACs, so the core
//! peaks at 2 complex MACs/cycle. Dense kernels reach roughly half of
//! peak once load/store and pipeline overhead on the .D/.L/.S units is
//! accounted (the software-pipelining efficiency factor below, the same
//! assumption [11] uses for its 768-cycle inversion figure).
//!
//! * complex n x n matmul: `n^3` cMACs -> `n^3 / 2` cycles at peak,
//!   divided by the pipelining efficiency, plus `n^2` store cycles.
//! * matrix add/sub: `n^2 / 4` cycles (4 16-bit lanes per .L unit, 2
//!   units) plus overhead.
//! * complex 4x4 inversion: fixed at [11]'s measured 768 cycles and
//!   scaled `(n/4)^3` for other sizes.
//!
//! A compound-node update on the DSP computes the Schur complement the
//! conventional way — an explicit inverse plus two more matmuls — which
//! is exactly the inefficiency the FGP's Faddeev datapath removes.

/// Cycle-cost model of the C66x fixed-point core.
#[derive(Clone, Copy, Debug)]
pub struct C66xModel {
    /// Real 16x16 MACs per cycle at peak (8 for the C66x).
    pub macs_per_cycle: f64,
    /// Fraction of peak a software-pipelined dense kernel sustains.
    pub pipeline_efficiency: f64,
    /// Cycles for the complex 4x4 matrix inversion (ref [11]).
    pub inv4_cycles: u64,
    /// Per-kernel call overhead (prolog/epilog of the pipelined loop).
    pub call_overhead: u64,
}

impl Default for C66xModel {
    fn default() -> Self {
        C66xModel {
            macs_per_cycle: 8.0,
            pipeline_efficiency: 2.0 / 3.0,
            inv4_cycles: crate::paper::DSP_INV4_CYCLES,
            call_overhead: 4,
        }
    }
}

/// Cycle breakdown of a compound-node update on the DSP.
#[derive(Clone, Copy, Debug, Default)]
pub struct CnBreakdown {
    /// `t1 = V_X A^H` matmul cycles.
    pub t1_matmul: u64,
    /// `G = V_Y + A t1` matmul + add cycles.
    pub g_matmul_add: u64,
    /// `G^{-1}` inversion cycles (ref [11]).
    pub inversion: u64,
    /// Gain matmul `t1 G^{-1}` cycles.
    pub gain_matmul: u64,
    /// Schur matmul + subtract cycles.
    pub schur_matmul_sub: u64,
    /// Mean-vector update cycles.
    pub mean_update: u64,
}

impl CnBreakdown {
    /// Total cycles of the compound-node update.
    pub fn total(&self) -> u64 {
        self.t1_matmul
            + self.g_matmul_add
            + self.inversion
            + self.gain_matmul
            + self.schur_matmul_sub
            + self.mean_update
    }
}

impl C66xModel {
    /// Cycles for a complex n x n matrix multiplication.
    pub fn matmul_cycles(&self, n: usize) -> u64 {
        let n = n as f64;
        let cmacs = n * n * n;
        let real_macs = cmacs * 4.0;
        let compute = real_macs / (self.macs_per_cycle * self.pipeline_efficiency);
        (compute + n * n) as u64 + self.call_overhead
    }

    /// Cycles for a complex n x n matrix addition/subtraction.
    pub fn matadd_cycles(&self, n: usize) -> u64 {
        let n2 = (n * n) as f64;
        (n2 * 2.0 / 8.0) as u64 + self.call_overhead / 2
    }

    /// Cycles for a complex n x n matrix inversion ([11] anchor, cubic
    /// scaling away from n = 4).
    pub fn inversion_cycles(&self, n: usize) -> u64 {
        let scale = (n as f64 / 4.0).powi(3);
        (self.inv4_cycles as f64 * scale) as u64
    }

    /// Cycles for a complex matrix-vector product (n x n * n).
    pub fn matvec_cycles(&self, n: usize) -> u64 {
        let real_macs = (n * n * 4) as f64;
        (real_macs / (self.macs_per_cycle * self.pipeline_efficiency)) as u64
            + self.call_overhead / 2
    }

    /// The compound-node update computed the conventional way:
    ///
    /// ```text
    /// T1 = V_X A^H            (matmul)
    /// G  = V_Y + A T1         (matmul + add)
    /// Gi = G^{-1}             (inversion, [11])
    /// K  = T1 Gi              (matmul)
    /// V_Z = V_X - K (A V_X)   (matmul + sub; A V_X = T1^H free by symmetry)
    /// m_Z = m_X + K (m_Y - A m_X)   (2 matvec + 2 vec add)
    /// ```
    pub fn compound_node_breakdown(&self, n: usize) -> CnBreakdown {
        CnBreakdown {
            t1_matmul: self.matmul_cycles(n),
            g_matmul_add: self.matmul_cycles(n) + self.matadd_cycles(n),
            inversion: self.inversion_cycles(n),
            gain_matmul: self.matmul_cycles(n),
            schur_matmul_sub: self.matmul_cycles(n) + self.matadd_cycles(n),
            mean_update: 2 * self.matvec_cycles(n) + 2,
        }
    }

    /// Total compound-node cycles (the Table II row).
    pub fn compound_node_cycles(&self, n: usize) -> u64 {
        self.compound_node_breakdown(n).total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cn_cycles_near_paper_estimate() {
        let m = C66xModel::default();
        let got = m.compound_node_cycles(4) as f64;
        let paper = crate::paper::DSP_CN_CYCLES as f64;
        let rel = (got - paper).abs() / paper;
        assert!(rel < 0.10, "DSP CN cycles {got} should be within 10% of 1076");
    }

    #[test]
    fn inversion_anchored_to_ref11() {
        let m = C66xModel::default();
        assert_eq!(m.inversion_cycles(4), 768);
        assert_eq!(m.inversion_cycles(8), 768 * 8);
    }

    #[test]
    fn inversion_dominates_cn_cost() {
        // the paper's core argument: the explicit inverse is the DSP's
        // bottleneck, which Faddeev avoids
        let m = C66xModel::default();
        let b = m.compound_node_breakdown(4);
        assert!(b.inversion as f64 > 0.5 * b.total() as f64);
    }

    #[test]
    fn matmul_scales_cubically() {
        let m = C66xModel::default();
        let c4 = m.matmul_cycles(4) - m.call_overhead;
        let c8 = m.matmul_cycles(8) - m.call_overhead;
        let ratio = c8 as f64 / c4 as f64;
        assert!(ratio > 6.0 && ratio < 9.0, "ratio {ratio}");
    }

    #[test]
    fn breakdown_sums_to_total() {
        let m = C66xModel::default();
        let b = m.compound_node_breakdown(4);
        assert_eq!(b.total(), m.compound_node_cycles(4));
    }
}
