//! Admission control: the serving tier's front-door backpressure.
//!
//! Two independent gates stand between a request and the farm:
//!
//! 1. **Per-tenant token buckets** ([`TenantQuotas`]) — rate-limit each
//!    tenant's *work units* (one sample = one unit). An empty bucket is
//!    a `QuotaExceeded` reply: deterministic, per-tenant, and refilled
//!    by wall-clock time, so one greedy tenant cannot starve the rest.
//! 2. **A bounded in-flight window** ([`AdmissionController`]) — caps
//!    the total units admitted but not yet executed, across all tenants
//!    and streams. A full window is an explicit `Busy` reply (with a
//!    retry hint) instead of an unbounded queue: the client sees
//!    backpressure immediately and the server's memory stays bounded.
//!
//! [`FairRotor`] provides the third leg — fair *ordering*: each engine
//! room round visits streams in a rotated order, so admitted work from
//! every tenant drains at the same rate regardless of stream id or
//! arrival order.
//!
//! Time is injected (`now: Instant` parameters) rather than read inside,
//! which keeps every decision deterministic under test.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

/// A token bucket over fractional tokens: capacity `burst`, refilled at
/// `rate` tokens/second. Starts full.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    tokens: f64,
    rate: f64,
    burst: f64,
    last: Instant,
}

impl TokenBucket {
    /// Full bucket with the given refill rate (tokens/second) and
    /// capacity, anchored at `now`.
    pub fn new(rate: f64, burst: f64, now: Instant) -> Self {
        TokenBucket { tokens: burst, rate, burst, last: now }
    }

    /// Take `n` tokens if available at `now`; refills by elapsed time
    /// first. With `rate == 0` the bucket never refills — a
    /// deterministic way to exhaust a tenant in tests.
    pub fn try_take(&mut self, n: f64, now: Instant) -> bool {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        self.last = now;
        if self.tokens >= n {
            self.tokens -= n;
            true
        } else {
            false
        }
    }

    /// Tokens currently available (after a refill at `now`).
    pub fn available(&mut self, now: Instant) -> f64 {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        self.last = now;
        self.tokens
    }
}

/// Per-tenant rate policy: `rate` work units per second, bursting to
/// `burst`.
#[derive(Clone, Copy, Debug)]
pub struct QuotaPolicy {
    /// Sustained units/second each tenant may submit.
    pub rate: f64,
    /// Bucket capacity (instantaneous burst).
    pub burst: f64,
}

impl Default for QuotaPolicy {
    fn default() -> Self {
        // effectively unlimited: quotas opt in by lowering these
        QuotaPolicy { rate: 1e9, burst: 1e9 }
    }
}

/// One token bucket per tenant, created on first sight under a shared
/// [`QuotaPolicy`].
#[derive(Debug)]
pub struct TenantQuotas {
    policy: QuotaPolicy,
    buckets: HashMap<String, TokenBucket>,
}

impl TenantQuotas {
    /// Empty quota table under `policy`.
    pub fn new(policy: QuotaPolicy) -> Self {
        TenantQuotas { policy, buckets: HashMap::new() }
    }

    /// Admit `units` work units for `tenant` at `now`, or refuse.
    pub fn admit(&mut self, tenant: &str, units: u64, now: Instant) -> bool {
        let bucket = self
            .buckets
            .entry(tenant.to_string())
            .or_insert_with(|| TokenBucket::new(self.policy.rate, self.policy.burst, now));
        bucket.try_take(units as f64, now)
    }
}

/// Bounded in-flight work window shared by every connection handler:
/// lock-free CAS admission, explicit release as units execute (or are
/// refused further down the pipeline).
#[derive(Debug)]
pub struct AdmissionController {
    max_inflight: usize,
    inflight: AtomicUsize,
}

impl AdmissionController {
    /// Window of `max_inflight` work units.
    pub fn new(max_inflight: usize) -> Self {
        AdmissionController { max_inflight, inflight: AtomicUsize::new(0) }
    }

    /// Try to admit `units`; all-or-nothing. A request larger than the
    /// whole window can never be admitted — the caller sees `false`
    /// immediately rather than deadlocking on a window that can't grow.
    pub fn try_acquire(&self, units: usize) -> bool {
        self.inflight
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |cur| {
                cur.checked_add(units).filter(|next| *next <= self.max_inflight)
            })
            .is_ok()
    }

    /// Return `units` to the window (after execution, or after a
    /// downstream refusal).
    pub fn release(&self, units: usize) {
        let prev = self.inflight.fetch_sub(units, Ordering::AcqRel);
        debug_assert!(prev >= units, "admission release underflow");
    }

    /// Units currently admitted and unexecuted.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    /// The window size.
    pub fn capacity(&self) -> usize {
        self.max_inflight
    }

    /// Window occupancy in `[0, 1]` — the `serve.inflight` gauge the
    /// telemetry registry exports, normalized for dashboards. A
    /// zero-capacity window reports 0 (it can never hold work).
    pub fn utilization(&self) -> f64 {
        if self.max_inflight == 0 {
            return 0.0;
        }
        self.inflight() as f64 / self.max_inflight as f64
    }
}

/// Rotating fair scheduler: each round visits the same item list in an
/// order rotated by one, so no stream or tenant is persistently first
/// (first place drains fastest when the farm saturates).
#[derive(Debug, Default)]
pub struct FairRotor {
    cursor: usize,
}

impl FairRotor {
    /// Fresh rotor.
    pub fn new() -> Self {
        Self::default()
    }

    /// The visiting order for a round over `len` items: indices rotated
    /// by the round number.
    pub fn order(&mut self, len: usize) -> Vec<usize> {
        if len == 0 {
            return Vec::new();
        }
        let start = self.cursor % len;
        self.cursor = self.cursor.wrapping_add(1);
        (0..len).map(|i| (start + i) % len).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn bucket_burst_then_refill() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(10.0, 4.0, t0);
        // burst drains the full capacity instantly
        assert!(b.try_take(4.0, t0));
        assert!(!b.try_take(1.0, t0));
        // 200 ms at 10/s refills 2 tokens
        let t1 = t0 + Duration::from_millis(200);
        assert!(b.try_take(2.0, t1));
        assert!(!b.try_take(0.5, t1));
        // refill caps at burst
        let t2 = t1 + Duration::from_secs(60);
        assert!((b.available(t2) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn zero_rate_bucket_never_refills() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(0.0, 2.0, t0);
        assert!(b.try_take(2.0, t0));
        assert!(!b.try_take(1.0, t0 + Duration::from_secs(3600)));
    }

    #[test]
    fn quotas_isolate_tenants() {
        let t0 = Instant::now();
        let mut q = TenantQuotas::new(QuotaPolicy { rate: 0.0, burst: 3.0 });
        assert!(q.admit("a", 3, t0));
        assert!(!q.admit("a", 1, t0), "tenant a is exhausted");
        assert!(q.admit("b", 3, t0), "tenant b has its own bucket");
    }

    #[test]
    fn admission_window_is_all_or_nothing() {
        let c = AdmissionController::new(4);
        assert!(c.try_acquire(3));
        assert!(!c.try_acquire(2), "3 + 2 exceeds the window");
        assert!(c.try_acquire(1));
        assert_eq!(c.inflight(), 4);
        c.release(2);
        assert!(c.try_acquire(2));
        c.release(4);
        assert_eq!(c.inflight(), 0);
        // a single request larger than the window is refused outright
        assert!(!c.try_acquire(5));
    }

    #[test]
    fn utilization_tracks_the_window() {
        let c = AdmissionController::new(8);
        assert_eq!(c.utilization(), 0.0);
        assert!(c.try_acquire(2));
        assert!((c.utilization() - 0.25).abs() < 1e-12);
        assert!(c.try_acquire(6));
        assert_eq!(c.utilization(), 1.0);
        c.release(8);
        assert_eq!(c.utilization(), 0.0);
        assert_eq!(AdmissionController::new(0).utilization(), 0.0);
    }

    #[test]
    fn admission_window_survives_concurrent_pressure() {
        use std::sync::Arc;
        let c = Arc::new(AdmissionController::new(16));
        let mut joins = Vec::new();
        for _ in 0..8 {
            let c = Arc::clone(&c);
            joins.push(std::thread::spawn(move || {
                let mut admitted = 0u64;
                for _ in 0..1000 {
                    if c.try_acquire(3) {
                        admitted += 1;
                        assert!(c.inflight() <= 16, "window overrun");
                        c.release(3);
                    }
                }
                admitted
            }));
        }
        let total: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert!(total > 0);
        assert_eq!(c.inflight(), 0);
    }

    #[test]
    fn rotor_rotates_start() {
        let mut r = FairRotor::new();
        assert_eq!(r.order(3), vec![0, 1, 2]);
        assert_eq!(r.order(3), vec![1, 2, 0]);
        assert_eq!(r.order(3), vec![2, 0, 1]);
        assert_eq!(r.order(3), vec![0, 1, 2]);
        assert!(r.order(0).is_empty());
    }
}
