//! Length-framed binary wire codec for the serving tier.
//!
//! Everything on the socket is a **frame**: a little-endian `u32` byte
//! length followed by that many payload bytes, capped at [`MAX_FRAME`].
//! Payloads are tag-byte enums over a fixed set of primitives:
//!
//! | primitive | encoding |
//! |-----------|----------|
//! | `u8`/`u32`/`u64` | little-endian |
//! | `f64`     | IEEE-754 bits via `to_bits`, little-endian (bit-exact) |
//! | `string`/`bytes` | `u32` length + raw bytes (strings are UTF-8) |
//! | `c64`     | re `f64`, im `f64` |
//! | vector    | `u32` length + elements |
//! | matrix    | `u32` rows, `u32` cols, row-major `c64`s |
//! | message   | mean vector + covariance matrix |
//!
//! Floats travel as raw bits, so a decode(encode(x)) round trip is
//! **bit-exact** — the property `rust/tests/property_wire.rs` pins for
//! every frame type, and what makes a checkpoint restored on another
//! process resume bitwise-identically. Decoding is total: any byte
//! slice either parses completely or returns a typed [`WireError`]
//! (truncation, bad tag, trailing garbage) — it never panics and never
//! allocates more than the payload could hold.
//!
//! Three protocol families share the codec:
//!
//! * [`ServeRequest`]/[`ServeReply`] — the serving tier's front-door
//!   protocol (one-shot updates, stream admission, checkpoint/failover,
//!   `STATS`);
//! * [`Command`]/[`Reply`] — the Fig. 5 device protocol, so a remote
//!   host can drive a raw device channel through the same framing;
//! * [`encode_checkpoint`]/[`decode_checkpoint`] — the portable
//!   `StreamCheckpoint` image (`FGCK` magic + version byte).
//!
//! ## Versioning and the trace envelope
//!
//! [`WIRE_VERSION`] is 2. Version-dependent values get **new tags**
//! rather than optional trailing fields, because the codec's totality
//! property ("every strict prefix errors") forbids optionals: a
//! version-2 `Hello` is tag 12 (tenant + declared client version), a
//! telemetry-extended `STATS` reply is tag 12 (the version-1 body plus
//! a [`RegistrySnapshot`](crate::obs::RegistrySnapshot) section —
//! since the health layer, counters + gauges + histograms), and the
//! health surface is request tag 11 / reply tag 13
//! ([`HealthSnapshot`]). The version-1 encodings are still emitted
//! whenever the value carries no version-2 information, so old peers
//! interoperate byte-for-byte and never see the health tags.
//!
//! Requests may additionally be wrapped in a **trace envelope**
//! ([`encode_request_traced`]): a leading marker byte 0 (request tags
//! start at 1) followed by `trace_id`/`span_id`, then the ordinary
//! request payload. [`decode_request_traced`] accepts both enveloped
//! and bare payloads, which is how a version-1 client talks to a
//! version-2 server unchanged.

use std::io::{self, Read, Write};

use crate::coordinator::MetricsSnapshot;
use crate::engine::StreamCheckpoint;
use crate::fixed::QFormat;
use crate::obs::health::{
    Alert, AlertKind, AlertSeverity, AlertState, DeviceHealth, HealthSnapshot, SloStatus,
};
use crate::obs::{RegistrySnapshot, TraceContext};
use crate::fgp::processor::{Command, FsmState, Reply};
use crate::fgp::RunStats;
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::isa::MemoryImage;

/// Hard cap on a frame's payload size (16 MiB). Large enough for any
/// realistic chunk of `n = 4` messages, small enough that a corrupt
/// length prefix cannot make a reader allocate unbounded memory.
pub const MAX_FRAME: usize = 16 * 1024 * 1024;

/// Wire protocol version carried in `Welcome` (and, since 2, declared
/// by the client in `Hello`). Version 2 adds the request trace
/// envelope, the telemetry section of `STATS`, and the declared
/// fixed-point precision of `OpenStream`/`Resume`; all are encoded
/// under new tags, so version-1 byte streams remain valid and
/// bit-identical.
pub const WIRE_VERSION: u32 = 2;

/// Typed decode/framing failures. Decoding never panics: every
/// malformed input maps to one of these.
#[derive(Clone, Debug, PartialEq, Eq, thiserror::Error)]
pub enum WireError {
    /// A frame length prefix exceeds [`MAX_FRAME`].
    #[error("frame of {len} bytes exceeds the {MAX_FRAME}-byte cap")]
    FrameTooLarge {
        /// The advertised payload length.
        len: usize,
    },
    /// The payload ended before the value was complete.
    #[error("truncated payload: needed {need} more bytes decoding {what}")]
    Truncated {
        /// What was being decoded.
        what: &'static str,
        /// How many bytes were missing.
        need: usize,
    },
    /// An enum tag byte matched no variant.
    #[error("bad {what} tag {tag}")]
    BadTag {
        /// Which enum was being decoded.
        what: &'static str,
        /// The offending tag byte.
        tag: u8,
    },
    /// The value decoded but bytes were left over.
    #[error("{extra} trailing bytes after a complete value")]
    Trailing {
        /// Leftover byte count.
        extra: usize,
    },
    /// A string field held invalid UTF-8.
    #[error("string field is not valid UTF-8")]
    BadUtf8,
}

// ---------------------------------------------------------------------
// primitive encoder / decoder
// ---------------------------------------------------------------------

/// Append-only payload encoder over the primitive vocabulary.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    /// Fresh empty encoder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Finish and take the payload.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Append a `u8`.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a little-endian `u32`.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian `u64`.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f64` as its IEEE-754 bits (bit-exact round trip).
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    /// Append a length-prefixed byte string.
    pub fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    /// Append a length-prefixed UTF-8 string.
    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    /// Append a complex scalar.
    pub fn c64(&mut self, v: c64) {
        self.f64(v.re);
        self.f64(v.im);
    }

    /// Append a length-prefixed complex vector.
    pub fn cvec(&mut self, v: &[c64]) {
        self.u32(v.len() as u32);
        for z in v {
            self.c64(*z);
        }
    }

    /// Append a complex matrix (rows, cols, row-major data).
    pub fn cmatrix(&mut self, m: &CMatrix) {
        self.u32(m.rows as u32);
        self.u32(m.cols as u32);
        for z in m.data() {
            self.c64(*z);
        }
    }

    /// Append a Gaussian message (mean vector + covariance matrix).
    pub fn msg(&mut self, m: &GaussMessage) {
        self.cvec(&m.mean);
        self.cmatrix(&m.cov);
    }
}

/// Cursor-style payload decoder; every read is bounds-checked.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    /// Decoder over a payload slice.
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], WireError> {
        let rest = self.buf.len() - self.pos;
        if rest < n {
            return Err(WireError::Truncated { what, need: n - rest });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read a `u8`.
    pub fn u8(&mut self, what: &'static str) -> Result<u8, WireError> {
        Ok(self.take(1, what)?[0])
    }

    /// Read a little-endian `u32`.
    pub fn u32(&mut self, what: &'static str) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    /// Read a little-endian `u64`.
    pub fn u64(&mut self, what: &'static str) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    /// Read an `f64` from its IEEE-754 bits.
    pub fn f64(&mut self, what: &'static str) -> Result<f64, WireError> {
        Ok(f64::from_bits(u64::from_le_bytes(
            self.take(8, what)?.try_into().unwrap(),
        )))
    }

    /// Read a length-prefixed byte string.
    pub fn bytes(&mut self, what: &'static str) -> Result<Vec<u8>, WireError> {
        let len = self.u32(what)? as usize;
        Ok(self.take(len, what)?.to_vec())
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn str(&mut self, what: &'static str) -> Result<String, WireError> {
        String::from_utf8(self.bytes(what)?).map_err(|_| WireError::BadUtf8)
    }

    /// Read a complex scalar.
    pub fn c64(&mut self, what: &'static str) -> Result<c64, WireError> {
        Ok(c64::new(self.f64(what)?, self.f64(what)?))
    }

    /// Read a length-prefixed complex vector. The length is validated
    /// against the remaining payload before allocating.
    pub fn cvec(&mut self, what: &'static str) -> Result<Vec<c64>, WireError> {
        let len = self.u32(what)? as usize;
        self.ensure_elems(len, what)?;
        (0..len).map(|_| self.c64(what)).collect()
    }

    /// Read a complex matrix (rows, cols, row-major data).
    pub fn cmatrix(&mut self, what: &'static str) -> Result<CMatrix, WireError> {
        let rows = self.u32(what)? as usize;
        let cols = self.u32(what)? as usize;
        let n = rows.checked_mul(cols).ok_or(WireError::FrameTooLarge { len: usize::MAX })?;
        self.ensure_elems(n, what)?;
        let mut m = CMatrix::zeros(rows, cols);
        for z in m.data_mut() {
            *z = self.c64(what)?;
        }
        Ok(m)
    }

    /// Read a Gaussian message.
    pub fn msg(&mut self, what: &'static str) -> Result<GaussMessage, WireError> {
        let mean = self.cvec(what)?;
        let cov = self.cmatrix(what)?;
        if mean.len() != cov.rows || cov.rows != cov.cols {
            return Err(WireError::BadTag { what, tag: 0xff });
        }
        Ok(GaussMessage { mean, cov })
    }

    /// Fail if any payload bytes remain.
    pub fn finish(self) -> Result<(), WireError> {
        let extra = self.buf.len() - self.pos;
        if extra != 0 {
            return Err(WireError::Trailing { extra });
        }
        Ok(())
    }

    /// Guard an upcoming `len`-element `c64` read against a corrupt
    /// length prefix: the elements must fit in the remaining payload.
    fn ensure_elems(&self, len: usize, what: &'static str) -> Result<(), WireError> {
        let need = len.checked_mul(16).ok_or(WireError::FrameTooLarge { len: usize::MAX })?;
        let rest = self.buf.len() - self.pos;
        if need > rest {
            return Err(WireError::Truncated { what, need: need - rest });
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------

/// Write one `[u32-le len][payload]` frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            WireError::FrameTooLarge { len: payload.len() },
        ));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Blocking frame read: `Ok(None)` on a clean EOF **at a frame
/// boundary**; EOF mid-frame is an `UnexpectedEof` error. Used by the
/// client, whose socket has no read timeout.
pub fn read_frame(r: &mut impl Read) -> io::Result<Option<Vec<u8>>> {
    let mut hdr = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut hdr[got..])? {
            0 if got == 0 => return Ok(None),
            0 => return Err(io::ErrorKind::UnexpectedEof.into()),
            n => got += n,
        }
    }
    let len = u32::from_le_bytes(hdr) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            WireError::FrameTooLarge { len },
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

/// Result of one [`FrameReader::poll`].
#[derive(Debug)]
pub enum FramePoll {
    /// A complete frame payload.
    Frame(Vec<u8>),
    /// The read timed out with the frame still incomplete; poll again.
    Pending,
    /// The peer closed cleanly at a frame boundary.
    Eof,
}

/// Incremental frame reader for sockets with a read timeout: partial
/// bytes survive across [`poll`](Self::poll) calls, so a server worker
/// can wake periodically (to observe shutdown) without ever losing
/// mid-frame state.
#[derive(Default)]
pub struct FrameReader {
    buf: Vec<u8>,
}

impl FrameReader {
    /// Fresh reader with no buffered bytes.
    pub fn new() -> Self {
        Self::default()
    }

    /// Read until a full frame, a timeout, or EOF.
    pub fn poll(&mut self, r: &mut impl Read) -> io::Result<FramePoll> {
        let mut scratch = [0u8; 64 * 1024];
        loop {
            if self.buf.len() >= 4 {
                let len = u32::from_le_bytes(self.buf[..4].try_into().unwrap()) as usize;
                if len > MAX_FRAME {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        WireError::FrameTooLarge { len },
                    ));
                }
                if self.buf.len() >= 4 + len {
                    let rest = self.buf.split_off(4 + len);
                    let mut frame = std::mem::replace(&mut self.buf, rest);
                    frame.drain(..4);
                    return Ok(FramePoll::Frame(frame));
                }
            }
            match r.read(&mut scratch) {
                Ok(0) => {
                    return if self.buf.is_empty() {
                        Ok(FramePoll::Eof)
                    } else {
                        Err(io::ErrorKind::UnexpectedEof.into())
                    };
                }
                Ok(n) => self.buf.extend_from_slice(&scratch[..n]),
                Err(e)
                    if e.kind() == io::ErrorKind::WouldBlock
                        || e.kind() == io::ErrorKind::TimedOut =>
                {
                    return Ok(FramePoll::Pending);
                }
                Err(e) => return Err(e),
            }
        }
    }
}

// ---------------------------------------------------------------------
// serving protocol
// ---------------------------------------------------------------------

/// How a client wants its stream scheduled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamMode {
    /// Pinned to one farm device; chunked chain dispatches, eligible for
    /// checkpoint/failover.
    Sticky,
    /// Fair-picked into cross-stream coalesced batches.
    Coalesced,
}

/// A client-to-server request frame.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeRequest {
    /// Identify the connection's tenant (first frame of a session).
    Hello {
        /// Tenant name for quotas and per-tenant accounting.
        tenant: String,
        /// The client's wire version. Version-1 peers have no such
        /// field on the wire (legacy tag 1); they decode as `1`.
        version: u32,
    },
    /// One-shot compound-node update.
    CnUpdate {
        /// Incoming state message.
        x: GaussMessage,
        /// Observation message.
        y: GaussMessage,
        /// Section state matrix.
        a: CMatrix,
    },
    /// One-shot compound-observation chain.
    Chain {
        /// Prior folded through the sections.
        prior: GaussMessage,
        /// (observation, state matrix) sections, in order.
        sections: Vec<(GaussMessage, CMatrix)>,
    },
    /// Open a recursive stream.
    OpenStream {
        /// Stream name (checkpoints are validated against it).
        name: String,
        /// Scheduling mode.
        mode: StreamMode,
        /// Initial recursive state.
        prior: GaussMessage,
        /// Fixed-point format every sample of this stream executes
        /// under (`None` = the server's configured width). Version-2
        /// information: a declared format rides a new tag; `None`
        /// emits the version-1 bytes, so old peers never see it.
        precision: Option<QFormat>,
    },
    /// Queue samples onto an open stream.
    Push {
        /// Stream id from `StreamOpened`.
        stream: u64,
        /// (observation, state matrix) samples, in order.
        samples: Vec<(GaussMessage, CMatrix)>,
    },
    /// Read a stream's progress and current state.
    Poll {
        /// Stream id.
        stream: u64,
    },
    /// Drain and close a stream.
    CloseStream {
        /// Stream id.
        stream: u64,
    },
    /// Snapshot a stream's committed state as a portable checkpoint.
    Checkpoint {
        /// Stream id.
        stream: u64,
    },
    /// Reopen a stream from a checkpoint (possibly on another server).
    Resume {
        /// Stream name; must match the checkpoint's.
        name: String,
        /// Scheduling mode for the resumed stream.
        mode: StreamMode,
        /// An [`encode_checkpoint`] image.
        checkpoint: Vec<u8>,
        /// Fixed-point format for the resumed stream (`None` = the
        /// server's configured width). Precision is a *session*
        /// property, not part of the checkpoint image — re-declare it
        /// on resume. Version-2 information under a new tag; `None`
        /// emits the version-1 bytes.
        precision: Option<QFormat>,
    },
    /// Fetch the server's SLO snapshot.
    Stats,
    /// Fetch the server's health snapshot: per-tenant SLO status,
    /// active alerts, per-device routing scores (version 2 only — a
    /// version-1 peer never emits or receives this tag).
    Health,
}

/// A server-to-client reply frame.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeReply {
    /// Session accepted.
    Welcome {
        /// Server wire version ([`WIRE_VERSION`]).
        version: u32,
    },
    /// One-shot result message.
    Output {
        /// The posterior message.
        msg: GaussMessage,
    },
    /// Stream admitted.
    StreamOpened {
        /// Stream id for subsequent frames.
        stream: u64,
        /// Farm device the stream is pinned to (sticky) or was opened
        /// on (coalesced streams may migrate every batch).
        device: u32,
    },
    /// Samples queued.
    Ack {
        /// Stream id.
        stream: u64,
        /// Samples accepted by this push.
        accepted: u32,
        /// Samples now pending on the stream.
        pending: u32,
    },
    /// Stream progress.
    StreamState {
        /// Stream id.
        stream: u64,
        /// Samples executed and committed so far.
        samples_done: u64,
        /// Samples queued but not yet executed.
        pending: u32,
        /// Current device pin.
        device: u32,
        /// Device failovers this stream has survived.
        failovers: u32,
        /// Committed recursive state.
        state: GaussMessage,
    },
    /// Stream drained and closed.
    Closed {
        /// Stream id.
        stream: u64,
        /// Total samples executed.
        samples_done: u64,
        /// Device failovers survived.
        failovers: u32,
        /// Final recursive state.
        state: GaussMessage,
    },
    /// A checkpoint image ([`decode_checkpoint`] reads it back).
    CheckpointData {
        /// The encoded checkpoint.
        bytes: Vec<u8>,
    },
    /// SLO snapshot.
    Stats(StatsSnapshot),
    /// Health snapshot (version 2 only; the reply to
    /// [`ServeRequest::Health`]).
    Health(HealthSnapshot),
    /// The admission window is full; retry after the hint.
    Busy {
        /// Suggested client backoff in milliseconds.
        retry_ms: u32,
    },
    /// The tenant's token bucket is empty; retry after the hint.
    QuotaExceeded {
        /// Suggested client backoff in milliseconds.
        retry_ms: u32,
    },
    /// Request failed.
    Error {
        /// Whether retrying (possibly after a failover) can succeed.
        retryable: bool,
        /// Human-readable reason.
        message: String,
    },
}

/// Per-tenant accounting row in a [`StatsSnapshot`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TenantSnapshot {
    /// Tenant name.
    pub tenant: String,
    /// Requests served (one-shots + pushes).
    pub requests: u64,
    /// Stream samples executed.
    pub samples: u64,
    /// Requests rejected by quota.
    pub rejected_quota: u64,
    /// Requests rejected by the admission window.
    pub rejected_busy: u64,
}

/// The `STATS` reply body: global SLO latency plus per-tenant
/// throughput, the row `BENCH_serving.json` is built from.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    /// End-to-end latency/completion distribution.
    pub latency: MetricsSnapshot,
    /// Work units (samples/one-shots) admitted in total.
    pub admitted: u64,
    /// Rejections due to a full admission window.
    pub rejected_busy: u64,
    /// Rejections due to tenant quotas.
    pub rejected_quota: u64,
    /// Stream failovers performed.
    pub failovers: u64,
    /// Per-tenant rows, sorted by tenant name.
    pub tenants: Vec<TenantSnapshot>,
    /// The unified telemetry registry (version 2; empty when talking
    /// to/behind a version-1 peer — an empty section encodes under the
    /// legacy tag, so version-1 byte streams are unchanged).
    pub telemetry: RegistrySnapshot,
}

fn enc_mode(e: &mut Enc, m: StreamMode) {
    e.u8(match m {
        StreamMode::Sticky => 0,
        StreamMode::Coalesced => 1,
    });
}

fn dec_mode(d: &mut Dec) -> Result<StreamMode, WireError> {
    match d.u8("StreamMode")? {
        0 => Ok(StreamMode::Sticky),
        1 => Ok(StreamMode::Coalesced),
        tag => Err(WireError::BadTag { what: "StreamMode", tag }),
    }
}

fn enc_sections(e: &mut Enc, sections: &[(GaussMessage, CMatrix)]) {
    e.u32(sections.len() as u32);
    for (y, a) in sections {
        e.msg(y);
        e.cmatrix(a);
    }
}

fn dec_sections(d: &mut Dec) -> Result<Vec<(GaussMessage, CMatrix)>, WireError> {
    let len = d.u32("sections")? as usize;
    (0..len)
        .map(|_| Ok((d.msg("sections")?, d.cmatrix("sections")?)))
        .collect()
}

fn enc_metrics(e: &mut Enc, m: &MetricsSnapshot) {
    e.u64(m.completed);
    e.u64(m.failed);
    e.u64(m.mean_ns);
    e.u64(m.p50_ns);
    e.u64(m.p95_ns);
    e.u64(m.p99_ns);
}

fn dec_metrics(d: &mut Dec) -> Result<MetricsSnapshot, WireError> {
    Ok(MetricsSnapshot {
        completed: d.u64("metrics")?,
        failed: d.u64("metrics")?,
        mean_ns: d.u64("metrics")?,
        p50_ns: d.u64("metrics")?,
        p95_ns: d.u64("metrics")?,
        p99_ns: d.u64("metrics")?,
    })
}

fn enc_registry(e: &mut Enc, r: &RegistrySnapshot) {
    e.u32(r.counters.len() as u32);
    for c in &r.counters {
        e.str(&c.name);
        e.u64(c.value);
    }
    e.u32(r.gauges.len() as u32);
    for g in &r.gauges {
        e.str(&g.name);
        e.u64(g.value);
    }
    e.u32(r.histograms.len() as u32);
    for h in &r.histograms {
        e.str(&h.name);
        e.u64(h.count);
        e.u64(h.mean_ns);
        e.u64(h.p50_ns);
        e.u64(h.p95_ns);
        e.u64(h.p99_ns);
    }
}

fn dec_registry(d: &mut Dec) -> Result<RegistrySnapshot, WireError> {
    let mut r = RegistrySnapshot::new();
    let nc = d.u32("telemetry")? as usize;
    for _ in 0..nc {
        let name = d.str("telemetry")?;
        let value = d.u64("telemetry")?;
        r.push_counter(&name, value);
    }
    let ng = d.u32("telemetry")? as usize;
    for _ in 0..ng {
        let name = d.str("telemetry")?;
        let value = d.u64("telemetry")?;
        r.push_gauge(&name, value);
    }
    let nh = d.u32("telemetry")? as usize;
    for _ in 0..nh {
        r.histograms.push(crate::obs::HistSummary {
            name: d.str("telemetry")?,
            count: d.u64("telemetry")?,
            mean_ns: d.u64("telemetry")?,
            p50_ns: d.u64("telemetry")?,
            p95_ns: d.u64("telemetry")?,
            p99_ns: d.u64("telemetry")?,
        });
    }
    Ok(r)
}

fn enc_slo_status(e: &mut Enc, s: &SloStatus) {
    e.str(&s.tenant);
    e.u64(s.p99_objective_ns);
    e.f64(s.error_budget);
    e.u64(s.p99_ns);
    e.f64(s.burn_short);
    e.f64(s.burn_long);
    e.u64(s.requests);
    e.u64(s.errors);
    e.u8(u8::from(s.healthy));
}

fn dec_slo_status(d: &mut Dec) -> Result<SloStatus, WireError> {
    Ok(SloStatus {
        tenant: d.str("SloStatus")?,
        p99_objective_ns: d.u64("SloStatus")?,
        error_budget: d.f64("SloStatus")?,
        p99_ns: d.u64("SloStatus")?,
        burn_short: d.f64("SloStatus")?,
        burn_long: d.f64("SloStatus")?,
        requests: d.u64("SloStatus")?,
        errors: d.u64("SloStatus")?,
        healthy: d.u8("SloStatus")? != 0,
    })
}

fn enc_alert(e: &mut Enc, a: &Alert) {
    e.u8(match a.kind {
        AlertKind::P99Regression => 1,
        AlertKind::AdmissionSaturation => 2,
        AlertKind::CacheHitCollapse => 3,
        AlertKind::DeviceOutlier => 4,
        AlertKind::SloBurn => 5,
    });
    e.u8(match a.state {
        AlertState::Firing => 0,
        AlertState::Resolved => 1,
    });
    e.u8(match a.severity {
        AlertSeverity::Warning => 0,
        AlertSeverity::Critical => 1,
    });
    e.str(&a.subject);
    e.f64(a.value);
    e.f64(a.threshold);
    e.u64(a.t_ns);
    e.str(&a.message);
}

fn dec_alert(d: &mut Dec) -> Result<Alert, WireError> {
    let kind = match d.u8("AlertKind")? {
        1 => AlertKind::P99Regression,
        2 => AlertKind::AdmissionSaturation,
        3 => AlertKind::CacheHitCollapse,
        4 => AlertKind::DeviceOutlier,
        5 => AlertKind::SloBurn,
        tag => return Err(WireError::BadTag { what: "AlertKind", tag }),
    };
    let state = match d.u8("AlertState")? {
        0 => AlertState::Firing,
        1 => AlertState::Resolved,
        tag => return Err(WireError::BadTag { what: "AlertState", tag }),
    };
    let severity = match d.u8("AlertSeverity")? {
        0 => AlertSeverity::Warning,
        1 => AlertSeverity::Critical,
        tag => return Err(WireError::BadTag { what: "AlertSeverity", tag }),
    };
    Ok(Alert {
        kind,
        state,
        severity,
        subject: d.str("Alert")?,
        value: d.f64("Alert")?,
        threshold: d.f64("Alert")?,
        t_ns: d.u64("Alert")?,
        message: d.str("Alert")?,
    })
}

fn enc_device_health(e: &mut Enc, dh: &DeviceHealth) {
    e.u32(dh.device);
    e.u8(u8::from(dh.live));
    e.u64(dh.requests);
    e.u64(dh.errors);
    e.u64(dh.ewma_ns);
    e.f64(dh.score);
}

fn dec_device_health(d: &mut Dec) -> Result<DeviceHealth, WireError> {
    Ok(DeviceHealth {
        device: d.u32("DeviceHealth")?,
        live: d.u8("DeviceHealth")? != 0,
        requests: d.u64("DeviceHealth")?,
        errors: d.u64("DeviceHealth")?,
        ewma_ns: d.u64("DeviceHealth")?,
        score: d.f64("DeviceHealth")?,
    })
}

fn enc_health(e: &mut Enc, h: &HealthSnapshot) {
    e.u8(u8::from(h.enabled));
    e.u64(h.snapshots);
    e.u64(h.alerts_total);
    e.u32(h.slos.len() as u32);
    for s in &h.slos {
        enc_slo_status(e, s);
    }
    e.u32(h.alerts.len() as u32);
    for a in &h.alerts {
        enc_alert(e, a);
    }
    e.u32(h.devices.len() as u32);
    for dh in &h.devices {
        enc_device_health(e, dh);
    }
}

fn dec_health(d: &mut Dec) -> Result<HealthSnapshot, WireError> {
    let enabled = d.u8("Health")? != 0;
    let snapshots = d.u64("Health")?;
    let alerts_total = d.u64("Health")?;
    let ns = d.u32("Health")? as usize;
    let slos = (0..ns).map(|_| dec_slo_status(d)).collect::<Result<_, _>>()?;
    let na = d.u32("Health")? as usize;
    let alerts = (0..na).map(|_| dec_alert(d)).collect::<Result<_, _>>()?;
    let nd = d.u32("Health")? as usize;
    let devices = (0..nd).map(|_| dec_device_health(d)).collect::<Result<_, _>>()?;
    Ok(HealthSnapshot { enabled, snapshots, alerts_total, slos, alerts, devices })
}

fn enc_qformat(e: &mut Enc, f: QFormat) {
    // widths are ≤ 32 bits by QFormat's invariant, so u8 is lossless
    e.u8(f.int_bits as u8);
    e.u8(f.frac_bits as u8);
}

fn dec_qformat(d: &mut Dec) -> Result<QFormat, WireError> {
    let int_bits = d.u8("QFormat")? as u32;
    let frac_bits = d.u8("QFormat")? as u32;
    // QFormat::new asserts the 32-bit bound; decoding must stay total,
    // so reject oversized widths as a typed error instead
    let width = 1 + int_bits + frac_bits;
    if width > 32 {
        return Err(WireError::BadTag {
            what: "QFormat width",
            tag: width.min(u8::MAX as u32) as u8,
        });
    }
    Ok(QFormat::new(int_bits, frac_bits))
}

/// Encode a [`ServeRequest`] payload.
pub fn encode_request(req: &ServeRequest) -> Vec<u8> {
    let mut e = Enc::new();
    match req {
        ServeRequest::Hello { tenant, version } => {
            if *version == 1 {
                // exact version-1 bytes: a legacy server keeps working
                e.u8(1);
                e.str(tenant);
            } else {
                e.u8(12);
                e.str(tenant);
                e.u32(*version);
            }
        }
        ServeRequest::CnUpdate { x, y, a } => {
            e.u8(2);
            e.msg(x);
            e.msg(y);
            e.cmatrix(a);
        }
        ServeRequest::Chain { prior, sections } => {
            e.u8(3);
            e.msg(prior);
            enc_sections(&mut e, sections);
        }
        ServeRequest::OpenStream { name, mode, prior, precision } => {
            // exact version-1 bytes whenever no format is declared
            e.u8(if precision.is_some() { 13 } else { 4 });
            e.str(name);
            enc_mode(&mut e, *mode);
            e.msg(prior);
            if let Some(f) = precision {
                enc_qformat(&mut e, *f);
            }
        }
        ServeRequest::Push { stream, samples } => {
            e.u8(5);
            e.u64(*stream);
            enc_sections(&mut e, samples);
        }
        ServeRequest::Poll { stream } => {
            e.u8(6);
            e.u64(*stream);
        }
        ServeRequest::CloseStream { stream } => {
            e.u8(7);
            e.u64(*stream);
        }
        ServeRequest::Checkpoint { stream } => {
            e.u8(8);
            e.u64(*stream);
        }
        ServeRequest::Resume { name, mode, checkpoint, precision } => {
            e.u8(if precision.is_some() { 14 } else { 9 });
            e.str(name);
            enc_mode(&mut e, *mode);
            e.bytes(checkpoint);
            if let Some(f) = precision {
                enc_qformat(&mut e, *f);
            }
        }
        ServeRequest::Stats => e.u8(10),
        ServeRequest::Health => e.u8(11),
    }
    e.into_bytes()
}

/// Decode a [`ServeRequest`] payload (total: typed error, never panics).
pub fn decode_request(buf: &[u8]) -> Result<ServeRequest, WireError> {
    let mut d = Dec::new(buf);
    let req = match d.u8("ServeRequest")? {
        1 => ServeRequest::Hello { tenant: d.str("Hello")?, version: 1 },
        2 => ServeRequest::CnUpdate {
            x: d.msg("CnUpdate")?,
            y: d.msg("CnUpdate")?,
            a: d.cmatrix("CnUpdate")?,
        },
        3 => ServeRequest::Chain {
            prior: d.msg("Chain")?,
            sections: dec_sections(&mut d)?,
        },
        4 => ServeRequest::OpenStream {
            name: d.str("OpenStream")?,
            mode: dec_mode(&mut d)?,
            prior: d.msg("OpenStream")?,
            precision: None,
        },
        5 => ServeRequest::Push {
            stream: d.u64("Push")?,
            samples: dec_sections(&mut d)?,
        },
        6 => ServeRequest::Poll { stream: d.u64("Poll")? },
        7 => ServeRequest::CloseStream { stream: d.u64("CloseStream")? },
        8 => ServeRequest::Checkpoint { stream: d.u64("Checkpoint")? },
        9 => ServeRequest::Resume {
            name: d.str("Resume")?,
            mode: dec_mode(&mut d)?,
            checkpoint: d.bytes("Resume")?,
            precision: None,
        },
        10 => ServeRequest::Stats,
        11 => ServeRequest::Health,
        12 => ServeRequest::Hello { tenant: d.str("Hello")?, version: d.u32("Hello")? },
        13 => ServeRequest::OpenStream {
            name: d.str("OpenStream")?,
            mode: dec_mode(&mut d)?,
            prior: d.msg("OpenStream")?,
            precision: Some(dec_qformat(&mut d)?),
        },
        14 => ServeRequest::Resume {
            name: d.str("Resume")?,
            mode: dec_mode(&mut d)?,
            checkpoint: d.bytes("Resume")?,
            precision: Some(dec_qformat(&mut d)?),
        },
        tag => return Err(WireError::BadTag { what: "ServeRequest", tag }),
    };
    d.finish()?;
    Ok(req)
}

/// Marker byte opening a trace-context envelope. Request tags start at
/// 1, so a leading 0 is unambiguous and a bare request payload is
/// never mistaken for an envelope.
const TRACE_MARKER: u8 = 0;

/// Encode a [`ServeRequest`], optionally wrapped in a trace envelope
/// (`[0][trace_id u64][span_id u64][request payload]`). With
/// `ctx = None` the bytes are identical to [`encode_request`] — the
/// version-1 stream.
pub fn encode_request_traced(req: &ServeRequest, ctx: Option<&TraceContext>) -> Vec<u8> {
    match ctx {
        None => encode_request(req),
        Some(ctx) => {
            let mut e = Enc::new();
            e.u8(TRACE_MARKER);
            e.u64(ctx.trace_id);
            e.u64(ctx.span_id);
            let mut buf = e.into_bytes();
            buf.extend_from_slice(&encode_request(req));
            buf
        }
    }
}

/// Decode a request payload that may carry a trace envelope. Bare
/// payloads (version-1 peers, untraced clients) return `None` for the
/// context. Total like every other decoder: strict prefixes of either
/// form error, trailing bytes are rejected.
pub fn decode_request_traced(
    buf: &[u8],
) -> Result<(ServeRequest, Option<TraceContext>), WireError> {
    if buf.first() != Some(&TRACE_MARKER) {
        return Ok((decode_request(buf)?, None));
    }
    let mut d = Dec::new(buf);
    d.u8("trace envelope")?;
    let trace_id = d.u64("trace envelope")?;
    let span_id = d.u64("trace envelope")?;
    let req = decode_request(&buf[d.pos..])?;
    Ok((req, Some(TraceContext { trace_id, span_id })))
}

/// Encode a [`ServeReply`] payload.
pub fn encode_reply(reply: &ServeReply) -> Vec<u8> {
    let mut e = Enc::new();
    match reply {
        ServeReply::Welcome { version } => {
            e.u8(1);
            e.u32(*version);
        }
        ServeReply::Output { msg } => {
            e.u8(2);
            e.msg(msg);
        }
        ServeReply::StreamOpened { stream, device } => {
            e.u8(3);
            e.u64(*stream);
            e.u32(*device);
        }
        ServeReply::Ack { stream, accepted, pending } => {
            e.u8(4);
            e.u64(*stream);
            e.u32(*accepted);
            e.u32(*pending);
        }
        ServeReply::StreamState { stream, samples_done, pending, device, failovers, state } => {
            e.u8(5);
            e.u64(*stream);
            e.u64(*samples_done);
            e.u32(*pending);
            e.u32(*device);
            e.u32(*failovers);
            e.msg(state);
        }
        ServeReply::Closed { stream, samples_done, failovers, state } => {
            e.u8(6);
            e.u64(*stream);
            e.u64(*samples_done);
            e.u32(*failovers);
            e.msg(state);
        }
        ServeReply::CheckpointData { bytes } => {
            e.u8(7);
            e.bytes(bytes);
        }
        ServeReply::Stats(s) => {
            // empty telemetry → exact version-1 bytes under the legacy tag
            e.u8(if s.telemetry.is_empty() { 8 } else { 12 });
            enc_metrics(&mut e, &s.latency);
            e.u64(s.admitted);
            e.u64(s.rejected_busy);
            e.u64(s.rejected_quota);
            e.u64(s.failovers);
            e.u32(s.tenants.len() as u32);
            for t in &s.tenants {
                e.str(&t.tenant);
                e.u64(t.requests);
                e.u64(t.samples);
                e.u64(t.rejected_quota);
                e.u64(t.rejected_busy);
            }
            if !s.telemetry.is_empty() {
                enc_registry(&mut e, &s.telemetry);
            }
        }
        ServeReply::Health(h) => {
            e.u8(13);
            enc_health(&mut e, h);
        }
        ServeReply::Busy { retry_ms } => {
            e.u8(9);
            e.u32(*retry_ms);
        }
        ServeReply::QuotaExceeded { retry_ms } => {
            e.u8(10);
            e.u32(*retry_ms);
        }
        ServeReply::Error { retryable, message } => {
            e.u8(11);
            e.u8(u8::from(*retryable));
            e.str(message);
        }
    }
    e.into_bytes()
}

/// Decode a [`ServeReply`] payload (total: typed error, never panics).
pub fn decode_reply(buf: &[u8]) -> Result<ServeReply, WireError> {
    let mut d = Dec::new(buf);
    let reply = match d.u8("ServeReply")? {
        1 => ServeReply::Welcome { version: d.u32("Welcome")? },
        2 => ServeReply::Output { msg: d.msg("Output")? },
        3 => ServeReply::StreamOpened {
            stream: d.u64("StreamOpened")?,
            device: d.u32("StreamOpened")?,
        },
        4 => ServeReply::Ack {
            stream: d.u64("Ack")?,
            accepted: d.u32("Ack")?,
            pending: d.u32("Ack")?,
        },
        5 => ServeReply::StreamState {
            stream: d.u64("StreamState")?,
            samples_done: d.u64("StreamState")?,
            pending: d.u32("StreamState")?,
            device: d.u32("StreamState")?,
            failovers: d.u32("StreamState")?,
            state: d.msg("StreamState")?,
        },
        6 => ServeReply::Closed {
            stream: d.u64("Closed")?,
            samples_done: d.u64("Closed")?,
            failovers: d.u32("Closed")?,
            state: d.msg("Closed")?,
        },
        7 => ServeReply::CheckpointData { bytes: d.bytes("CheckpointData")? },
        tag @ (8 | 12) => {
            let latency = dec_metrics(&mut d)?;
            let admitted = d.u64("Stats")?;
            let rejected_busy = d.u64("Stats")?;
            let rejected_quota = d.u64("Stats")?;
            let failovers = d.u64("Stats")?;
            let n = d.u32("Stats")? as usize;
            let tenants = (0..n)
                .map(|_| {
                    Ok(TenantSnapshot {
                        tenant: d.str("Stats")?,
                        requests: d.u64("Stats")?,
                        samples: d.u64("Stats")?,
                        rejected_quota: d.u64("Stats")?,
                        rejected_busy: d.u64("Stats")?,
                    })
                })
                .collect::<Result<_, WireError>>()?;
            let telemetry =
                if tag == 12 { dec_registry(&mut d)? } else { RegistrySnapshot::default() };
            ServeReply::Stats(StatsSnapshot {
                latency,
                admitted,
                rejected_busy,
                rejected_quota,
                failovers,
                tenants,
                telemetry,
            })
        }
        9 => ServeReply::Busy { retry_ms: d.u32("Busy")? },
        10 => ServeReply::QuotaExceeded { retry_ms: d.u32("QuotaExceeded")? },
        13 => ServeReply::Health(dec_health(&mut d)?),
        11 => ServeReply::Error {
            retryable: d.u8("Error")? != 0,
            message: d.str("Error")?,
        },
        tag => return Err(WireError::BadTag { what: "ServeReply", tag }),
    };
    d.finish()?;
    Ok(reply)
}

// ---------------------------------------------------------------------
// checkpoint image
// ---------------------------------------------------------------------

const CKPT_MAGIC: [u8; 4] = *b"FGCK";
const CKPT_VERSION: u8 = 1;

/// Encode a [`StreamCheckpoint`] as a portable image (`FGCK` magic,
/// version byte, then the checkpoint fields). Floats travel as raw
/// bits, so restoring on another process resumes bitwise-identically.
pub fn encode_checkpoint(ckpt: &StreamCheckpoint) -> Vec<u8> {
    let mut e = Enc::new();
    e.buf.extend_from_slice(&CKPT_MAGIC);
    e.u8(CKPT_VERSION);
    e.str(&ckpt.stream_name);
    e.u64(ckpt.samples);
    e.msg(&ckpt.state);
    e.u32(ckpt.boundaries.len() as u32);
    for b in &ckpt.boundaries {
        e.msg(b);
    }
    e.into_bytes()
}

/// Decode a checkpoint image (total: typed error, never panics).
pub fn decode_checkpoint(buf: &[u8]) -> Result<StreamCheckpoint, WireError> {
    let mut d = Dec::new(buf);
    let magic = d.take(4, "checkpoint magic")?;
    if magic != CKPT_MAGIC {
        return Err(WireError::BadTag { what: "checkpoint magic", tag: magic[0] });
    }
    let version = d.u8("checkpoint version")?;
    if version != CKPT_VERSION {
        return Err(WireError::BadTag { what: "checkpoint version", tag: version });
    }
    let stream_name = d.str("checkpoint")?;
    let samples = d.u64("checkpoint")?;
    let state = d.msg("checkpoint")?;
    let n = d.u32("checkpoint")? as usize;
    let boundaries = (0..n).map(|_| d.msg("checkpoint")).collect::<Result<_, _>>()?;
    d.finish()?;
    Ok(StreamCheckpoint { stream_name, samples, state, boundaries })
}

// ---------------------------------------------------------------------
// Fig. 5 device protocol
// ---------------------------------------------------------------------

fn enc_run_stats(e: &mut Enc, s: &RunStats) {
    e.u64(s.cycles);
    e.u64(s.instructions);
    e.u64(s.datapath_cycles);
    e.u64(s.sections);
}

fn dec_run_stats(d: &mut Dec) -> Result<RunStats, WireError> {
    Ok(RunStats {
        cycles: d.u64("RunStats")?,
        instructions: d.u64("RunStats")?,
        datapath_cycles: d.u64("RunStats")?,
        sections: d.u64("RunStats")?,
    })
}

/// Encode a Fig. 5 [`Command`] payload.
pub fn encode_command(cmd: &Command) -> Vec<u8> {
    let mut e = Enc::new();
    match cmd {
        Command::LoadProgram(image) => {
            e.u8(1);
            e.bytes(&image.bytes);
        }
        Command::StartProgram { id } => {
            e.u8(2);
            e.u8(*id);
        }
        Command::WriteMessage { slot, msg } => {
            e.u8(3);
            e.u8(*slot);
            e.msg(msg);
        }
        Command::WriteState { slot, a } => {
            e.u8(4);
            e.u8(*slot);
            e.cmatrix(a);
        }
        Command::ReadMessage { slot } => {
            e.u8(5);
            e.u8(*slot);
        }
        Command::Status => e.u8(6),
    }
    e.into_bytes()
}

/// Decode a Fig. 5 [`Command`] payload (total: typed error, never
/// panics).
pub fn decode_command(buf: &[u8]) -> Result<Command, WireError> {
    let mut d = Dec::new(buf);
    let cmd = match d.u8("Command")? {
        1 => Command::LoadProgram(MemoryImage { bytes: d.bytes("LoadProgram")? }),
        2 => Command::StartProgram { id: d.u8("StartProgram")? },
        3 => Command::WriteMessage { slot: d.u8("WriteMessage")?, msg: d.msg("WriteMessage")? },
        4 => Command::WriteState { slot: d.u8("WriteState")?, a: d.cmatrix("WriteState")? },
        5 => Command::ReadMessage { slot: d.u8("ReadMessage")? },
        6 => Command::Status,
        tag => return Err(WireError::BadTag { what: "Command", tag }),
    };
    d.finish()?;
    Ok(cmd)
}

/// Encode a Fig. 5 [`Reply`] payload.
pub fn encode_device_reply(reply: &Reply) -> Vec<u8> {
    let mut e = Enc::new();
    match reply {
        Reply::Ok => e.u8(1),
        Reply::Loaded { instrs } => {
            e.u8(2);
            e.u64(*instrs as u64);
        }
        Reply::Finished(stats) => {
            e.u8(3);
            enc_run_stats(&mut e, stats);
        }
        Reply::Message(msg) => {
            e.u8(4);
            e.msg(msg);
        }
        Reply::Status { state, cycles } => {
            e.u8(5);
            e.u8(match state {
                FsmState::Idle => 0,
                FsmState::Running => 1,
                FsmState::Done => 2,
            });
            e.u64(*cycles);
        }
        Reply::Error(msg) => {
            e.u8(6);
            e.str(msg);
        }
    }
    e.into_bytes()
}

/// Decode a Fig. 5 [`Reply`] payload (total: typed error, never panics).
pub fn decode_device_reply(buf: &[u8]) -> Result<Reply, WireError> {
    let mut d = Dec::new(buf);
    let reply = match d.u8("Reply")? {
        1 => Reply::Ok,
        2 => Reply::Loaded { instrs: d.u64("Loaded")? as usize },
        3 => Reply::Finished(dec_run_stats(&mut d)?),
        4 => Reply::Message(d.msg("Message")?),
        5 => Reply::Status {
            state: match d.u8("Status")? {
                0 => FsmState::Idle,
                1 => FsmState::Running,
                2 => FsmState::Done,
                tag => return Err(WireError::BadTag { what: "FsmState", tag }),
            },
            cycles: d.u64("Status")?,
        },
        6 => Reply::Error(d.str("Error")?),
        tag => return Err(WireError::BadTag { what: "Reply", tag }),
    };
    d.finish()?;
    Ok(reply)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_round_trip_bit_exact() {
        let mut e = Enc::new();
        e.f64(0.1 + 0.2); // not representable exactly: bits must survive
        e.f64(-0.0);
        e.f64(f64::NAN);
        e.str("tenant-α");
        e.u64(u64::MAX);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert_eq!(d.f64("t").unwrap().to_bits(), (0.1 + 0.2_f64).to_bits());
        assert_eq!(d.f64("t").unwrap().to_bits(), (-0.0_f64).to_bits());
        assert!(d.f64("t").unwrap().is_nan());
        assert_eq!(d.str("t").unwrap(), "tenant-α");
        assert_eq!(d.u64("t").unwrap(), u64::MAX);
        d.finish().unwrap();
    }

    #[test]
    fn frame_reader_reassembles_split_frames() {
        let mut payloads = Vec::new();
        write_frame(&mut payloads, b"hello").unwrap();
        write_frame(&mut payloads, b"").unwrap();
        write_frame(&mut payloads, &[7u8; 300]).unwrap();
        // feed the byte stream one byte at a time through a reader that
        // "times out" between bytes: frames must reassemble intact
        struct OneByte<'a>(&'a [u8], usize, bool);
        impl Read for OneByte<'_> {
            fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
                if self.2 {
                    self.2 = false;
                    return Err(io::ErrorKind::WouldBlock.into());
                }
                self.2 = true;
                if self.1 >= self.0.len() {
                    return Ok(0);
                }
                buf[0] = self.0[self.1];
                self.1 += 1;
                Ok(1)
            }
        }
        let mut src = OneByte(&payloads, 0, false);
        let mut reader = FrameReader::new();
        let mut frames = Vec::new();
        loop {
            match reader.poll(&mut src).unwrap() {
                FramePoll::Frame(f) => frames.push(f),
                FramePoll::Pending => continue,
                FramePoll::Eof => break,
            }
        }
        assert_eq!(frames.len(), 3);
        assert_eq!(frames[0], b"hello");
        assert!(frames[1].is_empty());
        assert_eq!(frames[2], vec![7u8; 300]);
    }

    #[test]
    fn oversized_frame_is_rejected_by_reader_and_writer() {
        let mut sink = Vec::new();
        let big = vec![0u8; MAX_FRAME + 1];
        assert!(write_frame(&mut sink, &big).is_err());
        // a corrupt length prefix must not trigger a giant allocation
        let bad = ((MAX_FRAME + 1) as u32).to_le_bytes();
        assert!(read_frame(&mut &bad[..]).is_err());
        let mut reader = FrameReader::new();
        assert!(reader.poll(&mut &bad[..]).is_err());
    }

    #[test]
    fn trace_envelope_wraps_and_unwraps() {
        let req = ServeRequest::Stats;
        let ctx = TraceContext { trace_id: 0xABCD, span_id: 0x1234 };
        let plain = encode_request_traced(&req, None);
        assert_eq!(plain, encode_request(&req), "no context ⇒ the version-1 byte stream");
        let wrapped = encode_request_traced(&req, Some(&ctx));
        assert_eq!(wrapped.len(), plain.len() + 17, "marker + two u64 ids");
        let (back, got) = decode_request_traced(&wrapped).unwrap();
        assert_eq!(back, req);
        assert_eq!(got, Some(ctx));
        let (bare, none) = decode_request_traced(&plain).unwrap();
        assert_eq!(bare, req);
        assert!(none.is_none());
        for cut in 0..wrapped.len() {
            assert!(decode_request_traced(&wrapped[..cut]).is_err(), "prefix {cut} must error");
        }
    }

    #[test]
    fn hello_version_tags_interoperate() {
        // a version-1 Hello is the legacy tag and round-trips as version 1
        let v1 = ServeRequest::Hello { tenant: "t".into(), version: 1 };
        let bytes = encode_request(&v1);
        assert_eq!(bytes[0], 1, "version 1 must emit the legacy tag");
        assert_eq!(decode_request(&bytes).unwrap(), v1);
        // the current version uses the new tag and carries the number
        let v2 = ServeRequest::Hello { tenant: "t".into(), version: WIRE_VERSION };
        let bytes2 = encode_request(&v2);
        assert_eq!(bytes2[0], 12);
        assert_eq!(decode_request(&bytes2).unwrap(), v2);
    }

    #[test]
    fn precision_tags_interoperate_with_version_1_peers() {
        let prior = GaussMessage {
            mean: vec![c64::new(0.1 + 0.2, -0.0)],
            cov: CMatrix::identity(1),
        };
        // no declared precision ⇒ byte-identical to the version-1 frame
        let open = ServeRequest::OpenStream {
            name: "s".into(),
            mode: StreamMode::Sticky,
            prior: prior.clone(),
            precision: None,
        };
        let bytes = encode_request(&open);
        assert_eq!(bytes[0], 4, "None must emit the legacy tag");
        assert_eq!(decode_request(&bytes).unwrap(), open);

        // a declared format rides tag 13 with two trailing format bytes
        let open_q = ServeRequest::OpenStream {
            name: "s".into(),
            mode: StreamMode::Sticky,
            prior: prior.clone(),
            precision: Some(QFormat::new(8, 20)),
        };
        let bytes_q = encode_request(&open_q);
        assert_eq!(bytes_q[0], 13);
        assert_eq!(bytes_q.len(), bytes.len() + 2, "format is exactly two bytes");
        assert_eq!(decode_request(&bytes_q).unwrap(), open_q);

        // same pairing for Resume: legacy tag 9 vs versioned tag 14
        let res = ServeRequest::Resume {
            name: "s".into(),
            mode: StreamMode::Coalesced,
            checkpoint: vec![1, 2, 3],
            precision: None,
        };
        let rb = encode_request(&res);
        assert_eq!(rb[0], 9);
        assert_eq!(decode_request(&rb).unwrap(), res);
        let res_q = ServeRequest::Resume {
            name: "s".into(),
            mode: StreamMode::Coalesced,
            checkpoint: vec![1, 2, 3],
            precision: Some(QFormat::q5_10()),
        };
        let rqb = encode_request(&res_q);
        assert_eq!(rqb[0], 14);
        assert_eq!(rqb.len(), rb.len() + 2);
        assert_eq!(decode_request(&rqb).unwrap(), res_q);
    }

    #[test]
    fn oversized_qformat_width_is_a_decode_error_not_a_panic() {
        // hand-build a tag-13 frame whose format bytes claim a 1+30+30
        // bit word: `QFormat::new` would panic, so the decoder must
        // reject the bytes before constructing the format
        let prior = GaussMessage { mean: vec![c64::new(0.0, 0.0)], cov: CMatrix::identity(1) };
        let good = encode_request(&ServeRequest::OpenStream {
            name: "s".into(),
            mode: StreamMode::Sticky,
            prior,
            precision: Some(QFormat::q5_10()),
        });
        let mut bad = good.clone();
        let n = bad.len();
        bad[n - 2] = 30;
        bad[n - 1] = 30;
        match decode_request(&bad) {
            Err(WireError::BadTag { what, .. }) => assert_eq!(what, "QFormat width"),
            other => panic!("expected a typed width error, got {other:?}"),
        }
    }

    #[test]
    fn stats_telemetry_section_is_tag_gated() {
        let mut s = StatsSnapshot::default();
        let legacy = encode_reply(&ServeReply::Stats(s.clone()));
        assert_eq!(legacy[0], 8, "empty telemetry must emit the version-1 tag");
        s.telemetry.push_counter("engine.cache_hit", 3);
        let extended = encode_reply(&ServeReply::Stats(s.clone()));
        assert_eq!(extended[0], 12);
        match decode_reply(&extended).unwrap() {
            ServeReply::Stats(back) => {
                assert_eq!(back.telemetry.counter("engine.cache_hit"), Some(3));
                assert_eq!(back, s);
            }
            other => panic!("expected Stats, got {other:?}"),
        }
    }

    #[test]
    fn stats_gauge_section_round_trips_and_stays_v2_gated() {
        // a snapshot with only gauges is non-empty telemetry → tag 12
        let mut s = StatsSnapshot::default();
        s.telemetry.push_gauge("serve.inflight", 4);
        s.telemetry.push_gauge("serve.inflight_capacity", 16);
        let bytes = encode_reply(&ServeReply::Stats(s.clone()));
        assert_eq!(bytes[0], 12, "gauges are version-2 information");
        match decode_reply(&bytes).unwrap() {
            ServeReply::Stats(back) => {
                assert_eq!(back.telemetry.gauge("serve.inflight"), Some(4));
                assert_eq!(back, s);
            }
            other => panic!("expected Stats, got {other:?}"),
        }
    }

    fn sample_health() -> HealthSnapshot {
        HealthSnapshot {
            enabled: true,
            snapshots: 42,
            alerts_total: 2,
            slos: vec![SloStatus {
                tenant: "acme".into(),
                p99_objective_ns: 5_000_000,
                error_budget: 0.01,
                p99_ns: 98_303,
                burn_short: 0.5,
                burn_long: 0.1 + 0.2, // non-representable: bits must survive
                requests: 1_000,
                errors: 3,
                healthy: true,
            }],
            alerts: vec![Alert {
                kind: AlertKind::DeviceOutlier,
                state: AlertState::Firing,
                severity: AlertSeverity::Warning,
                subject: "farm.device2".into(),
                value: 9.75,
                threshold: 8.0,
                t_ns: 123_456_789,
                message: "ewma 9.8× live median".into(),
            }],
            devices: vec![
                DeviceHealth {
                    device: 0,
                    live: true,
                    requests: 500,
                    errors: 0,
                    ewma_ns: 40_000,
                    score: 1.0,
                },
                DeviceHealth {
                    device: 2,
                    live: false,
                    requests: 120,
                    errors: 7,
                    ewma_ns: 390_000,
                    score: 0.0,
                },
            ],
        }
    }

    #[test]
    fn health_request_and_reply_round_trip() {
        let req = ServeRequest::Health;
        let rb = encode_request(&req);
        assert_eq!(rb, vec![11], "Health is a bare version-2 tag");
        assert_eq!(decode_request(&rb).unwrap(), req);

        let reply = ServeReply::Health(sample_health());
        let bytes = encode_reply(&reply);
        assert_eq!(bytes[0], 13);
        assert_eq!(decode_reply(&bytes).unwrap(), reply);
        // f64 fields are bit-exact through the codec
        match decode_reply(&bytes).unwrap() {
            ServeReply::Health(h) => {
                assert_eq!(h.slos[0].burn_long.to_bits(), (0.1 + 0.2_f64).to_bits());
            }
            other => panic!("expected Health, got {other:?}"),
        }
        // a disabled-layer reply also round-trips
        let off = ServeReply::Health(HealthSnapshot::disabled(vec![]));
        assert_eq!(decode_reply(&encode_reply(&off)).unwrap(), off);
    }

    #[test]
    fn health_reply_rejects_prefixes_trailing_and_bad_tags() {
        let bytes = encode_reply(&ServeReply::Health(sample_health()));
        for cut in 0..bytes.len() {
            assert!(decode_reply(&bytes[..cut]).is_err(), "prefix {cut} must error");
        }
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert_eq!(decode_reply(&trailing), Err(WireError::Trailing { extra: 1 }));
        // corrupt the alert-kind byte (first byte after the u32 alert
        // count, whose offset we find by re-encoding the prefix)
        let mut e = Enc::new();
        e.u8(13);
        let h = sample_health();
        e.u8(1);
        e.u64(h.snapshots);
        e.u64(h.alerts_total);
        e.u32(1);
        enc_slo_status(&mut e, &h.slos[0]);
        e.u32(1);
        let kind_at = e.into_bytes().len();
        let mut bad = bytes;
        bad[kind_at] = 99;
        assert!(matches!(
            decode_reply(&bad),
            Err(WireError::BadTag { what: "AlertKind", tag: 99 })
        ));
    }

    #[test]
    fn clean_eof_vs_mid_frame_eof() {
        // clean EOF at a boundary
        assert!(read_frame(&mut &[][..]).unwrap().is_none());
        // EOF inside a frame is an error
        let mut partial = Vec::new();
        write_frame(&mut partial, b"abcdef").unwrap();
        partial.truncate(partial.len() - 2);
        assert!(read_frame(&mut &partial[..]).is_err());
    }
}
