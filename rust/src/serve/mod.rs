//! S10 — The network serving tier: an admission-controlled front door
//! over the device farm, with stream checkpoint/failover.
//!
//! §III of the paper positions the FGP as a co-processor "attached to an
//! existing system"; [`crate::coordinator`] built that system in-process.
//! This module puts it behind a socket, because the moment the farm is
//! shared by clients that don't share an address space, three serving
//! problems appear that the in-process tier never had to answer:
//!
//! 1. **Admission** — a socket accepts bytes faster than devices retire
//!    samples. [`admission`] bounds the gap: per-tenant token-bucket
//!    quotas (`QuotaExceeded`), a global bounded in-flight window
//!    (`Busy` + retry hint, never an unbounded queue), and a fairness
//!    rotor so admitted work drains tenant-fairly into the existing
//!    [`StreamCoalescer`](crate::coordinator::StreamCoalescer) and
//!    sticky-chain paths.
//! 2. **Failover** — a stream outlives any single device. The committed
//!    recursive state ([`CnStream`](crate::coordinator::CnStream)) is
//!    the *whole* per-sample truth of a Gaussian message-passing stream,
//!    so a checkpoint is one message + a cursor, and the chunk-invariance
//!    property (pinned by `tests/integration_streaming.rs`) makes a
//!    resume on any other member **bitwise identical** — not
//!    approximately recovered. [`wire`] gives checkpoints a stable
//!    `FGCK` image so they survive the network.
//! 3. **Observability** — an SLO is a wire artifact here: `Stats`
//!    returns p50/p95/p99 latency, per-tenant throughput assembled
//!    from [`crate::coordinator::Metrics`], and (wire version 2) the
//!    unified [`crate::obs`] registry snapshot; the serving bench
//!    commits the same snapshot to `BENCH_serving.json`. Requests may
//!    carry a [`TraceContext`](crate::obs::TraceContext) envelope, so
//!    one client call yields one correlated span tree from the socket
//!    down to the device's cycle counters (`examples/trace_rls.rs`).
//!    On top of the raw telemetry sits the operational-intelligence
//!    layer ([`crate::obs::health`]): with
//!    [`ServeConfig::health`](server::ServeConfig) enabled, a
//!    background watcher evaluates per-tenant SLO burn rates and
//!    anomaly detectors over the unified registry, the wire grows a
//!    v2-only `Health` request, and sticky routing drains streams off
//!    degraded-but-alive devices (`examples/monitor_farm.rs`).
//!
//! Layering: `serve` sits strictly **above** the coordinator — it owns
//! sockets, framing, tenancy, and admission, and delegates every
//! numeric decision downward. Nothing below this module knows a TCP
//! stream exists. The runtime is std-only (`TcpListener` + worker
//! threads + channels); the protocol is the length-framed, bit-exact
//! little-endian codec of [`wire`] (f64 travels as raw bits, never
//! through text), so a reply is byte-reproducible across hosts.
//!
//! ```text
//! client ──frame──▶ worker ──gate──▶ registry ──rotor──▶ engine room ──chunk──▶ FgpFarm
//!   ▲                 │ quota/window    │ CnStream          │ chain/coalesce      │ devices
//!   └───── reply ─────┘                 └── checkpoint ─────┴── failover ◀────────┘
//! ```

pub mod admission;
pub mod client;
pub mod registry;
pub mod server;
pub mod wire;

pub use admission::{AdmissionController, FairRotor, QuotaPolicy, TenantQuotas, TokenBucket};
pub use client::{ServeClient, StreamClosed, StreamStatus};
pub use registry::{SessionRegistry, StreamEntry, TenantLedger};
pub use server::{FgpServe, ServeConfig};
pub use wire::{
    decode_checkpoint, decode_reply, decode_request, decode_request_traced, encode_checkpoint,
    encode_reply, encode_request, encode_request_traced, read_frame, write_frame, FramePoll,
    FrameReader, ServeReply, ServeRequest, StatsSnapshot, StreamMode, TenantSnapshot, WireError,
    MAX_FRAME, WIRE_VERSION,
};
