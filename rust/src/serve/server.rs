//! The serving front door: TCP listener, worker pool, and engine room.
//!
//! std-only by design (no tokio in the vendored crate set): a blocking
//! [`TcpListener`] accept loop hands connections to a fixed worker pool
//! over an mpsc channel; each worker speaks the framed protocol of
//! [`super::wire`] with a read-timeout poll loop so it can observe
//! shutdown between frames. Compute never happens on connection
//! threads — handlers only gate (quota → admission → capacity), queue,
//! and reply, while a single **engine room** thread drains admitted
//! samples into the [`FgpFarm`]:
//!
//! * **sticky** streams advance one chunk per round, each chunk a
//!   [`WorkloadRequest::chain`] dispatched to the stream's pinned
//!   device, all rounds' dispatches in flight concurrently; a retryable
//!   device failure re-pins the stream ([`FarmError::is_retryable`])
//!   and requeues the batch — the zero-loss failover path;
//! * **coalesced** streams are fair-picked (rotor order, bounded by
//!   `coalesce_width`) into a cross-stream
//!   [`StreamCoalescer::tick_refs`] batch over a [`FarmCnBackend`].
//!
//! Admission units (1 unit = 1 sample) are released only when their
//! sample has executed — or when the request is refused downstream — so
//! the in-flight window measures real outstanding device work and a
//! full window is honest `Busy` backpressure.
//!
//! The engine room holds the registry lock for the duration of a drain
//! round; rounds are kept short (one `chunk` per stream), and close
//! handlers poll with the lock released between attempts.
//!
//! With [`ServeConfig::health`] enabled a fourth kind of thread runs —
//! the **health watcher** (`fgp-serve-health`) — sampling the unified
//! registry snapshot on a fixed cadence into a [`HealthState`], and
//! sticky routing turns health-aware: new pins, failover re-pins, and a
//! per-round proactive drain all avoid devices whose
//! [`device_score`](crate::obs::health::device_score) has fallen below
//! [`HealthConfig::min_device_score`]. Disabled (the default), none of
//! that exists at runtime: no thread, no clock reads, bitwise-identical
//! outputs (ARCHITECTURE invariant 7 extension).

use std::collections::BTreeMap;
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{
    recv_exec, CnRequestData, CnStream, FarmCnBackend, FarmError, FgpFarm, Metrics, RoutePolicy,
    StreamCoalescer, WorkloadRequest,
};
use crate::fgp::FgpConfig;
use crate::fixed::QFormat;
use crate::gmp::matrix::CMatrix;
use crate::gmp::message::GaussMessage;
use crate::obs::health::{AlertSink, HealthConfig, HealthSnapshot, HealthState};
use crate::obs::{RegistrySnapshot, Telemetry, TelemetryConfig, TraceContext};

use super::admission::{AdmissionController, QuotaPolicy, TenantQuotas};
use super::registry::{SessionRegistry, TenantLedger};
use super::wire::{
    decode_checkpoint, decode_request_traced, encode_checkpoint, encode_reply, write_frame,
    FramePoll, FrameReader, ServeReply, ServeRequest, StatsSnapshot, StreamMode, WIRE_VERSION,
};
use crate::engine::StreamCheckpoint;

/// Serving-tier configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address (`127.0.0.1:0` binds an ephemeral port).
    pub addr: String,
    /// Farm devices to boot.
    pub devices: usize,
    /// Device configuration.
    pub fgp: FgpConfig,
    /// Farm routing policy.
    pub policy: RoutePolicy,
    /// Connection-handler worker threads.
    pub workers: usize,
    /// Admission window: total samples admitted but not yet executed.
    pub max_inflight: usize,
    /// Per-tenant token-bucket quota.
    pub quota: QuotaPolicy,
    /// Sticky-stream samples dispatched per engine-room round.
    pub chunk: usize,
    /// Coalesced streams batched per engine-room round.
    pub coalesce_width: usize,
    /// Backoff hint (ms) carried in `Busy`/`QuotaExceeded` replies.
    pub retry_ms: u32,
    /// Per-stream pending-queue cap (excess pushes get `Busy`).
    pub max_pending_per_stream: usize,
    /// Telemetry: span recording off by default ([`TelemetryConfig`]);
    /// registry counters always run (they back the `STATS` reply).
    pub telemetry: TelemetryConfig,
    /// Operational intelligence ([`HealthConfig`]): off by default — no
    /// watcher thread, no clock reads, bitwise-identical outputs.
    /// Enabled, it starts the `fgp-serve-health` watcher, turns on the
    /// farm's per-device latency tracking, and makes sticky routing
    /// health-aware.
    pub health: HealthConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            devices: 2,
            fgp: FgpConfig::default(),
            policy: RoutePolicy::RoundRobin,
            workers: 4,
            max_inflight: 256,
            quota: QuotaPolicy::default(),
            chunk: 16,
            coalesce_width: 8,
            retry_ms: 5,
            max_pending_per_stream: 1024,
            telemetry: TelemetryConfig::default(),
            health: HealthConfig::default(),
        }
    }
}

/// Recover a lock even if a previous holder panicked: serving state is
/// guarded by invariants, not by the poison bit.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

struct Shared {
    cfg: ServeConfig,
    farm: Arc<FgpFarm>,
    registry: Mutex<SessionRegistry>,
    admission: AdmissionController,
    quotas: Mutex<TenantQuotas>,
    tenants: Mutex<BTreeMap<String, Arc<TenantLedger>>>,
    metrics: Metrics,
    admitted: AtomicU64,
    rejected_busy: AtomicU64,
    rejected_quota: AtomicU64,
    failovers: AtomicU64,
    /// Sticky streams proactively re-pinned off degraded-but-alive
    /// devices (health-aware routing; distinct from `failovers`, which
    /// count re-pins after a device actually failed).
    drains: AtomicU64,
    shutdown: AtomicBool,
    tel: Arc<Telemetry>,
    /// The watcher's state, present only when `cfg.health.enabled` — the
    /// disabled path carries no health state at all.
    health: Option<Mutex<HealthState>>,
}

impl Shared {
    fn ledger(&self, tenant: &str) -> Arc<TenantLedger> {
        Arc::clone(
            lock(&self.tenants)
                .entry(tenant.to_string())
                .or_default(),
        )
    }

    /// The unified registry snapshot: everything the device sessions and
    /// engines fed into the obs registry, plus the serve tier's own
    /// counters, gauges and latency histograms folded in under `serve.*`
    /// names, per-tenant ledger counters under `tenant.<name>.*`, and
    /// per-device farm health under `farm.device<i>.*` — one flat,
    /// sorted view across every layer. This is also exactly what the
    /// health watcher samples and what the anomaly detectors read.
    fn telemetry_snapshot(&self) -> RegistrySnapshot {
        let mut snap = self.tel.registry().snapshot();
        snap.push_counter("serve.admitted", self.admitted.load(Ordering::Relaxed));
        snap.push_counter("serve.rejected_busy", self.rejected_busy.load(Ordering::Relaxed));
        snap.push_counter("serve.rejected_quota", self.rejected_quota.load(Ordering::Relaxed));
        snap.push_counter("serve.failovers", self.failovers.load(Ordering::Relaxed));
        snap.push_counter("serve.drains", self.drains.load(Ordering::Relaxed));
        snap.push_counter("serve.batches", self.metrics.batches.load(Ordering::Relaxed));
        snap.push_counter(
            "serve.batched_requests",
            self.metrics.batched_requests.load(Ordering::Relaxed),
        );
        snap.push_gauge("serve.inflight", self.admission.inflight() as u64);
        snap.push_gauge("serve.inflight_capacity", self.admission.capacity() as u64);
        for (name, ledger) in lock(&self.tenants).iter() {
            let t = ledger.snapshot(name);
            snap.push_counter(&format!("tenant.{name}.requests"), t.requests);
            snap.push_counter(&format!("tenant.{name}.samples"), t.samples);
            snap.push_counter(&format!("tenant.{name}.rejected_quota"), t.rejected_quota);
            snap.push_counter(&format!("tenant.{name}.rejected_busy"), t.rejected_busy);
        }
        for d in self.farm.device_health() {
            let p = format!("farm.device{}", d.device);
            snap.push_counter(&format!("{p}.requests"), d.requests);
            snap.push_counter(&format!("{p}.errors"), d.errors);
            snap.push_gauge(&format!("{p}.ewma_ns"), d.ewma_ns);
            snap.push_gauge(&format!("{p}.live"), u64::from(d.live));
        }
        snap.push_histogram("serve.latency", &self.metrics.latency);
        snap.push_histogram("serve.queue_wait", &self.metrics.queue_wait);
        snap.sort();
        snap
    }

    /// Assemble the health reply: per-device scores always (routing
    /// identity is useful even with the layer off), SLO/alert state only
    /// when the watcher exists.
    fn health_snapshot(&self) -> HealthSnapshot {
        let devices = self.farm.device_health();
        match &self.health {
            Some(h) => lock(h).snapshot(devices),
            None => HealthSnapshot::disabled(devices),
        }
    }

    /// `include_telemetry` is the wire-version gate: a v1 peer gets the
    /// exact v1 `Stats` bytes (empty telemetry section encodes as the
    /// legacy tag), a v2 peer additionally gets the registry snapshot.
    fn snapshot(&self, include_telemetry: bool) -> StatsSnapshot {
        StatsSnapshot {
            latency: self.metrics.snapshot(),
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected_busy: self.rejected_busy.load(Ordering::Relaxed),
            rejected_quota: self.rejected_quota.load(Ordering::Relaxed),
            failovers: self.failovers.load(Ordering::Relaxed),
            tenants: lock(&self.tenants)
                .iter()
                .map(|(name, ledger)| ledger.snapshot(name))
                .collect(),
            telemetry: if include_telemetry {
                self.telemetry_snapshot()
            } else {
                RegistrySnapshot::default()
            },
        }
    }
}

/// The network serving tier: a farm behind a framed TCP protocol with
/// admission control, fair multi-tenant scheduling, SLO metrics, and
/// stream checkpoint/failover. See the module docs for the thread
/// model; see [`super::client::ServeClient`] for the matching client.
pub struct FgpServe {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    engine: Option<JoinHandle<()>>,
    watcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl FgpServe {
    /// Boot the farm, bind the listener, and start the worker pool and
    /// engine room.
    pub fn start(cfg: ServeConfig) -> Result<Self> {
        let tel = Arc::new(Telemetry::new(cfg.telemetry));
        let farm = Arc::new(FgpFarm::start_with_telemetry(
            cfg.devices,
            cfg.fgp,
            cfg.policy,
            Arc::clone(&tel),
        )?);
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding serve listener on {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let quota = cfg.quota;
        let max_inflight = cfg.max_inflight;
        let workers = cfg.workers.max(1);
        // the health layer's entire enabled path hangs off this Option:
        // disabled means no state, no watcher thread, no clock reads
        let health = cfg.health.enabled.then(|| Mutex::new(HealthState::new(cfg.health.clone())));
        if cfg.health.enabled {
            farm.enable_health_tracking();
        }
        let shared = Arc::new(Shared {
            cfg,
            farm,
            registry: Mutex::new(SessionRegistry::new()),
            admission: AdmissionController::new(max_inflight),
            quotas: Mutex::new(TenantQuotas::new(quota)),
            tenants: Mutex::new(BTreeMap::new()),
            metrics: Metrics::new(),
            admitted: AtomicU64::new(0),
            rejected_busy: AtomicU64::new(0),
            rejected_quota: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            drains: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            tel,
            health,
        });

        let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));

        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("fgp-serve-accept".into())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if shared.shutdown.load(Ordering::Acquire) {
                            break;
                        }
                        if let Ok(sock) = conn {
                            // a send failure means the pool is gone:
                            // we're shutting down
                            if conn_tx.send(sock).is_err() {
                                break;
                            }
                        }
                    }
                })
                .expect("spawn serve accept thread")
        };

        let worker_handles = (0..workers)
            .map(|w| {
                let shared = Arc::clone(&shared);
                let conn_rx = Arc::clone(&conn_rx);
                std::thread::Builder::new()
                    .name(format!("fgp-serve-worker-{w}"))
                    .spawn(move || loop {
                        let sock = {
                            let rx = lock(&conn_rx);
                            rx.recv_timeout(Duration::from_millis(100))
                        };
                        match sock {
                            Ok(sock) => {
                                let _ = handle_conn(&shared, sock);
                            }
                            Err(RecvTimeoutError::Timeout) => {
                                if shared.shutdown.load(Ordering::Acquire) {
                                    break;
                                }
                            }
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    })
                    .expect("spawn serve worker")
            })
            .collect();

        let engine = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("fgp-serve-engine".into())
                .spawn(move || {
                    while !shared.shutdown.load(Ordering::Acquire) {
                        if drain_round(&shared) == 0 {
                            std::thread::sleep(Duration::from_micros(300));
                        }
                    }
                })
                .expect("spawn serve engine room")
        };

        // the background watcher: sample the unified registry on a fixed
        // cadence into the detector state. Only exists when enabled.
        let watcher = shared.health.is_some().then(|| {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("fgp-serve-health".into())
                .spawn(move || {
                    let epoch = Instant::now();
                    let interval =
                        Duration::from_millis(shared.cfg.health.watch.interval_ms.max(1));
                    while !shared.shutdown.load(Ordering::Acquire) {
                        let snap = shared.telemetry_snapshot();
                        let t_ns = epoch.elapsed().as_nanos() as u64;
                        if let Some(h) = &shared.health {
                            lock(h).observe(t_ns, snap);
                        }
                        // sleep in short slices so shutdown stays prompt
                        // even with a long sampling interval
                        let mut slept = Duration::ZERO;
                        while slept < interval && !shared.shutdown.load(Ordering::Acquire) {
                            let slice = (interval - slept).min(Duration::from_millis(5));
                            std::thread::sleep(slice);
                            slept += slice;
                        }
                    }
                })
                .expect("spawn serve health watcher")
        });

        Ok(FgpServe {
            shared,
            addr,
            accept: Some(accept),
            engine: Some(engine),
            watcher,
            workers: worker_handles,
        })
    }

    /// The bound listen address (with the resolved ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The underlying farm — churn drivers (tests, the soak bench) kill
    /// and revive devices through this while streams are live.
    pub fn farm(&self) -> Arc<FgpFarm> {
        Arc::clone(&self.shared.farm)
    }

    /// In-process SLO snapshot (the same body a wire-version-2 `Stats`
    /// reply carries, telemetry section included).
    pub fn stats(&self) -> StatsSnapshot {
        self.shared.snapshot(true)
    }

    /// In-process health snapshot (the same body a wire `Health` reply
    /// carries): per-tenant SLO status, firing alerts, per-device
    /// scores. With the health layer off only the device section is
    /// populated.
    pub fn health(&self) -> HealthSnapshot {
        self.shared.health_snapshot()
    }

    /// Attach an [`AlertSink`] to the running watcher; every future
    /// firing/resolved transition is delivered to it. Returns `false`
    /// (and drops the sink) when the health layer is disabled. Sinks
    /// attach post-start because trait objects don't fit the `Clone +
    /// Debug` [`ServeConfig`].
    pub fn add_alert_sink(&self, sink: Box<dyn AlertSink>) -> bool {
        match &self.shared.health {
            Some(h) => {
                lock(h).add_sink(sink);
                true
            }
            None => false,
        }
    }

    /// The server's shared telemetry handle: the span ring every layer
    /// records into and the registry behind the `STATS` telemetry
    /// section. Hand it to [`ServeClient::connect_traced`]
    /// (in-process) to read one request's full span tree.
    ///
    /// [`ServeClient::connect_traced`]: super::client::ServeClient::connect_traced
    pub fn telemetry(&self) -> Arc<Telemetry> {
        Arc::clone(&self.shared.tel)
    }

    /// Stop accepting, drain workers, and join every thread.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.engine.take() {
            let _ = h.join();
        }
        if let Some(h) = self.watcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FgpServe {
    fn drop(&mut self) {
        self.stop();
    }
}

// ---------------------------------------------------------------------
// connection handling
// ---------------------------------------------------------------------

struct ConnState {
    tenant: String,
    ledger: Arc<TenantLedger>,
    /// `min(client, server)` wire version from the handshake; 1 until a
    /// `Hello` arrives, so a pre-handshake `Stats` gets the v1 shape.
    version: u32,
}

fn handle_conn(shared: &Shared, mut sock: TcpStream) -> io::Result<()> {
    sock.set_nodelay(true)?;
    sock.set_read_timeout(Some(Duration::from_millis(50)))?;
    sock.set_write_timeout(Some(Duration::from_secs(10)))?;
    let mut conn =
        ConnState { tenant: "anon".to_string(), ledger: shared.ledger("anon"), version: 1 };
    let mut reader = FrameReader::new();
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            return Ok(());
        }
        match reader.poll(&mut sock)? {
            FramePoll::Pending => continue,
            FramePoll::Eof => return Ok(()),
            FramePoll::Frame(payload) => {
                let reply = handle_frame(shared, &mut conn, &payload);
                write_frame(&mut sock, &encode_reply(&reply))?;
            }
        }
    }
}

/// Quota → admission gates for `units` of work. Returns an early reply
/// on refusal; on success the caller OWNS `units` admission units and
/// must release them. Traced requests get a `serve.gate` span either way
/// (a0 = 1 admitted, 0 refused).
fn gate(
    shared: &Shared,
    conn: &ConnState,
    units: u64,
    ctx: Option<TraceContext>,
) -> Option<ServeReply> {
    let t0 = ctx.map_or(0, |_| shared.tel.now_ns());
    let refusal = gate_inner(shared, conn, units);
    if let Some(c) = ctx {
        let admitted = u64::from(refusal.is_none());
        shared.tel.span(c.child(), c.span_id, "serve.gate", "serve", t0, admitted);
    }
    refusal
}

fn gate_inner(shared: &Shared, conn: &ConnState, units: u64) -> Option<ServeReply> {
    let admitted = lock(&shared.quotas).admit(&conn.tenant, units, Instant::now());
    if !admitted {
        conn.ledger.rejected_quota.fetch_add(1, Ordering::Relaxed);
        shared.rejected_quota.fetch_add(1, Ordering::Relaxed);
        return Some(ServeReply::QuotaExceeded { retry_ms: shared.cfg.retry_ms });
    }
    if !shared.admission.try_acquire(units as usize) {
        conn.ledger.rejected_busy.fetch_add(1, Ordering::Relaxed);
        shared.rejected_busy.fetch_add(1, Ordering::Relaxed);
        return Some(ServeReply::Busy { retry_ms: shared.cfg.retry_ms });
    }
    shared.admitted.fetch_add(units, Ordering::Relaxed);
    None
}

fn farm_retryable(err: &anyhow::Error) -> bool {
    err.downcast_ref::<FarmError>().map(FarmError::is_retryable).unwrap_or(false)
}

/// Run a farm call, retrying across members while failures stay
/// retryable (at most one attempt per farm device).
fn with_farm_retry<T>(shared: &Shared, f: impl Fn() -> Result<T>) -> Result<T> {
    let attempts = shared.farm.size().max(1);
    let mut last = None;
    for _ in 0..attempts {
        match f() {
            Ok(v) => return Ok(v),
            Err(e) => {
                let retry = farm_retryable(&e);
                last = Some(e);
                if !retry {
                    break;
                }
            }
        }
    }
    Err(last.expect("at least one attempt"))
}

fn error_reply(err: &anyhow::Error) -> ServeReply {
    ServeReply::Error { retryable: farm_retryable(err), message: format!("{err:#}") }
}

fn one_shot<T>(
    shared: &Shared,
    conn: &ConnState,
    units: u64,
    ctx: Option<TraceContext>,
    run: impl Fn(Option<TraceContext>) -> Result<T>,
    ok: impl FnOnce(T) -> ServeReply,
) -> ServeReply {
    if let Some(refused) = gate(shared, conn, units, ctx) {
        return refused;
    }
    // the execute span's context is the parent the farm device hangs its
    // own span under, so the tree reads serve.execute → farm.device → …
    let exec_ctx = ctx.map(|c| c.child());
    let t0_ns = exec_ctx.map_or(0, |_| shared.tel.now_ns());
    let t0 = Instant::now();
    let result = with_farm_retry(shared, || run(exec_ctx));
    if let (Some(parent), Some(ec)) = (ctx, exec_ctx) {
        shared.tel.span(ec, parent.span_id, "serve.execute", "serve", t0_ns, units);
    }
    shared.admission.release(units as usize);
    conn.ledger.requests.fetch_add(1, Ordering::Relaxed);
    match result {
        Ok(v) => {
            shared.metrics.latency.record(t0.elapsed());
            shared.metrics.completed.fetch_add(1, Ordering::Relaxed);
            conn.ledger.samples.fetch_add(units, Ordering::Relaxed);
            ok(v)
        }
        Err(e) => {
            shared.metrics.failed.fetch_add(1, Ordering::Relaxed);
            error_reply(&e)
        }
    }
}

/// Pick a pin for a new/resumed stream, excluding `avoid`. With the
/// health layer on, sticky pins prefer members scoring at least
/// `min_device_score` (falling back to any live member — degraded
/// beats refused).
fn pick_device(shared: &Shared, mode: StreamMode, avoid: &[usize]) -> Result<usize, ServeReply> {
    match mode {
        // coalesced streams route per batch; the pin is informational
        StreamMode::Coalesced => Ok(0),
        StreamMode::Sticky => {
            let picked = if shared.cfg.health.enabled {
                shared.farm.pick_healthy(avoid, shared.cfg.health.min_device_score)
            } else {
                shared.farm.pick(avoid)
            };
            picked.map_err(|e| ServeReply::Error {
                retryable: e.is_retryable(),
                message: e.to_string(),
            })
        }
    }
}

/// Short span name for one request kind (the `serve.*` request span).
fn request_span_name(req: &ServeRequest) -> &'static str {
    match req {
        ServeRequest::Hello { .. } => "serve.hello",
        ServeRequest::CnUpdate { .. } => "serve.cn_update",
        ServeRequest::Chain { .. } => "serve.chain",
        ServeRequest::OpenStream { .. } => "serve.open_stream",
        ServeRequest::Resume { .. } => "serve.resume",
        ServeRequest::Push { .. } => "serve.push",
        ServeRequest::Poll { .. } => "serve.poll",
        ServeRequest::Checkpoint { .. } => "serve.checkpoint",
        ServeRequest::CloseStream { .. } => "serve.close_stream",
        ServeRequest::Stats => "serve.stats",
        ServeRequest::Health => "serve.health",
    }
}

fn handle_frame(shared: &Shared, conn: &mut ConnState, payload: &[u8]) -> ServeReply {
    let (req, wire_ctx) = match decode_request_traced(payload) {
        Ok(v) => v,
        Err(e) => return ServeReply::Error { retryable: false, message: e.to_string() },
    };
    // the request span: child of the envelope's (client) span when one
    // arrived, a fresh root when the server itself is the trace origin
    let ctx = if shared.tel.enabled() {
        Some(wire_ctx.map_or_else(TraceContext::mint, |c| c.child()))
    } else {
        None
    };
    let parent = wire_ctx.map_or(0, |c| c.span_id);
    let t0 = ctx.map_or(0, |_| shared.tel.now_ns());
    let name = request_span_name(&req);
    let reply = dispatch_request(shared, conn, req, ctx);
    if let Some(c) = ctx {
        shared.tel.span(c, parent, name, "serve", t0, payload.len() as u64);
    }
    reply
}

fn dispatch_request(
    shared: &Shared,
    conn: &mut ConnState,
    req: ServeRequest,
    ctx: Option<TraceContext>,
) -> ServeReply {
    match req {
        ServeRequest::Hello { tenant, version } => {
            conn.ledger = shared.ledger(&tenant);
            conn.tenant = tenant;
            conn.version = version.clamp(1, WIRE_VERSION);
            ServeReply::Welcome { version: conn.version }
        }
        ServeRequest::CnUpdate { x, y, a } => one_shot(
            shared,
            conn,
            1,
            ctx,
            |c| {
                let req = WorkloadRequest::cn(&CnRequestData {
                    x: x.clone(),
                    y: y.clone(),
                    a: a.clone(),
                })?;
                let exec = shared.farm.run_traced(req, c)?;
                Ok(exec.output()?.clone())
            },
            |msg| ServeReply::Output { msg },
        ),
        ServeRequest::Chain { prior, sections } => {
            if sections.is_empty() {
                return ServeReply::Error {
                    retryable: false,
                    message: "chain request needs at least one section".into(),
                };
            }
            one_shot(
                shared,
                conn,
                sections.len() as u64,
                ctx,
                |c| {
                    let req = WorkloadRequest::chain(&prior, &sections)?;
                    let exec = shared.farm.run_traced(req, c)?;
                    Ok(exec.output()?.clone())
                },
                |msg| ServeReply::Output { msg },
            )
        }
        ServeRequest::OpenStream { name, mode, prior, precision } => {
            let device = match pick_device(shared, mode, &[]) {
                Ok(d) => d,
                Err(reply) => return reply,
            };
            let id = lock(&shared.registry).open(
                name,
                Arc::clone(&conn.ledger),
                mode,
                prior,
                0,
                device,
                precision,
            );
            ServeReply::StreamOpened { stream: id, device: device as u32 }
        }
        ServeRequest::Resume { name, mode, checkpoint, precision } => {
            let ckpt = match decode_checkpoint(&checkpoint) {
                Ok(c) => c,
                Err(e) => {
                    return ServeReply::Error { retryable: false, message: e.to_string() }
                }
            };
            if ckpt.stream_name != name {
                return ServeReply::Error {
                    retryable: false,
                    message: format!(
                        "checkpoint belongs to stream '{}' but the request names '{}'",
                        ckpt.stream_name, name
                    ),
                };
            }
            let device = match pick_device(shared, mode, &[]) {
                Ok(d) => d,
                Err(reply) => return reply,
            };
            // precision is a session property, not part of the
            // checkpoint image: a fixed-point stream keeps its width
            // across resume only when the client re-declares it here
            let id = lock(&shared.registry).open(
                name,
                Arc::clone(&conn.ledger),
                mode,
                ckpt.state,
                ckpt.samples,
                device,
                precision,
            );
            ServeReply::StreamOpened { stream: id, device: device as u32 }
        }
        ServeRequest::Push { stream, samples } => {
            let n = samples.len();
            if n == 0 {
                return ServeReply::Error {
                    retryable: false,
                    message: "push carries no samples".into(),
                };
            }
            let mut reg = lock(&shared.registry);
            let Some(entry) = reg.get_mut(stream) else {
                return ServeReply::Error {
                    retryable: false,
                    message: format!("no open stream {stream}"),
                };
            };
            if let Some(err) = &entry.error {
                return ServeReply::Error { retryable: false, message: err.clone() };
            }
            if entry.cn.pending() + n > shared.cfg.max_pending_per_stream {
                conn.ledger.rejected_busy.fetch_add(1, Ordering::Relaxed);
                shared.rejected_busy.fetch_add(1, Ordering::Relaxed);
                return ServeReply::Busy { retry_ms: shared.cfg.retry_ms };
            }
            if let Some(refused) = gate(shared, conn, n as u64, ctx) {
                return refused;
            }
            for (y, a) in samples {
                entry.cn.push(y, a);
            }
            entry.inflight += n;
            // the engine room drains these samples asynchronously: hand
            // it the push's span context so chunk/device spans still
            // attach to this request's trace
            if ctx.is_some() {
                entry.ctx = ctx;
                entry.queued_ns = shared.tel.now_ns();
            }
            conn.ledger.requests.fetch_add(1, Ordering::Relaxed);
            ServeReply::Ack {
                stream,
                accepted: n as u32,
                pending: entry.cn.pending() as u32,
            }
        }
        ServeRequest::Poll { stream } => {
            let reg = lock(&shared.registry);
            let Some(entry) = reg.get(stream) else {
                return ServeReply::Error {
                    retryable: false,
                    message: format!("no open stream {stream}"),
                };
            };
            if let Some(err) = &entry.error {
                return ServeReply::Error { retryable: false, message: err.clone() };
            }
            ServeReply::StreamState {
                stream,
                samples_done: entry.cn.samples_done,
                pending: entry.cn.pending() as u32,
                device: entry.device as u32,
                failovers: entry.failovers,
                state: entry.cn.state.clone(),
            }
        }
        ServeRequest::Checkpoint { stream } => {
            let reg = lock(&shared.registry);
            let Some(entry) = reg.get(stream) else {
                return ServeReply::Error {
                    retryable: false,
                    message: format!("no open stream {stream}"),
                };
            };
            // the checkpoint is the COMMITTED state: pending samples are
            // deliberately excluded (they have not executed; the client
            // re-pushes anything it still wants after a resume)
            let ckpt = StreamCheckpoint {
                stream_name: entry.name.clone(),
                samples: entry.cn.samples_done,
                state: entry.cn.state.clone(),
                boundaries: Vec::new(),
            };
            ServeReply::CheckpointData { bytes: encode_checkpoint(&ckpt) }
        }
        ServeRequest::CloseStream { stream } => loop {
            {
                let mut reg = lock(&shared.registry);
                let Some(entry) = reg.get(stream) else {
                    return ServeReply::Error {
                        retryable: false,
                        message: format!("no open stream {stream}"),
                    };
                };
                if entry.error.is_some() || entry.cn.pending() == 0 {
                    let entry = reg.close(stream).expect("entry exists under lock");
                    // anything still queued (error path) gives its
                    // admission units back
                    shared.admission.release(entry.inflight);
                    return match entry.error {
                        Some(err) => ServeReply::Error { retryable: false, message: err },
                        None => ServeReply::Closed {
                            stream,
                            samples_done: entry.cn.samples_done,
                            failovers: entry.failovers,
                            state: entry.cn.state,
                        },
                    };
                }
            }
            if shared.shutdown.load(Ordering::Acquire) {
                return ServeReply::Error {
                    retryable: true,
                    message: "server shutting down before the stream drained".into(),
                };
            }
            std::thread::sleep(Duration::from_micros(200));
        },
        ServeRequest::Stats => ServeReply::Stats(shared.snapshot(conn.version >= 2)),
        // v2-gated like the trace envelope: a v1 peer that somehow sends
        // tag 11 gets a terminal error, never bytes it can't decode
        ServeRequest::Health => {
            if conn.version >= 2 {
                ServeReply::Health(shared.health_snapshot())
            } else {
                ServeReply::Error {
                    retryable: false,
                    message: "HEALTH needs wire version 2: send a v2 HELLO first".into(),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// engine room
// ---------------------------------------------------------------------

/// One drain round; returns samples executed (0 = idle).
fn drain_round(shared: &Shared) -> u64 {
    let farm = &shared.farm;
    let mut reg = lock(&shared.registry);
    let mut advanced = 0u64;

    // --- sticky streams: one chain chunk per stream, dispatched to the
    // pinned devices concurrently, then collected
    struct Job {
        id: u64,
        batch: Vec<(GaussMessage, CMatrix)>,
        device: usize,
        t0: Instant,
        rx: std::sync::mpsc::Receiver<Result<crate::engine::Execution>>,
        /// (chunk span ctx, its parent span id, chunk start ns) when the
        /// drained samples belong to a traced push.
        trace: Option<(TraceContext, u64, u64)>,
    }
    let mut jobs: Vec<Job> = Vec::new();
    // health-aware draining: with the layer on, score the members once
    // per round; streams pinned to a degraded-but-alive device re-pin to
    // a qualifying member BEFORE the chunk dispatches, so the move costs
    // nothing — no sample is in flight when the pin changes
    let min_score = shared.cfg.health.min_device_score;
    let health = (shared.cfg.health.enabled && min_score > 0.0)
        .then(|| farm.device_health());
    for id in reg.fair_ids(StreamMode::Sticky) {
        let entry = reg.get_mut(id).expect("fair_ids returns live ids");
        if let Some(health) = &health {
            let degraded = health
                .iter()
                .any(|h| h.device as usize == entry.device && h.live && h.score < min_score);
            if degraded {
                if let Ok(next) = farm.pick_healthy(&[entry.device], min_score) {
                    let qualifies = health
                        .iter()
                        .any(|h| h.device as usize == next && h.score >= min_score);
                    if qualifies && next != entry.device {
                        entry.device = next;
                        shared.drains.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
        let batch = entry.cn.take(shared.cfg.chunk);
        if batch.is_empty() {
            continue;
        }
        // a declared stream width rides on every chunk; failover re-pins
        // keep `entry.precision`, so the replacement device executes the
        // requeued batch at the same width
        let built = WorkloadRequest::chain(&entry.cn.state, &batch).map(|req| {
            match entry.precision {
                Some(f) => req.with_precision(f),
                None => req,
            }
        });
        match built {
            Ok(req) => {
                // queue-wait span: push arrival → this dispatch; the
                // cursor then resets so a follow-on chunk measures its
                // own wait, not the whole queue history again
                let trace = match entry.ctx {
                    Some(c) if shared.tel.enabled() => {
                        let now = shared.tel.now_ns();
                        shared.tel.span_at(
                            c.child(),
                            c.span_id,
                            "serve.queue_wait",
                            "serve",
                            entry.queued_ns,
                            now.saturating_sub(entry.queued_ns),
                            batch.len() as u64,
                        );
                        entry.queued_ns = now;
                        Some((c.child(), c.span_id, now))
                    }
                    _ => None,
                };
                let t0 = Instant::now();
                let rx = farm.submit_to_traced(entry.device, req, trace.map(|(cc, _, _)| cc));
                jobs.push(Job { id, batch, device: entry.device, t0, rx, trace });
            }
            Err(e) => {
                // malformed samples: terminal for the stream, but the
                // queue stays intact for the close report
                entry.cn.requeue_front(batch);
                entry.error = Some(format!("{e:#}"));
                shared.admission.release(entry.inflight);
                entry.inflight = 0;
                shared.metrics.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    for job in jobs {
        let entry = reg.get_mut(job.id).expect("entry outlives its job");
        let n = job.batch.len();
        let out = recv_exec(&job.rx, job.device).and_then(|exec| Ok(exec.output()?.clone()));
        if let Some((cc, parent, t0_ns)) = job.trace {
            shared.tel.span(cc, parent, "serve.chunk", "serve", t0_ns, n as u64);
        }
        match out {
            Ok(state) => {
                entry.cn.commit(state, n as u64);
                entry.inflight -= n;
                shared.admission.release(n);
                entry.tenant.samples.fetch_add(n as u64, Ordering::Relaxed);
                shared.metrics.latency.record(job.t0.elapsed());
                shared.metrics.completed.fetch_add(1, Ordering::Relaxed);
                advanced += n as u64;
            }
            Err(e) if farm_retryable(&e) => {
                // the chunk never executed: requeue it unchanged and
                // re-pin the stream on a surviving member — nothing is
                // lost, nothing duplicated. Health-aware when enabled:
                // prefer a member that is not itself degraded.
                entry.cn.requeue_front(job.batch);
                let next = if shared.cfg.health.enabled {
                    farm.pick_healthy(&[job.device], shared.cfg.health.min_device_score)
                } else {
                    farm.pick(&[job.device])
                };
                if let Ok(next) = next {
                    entry.device = next;
                    entry.failovers += 1;
                    shared.failovers.fetch_add(1, Ordering::Relaxed);
                }
                // if every member is down the samples stay queued; a
                // revive (or a later pick) resumes the stream
            }
            Err(e) => {
                entry.cn.requeue_front(job.batch);
                entry.error = Some(format!("{e:#}"));
                shared.admission.release(entry.inflight);
                entry.inflight = 0;
                shared.metrics.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    // --- coalesced streams: fair-picked cross-stream batch. A batch
    // only ever coalesces streams of one declared width — the fair picks
    // are partitioned by precision so a mixed population cannot blend
    // formats inside one device program.
    let fair: Vec<u64> = reg
        .fair_ids(StreamMode::Coalesced)
        .into_iter()
        .take(shared.cfg.coalesce_width)
        .collect();
    let mut groups: Vec<(Option<QFormat>, Vec<u64>)> = Vec::new();
    for id in fair {
        let p = reg.get(id).expect("picked ids are live").precision;
        match groups.iter_mut().find(|(g, _)| *g == p) {
            Some((_, ids)) => ids.push(id),
            None => groups.push((p, vec![id])),
        }
    }
    for (precision, picked) in groups {
        // move the CnStreams out so tick_refs can borrow them all
        // mutably at once; a cheap placeholder stands in
        let mut moved: Vec<(u64, CnStream, u64)> = picked
            .iter()
            .map(|id| {
                let entry = reg.get_mut(*id).expect("picked ids are live");
                let before = entry.cn.samples_done;
                let cn = std::mem::replace(
                    &mut entry.cn,
                    CnStream::new(GaussMessage::isotropic(1, 1.0)),
                );
                (*id, cn, before)
            })
            .collect();
        let t0 = Instant::now();
        let t0_ns = if shared.tel.enabled() { shared.tel.now_ns() } else { 0 };
        let mut backend = match precision {
            Some(f) => FarmCnBackend::with_precision(Arc::clone(farm), f),
            None => FarmCnBackend::new(Arc::clone(farm)),
        };
        let tick = {
            let mut refs: Vec<&mut CnStream> =
                moved.iter_mut().map(|(_, cn, _)| cn).collect();
            StreamCoalescer::tick_refs(&mut backend, &mut refs)
        };
        let mut any = false;
        for (id, cn, before) in moved {
            let entry = reg.get_mut(id).expect("picked ids are live");
            let delta = cn.samples_done - before;
            entry.cn = cn;
            if delta > 0 {
                any = true;
                // one coalesce span per advanced traced stream: the
                // batch is cross-stream, so each trace sees its share
                if let Some(c) = entry.ctx.filter(|_| shared.tel.enabled()) {
                    shared.tel.span(c.child(), c.span_id, "serve.coalesce", "serve", t0_ns, delta);
                }
                entry.inflight -= delta as usize;
                shared.admission.release(delta as usize);
                entry.tenant.samples.fetch_add(delta, Ordering::Relaxed);
                advanced += delta;
            }
        }
        if any {
            shared.metrics.latency.record(t0.elapsed());
            shared.metrics.completed.fetch_add(1, Ordering::Relaxed);
        }
        // a tick error left the failing streams' samples queued; a
        // retryable one (device churn) is re-dispatched next round and
        // is not a served failure
        if let Err(e) = tick {
            if !farm_retryable(&e) {
                shared.metrics.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    advanced
}
