//! Stream/session registry: the server-side state behind the wire ids.
//!
//! Every open stream is a [`StreamEntry`]: a
//! [`CnStream`](crate::coordinator::CnStream) (committed recursive state
//! + pending sample queue, with the take/requeue/commit zero-loss
//! accounting), its tenant ledger, its scheduling mode, and — for sticky
//! streams — its device pin and failover count. The registry hands out
//! monotonically increasing `u64` ids; connection handlers mutate
//! entries under the registry lock while the engine room drains them.
//!
//! [`TenantLedger`] rows are shared (`Arc`) between the registry, the
//! connection handlers and the `STATS` reply — counters are atomics, so
//! per-tenant throughput accounting never takes a lock on the hot path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::coordinator::CnStream;
use crate::fixed::QFormat;
use crate::gmp::message::GaussMessage;

use super::admission::FairRotor;
use super::wire::{StreamMode, TenantSnapshot};

/// Lock-free per-tenant accounting row, shared by reference.
#[derive(Debug, Default)]
pub struct TenantLedger {
    /// Requests served (one-shots and pushes).
    pub requests: AtomicU64,
    /// Stream samples executed.
    pub samples: AtomicU64,
    /// Requests refused by quota.
    pub rejected_quota: AtomicU64,
    /// Requests refused by the admission window.
    pub rejected_busy: AtomicU64,
}

impl TenantLedger {
    /// Snapshot this ledger as a wire row.
    pub fn snapshot(&self, tenant: &str) -> TenantSnapshot {
        TenantSnapshot {
            tenant: tenant.to_string(),
            requests: self.requests.load(Ordering::Relaxed),
            samples: self.samples.load(Ordering::Relaxed),
            rejected_quota: self.rejected_quota.load(Ordering::Relaxed),
            rejected_busy: self.rejected_busy.load(Ordering::Relaxed),
        }
    }
}

/// One open stream as the server tracks it.
pub struct StreamEntry {
    /// Stream name (checkpoints validate against it).
    pub name: String,
    /// Owning tenant's ledger.
    pub tenant: Arc<TenantLedger>,
    /// Scheduling mode.
    pub mode: StreamMode,
    /// Committed state + pending queue (zero-loss accounting).
    pub cn: CnStream,
    /// Device pin (sticky mode; coalesced streams route per batch).
    pub device: usize,
    /// Failovers this stream has survived.
    pub failovers: u32,
    /// Admission units held by queued-but-unexecuted samples.
    pub inflight: usize,
    /// Terminal error: set once a non-retryable failure occurs;
    /// surfaced to the client on the next poll/push/close.
    pub error: Option<String>,
    /// Fixed-point format every chunk of this stream executes under, or
    /// `None` for the executing device's configured default. Declared at
    /// open/resume; a width never changes silently mid-stream.
    pub precision: Option<QFormat>,
    /// Parent span for the samples currently queued (the context of the
    /// push that enqueued them); `None` on untraced streams.
    pub ctx: Option<crate::obs::TraceContext>,
    /// Telemetry clock reading when the queued samples arrived — the
    /// start of the `serve.queue_wait` span the engine room records.
    pub queued_ns: u64,
}

/// Id-keyed stream table plus the fairness rotor the engine room visits
/// it with.
pub struct SessionRegistry {
    streams: HashMap<u64, StreamEntry>,
    next_id: u64,
    rotor: FairRotor,
}

impl Default for SessionRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl SessionRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        SessionRegistry { streams: HashMap::new(), next_id: 1, rotor: FairRotor::new() }
    }

    /// Register a stream and return its wire id.
    #[allow(clippy::too_many_arguments)]
    pub fn open(
        &mut self,
        name: String,
        tenant: Arc<TenantLedger>,
        mode: StreamMode,
        prior: GaussMessage,
        samples_done: u64,
        device: usize,
        precision: Option<QFormat>,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let mut cn = CnStream::new(prior);
        cn.samples_done = samples_done;
        self.streams.insert(
            id,
            StreamEntry {
                name,
                tenant,
                mode,
                cn,
                device,
                failovers: 0,
                inflight: 0,
                error: None,
                precision,
                ctx: None,
                queued_ns: 0,
            },
        );
        id
    }

    /// Look up a stream.
    pub fn get(&self, id: u64) -> Option<&StreamEntry> {
        self.streams.get(&id)
    }

    /// Look up a stream mutably.
    pub fn get_mut(&mut self, id: u64) -> Option<&mut StreamEntry> {
        self.streams.get_mut(&id)
    }

    /// Remove a stream, returning its entry (the handler releases any
    /// remaining admission units from it).
    pub fn close(&mut self, id: u64) -> Option<StreamEntry> {
        self.streams.remove(&id)
    }

    /// Open stream count.
    pub fn len(&self) -> usize {
        self.streams.len()
    }

    /// Whether no streams are open.
    pub fn is_empty(&self) -> bool {
        self.streams.is_empty()
    }

    /// Ids in this round's fair visiting order: ascending ids, rotated
    /// one further per call, filtered to `mode`. Sorting makes the
    /// rotation deterministic; rotating makes it fair (no stream is
    /// persistently drained first).
    pub fn fair_ids(&mut self, mode: StreamMode) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .streams
            .iter()
            .filter(|(_, e)| e.mode == mode && e.error.is_none() && e.cn.pending() > 0)
            .map(|(id, _)| *id)
            .collect();
        ids.sort_unstable();
        let order = self.rotor.order(ids.len());
        order.into_iter().map(|i| ids[i]).collect()
    }

    /// Total pending samples across all streams.
    pub fn total_pending(&self) -> usize {
        self.streams.values().map(|e| e.cn.pending()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prior() -> GaussMessage {
        GaussMessage::isotropic(2, 1.0)
    }

    fn push_n(e: &mut StreamEntry, n: usize) {
        for _ in 0..n {
            e.cn.push(prior(), crate::gmp::matrix::CMatrix::identity(2));
        }
    }

    #[test]
    fn ids_are_unique_and_entries_close() {
        let mut r = SessionRegistry::new();
        let t = Arc::new(TenantLedger::default());
        let a = r.open("s".into(), Arc::clone(&t), StreamMode::Sticky, prior(), 0, 0, None);
        let b = r.open(
            "s".into(),
            Arc::clone(&t),
            StreamMode::Sticky,
            prior(),
            7,
            1,
            Some(QFormat::q5_10()),
        );
        assert_ne!(a, b);
        assert_eq!(r.len(), 2);
        assert_eq!(r.get(b).unwrap().cn.samples_done, 7);
        assert_eq!(r.get(b).unwrap().precision, Some(QFormat::q5_10()));
        assert_eq!(r.get(a).unwrap().precision, None, "default width unless declared");
        assert!(r.close(a).is_some());
        assert!(r.close(a).is_none());
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn fair_ids_rotate_and_filter() {
        let mut r = SessionRegistry::new();
        let t = Arc::new(TenantLedger::default());
        let ids: Vec<u64> = (0..3)
            .map(|i| {
                r.open(format!("s{i}"), Arc::clone(&t), StreamMode::Sticky, prior(), 0, 0, None)
            })
            .collect();
        let coalesced =
            r.open("c".into(), Arc::clone(&t), StreamMode::Coalesced, prior(), 0, 0, None);
        for id in ids.iter().chain([&coalesced]) {
            push_n(r.get_mut(*id).unwrap(), 2);
        }
        // errored and drained streams are excluded
        r.get_mut(ids[1]).unwrap().error = Some("boom".into());
        let round1 = r.fair_ids(StreamMode::Sticky);
        assert_eq!(round1, vec![ids[0], ids[2]]);
        let round2 = r.fair_ids(StreamMode::Sticky);
        assert_eq!(round2, vec![ids[2], ids[0]], "rotation advanced");
        assert_eq!(r.fair_ids(StreamMode::Coalesced), vec![coalesced]);
        assert_eq!(r.total_pending(), 8);
    }
}
