//! Blocking client for the serve wire protocol.
//!
//! One [`ServeClient`] per connection: it performs the
//! `Hello`/`Welcome` handshake on connect (verifying
//! [`WIRE_VERSION`]), then exposes a typed helper per request. Helpers
//! honour the server's backpressure contract — a `Busy` or
//! `QuotaExceeded` reply is retried after the server-suggested backoff,
//! up to a bounded number of attempts — while the raw [`ServeClient::call`]
//! surface lets tests and admission-aware callers observe refusals
//! directly.

use std::net::{TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::fixed::QFormat;
use crate::gmp::matrix::CMatrix;
use crate::gmp::message::GaussMessage;
use crate::obs::health::HealthSnapshot;
use crate::obs::{Telemetry, TraceContext};

use super::wire::{
    decode_reply, encode_request_traced, read_frame, write_frame, ServeReply, ServeRequest,
    StatsSnapshot, StreamMode, WIRE_VERSION,
};

/// How many times the retrying helpers re-submit after a `Busy` or
/// `QuotaExceeded` reply before giving up.
const MAX_RETRIES: usize = 2000;

/// A stream's progress as reported by `Poll`.
#[derive(Clone, Debug)]
pub struct StreamStatus {
    /// Samples executed and committed.
    pub samples_done: u64,
    /// Samples queued but not yet executed.
    pub pending: u32,
    /// Current device pin.
    pub device: u32,
    /// Failovers survived.
    pub failovers: u32,
    /// Committed recursive state.
    pub state: GaussMessage,
}

/// A drained stream's final report from `CloseStream`.
#[derive(Clone, Debug)]
pub struct StreamClosed {
    /// Total samples executed.
    pub samples_done: u64,
    /// Failovers survived.
    pub failovers: u32,
    /// Final recursive state.
    pub state: GaussMessage,
}

/// Blocking connection to an [`FgpServe`](super::FgpServe) front door.
pub struct ServeClient {
    sock: TcpStream,
    /// `min(client, server)` wire version agreed in the handshake; trace
    /// envelopes are only sent when this is ≥ 2.
    version: u32,
    /// Client-side telemetry ([`ServeClient::connect_traced`]): every
    /// call mints a root [`TraceContext`], records a `client.request`
    /// span, and ships the context in the frame's trace envelope.
    tel: Option<Arc<Telemetry>>,
    /// Trace id of the most recent traced call (0 before the first).
    last_trace_id: u64,
}

impl ServeClient {
    /// Connect and handshake as `tenant`.
    pub fn connect(addr: impl ToSocketAddrs, tenant: &str) -> Result<Self> {
        Self::handshake(addr, tenant, None)
    }

    /// [`ServeClient::connect`] with a telemetry handle — typically the
    /// server's own ([`FgpServe::telemetry`](super::FgpServe::telemetry))
    /// in-process, so client and server spans land in one ring and one
    /// request reads as one tree from socket to device cycles.
    pub fn connect_traced(
        addr: impl ToSocketAddrs,
        tenant: &str,
        tel: Arc<Telemetry>,
    ) -> Result<Self> {
        Self::handshake(addr, tenant, Some(tel))
    }

    fn handshake(
        addr: impl ToSocketAddrs,
        tenant: &str,
        tel: Option<Arc<Telemetry>>,
    ) -> Result<Self> {
        let sock = TcpStream::connect(addr).context("connecting to serve front door")?;
        sock.set_nodelay(true)?;
        let mut client = ServeClient { sock, version: WIRE_VERSION, tel, last_trace_id: 0 };
        let hello = ServeRequest::Hello { tenant: tenant.to_string(), version: WIRE_VERSION };
        match client.call(&hello)? {
            // the server replies with min(client, server): anything in
            // 1..=ours is speakable, newer-than-ours is not
            ServeReply::Welcome { version } if (1..=WIRE_VERSION).contains(&version) => {
                client.version = version;
                Ok(client)
            }
            ServeReply::Welcome { version } => {
                bail!("server speaks wire version {version}, client speaks {WIRE_VERSION}")
            }
            other => bail!("expected Welcome, got {other:?}"),
        }
    }

    /// The wire version agreed in the handshake.
    pub fn negotiated_version(&self) -> u32 {
        self.version
    }

    /// Trace id minted for the most recent traced call (0 if untraced) —
    /// the key for filtering the telemetry ring down to one request.
    pub fn last_trace_id(&self) -> u64 {
        self.last_trace_id
    }

    /// Send one request frame and block for its reply frame. Exposes
    /// `Busy`/`QuotaExceeded` verbatim — the typed helpers below retry
    /// them instead.
    pub fn call(&mut self, req: &ServeRequest) -> Result<ServeReply> {
        // a v1 peer would reject the envelope, so only trace on v2+
        let ctx = match &self.tel {
            Some(tel) if tel.enabled() && self.version >= 2 => Some(TraceContext::mint()),
            _ => None,
        };
        let t0 = match (&self.tel, ctx) {
            (Some(tel), Some(_)) => tel.now_ns(),
            _ => 0,
        };
        write_frame(&mut self.sock, &encode_request_traced(req, ctx.as_ref()))?;
        let frame = read_frame(&mut self.sock)?
            .ok_or_else(|| anyhow!("server closed the connection mid-request"))?;
        let reply = decode_reply(&frame)?;
        if let (Some(tel), Some(ctx)) = (&self.tel, ctx) {
            tel.span(ctx, 0, "client.request", "client", t0, frame.len() as u64);
            self.last_trace_id = ctx.trace_id;
        }
        Ok(reply)
    }

    /// [`call`](Self::call), retrying refused admissions with the
    /// server's backoff hint.
    fn call_admitted(&mut self, req: &ServeRequest) -> Result<ServeReply> {
        for _ in 0..MAX_RETRIES {
            match self.call(req)? {
                ServeReply::Busy { retry_ms } | ServeReply::QuotaExceeded { retry_ms } => {
                    std::thread::sleep(Duration::from_millis(u64::from(retry_ms.max(1))));
                }
                reply => return Ok(reply),
            }
        }
        bail!("request still refused after {MAX_RETRIES} backpressure retries")
    }

    /// One-shot compound-node update.
    pub fn cn_update(&mut self, x: GaussMessage, y: GaussMessage, a: CMatrix) -> Result<GaussMessage> {
        match self.call_admitted(&ServeRequest::CnUpdate { x, y, a })? {
            ServeReply::Output { msg } => Ok(msg),
            other => unexpected("CnUpdate", other),
        }
    }

    /// One-shot compound-observation chain.
    pub fn chain(
        &mut self,
        prior: GaussMessage,
        sections: Vec<(GaussMessage, CMatrix)>,
    ) -> Result<GaussMessage> {
        match self.call_admitted(&ServeRequest::Chain { prior, sections })? {
            ServeReply::Output { msg } => Ok(msg),
            other => unexpected("Chain", other),
        }
    }

    /// Open a stream; returns `(stream id, device pin)`.
    pub fn open_stream(
        &mut self,
        name: &str,
        mode: StreamMode,
        prior: GaussMessage,
    ) -> Result<(u64, u32)> {
        let req = ServeRequest::OpenStream { name: name.to_string(), mode, prior, precision: None };
        match self.call_admitted(&req)? {
            ServeReply::StreamOpened { stream, device } => Ok((stream, device)),
            other => unexpected("OpenStream", other),
        }
    }

    /// [`open_stream`](Self::open_stream) with a declared fixed-point
    /// format: every chunk of the stream executes under `fmt` on the
    /// device, regardless of the device's configured default width.
    /// Rides a version-2 tag, so the handshake must have agreed on
    /// wire version ≥ 2.
    pub fn open_stream_fixed(
        &mut self,
        name: &str,
        mode: StreamMode,
        prior: GaussMessage,
        fmt: QFormat,
    ) -> Result<(u64, u32)> {
        if self.version < 2 {
            bail!(
                "declared precision needs wire version 2, but the handshake agreed on {}",
                self.version
            );
        }
        let req = ServeRequest::OpenStream {
            name: name.to_string(),
            mode,
            prior,
            precision: Some(fmt),
        };
        match self.call_admitted(&req)? {
            ServeReply::StreamOpened { stream, device } => Ok((stream, device)),
            other => unexpected("OpenStream", other),
        }
    }

    /// Queue samples onto a stream; returns `(accepted, pending)`.
    pub fn push(
        &mut self,
        stream: u64,
        samples: Vec<(GaussMessage, CMatrix)>,
    ) -> Result<(u32, u32)> {
        match self.call_admitted(&ServeRequest::Push { stream, samples })? {
            ServeReply::Ack { accepted, pending, .. } => Ok((accepted, pending)),
            other => unexpected("Push", other),
        }
    }

    /// Read a stream's progress.
    pub fn poll(&mut self, stream: u64) -> Result<StreamStatus> {
        match self.call(&ServeRequest::Poll { stream })? {
            ServeReply::StreamState { samples_done, pending, device, failovers, state, .. } => {
                Ok(StreamStatus { samples_done, pending, device, failovers, state })
            }
            other => unexpected("Poll", other),
        }
    }

    /// Drain and close a stream, returning its final report.
    pub fn close_stream(&mut self, stream: u64) -> Result<StreamClosed> {
        match self.call(&ServeRequest::CloseStream { stream })? {
            ServeReply::Closed { samples_done, failovers, state, .. } => {
                Ok(StreamClosed { samples_done, failovers, state })
            }
            other => unexpected("CloseStream", other),
        }
    }

    /// Fetch a stream's committed-state checkpoint image.
    pub fn checkpoint(&mut self, stream: u64) -> Result<Vec<u8>> {
        match self.call(&ServeRequest::Checkpoint { stream })? {
            ServeReply::CheckpointData { bytes } => Ok(bytes),
            other => unexpected("Checkpoint", other),
        }
    }

    /// Reopen a stream from a checkpoint image; returns
    /// `(stream id, device pin)`.
    pub fn resume(
        &mut self,
        name: &str,
        mode: StreamMode,
        checkpoint: Vec<u8>,
    ) -> Result<(u64, u32)> {
        let req = ServeRequest::Resume { name: name.to_string(), mode, checkpoint, precision: None };
        match self.call_admitted(&req)? {
            ServeReply::StreamOpened { stream, device } => Ok((stream, device)),
            other => unexpected("Resume", other),
        }
    }

    /// [`resume`](Self::resume) with a declared fixed-point format.
    /// Precision is a session property, not part of the checkpoint
    /// image — a fixed-point stream resumed without re-declaring its
    /// format continues at the device default width.
    pub fn resume_fixed(
        &mut self,
        name: &str,
        mode: StreamMode,
        checkpoint: Vec<u8>,
        fmt: QFormat,
    ) -> Result<(u64, u32)> {
        if self.version < 2 {
            bail!(
                "declared precision needs wire version 2, but the handshake agreed on {}",
                self.version
            );
        }
        let req = ServeRequest::Resume {
            name: name.to_string(),
            mode,
            checkpoint,
            precision: Some(fmt),
        };
        match self.call_admitted(&req)? {
            ServeReply::StreamOpened { stream, device } => Ok((stream, device)),
            other => unexpected("Resume", other),
        }
    }

    /// Fetch the server's SLO snapshot.
    pub fn stats(&mut self) -> Result<StatsSnapshot> {
        match self.call(&ServeRequest::Stats)? {
            ServeReply::Stats(snapshot) => Ok(snapshot),
            other => unexpected("Stats", other),
        }
    }

    /// Fetch the server's health snapshot: per-tenant SLO status,
    /// firing alerts, per-device routing scores. Needs a wire-version-2
    /// handshake (every `connect` against a current server gets one);
    /// the server answers with `enabled: false` and device identity
    /// only when its health layer is off.
    pub fn health(&mut self) -> Result<HealthSnapshot> {
        if self.version < 2 {
            bail!("HEALTH needs wire version 2, but the handshake agreed on {}", self.version);
        }
        match self.call(&ServeRequest::Health)? {
            ServeReply::Health(snapshot) => Ok(snapshot),
            other => unexpected("Health", other),
        }
    }
}

fn unexpected<T>(what: &str, reply: ServeReply) -> Result<T> {
    match reply {
        ServeReply::Error { message, retryable } => {
            Err(anyhow!("{what} failed (retryable: {retryable}): {message}"))
        }
        other => Err(anyhow!("unexpected reply to {what}: {other:?}")),
    }
}
