//! S15 — EM parameter estimation as Gaussian message passing.
//!
//! The paper's RLS example assumes the observation-noise variance and
//! the model coefficients are *known*; every real receiver has to
//! estimate them. Dauwels et al., *Expectation Maximization as Message
//! Passing* (part I) show the estimation itself is message passing: the
//! E-step only needs the **posterior marginals** the engine already
//! produces, and the M-step is a closed-form node-local Gaussian update
//! — exactly the composable rule shape Cox et al. (*A Factor Graph
//! Approach to Automated Design of Bayesian Signal Processing
//! Algorithms*) identify as what makes an inference layer reusable.
//! This subsystem runs that loop natively on the engine surface:
//!
//! * [`param`] — [`EmParameter`] (E-step accumulation + closed-form
//!   M-step) with the first three implementations: observation-noise
//!   variance, process-noise variance, scalar AR/channel coefficient;
//! * [`driver`] — [`EmDriver`], the batch loop mirroring
//!   [`crate::nonlinear::IteratedRelinearization`]: only *data* changes
//!   between rounds, so every round after the first is a session
//!   program-cache hit;
//! * [`online`] — [`OnlineEm`], recursive EM as a plain
//!   [`crate::engine::StreamingWorkload`] wrapper: per-chunk
//!   sufficient-statistic accumulation with exponential forgetting,
//!   served unchanged by `Session::run_stream` and the coordinator's
//!   sticky farm streams;
//! * [`reference`] — the dense chain log-likelihood exact EM must never
//!   decrease (the monotone-ascent pin in `rust/tests/property_em.rs`).
//!
//! The paper's channel-estimation example, made adaptive:
//!
//! ```
//! use fgp_repro::apps::rls::{NoiseEmRls, RlsProblem};
//! use fgp_repro::em::EmDriver;
//! use fgp_repro::engine::Session;
//!
//! // true noise 0.01, estimate started 10x off
//! let problem = RlsProblem::synthetic(4, 48, 0.01, 17);
//! let mut em = NoiseEmRls::new(problem, 0.1);
//! let report = EmDriver::new().run(&mut Session::golden(), &mut em).unwrap();
//! assert!(report.values[0] < 0.05, "estimate pulled toward the truth");
//! // exact EM: the dense log-likelihood never decreases
//! for w in report.log_likelihood.windows(2) {
//!     assert!(w[1] >= w[0] - 1e-7 * w[0].abs().max(1.0));
//! }
//! ```
//!
//! Contract, pinned by `rust/tests/integration_em.rs` and
//! `rust/tests/property_em.rs`:
//!
//! 1. EM recovers the synthetic ground-truth observation-noise variance
//!    on the RLS fixture to ≤ 5 % relative error;
//! 2. the per-round dense log-likelihood is non-decreasing (exact EM);
//! 3. every round after the first hits the session program cache on
//!    fgp-sim (the round only rebinds data, never reshapes the model).

pub mod driver;
pub mod online;
pub mod param;
pub mod reference;

pub use driver::{EmDriver, EmEstimand, EmOptions, EmReport, EmStop};
pub use online::{
    OnlineEm, OnlineEmOutcome, OnlineNoiseSource, OnlineSection, DEFAULT_BURN_IN,
    DEFAULT_FORGET,
};
pub use param::{EmParameter, Evidence, ObsNoiseVar, ProcessNoiseVar, ScalarCoeff, SuffStats};
pub use reference::{chain_log_likelihood, NoiseSection};
